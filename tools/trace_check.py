#!/usr/bin/env python3
"""Structural validation of an exported Chrome trace-event JSON file.

CI runs this on the trace written by ``cargo run --release --example
trace_export`` (after ``python3 -m json.tool`` has proven it parses).
Checks, per the Chrome trace-event format the exporter targets:

* every event timestamp is a finite number >= 0;
* on each (pid, tid) track, the complete ("X") events do not overlap:
  sorted by start, each event begins at or after the previous one ends
  (small float slack for the exporter's microsecond rounding);
* every flow id has exactly one start ("s") and one finish ("f"), the
  start does not come after the finish, and each endpoint lands inside
  some "X" span on its own track — dangling flow arrows would render as
  arrows into empty space in Perfetto.

Usage: python3 tools/trace_check.py trace.json
"""

import json
import math
import sys

# Microseconds of slack: the exporter rounds ts and dur to 3 decimals
# independently, so a slice end (ts + dur) can sit up to 1e-3 us away from
# a flow timestamp rounded from the same instant — allow twice that.
EPS = 2e-3


def fail(msg: str) -> None:
    print(f"trace_check FAILED: {msg}", file=sys.stderr)
    sys.exit(1)


def main() -> None:
    if len(sys.argv) != 2:
        fail("usage: trace_check.py <trace.json>")
    with open(sys.argv[1]) as f:
        doc = json.load(f)
    events = doc.get("traceEvents")
    if not isinstance(events, list) or not events:
        fail("no traceEvents array")

    slices = {}  # (pid, tid) -> [(ts, ts+dur)]
    flows = {}  # id -> {"s": [...], "f": [...]}
    for ev in events:
        ph = ev.get("ph")
        if ph == "M":
            continue
        ts = ev.get("ts")
        if not isinstance(ts, (int, float)) or not math.isfinite(ts) or ts < 0:
            fail(f"bad timestamp {ts!r} on {ev!r}")
        track = (ev.get("pid"), ev.get("tid"))
        if ph == "X":
            dur = ev.get("dur")
            if not isinstance(dur, (int, float)) or not math.isfinite(dur) or dur < 0:
                fail(f"bad duration {dur!r} on {ev!r}")
            slices.setdefault(track, []).append((ts, ts + dur))
        elif ph in ("s", "f"):
            flows.setdefault(ev.get("id"), {"s": [], "f": []})[ph].append((ts, track))
        else:
            fail(f"unexpected phase {ph!r} on {ev!r}")

    if not slices:
        fail("no complete ('X') span events")
    for track, spans in slices.items():
        spans.sort()
        for (a0, a1), (b0, _) in zip(spans, spans[1:]):
            if b0 < a1 - EPS:
                fail(
                    f"track {track}: overlapping spans "
                    f"[{a0:.3f}, {a1:.3f}) and [{b0:.3f}, ...)"
                )

    if not flows:
        fail("no flow ('s'/'f') events — hand-off arrows missing")
    for fid, ends in flows.items():
        if len(ends["s"]) != 1 or len(ends["f"]) != 1:
            fail(
                f"flow {fid!r}: expected exactly one start and one finish, "
                f"got {len(ends['s'])}/{len(ends['f'])}"
            )
        (s_ts, s_track), (f_ts, f_track) = ends["s"][0], ends["f"][0]
        if s_ts > f_ts + EPS:
            fail(f"flow {fid!r}: start {s_ts:.3f} after finish {f_ts:.3f}")
        for name, ts, track in (("start", s_ts, s_track), ("finish", f_ts, f_track)):
            spans = slices.get(track, [])
            if not any(a - EPS <= ts <= b + EPS for a, b in spans):
                fail(
                    f"flow {fid!r}: {name} at {ts:.3f} lands outside every "
                    f"span on track {track}"
                )

    tracks = len(slices)
    print(
        f"trace_check: OK — {sum(len(s) for s in slices.values())} spans on "
        f"{tracks} rank tracks, {len(flows)} flow arrows"
    )


if __name__ == "__main__":
    main()
