#!/usr/bin/env python3
"""Compare emitted BENCH_*.json against the committed baseline checkpoint.

The bench harnesses (`cargo bench --bench hotpath_micro`, `temporal_cadence`,
`fig15_mixed_length`) write machine-readable reports next to Cargo.toml.
This script diffs them against `bench/baseline/BENCH_*.json` and fails on a
>20% regression in the guarded hot-path rows (specialize cost, cached
hot-switch, ragged step time, compiled dispatch, tape-compile cost, traced
compiled step).

Two escape hatches keep the gate honest rather than noisy:

* a baseline tagged ``"seed": true`` is a fresh checkpoint with no real
  numbers yet — structural checks only (the guarded rows must exist);
* an emitted report tagged ``"smoke": true`` timed single iterations
  (the CI ``--test`` mode) — single-sample wall times on shared runners
  are noise, so ratio checks are skipped but structure is still enforced.

To re-seed after an intentional perf change, run the full bench harnesses
locally and then ``tools/bench_compare.py --update-baseline``: it copies the
emitted reports over bench/baseline/ verbatim. Commit the result. (A smoke
report is refused as a baseline — its single-iteration numbers would make
every later full run look like a regression or a miracle.)
"""

import json
import shutil
import sys
from pathlib import Path

ROOT = Path(__file__).resolve().parent.parent
BENCHES = ["hotpath", "temporal", "fig15"]
TOLERANCE = 1.20  # fail when emitted mean exceeds baseline mean by >20%

# the perf-trajectory rows the gate guards (all in BENCH_hotpath.json)
GUARDED = {
    "hotpath": [
        "specialize lowered-C2 -> per-rank plans",
        "engine hot-switch A<->B (cached, batched)",
        "engine train_step dp2 ragged 12x[2,2]",
        "step wall lowered-C2 compiled dispatch",
        "step wall lowered-C2 compiled unfused",
        "kernel launches lowered-C2 fused step",
        "kernel launches lowered-C2 unfused step",
        "compile lowered-C2 -> rank tape",
        "trace_overhead",
        "specialize 256-rank generated strategy",
        "compile 256-rank generated strategy",
        "specialize 1024-rank generated strategy",
        "compile 1024-rank generated strategy",
        "synth 1024-rank search",
    ],
    "temporal": [],
    "fig15": [],
}


def load(path: Path):
    if not path.exists():
        return None
    with path.open() as f:
        return json.load(f)


def rows_by_name(report):
    return {r["name"]: r for r in report.get("rows", [])}


def update_baseline() -> int:
    """Rewrite bench/baseline/ from the emitted reports."""
    baseline_dir = ROOT / "bench" / "baseline"
    baseline_dir.mkdir(parents=True, exist_ok=True)
    failures = []
    for bench in BENCHES:
        emitted_path = ROOT / f"BENCH_{bench}.json"
        emitted = load(emitted_path)
        if emitted is None:
            failures.append(f"{emitted_path} missing — run the bench harnesses first")
            continue
        if emitted.get("smoke"):
            failures.append(
                f"{bench}: emitted report is a --test smoke run — "
                "refusing to seed the baseline with single-iteration timings"
            )
            continue
        missing = [n for n in GUARDED[bench] if n not in rows_by_name(emitted)]
        if missing:
            failures.append(f"{bench}: emitted report lacks guarded rows {missing!r}")
            continue
        dest = baseline_dir / f"BENCH_{bench}.json"
        shutil.copyfile(emitted_path, dest)
        print(f"{bench}: baseline updated from {emitted_path} "
              f"(rev {emitted.get('rev')}, {len(emitted.get('rows', []))} rows)")
    if failures:
        print("\nbench-compare --update-baseline FAILED:", file=sys.stderr)
        for f in failures:
            print(f"  - {f}", file=sys.stderr)
        return 1
    print("bench-compare: baseline rewritten — review and commit bench/baseline/")
    return 0


def main() -> int:
    if "--update-baseline" in sys.argv[1:]:
        return update_baseline()

    failures = []
    for bench in BENCHES:
        emitted_path = ROOT / f"BENCH_{bench}.json"
        baseline_path = ROOT / "bench" / "baseline" / f"BENCH_{bench}.json"
        emitted = load(emitted_path)
        baseline = load(baseline_path)
        if emitted is None:
            failures.append(f"{emitted_path} missing — run the bench harnesses first")
            continue
        if baseline is None:
            failures.append(f"{baseline_path} missing — commit a baseline checkpoint")
            continue

        rows = rows_by_name(emitted)
        # structure: every guarded row must be present in the fresh run
        for name in GUARDED[bench]:
            if name not in rows:
                failures.append(f"{bench}: guarded row {name!r} missing from emitted report")

        if baseline.get("seed"):
            print(f"{bench}: baseline is a seed checkpoint (rev {baseline.get('rev')}) — "
                  "structural check only")
            continue
        if emitted.get("smoke"):
            print(f"{bench}: emitted report is a --test smoke run — "
                  "ratio checks skipped (single-iteration timings)")
            continue

        base_rows = rows_by_name(baseline)
        for name in GUARDED[bench]:
            got = rows.get(name)
            want = base_rows.get(name)
            if got is None:
                continue  # missing-emitted already reported above
            if want is None:
                # a guarded row the checkpoint predates: a clear verdict,
                # not a KeyError and not a silent pass — re-seed via
                # --update-baseline after a full local run
                failures.append(
                    f"{bench}: baseline row missing: {name!r} — refresh "
                    "bench/baseline/ with tools/bench_compare.py --update-baseline"
                )
                continue
            g, w = got.get("mean_s"), want.get("mean_s")
            if not isinstance(g, (int, float)) or not isinstance(w, (int, float)) or w <= 0:
                continue
            ratio = g / w
            verdict = "ok" if ratio <= TOLERANCE else "REGRESSION"
            print(f"{bench}: {name!r}: {w * 1e3:.3f}ms -> {g * 1e3:.3f}ms "
                  f"({ratio:.2f}x) [{verdict}]")
            if ratio > TOLERANCE:
                failures.append(
                    f"{bench}: {name!r} regressed {ratio:.2f}x "
                    f"(baseline {w * 1e3:.3f}ms, emitted {g * 1e3:.3f}ms)"
                )

    if failures:
        print("\nbench-compare FAILED:", file=sys.stderr)
        for f in failures:
            print(f"  - {f}", file=sys.stderr)
        return 1
    print("bench-compare: all checks passed")
    return 0


if __name__ == "__main__":
    sys.exit(main())
