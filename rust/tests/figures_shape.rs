//! Shape tests for the paper's evaluation figures: we do not match the
//! authors' absolute H800/H20 wall clocks (DESIGN.md §2), but the
//! *comparative structure* — who wins, roughly by how much, where
//! crossovers fall — must hold.

use hetu::figures;

#[test]
fn fig13_hetu_wins_every_heterogeneous_scenario() {
    let (_, rows) = figures::fig13().unwrap();
    assert_eq!(rows.len(), 8);
    for r in &rows {
        if !r.label.contains('+') {
            continue; // homogeneous rows: parity expected
        }
        let hetu = r.times.iter().find(|(s, _)| *s == "Hetu").unwrap().1;
        for (sys, t) in &r.times {
            if *sys == "Hetu" {
                continue;
            }
            assert!(
                hetu <= *t * 1.02,
                "{}: Hetu {hetu:.2}s should beat {sys} {t:.2}s",
                r.label
            );
        }
    }
}

#[test]
fn fig13_homogeneous_rows_show_parity() {
    let (_, rows) = figures::fig13().unwrap();
    for r in rows.iter().filter(|r| !r.label.contains('+')) {
        let hetu = r.times.iter().find(|(s, _)| *s == "Hetu").unwrap().1;
        let mg = r.times.iter().find(|(s, _)| *s == "Megatron").unwrap().1;
        assert!(
            (hetu / mg - 1.0).abs() < 0.05,
            "{}: homogeneous Hetu {hetu} vs Megatron {mg} should be comparable",
            r.label
        );
    }
}

#[test]
fn fig14_hetu_reconfigures_cheaper_and_runs_faster_after_failure() {
    let tables = figures::fig14().unwrap();
    assert_eq!(tables.len(), 2);
    // structural assertions are already in elastic::tests; here verify the
    // table artifact carries all configurations
    assert_eq!(tables[0].1.rows.len(), 3); // C1..C3
    assert_eq!(tables[1].1.rows.len(), 4); // C4..C7
}

#[test]
fn fig15_hetu_b_wins_on_mean() {
    let (_, cells) = figures::fig15(8).unwrap();
    assert_eq!(cells.len(), 4);
    for c in &cells {
        let mean = |name: &str| {
            let v = &c.samples.iter().find(|(s, _)| *s == name).unwrap().1;
            v.iter().sum::<f64>() / v.len() as f64
        };
        let hetu_b = mean("Hetu-B");
        let hotspa = mean("HotSPa");
        let ds = mean("DeepSpeed");
        let mg = mean("Megatron");
        assert!(hetu_b <= hotspa * 1.05, "{}: Hetu-B {hetu_b:.2} vs HotSPa {hotspa:.2}", c.label);
        assert!(hetu_b < ds && hetu_b < mg, "{}: Hetu-B must beat packed baselines", c.label);
    }
}

#[test]
fn fig16_length_distribution_matches_the_papers_97pct() {
    let t = figures::fig16(50).unwrap();
    let pcts: Vec<f64> =
        t.rows.iter().map(|r| r[4].trim_end_matches('%').parse::<f64>().unwrap()).collect();
    let mean = pcts.iter().sum::<f64>() / pcts.len() as f64;
    assert!((94.0..99.5).contains(&mean), "mean %<8K = {mean}");
    // both strategies must actually get selected across steps
    let s1 = t.rows.iter().filter(|r| r[5] == "Strategy 1").count();
    let s2 = t.rows.iter().filter(|r| r[5] == "Strategy 2").count();
    assert!(s1 > 0 && s2 > 0, "strategy switching exercised: s1={s1} s2={s2}");
}

#[test]
fn fig17_shows_the_papers_operator_mix() {
    let t = figures::fig17().unwrap();
    let resolutions: Vec<&str> = t.rows.iter().map(|r| r[1].as_str()).collect();
    // within-stage sync resolves to a collective; boundaries to SR/BSR;
    // gradient sync to AR (equal TP) and the asymmetric tail to SplitAR/BSR
    assert!(resolutions.contains(&"AR"), "{resolutions:?}");
    assert!(
        resolutions.iter().any(|k| *k == "SR" || *k == "BSR"),
        "boundaries: {resolutions:?}"
    );
    assert!(
        resolutions.iter().any(|k| *k == "SplitAR" || *k == "AR"),
        "grad sync: {resolutions:?}"
    );
}

#[test]
fn fig18_left_c2_balances_despite_asymmetry() {
    let t = figures::fig18_left().unwrap();
    assert!(t.rows.len() >= 3);
    // compute remains the dominant term for rank 0 under C2
    let c2_rank0 = t.rows.iter().find(|r| r[0] == "C2" && r[1] == "0").unwrap();
    let compute: f64 = c2_rank0[2].trim_end_matches('s').parse().unwrap();
    let step: f64 = c2_rank0[5].trim_end_matches('s').parse().unwrap();
    assert!(compute / step > 0.4, "compute {compute} of step {step}");
}

#[test]
fn table2_volume_invariant_and_nvlink_preference() {
    let t = figures::table2().unwrap();
    let sum = |planner: &str, col: usize| -> u64 {
        t.rows
            .iter()
            .filter(|r| r[0] == planner)
            .map(|r| r[col].parse::<u64>().unwrap_or(0))
            .sum()
    };
    let unfused_total = sum("unfused w/o heuristics", 2) + sum("unfused w/o heuristics", 3);
    let fused_total = sum("fused", 2) + sum("fused", 3);
    // same total volume (±1 MB rounding)
    assert!(
        (unfused_total as i64 - fused_total as i64).abs() <= 2,
        "volume invariant: {unfused_total} vs {fused_total}"
    );
    // fused planner must not use NVLink less than the naive one
    assert!(sum("fused", 2) >= sum("unfused w/o heuristics", 2));
    // and must spread load: max per-rank volume strictly smaller
    let max_of = |planner: &str| {
        t.rows
            .iter()
            .filter(|r| r[0] == planner)
            .map(|r| r[2].parse::<u64>().unwrap_or(0) + r[3].parse::<u64>().unwrap_or(0))
            .max()
            .unwrap_or(0)
    };
    assert!(
        max_of("fused") <= max_of("unfused w/o heuristics"),
        "fused max {} vs unfused max {}",
        max_of("fused"),
        max_of("unfused w/o heuristics")
    );
}

#[test]
fn table2_engine_rows_are_measured_on_the_native_backend() {
    // table2() itself asserts measured == planned (total and per sender)
    // before emitting the engine rows; here we check the rows exist and
    // carry real volume.
    let t = figures::table2().unwrap();
    let engine_rows: Vec<_> =
        t.rows.iter().filter(|r| r[0].starts_with("engine")).collect();
    assert!(!engine_rows.is_empty(), "table2 must carry a measured engine column");
    let total_kib: u64 = engine_rows
        .iter()
        .map(|r| r[2].parse::<u64>().unwrap_or(0) + r[3].parse::<u64>().unwrap_or(0))
        .sum();
    assert!(total_kib > 0, "engine rows should move real bytes, got {total_kib} KiB");
}
