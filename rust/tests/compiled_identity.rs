//! Bit-identity of the compiled MPMD executor (ISSUE 7, DESIGN.md §9).
//!
//! The oracle hierarchy: the global interpreter
//! (`Engine::train_step_reference`) anchors the numerics, the
//! event-driven executor is bit-identical to it (PR 5), and the compiled
//! tape replay must match both — same losses (`f32::to_bits`), same
//! measured wire volume, same collective counts — on the lowered
//! Appendix-A hetero encodings (C1/C2/C6) under GPipe and 1F1B, with
//! ZeRO-1, with ragged micro-batches, and across hot switches.

use hetu::engine::{Engine, EngineStrategy, ExecMode, MicroBatch, StepStats, WindowShape};
use hetu::runtime::{native, Runtime};
use hetu::spec::schedule::ScheduleKind;
use hetu::strategy::{tables, LowerOptions};

fn native_engine(strategy: EngineStrategy, seed: u64, lr: f32) -> Engine {
    Engine::with_runtime(Runtime::native(native::tiny_config()), strategy, seed, lr).unwrap()
}

/// The lowered Appendix-A hetero encodings the acceptance names.
fn lowered_encodings() -> Vec<EngineStrategy> {
    let cfg = native::tiny_config();
    let lopts = LowerOptions { total_microbatches: 7, tp_degrees: vec![1, 2, 4] };
    vec![
        hetu::strategy::lower(&tables::hetu_c1_32h20(), &cfg, &lopts).unwrap(),
        hetu::strategy::lower(&tables::hetu_c2_31h20(), &cfg, &lopts).unwrap(),
        hetu::strategy::lower(&tables::hetu_c6(), &cfg, &lopts).unwrap(),
    ]
}

/// A fixed pipeline-major pool of micro-batches so every execution path
/// sees exactly the same data.
struct Pool {
    mbs: Vec<Vec<MicroBatch>>,
}

impl Pool {
    fn for_strategy(s: &EngineStrategy, seed: u64) -> Pool {
        let cfg = native::tiny_config();
        let mut corpus = hetu::coordinator::SyntheticCorpus::new(seed, cfg.vocab);
        let mbs = s
            .pipelines
            .iter()
            .map(|p| {
                (0..p.num_microbatches).map(|_| corpus.microbatch(cfg.batch, cfg.seq)).collect()
            })
            .collect();
        Pool { mbs }
    }

    fn get(&self, pipe: usize, mb: usize) -> MicroBatch {
        self.mbs[pipe][mb].clone()
    }
}

fn assert_stats_match(a: &StepStats, b: &StepStats, what: &str) {
    assert_eq!(a.loss.to_bits(), b.loss.to_bits(), "{what}: loss bits diverge");
    assert_eq!(a.tokens, b.tokens, "{what}: tokens");
    assert_eq!(a.wire_elems, b.wire_elems, "{what}: wire accounting");
    assert_eq!(a.comm_ops, b.comm_ops, "{what}: comm-op accounting");
}

#[test]
fn compiled_losses_bit_identical_on_lowered_encodings() {
    // The tentpole acceptance: compiled dispatch vs the reference
    // interpreter vs the event-driven executor on lowered C1/C2/C6 under
    // both schedules — every step, every counter, bit-identical.
    for base in lowered_encodings() {
        let steps = if base.num_devices() > 8 { 1 } else { 2 };
        for kind in [ScheduleKind::GPipe, ScheduleKind::OneFOneB] {
            let strategy = base.clone().with_schedule(kind);
            let name = strategy.name.clone();
            let pool = Pool::for_strategy(&strategy, 0xC0DE);
            let mut compiled = native_engine(strategy.clone(), 42, 1e-3);
            compiled.set_exec_mode(ExecMode::Compiled);
            let mut event = native_engine(strategy.clone(), 42, 1e-3);
            let mut interp = native_engine(strategy, 42, 1e-3);
            for step in 0..steps {
                let a = compiled.train_step(&mut |p, m| pool.get(p, m)).unwrap();
                let b = event.train_step(&mut |p, m| pool.get(p, m)).unwrap();
                let c = interp.train_step_reference(&mut |p, m| pool.get(p, m)).unwrap();
                assert_stats_match(&a, &b, &format!("{name} ({kind:?}) step {step} vs event"));
                assert_stats_match(&a, &c, &format!("{name} ({kind:?}) step {step} vs interp"));
            }
            assert!(compiled.compiled_cached().is_some(), "{name}: tape cached across steps");
        }
    }
}

#[test]
fn compiled_zero1_bit_identical() {
    for s in [
        EngineStrategy::uniform("dp2tp2", 2, 2, 1, 8, 2),
        EngineStrategy::uniform("dp2pp2", 2, 1, 2, 8, 2).with_schedule(ScheduleKind::OneFOneB),
    ] {
        let name = s.name.clone();
        let pool = Pool::for_strategy(&s, 0x21);
        let mut compiled = native_engine(s.clone(), 42, 1e-3);
        compiled.set_zero1(true).unwrap();
        compiled.set_exec_mode(ExecMode::Compiled);
        let mut interp = native_engine(s, 42, 1e-3);
        interp.set_zero1(true).unwrap();
        for step in 0..3 {
            let a = compiled.train_step(&mut |p, m| pool.get(p, m)).unwrap();
            let b = interp.train_step_reference(&mut |p, m| pool.get(p, m)).unwrap();
            assert_stats_match(&a, &b, &format!("{name} zero1 step {step}"));
        }
    }
}

#[test]
fn compiled_ragged_microbatches_bit_identical() {
    // Ragged per-window shapes flow into the tape's shape class; the
    // compiled replay must land on the reference bits, and a shape change
    // must recompile (new class) rather than misreplay.
    let cfg = native::tiny_config();
    let s = EngineStrategy::uniform("dp2", 2, 1, 1, 8, 2);
    let windows = vec![
        vec![
            WindowShape { rows: vec![2, 2], seq_len: 10 },
            WindowShape { rows: vec![4], seq_len: 6 },
        ],
        vec![
            WindowShape { rows: vec![3, 1], seq_len: 7 },
            WindowShape { rows: vec![2], seq_len: 16 },
        ],
    ];
    let mut compiled = native_engine(s.clone(), 42, 1e-3);
    compiled.set_exec_mode(ExecMode::Compiled);
    compiled.set_microbatches(&windows).unwrap();
    let mut interp = native_engine(s, 42, 1e-3);
    interp.set_microbatches(&windows).unwrap();
    for step in 0..2 {
        let mut c1 = hetu::coordinator::SyntheticCorpus::new(60 + step, cfg.vocab);
        let mut c2 = hetu::coordinator::SyntheticCorpus::new(60 + step, cfg.vocab);
        let a = compiled.train_step(&mut |p, m| c1.window_for(&windows[p][m])).unwrap();
        let b = interp.train_step_reference(&mut |p, m| c2.window_for(&windows[p][m])).unwrap();
        assert_stats_match(&a, &b, &format!("ragged step {step}"));
    }
    let first_tape = std::sync::Arc::clone(compiled.compiled_cached().unwrap());

    // different window shapes → different shape class → fresh tape
    let windows2 = vec![
        vec![
            WindowShape { rows: vec![4], seq_len: 5 },
            WindowShape { rows: vec![1, 1], seq_len: 12 },
        ],
        vec![
            WindowShape { rows: vec![2], seq_len: 9 },
            WindowShape { rows: vec![2, 2], seq_len: 4 },
        ],
    ];
    compiled.set_microbatches(&windows2).unwrap();
    interp.set_microbatches(&windows2).unwrap();
    let mut c1 = hetu::coordinator::SyntheticCorpus::new(99, cfg.vocab);
    let mut c2 = hetu::coordinator::SyntheticCorpus::new(99, cfg.vocab);
    let a = compiled.train_step(&mut |p, m| c1.window_for(&windows2[p][m])).unwrap();
    let b = interp.train_step_reference(&mut |p, m| c2.window_for(&windows2[p][m])).unwrap();
    assert_stats_match(&a, &b, "ragged reshape step");
    assert!(
        !std::sync::Arc::ptr_eq(&first_tape, compiled.compiled_cached().unwrap()),
        "a new shape class must compile a new tape"
    );
}

#[test]
fn compiled_survives_hot_switch_cycle_bit_identically() {
    // A compiled engine hot-switches through the pool's cached plans and
    // lands on the same bits as its event-driven twin every step; after
    // each switch the pooled artifact is re-dispatched (second lap of the
    // cadence is all cache hits).
    use hetu::temporal::StrategyPool;
    let cfg = native::tiny_config();
    let mk_pool = || {
        StrategyPool::new(
            cfg,
            vec![
                (EngineStrategy::uniform("dp2", 2, 1, 1, 8, 1), 4096),
                (EngineStrategy::uniform("tp2", 1, 2, 1, 8, 2), 32768),
            ],
        )
        .unwrap()
    };
    let mut pool = mk_pool();
    let mut cmp = pool.spawn_engine_compiled(Runtime::native(cfg), 0, 42, 1e-3).unwrap();
    let mut ev = pool.spawn_engine(Runtime::native(cfg), 0, 42, 1e-3).unwrap();
    let (b, s) = (cfg.batch, cfg.seq);
    let mut step = |eng: &mut Engine, seed: u64| {
        let mut corpus = hetu::coordinator::SyntheticCorpus::new(seed, cfg.vocab);
        eng.train_step(&mut |_p, _m| corpus.microbatch(b, s)).unwrap()
    };
    for (salt, entry) in [(3u64, 1usize), (4, 0), (5, 1), (6, 0)] {
        pool.compiled_for(&mut cmp).unwrap();
        let a = step(&mut cmp, salt);
        let r = step(&mut ev, salt);
        assert_stats_match(&a, &r, &format!("switch cadence salt {salt}"));
        pool.switch_engine(&mut cmp, entry).unwrap();
        pool.switch_engine(&mut ev, entry).unwrap();
    }
    // 4 lookups over a 2-entry A↔B cadence: 2 compiles, then 2 hits
    assert_eq!((pool.artifact_hits(), pool.artifact_misses()), (2, 2));
}

#[test]
fn compiled_threaded_matches_the_oracles() {
    // The threaded executor replaying frozen tapes (CompiledThreaded)
    // stays inside the same bit-identity contract.
    let s = EngineStrategy::uniform("tp2pp2", 1, 2, 2, 8, 3).with_schedule(ScheduleKind::OneFOneB);
    let pool = Pool::for_strategy(&s, 0x7E);
    let mut thr = native_engine(s.clone(), 42, 1e-3);
    thr.set_exec_mode(ExecMode::CompiledThreaded);
    let mut interp = native_engine(s, 42, 1e-3);
    for step in 0..2 {
        let a = thr.train_step(&mut |p, m| pool.get(p, m)).unwrap();
        let b = interp.train_step_reference(&mut |p, m| pool.get(p, m)).unwrap();
        assert_stats_match(&a, &b, &format!("compiled-threaded step {step}"));
    }
}
