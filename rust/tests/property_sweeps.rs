//! Randomized property sweeps over the HSPMD core (the crate's stand-in for
//! proptest, see `hetu::testutil`): invariants that must hold for *any*
//! annotation pair, not just the worked examples.

use hetu::comm::{plan_transition, resolve, BsrOptions, TensorMove, UniformBandwidth};
use hetu::hspmd::ds::DUPLICATE;
use hetu::hspmd::slices::{region_elems, regions, SliceGrid};
use hetu::hspmd::{Annotation, DeviceGroup, DistStates, Subgroup};
use hetu::testutil::{check, Rng};

/// Generate a random Partial-free annotation over ranks drawn from `pool`,
/// for a rank-`dims` tensor.
fn arb_annotation(rng: &mut Rng, pool: &mut Vec<u32>, dims: usize) -> Annotation {
    let hsize = rng.range(1, 3);
    let mut groups = vec![];
    for _ in 0..hsize {
        // subgroup size: 1, 2 or 4
        let size = *rng.pick(&[1usize, 2, 2, 4]);
        let size = size.min(pool.len().saturating_sub(hsize - groups.len() - 1).max(1));
        let mut ranks = vec![];
        for _ in 0..size {
            if pool.is_empty() {
                break;
            }
            let i = rng.range(0, pool.len() - 1);
            ranks.push(pool.swap_remove(i));
        }
        if ranks.is_empty() {
            break;
        }
        let n = ranks.len() as u32;
        // random DS over the devices: split one dim, dup the rest
        let ds = if n == 1 {
            DistStates::trivial()
        } else if rng.chance(0.4) {
            DistStates::duplicate(n)
        } else if rng.chance(0.5) || n == 3 {
            DistStates::split(rng.range(0, dims - 1) as u32, n)
        } else {
            // split 2 × dup n/2
            let d = rng.range(0, dims - 1) as u32;
            DistStates::new(&[(d as i32, 2), (DUPLICATE, n / 2)], &[d as i32, -1]).unwrap()
        };
        groups.push(Subgroup::new(DeviceGroup::new(ranks).unwrap(), ds).unwrap());
    }
    let hdim = if groups.len() == 1 || rng.chance(0.4) {
        DUPLICATE
    } else {
        rng.range(0, dims - 1) as i32
    };
    Annotation::new(groups, hdim).unwrap()
}

fn arb_shape(rng: &mut Rng, dims: usize) -> Vec<u64> {
    (0..dims).map(|_| 8 * rng.range(1, 6) as u64).collect()
}

#[test]
fn prop_regions_cover_every_element() {
    check("regions cover tensor", 300, |rng| {
        let dims = rng.range(1, 3);
        let shape = arb_shape(rng, dims);
        let mut pool: Vec<u32> = (0..12).collect();
        let a = arb_annotation(rng, &mut pool, dims);
        let rs = regions(&a, &shape).map_err(|e| e.to_string())?;
        // every atomic slice must be held by >= 1 device
        let grid = SliceGrid::build(&shape, &[&rs]);
        for slice in grid.slices() {
            if SliceGrid::holders(&slice, &rs).is_empty() {
                return Err(format!("uncovered slice {slice:?} in {}", a.describe()));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_bsr_delivers_every_destination_slice_exactly_once() {
    check("bsr delivery", 300, |rng| {
        let dims = rng.range(1, 3);
        let shape = arb_shape(rng, dims);
        let mut pool: Vec<u32> = (0..16).collect();
        let src = arb_annotation(rng, &mut pool.clone(), dims);
        let dst = arb_annotation(rng, &mut pool, dims);
        let res = resolve(&src, &dst, &shape, &UniformBandwidth, BsrOptions::default());
        let res = match res {
            Ok(r) => r,
            Err(_) => return Ok(()), // unsupported combos are fine to reject
        };
        // delivered volume (wire + local) must equal the destination's
        // total owned volume whenever the plan is a BSR
        if let hetu::comm::CommPlan::Bsr(plan) = &res.plan {
            let delivered: u64 = plan.transfers.iter().map(|t| t.elems()).sum::<u64>()
                + plan.local_copies.iter().map(|(_, r)| region_elems(r)).sum::<u64>();
            let needed: u64 = regions(&dst, &shape)
                .map_err(|e| e.to_string())?
                .iter()
                .map(|r| region_elems(&r.region))
                .sum();
            if delivered != needed {
                return Err(format!(
                    "delivered {delivered} != needed {needed} for {} -> {}",
                    src.describe(),
                    dst.describe()
                ));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_planner_options_preserve_wire_volume() {
    check("planner volume invariant", 200, |rng| {
        let dims = rng.range(1, 2);
        let shape = arb_shape(rng, dims);
        let mut pool: Vec<u32> = (0..12).collect();
        let src = arb_annotation(rng, &mut pool.clone(), dims);
        let dst = arb_annotation(rng, &mut pool, dims);
        if src.has_partial() || dst.has_partial() {
            return Ok(());
        }
        let mv = |_: u32| TensorMove {
            name: "t".into(),
            src: src.clone(),
            dst: dst.clone(),
            shape: shape.clone(),
            elem_bytes: 2,
        };
        let moves: Vec<TensorMove> = (0..3).map(mv).collect();
        let fused =
            plan_transition(&moves, &UniformBandwidth, BsrOptions { heuristics: true }, true)
                .map_err(|e| e.to_string())?;
        let unfused =
            plan_transition(&moves, &UniformBandwidth, BsrOptions { heuristics: false }, false)
                .map_err(|e| e.to_string())?;
        if fused.wire_bytes() != unfused.wire_bytes() {
            return Err(format!(
                "volume changed: fused {} vs unfused {}",
                fused.wire_bytes(),
                unfused.wire_bytes()
            ));
        }
        if fused.num_messages() > unfused.num_messages() {
            return Err("fusion increased message count".into());
        }
        Ok(())
    });
}

#[test]
fn prop_refine_preserves_geometry() {
    check("refine geometry", 200, |rng| {
        // single-subgroup annotation with a composite DS; refine along each
        // eligible dim and compare regions
        let dims = 2;
        let shape = arb_shape(rng, dims);
        let n = *rng.pick(&[2u32, 4]);
        let d = rng.range(0, dims - 1) as u32;
        let ds = if rng.chance(0.5) {
            DistStates::split(d, n)
        } else {
            DistStates::new(&[(d as i32, n), (DUPLICATE, 2)], &[d as i32, -1]).unwrap()
        };
        let total = ds.num_devices();
        let a = Annotation::spmd(DeviceGroup::range(0, total), ds).unwrap();
        for ld in [d as i32, DUPLICATE] {
            let k = 2;
            if a.groups[0].ds.shards(ld) % k != 0 || a.groups[0].ds.shards(ld) < 2 {
                continue;
            }
            let refined = a.refine(ld, k).map_err(|e| e.to_string())?;
            let before = regions(&a, &shape).map_err(|e| e.to_string())?;
            let after = regions(&refined, &shape).map_err(|e| e.to_string())?;
            // geometry per rank must be identical (order may differ)
            for b in &before {
                let Some(aa) = after.iter().find(|x| x.rank == b.rank) else {
                    return Err(format!("rank {} vanished", b.rank));
                };
                if aa.region != b.region {
                    return Err(format!(
                        "rank {} region changed {:?} -> {:?} (refine {ld})",
                        b.rank, b.region, aa.region
                    ));
                }
            }
        }
        Ok(())
    });
}

#[test]
fn prop_schedules_complete_and_respect_fifo() {
    use hetu::spec::schedule::{stage_schedule, ScheduleKind, TaskKind};
    check("schedule completeness", 300, |rng| {
        let stages = rng.range(1, 8);
        let m = rng.range(1, 40);
        let kind = if rng.chance(0.5) { ScheduleKind::GPipe } else { ScheduleKind::OneFOneB };
        for s in 0..stages {
            let tasks = stage_schedule(kind, stages, s, m);
            if tasks.len() != 2 * m {
                return Err(format!("stage {s}: {} tasks for m={m}", tasks.len()));
            }
            for i in 0..m {
                let f = tasks
                    .iter()
                    .position(|t| t.kind == TaskKind::Fwd && t.microbatch == i)
                    .ok_or(format!("missing fwd {i}"))?;
                let b = tasks
                    .iter()
                    .position(|t| t.kind == TaskKind::Bwd && t.microbatch == i)
                    .ok_or(format!("missing bwd {i}"))?;
                if f > b {
                    return Err(format!("bwd {i} before fwd at stage {s}"));
                }
            }
        }
        Ok(())
    });
}

#[test]
fn prop_simulated_step_time_conserves_rank_budget() {
    use hetu::cluster::Cluster;
    use hetu::costmodel::{CostModel, ModelCfg};
    use hetu::sim::simulate_step;
    use hetu::spec::schedule::ScheduleKind;
    use hetu::strategy::uniform;
    check("sim budget conservation", 40, |rng| {
        let tp = *rng.pick(&[1u32, 2, 4]);
        let pp = *rng.pick(&[1u32, 2, 4]);
        let dp = *rng.pick(&[1u32, 2]);
        let n = tp * pp * dp;
        let cluster = Cluster::h20(n.max(8));
        let ranks: Vec<u32> = (0..n).collect();
        let strat = uniform(
            "x",
            &ranks,
            dp,
            tp,
            pp,
            12,
            (dp * 4) as u64,
            1,
            2048,
            if rng.chance(0.5) { ScheduleKind::GPipe } else { ScheduleKind::OneFOneB },
            true,
            false,
        )
        .map_err(|e| e.to_string())?;
        let cm = CostModel::new(ModelCfg::llama_7b());
        let rep = simulate_step(&cluster, &cm, &strat).map_err(|e| e.to_string())?;
        if !(rep.step_s > 0.0) {
            return Err("non-positive step".into());
        }
        for (r, b) in &rep.per_rank {
            let sum = b.total_s();
            if (sum - rep.step_s).abs() > 1e-6 * rep.step_s.max(1.0) {
                return Err(format!("rank {r}: budget {sum} != step {}", rep.step_s));
            }
            if b.bubble_s < -1e-9 {
                return Err(format!("rank {r}: negative bubble"));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_bucketize_conserves_tokens_with_monotone_bounds() {
    use hetu::data::{bucketize, sample_step, Corpus};
    check("bucketize invariants", 200, |rng| {
        let corpus = if rng.chance(0.5) { Corpus::CommonCrawl } else { Corpus::GitHub };
        let b = sample_step(rng, corpus, 50_000, 32_768);
        let bounds = [4096u64, 16_384, 32_768];
        let buckets = bucketize(&b.seq_lens, &bounds);
        if buckets.len() != bounds.len() {
            return Err("bucket count != bound count".into());
        }
        // token conservation: the buckets partition the batch exactly
        let n: usize = buckets.iter().map(|v| v.len()).sum();
        if n != b.seq_lens.len() {
            return Err(format!("{n} bucketed of {} sequences", b.seq_lens.len()));
        }
        let toks: u64 = buckets.iter().flat_map(|v| v.iter()).sum();
        if toks != b.total_tokens {
            return Err(format!("tokens {toks} != batch total {}", b.total_tokens));
        }
        // bucket boundaries are monotone: bucket i holds exactly the
        // lengths in (bounds[i-1], bounds[i]]
        for (i, bucket) in buckets.iter().enumerate() {
            for &l in bucket {
                if i > 0 && l <= bounds[i - 1] {
                    return Err(format!("len {l} below bucket {i} lower bound"));
                }
                if i + 1 < bounds.len() && l > bounds[i] {
                    return Err(format!("len {l} above bucket {i} upper bound"));
                }
            }
        }
        Ok(())
    });
}

#[test]
fn prop_dispatch_hetu_b_conserves_and_respects_max_context() {
    use hetu::data::{dispatch_hetu_b, sample_step, Corpus, PipeClass};
    check("hetu-b dispatch invariants", 200, |rng| {
        let corpus = if rng.chance(0.5) { Corpus::CommonCrawl } else { Corpus::GitHub };
        let max_len = 32_768u64;
        let b = sample_step(rng, corpus, 50_000, max_len);
        // 2–4 pipelines; at least one can host the longest sequence, so
        // the eligibility rule (not the overflow fallback) is exercised
        let n = rng.range(2, 4);
        let mut classes: Vec<PipeClass> = (0..n)
            .map(|_| PipeClass {
                max_seq: *rng.pick(&[4096u64, 8192, 16_384, 32_768]),
                tokens_per_s: *rng.pick(&[1.0f64, 2.0, 4.0]),
            })
            .collect();
        classes[0].max_seq = max_len;
        let assign = dispatch_hetu_b(&b.seq_lens, &classes);
        if assign.len() != classes.len() {
            return Err("assignment count != class count".into());
        }
        // conservation: every sequence lands exactly once
        let count: usize = assign.iter().map(|v| v.len()).sum();
        if count != b.seq_lens.len() {
            return Err(format!("{count} assigned of {} sequences", b.seq_lens.len()));
        }
        let toks: u64 = assign.iter().flat_map(|v| v.iter()).sum();
        if toks != b.total_tokens {
            return Err(format!("tokens {toks} != batch total {}", b.total_tokens));
        }
        // no sequence past its pipeline's max context
        for (i, (seqs, c)) in assign.iter().zip(classes.iter()).enumerate() {
            if let Some(&l) = seqs.iter().find(|&&l| l > c.max_seq) {
                return Err(format!("pipeline {i}: len {l} > max_seq {}", c.max_seq));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_pack_windows_respect_ctx_and_conserve_tokens() {
    use hetu::data::{pack_sequences, sample_step, Corpus};
    check("pack window invariants", 200, |rng| {
        let corpus = if rng.chance(0.5) { Corpus::CommonCrawl } else { Corpus::GitHub };
        let ctx = *rng.pick(&[4096u64, 8192, 16_384, 32_768]);
        let b = sample_step(rng, corpus, 60_000, 32_768);
        let windows = pack_sequences(&b.seq_lens, ctx);
        // every sequence lands in exactly one window
        let n: usize = windows.iter().map(|w| w.len()).sum();
        if n != b.seq_lens.len() {
            return Err(format!("{n} packed of {} sequences", b.seq_lens.len()));
        }
        // no window exceeds its context, and no window is empty
        for (i, w) in windows.iter().enumerate() {
            if w.is_empty() {
                return Err(format!("window {i} is empty"));
            }
            let used: u64 = w.iter().sum();
            if used > ctx {
                return Err(format!("window {i} holds {used} > ctx {ctx}"));
            }
        }
        // tokens conserve up to the baseline truncation of overlong
        // sequences
        let packed: u64 = windows.iter().flatten().sum();
        let expect: u64 = b.seq_lens.iter().map(|&l| l.min(ctx)).sum();
        if packed != expect {
            return Err(format!("tokens {packed} != truncated total {expect}"));
        }
        // first-fit can't beat the volume lower bound
        if (windows.len() as u64) < expect.div_ceil(ctx) {
            return Err("fewer windows than the volume bound".into());
        }
        Ok(())
    });
}

#[test]
fn prop_dispatcher_windows_cover_pipelines_with_real_shapes() {
    use hetu::costmodel::{CostModel, ModelCfg};
    use hetu::data::{sample_step, Corpus};
    use hetu::runtime::native;
    use hetu::temporal::{default_pool_entries, DispatchPolicy, Dispatcher, StrategyPool};
    let cfg = native::tiny_config();
    let pool = StrategyPool::new(cfg, default_pool_entries(&cfg).unwrap()).unwrap();
    let disp = Dispatcher::new(CostModel::new(ModelCfg::llama_32b()), DispatchPolicy::HetuB);
    check("dispatcher ragged windows", 100, |rng| {
        let b = sample_step(rng, Corpus::CommonCrawl, 50_000, 32_768);
        for i in 0..pool.len() {
            let entry = pool.entry(i);
            let windows = disp.microbatch_windows(entry, &b).map_err(|e| e.to_string())?;
            if windows.len() != entry.strategy.pipelines.len() {
                return Err("window lists per pipeline".into());
            }
            // no pipeline is starved, every shape is well-formed, and no
            // window exceeds the entry's scaled context
            let cell_cap = entry.ctx.div_ceil(disp.cell_tokens) as usize;
            for pipe in &windows {
                if pipe.is_empty() {
                    return Err("pipeline starved of micro-batches".into());
                }
                for mb in pipe {
                    mb.validate().map_err(|e| e.to_string())?;
                    if mb.rows.len() > disp.rows_per_mb {
                        return Err(format!("{} rows above the grouping cap", mb.rows.len()));
                    }
                    if mb.rows.iter().any(|&r| r > cell_cap) {
                        return Err(format!(
                            "window of {} cells exceeds scaled ctx {cell_cap}",
                            mb.seq_len
                        ));
                    }
                    // the grouping rule: only equal-length windows share a
                    // micro-batch, so dispatched steps never pad
                    if mb.rows.iter().any(|&r| r != mb.seq_len) {
                        return Err(format!("unequal rows {:?} grouped", mb.rows));
                    }
                }
            }
            // determinism: the same batch always produces the same shapes
            let again = disp.microbatch_windows(entry, &b).map_err(|e| e.to_string())?;
            if again != windows {
                return Err("nondeterministic window shapes".into());
            }
        }
        Ok(())
    });
}

#[test]
fn prop_uniform_round_trips_through_strategy_lowering() {
    use hetu::engine::EngineStrategy;
    use hetu::runtime::native;
    use hetu::spec::schedule::ScheduleKind;
    use hetu::strategy::{lower, uniform, LowerOptions};
    check("uniform lowering round-trip", 60, |rng| {
        let tp = *rng.pick(&[1usize, 2, 4]);
        let pp = *rng.pick(&[1usize, 2, 4]);
        let dp = *rng.pick(&[1usize, 2, 3]);
        let mb = *rng.pick(&[1usize, 2, 4]);
        let kind = if rng.chance(0.5) { ScheduleKind::GPipe } else { ScheduleKind::OneFOneB };
        let cfg = native::tiny_config();
        let n = dp * tp * pp;
        let ranks: Vec<u32> = (0..n as u32).collect();
        let spec = uniform(
            "u",
            &ranks,
            dp as u32,
            tp as u32,
            pp as u32,
            cfg.layers,
            (dp * mb) as u64,
            1,
            2048,
            kind,
            false,
            false,
        )
        .map_err(|e| e.to_string())?;
        let lopts = LowerOptions { total_microbatches: dp * mb, tp_degrees: vec![1, 2, 4] };
        let lowered = lower(&spec, &cfg, &lopts).map_err(|e| e.to_string())?;
        let direct = EngineStrategy::uniform("u", dp, tp, pp, cfg.layers, mb).with_schedule(kind);
        if lowered.pipelines != direct.pipelines {
            return Err(format!("pipelines: {:?} vs {:?}", lowered.pipelines, direct.pipelines));
        }
        if lowered.schedule != direct.schedule {
            return Err("schedule dropped by lowering".into());
        }
        lowered.validate(&cfg, &[1, 2, 4]).map_err(|e| e.to_string())?;
        Ok(())
    });
}
