//! §10 observability integration: traced steps must break down to the
//! step makespan on both the event-driven (modeled clock) and threaded
//! (wall clock) executors, the Chrome export must be structurally sound
//! (per-rank tracks, balanced JSON, flow arrows with both endpoints), and
//! span calibration must fit a usable dispatch profile.

use std::collections::BTreeSet;

use hetu::coordinator::SyntheticCorpus;
use hetu::costmodel::{CostModel, ModelCfg};
use hetu::data::StepBatch;
use hetu::engine::{Engine, EngineStrategy, ExecMode};
use hetu::obs::per_rank;
use hetu::runtime::{native, Runtime};
use hetu::temporal::{default_pool_entries, DispatchPolicy, Dispatcher, StrategyPool};

fn traced_engine(mode: ExecMode) -> Engine {
    let cfg = native::tiny_config();
    let s = EngineStrategy::uniform("dp2tp2", 2, 2, 1, cfg.layers, 2);
    let mut eng = Engine::with_runtime(Runtime::native(cfg), s, 42, 1e-3).unwrap();
    eng.set_exec_mode(mode);
    eng.set_tracing(true);
    eng
}

#[test]
fn event_driven_breakdown_sums_to_the_modeled_makespan() {
    let cfg = native::tiny_config();
    let mut eng = traced_engine(ExecMode::EventDriven);
    let mut corpus = SyntheticCorpus::new(7, cfg.vocab);
    let st = eng.train_step(&mut |_p, _m| corpus.microbatch(cfg.batch, cfg.seq)).unwrap();
    let b = st.breakdown.expect("traced step carries a breakdown");
    let tol = 0.05 * st.makespan_s.max(1e-12);
    assert!(
        (b.components_sum_s() - st.makespan_s).abs() <= tol,
        "components {} vs makespan {}",
        b.components_sum_s(),
        st.makespan_s
    );
    assert!(
        (b.critical_path_s - st.makespan_s).abs() <= tol,
        "critical path {} vs makespan {}",
        b.critical_path_s,
        st.makespan_s
    );
    assert!(b.compute_s > 0.0, "a training step must measure compute");
    // spans cover all four mesh ranks, and per-rank busy+bubble closes
    // exactly against the makespan
    let spans = eng.last_step_spans().to_vec();
    let ranks: BTreeSet<u32> = spans.iter().map(|s| s.rank).collect();
    assert_eq!(ranks.len(), 4, "dp2tp2 spans must cover all 4 ranks");
    for r in per_rank(&spans, st.makespan_s) {
        assert!(
            (r.busy_s + r.bubble_s - st.makespan_s).abs() <= 1e-9,
            "rank {}: busy {} + bubble {} must close the makespan {}",
            r.rank,
            r.busy_s,
            r.bubble_s,
            st.makespan_s
        );
    }
}

#[test]
fn threaded_breakdown_sums_to_the_wall_makespan() {
    let cfg = native::tiny_config();
    let mut eng = traced_engine(ExecMode::Threaded);
    let mut corpus = SyntheticCorpus::new(7, cfg.vocab);
    let st = eng.train_step(&mut |_p, _m| corpus.microbatch(cfg.batch, cfg.seq)).unwrap();
    let b = st.breakdown.expect("traced threaded step carries a breakdown");
    let tol = 0.05 * st.makespan_s.max(1e-12);
    assert!(
        (b.components_sum_s() - st.makespan_s).abs() <= tol,
        "components {} vs wall makespan {}",
        b.components_sum_s(),
        st.makespan_s
    );
    // wall spans live strictly inside the measured step: the last span
    // ends before the post-join makespan stamp, and within tolerance
    assert!(b.critical_path_s <= st.makespan_s + 1e-9);
    assert!(
        b.critical_path_s >= st.makespan_s - tol,
        "critical path {} trails the wall makespan {} by more than 5%",
        b.critical_path_s,
        st.makespan_s
    );
    assert!(b.compute_s > 0.0);
}

#[test]
fn untraced_step_records_nothing() {
    let cfg = native::tiny_config();
    let mut eng = traced_engine(ExecMode::EventDriven);
    eng.set_tracing(false);
    let mut corpus = SyntheticCorpus::new(7, cfg.vocab);
    let st = eng.train_step(&mut |_p, _m| corpus.microbatch(cfg.batch, cfg.seq)).unwrap();
    assert!(st.breakdown.is_none());
    assert!(eng.last_step_spans().is_empty());
    assert!(eng.export_chrome_trace().is_err(), "no traced step -> no export");
}

#[test]
fn chrome_export_carries_rank_tracks_and_flow_pairs() {
    // pp2 so cross-stage hand-off edges exist -> flow arrows
    let cfg = native::tiny_config();
    let s = EngineStrategy::uniform("pp2", 1, 1, 2, cfg.layers, 2);
    let mut eng = Engine::with_runtime(Runtime::native(cfg), s, 42, 1e-3).unwrap();
    eng.set_tracing(true);
    let mut corpus = SyntheticCorpus::new(9, cfg.vocab);
    eng.train_step(&mut |_p, _m| corpus.microbatch(cfg.batch, cfg.seq)).unwrap();
    let json = eng.export_chrome_trace().unwrap();
    assert_eq!(json.matches('{').count(), json.matches('}').count());
    assert_eq!(json.matches('[').count(), json.matches(']').count());
    assert!(json.contains("\"traceEvents\""));
    assert!(json.contains("\"rank 0\"") && json.contains("\"rank 1\""));
    let starts = json.matches("\"ph\": \"s\"").count();
    let ends = json.matches("\"ph\": \"f\"").count();
    assert!(starts > 0, "pp2 must emit flow arrows on its hand-off edges");
    assert_eq!(starts, ends, "every flow start needs its finish endpoint");
}

#[test]
fn calibration_fits_a_profile_and_keeps_dispatch_sound() {
    let tiny = native::tiny_config();
    let mut pool = StrategyPool::new(tiny, default_pool_entries(&tiny).unwrap()).unwrap();
    let mut eng = pool.spawn_engine(Runtime::native(tiny), 0, 7, 1e-3).unwrap();
    let mut corpus = SyntheticCorpus::new(3, tiny.vocab);
    let mut disp = Dispatcher::new(CostModel::new(ModelCfg::llama_32b()), DispatchPolicy::HetuB);
    disp.scale_cells_to_pool(&pool, tiny.seq);
    let lens: Vec<u64> = vec![2048; 24];
    let batch = StepBatch { total_tokens: lens.iter().sum(), seq_lens: lens };
    assert!(disp.calibration.is_none());
    let prof = disp.calibrate_from_step(&mut eng, &pool, &batch, &mut corpus).unwrap();
    assert!(prof.s_per_flop > 0.0, "measured compute must fit a positive s/flop");
    assert!(prof.s_per_byte >= 0.0);
    assert_eq!(disp.calibration, Some(prof), "the fitted profile installs itself");
    assert!(!eng.tracing(), "calibration restores the engine's tracing flag");
    // calibrated scoring still picks the short-context entry for short
    // data (the clear-cut Fig 15 case must not flip)
    assert_eq!(disp.choose(&pool, &batch, 2), 0);
    // and the profile predicts more time for more work
    let t1 = prof.step_s(1e12, 1e9, 4.0);
    let t2 = prof.step_s(2e12, 2e9, 4.0);
    assert!(t2 >= t1);
}
