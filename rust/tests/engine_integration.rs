//! Integration: the distributed engine against real AOT artifacts.
//!
//! These tests require `make artifacts` to have run (they are skipped with
//! a notice otherwise) and verify the paper's core execution property on
//! real numerics: *the parallelization strategy does not change the
//! computation*. TP/PP/DP layouts and graph switching must produce the same
//! losses as the single-device oracle.

use hetu::config::RunConfig;
use hetu::coordinator::{SyntheticCorpus, Trainer};
use hetu::engine::{Engine, EngineStage, EngineStrategy, EnginePipeline, MicroBatch};

fn artifacts_ready() -> bool {
    std::path::Path::new("artifacts/manifest.json").exists()
}

/// A fixed pool of microbatches so every strategy sees the same data:
/// pipeline-major assignment (pipeline p of n gets slots p*per..(p+1)*per).
struct Pool {
    mbs: Vec<MicroBatch>,
    per_pipeline: usize,
}

impl Pool {
    fn new(total: usize, b: usize, s: usize, pipelines: usize) -> Pool {
        let mut corpus = SyntheticCorpus::new(1234, 32000);
        Pool {
            mbs: (0..total).map(|_| corpus.microbatch(b, s)).collect(),
            per_pipeline: total / pipelines,
        }
    }
    fn get(&self, pipe: usize, mb: usize) -> MicroBatch {
        self.mbs[pipe * self.per_pipeline + mb].clone()
    }
}

fn run_one_step(strategy: EngineStrategy, pipelines: usize, total_mb: usize) -> f32 {
    let mut eng = Engine::new("artifacts", strategy, 42, 1e-3).unwrap();
    let cfg = eng.runtime.config;
    let pool = Pool::new(total_mb, cfg.batch, cfg.seq, pipelines);
    let stats = eng.train_step(&mut |p, m| pool.get(p, m)).unwrap();
    stats.loss
}

#[test]
fn single_device_loss_starts_near_log_vocab() {
    if !artifacts_ready() {
        eprintln!("skipping: run `make artifacts`");
        return;
    }
    let s = EngineStrategy::uniform("solo", 1, 1, 1, 8, 2);
    let loss = run_one_step(s, 1, 2);
    let logv = (32000f32).ln();
    assert!((loss - logv).abs() < 1.0, "initial loss {loss} vs ln(V) {logv}");
}

#[test]
fn tp_and_pp_match_single_device_loss() {
    if !artifacts_ready() {
        eprintln!("skipping: run `make artifacts`");
        return;
    }
    let base = run_one_step(EngineStrategy::uniform("solo", 1, 1, 1, 8, 2), 1, 2);
    let tp2 = run_one_step(EngineStrategy::uniform("tp2", 1, 2, 1, 8, 2), 1, 2);
    let pp2 = run_one_step(EngineStrategy::uniform("pp2", 1, 1, 2, 8, 2), 1, 2);
    let tp2pp2 = run_one_step(EngineStrategy::uniform("tp2pp2", 1, 2, 2, 8, 2), 1, 2);
    assert!((tp2 - base).abs() < 1e-3, "tp2 {tp2} vs base {base}");
    assert!((pp2 - base).abs() < 1e-5, "pp2 {pp2} vs base {base}");
    assert!((tp2pp2 - base).abs() < 1e-3, "tp2pp2 {tp2pp2} vs base {base}");
}

#[test]
fn dp_matches_single_device_loss() {
    if !artifacts_ready() {
        eprintln!("skipping: run `make artifacts`");
        return;
    }
    // dp1 with 4 microbatches == dp2 with 2 microbatches each (same pool)
    let base = run_one_step(EngineStrategy::uniform("solo", 1, 1, 1, 8, 2), 1, 2);
    let dp2 = run_one_step(EngineStrategy::uniform("dp2", 2, 1, 1, 8, 1), 2, 2);
    assert!((dp2 - base).abs() < 1e-5, "dp2 {dp2} vs base {base}");
}

#[test]
fn training_reduces_loss_and_switching_is_transparent() {
    if !artifacts_ready() {
        eprintln!("skipping: run `make artifacts`");
        return;
    }
    // Reference run: pp2 for 6 steps.
    let cfg = RunConfig { steps: 4, lr: 3e-3, ..RunConfig::default() };
    let mut t_ref = Trainer::new(cfg.clone(), EngineStrategy::uniform("pp2", 1, 1, 2, 8, 2)).unwrap();
    t_ref.train(4).unwrap();
    let ref_losses: Vec<f32> = t_ref.logs().iter().map(|l| l.loss).collect();
    // 4 steps x 128 tokens is far too little data for a monotone trend
    // (the long-horizon loss curve is train_e2e's job); assert sanity only.
    let (head, tail) = t_ref.loss_improved().unwrap();
    assert!(tail.is_finite() && head.is_finite() && tail < 20.0, "sane losses: {head} -> {tail}");

    // Switched run: pp2 for 3 steps, graph-switch to pp4, 3 more steps.
    // Same seed + data stream => identical losses (switching moves state
    // without changing the computation).
    let mut t_sw = Trainer::new(cfg, EngineStrategy::uniform("pp2", 1, 1, 2, 8, 2)).unwrap();
    t_sw.train(2).unwrap();
    let (msgs, elems) = t_sw.switch(EngineStrategy::uniform("pp4", 1, 1, 4, 8, 2)).unwrap();
    assert!(msgs > 0 && elems > 0, "switch moved {msgs} msgs / {elems} elems");
    t_sw.train(2).unwrap();
    let sw_losses: Vec<f32> = t_sw.logs().iter().map(|l| l.loss).collect();
    for (i, (a, b)) in ref_losses.iter().zip(sw_losses.iter()).enumerate() {
        assert!(
            (a - b).abs() < 2e-4,
            "step {i}: switched run diverged: {a} vs {b} (all: {ref_losses:?} vs {sw_losses:?})"
        );
    }
}

#[test]
fn stage_layout_rebalance_switch() {
    if !artifacts_ready() {
        eprintln!("skipping: run `make artifacts`");
        return;
    }
    // Asymmetric re-layering (the Fig 1(b)-style reconfiguration): 4+4 → 6+2.
    let mk = |l0: u32, name: &str| EngineStrategy {
        name: name.into(),
        pipelines: vec![EnginePipeline {
            stages: vec![
                EngineStage { devices: vec![0], layers: (0, l0) },
                EngineStage { devices: vec![1], layers: (l0, 8) },
            ],
            num_microbatches: 2,
        }],
    };
    let mut eng = Engine::new("artifacts", mk(4, "even"), 42, 1e-3).unwrap();
    let cfg = eng.runtime.config;
    let pool = Pool::new(2, cfg.batch, cfg.seq, 1);
    let before = eng.train_step(&mut |p, m| pool.get(p, m)).unwrap().loss;
    let (_, elems) = eng.switch_to(mk(6, "skewed")).unwrap();
    // layers 4,5 move from device 1 to device 0 (params + opt state)
    assert!(elems > 0);
    let after = eng.train_step(&mut |p, m| pool.get(p, m)).unwrap().loss;
    assert!(after < before + 0.5, "loss sane after rebalance: {before} -> {after}");
}

#[test]
fn tp_degree_resharding_switch_is_transparent() {
    if !artifacts_ready() {
        eprintln!("skipping: run `make artifacts`");
        return;
    }
    // tp1 → tp2 reslices every split parameter (the C2-style 4→2→1 tail
    // reconfiguration at engine scale). Losses must match an unswitched run.
    let cfg = RunConfig { steps: 2, lr: 1e-3, ..RunConfig::default() };
    let mut t_ref = Trainer::new(cfg.clone(), EngineStrategy::uniform("tp1", 1, 1, 1, 8, 1)).unwrap();
    t_ref.train(2).unwrap();
    let rl: Vec<f32> = t_ref.logs().iter().map(|l| l.loss).collect();

    let mut t_sw = Trainer::new(cfg, EngineStrategy::uniform("tp1", 1, 1, 1, 8, 1)).unwrap();
    t_sw.train(1).unwrap();
    let (msgs, elems) = t_sw.switch(EngineStrategy::uniform("tp2", 1, 2, 1, 8, 1)).unwrap();
    assert!(msgs > 0 && elems > 0, "resharding moved data: {msgs}/{elems}");
    t_sw.train(1).unwrap();
    let sl: Vec<f32> = t_sw.logs().iter().map(|l| l.loss).collect();
    for (i, (a, b)) in rl.iter().zip(sl.iter()).enumerate() {
        assert!((a - b).abs() < 2e-3, "step {i}: {a} vs {b} ({rl:?} vs {sl:?})");
    }
}
