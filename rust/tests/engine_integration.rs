//! Integration: the distributed engine at real numerics.
//!
//! These tests run on the native reference backend (always available; the
//! PJRT artifact path is exercised instead when `artifacts/manifest.json`
//! exists for the `Trainer`-level tests) and verify the paper's core
//! execution property: *the parallelization strategy does not change the
//! computation*. TP/PP/DP layouts, per-layer heterogeneous TP, and §6
//! graph switching must produce the same losses as the single-device
//! oracle — and the switch's measured wire volume must equal the fused-BSR
//! plan's prediction.

use hetu::config::RunConfig;
use hetu::coordinator::{SyntheticCorpus, Trainer};
use hetu::engine::{
    Engine, EnginePipeline, EngineStage, EngineStrategy, MicroBatch,
};
use hetu::runtime::{native, Runtime};
use hetu::spec::schedule::ScheduleKind;

fn native_engine(strategy: EngineStrategy, seed: u64, lr: f32) -> Engine {
    Engine::with_runtime(Runtime::native(native::tiny_config()), strategy, seed, lr).unwrap()
}

fn native_run_config(steps: u64, lr: f64) -> RunConfig {
    // a directory with no manifest forces the native backend
    RunConfig { artifacts_dir: "__no_artifacts__".into(), steps, lr, ..RunConfig::default() }
}

/// The previously-rejected asymmetric layout: the same 8 layers held at
/// TP2 (devices 0-1) and TP1 (device 2) across two DP replicas.
fn hetero_strategy(num_mb: usize) -> EngineStrategy {
    EngineStrategy {
        name: "hetero-tp2+tp1".into(),
        pipelines: vec![
            EnginePipeline {
                stages: vec![EngineStage { devices: vec![0, 1], layers: (0, 8) }],
                num_microbatches: num_mb,
            },
            EnginePipeline {
                stages: vec![EngineStage { devices: vec![2], layers: (0, 8) }],
                num_microbatches: num_mb,
            },
        ],
        schedule: ScheduleKind::GPipe,
    }
}

/// A fixed pool of microbatches so every strategy sees the same data:
/// pipeline-major assignment (pipeline p's slots start at offset[p]).
struct Pool {
    mbs: Vec<MicroBatch>,
    offsets: Vec<usize>,
}

impl Pool {
    /// Equal split of `total` slots over `pipelines`.
    fn new(total: usize, b: usize, s: usize, vocab: usize, pipelines: usize) -> Pool {
        let per = total / pipelines;
        Pool::split(total, b, s, vocab, &vec![per; pipelines])
    }

    /// Explicit per-pipeline slot counts (uneven micro-batching): pipeline
    /// p gets slots `offset[p]..offset[p]+counts[p]` of the same stream.
    fn split(total: usize, b: usize, s: usize, vocab: usize, counts: &[usize]) -> Pool {
        assert_eq!(counts.iter().sum::<usize>(), total);
        let mut corpus = SyntheticCorpus::new(1234, vocab);
        let mut offsets = vec![0usize];
        for &c in &counts[..counts.len() - 1] {
            offsets.push(offsets.last().unwrap() + c);
        }
        Pool { mbs: (0..total).map(|_| corpus.microbatch(b, s)).collect(), offsets }
    }

    fn get(&self, pipe: usize, mb: usize) -> MicroBatch {
        self.mbs[self.offsets[pipe] + mb].clone()
    }
}

fn run_one_step(strategy: EngineStrategy, pipelines: usize, total_mb: usize) -> f32 {
    let mut eng = native_engine(strategy, 42, 1e-3);
    let cfg = eng.runtime.config;
    let pool = Pool::new(total_mb, cfg.batch, cfg.seq, cfg.vocab, pipelines);
    let stats = eng.train_step(&mut |p, m| pool.get(p, m)).unwrap();
    stats.loss
}

/// Train `steps` steps on a fresh seeded corpus; returns per-step losses.
fn train_losses(eng: &mut Engine, steps: usize, corpus: &mut SyntheticCorpus) -> Vec<f32> {
    let (b, s) = (eng.runtime.config.batch, eng.runtime.config.seq);
    (0..steps)
        .map(|_| eng.train_step(&mut |_p, _m| corpus.microbatch(b, s)).unwrap().loss)
        .collect()
}

#[test]
fn single_device_loss_starts_near_log_vocab() {
    let mut eng = native_engine(EngineStrategy::uniform("solo", 1, 1, 1, 8, 2), 42, 1e-3);
    let cfg = eng.runtime.config;
    let pool = Pool::new(2, cfg.batch, cfg.seq, cfg.vocab, 1);
    let loss = eng.train_step(&mut |p, m| pool.get(p, m)).unwrap().loss;
    let logv = (cfg.vocab as f32).ln();
    assert!((loss - logv).abs() < 1.0, "initial loss {loss} vs ln(V) {logv}");
}

#[test]
fn tp_and_pp_match_single_device_loss() {
    let base = run_one_step(EngineStrategy::uniform("solo", 1, 1, 1, 8, 2), 1, 2);
    let tp2 = run_one_step(EngineStrategy::uniform("tp2", 1, 2, 1, 8, 2), 1, 2);
    let tp4 = run_one_step(EngineStrategy::uniform("tp4", 1, 4, 1, 8, 2), 1, 2);
    let pp2 = run_one_step(EngineStrategy::uniform("pp2", 1, 1, 2, 8, 2), 1, 2);
    let tp2pp2 = run_one_step(EngineStrategy::uniform("tp2pp2", 1, 2, 2, 8, 2), 1, 2);
    assert!((tp2 - base).abs() < 1e-3, "tp2 {tp2} vs base {base}");
    assert!((tp4 - base).abs() < 2e-3, "tp4 {tp4} vs base {base}");
    assert!((pp2 - base).abs() < 1e-5, "pp2 {pp2} vs base {base}");
    assert!((tp2pp2 - base).abs() < 1e-3, "tp2pp2 {tp2pp2} vs base {base}");
}

#[test]
fn dp_matches_single_device_loss() {
    // dp1 with 2 microbatches == dp2 with 1 microbatch each (same pool)
    let base = run_one_step(EngineStrategy::uniform("solo", 1, 1, 1, 8, 2), 1, 2);
    let dp2 = run_one_step(EngineStrategy::uniform("dp2", 2, 1, 1, 8, 1), 2, 2);
    assert!((dp2 - base).abs() < 1e-5, "dp2 {dp2} vs base {base}");
}

#[test]
fn hetero_tp_per_layer_matches_dp_oracle() {
    // the tentpole case: the same layer held at TP=2 and TP=1 across DP
    // replicas, trained with slice-aware gradient reduction. Multi-step so
    // optimizer state and parameter consistency are exercised too.
    let steps = 2;
    let mut oracle = native_engine(EngineStrategy::uniform("dp2", 2, 1, 1, 8, 1), 42, 1e-3);
    let mut hetero = native_engine(hetero_strategy(1), 42, 1e-3);
    let vocab = oracle.runtime.config.vocab;
    let mut c1 = SyntheticCorpus::new(77, vocab);
    let mut c2 = SyntheticCorpus::new(77, vocab);
    let ol = train_losses(&mut oracle, steps, &mut c1);
    let hl = train_losses(&mut hetero, steps, &mut c2);
    for (i, (a, b)) in ol.iter().zip(hl.iter()).enumerate() {
        assert!(
            (a - b).abs() < 3e-3,
            "step {i}: hetero-TP diverged from DP oracle: {a} vs {b} ({ol:?} vs {hl:?})"
        );
    }
}

#[test]
fn training_reduces_loss_and_switching_is_transparent() {
    // Reference run: pp2 for 4 steps.
    let cfg = native_run_config(4, 3e-3);
    let mut t_ref =
        Trainer::new(cfg.clone(), EngineStrategy::uniform("pp2", 1, 1, 2, 8, 2)).unwrap();
    t_ref.train(4).unwrap();
    let ref_losses: Vec<f32> = t_ref.logs().iter().map(|l| l.loss).collect();
    let (head, tail) = t_ref.loss_improved().unwrap();
    assert!(tail.is_finite() && head.is_finite() && tail < 20.0, "sane losses: {head} -> {tail}");

    // Switched run: pp2 for 2 steps, graph-switch to pp4, 2 more steps.
    // Same seed + data stream => identical losses (switching moves state
    // without changing the computation).
    let mut t_sw = Trainer::new(cfg, EngineStrategy::uniform("pp2", 1, 1, 2, 8, 2)).unwrap();
    t_sw.train(2).unwrap();
    let (msgs, elems) = t_sw.switch(EngineStrategy::uniform("pp4", 1, 1, 4, 8, 2)).unwrap();
    assert!(msgs > 0 && elems > 0, "switch moved {msgs} msgs / {elems} elems");
    t_sw.train(2).unwrap();
    let sw_losses: Vec<f32> = t_sw.logs().iter().map(|l| l.loss).collect();
    for (i, (a, b)) in ref_losses.iter().zip(sw_losses.iter()).enumerate() {
        assert!(
            (a - b).abs() < 2e-4,
            "step {i}: switched run diverged: {a} vs {b} (all: {ref_losses:?} vs {sw_losses:?})"
        );
    }
}

#[test]
fn stage_layout_rebalance_switch() {
    // Asymmetric re-layering (the Fig 1(b)-style reconfiguration): 4+4 → 6+2.
    let mk = |l0: u32, name: &str| EngineStrategy {
        name: name.into(),
        pipelines: vec![EnginePipeline {
            stages: vec![
                EngineStage { devices: vec![0], layers: (0, l0) },
                EngineStage { devices: vec![1], layers: (l0, 8) },
            ],
            num_microbatches: 2,
        }],
        schedule: ScheduleKind::GPipe,
    };
    let mut eng = native_engine(mk(4, "even"), 42, 1e-3);
    let cfg = eng.runtime.config;
    let pool = Pool::new(2, cfg.batch, cfg.seq, cfg.vocab, 1);
    let before = eng.train_step(&mut |p, m| pool.get(p, m)).unwrap().loss;
    let (_, elems) = eng.switch_to(mk(6, "skewed")).unwrap();
    // layers 4,5 move from device 1 to device 0 (params + opt state)
    assert!(elems > 0);
    let after = eng.train_step(&mut |p, m| pool.get(p, m)).unwrap().loss;
    assert!(after < before + 0.5, "loss sane after rebalance: {before} -> {after}");
}

#[test]
fn tp_degree_resharding_4_2_1_is_transparent() {
    // the C2-style tail reconfiguration: every split parameter (and its
    // optimizer moments) reslices 4→2→1. Losses and final parameters must
    // match the never-switched oracle.
    let mut oracle = native_engine(EngineStrategy::uniform("tp1", 1, 1, 1, 8, 2), 42, 1e-3);
    let vocab = oracle.runtime.config.vocab;
    let mut c_ref = SyntheticCorpus::new(9, vocab);
    let rl = train_losses(&mut oracle, 4, &mut c_ref);

    let mut sw = native_engine(EngineStrategy::uniform("tp4", 1, 4, 1, 8, 2), 42, 1e-3);
    let mut c_sw = SyntheticCorpus::new(9, vocab);
    let mut sl = train_losses(&mut sw, 1, &mut c_sw);
    let (m1, e1) = sw.switch_to(EngineStrategy::uniform("tp2", 1, 2, 1, 8, 2)).unwrap();
    assert!(m1 > 0 && e1 > 0, "4→2 resharding moved data: {m1}/{e1}");
    sl.extend(train_losses(&mut sw, 1, &mut c_sw));
    let (m2, e2) = sw.switch_to(EngineStrategy::uniform("tp1", 1, 1, 1, 8, 2)).unwrap();
    assert!(m2 > 0 && e2 > 0, "2→1 resharding moved data: {m2}/{e2}");
    sl.extend(train_losses(&mut sw, 2, &mut c_sw));

    for (i, (a, b)) in rl.iter().zip(sl.iter()).enumerate() {
        assert!((a - b).abs() < 5e-3, "step {i}: {a} vs {b} ({rl:?} vs {sl:?})");
    }
    // final parameters agree shard-for-shard (both now tp1 on device 0)
    let p_ref = oracle.mesh.devices[0].get("L0.wq").unwrap().as_f32().unwrap().to_vec();
    let p_sw = sw.mesh.devices[0].get("L0.wq").unwrap().as_f32().unwrap().to_vec();
    hetu::testutil::assert_allclose(&p_sw, &p_ref, 1e-4, 1e-3, "L0.wq after 4→2→1");
}

#[test]
fn switch_into_hetero_tp_is_transparent() {
    // dp2 → hetero (tp2+tp1): the switch replicates/reslices weights onto
    // the asymmetric layout, and training continues on the oracle's loss
    // trajectory.
    let mut oracle = native_engine(EngineStrategy::uniform("dp2", 2, 1, 1, 8, 1), 42, 1e-3);
    let vocab = oracle.runtime.config.vocab;
    let mut c_ref = SyntheticCorpus::new(5, vocab);
    let rl = train_losses(&mut oracle, 3, &mut c_ref);

    let mut sw = native_engine(EngineStrategy::uniform("dp2", 2, 1, 1, 8, 1), 42, 1e-3);
    let mut c_sw = SyntheticCorpus::new(5, vocab);
    let mut sl = train_losses(&mut sw, 1, &mut c_sw);
    let (msgs, elems) = sw.switch_to(hetero_strategy(1)).unwrap();
    assert!(msgs > 0 && elems > 0, "dp2→hetero moved data: {msgs}/{elems}");
    sl.extend(train_losses(&mut sw, 2, &mut c_sw));

    for (i, (a, b)) in rl.iter().zip(sl.iter()).enumerate() {
        assert!((a - b).abs() < 5e-3, "step {i}: {a} vs {b} ({rl:?} vs {sl:?})");
    }
}

#[test]
fn switch_wire_volume_matches_fused_plan() {
    // Table-2 / §6.2 consistency: the engine-measured wire volume of a
    // switch equals the fused-BSR plan's predicted `wire_bytes()/4`, and
    // the message count equals the plan's fused launches.
    let mut eng = native_engine(EngineStrategy::uniform("tp1", 1, 1, 1, 8, 1), 42, 1e-3);
    let cfg = eng.runtime.config;
    let pool = Pool::new(1, cfg.batch, cfg.seq, cfg.vocab, 1);
    eng.train_step(&mut |p, m| pool.get(p, m)).unwrap(); // moments exist

    let report = eng.switch_to_avoiding(EngineStrategy::uniform("tp2", 1, 2, 1, 8, 1), &[]).unwrap();
    assert!(report.wire_elems > 0);
    assert_eq!(
        report.wire_elems,
        report.plan.wire_bytes() / 4,
        "engine-measured wire volume vs planner prediction"
    );
    assert_eq!(report.messages, report.plan.num_messages() as u64);
}

#[test]
fn switch_evicts_stale_state() {
    // dp2 → solo: the dropped replica's parameter/moment shards must not
    // linger on device 1 (the seed engine leaked them forever).
    let mut eng = native_engine(EngineStrategy::uniform("dp2", 2, 1, 1, 8, 1), 42, 1e-3);
    let cfg = eng.runtime.config;
    let pool = Pool::new(2, cfg.batch, cfg.seq, cfg.vocab, 2);
    eng.train_step(&mut |p, m| pool.get(p, m)).unwrap();
    assert!(eng.mesh.devices[1].has("L0.wq") && eng.mesh.devices[1].has("m.L0.wq"));

    eng.switch_to(EngineStrategy::uniform("solo", 1, 1, 1, 8, 2)).unwrap();
    assert!(
        eng.mesh.devices[1].keys().is_empty(),
        "device 1 still holds {:?}",
        eng.mesh.devices[1].keys()
    );
    // the survivor still owns everything and can keep training
    assert!(eng.mesh.devices[0].has("L7.w2") && eng.mesh.devices[0].has("m.L7.w2"));
    let after = eng.train_step(&mut |p, m| pool.get(0, m)).unwrap().loss;
    assert!(after.is_finite());
}

#[test]
fn engine_failover_excludes_dead_senders() {
    // §7.2 at engine scale: kill pipeline 1 (devices 2,3) of dp2pp2; the
    // fused plan must source every slice from the survivors only.
    let mut eng = native_engine(EngineStrategy::uniform("dp2pp2", 2, 1, 2, 8, 1), 42, 1e-3);
    let cfg = eng.runtime.config;
    let pool = Pool::new(2, cfg.batch, cfg.seq, cfg.vocab, 2);
    eng.train_step(&mut |p, m| pool.get(p, m)).unwrap();

    let survivor = EngineStrategy {
        name: "pp2-survivor".into(),
        pipelines: vec![EnginePipeline {
            stages: vec![
                EngineStage { devices: vec![0], layers: (0, 4) },
                EngineStage { devices: vec![1], layers: (4, 8) },
            ],
            num_microbatches: 2,
        }],
        schedule: ScheduleKind::GPipe,
    };
    let report = hetu::elastic::engine_failover(&mut eng, survivor, &[2, 3]).unwrap();
    for msg in &report.plan.messages {
        assert!(msg.from != 2 && msg.from != 3, "dead device sent: {msg:?}");
    }
    // dead devices are emptied, survivors keep training
    assert!(eng.mesh.devices[2].keys().is_empty() && eng.mesh.devices[3].keys().is_empty());
    let after = eng.train_step(&mut |_p, m| pool.get(0, m)).unwrap().loss;
    assert!(after.is_finite());
}

// ---------------------------------------------------------------------------
// Strategy lowering, uneven micro-batching, schedule unification, and the
// engine↔simulator cross-validation harness (ISSUE 2 acceptance).

#[test]
fn gpipe_and_1f1b_produce_the_same_training_trajectory() {
    // one strategy, both schedules, one code path: losses must agree to
    // f32 accumulation-order noise.
    let mut losses = vec![];
    for kind in [ScheduleKind::GPipe, ScheduleKind::OneFOneB] {
        let s = EngineStrategy::uniform("pp4", 1, 1, 4, 8, 8).with_schedule(kind);
        let mut eng = native_engine(s, 42, 1e-3);
        let vocab = eng.runtime.config.vocab;
        let mut corpus = SyntheticCorpus::new(21, vocab);
        losses.push(train_losses(&mut eng, 3, &mut corpus));
    }
    for (i, (a, b)) in losses[0].iter().zip(losses[1].iter()).enumerate() {
        assert!(
            (a - b).abs() < 3e-4,
            "step {i}: GPipe {a} vs 1F1B {b} ({:?} vs {:?})",
            losses[0],
            losses[1]
        );
    }
}

#[test]
fn uneven_microbatch_dp_matches_uniform_oracle() {
    // DP replicas running 3 and 1 micro-batches == solo running all 4: the
    // token-weighted sync reduces uneven apportioning to the exact
    // global-mean gradient.
    let uneven = EngineStrategy {
        name: "dp2-uneven".into(),
        pipelines: vec![
            EnginePipeline {
                stages: vec![EngineStage { devices: vec![0], layers: (0, 8) }],
                num_microbatches: 3,
            },
            EnginePipeline {
                stages: vec![EngineStage { devices: vec![1], layers: (0, 8) }],
                num_microbatches: 1,
            },
        ],
        schedule: ScheduleKind::GPipe,
    };
    let cfg = native::tiny_config();
    let mut oracle = native_engine(EngineStrategy::uniform("solo", 1, 1, 1, 8, 4), 42, 1e-3);
    let mut sw = native_engine(uneven, 42, 1e-3);
    let pool_solo = Pool::new(4, cfg.batch, cfg.seq, cfg.vocab, 1);
    let pool_31 = Pool::split(4, cfg.batch, cfg.seq, cfg.vocab, &[3, 1]);
    for step in 0..2 {
        let a = oracle.train_step(&mut |p, m| pool_solo.get(p, m)).unwrap().loss;
        let b = sw.train_step(&mut |p, m| pool_31.get(p, m)).unwrap().loss;
        assert!((a - b).abs() < 1e-4, "step {step}: solo {a} vs uneven dp2 {b}");
    }
}

#[test]
fn lowered_c2_trains_on_the_uniform_oracle_trajectory() {
    // The acceptance case: a strategy::tables hetero encoding (C2 —
    // non-uniform layer split, TP4→TP2→TP1 tail, 33:31 micro-batches)
    // lowers onto the engine and matches the single-device oracle under
    // BOTH schedules.
    let cfg = native::tiny_config();
    let steps = 2;
    let mut oracle = native_engine(EngineStrategy::uniform("solo", 1, 1, 1, 8, 7), 42, 1e-3);
    let pool_solo = Pool::new(7, cfg.batch, cfg.seq, cfg.vocab, 1);
    let mut ol = vec![];
    for _ in 0..steps {
        ol.push(oracle.train_step(&mut |p, m| pool_solo.get(p, m)).unwrap().loss);
    }

    let c2 = hetu::strategy::tables::hetu_c2_31h20();
    let lopts =
        hetu::strategy::LowerOptions { total_microbatches: 7, tp_degrees: vec![1, 2, 4] };
    for kind in [ScheduleKind::GPipe, ScheduleKind::OneFOneB] {
        let lowered = hetu::strategy::lower(&c2, &cfg, &lopts).unwrap().with_schedule(kind);
        assert_eq!(lowered.pipelines[0].num_microbatches, 4);
        assert_eq!(lowered.pipelines[1].num_microbatches, 3);
        let mut eng = native_engine(lowered, 42, 1e-3);
        let pool = Pool::split(7, cfg.batch, cfg.seq, cfg.vocab, &[4, 3]);
        for (step, &a) in ol.iter().enumerate() {
            let b = eng.train_step(&mut |p, m| pool.get(p, m)).unwrap().loss;
            assert!(
                (a - b).abs() < 5e-3,
                "step {step} ({kind:?}): oracle {a} vs lowered C2 {b}"
            );
        }
    }
}

#[test]
fn switch_through_ragged_uneven_layout_is_transparent() {
    // uniform pp2 → ragged 3/5 split + full replica with uneven
    // micro-batches (3+1) → back to uniform; the never-switched pp2 oracle
    // trajectory must continue across both transitions.
    let ragged = EngineStrategy {
        name: "ragged-3-5+solo".into(),
        pipelines: vec![
            EnginePipeline {
                stages: vec![
                    EngineStage { devices: vec![0], layers: (0, 3) },
                    EngineStage { devices: vec![1], layers: (3, 8) },
                ],
                num_microbatches: 3,
            },
            EnginePipeline {
                stages: vec![EngineStage { devices: vec![2], layers: (0, 8) }],
                num_microbatches: 1,
            },
        ],
        schedule: ScheduleKind::OneFOneB,
    };
    let cfg = native::tiny_config();
    let uniform = || EngineStrategy::uniform("pp2", 1, 1, 2, 8, 4);
    let pool_solo = Pool::new(4, cfg.batch, cfg.seq, cfg.vocab, 1);
    let pool_31 = Pool::split(4, cfg.batch, cfg.seq, cfg.vocab, &[3, 1]);

    let mut oracle = native_engine(uniform(), 42, 1e-3);
    let mut ol = vec![];
    for _ in 0..3 {
        ol.push(oracle.train_step(&mut |p, m| pool_solo.get(p, m)).unwrap().loss);
    }

    let mut sw = native_engine(uniform(), 42, 1e-3);
    let mut sl = vec![sw.train_step(&mut |p, m| pool_solo.get(p, m)).unwrap().loss];
    let (m1, e1) = sw.switch_to(ragged.clone()).unwrap();
    assert!(m1 > 0 && e1 > 0, "into ragged moved data: {m1}/{e1}");
    sl.push(sw.train_step(&mut |p, m| pool_31.get(p, m)).unwrap().loss);
    let (m2, e2) = sw.switch_to(uniform()).unwrap();
    assert!(m2 > 0 && e2 > 0, "out of ragged moved data: {m2}/{e2}");
    sl.push(sw.train_step(&mut |p, m| pool_solo.get(p, m)).unwrap().loss);

    for (i, (a, b)) in ol.iter().zip(sl.iter()).enumerate() {
        assert!((a - b).abs() < 2e-3, "step {i}: {a} vs {b} ({ol:?} vs {sl:?})");
    }
}

#[test]
fn engine_step_time_ordering_matches_sim_ranking() {
    // Cross-validation harness: three paper-scale encodings whose step
    // ranking is structural (pipeline balance, not hardware speed), ranked
    // by the simulator at 60-layer scale and by measured engine makespans
    // after lowering to tiny-48.
    use hetu::cluster::Cluster;
    use hetu::costmodel::{CostModel, ModelCfg};
    use hetu::strategy::{uniform, ParallelStrategy, PipelineSpec, StageSpec};

    let ranks: Vec<u32> = (0..2).collect();
    let balanced = uniform(
        "balanced-pp2",
        &ranks,
        1,
        1,
        2,
        60,
        8,
        1,
        4096,
        ScheduleKind::OneFOneB,
        false,
        false,
    )
    .unwrap();
    let skewed = ParallelStrategy {
        name: "skewed-pp2".into(),
        pipelines: vec![PipelineSpec {
            stages: vec![StageSpec::r_l(0, 0, 0, 52), StageSpec::r_l(1, 1, 53, 59)],
            num_microbatches: 8,
            microbatch_size: 1,
        }],
        zero1: false,
        schedule: ScheduleKind::OneFOneB,
        seq_len: 4096,
        ac: false,
    };
    let solo = uniform(
        "solo",
        &ranks[..1],
        1,
        1,
        1,
        60,
        8,
        1,
        4096,
        ScheduleKind::OneFOneB,
        false,
        false,
    )
    .unwrap();

    let cluster = Cluster::h20(8);
    let cm = CostModel::new(ModelCfg::llama_32b());
    let strats = [&balanced, &skewed, &solo];
    let sim_rank = hetu::sim::rank_by_step_time(&cluster, &cm, &strats).unwrap();

    let cfg = native::tiny_config();
    let lopts =
        hetu::strategy::LowerOptions { total_microbatches: 8, tp_degrees: vec![1, 2, 4] };
    let mut measured = vec![];
    for &s in &strats {
        let lowered = hetu::strategy::lower(s, &cfg, &lopts).unwrap();
        let mut eng = native_engine(lowered, 42, 1e-3);
        let pool = Pool::new(8, cfg.batch, cfg.seq, cfg.vocab, 1);
        // min over a few steps damps scheduler noise
        let mut best = f64::INFINITY;
        for _ in 0..3 {
            best = best.min(eng.train_step(&mut |p, m| pool.get(p, m)).unwrap().makespan_s);
        }
        assert!(best > 0.0);
        measured.push(best);
    }
    let mut eng_rank: Vec<usize> = (0..measured.len()).collect();
    eng_rank.sort_by(|&a, &b| measured[a].partial_cmp(&measured[b]).unwrap());
    assert_eq!(
        eng_rank, sim_rank,
        "engine makespans {measured:?} disagree with simulator ranking"
    );
}

#[test]
fn topology_aware_switch_prefers_intra_node_senders() {
    // BSR heuristic (2) at engine scale: replicas live on node 0 (device
    // 0) and node 1 (device 8); a new TP2 layout on node-1 devices 9,10
    // can source everything over NVLink — but only if the planner sees
    // real bandwidths instead of UniformBandwidth.
    use hetu::cluster::Cluster;
    let dp2 = EngineStrategy {
        name: "dp2-across-nodes".into(),
        pipelines: vec![
            EnginePipeline {
                stages: vec![EngineStage { devices: vec![0], layers: (0, 8) }],
                num_microbatches: 1,
            },
            EnginePipeline {
                stages: vec![EngineStage { devices: vec![8], layers: (0, 8) }],
                num_microbatches: 1,
            },
        ],
        schedule: ScheduleKind::GPipe,
    };
    let tp2 = EngineStrategy {
        name: "tp2-node1".into(),
        pipelines: vec![EnginePipeline {
            stages: vec![EngineStage { devices: vec![9, 10], layers: (0, 8) }],
            num_microbatches: 2,
        }],
        schedule: ScheduleKind::GPipe,
    };

    // uniform bandwidth: load balancing alone spreads senders across nodes
    let mut flat = native_engine(dp2.clone(), 42, 1e-3);
    let rep_flat = flat.switch_to_avoiding(tp2.clone(), &[]).unwrap();
    let cross_node =
        rep_flat.plan.messages.iter().filter(|m| m.from == 0).count();
    assert!(cross_node > 0, "uniform bandwidth should pick device 0 for some slices");

    // with the topology threaded through, every slice sources intra-node
    let mut topo = native_engine(dp2, 42, 1e-3);
    topo.set_topology(Cluster::h20(16));
    let rep = topo.switch_to_avoiding(tp2, &[]).unwrap();
    assert!(rep.wire_elems > 0);
    for m in &rep.plan.messages {
        assert_eq!(m.from, 8, "with topology every sender is intra-node: {m:?}");
    }
    // measured per-pair volumes cover exactly the planned wire bytes
    let sent_total: u64 = rep.sent.values().sum();
    assert_eq!(sent_total, rep.wire_elems);
}

// ---------------------------------------------------------------------------
// The temporal-heterogeneity runtime (ISSUE 3): strategy pool + plan cache,
// hot-cycle loss continuity, the Hetu-B dispatcher over a mixed-length
// stream (the measured Fig 15 claim), and ZeRO-1 optimizer sharding.

#[test]
fn temporal_hot_cycle_matches_never_switching_oracle() {
    // A→B→A→B→A hot cycling through the pool: same seed and data stream,
    // so the switching engine must stay on the never-switching oracle's
    // loss trajectory after every re-entry — and the second A→B / B→A
    // transitions must hit the pairwise plan cache.
    use hetu::temporal::StrategyPool;
    let a = || EngineStrategy::uniform("dp2", 2, 1, 1, 8, 1); // 2 mbs/step
    let b = || EngineStrategy::uniform("pp2", 1, 1, 2, 8, 2); // 2 mbs/step
    let cfg = native::tiny_config();

    let mut oracle = native_engine(a(), 42, 1e-3);
    let mut c_ref = SyntheticCorpus::new(31, cfg.vocab);
    let ol = train_losses(&mut oracle, 6, &mut c_ref);

    let mut pool = StrategyPool::new(cfg, vec![(a(), 4096), (b(), 32768)]).unwrap();
    let mut eng = native_engine(a(), 42, 1e-3);
    let mut c_sw = SyntheticCorpus::new(31, cfg.vocab);
    let mut sl = train_losses(&mut eng, 2, &mut c_sw);
    pool.switch_engine(&mut eng, 1).unwrap(); // A→B (plan miss)
    sl.extend(train_losses(&mut eng, 1, &mut c_sw));
    pool.switch_engine(&mut eng, 0).unwrap(); // B→A (plan miss)
    sl.extend(train_losses(&mut eng, 1, &mut c_sw));
    pool.switch_engine(&mut eng, 1).unwrap(); // A→B (cache hit)
    sl.extend(train_losses(&mut eng, 1, &mut c_sw));
    pool.switch_engine(&mut eng, 0).unwrap(); // B→A (cache hit)
    sl.extend(train_losses(&mut eng, 1, &mut c_sw));

    assert_eq!((pool.hits(), pool.misses()), (2, 2), "repeated transitions reuse plans");
    for (i, (x, y)) in ol.iter().zip(sl.iter()).enumerate() {
        assert!(
            (x - y).abs() < 5e-3,
            "step {i}: hot cycle diverged from oracle: {x} vs {y} ({ol:?} vs {sl:?})"
        );
    }
}

/// A hand-built mixed-length stream with a known bucket cadence:
/// short / long / short / mid / short / long / short runs.
fn cadenced_stream() -> Vec<hetu::data::StepBatch> {
    let mk = |lens: Vec<u64>| {
        let total_tokens = lens.iter().sum();
        hetu::data::StepBatch { seq_lens: lens, total_tokens }
    };
    let short = || mk(vec![2048; 48]); // max 2K → 4K bucket
    let mid = || {
        let mut v = vec![2048u64; 42];
        v.push(12_000); // max 12K → 16K bucket
        mk(v)
    };
    let long = || {
        let mut v = vec![2048u64; 38];
        v.push(20_000); // max 20K → 32K bucket
        mk(v)
    };
    let mut stream = vec![];
    for _ in 0..4 {
        stream.push(short());
    }
    for _ in 0..3 {
        stream.push(long());
    }
    for _ in 0..3 {
        stream.push(short());
    }
    for _ in 0..3 {
        stream.push(mid());
    }
    for _ in 0..3 {
        stream.push(short());
    }
    for _ in 0..3 {
        stream.push(long());
    }
    for _ in 0..3 {
        stream.push(short());
    }
    stream
}

#[test]
fn temporal_hetu_b_stream_beats_best_feasible_static() {
    // The tentpole acceptance: a pool of 3 lowered strategies driven by
    // the Hetu-B dispatcher over a 22-step mixed-length stream completes
    // with loss continuity across every switch, hits the plan cache on
    // repeated transitions, and its amortized per-step time (makespans +
    // non-overlapped switch seconds) beats the best single static
    // strategy that can host the stream — Fig 15, measured.
    use hetu::costmodel::{CostModel, ModelCfg};
    use hetu::runtime::Runtime;
    use hetu::temporal::{default_pool_entries, DispatchPolicy, Dispatcher, StrategyPool};

    let cfg = native::tiny_config();
    let stream = cadenced_stream();
    assert!(stream.len() >= 20);
    let cm = CostModel::new(ModelCfg::llama_32b());
    let disp = Dispatcher::new(cm, DispatchPolicy::HetuB);
    let entries = default_pool_entries(&cfg).unwrap();

    // dynamic: the full pool
    let mut pool = StrategyPool::new(cfg, entries.clone()).unwrap();
    let mut eng = pool.spawn_engine(Runtime::native(cfg), 0, 42, 3e-3).unwrap();
    let mut corpus = SyntheticCorpus::new(17, cfg.vocab);
    let dynamic = disp.run_stream(&mut eng, &mut pool, &stream, &mut corpus).unwrap();

    assert_eq!(dynamic.steps.len(), stream.len());
    assert_eq!(
        dynamic.entries_used(),
        (0..3).collect::<std::collections::BTreeSet<usize>>(),
        "all three pooled strategies must execute"
    );
    assert!(dynamic.switches >= 4, "cadence must hot-switch: {}", dynamic.switches);
    assert!(
        dynamic.cache_hits >= 1,
        "repeated transitions must hit the plan cache ({} switches, {} hits)",
        dynamic.switches,
        dynamic.cache_hits
    );
    // loss continuity across every switch: finite, and no jump at a
    // switched step beyond early-training drift
    for w in dynamic.steps.windows(2) {
        let (prev, cur) = (&w[0], &w[1]);
        assert!(cur.loss.is_finite());
        if cur.switched {
            assert!(
                (cur.loss - prev.loss).abs() < 1.0,
                "step {}: loss jumped across switch: {} -> {}",
                cur.step,
                prev.loss,
                cur.loss
            );
        }
    }
    // ...and training still converges through 6 hot switches
    let head: f32 =
        dynamic.steps[..5].iter().map(|s| s.loss).sum::<f32>() / 5.0;
    let tail: f32 =
        dynamic.steps[stream.len() - 5..].iter().map(|s| s.loss).sum::<f32>() / 5.0;
    assert!(tail < head, "loss must improve across the stream: {head} -> {tail}");

    // static baselines: only the 32K entry can host the 20K sequences;
    // the dynamic engine must beat it on total measured time
    let long_entry = vec![entries[2].clone()];
    let mut pool_s = StrategyPool::new(cfg, long_entry).unwrap();
    let mut eng_s = pool_s.spawn_engine(Runtime::native(cfg), 0, 42, 3e-3).unwrap();
    let mut corpus_s = SyntheticCorpus::new(17, cfg.vocab);
    let static_long = disp.run_stream(&mut eng_s, &mut pool_s, &stream, &mut corpus_s).unwrap();
    assert_eq!(static_long.switches, 0);
    assert!(
        stream.iter().all(|b| b.max_len() <= entries[2].1),
        "the wide static strategy must host the whole stream"
    );
    // ragged execution: every step ran the batch's real packed windows —
    // no padded-context fallback executed on either engine, and the token
    // cells the engines measured agree (same data, modulo per-window
    // ceil-rounding of the cell scaling)
    assert_eq!(dynamic.total_padded(), 0, "dynamic engine executed padded positions");
    assert_eq!(static_long.total_padded(), 0, "static engine executed padded positions");
    assert!(dynamic.steps.iter().all(|s| s.windows > 0 && s.tokens > 0));
    let (dt, st) = (dynamic.total_tokens() as i64, static_long.total_tokens() as i64);
    assert!(
        (dt - st).abs() <= stream.len() as i64 * 2,
        "ragged token cells must conserve across strategies: {dt} vs {st}"
    );
    assert!(
        dynamic.total_s() < static_long.total_s(),
        "amortized switching engine must beat the best feasible static \
         on measured ragged step times: {:.4}s vs {:.4}s",
        dynamic.total_s(),
        static_long.total_s()
    );
}

#[test]
fn ragged_two_window_step_matches_flat_masked_oracle() {
    // Token-weighted sync equivalence at ragged shapes (the §5.5 claim at
    // engine numerics): a step of two packed windows executed at their
    // true lengths — [1,10] and [1,6] — must produce the same loss and
    // the same global-mean gradient as the equivalent flat [2,16] batch
    // holding the same windows as right-padded, masked rows.
    use hetu::engine::WindowShape;
    let mk_row = |seed: u64, n: usize| -> (Vec<i32>, Vec<i32>) {
        let mut rng = hetu::testutil::Rng::new(seed);
        let row: Vec<i32> = (0..n + 1).map(|_| rng.below(512) as i32).collect();
        (row[..n].to_vec(), row[1..].to_vec())
    };
    let (t1, g1) = mk_row(100, 10);
    let (t2, g2) = mk_row(200, 6);

    // ragged run: two windows, each at its true length
    let mut ragged = native_engine(EngineStrategy::uniform("solo", 1, 1, 1, 8, 2), 42, 1e-2);
    ragged
        .set_microbatches(&[vec![
            WindowShape { rows: vec![10], seq_len: 10 },
            WindowShape { rows: vec![6], seq_len: 6 },
        ]])
        .unwrap();
    let mbs = vec![
        MicroBatch { tokens: t1.clone(), targets: g1.clone(), n_seqs: 1, seq_len: 10 },
        MicroBatch { tokens: t2.clone(), targets: g2.clone(), n_seqs: 1, seq_len: 6 },
    ];
    let stats_r = ragged.train_step(&mut |_p, m| mbs[m].clone()).unwrap();
    assert_eq!((stats_r.tokens, stats_r.padded), (16, 0));

    // flat run: the same windows as rows of one [2,16] batch, with the
    // padding mask (target -1) covering the tails
    let mut tokens = t1.clone();
    tokens.extend(vec![0; 6]);
    tokens.extend(t2.clone());
    tokens.extend(vec![0; 10]);
    let mut targets = g1.clone();
    targets.extend(vec![-1; 6]);
    targets.extend(g2.clone());
    targets.extend(vec![-1; 10]);
    let flat_mb = MicroBatch { tokens, targets, n_seqs: 2, seq_len: 16 };
    let mut flat = native_engine(EngineStrategy::uniform("solo", 1, 1, 1, 8, 1), 42, 1e-2);
    flat.set_microbatches(&[vec![WindowShape { rows: vec![10, 6], seq_len: 16 }]]).unwrap();
    let stats_f = flat.train_step(&mut |_p, _m| flat_mb.clone()).unwrap();
    assert_eq!((stats_f.tokens, stats_f.padded), (16, 16));

    assert!(
        (stats_r.loss - stats_f.loss).abs() < 1e-5,
        "ragged loss {} vs flat masked loss {}",
        stats_r.loss,
        stats_f.loss
    );
    // the gradients were equal too: after the (shared-trajectory) AdamW
    // update, a second pass over the same data must land on the same
    // loss — if padding had leaked into any gradient, the trajectories
    // would fork here
    let r2 = ragged.train_step(&mut |_p, m| mbs[m].clone()).unwrap();
    let f2 = flat.train_step(&mut |_p, _m| flat_mb.clone()).unwrap();
    assert!(
        (r2.loss - f2.loss).abs() < 1e-3,
        "post-update trajectories forked: ragged {} vs flat {}",
        r2.loss,
        f2.loss
    );
    assert!(r2.loss.is_finite() && f2.loss.is_finite());
}

#[test]
fn train_step_enforces_the_window_contract() {
    use hetu::engine::WindowShape;
    let mut eng = native_engine(EngineStrategy::uniform("solo", 1, 1, 1, 8, 1), 42, 1e-3);
    eng.set_microbatches(&[vec![WindowShape { rows: vec![4], seq_len: 4 }]]).unwrap();
    let mut corpus = SyntheticCorpus::new(5, eng.runtime.config.vocab);
    // a provider that ignores the prescribed ragged shape is rejected
    let wrong = corpus.microbatch(2, 16);
    assert!(eng.train_step(&mut |_p, _m| wrong.clone()).is_err());
    // the matching shape runs
    let right = corpus.microbatch(1, 4);
    let stats = eng.train_step(&mut |_p, _m| right.clone()).unwrap();
    assert_eq!((stats.tokens, stats.padded), (4, 0));
    assert!(stats.loss.is_finite());
    // a switch clears the contract (the old shapes indexed old pipelines)
    eng.switch_to(EngineStrategy::uniform("tp2", 1, 2, 1, 8, 1)).unwrap();
    assert!(eng.mb_windows.is_none());
}

#[test]
fn zero1_matches_replicated_and_shards_moment_memory() {
    // ZeRO-1 over the DP axis: bit-compatible trajectory (elementwise
    // AdamW over slice-synced gradients), exactly one moment copy per
    // replica set, and the strategy/memory.rs accounting matches the
    // engine's actual stores — including across a hot switch cycle.
    use hetu::strategy::memory::engine_moment_elems;

    fn stored_moment_elems(eng: &Engine) -> u64 {
        let mut total = 0u64;
        for dev in &eng.mesh.devices {
            for k in dev.keys() {
                if k.starts_with("m.") {
                    total += dev.get(&k).unwrap().len() as u64;
                }
            }
        }
        total
    }

    let cfg = native::tiny_config();
    let dp2 = || EngineStrategy::uniform("dp2", 2, 1, 1, 8, 1);
    let tp2 = || EngineStrategy::uniform("tp2", 1, 2, 1, 8, 2);

    let mut rep = native_engine(dp2(), 42, 1e-3);
    let mut z1 = native_engine(dp2(), 42, 1e-3);
    z1.set_zero1(true).unwrap();
    let mut c1 = SyntheticCorpus::new(13, cfg.vocab);
    let mut c2 = SyntheticCorpus::new(13, cfg.vocab);
    let rl = train_losses(&mut rep, 3, &mut c1);
    let zl = train_losses(&mut z1, 3, &mut c2);
    for (i, (a, b)) in rl.iter().zip(zl.iter()).enumerate() {
        assert!((a - b).abs() < 1e-6, "step {i}: zero1 diverged: {a} vs {b}");
    }

    // memory accounting: measured == predicted, and dp2 halves exactly
    let m_rep = stored_moment_elems(&rep);
    let m_z1 = stored_moment_elems(&z1);
    assert_eq!(m_rep, engine_moment_elems(&cfg, &rep.layout, false));
    assert_eq!(m_z1, engine_moment_elems(&cfg, &z1.layout, true));
    assert_eq!(m_z1 * 2, m_rep, "ZeRO-1 over dp2 stores exactly one moment copy");

    // zero1 can't be toggled once moments exist
    assert!(rep.set_zero1(true).is_err());

    // hot switch with sharded moments: gather → move → re-shard, staying
    // on the replicated switching engine's trajectory
    rep.switch_to(tp2()).unwrap();
    z1.switch_to(tp2()).unwrap();
    let rl2 = train_losses(&mut rep, 2, &mut c1);
    let zl2 = train_losses(&mut z1, 2, &mut c2);
    for (i, (a, b)) in rl2.iter().zip(zl2.iter()).enumerate() {
        assert!((a - b).abs() < 1e-5, "post-switch step {i}: {a} vs {b}");
    }
    assert_eq!(
        stored_moment_elems(&z1),
        engine_moment_elems(&cfg, &z1.layout, true),
        "moment accounting holds after re-sharding under the new layout"
    );
    rep.switch_to(dp2()).unwrap();
    z1.switch_to(dp2()).unwrap();
    let rl3 = train_losses(&mut rep, 1, &mut c1);
    let zl3 = train_losses(&mut z1, 1, &mut c2);
    assert!((rl3[0] - zl3[0]).abs() < 1e-5, "re-entry: {} vs {}", rl3[0], zl3[0]);
    assert_eq!(stored_moment_elems(&z1) * 2, stored_moment_elems(&rep));
}

#[test]
fn step_leaves_no_transient_activation_state() {
    let s = EngineStrategy::uniform("pp2", 1, 1, 2, 8, 4)
        .with_schedule(ScheduleKind::OneFOneB);
    let mut eng = native_engine(s, 42, 1e-3);
    let cfg = eng.runtime.config;
    let pool = Pool::new(4, cfg.batch, cfg.seq, cfg.vocab, 1);
    eng.train_step(&mut |p, m| pool.get(p, m)).unwrap();
    for (d, dev) in eng.mesh.devices.iter().enumerate() {
        for k in dev.keys() {
            assert!(
                !k.starts_with("act.") && !k.starts_with("dact.") && !k.starts_with("save."),
                "device {d} leaked transient buffer `{k}`"
            );
        }
    }
}
