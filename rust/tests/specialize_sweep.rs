//! Property sweep for the §7 specialize→execute pipeline (ISSUE 5).
//!
//! Two contracts, over every lowered strategy × schedule kind:
//!
//! 1. **Reconstruction** — the per-rank `RankPlan` task multiset plus the
//!    comm-task endpoints reconstructs `spec::schedule::full_schedule`
//!    exactly, with all dependency edges preserved (the interpreter's
//!    ready conditions verbatim);
//! 2. **Oracle bit-identity** — the event-driven executor's step losses
//!    are bit-identical (`f32::to_bits`) to the pre-refactor global
//!    interpreter (`Engine::train_step_reference`), including on the
//!    lowered C1/C2/C6 hetero encodings, with equal measured wire volume.

use hetu::engine::{
    Engine, EnginePipeline, EngineStage, EngineStrategy, MicroBatch, ShardLayout, SpecTaskKind,
};
use hetu::runtime::{native, Runtime};
use hetu::spec::schedule::{stage_schedule, ScheduleKind, Task, TaskKind};
use hetu::strategy::{tables, LowerOptions};

fn native_engine(strategy: EngineStrategy, seed: u64, lr: f32) -> Engine {
    Engine::with_runtime(Runtime::native(native::tiny_config()), strategy, seed, lr).unwrap()
}

/// The asymmetric per-layer hetero-TP layout (tp2 + tp1 replicas).
fn hetero_strategy(num_mb: usize) -> EngineStrategy {
    EngineStrategy {
        name: "hetero-tp2+tp1".into(),
        pipelines: vec![
            EnginePipeline {
                stages: vec![EngineStage { devices: vec![0, 1], layers: (0, 8) }],
                num_microbatches: num_mb,
            },
            EnginePipeline {
                stages: vec![EngineStage { devices: vec![2], layers: (0, 8) }],
                num_microbatches: num_mb,
            },
        ],
        schedule: ScheduleKind::GPipe,
    }
}

/// The strategy zoo the sweep runs over: uniform TP/PP/DP mixes, the
/// hetero-TP layout, uneven micro-batching, and the lowered Appendix-A
/// hetero encodings C1/C2/C6.
fn sweep_strategies() -> Vec<EngineStrategy> {
    let cfg = native::tiny_config();
    let lopts = LowerOptions { total_microbatches: 7, tp_degrees: vec![1, 2, 4] };
    let uneven = EngineStrategy {
        name: "dp2-uneven".into(),
        pipelines: vec![
            EnginePipeline {
                stages: vec![EngineStage { devices: vec![0], layers: (0, 8) }],
                num_microbatches: 3,
            },
            EnginePipeline {
                stages: vec![EngineStage { devices: vec![1], layers: (0, 8) }],
                num_microbatches: 1,
            },
        ],
        schedule: ScheduleKind::GPipe,
    };
    vec![
        EngineStrategy::uniform("dp2tp2", 2, 2, 1, 8, 2),
        EngineStrategy::uniform("pp4", 1, 1, 4, 8, 4),
        EngineStrategy::uniform("tp2pp2", 1, 2, 2, 8, 3),
        hetero_strategy(2),
        uneven,
        hetu::strategy::lower(&tables::hetu_c1_32h20(), &cfg, &lopts).unwrap(),
        hetu::strategy::lower(&tables::hetu_c2_31h20(), &cfg, &lopts).unwrap(),
        hetu::strategy::lower(&tables::hetu_c6(), &cfg, &lopts).unwrap(),
    ]
}

#[test]
fn rank_plans_reconstruct_the_global_schedule_with_dependencies() {
    let cfg = native::tiny_config();
    for base in sweep_strategies() {
        for kind in [ScheduleKind::GPipe, ScheduleKind::OneFOneB] {
            let strategy = base.clone().with_schedule(kind);
            let layout = ShardLayout::build(&cfg, &strategy).unwrap();
            let plan = hetu::engine::specialize(&strategy, &layout, false).unwrap();
            let name = &strategy.name;

            // rank → (pipe, stage) membership
            let mut stage_of = std::collections::BTreeMap::new();
            for (pi, p) in strategy.pipelines.iter().enumerate() {
                for (si, s) in p.stages.iter().enumerate() {
                    for &d in &s.devices {
                        stage_of.insert(d, (pi, si));
                    }
                }
            }

            for rp in &plan.ranks {
                let (pi, si) = stage_of[&rp.rank];
                let pipe = &strategy.pipelines[pi];
                let s_count = pipe.stages.len();
                let m = pipe.num_microbatches;
                // 1. the rank's FwdIn/BwdIn sequence == its stage schedule
                let got: Vec<Task> = rp
                    .tasks
                    .iter()
                    .filter_map(|&ti| match plan.tasks[ti].kind {
                        SpecTaskKind::FwdIn { pipe, stage, mb } => {
                            assert_eq!((pipe, stage), (pi, si), "{name}: foreign task on rank");
                            Some(Task { kind: TaskKind::Fwd, microbatch: mb })
                        }
                        SpecTaskKind::BwdIn { pipe, stage, mb } => {
                            assert_eq!((pipe, stage), (pi, si), "{name}: foreign task on rank");
                            Some(Task { kind: TaskKind::Bwd, microbatch: mb })
                        }
                        _ => None,
                    })
                    .collect();
                assert_eq!(
                    got,
                    stage_schedule(kind, s_count, si, m),
                    "{name} ({kind:?}): rank {} does not replay its stage schedule",
                    rp.rank
                );
                // 2. every non-global task on this rank belongs to its stage
                for &ti in &rp.tasks {
                    if let Some((tp, ts, _)) = plan.tasks[ti].kind.group() {
                        assert_eq!((tp, ts), (pi, si), "{name}: rank {} hosts a foreign group", rp.rank);
                    }
                }
                // 3. the global phases close the timeline
                let n = rp.tasks.len();
                assert!(matches!(plan.tasks[rp.tasks[n - 1]].kind, SpecTaskKind::OptimStep));
                assert!(matches!(plan.tasks[rp.tasks[n - 2]].kind, SpecTaskKind::GradReduce));
            }

            // 4. per-group GEMM tasks tile the stage layer range exactly once
            let mut fwd_layers = std::collections::BTreeMap::new();
            let mut bwd_layers = std::collections::BTreeMap::new();
            for t in &plan.tasks {
                match t.kind {
                    SpecTaskKind::FwdGemm { pipe, stage, mb, layer } => {
                        fwd_layers.entry((pipe, stage, mb)).or_insert_with(Vec::new).push(layer)
                    }
                    SpecTaskKind::BwdGemm { pipe, stage, mb, layer } => {
                        bwd_layers.entry((pipe, stage, mb)).or_insert_with(Vec::new).push(layer)
                    }
                    _ => {}
                }
            }
            for (pi, p) in strategy.pipelines.iter().enumerate() {
                for (si, s) in p.stages.iter().enumerate() {
                    let fwd: Vec<u32> = (s.layers.0..s.layers.1).collect();
                    let bwd: Vec<u32> = (s.layers.0..s.layers.1).rev().collect();
                    for mb in 0..p.num_microbatches {
                        assert_eq!(fwd_layers[&(pi, si, mb)], fwd, "{name}: fwd tiling");
                        assert_eq!(bwd_layers[&(pi, si, mb)], bwd, "{name}: bwd tiling");
                    }
                }
            }

            // 5. dependency edges are the interpreter's ready conditions,
            //    and comm endpoints name the adjacent stage
            for t in &plan.tasks {
                match t.kind {
                    SpecTaskKind::FwdIn { pipe, stage, mb } => {
                        if stage == 0 {
                            assert!(t.deps.is_empty() && t.src.is_empty(), "{name}");
                        } else {
                            assert_eq!(
                                t.src, strategy.pipelines[pipe].stages[stage - 1].devices,
                                "{name}: fwd hand-off endpoints"
                            );
                            assert_eq!(t.deps.len(), 1, "{name}");
                            match plan.tasks[t.deps[0]].kind {
                                SpecTaskKind::FwdTpSync { pipe: dp, stage: ds, mb: dm, layer } => {
                                    assert_eq!((dp, ds, dm), (pipe, stage - 1, mb), "{name}");
                                    assert_eq!(
                                        layer,
                                        strategy.pipelines[pipe].stages[stage - 1].layers.1 - 1,
                                        "{name}: dep is the producer's last layer"
                                    );
                                }
                                ref k => panic!("{name}: fwd dep is {k:?}"),
                            }
                        }
                    }
                    SpecTaskKind::BwdIn { pipe, stage, mb } => {
                        let last = strategy.pipelines[pipe].stages.len() - 1;
                        assert_eq!(t.deps.len(), 1, "{name}");
                        if stage == last {
                            assert!(t.src.is_empty(), "{name}: head stage has no producer");
                            match plan.tasks[t.deps[0]].kind {
                                SpecTaskKind::FwdTpSync { pipe: dp, stage: ds, mb: dm, .. } => {
                                    assert_eq!((dp, ds, dm), (pipe, stage, mb), "{name}");
                                }
                                ref k => panic!("{name}: head dep is {k:?}"),
                            }
                        } else {
                            assert_eq!(
                                t.src, strategy.pipelines[pipe].stages[stage + 1].devices,
                                "{name}: bwd hand-off endpoints"
                            );
                            match plan.tasks[t.deps[0]].kind {
                                SpecTaskKind::BwdTpSync { pipe: dp, stage: ds, mb: dm, .. } => {
                                    assert_eq!((dp, ds, dm), (pipe, stage + 1, mb), "{name}");
                                }
                                ref k => panic!("{name}: bwd dep is {k:?}"),
                            }
                        }
                    }
                    _ => {}
                }
            }
        }
    }
}

/// A fixed pipeline-major pool of micro-batches so both execution paths
/// see exactly the same data.
struct Pool {
    mbs: Vec<Vec<MicroBatch>>,
}

impl Pool {
    fn for_strategy(s: &EngineStrategy, seed: u64) -> Pool {
        let cfg = native::tiny_config();
        let mut corpus = hetu::coordinator::SyntheticCorpus::new(seed, cfg.vocab);
        let mbs = s
            .pipelines
            .iter()
            .map(|p| {
                (0..p.num_microbatches).map(|_| corpus.microbatch(cfg.batch, cfg.seq)).collect()
            })
            .collect();
        Pool { mbs }
    }

    fn get(&self, pipe: usize, mb: usize) -> MicroBatch {
        self.mbs[pipe][mb].clone()
    }
}

#[test]
fn executor_losses_are_bit_identical_to_the_interpreter_oracle() {
    // The tentpole numerics acceptance: for every sweep strategy (incl.
    // the lowered C1/C2/C6 hetero encodings) under both schedules, the
    // event-driven executor and the pre-refactor interpreter produce the
    // SAME bits — identical loss, identical measured wire volume and
    // collective count.
    for base in sweep_strategies() {
        // one step for the 30+-device lowered encodings, two elsewhere
        let steps = if base.num_devices() > 8 { 1 } else { 2 };
        for kind in [ScheduleKind::GPipe, ScheduleKind::OneFOneB] {
            let strategy = base.clone().with_schedule(kind);
            let name = strategy.name.clone();
            let pool = Pool::for_strategy(&strategy, 0xB17);
            let mut specialized = native_engine(strategy.clone(), 42, 1e-3);
            let mut interpreter = native_engine(strategy, 42, 1e-3);
            for step in 0..steps {
                let a = specialized.train_step(&mut |p, m| pool.get(p, m)).unwrap();
                let b = interpreter.train_step_reference(&mut |p, m| pool.get(p, m)).unwrap();
                assert_eq!(
                    a.loss.to_bits(),
                    b.loss.to_bits(),
                    "{name} ({kind:?}) step {step}: executor {} != interpreter {}",
                    a.loss,
                    b.loss
                );
                assert_eq!(a.wire_elems, b.wire_elems, "{name} ({kind:?}) step {step}: wire");
                assert_eq!(a.comm_ops, b.comm_ops, "{name} ({kind:?}) step {step}: ops");
                assert_eq!(a.tokens, b.tokens, "{name} ({kind:?}) step {step}: tokens");
            }
        }
    }
}

#[test]
fn executor_zero1_stays_bit_identical_too() {
    // ZeRO-1 routes the optimizer through the OptimStep + ZeroExchange
    // task pair; the split must not perturb the trajectory.
    let s = EngineStrategy::uniform("dp2tp2", 2, 2, 1, 8, 2);
    let pool = Pool::for_strategy(&s, 0x21);
    let mut specialized = native_engine(s.clone(), 42, 1e-3);
    specialized.set_zero1(true).unwrap();
    let mut interpreter = native_engine(s, 42, 1e-3);
    interpreter.set_zero1(true).unwrap();
    for step in 0..3 {
        let a = specialized.train_step(&mut |p, m| pool.get(p, m)).unwrap();
        let b = interpreter.train_step_reference(&mut |p, m| pool.get(p, m)).unwrap();
        assert_eq!(a.loss.to_bits(), b.loss.to_bits(), "step {step}");
        assert_eq!(a.wire_elems, b.wire_elems, "step {step}: ZeRO-1 exchange wire");
    }
}

#[test]
fn executor_measures_interleaved_switch_exposure() {
    // A hot switch queues its per-sender delivery batches; the next step
    // interleaves them on wire lanes: for a single switch the lane
    // maximum IS the report's delivery_s, the exposure is the overhang
    // beyond the step's compute critical path, and the step after that
    // has nothing pending.
    use hetu::temporal::StrategyPool;
    let cfg = native::tiny_config();
    let mut pool = StrategyPool::new(
        cfg,
        vec![
            (EngineStrategy::uniform("dp2", 2, 1, 1, 8, 1), 4096),
            (EngineStrategy::uniform("tp2", 1, 2, 1, 8, 2), 32768),
        ],
    )
    .unwrap();
    // start on tp2: the switch to dp2 must ship the missing halves, so
    // the per-sender batches are real wire deliveries (a dp2→tp2 switch
    // would be all local copies and deliver nothing)
    let mut eng = pool.spawn_engine(Runtime::native(cfg), 1, 42, 1e-3).unwrap();
    let mut corpus = hetu::coordinator::SyntheticCorpus::new(5, cfg.vocab);
    let (b, s) = (cfg.batch, cfg.seq);
    let pre = eng.train_step(&mut |_p, _m| corpus.microbatch(b, s)).unwrap();
    assert_eq!(pre.exposed_switch_s, 0.0, "no switch pending before the first one");
    assert_eq!(pre.switch_delivery_s, 0.0);

    let rep = pool.switch_engine(&mut eng, 0).unwrap();
    assert!(rep.wire_elems > 0, "tp2→dp2 ships the missing halves");
    assert!(rep.delivery_s > 0.0);
    let first = eng.train_step(&mut |_p, _m| corpus.microbatch(b, s)).unwrap();
    // single switch: the slowest per-sender lane is the delivery itself
    assert!(
        (first.switch_delivery_s - rep.delivery_s).abs() < 1e-12,
        "lane max {} vs delivery {}",
        first.switch_delivery_s,
        rep.delivery_s
    );
    let bound = (rep.delivery_s - first.makespan_s).max(0.0);
    assert!(
        (first.exposed_switch_s - bound).abs() < 1e-12,
        "measured exposure {} vs single-switch bound {}",
        first.exposed_switch_s,
        bound
    );
    // drained: the following step interleaves nothing
    let second = eng.train_step(&mut |_p, _m| corpus.microbatch(b, s)).unwrap();
    assert_eq!(second.exposed_switch_s, 0.0);
    assert_eq!(second.switch_delivery_s, 0.0);
}
