//! Golden tests: the paper's worked examples (Figs 2, 9, 10, 11) verified
//! end-to-end through the public API.

use hetu::comm::{resolve, BsrOptions, ResolvedKind, UniformBandwidth};
use hetu::graph::{deduce::deduce, lits, DType, Graph, UnaryKind};
use hetu::hspmd::ds::{DUPLICATE, PARTIAL};
use hetu::hspmd::{Annotation, DeviceGroup, DistStates, Subgroup};

fn sub(ranks: Vec<u32>, entries: &[(i32, u32)], order: &[i32]) -> Subgroup {
    Subgroup::new(DeviceGroup::new(ranks).unwrap(), DistStates::new(entries, order).unwrap())
        .unwrap()
}

/// Fig 2 (right): the heterogeneous example — X split across three uneven
/// subgroups (TP pair {0,3}, single {1}... simplified to the tensor X of
/// the figure), W replicated across subgroups with different bottom
/// shardings. Checks that the annotation validates and the geometry covers
/// the tensor exactly once per replica set.
#[test]
fn fig2_right_annotation_is_expressible() {
    // X: hdim=0, three subgroups: {0,3} split dim1, {1} whole, {2,4} split dim0
    let x = Annotation::new(
        vec![
            sub(vec![0, 3], &[(1, 2)], &[1]),
            sub(vec![1], &[], &[]),
            sub(vec![2, 4], &[(0, 2)], &[0]),
        ],
        0,
    )
    .unwrap();
    assert_eq!(x.hsize(), 3);
    let regions = hetu::hspmd::slices::regions(&x, &[12, 8]).unwrap();
    let total: u64 = regions.iter().map(|r| hetu::hspmd::slices::region_elems(&r.region)).sum();
    assert_eq!(total, 96, "partition covers the tensor exactly");

    // W: replicated across subgroups (hdim=-1), TP-split within {0,3} and
    // {5,6}, whole on {1}.
    let w = Annotation::new(
        vec![
            sub(vec![0, 3], &[(0, 2)], &[0]),
            sub(vec![1], &[], &[]),
            sub(vec![5, 6], &[(0, 2)], &[0]),
        ],
        DUPLICATE,
    )
    .unwrap();
    assert!(w.same_dg_union(&w));
}

/// Fig 9: the full specialization walk-through — Gelu(X)·Comm(W) → Comm(Y)
/// with a TP/DP layout; checks CommOp resolutions and per-device graphs.
#[test]
fn fig9_specialization_walkthrough() {
    let mut g = Graph::new(1);
    let x_ann = Annotation::spmd(
        DeviceGroup::range(0, 4),
        DistStates::new(&[(0, 2), (1, 2)], &[0, 1]).unwrap(),
    )
    .unwrap();
    let x = g.placeholder("X", lits(&[8, 16]), DType::F32, vec![x_ann]).unwrap();
    let w = g
        .parameter(
            "W",
            lits(&[16, 32]),
            DType::F32,
            vec![Annotation::spmd(DeviceGroup::range(0, 4), DistStates::duplicate(4)).unwrap()],
        )
        .unwrap();
    // CommOp id=1: replicate -> TP row split
    let w_tp = Annotation::spmd(
        DeviceGroup::range(0, 4),
        DistStates::new(&[(DUPLICATE, 2), (0, 2)], &[-1, 0]).unwrap(),
    )
    .unwrap();
    let wc = g.comm(w, vec![w_tp]).unwrap();
    let xg = g.unary(UnaryKind::Gelu, x);
    let y = g.dot(xg, wc).unwrap();
    // CommOp id=2: partial -> replicated within TP pairs
    let y_sync = Annotation::spmd(
        DeviceGroup::range(0, 4),
        DistStates::new(&[(0, 2), (DUPLICATE, 2)], &[-1, 0]).unwrap(),
    )
    .unwrap();
    let yc = g.comm(y, vec![y_sync]).unwrap();
    let _ = yc;

    deduce(&mut g, 0).unwrap();
    // deduction: Y is partial over TP
    let y_ann = g.tensor(y).annotation(0).unwrap();
    assert_eq!(y_ann.groups[0].ds.shards(PARTIAL), 2);

    let spec = hetu::spec::instantiate::specialize(
        &mut g,
        0,
        &hetu::graph::Binding::new(),
        &UniformBandwidth,
        BsrOptions::default(),
    )
    .unwrap();
    assert_eq!(spec.graphs.len(), 4);
    let kinds: Vec<ResolvedKind> = spec.comm_resolutions.values().map(|r| r.kind).collect();
    assert!(kinds.contains(&ResolvedKind::AllReduce), "CommOp id=2 → AR: {kinds:?}");
}

/// Fig 10: HSize conversion — semantic equivalence of the refined
/// annotation, verified by geometry.
#[test]
fn fig10_hsize_conversion_preserves_geometry() {
    let ds = DistStates::new(&[(0, 2), (DUPLICATE, 2)], &[0, -1]).unwrap();
    let a = Annotation::spmd(DeviceGroup::new(vec![2, 4, 5, 6]).unwrap(), ds).unwrap();
    let refined = a.refine(0, 2).unwrap();
    assert_eq!(refined.hsize(), 2);
    let shape = [8u64, 6];
    let before = hetu::hspmd::slices::regions(&a, &shape).unwrap();
    let after = hetu::hspmd::slices::regions(&refined, &shape).unwrap();
    assert_eq!(before.len(), after.len());
    for (x, y) in before.iter().zip(after.iter()) {
        assert_eq!(x.rank, y.rank);
        assert_eq!(x.region, y.region);
    }
}

/// Fig 11: the 3D×2D Dot deduction table, via the public graph API.
#[test]
fn fig11_dot_deduction_through_graph() {
    let mut g = Graph::new(1);
    // X [4, 6, 8] split a=2 on dim0, c=2 on dim2, over 8 devices (dup 2)
    let x_ann = Annotation::spmd(
        DeviceGroup::range(0, 8),
        DistStates::new(&[(0, 2), (2, 2), (DUPLICATE, 2)], &[0, 2, -1]).unwrap(),
    )
    .unwrap();
    let x = g.placeholder("X", lits(&[4, 6, 8]), DType::F32, vec![x_ann]).unwrap();
    // W [8, 10] split c=2 on dim0, d=2 on dim1
    let w_ann = Annotation::spmd(
        DeviceGroup::range(0, 8),
        DistStates::new(&[(0, 2), (1, 2), (DUPLICATE, 2)], &[0, 1, -1]).unwrap(),
    )
    .unwrap();
    let w = g.parameter("W", lits(&[8, 10]), DType::F32, vec![w_ann]).unwrap();
    let y = g.dot(x, w).unwrap();
    deduce(&mut g, 0).unwrap();
    let ds = &g.tensor(y).annotation(0).unwrap().groups[0].ds;
    assert_eq!(ds.shards(0), 2, "a preserved");
    assert_eq!(ds.shards(2), 2, "d from W");
    assert_eq!(ds.shards(PARTIAL), 2, "c became partial");
}

/// The full Fig 4 classification matrix, one probe per class.
#[test]
fn fig4_classification_matrix() {
    let bw = UniformBandwidth;
    let opts = BsrOptions::default();
    let dg = |lo, hi| DeviceGroup::range(lo, hi);

    // Identity
    let a = Annotation::spmd(dg(0, 2), DistStates::split(0, 2)).unwrap();
    assert_eq!(resolve(&a, &a.clone(), &[8], &bw, opts).unwrap().kind, ResolvedKind::Identity);

    // SR: same DS, shifted devices
    let b = Annotation::spmd(dg(2, 4), DistStates::split(0, 2)).unwrap();
    assert_eq!(resolve(&a, &b, &[8], &bw, opts).unwrap().kind, ResolvedKind::SendRecv);

    // AR / RS / AG
    let p = Annotation::spmd(dg(0, 2), DistStates::partial(2)).unwrap();
    let d = Annotation::spmd(dg(0, 2), DistStates::duplicate(2)).unwrap();
    let s = Annotation::spmd(dg(0, 2), DistStates::split(0, 2)).unwrap();
    assert_eq!(resolve(&p, &d, &[8], &bw, opts).unwrap().kind, ResolvedKind::AllReduce);
    assert_eq!(resolve(&p, &s, &[8], &bw, opts).unwrap().kind, ResolvedKind::ReduceScatter);
    assert_eq!(resolve(&s, &d, &[8], &bw, opts).unwrap().kind, ResolvedKind::AllGather);

    // bottom BSR: resplit
    let s1 = Annotation::spmd(dg(0, 2), DistStates::split(1, 2)).unwrap();
    assert_eq!(resolve(&s, &s1, &[8, 4], &bw, opts).unwrap().kind, ResolvedKind::Bsr);

    // SplitAR / SplitRS / SplitAG across two subgroups
    let mk = |hdim| {
        Annotation::new(
            vec![sub(vec![0, 1], &[(0, 2)], &[0]), sub(vec![2, 3], &[(0, 2)], &[0])],
            hdim,
        )
        .unwrap()
    };
    assert_eq!(
        resolve(&mk(PARTIAL), &mk(DUPLICATE), &[8, 4], &bw, opts).unwrap().kind,
        ResolvedKind::SplitAllReduce
    );
    assert_eq!(
        resolve(&mk(PARTIAL), &mk(1), &[8, 4], &bw, opts).unwrap().kind,
        ResolvedKind::SplitReduceScatter
    );
    assert_eq!(
        resolve(&mk(1), &mk(DUPLICATE), &[8, 4], &bw, opts).unwrap().kind,
        ResolvedKind::SplitAllGather
    );

    // top-tier BSR: HSize change
    let one = Annotation::spmd(dg(0, 4), DistStates::split(0, 4)).unwrap();
    assert_eq!(resolve(&one, &mk(0), &[8, 4], &bw, opts).unwrap().kind, ResolvedKind::Bsr);
}
