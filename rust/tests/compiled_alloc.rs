//! Steady-state allocation contract of the compiled executor (ISSUE 7 +
//! ISSUE 10, DESIGN.md §9/§12): after warm-up, the **dispatch layer** —
//! the tape walk with its ready checks, clock propagation, and
//! delivery-lane folding — performs **zero** heap allocation; the
//! **kernel layer** of a warm fused step allocates **zero** bytes
//! (every intermediate is a `KernelWorkspace` slice and every weight a
//! cached panel — `StepStats::kernel_bytes_alloc == 0`); and whole
//! steps order strictly: fused compiled < unfused compiled <
//! event-driven on the same data, because fusion removes the per-call
//! kernel `Vec`s the unfused tape still pays. Host-side tensor
//! transfers still allocate by design. With §10 tracing enabled the
//! contract holds unchanged: the span ring is sized once on the first
//! traced step and warm walks store spans without allocating.
//!
//! This file holds exactly ONE test: the counting allocator is global to
//! the test binary, so a second concurrently-running test would pollute
//! the counter.

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

use hetu::engine::{Engine, EngineStrategy, ExecMode, MicroBatch};
use hetu::runtime::{native, Runtime};

/// `System`, with every allocation path counted.
struct CountingAlloc;

static ALLOCS: AtomicU64 = AtomicU64::new(0);

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.alloc(layout)
    }

    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.alloc_zeroed(layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.realloc(ptr, layout, new_size)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }
}

#[global_allocator]
static GLOBAL: CountingAlloc = CountingAlloc;

fn allocs() -> u64 {
    ALLOCS.load(Ordering::Relaxed)
}

#[test]
fn warm_compiled_dispatch_allocates_nothing() {
    let cfg = native::tiny_config();
    let s = EngineStrategy::uniform("dp2tp2", 2, 2, 1, 8, 2);
    let mk_batches = |seed: u64| -> Vec<Vec<MicroBatch>> {
        let mut corpus = hetu::coordinator::SyntheticCorpus::new(seed, cfg.vocab);
        s.pipelines
            .iter()
            .map(|p| {
                (0..p.num_microbatches).map(|_| corpus.microbatch(cfg.batch, cfg.seq)).collect()
            })
            .collect()
    };

    let mut cmp =
        Engine::with_runtime(Runtime::native(cfg), s.clone(), 42, 1e-3).unwrap();
    cmp.set_exec_mode(ExecMode::Compiled);
    let mut unf =
        Engine::with_runtime(Runtime::native(cfg), s.clone(), 42, 1e-3).unwrap();
    unf.set_exec_mode(ExecMode::Compiled);
    unf.set_kernel_fusion(false);
    let mut ev = Engine::with_runtime(Runtime::native(cfg), s.clone(), 42, 1e-3).unwrap();

    // warm-up: compile the tape, size the workspace/arena, pack panels,
    // create moments
    let pool = mk_batches(7);
    for eng in [&mut cmp, &mut unf, &mut ev] {
        for _ in 0..2 {
            eng.train_step(&mut |p, m| pool[p][m].clone()).unwrap();
        }
    }

    // 1. the dispatch layer in isolation: a warm null-exec tape walk —
    //    full ready checks and clock propagation, no kernels — performs
    //    exactly zero heap allocations
    let prog = Arc::clone(cmp.compiled_cached().expect("tape cached after warm steps"));
    cmp.replay_compiled_tape(&prog).unwrap(); // warm the walk scratch
    let a0 = allocs();
    let makespan = cmp.replay_compiled_tape(&prog).unwrap();
    let walk_allocs = allocs() - a0;
    assert_eq!(walk_allocs, 0, "warm dispatch walk allocated {walk_allocs} times");
    assert_eq!(makespan, 0.0, "null executor has zero-duration ops");

    // 2. kernel layer (ISSUE 10): a warm fused compiled step allocates
    //    ZERO bytes in the kernels — intermediates live in the frozen
    //    `KernelWorkspace`, weights in repacked panels — and launches
    //    strictly fewer kernels than the unfused tape (fused epilogues
    //    merge the gelu / residual / merge passes into their GEMMs).
    //    Whole steps order strictly: fused < unfused compiled <
    //    event-driven, and all three land on identical loss bits.
    let a1 = allocs();
    let st_f = cmp.train_step(&mut |p, m| pool[p][m].clone()).unwrap();
    let fused_step = allocs() - a1;
    let a2 = allocs();
    let st_u = unf.train_step(&mut |p, m| pool[p][m].clone()).unwrap();
    let unfused_step = allocs() - a2;
    let a3 = allocs();
    let st_e = ev.train_step(&mut |p, m| pool[p][m].clone()).unwrap();
    let event_step = allocs() - a3;
    assert_eq!(st_f.loss.to_bits(), st_e.loss.to_bits(), "fused loss bits diverge");
    assert_eq!(st_u.loss.to_bits(), st_e.loss.to_bits(), "unfused loss bits diverge");
    assert_eq!(
        st_f.kernel_bytes_alloc, 0,
        "warm fused step allocated {} kernel floats",
        st_f.kernel_bytes_alloc
    );
    assert!(st_u.kernel_bytes_alloc > 0, "unfused tape pays per-kernel output Vecs");
    assert!(st_e.kernel_bytes_alloc > 0, "interpreter pays per-kernel output Vecs");
    assert!(
        st_f.kernel_launches > 0 && st_f.kernel_launches < st_u.kernel_launches,
        "fused launches {} must undercut unfused {}",
        st_f.kernel_launches,
        st_u.kernel_launches
    );
    assert!(
        fused_step < unfused_step && unfused_step < event_step,
        "step allocations must order fused {fused_step} < unfused {unfused_step} \
         < event-driven {event_step}"
    );

    // 3. tracing on (§10): the first traced step sizes the span ring —
    //    one reservation — and every later traced dispatch walk stores
    //    spans into the preallocated slots with zero heap allocation.
    //    Tracing must also leave the numerics untouched: the traced
    //    compiled loss stays bit-identical to the untraced event-driven
    //    engine on the same data.
    cmp.set_tracing(true);
    let st_tr = cmp.train_step(&mut |p, m| pool[p][m].clone()).unwrap();
    let st_ev = ev.train_step(&mut |p, m| pool[p][m].clone()).unwrap();
    assert_eq!(
        st_tr.loss.to_bits(),
        st_ev.loss.to_bits(),
        "tracing must not perturb the numerics"
    );
    assert!(st_tr.breakdown.is_some(), "traced step must fold a breakdown");
    assert!(st_ev.breakdown.is_none(), "untraced step must not fabricate one");
    assert_eq!(
        st_tr.kernel_bytes_alloc, 0,
        "tracing must not reopen kernel-layer allocation"
    );
    cmp.replay_compiled_tape(&prog).unwrap(); // warm the traced walk
    let a3 = allocs();
    cmp.replay_compiled_tape(&prog).unwrap();
    let traced_walk = allocs() - a3;
    assert_eq!(traced_walk, 0, "warm traced dispatch walk allocated {traced_walk} times");
}
