//! Cluster-scale strategy synthesis, end to end.
//!
//! Property sweeps over generated [`ClusterSpec`] clusters, cross-validation
//! of the synthesis ranking against engine-measured step times, the CI synth
//! smoke path (generated cluster → search → lower → one engine step at
//! bit-identity), and elastic re-synthesis under multi-rank concurrent
//! failure.

use std::collections::BTreeSet;
use std::sync::Arc;

use hetu::cluster::{Cluster, ClusterSpec, GPUS_PER_NODE};
use hetu::coordinator::SyntheticCorpus;
use hetu::costmodel::{CostModel, ModelCfg};
use hetu::engine::{Engine, EngineStrategy, ExecMode, MicroBatch};
use hetu::runtime::{native, Runtime};
use hetu::strategy::{lower, synthesize, LowerOptions, SynthOptions};
use hetu::temporal::StrategyPool;

fn lopts() -> LowerOptions {
    LowerOptions { total_microbatches: 8, tp_degrees: vec![1, 2, 4] }
}

/// A fixed per-(pipeline, microbatch) batch pool so every execution mode of
/// the same strategy consumes identical data regardless of request order.
struct Pool {
    mbs: Vec<MicroBatch>,
    offsets: Vec<usize>,
}

impl Pool {
    fn for_strategy(strat: &EngineStrategy, b: usize, s: usize, vocab: usize) -> Pool {
        let counts: Vec<usize> = strat.pipelines.iter().map(|p| p.num_microbatches).collect();
        let total: usize = counts.iter().sum();
        let mut corpus = SyntheticCorpus::new(1234, vocab);
        let mut offsets = vec![0usize];
        for &c in &counts[..counts.len() - 1] {
            offsets.push(offsets.last().unwrap() + c);
        }
        Pool { mbs: (0..total).map(|_| corpus.microbatch(b, s)).collect(), offsets }
    }

    fn get(&self, pipe: usize, mb: usize) -> MicroBatch {
        self.mbs[self.offsets[pipe] + mb].clone()
    }
}

#[test]
fn generated_cluster_synthesis_property_sweep() {
    let cm = CostModel::new(ModelCfg::llama_32b());
    let cfg = native::tiny_config();
    let mut rng = hetu::testutil::Rng::new(0x5EED_5EED);
    for case in 0..10 {
        let nodes = rng.range(2, 8) as u32;
        let spec = ClusterSpec::new(rng.next_u64(), nodes);
        let cluster = spec.build();
        assert_eq!(cluster.devices.len() as u32, spec.num_ranks(), "case {case}");
        let rep = synthesize(&cluster, &cm, &SynthOptions::new(64, 4096))
            .unwrap_or_else(|e| panic!("case {case}: {e}"));
        // the pruning ledger always balances
        assert_eq!(
            rep.generated,
            rep.pruned_memory + rep.pruned_bound + rep.simulated,
            "case {case}: ledger"
        );
        for (s, step_s) in &rep.ranked {
            assert!(*step_s > 0.0, "case {case}: {}", s.name);
            // layer conservation, >= 1 layer per stage, globally disjoint
            // ranks — all enforced by validate
            s.validate(cm.model.layers).unwrap_or_else(|e| panic!("case {case}: {e}"));
            for p in &s.pipelines {
                for st in &p.stages {
                    // TP clamped to node-local same-kind device counts
                    assert!(st.tp() <= GPUS_PER_NODE, "case {case}: tp {}", st.tp());
                    let d0 = cluster.device(st.ranks[0]);
                    for &r in &st.ranks {
                        let d = cluster.device(r);
                        assert!(d.alive, "case {case}: dead rank {r}");
                        assert_eq!(d.node, d0.node, "case {case}: TP group crosses nodes");
                        assert_eq!(
                            d.kind.name, d0.kind.name,
                            "case {case}: TP group mixes kinds"
                        );
                    }
                }
            }
            // round-trip through lower() whenever the shape fits the tiny
            // engine's stage budget
            if s.pipelines.iter().all(|p| p.stages.len() as u32 <= cfg.layers) {
                let mut lo = lopts();
                lo.total_microbatches = lo.total_microbatches.max(s.pipelines.len());
                let e = lower(s, &cfg, &lo).unwrap_or_else(|e| panic!("case {case}: {e}"));
                e.validate(&cfg, &[1, 2, 4]).unwrap_or_else(|e| panic!("case {case}: {e}"));
            }
        }
    }
}

#[test]
fn synth_top_k_matches_engine_measured_ordering() {
    // A generated heterogeneous cluster (first seed mixing >= 2 device
    // kinds across 2 nodes). The tiny engine's devices all run at the same
    // CPU speed, so the assertion is restricted to candidates whose sim
    // ranking is structural — distinct lowered pipeline shapes with a
    // >= 25% simulated separation — not hardware-speed driven.
    let spec = (0..64u64)
        .map(|s| ClusterSpec::new(s, 2))
        .find(|sp| {
            let kinds: BTreeSet<&str> =
                sp.build().devices.iter().map(|d| d.kind.name).collect();
            kinds.len() >= 2
        })
        .expect("some seed in 0..64 mixes device kinds");
    let cluster = spec.build();
    let cm = CostModel::new(ModelCfg::tiny_100m());
    let mut opts = SynthOptions::new(16, 2048);
    opts.top_k = 32;
    let rep = synthesize(&cluster, &cm, &opts).unwrap();
    assert!(rep.ranked.len() >= 3, "only {} ranked candidates", rep.ranked.len());

    let cfg = native::tiny_config();
    let mut picked: Vec<(f64, EngineStrategy)> = vec![];
    let mut shapes: BTreeSet<Vec<(usize, usize)>> = BTreeSet::new();
    for (s, t) in &rep.ranked {
        let Ok(low) = lower(s, &cfg, &lopts()) else { continue };
        let shape: Vec<(usize, usize)> =
            low.pipelines.iter().map(|p| (p.stages.len(), p.num_microbatches)).collect();
        if !shapes.insert(shape) {
            continue;
        }
        if let Some((lt, _)) = picked.last() {
            if *t < lt * 1.25 {
                continue;
            }
        }
        picked.push((*t, low));
        if picked.len() == 3 {
            break;
        }
    }
    assert!(
        picked.len() >= 3,
        "need 3 structurally distinct, well-separated candidates, got {}",
        picked.len()
    );

    let mut measured = vec![];
    for (_, low) in &picked {
        let mut eng =
            Engine::with_runtime(Runtime::native(cfg), low.clone(), 42, 1e-3).unwrap();
        let pool = Pool::for_strategy(low, cfg.batch, cfg.seq, cfg.vocab);
        // min over a few steps damps scheduler noise
        let mut best = f64::INFINITY;
        for _ in 0..3 {
            best = best.min(eng.train_step(&mut |p, m| pool.get(p, m)).unwrap().makespan_s);
        }
        assert!(best > 0.0);
        measured.push(best);
    }
    for w in 0..measured.len() - 1 {
        assert!(
            measured[w] < measured[w + 1],
            "engine makespans {measured:?} disagree with synth ranking {:?}",
            picked.iter().map(|(t, _)| *t).collect::<Vec<_>>()
        );
    }
}

#[test]
fn synth_smoke_lowered_strategy_is_bit_identical() {
    // The CI smoke path: generated cluster → synthesize → lower → one
    // engine step, bit-identical across reference / event-driven /
    // compiled execution.
    let cluster = ClusterSpec::new(3, 2).build();
    let cm = CostModel::new(ModelCfg::tiny_100m());
    let rep = synthesize(&cluster, &cm, &SynthOptions::new(16, 2048)).unwrap();
    let cfg = native::tiny_config();
    let low = rep
        .ranked
        .iter()
        .find_map(|(s, _)| lower(s, &cfg, &lopts()).ok())
        .expect("a ranked strategy lowers onto the tiny engine");
    low.validate(&cfg, &[1, 2, 4]).unwrap();

    let pool = Pool::for_strategy(&low, cfg.batch, cfg.seq, cfg.vocab);
    let run = |mode: Option<ExecMode>, reference: bool| {
        let mut eng =
            Engine::with_runtime(Runtime::native(cfg), low.clone(), 42, 1e-3).unwrap();
        if let Some(m) = mode {
            eng.set_exec_mode(m);
        }
        let stats = if reference {
            eng.train_step_reference(&mut |p, m| pool.get(p, m)).unwrap()
        } else {
            eng.train_step(&mut |p, m| pool.get(p, m)).unwrap()
        };
        (stats.loss, stats.wire_elems, stats.comm_ops)
    };
    let (lr, wr, cr) = run(None, true);
    let (le, we, ce) = run(None, false);
    let (lc, wc, cc) = run(Some(ExecMode::Compiled), false);
    assert!(lr.is_finite());
    assert_eq!(lr.to_bits(), le.to_bits(), "event-driven loss bits diverge");
    assert_eq!(lr.to_bits(), lc.to_bits(), "compiled loss bits diverge");
    assert_eq!((wr, cr), (we, ce), "event-driven wire/ops diverge");
    assert_eq!((wr, cr), (wc, cc), "compiled wire/ops diverge");
}

#[test]
fn resynthesize_survives_concurrent_tp_group_loss() {
    // Two ranks die at once, spanning the whole second TP group of
    // pipeline 0 (devices 2,3 of dp2tp2pp2). Re-synthesis must find a
    // replacement on the 6 survivors, switch onto it, and keep the loss
    // continuous.
    let cfg = native::tiny_config();
    let base = EngineStrategy::uniform("dp2tp2pp2", 2, 2, 2, cfg.layers, 4);
    let mut pool = StrategyPool::new(cfg, vec![(base, 4096)]).unwrap();
    let mut eng = pool.spawn_engine(Runtime::native(cfg), 0, 42, 1e-3).unwrap();
    let mut corpus = SyntheticCorpus::new(5, cfg.vocab);
    let (b, s) = (cfg.batch, cfg.seq);
    let pre = eng.train_step(&mut |_p, _m| corpus.microbatch(b, s)).unwrap().loss;

    let dead = [2usize, 3];
    let mut cluster = Cluster::h20(8);
    for &d in &dead {
        cluster.fail_gpu(d as u32);
    }
    let cm = CostModel::new(ModelCfg::tiny_100m());
    let rep = hetu::elastic::resynthesize(
        &mut pool, &mut eng, &cluster, &cm, &dead, 16, 2048, &lopts(),
    )
    .unwrap();

    // the replacement entry exists, inherits the bucket context, and
    // schedules only survivors
    assert_eq!(rep.entry, 1);
    assert_eq!(pool.entry(rep.entry).ctx, 4096);
    assert!(rep.sim_step_s > 0.0);
    let used: BTreeSet<usize> = eng
        .strategy
        .pipelines
        .iter()
        .flat_map(|p| p.stages.iter().flat_map(|st| st.devices.iter().copied()))
        .collect();
    assert!(!used.contains(&2) && !used.contains(&3), "replacement uses dead devices");
    assert!(!used.is_empty());
    // dead devices hold no state after the switch
    assert!(eng.mesh.devices[2].keys().is_empty());
    assert!(eng.mesh.devices[3].keys().is_empty());
    // loss continuity across the reconfiguration
    let post = eng.train_step(&mut |_p, _m| corpus.microbatch(b, s)).unwrap().loss;
    assert!(post.is_finite());
    assert!((post - pre).abs() < 1.0, "loss continuity: pre {pre} post {post}");
}

#[test]
fn resynthesized_entry_does_not_pollute_artifact_cache() {
    // Three concurrent deaths (a full TP group plus one more rank). The
    // compiled artifact for the re-synthesized entry must be keyed without
    // any notion of the dead set: a healthy engine landing on the same
    // entry shares the identical pooled program and trains bit-identically
    // to the reference interpreter.
    let cfg = native::tiny_config();
    let base = EngineStrategy::uniform("dp2tp2pp2", 2, 2, 2, cfg.layers, 4);
    let mut pool = StrategyPool::new(cfg, vec![(base, 4096)]).unwrap();
    let mut eng = pool.spawn_engine(Runtime::native(cfg), 0, 42, 1e-3).unwrap();
    let mut corpus = SyntheticCorpus::new(5, cfg.vocab);
    let (b, s) = (cfg.batch, cfg.seq);
    eng.train_step(&mut |_p, _m| corpus.microbatch(b, s)).unwrap();

    let dead = [2usize, 3, 5];
    let mut cluster = Cluster::h20(8);
    for &d in &dead {
        cluster.fail_gpu(d as u32);
    }
    let cm = CostModel::new(ModelCfg::tiny_100m());
    let rep = hetu::elastic::resynthesize(
        &mut pool, &mut eng, &cluster, &cm, &dead, 16, 2048, &lopts(),
    )
    .unwrap();

    let p_failover = pool.compiled_for(&mut eng).unwrap();
    assert_eq!((pool.artifact_hits(), pool.artifact_misses()), (0, 1));

    // a fresh healthy engine on the re-synthesized entry: plain cache hit,
    // same Arc
    let mut healthy =
        pool.spawn_engine_compiled(Runtime::native(cfg), rep.entry, 7, 1e-3).unwrap();
    let p_healthy = pool.compiled_for(&mut healthy).unwrap();
    assert!(
        Arc::ptr_eq(&p_failover, &p_healthy),
        "failover recompile and healthy compile must share one pooled program"
    );
    assert_eq!((pool.artifact_hits(), pool.artifact_misses()), (1, 1));

    // and the shared tape trains the healthy engine bit-identically
    let mut refr = pool.spawn_engine(Runtime::native(cfg), rep.entry, 7, 1e-3).unwrap();
    let data = Pool::for_strategy(&healthy.strategy, cfg.batch, cfg.seq, cfg.vocab);
    let a = healthy.train_step(&mut |p, m| data.get(p, m)).unwrap();
    let r = refr.train_step_reference(&mut |p, m| data.get(p, m)).unwrap();
    assert_eq!(a.loss.to_bits(), r.loss.to_bits(), "compiled loss bits diverge");
    assert_eq!(a.wire_elems, r.wire_elems);
}
