//! Determinism stress for the concurrent OS-thread executor (ISSUE 6).
//!
//! The threaded executor's contract is that thread scheduling is
//! *invisible* in the numbers: collectives reduce in rank order
//! regardless of arrival order, gradient accumulation follows per-rank
//! program order, and the loss replay is a fixed pipeline-major fold —
//! so every loss and every `StepStats` wire counter is bit-identical to
//! the single-thread oracles no matter how the OS interleaves ranks.
//!
//! The stress drives the lowered Appendix-A hetero encodings (C1/C2/C6,
//! 30+ ranks ⇒ 30+ OS threads) under both schedules with randomized
//! per-task sleeps (`set_exec_jitter`) that exaggerate scheduling skew,
//! and checks every run against `Engine::train_step_reference` — the
//! bottom of the oracle hierarchy (reference interpreter → event-driven
//! executor → threaded executor).

use hetu::engine::{Engine, EngineStrategy, ExecMode, MicroBatch};
use hetu::runtime::{native, Runtime};
use hetu::spec::schedule::ScheduleKind;
use hetu::strategy::{tables, LowerOptions};

fn native_engine(strategy: EngineStrategy, seed: u64, lr: f32) -> Engine {
    Engine::with_runtime(Runtime::native(native::tiny_config()), strategy, seed, lr).unwrap()
}

/// A fixed pipeline-major pool of micro-batches so every execution path
/// sees exactly the same data.
struct Pool {
    mbs: Vec<Vec<MicroBatch>>,
}

impl Pool {
    fn for_strategy(s: &EngineStrategy, seed: u64) -> Pool {
        let cfg = native::tiny_config();
        let mut corpus = hetu::coordinator::SyntheticCorpus::new(seed, cfg.vocab);
        let mbs = s
            .pipelines
            .iter()
            .map(|p| {
                (0..p.num_microbatches).map(|_| corpus.microbatch(cfg.batch, cfg.seq)).collect()
            })
            .collect();
        Pool { mbs }
    }

    fn get(&self, pipe: usize, mb: usize) -> MicroBatch {
        self.mbs[pipe][mb].clone()
    }
}

/// The lowered hetero encodings: 2 uneven pipelines, TP tails, 30+ ranks.
fn lowered_hetero() -> Vec<EngineStrategy> {
    let cfg = native::tiny_config();
    let lopts = LowerOptions { total_microbatches: 7, tp_degrees: vec![1, 2, 4] };
    vec![
        hetu::strategy::lower(&tables::hetu_c1_32h20(), &cfg, &lopts).unwrap(),
        hetu::strategy::lower(&tables::hetu_c2_31h20(), &cfg, &lopts).unwrap(),
        hetu::strategy::lower(&tables::hetu_c6(), &cfg, &lopts).unwrap(),
    ]
}

#[test]
fn threaded_lowered_hetero_is_bit_identical_under_scheduling_jitter() {
    for base in lowered_hetero() {
        // one step for the 30+-rank encodings keeps the stress tractable
        let steps = if base.num_devices() > 8 { 1 } else { 2 };
        for kind in [ScheduleKind::GPipe, ScheduleKind::OneFOneB] {
            let strategy = base.clone().with_schedule(kind);
            let name = strategy.name.clone();
            let pool = Pool::for_strategy(&strategy, 0x6E);

            // the oracle trajectory: the pre-refactor global interpreter
            let mut oracle = native_engine(strategy.clone(), 42, 1e-3);
            let want: Vec<_> = (0..steps)
                .map(|_| oracle.train_step_reference(&mut |p, m| pool.get(p, m)).unwrap())
                .collect();

            // no jitter + two jitter seeds: scheduling skew must not show
            for jitter in [None, Some(1u64), Some(0xDECAF)] {
                let mut th = native_engine(strategy.clone(), 42, 1e-3);
                th.set_exec_mode(ExecMode::Threaded);
                th.set_exec_jitter(jitter);
                for (step, w) in want.iter().enumerate() {
                    let got = th.train_step(&mut |p, m| pool.get(p, m)).unwrap();
                    let tag = format!("{name} ({kind:?}) jitter {jitter:?} step {step}");
                    assert_eq!(
                        got.loss.to_bits(),
                        w.loss.to_bits(),
                        "{tag}: threaded {} != oracle {}",
                        got.loss,
                        w.loss
                    );
                    assert_eq!(got.wire_elems, w.wire_elems, "{tag}: wire");
                    assert_eq!(got.comm_ops, w.comm_ops, "{tag}: ops");
                    assert_eq!(got.tokens, w.tokens, "{tag}: tokens");
                }
            }
        }
    }
}

#[test]
fn threaded_zero1_trajectory_is_jitter_invariant() {
    // ZeRO-1 adds the ZeroExchange global phase (leader-replayed shard
    // scatter) — repeat a 3-step trajectory under distinct jitter seeds
    // and demand one bit pattern
    let s = EngineStrategy::uniform("dp2tp2", 2, 2, 1, 8, 2);
    let pool = Pool::for_strategy(&s, 0x21);
    let mut oracle = native_engine(s.clone(), 42, 1e-3);
    oracle.set_zero1(true).unwrap();
    let want: Vec<_> = (0..3)
        .map(|_| oracle.train_step_reference(&mut |p, m| pool.get(p, m)).unwrap())
        .collect();
    for jitter in [Some(7u64), Some(0xBEE)] {
        let mut th = native_engine(s.clone(), 42, 1e-3);
        th.set_zero1(true).unwrap();
        th.set_exec_mode(ExecMode::Threaded);
        th.set_exec_jitter(jitter);
        for (step, w) in want.iter().enumerate() {
            let got = th.train_step(&mut |p, m| pool.get(p, m)).unwrap();
            assert_eq!(got.loss.to_bits(), w.loss.to_bits(), "jitter {jitter:?} step {step}");
            assert_eq!(got.wire_elems, w.wire_elems, "jitter {jitter:?} step {step}: wire");
            assert_eq!(got.comm_ops, w.comm_ops, "jitter {jitter:?} step {step}: ops");
        }
    }
}

#[test]
fn threaded_wall_clock_makespan_is_reported() {
    // the threaded executor's makespan is wall-clock (unlike the
    // event-driven replay) — it must be positive and the stats sane
    let s = EngineStrategy::uniform("dp2tp2", 2, 2, 1, 8, 2);
    let pool = Pool::for_strategy(&s, 0x9);
    let mut th = native_engine(s, 42, 1e-3);
    th.set_exec_mode(ExecMode::Threaded);
    let stats = th.train_step(&mut |p, m| pool.get(p, m)).unwrap();
    assert!(stats.makespan_s > 0.0, "wall-clock makespan must be measured");
    assert!(stats.loss.is_finite());
    assert_eq!(stats.exposed_switch_s, 0.0, "no switch pending");
}
