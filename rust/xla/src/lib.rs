//! In-tree **stub** of the PJRT/XLA binding surface used by
//! `hetu::runtime`.
//!
//! The build image has no XLA toolchain and no network access, so this
//! crate provides the exact API shape the runtime compiles against:
//! [`Literal`] is fully functional (host-side shape + payload container),
//! while the compile/execute entry points ([`HloModuleProto::from_text_file`],
//! [`PjRtClient::compile`], [`PjRtLoadedExecutable::execute`]) return a
//! descriptive error at runtime. The `hetu` runtime detects missing
//! artifacts up front and falls back to its native Rust reference backend
//! (`hetu::runtime::native`), so the stub paths are only reached when a
//! user points the runtime at real HLO artifacts without a real PJRT
//! client linked in.
//!
//! Swapping this path dependency for an actual PJRT binding restores GPU /
//! compiled-CPU execution without touching `hetu` itself.

use std::fmt;

/// Stub error: every unavailable operation reports through this.
#[derive(Debug, Clone)]
pub struct Error(pub String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for Error {}

fn unavailable(what: &str) -> Error {
    Error(format!(
        "{what}: PJRT/XLA backend is not linked into this build (in-tree stub); \
         the hetu runtime uses its native reference backend instead"
    ))
}

/// Element types of array literals (subset used by the runtime).
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum ElementType {
    /// 32-bit float.
    F32,
    /// 32-bit signed integer.
    S32,
}

/// Primitive type tags accepted by [`Literal::create_from_shape`].
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum PrimitiveType {
    /// 32-bit float.
    F32,
    /// 32-bit signed integer.
    S32,
}

/// Array shape: dimensions + element type.
#[derive(Clone, Debug)]
pub struct ArrayShape {
    dims: Vec<i64>,
    ty: ElementType,
}

impl ArrayShape {
    /// Dimension extents.
    pub fn dims(&self) -> &[i64] {
        &self.dims
    }

    /// Element type.
    pub fn ty(&self) -> ElementType {
        self.ty
    }
}

/// Literal payload storage.
#[derive(Clone, Debug)]
enum Payload {
    F32(Vec<f32>),
    I32(Vec<i32>),
}

/// A host-side literal: dense row-major array with shape + payload.
#[derive(Clone, Debug)]
pub struct Literal {
    dims: Vec<usize>,
    payload: Payload,
}

/// Types that can be copied raw into / out of a [`Literal`].
pub trait NativeType: Copy {
    /// Write a raw buffer into the literal (must match its element type).
    fn write(lit: &mut Literal, data: &[Self]) -> Result<(), Error>;
    /// Read the literal's payload as this type.
    fn read(lit: &Literal) -> Result<Vec<Self>, Error>;
}

impl NativeType for f32 {
    fn write(lit: &mut Literal, data: &[Self]) -> Result<(), Error> {
        match &mut lit.payload {
            Payload::F32(v) => {
                if v.len() != data.len() {
                    return Err(Error(format!(
                        "copy_raw_from: literal holds {} f32s, got {}",
                        v.len(),
                        data.len()
                    )));
                }
                v.copy_from_slice(data);
                Ok(())
            }
            Payload::I32(_) => Err(Error("copy_raw_from: literal is i32, data is f32".into())),
        }
    }

    fn read(lit: &Literal) -> Result<Vec<Self>, Error> {
        match &lit.payload {
            Payload::F32(v) => Ok(v.clone()),
            Payload::I32(_) => Err(Error("to_vec::<f32>: literal is i32".into())),
        }
    }
}

impl NativeType for i32 {
    fn write(lit: &mut Literal, data: &[Self]) -> Result<(), Error> {
        match &mut lit.payload {
            Payload::I32(v) => {
                if v.len() != data.len() {
                    return Err(Error(format!(
                        "copy_raw_from: literal holds {} i32s, got {}",
                        v.len(),
                        data.len()
                    )));
                }
                v.copy_from_slice(data);
                Ok(())
            }
            Payload::F32(_) => Err(Error("copy_raw_from: literal is f32, data is i32".into())),
        }
    }

    fn read(lit: &Literal) -> Result<Vec<Self>, Error> {
        match &lit.payload {
            Payload::I32(v) => Ok(v.clone()),
            Payload::F32(_) => Err(Error("to_vec::<i32>: literal is f32".into())),
        }
    }
}

impl Literal {
    /// Zero-initialized literal of the given type and shape.
    pub fn create_from_shape(ty: PrimitiveType, dims: &[usize]) -> Literal {
        let n: usize = dims.iter().product();
        let payload = match ty {
            PrimitiveType::F32 => Payload::F32(vec![0.0; n]),
            PrimitiveType::S32 => Payload::I32(vec![0; n]),
        };
        Literal { dims: dims.to_vec(), payload }
    }

    /// Copy a raw host buffer into the literal.
    pub fn copy_raw_from<T: NativeType>(&mut self, data: &[T]) -> Result<(), Error> {
        T::write(self, data)
    }

    /// Shape of the literal as an array.
    pub fn array_shape(&self) -> Result<ArrayShape, Error> {
        let ty = match self.payload {
            Payload::F32(_) => ElementType::F32,
            Payload::I32(_) => ElementType::S32,
        };
        Ok(ArrayShape { dims: self.dims.iter().map(|&d| d as i64).collect(), ty })
    }

    /// Payload as a typed vector.
    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>, Error> {
        T::read(self)
    }

    /// Decompose a tuple literal into its elements (stub literals are never
    /// tuples, so this always errors).
    pub fn to_tuple(self) -> Result<Vec<Literal>, Error> {
        Err(unavailable("Literal::to_tuple"))
    }
}

/// Parsed HLO module (stub: cannot be constructed).
pub struct HloModuleProto {
    _private: (),
}

impl HloModuleProto {
    /// Parse an HLO text file (unavailable in the stub).
    pub fn from_text_file(path: &str) -> Result<HloModuleProto, Error> {
        Err(unavailable(&format!("HloModuleProto::from_text_file({path})")))
    }
}

/// An XLA computation wrapping a parsed module.
pub struct XlaComputation {
    _private: (),
}

impl XlaComputation {
    /// Wrap a parsed proto.
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation { _private: () }
    }
}

/// A loaded (compiled) executable (stub: execution unavailable).
pub struct PjRtLoadedExecutable {
    _private: (),
}

/// An on-device buffer handle returned by execution.
pub struct PjRtBuffer {
    _private: (),
}

impl PjRtBuffer {
    /// Fetch the buffer back to the host as a literal.
    pub fn to_literal_sync(&self) -> Result<Literal, Error> {
        Err(unavailable("PjRtBuffer::to_literal_sync"))
    }
}

impl PjRtLoadedExecutable {
    /// Execute with host inputs (unavailable in the stub).
    pub fn execute<T>(&self, _args: &[T]) -> Result<Vec<Vec<PjRtBuffer>>, Error> {
        Err(unavailable("PjRtLoadedExecutable::execute"))
    }
}

/// A PJRT client handle.
pub struct PjRtClient {
    _private: (),
}

impl PjRtClient {
    /// The CPU client. Constructing the handle always succeeds so callers
    /// can defer the unavailability error to compile/execute time.
    pub fn cpu() -> Result<PjRtClient, Error> {
        Ok(PjRtClient { _private: () })
    }

    /// Compile a computation (unavailable in the stub).
    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable, Error> {
        Err(unavailable("PjRtClient::compile"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_roundtrip_f32() {
        let mut lit = Literal::create_from_shape(PrimitiveType::F32, &[2, 3]);
        lit.copy_raw_from(&[1.0f32, 2.0, 3.0, 4.0, 5.0, 6.0]).unwrap();
        let shape = lit.array_shape().unwrap();
        assert_eq!(shape.dims(), &[2, 3]);
        assert_eq!(shape.ty(), ElementType::F32);
        assert_eq!(lit.to_vec::<f32>().unwrap(), vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
        assert!(lit.to_vec::<i32>().is_err());
    }

    #[test]
    fn execute_paths_error_cleanly() {
        assert!(HloModuleProto::from_text_file("x.hlo.txt").is_err());
        let client = PjRtClient::cpu().unwrap();
        let comp = XlaComputation { _private: () };
        assert!(client.compile(&comp).is_err());
    }
}
