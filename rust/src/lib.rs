//! # Hetu v2 / HSPMD — reproduction library
//!
//! This crate reproduces the system described in *"Hetu v2: A General and
//! Scalable Deep Learning System with Hierarchical and Heterogeneous Single
//! Program Multiple Data Annotations"* (The Hetu Team @ PKU, cs.DC 2025).
//!
//! The paper's contribution — **HSPMD**, a hierarchical/heterogeneous
//! extension of SPMD sharding annotations, together with hierarchical
//! communication resolution, progressive graph specialization, and dynamic
//! graph switching — lives in the Rust layer (L3). Model compute (L2 JAX) and
//! the attention/RMSNorm hot-spots (L1 Pallas) are AOT-compiled to HLO text
//! at build time and executed through the PJRT CPU client at runtime; Python
//! is never on the training path.
//!
//! Module map (see DESIGN.md for the full inventory):
//!
//! - [`hspmd`] — §3 sharding annotations: `DistStates`, `DeviceGroup`,
//!   unions, `HDim`/`HSize`, slice geometry.
//! - [`comm`] — §4 hierarchical communication resolution + batched
//!   send-receive (BSR) planning, §6.2 fused BSR.
//! - [`graph`] — §5.1–5.2 computation graph, CommOp, annotation deduction,
//!   §5.5 symbolic shapes.
//! - [`spec`] — §5.3–5.4 operator instantiation (per-device executable
//!   graphs) and pipeline construction + GPipe/1F1B schedules.
//! - [`switch`] — §6 multi-annotation graphs and fused-BSR strategy
//!   transitions.
//! - [`temporal`] — the §6 temporal-heterogeneity runtime: strategy pool
//!   with a pairwise switch-plan cache, Hetu-A/B length-aware dispatch,
//!   and §6.2 switch/compute overlap accounting.
//! - [`cluster`], [`sim`], [`costmodel`] — the simulated heterogeneous
//!   testbed (Table 3) and discrete-event execution timeline.
//! - [`strategy`], [`data`], [`baselines`] — Appendix-A strategy encodings,
//!   mixed-length data substrate, and the five comparison systems.
//! - [`runtime`], [`collectives`], [`engine`] — PJRT artifact execution and
//!   the real-numerics distributed engine (threads = devices).
//! - [`obs`] — per-rank execution tracing: span recorder in all three
//!   executors, Chrome-trace export, measured step breakdowns, and
//!   span-calibrated dispatch profiles (DESIGN.md §10).
//! - [`elastic`], [`coordinator`], [`config`], [`metrics`] — failure traces
//!   and reconfiguration, the top-level trainer, CLI/config, reporting.

pub mod baselines;
pub mod cluster;
pub mod collectives;
pub mod comm;
pub mod config;
pub mod coordinator;
pub mod costmodel;
pub mod data;
pub mod elastic;
pub mod engine;
pub mod error;
pub mod figures;
pub mod graph;
pub mod hspmd;
pub mod metrics;
pub mod obs;
pub mod runtime;
pub mod sim;
pub mod spec;
pub mod strategy;
pub mod switch;
pub mod temporal;
pub mod testutil;

pub use error::{Error, Result};
