//! §6 graph switching at engine level, driven by the §6.2 fused-BSR
//! planner.
//!
//! The seed engine re-implemented switching with ad-hoc sender picking and
//! its own reslicing arithmetic; plan-level volumes (Table 2) and
//! engine-measured wire traffic came from two unrelated code paths. Here
//! `switch_to` instead:
//!
//! 1. exports the old and new [`ShardLayout`]s as HSPMD annotations and
//!    builds one [`TensorMove`] per changed parameter (and optimizer
//!    moment) — the same inputs `switch::plan_strategy_switch` feeds the
//!    planner at paper scale;
//! 2. asks [`plan_transition_avoiding`] for a fused [`FusedBsrPlan`]
//!    (heuristics 1–3, shared load tracker, per-device-pair message
//!    fusion, dead senders excluded);
//! 3. *executes* that plan over the mesh: local copies materialize
//!    receiver-side staging buffers for free, each fused message moves its
//!    slice payloads and accounts wire volume once — so the engine's
//!    measured `wire_elems` equals `plan.wire_bytes() / 4` by
//!    construction (asserted in `rust/tests/engine_integration.rs`);
//! 4. commits the staged shards and evicts every parameter, moment, and
//!    gradient shard a device no longer owns under the new layout
//!    (devices dropped by the strategy are emptied entirely).

use std::collections::{BTreeMap, HashMap};

use crate::collectives::{extract_region, localize, write_region};
use crate::comm::fused::plan_transition_avoiding;
use crate::comm::{Bandwidth, BsrOptions, FusedBsrPlan, TensorMove, UniformBandwidth};
use crate::hspmd::dg::Rank;
use crate::hspmd::slices::{Interval, Region};
use crate::runtime::{HostTensor, ManifestConfig};
use crate::{Error, Result};

use super::layout::{full_shape, pkey, special_shape, ShardLayout};
use super::{Engine, EngineStrategy, BLOCK_PARAMS};

/// Outcome of an engine-level strategy switch.
#[derive(Clone, Debug)]
pub struct EngineSwitchReport {
    /// The fused-BSR transition plan that was executed.
    pub plan: FusedBsrPlan,
    /// Fused messages launched (mesh `ops` delta).
    pub messages: u64,
    /// Elements measured on the wire while executing the plan.
    pub wire_elems: u64,
    /// Measured elements per `(sender, receiver)` device pair — the
    /// engine-side Table-2 rows (local copies move zero wire and are not
    /// listed).
    pub sent: BTreeMap<(usize, usize), u64>,
}

/// What a planned tensor move refers to in the engine's stores.
enum Target {
    /// A block parameter `(layer, param index)`.
    Block(u32, usize),
    /// A root-held tensor (`emb`/`gf`/`wout`).
    Special(&'static str),
}

/// The region `dev` holds of a move target under `layout` (global coords).
fn region_under(
    layout: &ShardLayout,
    cfg: &ManifestConfig,
    target: &Target,
    dev: usize,
) -> Result<Region> {
    match target {
        Target::Block(l, pidx) => layout.region_of(*l, *pidx, dev).cloned().ok_or_else(|| {
            Error::Engine(format!(
                "switch: device {dev} holds no shard of layer {l} param {pidx}"
            ))
        }),
        Target::Special(name) => Ok(special_shape(cfg, name)
            .iter()
            .map(|&n| Interval { lo: 0, hi: n })
            .collect()),
    }
}

/// Base parameter key of a device-store key if it is parameter state
/// (parameter, optimizer moment, or gradient); `None` for transient
/// activation buffers.
fn param_base(key: &str) -> Option<&str> {
    let base = key
        .strip_prefix("m.")
        .or_else(|| key.strip_prefix("v."))
        .or_else(|| key.strip_prefix("grad."))
        .unwrap_or(key);
    let is_param =
        base == "emb" || base == "gf" || base == "wout" || (base.starts_with('L') && base.contains('.'));
    if is_param {
        Some(base)
    } else {
        None
    }
}

impl Engine {
    /// §6 switching: repartition every parameter (and optimizer moment)
    /// from the current layout to `new` by executing the fused-BSR plan.
    /// Returns `(messages, elems moved)`.
    pub fn switch_to(&mut self, new: EngineStrategy) -> Result<(u64, u64)> {
        let report = self.switch_to_avoiding(new, &[])?;
        Ok((report.messages, report.wire_elems))
    }

    /// [`Engine::switch_to`] with `dead` devices excluded as senders (§7.2
    /// elastic failover: a failed rank cannot source weights; surviving
    /// replicas cover its slices or planning errors out). The new strategy
    /// must not schedule a dead device. Returns the full report including
    /// the executed plan.
    pub fn switch_to_avoiding(
        &mut self,
        new: EngineStrategy,
        dead: &[usize],
    ) -> Result<EngineSwitchReport> {
        let cfg = self.runtime.config;
        new.validate(&cfg, &self.tp_degrees)?;
        for p in &new.pipelines {
            for s in &p.stages {
                if let Some(&d) = s.devices.iter().find(|&d| dead.contains(d)) {
                    return Err(Error::Engine(format!(
                        "{}: strategy schedules dead device {d}",
                        new.name
                    )));
                }
            }
        }
        let new_layout = ShardLayout::build(&cfg, &new)?;

        // grow the mesh if the new strategy brings devices online
        let need = new
            .pipelines
            .iter()
            .flat_map(|p| p.stages.iter().flat_map(|s| s.devices.iter().copied()))
            .max()
            .map(|m| m + 1)
            .unwrap_or(0);
        while self.mesh.devices.len() < need {
            self.mesh.devices.push(Default::default());
        }

        // ---- 1. tensor moves for every changed parameter (+ moments)
        let have_moments = self
            .layout
            .update_ops
            .first()
            .map(|(dev, pk, _)| self.mesh.devices[*dev].has(&format!("m.{pk}")))
            .unwrap_or(false);
        let prefixes: &[&str] = if have_moments { &["", "m.", "v."] } else { &[""] };

        let mut moves: Vec<TensorMove> = vec![];
        let mut targets: Vec<Target> = vec![];
        for l in 0..cfg.layers {
            for (pidx, name) in BLOCK_PARAMS.iter().enumerate() {
                let src = self.layout.annotation(l, pidx)?;
                let dst = new_layout.annotation(l, pidx)?;
                if src == dst {
                    continue;
                }
                let shape = full_shape(&cfg, name);
                for pre in prefixes {
                    moves.push(TensorMove {
                        name: format!("{pre}{}", pkey(l, name)),
                        src: src.clone(),
                        dst: dst.clone(),
                        shape: shape.clone(),
                        elem_bytes: 4,
                    });
                    targets.push(Target::Block(l, pidx));
                }
            }
        }
        let specials: [(&'static str, &Vec<usize>, &Vec<usize>); 3] = [
            ("emb", &self.layout.first_roots, &new_layout.first_roots),
            ("gf", &self.layout.last_roots, &new_layout.last_roots),
            ("wout", &self.layout.last_roots, &new_layout.last_roots),
        ];
        for (name, old_roots, new_roots) in specials {
            let src = ShardLayout::root_annotation(old_roots)?;
            let dst = ShardLayout::root_annotation(new_roots)?;
            if src == dst {
                continue;
            }
            let shape = special_shape(&cfg, name);
            for pre in prefixes {
                moves.push(TensorMove {
                    name: format!("{pre}{name}"),
                    src: src.clone(),
                    dst: dst.clone(),
                    shape: shape.clone(),
                    elem_bytes: 4,
                });
                targets.push(Target::Special(name));
            }
        }

        // ---- 2. one fused plan for the whole transition. When the engine
        // knows the physical topology behind its device ids, sender
        // selection runs the bandwidth heuristic (2) — intra-node replicas
        // are preferred as sources — instead of the uniform stand-in.
        let dead_ranks: Vec<Rank> = dead.iter().map(|&d| d as Rank).collect();
        if let Some(c) = &self.topology {
            if c.len() < self.mesh.devices.len() {
                return Err(Error::Engine(format!(
                    "topology covers {} devices but the mesh has {}",
                    c.len(),
                    self.mesh.devices.len()
                )));
            }
        }
        let bw: &dyn Bandwidth = match &self.topology {
            Some(c) => c,
            None => &UniformBandwidth,
        };
        let plan = plan_transition_avoiding(&moves, bw, BsrOptions::default(), true, &dead_ranks)?;

        // ---- 3. execute: stage destination shards, then commit.
        // Staging (rather than in-place writes) keeps every source read
        // consistent with the pre-switch state.
        let wire0 = self.mesh.wire_elems;
        let ops0 = self.mesh.ops;
        let mut staged: HashMap<(usize, usize), HostTensor> = HashMap::new();

        let mut sent: BTreeMap<(usize, usize), u64> = BTreeMap::new();
        for (rank, ti, slice) in &plan.local_copies {
            let dev = *rank as usize;
            self.stage_piece(&new_layout, &mut staged, &moves, &targets, *ti, dev, dev, slice)?;
        }
        for mi in 0..plan.messages.len() {
            self.mesh.ops += 1;
            let (from, to) = (plan.messages[mi].from as usize, plan.messages[mi].to as usize);
            for (ti, slice) in &plan.messages[mi].items {
                let moved = self
                    .stage_piece(&new_layout, &mut staged, &moves, &targets, *ti, from, to, slice)?;
                self.mesh.wire_elems += moved;
                *sent.entry((from, to)).or_insert(0) += moved;
            }
        }
        for ((dev, ti), tensor) in staged {
            self.mesh.devices[dev].put(&moves[ti].name, tensor);
        }

        // ---- 4. evict state not owned under the new layout
        for dev in 0..self.mesh.devices.len() {
            let keys = self.mesh.devices[dev].keys();
            let owned = new_layout.owned_keys(dev);
            for key in keys {
                let drop = match param_base(&key) {
                    Some(base) => owned.map(|o| !o.contains(base)).unwrap_or(true),
                    // transient buffers only linger on devices that left
                    // the strategy entirely
                    None => owned.is_none(),
                };
                if drop {
                    let _ = self.mesh.devices[dev].take(&key);
                }
            }
        }

        let report = EngineSwitchReport {
            messages: self.mesh.ops - ops0,
            wire_elems: self.mesh.wire_elems - wire0,
            plan,
            sent,
        };
        self.strategy = new;
        self.layout = new_layout;
        Ok(report)
    }

    /// Move one planned slice of move `ti` from `from`'s current shard into
    /// `to`'s staging buffer; returns the slice element count (wire volume
    /// when `from != to`).
    #[allow(clippy::too_many_arguments)]
    fn stage_piece(
        &mut self,
        new_layout: &ShardLayout,
        staged: &mut HashMap<(usize, usize), HostTensor>,
        moves: &[TensorMove],
        targets: &[Target],
        ti: usize,
        from: usize,
        to: usize,
        slice: &Region,
    ) -> Result<u64> {
        let cfg = self.runtime.config;
        let key = &moves[ti].name;
        let src_region = region_under(&self.layout, &cfg, &targets[ti], from)?;
        let src_tensor = self.mesh.devices[from].get(key).map_err(|_| {
            Error::Engine(format!("switch: sender {from} is missing `{key}`"))
        })?;
        let piece = extract_region(src_tensor, &localize(slice, &src_region))?;
        let elems = piece.len() as u64;
        let dst_region = region_under(new_layout, &cfg, &targets[ti], to)?;
        let buf = match staged.entry((to, ti)) {
            std::collections::hash_map::Entry::Occupied(e) => e.into_mut(),
            std::collections::hash_map::Entry::Vacant(e) => {
                let shape: Vec<usize> =
                    dst_region.iter().map(|iv| iv.len() as usize).collect();
                e.insert(HostTensor::zeros(shape))
            }
        };
        write_region(buf, &localize(slice, &dst_region), &piece)?;
        Ok(elems)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn param_base_classifies_keys() {
        assert_eq!(param_base("L3.wq"), Some("L3.wq"));
        assert_eq!(param_base("m.L3.wq"), Some("L3.wq"));
        assert_eq!(param_base("v.emb"), Some("emb"));
        assert_eq!(param_base("grad.wout"), Some("wout"));
        assert_eq!(param_base("grad.L0.g1"), Some("L0.g1"));
        assert_eq!(param_base("act"), None);
        assert_eq!(param_base("save.mb0.L3"), None);
        assert_eq!(param_base("dpart"), None);
    }
}
