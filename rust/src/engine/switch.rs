//! §6 graph switching at engine level, driven by the §6.2 fused-BSR
//! planner.
//!
//! The seed engine re-implemented switching with ad-hoc sender picking and
//! its own reslicing arithmetic; plan-level volumes (Table 2) and
//! engine-measured wire traffic came from two unrelated code paths. Here
//! switching is split into a *planning* half and an *execution* half so
//! the temporal runtime ([`crate::temporal`]) can cache plans pairwise:
//!
//! 1. [`build_moves`] exports the old and new [`ShardLayout`]s as HSPMD
//!    annotations and builds one [`TensorMove`] per changed parameter
//!    (and optimizer moment) — the same inputs
//!    `switch::plan_strategy_switch` feeds the planner at paper scale;
//! 2. [`plan_switch`] asks [`plan_transition_avoiding`] for a fused
//!    [`FusedBsrPlan`] (heuristics 1–3, shared load tracker,
//!    per-device-pair message fusion, dead senders excluded) and bundles
//!    it with the moves into a reusable [`SwitchPlan`];
//! 3. `Engine::switch_to_avoiding` (fresh plan) and
//!    [`Engine::switch_to_planned`] (cached plan) both *execute* that plan
//!    over the mesh: local copies materialize receiver-side staging
//!    buffers for free, fused messages are processed **batched per
//!    sender** (source regions resolved once per `(sender, tensor)`, the
//!    per-sender wall time measured for the §6.2 switch/compute overlap
//!    model — senders run concurrently in a deployment, so the
//!    transition's delivery time is the slowest sender's batch, not the
//!    sum) and each message accounts wire volume once — so the engine's
//!    measured `wire_elems` equals `plan.wire_bytes() / 4` by
//!    construction (asserted in `rust/tests/engine_integration.rs`);
//! 4. the staged shards are committed and every parameter, moment, and
//!    gradient shard a device no longer owns under the new layout is
//!    evicted (devices dropped by the strategy are emptied entirely).
//!
//! ZeRO-1 engines ([`Engine::set_zero1`]) hold only a DP partition of each
//! moment tensor; the execution half gathers partitions back to full
//! shards before staging (accounted separately as `moment_gather_elems`)
//! and re-shards them under the new layout after commit, so the cached
//! plans stay moment-layout-agnostic.

use std::collections::{BTreeMap, HashMap};
use std::sync::Arc;
use std::time::Instant;

use crate::collectives::{extract_region, localize, write_region};
use crate::comm::fused::plan_transition_avoiding;
use crate::comm::{Bandwidth, BsrOptions, FusedBsrPlan, TensorMove, UniformBandwidth};
use crate::hspmd::dg::Rank;
use crate::hspmd::slices::{Interval, Region};
use crate::runtime::{HostTensor, ManifestConfig};
use crate::{Error, Result};

use super::layout::{full_shape, pkey, special_shape, ShardLayout};
use super::{Engine, EngineStrategy, BLOCK_PARAMS};

/// Outcome of an engine-level strategy switch.
#[derive(Clone, Debug)]
pub struct EngineSwitchReport {
    /// The fused-BSR transition plan that was executed — shared with the
    /// (possibly cached) [`SwitchPlan`], not cloned: a pooled cache hit
    /// builds this report allocation-free.
    pub plan: Arc<FusedBsrPlan>,
    /// Plan summary: fused messages the plan prescribes.
    pub plan_messages: u64,
    /// Plan summary: total wire bytes the plan prescribes.
    pub plan_wire_bytes: u64,
    /// Fused messages launched (mesh `ops` delta).
    pub messages: u64,
    /// Elements measured on the wire while executing the plan.
    pub wire_elems: u64,
    /// Measured elements per `(sender, receiver)` device pair — the
    /// engine-side Table-2 rows (local copies move zero wire and are not
    /// listed).
    pub sent: BTreeMap<(usize, usize), u64>,
    /// Measured wall seconds each sender spent delivering its fused
    /// message batch (senders run concurrently in a deployment).
    pub per_sender_s: BTreeMap<usize, f64>,
    /// The transition's delivery time under concurrent senders: the
    /// slowest sender's batch. This is the quantity the §6.2 overlap
    /// model hides behind the first post-switch step
    /// ([`crate::temporal::overlap`]).
    pub delivery_s: f64,
    /// Elements moved by the ZeRO-1 moment gather that precedes plan
    /// execution (zero when the engine does not shard optimizer states).
    pub moment_gather_elems: u64,
}

/// What a planned tensor move refers to in the engine's stores.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum MoveTarget {
    /// A block parameter `(layer, param index)`.
    Block(u32, usize),
    /// A root-held tensor (`emb`/`gf`/`wout`).
    Special(&'static str),
}

/// A fully-planned strategy transition: the tensor moves, what each refers
/// to in the engine's stores, and the fused-BSR plan over them. Built once
/// per `(from layout, to layout, moments?)` triple and reusable across
/// repeated executions — the temporal runtime's pairwise plan cache
/// ([`crate::temporal::StrategyPool`]) stores these.
#[derive(Clone, Debug)]
pub struct SwitchPlan {
    /// Tensor moves in deterministic `(layer, param)` order, specials
    /// last.
    pub moves: Vec<TensorMove>,
    /// Store target of each move (parallel to `moves`).
    pub targets: Vec<MoveTarget>,
    /// The fused-BSR plan over `moves` (shared into every
    /// [`EngineSwitchReport`] that executes it).
    pub plan: Arc<FusedBsrPlan>,
    /// Whether optimizer moments (`m.*`/`v.*`) ride along. Must match the
    /// executing engine's state; [`Engine::switch_to_planned`] rejects a
    /// mismatch.
    pub with_moments: bool,
}

/// Typed error when `new` schedules any device in `dead` (shared by the
/// fresh-plan and cached-plan failover paths).
fn ensure_no_dead_scheduled(new: &EngineStrategy, dead: &[usize]) -> Result<()> {
    for p in &new.pipelines {
        for s in &p.stages {
            if let Some(&d) = s.devices.iter().find(|&d| dead.contains(d)) {
                return Err(Error::Engine(format!(
                    "{}: strategy schedules dead device {d}",
                    new.name
                )));
            }
        }
    }
    Ok(())
}

/// The region `dev` holds of a move target under `layout` (global coords).
fn region_under(
    layout: &ShardLayout,
    cfg: &ManifestConfig,
    target: &MoveTarget,
    dev: usize,
) -> Result<Region> {
    match target {
        MoveTarget::Block(l, pidx) => {
            layout.region_of(*l, *pidx, dev).cloned().ok_or_else(|| {
                Error::Engine(format!(
                    "switch: device {dev} holds no shard of layer {l} param {pidx}"
                ))
            })
        }
        MoveTarget::Special(name) => Ok(special_shape(cfg, name)
            .iter()
            .map(|&n| Interval { lo: 0, hi: n })
            .collect()),
    }
}

/// Base parameter key of a device-store key if it is parameter state
/// (parameter, optimizer moment, or gradient); `None` for transient
/// activation buffers.
fn param_base(key: &str) -> Option<&str> {
    let base = key
        .strip_prefix("m.")
        .or_else(|| key.strip_prefix("v."))
        .or_else(|| key.strip_prefix("grad."))
        .unwrap_or(key);
    let is_param =
        base == "emb" || base == "gf" || base == "wout" || (base.starts_with('L') && base.contains('.'));
    if is_param {
        Some(base)
    } else {
        None
    }
}

/// Build the [`TensorMove`] list for an `old → new` layout transition: one
/// move per changed block parameter and root tensor, with `m.*`/`v.*`
/// companions when `with_moments`.
pub fn build_moves(
    cfg: &ManifestConfig,
    old: &ShardLayout,
    new: &ShardLayout,
    with_moments: bool,
) -> Result<(Vec<TensorMove>, Vec<MoveTarget>)> {
    let prefixes: &[&str] = if with_moments { &["", "m.", "v."] } else { &[""] };
    let mut moves: Vec<TensorMove> = vec![];
    let mut targets: Vec<MoveTarget> = vec![];
    for l in 0..cfg.layers {
        for (pidx, name) in BLOCK_PARAMS.iter().enumerate() {
            let src = old.annotation(l, pidx)?;
            let dst = new.annotation(l, pidx)?;
            if src == dst {
                continue;
            }
            let shape = full_shape(cfg, name);
            for pre in prefixes {
                moves.push(TensorMove {
                    name: format!("{pre}{}", pkey(l, name)),
                    src: src.clone(),
                    dst: dst.clone(),
                    shape: shape.clone(),
                    elem_bytes: 4,
                });
                targets.push(MoveTarget::Block(l, pidx));
            }
        }
    }
    let specials: [(&'static str, &Vec<usize>, &Vec<usize>); 3] = [
        ("emb", &old.first_roots, &new.first_roots),
        ("gf", &old.last_roots, &new.last_roots),
        ("wout", &old.last_roots, &new.last_roots),
    ];
    for (name, old_roots, new_roots) in specials {
        let src = ShardLayout::root_annotation(old_roots)?;
        let dst = ShardLayout::root_annotation(new_roots)?;
        if src == dst {
            continue;
        }
        let shape = special_shape(cfg, name);
        for pre in prefixes {
            moves.push(TensorMove {
                name: format!("{pre}{name}"),
                src: src.clone(),
                dst: dst.clone(),
                shape: shape.clone(),
                elem_bytes: 4,
            });
            targets.push(MoveTarget::Special(name));
        }
    }
    Ok((moves, targets))
}

/// Plan an `old → new` layout transition end-to-end: moves plus the fused
/// BSR plan over them. `dead` devices are excluded as senders (cached
/// pool plans pass `&[]`; failover switches re-plan fresh).
pub fn plan_switch(
    cfg: &ManifestConfig,
    old: &ShardLayout,
    new: &ShardLayout,
    with_moments: bool,
    bw: &dyn Bandwidth,
    dead: &[usize],
) -> Result<SwitchPlan> {
    let (moves, targets) = build_moves(cfg, old, new, with_moments)?;
    let dead_ranks: Vec<Rank> = dead.iter().map(|&d| d as Rank).collect();
    let plan =
        Arc::new(plan_transition_avoiding(&moves, bw, BsrOptions::default(), true, &dead_ranks)?);
    Ok(SwitchPlan { moves, targets, plan, with_moments })
}

impl Engine {
    /// §6 switching: repartition every parameter (and optimizer moment)
    /// from the current layout to `new` by executing the fused-BSR plan.
    /// Returns `(messages, elems moved)`.
    pub fn switch_to(&mut self, new: EngineStrategy) -> Result<(u64, u64)> {
        let report = self.switch_to_avoiding(new, &[])?;
        Ok((report.messages, report.wire_elems))
    }

    /// [`Engine::switch_to`] with `dead` devices excluded as senders (§7.2
    /// elastic failover: a failed rank cannot source weights; surviving
    /// replicas cover its slices or planning errors out). The new strategy
    /// must not schedule a dead device. Returns the full report including
    /// the executed plan.
    pub fn switch_to_avoiding(
        &mut self,
        new: EngineStrategy,
        dead: &[usize],
    ) -> Result<EngineSwitchReport> {
        let cfg = self.runtime.config;
        new.validate(&cfg, &self.tp_degrees)?;
        ensure_no_dead_scheduled(&new, dead)?;
        let new_layout = Arc::new(ShardLayout::build(&cfg, &new)?);

        // When the engine knows the physical topology behind its device
        // ids, sender selection runs the bandwidth heuristic (2) —
        // intra-node replicas are preferred as sources — instead of the
        // uniform stand-in. It must cover the post-switch mesh.
        self.require_topology_coverage(new.max_device_bound().max(self.mesh.devices.len()))?;
        let bw: &dyn Bandwidth = match &self.topology {
            Some(c) => c,
            None => &UniformBandwidth,
        };
        let sp = plan_switch(&cfg, &self.layout, &new_layout, self.has_moments(), bw, dead)?;
        self.execute_switch(new, new_layout, &sp, dead)
    }

    /// Execute a *pre-built* [`SwitchPlan`] (the temporal runtime's hot
    /// path: the pairwise plan cache hands back the same plan on repeated
    /// A↔B transitions, so no BSR re-planning happens). The caller
    /// guarantees `sp` was planned from the engine's current layout to
    /// `new_layout`; `with_moments` is re-checked against the engine's
    /// actual state.
    pub fn switch_to_planned(
        &mut self,
        new: EngineStrategy,
        new_layout: Arc<ShardLayout>,
        sp: &SwitchPlan,
    ) -> Result<EngineSwitchReport> {
        self.switch_to_planned_avoiding(new, new_layout, sp, &[])
    }

    /// [`Engine::switch_to_planned`] under failover: `dead` ranks must
    /// not be scheduled by `new`, contribute nothing to the ZeRO-1 moment
    /// gather, and — the caller's obligation — must not appear as senders
    /// in `sp` (a cached pool plan is only reusable when the failed rank
    /// held no needed shard; `StrategyPool::switch_engine_avoiding`
    /// checks exactly that and re-plans otherwise). A dead sender in the
    /// plan is a typed error, not a silent read from a failed rank.
    pub fn switch_to_planned_avoiding(
        &mut self,
        new: EngineStrategy,
        new_layout: Arc<ShardLayout>,
        sp: &SwitchPlan,
        dead: &[usize],
    ) -> Result<EngineSwitchReport> {
        let cfg = self.runtime.config;
        new.validate(&cfg, &self.tp_degrees)?;
        ensure_no_dead_scheduled(&new, dead)?;
        if sp.with_moments != self.has_moments() {
            return Err(Error::Engine(format!(
                "switch_to_planned: plan {} moments but the engine {} them",
                if sp.with_moments { "includes" } else { "omits" },
                if self.has_moments() { "has" } else { "lacks" }
            )));
        }
        if let Some(m) =
            sp.plan.messages.iter().find(|m| dead.contains(&(m.from as usize)))
        {
            return Err(Error::Engine(format!(
                "switch_to_planned: cached plan reads from dead rank {} — \
                 re-plan with the dead senders excluded",
                m.from
            )));
        }
        self.execute_switch(new, new_layout, sp, dead)
    }

    /// The shared execution half: moment gather (ZeRO-1), staging via
    /// per-sender message batches, commit, eviction, moment re-shard.
    /// `dead` devices contribute nothing to the moment gather — a failed
    /// rank's ZeRO-1 partition is genuinely lost (the App.-A trade-off),
    /// so the reassembled moments keep zeros where its slice was.
    fn execute_switch(
        &mut self,
        new: EngineStrategy,
        new_layout: Arc<ShardLayout>,
        sp: &SwitchPlan,
        dead: &[usize],
    ) -> Result<EngineSwitchReport> {
        let cfg = self.runtime.config;

        // grow the mesh if the new strategy brings devices online
        while self.mesh.devices.len() < new.max_device_bound() {
            self.mesh.devices.push(Default::default());
        }

        // ---- 0. ZeRO-1: materialize full moment shards so the plan's
        // param-shaped moment moves can extract from them — but only for
        // parameters the plan actually moves (an unchanged annotation
        // keeps its partitions valid, so gathering it would waste wire).
        // Accounted separately from the plan's wire volume.
        let moved_moments: std::collections::BTreeSet<&str> = if self.zero1 && sp.with_moments {
            sp.moves.iter().filter_map(|m| m.name.strip_prefix("m.")).collect()
        } else {
            Default::default()
        };
        let gather0 = self.mesh.wire_elems;
        if !moved_moments.is_empty() {
            self.gather_zero1_moments(&moved_moments, dead)?;
        }
        let moment_gather_elems = self.mesh.wire_elems - gather0;

        // ---- 1. execute: stage destination shards, then commit. Staging
        // (rather than in-place writes) keeps every source read consistent
        // with the pre-switch state. Messages are processed batched per
        // sender: source regions resolve once per (sender, tensor) and
        // each sender's wall time is measured — senders are concurrent in
        // a deployment, so `delivery_s` is the slowest batch.
        let wire0 = self.mesh.wire_elems;
        let ops0 = self.mesh.ops;
        let mut staged: HashMap<(usize, usize), HostTensor> = HashMap::new();
        let mut sent: BTreeMap<(usize, usize), u64> = BTreeMap::new();

        for (rank, ti, slice) in &sp.plan.local_copies {
            let dev = *rank as usize;
            let src_region = region_under(&self.layout, &cfg, &sp.targets[*ti], dev)?;
            self.stage_piece(&new_layout, &mut staged, sp, *ti, dev, dev, slice, &src_region)?;
        }

        let mut by_sender: BTreeMap<usize, Vec<usize>> = BTreeMap::new();
        for (mi, m) in sp.plan.messages.iter().enumerate() {
            by_sender.entry(m.from as usize).or_default().push(mi);
        }
        let mut per_sender_s: BTreeMap<usize, f64> = BTreeMap::new();
        for (&from, batch) in &by_sender {
            let t0 = Instant::now();
            let mut src_regions: HashMap<usize, Region> = HashMap::new();
            for &mi in batch {
                self.mesh.ops += 1;
                let to = sp.plan.messages[mi].to as usize;
                for (ti, slice) in &sp.plan.messages[mi].items {
                    let src_region = match src_regions.entry(*ti) {
                        std::collections::hash_map::Entry::Occupied(e) => e.get().clone(),
                        std::collections::hash_map::Entry::Vacant(e) => e
                            .insert(region_under(&self.layout, &cfg, &sp.targets[*ti], from)?)
                            .clone(),
                    };
                    let moved = self.stage_piece(
                        &new_layout,
                        &mut staged,
                        sp,
                        *ti,
                        from,
                        to,
                        slice,
                        &src_region,
                    )?;
                    self.mesh.wire_elems += moved;
                    *sent.entry((from, to)).or_insert(0) += moved;
                }
            }
            per_sender_s.insert(from, t0.elapsed().as_secs_f64());
        }
        for ((dev, ti), tensor) in staged {
            self.mesh.devices[dev].put(&sp.moves[ti].name, tensor);
        }

        // ---- 2. evict state not owned under the new layout
        for dev in 0..self.mesh.devices.len() {
            let keys = self.mesh.devices[dev].keys();
            let owned = new_layout.owned_keys(dev);
            for key in keys {
                let drop = match param_base(&key) {
                    // a base the new layout never interned is owned nowhere
                    Some(base) => match (owned, new_layout.key_id(base)) {
                        (Some(o), Some(id)) => !o.contains(&id),
                        (Some(_), None) => true,
                        (None, _) => true,
                    },
                    // transient buffers only linger on devices that left
                    // the strategy entirely
                    None => owned.is_none(),
                };
                if drop {
                    let _ = self.mesh.devices[dev].take(&key);
                }
            }
        }

        let delivery_s = per_sender_s.values().copied().fold(0.0, f64::max);
        // queue the per-sender batches for injection into the first
        // post-switch step's timelines (§6.2 measured interleave,
        // DESIGN.md §7.3); back-to-back switches serialize per sender
        for (&s, &t) in &per_sender_s {
            self.pending_deliveries.push((s, t));
        }
        let report = EngineSwitchReport {
            messages: self.mesh.ops - ops0,
            wire_elems: self.mesh.wire_elems - wire0,
            plan: Arc::clone(&sp.plan), // refcount bump, no FusedBsrPlan clone
            plan_messages: sp.plan.num_messages() as u64,
            plan_wire_bytes: sp.plan.wire_bytes(),
            sent,
            per_sender_s,
            delivery_s,
            moment_gather_elems,
        };
        self.strategy = new;
        self.layout = new_layout;
        // the old per-pipeline window contract indexed the old pipelines
        self.mb_windows = None;
        // the per-rank specialization described the old strategy; the
        // next step re-specializes the survivors/new layout
        self.spec = None;
        // ... and the compiled tape froze that specialization's keys and
        // endpoints — same invalidation event (the pool's artifact cache
        // still holds it for the switch back)
        self.compiled = None;

        // ---- 3. ZeRO-1: trim the freshly-arrived full moment shards back
        // to each device's DP partition under the new layout (unmoved
        // parameters kept their old — still valid — partitions).
        if !moved_moments.is_empty() {
            self.reshard_zero1_moments(&moved_moments)?;
        }
        Ok(report)
    }

    /// Move one planned slice of move `ti` from `from`'s current shard
    /// (whose global region is `src_region`) into `to`'s staging buffer;
    /// returns the slice element count (wire volume when `from != to`).
    #[allow(clippy::too_many_arguments)]
    fn stage_piece(
        &mut self,
        new_layout: &ShardLayout,
        staged: &mut HashMap<(usize, usize), HostTensor>,
        sp: &SwitchPlan,
        ti: usize,
        from: usize,
        to: usize,
        slice: &Region,
        src_region: &Region,
    ) -> Result<u64> {
        let cfg = self.runtime.config;
        let key = &sp.moves[ti].name;
        let src_tensor = self.mesh.devices[from].get(key).map_err(|_| {
            Error::Engine(format!("switch: sender {from} is missing `{key}`"))
        })?;
        let piece = extract_region(src_tensor, &localize(slice, src_region))?;
        let elems = piece.len() as u64;
        let dst_region = region_under(new_layout, &cfg, &sp.targets[ti], to)?;
        let buf = match staged.entry((to, ti)) {
            std::collections::hash_map::Entry::Occupied(e) => e.into_mut(),
            std::collections::hash_map::Entry::Vacant(e) => {
                let shape: Vec<usize> =
                    dst_region.iter().map(|iv| iv.len() as usize).collect();
                e.insert(HostTensor::zeros(shape))
            }
        };
        write_region(buf, &localize(slice, &dst_region), &piece)?;
        Ok(elems)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn param_base_classifies_keys() {
        assert_eq!(param_base("L3.wq"), Some("L3.wq"));
        assert_eq!(param_base("m.L3.wq"), Some("L3.wq"));
        assert_eq!(param_base("v.emb"), Some("emb"));
        assert_eq!(param_base("grad.wout"), Some("wout"));
        assert_eq!(param_base("grad.L0.g1"), Some("L0.g1"));
        assert_eq!(param_base("act"), None);
        assert_eq!(param_base("save.mb0.L3"), None);
        assert_eq!(param_base("dpart"), None);
    }

    #[test]
    fn plan_switch_is_deterministic_and_reusable() {
        use crate::runtime::native;
        let cfg = native::tiny_config();
        let a = ShardLayout::build(&cfg, &EngineStrategy::uniform("dp2", 2, 1, 1, 8, 1)).unwrap();
        let b = ShardLayout::build(&cfg, &EngineStrategy::uniform("tp2", 1, 2, 1, 8, 2)).unwrap();
        let p1 = plan_switch(&cfg, &a, &b, false, &UniformBandwidth, &[]).unwrap();
        let p2 = plan_switch(&cfg, &a, &b, false, &UniformBandwidth, &[]).unwrap();
        assert_eq!(p1.moves.len(), p2.moves.len());
        assert_eq!(p1.plan.num_messages(), p2.plan.num_messages());
        assert_eq!(p1.plan.wire_bytes(), p2.plan.wire_bytes());
        // moments triple the move count when they ride along
        let pm = plan_switch(&cfg, &a, &b, true, &UniformBandwidth, &[]).unwrap();
        assert_eq!(pm.moves.len(), 3 * p1.moves.len());
        assert!(pm.with_moments && !p1.with_moments);
    }
}
