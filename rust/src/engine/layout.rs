//! The shard-layout layer: a first-class, typed ownership map computed
//! **once per strategy** and reused by parameter init, gradient sync, the
//! optimizer, and §6 graph switching.
//!
//! The seed engine re-derived "who owns which shard" independently in four
//! places (init, sync, update, switch), rebuilding `BTreeMap` sync groups
//! every step and hard-rejecting per-layer heterogeneous TP because the
//! `(layer, param, shard index)` keying cannot describe it. [`ShardLayout`]
//! replaces all of that with *region* bookkeeping on the global parameter
//! tensors (the same `hspmd::slices` geometry the §4 resolver and §4.3 BSR
//! planner use):
//!
//! * every device's holding of every `(layer, param)` is an axis-aligned
//!   [`Region`] of the full tensor — TP degree is just the region width, so
//!   different DP replicas may hold the same layer at different TP degrees;
//! * the DP gradient-sync plan ([`SyncOp`]) is the finest-grained slice
//!   grid over those regions: slices shared by holders with identical local
//!   extents reduce with a plain `AllReduce`, ragged sharings reduce
//!   region-wise ([`crate::collectives::Mesh::all_reduce_region`]);
//! * [`ShardLayout::annotation`] exports each parameter's holding as an
//!   HSPMD [`Annotation`] (one sharding subgroup per pipeline), which is
//!   what lets `Engine::switch_to` hand the §6.2 fused-BSR planner the
//!   exact engine layout (DESIGN.md §4).

use std::collections::{BTreeMap, BTreeSet};

use crate::collectives::localize;
use crate::hspmd::annot::{Annotation, Subgroup};
use crate::hspmd::dg::{DeviceGroup, Rank};
use crate::hspmd::ds::{DistStates, DUPLICATE};
use crate::hspmd::slices::{DeviceRegion, Interval, Region, SliceGrid};
use crate::runtime::ManifestConfig;
use crate::{Error, Result};

use super::intern::{KeyId, KeyInterner};
use super::{EngineStrategy, BLOCK_PARAMS};

/// Parameter-store key of a block parameter shard.
pub fn pkey(l: u32, p: &str) -> String {
    format!("L{l}.{p}")
}

/// Gradient-store key of a block parameter shard.
pub fn gkey(l: u32, p: &str) -> String {
    format!("grad.L{l}.{p}")
}

/// Megatron sharding axis of a block parameter.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum SplitAxis {
    /// Replicated across the TP group (RMSNorm gains).
    Replicated,
    /// Column-split (dim 1): `wq`, `wk`, `wv`, `w1`.
    Col,
    /// Row-split (dim 0): `wo`, `w2`.
    Row,
}

/// Sharding axis of a named block parameter.
pub fn split_axis(name: &str) -> SplitAxis {
    match name {
        "wq" | "wk" | "wv" | "w1" => SplitAxis::Col,
        "wo" | "w2" => SplitAxis::Row,
        _ => SplitAxis::Replicated,
    }
}

/// Full (unsharded) shape of a block parameter.
pub fn full_shape(cfg: &ManifestConfig, name: &str) -> Vec<u64> {
    let (h, f) = (cfg.hidden as u64, cfg.ffn as u64);
    match name {
        "g1" | "g2" => vec![h],
        "w1" => vec![h, f],
        "w2" => vec![f, h],
        _ => vec![h, h], // wq, wk, wv, wo
    }
}

/// Full shape of a non-block parameter (`emb`, `gf`, `wout`).
pub fn special_shape(cfg: &ManifestConfig, name: &str) -> Vec<u64> {
    let (h, v) = (cfg.hidden as u64, cfg.vocab as u64);
    match name {
        "emb" => vec![v, h],
        "wout" => vec![h, v],
        _ => vec![h], // gf
    }
}

/// The global region shard `j` of `tp` owns under `axis` sharding.
pub fn shard_region(shape: &[u64], axis: SplitAxis, tp: usize, j: usize) -> Region {
    let mut r: Region = shape.iter().map(|&n| Interval { lo: 0, hi: n }).collect();
    let d = match axis {
        SplitAxis::Replicated => return r,
        SplitAxis::Col => 1,
        SplitAxis::Row => 0,
    };
    let n = shape[d];
    let (t, j) = (tp as u64, j as u64);
    r[d] = Interval { lo: n * j / t, hi: n * (j + 1) / t };
    r
}

/// One device's holding of one `(layer, param)`.
#[derive(Clone, Debug)]
pub struct Holding {
    /// Mesh device id.
    pub dev: usize,
    /// Pipeline (DP replica) index.
    pub pipeline: usize,
    /// TP shard index within the stage.
    pub shard: usize,
    /// TP degree of the stage holding this layer.
    pub tp: usize,
    /// Owned box of the global parameter tensor.
    pub region: Region,
}

/// One gradient-synchronization step of the cached per-strategy plan.
/// Keys are interned [`KeyId`]s relative to the owning [`ShardLayout`]'s
/// table — resolve with [`ShardLayout::key`] at the device-store boundary.
#[derive(Clone, Debug)]
pub enum SyncOp {
    /// Plain all-reduce: every member holds the same extents.
    AllReduce {
        /// Gradient key.
        key: KeyId,
        /// Participating devices.
        devs: Vec<usize>,
    },
    /// Region-wise all-reduce of one atomic slice shared by holders whose
    /// local coordinates differ (per-layer heterogeneous TP).
    SliceReduce {
        /// Gradient key.
        key: KeyId,
        /// `(device, local region)` per holder.
        parts: Vec<(usize, Region)>,
    },
}

/// One ZeRO-1 replica set: devices holding an *identical* region of one
/// parameter, each owning a contiguous dim-0 partition of the shard's
/// optimizer state. Ragged (hetero-TP) sharings stay replicated — only
/// exact duplicates shard, which is what makes the partitioned update
/// bit-identical to the replicated one (elementwise AdamW over
/// slice-synced gradients).
#[derive(Clone, Debug)]
pub struct ZeroGroup {
    /// Parameter key (`L{l}.{param}`, `emb`, `gf`, `wout`), interned.
    pub key: KeyId,
    /// Replica devices (sorted, deduplicated).
    pub members: Vec<usize>,
    /// `(device, sub-box in the shard's local coordinates)` per partition
    /// owner. Members with no rows (more replicas than rows) are absent.
    pub parts: Vec<(usize, Region)>,
}

/// The typed `(layer, param, shard)` ownership map plus every derived
/// group the engine needs per step — computed once per strategy.
#[derive(Clone, Debug)]
pub struct ShardLayout {
    holdings: BTreeMap<(u32, usize), Vec<Holding>>,
    /// DP/TP gradient-reduction plan for block parameters, in deterministic
    /// `(layer, param)` order.
    pub sync_ops: Vec<SyncOp>,
    /// Stage-0 root device of each pipeline (embedding owners).
    pub first_roots: Vec<usize>,
    /// Last-stage root device of each pipeline (head owners).
    pub last_roots: Vec<usize>,
    /// Every `(device, gradient key)` produced by a step, for scaling
    /// without scanning device stores.
    pub grad_keys: Vec<(usize, KeyId)>,
    /// Every `(device, param key, grad key)` optimizer application.
    pub update_ops: Vec<(usize, KeyId, KeyId)>,
    /// ZeRO-1 partition plan over replica sets (used when the engine's
    /// `zero1` flag is on; computed unconditionally — it is cheap and the
    /// memory accounting in [`crate::strategy::memory`] reads it).
    pub zero_groups: Vec<ZeroGroup>,
    owned: BTreeMap<usize, BTreeSet<KeyId>>,
    /// Per-device ZeRO-1 roles: `key → None` (grouped, no rows) or
    /// `key → Some(region)` (partition owner).
    zero_parts: BTreeMap<usize, BTreeMap<KeyId, Option<Region>>>,
    /// Key table: every plan above stores dense [`KeyId`]s minted here;
    /// strings are formatted once per distinct key at build time and
    /// resolved by array index at the device-store boundary.
    keys: KeyInterner,
}

/// Contiguous dim-0 partition of `region` (a shard held identically by
/// `devs`) over its replicas, in the shard's local coordinates.
fn zero_partition(key: KeyId, devs: &[usize], region: &Region) -> ZeroGroup {
    let rows = region[0].len();
    let g = devs.len() as u64;
    let mut parts = vec![];
    for (k, &d) in devs.iter().enumerate() {
        let lo = rows * k as u64 / g;
        let hi = rows * (k as u64 + 1) / g;
        if hi > lo {
            let mut r: Region =
                region.iter().map(|iv| Interval { lo: 0, hi: iv.len() }).collect();
            r[0] = Interval { lo, hi };
            parts.push((d, r));
        }
    }
    ZeroGroup { key, members: devs.to_vec(), parts }
}

impl ShardLayout {
    /// Build the layout for a validated strategy.
    pub fn build(cfg: &ManifestConfig, strategy: &EngineStrategy) -> Result<ShardLayout> {
        let mut keys = KeyInterner::new();
        let mut holdings: BTreeMap<(u32, usize), Vec<Holding>> = BTreeMap::new();
        for (pi, pipe) in strategy.pipelines.iter().enumerate() {
            for stage in &pipe.stages {
                let tp = stage.tp();
                for l in stage.layers.0..stage.layers.1 {
                    for (pidx, name) in BLOCK_PARAMS.iter().enumerate() {
                        let shape = full_shape(cfg, name);
                        let axis = split_axis(name);
                        for (j, &dev) in stage.devices.iter().enumerate() {
                            holdings.entry((l, pidx)).or_default().push(Holding {
                                dev,
                                pipeline: pi,
                                shard: j,
                                tp,
                                region: shard_region(&shape, axis, tp, j),
                            });
                        }
                    }
                }
            }
        }

        // Gradient-sync plan: finest-grained slice grid per (layer, param).
        // Gains are full-region holdings on every TP member, so their single
        // atomic slice reduces raw per-device partials across *all* holders
        // (subsuming the seed's separate TP-internal gain pass); split
        // params reduce per atomic slice across the DP replicas sharing it.
        let mut sync_ops = vec![];
        for ((l, pidx), hs) in &holdings {
            if hs.len() <= 1 {
                continue;
            }
            let name = BLOCK_PARAMS[*pidx];
            let key = keys.intern(&gkey(*l, name));
            let shape = full_shape(cfg, name);
            let regs: Vec<DeviceRegion> = hs
                .iter()
                .map(|h| DeviceRegion {
                    rank: h.dev as Rank,
                    region: h.region.clone(),
                    partial: false,
                    subgroup: h.pipeline,
                })
                .collect();
            let grid = SliceGrid::build(&shape, &[regs.as_slice()]);
            for slice in grid.slices() {
                let holders = SliceGrid::holders(&slice, &regs);
                if holders.len() <= 1 {
                    continue;
                }
                if holders.iter().all(|h| h.region == slice) {
                    sync_ops.push(SyncOp::AllReduce {
                        key,
                        devs: holders.iter().map(|h| h.rank as usize).collect(),
                    });
                } else {
                    sync_ops.push(SyncOp::SliceReduce {
                        key,
                        parts: holders
                            .iter()
                            .map(|h| (h.rank as usize, localize(&slice, &h.region)))
                            .collect(),
                    });
                }
            }
        }

        let first_roots: Vec<usize> =
            strategy.pipelines.iter().map(|p| p.stages[0].devices[0]).collect();
        let last_roots: Vec<usize> = strategy
            .pipelines
            .iter()
            .map(|p| p.stages.last().unwrap().devices[0])
            .collect();

        let mut grad_keys = vec![];
        let mut update_ops = vec![];
        let mut owned: BTreeMap<usize, BTreeSet<KeyId>> = BTreeMap::new();
        for ((l, pidx), hs) in &holdings {
            let name = BLOCK_PARAMS[*pidx];
            // one format + intern per (layer, param); per-holding work is
            // Copy-id pushes and integer-keyed set inserts — this is what
            // keeps build cost flat as the rank count grows.
            let pk = keys.intern(&pkey(*l, name));
            let gk = keys.intern(&gkey(*l, name));
            for h in hs {
                grad_keys.push((h.dev, gk));
                update_ops.push((h.dev, pk, gk));
                owned.entry(h.dev).or_default().insert(pk);
            }
        }
        let emb = keys.intern("emb");
        let gf = keys.intern("gf");
        let wout = keys.intern("wout");
        let g_emb = keys.intern("grad.emb");
        let g_gf = keys.intern("grad.gf");
        let g_wout = keys.intern("grad.wout");
        for (&fr, &lr) in first_roots.iter().zip(last_roots.iter()) {
            grad_keys.push((fr, g_emb));
            grad_keys.push((lr, g_gf));
            grad_keys.push((lr, g_wout));
            update_ops.push((fr, emb, g_emb));
            update_ops.push((lr, gf, g_gf));
            update_ops.push((lr, wout, g_wout));
            owned.entry(fr).or_default().insert(emb);
            owned.entry(lr).or_default().insert(gf);
            owned.entry(lr).or_default().insert(wout);
        }

        // ZeRO-1 partition plan: replica sets (devices holding identical
        // regions) split the shard's dim 0 contiguously by member index.
        let mut zero_groups: Vec<ZeroGroup> = vec![];
        for ((l, pidx), hs) in &holdings {
            if hs.len() <= 1 {
                continue;
            }
            let mut all_devs: Vec<usize> = hs.iter().map(|h| h.dev).collect();
            all_devs.sort_unstable();
            if all_devs.windows(2).any(|w| w[0] == w[1]) {
                continue; // a device holding the param twice stays replicated
            }
            let name = BLOCK_PARAMS[*pidx];
            let pk = keys.intern(&pkey(*l, name));
            let mut by_region: BTreeMap<Region, Vec<usize>> = BTreeMap::new();
            for h in hs {
                by_region.entry(h.region.clone()).or_default().push(h.dev);
            }
            for (region, mut devs) in by_region {
                devs.sort_unstable();
                if devs.len() > 1 {
                    zero_groups.push(zero_partition(pk, &devs, &region));
                }
            }
        }
        for (key, roots, shape) in [
            (emb, &first_roots, special_shape(cfg, "emb")),
            (gf, &last_roots, special_shape(cfg, "gf")),
            (wout, &last_roots, special_shape(cfg, "wout")),
        ] {
            let mut devs = roots.clone();
            devs.sort_unstable();
            devs.dedup();
            if devs.len() > 1 {
                let region: Region =
                    shape.iter().map(|&n| Interval { lo: 0, hi: n }).collect();
                zero_groups.push(zero_partition(key, &devs, &region));
            }
        }
        let mut zero_parts: BTreeMap<usize, BTreeMap<KeyId, Option<Region>>> = BTreeMap::new();
        for g in &zero_groups {
            for &m in &g.members {
                zero_parts.entry(m).or_default().insert(g.key, None);
            }
            for (d, r) in &g.parts {
                zero_parts.entry(*d).or_default().insert(g.key, Some(r.clone()));
            }
        }

        Ok(ShardLayout {
            holdings,
            sync_ops,
            first_roots,
            last_roots,
            grad_keys,
            update_ops,
            zero_groups,
            owned,
            zero_parts,
            keys,
        })
    }

    /// Resolve an interned key id back to its string (array index, no
    /// allocation). Ids are only meaningful for this layout's table.
    #[inline]
    pub fn key(&self, id: KeyId) -> &str {
        self.keys.resolve(id)
    }

    /// Id of a key string under this layout's table, if interned.
    pub fn key_id(&self, key: &str) -> Option<KeyId> {
        self.keys.lookup(key)
    }

    /// ZeRO-1 role of `(dev, param key)`: `None` when the pair is not in
    /// any replica group (the device updates its full shard); `Some(None)`
    /// when grouped but owning no partition rows; `Some(Some(region))` for
    /// partition owners (local shard coordinates).
    pub fn zero_part(&self, dev: usize, key: &str) -> Option<Option<&Region>> {
        self.zero_part_id(dev, self.keys.lookup(key)?)
    }

    /// [`Self::zero_part`] by interned id — the per-step lookup path.
    pub fn zero_part_id(&self, dev: usize, key: KeyId) -> Option<Option<&Region>> {
        self.zero_parts.get(&dev)?.get(&key).map(|o| o.as_ref())
    }

    /// Holdings of one `(layer, param index)` (empty if uncovered).
    pub fn holdings_of(&self, l: u32, pidx: usize) -> &[Holding] {
        self.holdings.get(&(l, pidx)).map(|v| v.as_slice()).unwrap_or(&[])
    }

    /// Iterate all `(layer, param index) -> holdings` entries.
    pub fn iter_holdings(
        &self,
    ) -> impl Iterator<Item = (&(u32, usize), &Vec<Holding>)> + '_ {
        self.holdings.iter()
    }

    /// The region `dev` owns of `(layer, param)`, if any.
    pub fn region_of(&self, l: u32, pidx: usize, dev: usize) -> Option<&Region> {
        self.holdings
            .get(&(l, pidx))?
            .iter()
            .find(|h| h.dev == dev)
            .map(|h| &h.region)
    }

    /// Parameter keys `dev` owns under this layout (`L*.{param}`, `emb`,
    /// `gf`, `wout`) as interned ids, or `None` if the device holds
    /// nothing. Resolve with [`Self::key`]; test membership of a string
    /// via [`Self::key_id`] (a miss means "not owned").
    pub fn owned_keys(&self, dev: usize) -> Option<&BTreeSet<KeyId>> {
        self.owned.get(&dev)
    }

    /// Export the holding of `(layer, param)` as an HSPMD annotation: one
    /// sharding subgroup per pipeline (device order = shard order), gains
    /// replicated, split params `split(axis, tp)`. Different subgroups may
    /// carry different TP degrees — the paper's asymmetric sharding.
    pub fn annotation(&self, l: u32, pidx: usize) -> Result<Annotation> {
        let hs = self
            .holdings
            .get(&(l, pidx))
            .ok_or_else(|| Error::Engine(format!("no holdings for layer {l} param {pidx}")))?;
        let axis = split_axis(BLOCK_PARAMS[pidx]);
        let mut per_pipe: BTreeMap<usize, Vec<&Holding>> = BTreeMap::new();
        for h in hs {
            per_pipe.entry(h.pipeline).or_default().push(h);
        }
        let mut groups = vec![];
        for (_pi, mut members) in per_pipe {
            members.sort_by_key(|h| h.shard);
            let tp = members.len() as u32;
            let dg = DeviceGroup::new(members.iter().map(|h| h.dev as Rank).collect())?;
            let ds = match axis {
                SplitAxis::Replicated => DistStates::duplicate(tp),
                SplitAxis::Col => DistStates::split(1, tp),
                SplitAxis::Row => DistStates::split(0, tp),
            };
            groups.push(Subgroup::new(dg, ds)?);
        }
        Annotation::new(groups, DUPLICATE)
    }

    /// Annotation of a root-held tensor (`emb`/`gf`/`wout`): replicated
    /// across the pipeline roots.
    pub fn root_annotation(roots: &[usize]) -> Result<Annotation> {
        let dg = DeviceGroup::new(roots.iter().map(|&r| r as Rank).collect())?;
        Annotation::spmd(dg, DistStates::duplicate(roots.len() as u32))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::{EnginePipeline, EngineStage};
    use crate::runtime::native;

    fn hetero_strategy() -> EngineStrategy {
        // same 8 layers at TP2 (pipeline 0, devices 0-1) and TP1 (pipeline
        // 1, device 2) — the previously-rejected asymmetric case.
        EngineStrategy {
            name: "hetero".into(),
            pipelines: vec![
                EnginePipeline {
                    stages: vec![EngineStage { devices: vec![0, 1], layers: (0, 8) }],
                    num_microbatches: 1,
                },
                EnginePipeline {
                    stages: vec![EngineStage { devices: vec![2], layers: (0, 8) }],
                    num_microbatches: 1,
                },
            ],
            schedule: crate::spec::schedule::ScheduleKind::GPipe,
        }
    }

    #[test]
    fn homogeneous_sync_plan_uses_plain_allreduce() {
        let cfg = native::tiny_config();
        let s = EngineStrategy::uniform("dp2tp2", 2, 2, 1, 8, 1);
        let layout = ShardLayout::build(&cfg, &s).unwrap();
        assert!(!layout.sync_ops.is_empty());
        assert!(layout
            .sync_ops
            .iter()
            .all(|op| matches!(op, SyncOp::AllReduce { .. })));
        // gains reduce across all 4 holders; split shards across the 2 DP
        // replicas holding the same shard index.
        let (mut gain_groups, mut shard_groups) = (0, 0);
        for op in &layout.sync_ops {
            if let SyncOp::AllReduce { key, devs } = op {
                let key = layout.key(*key);
                if key.ends_with(".g1") || key.ends_with(".g2") {
                    assert_eq!(devs.len(), 4, "{key}");
                    gain_groups += 1;
                } else {
                    assert_eq!(devs.len(), 2, "{key}");
                    shard_groups += 1;
                }
            }
        }
        assert_eq!(gain_groups, 8 * 2);
        assert_eq!(shard_groups, 8 * 6 * 2);
    }

    #[test]
    fn hetero_tp_sync_plan_is_slice_aware() {
        let cfg = native::tiny_config();
        let layout = ShardLayout::build(&cfg, &hetero_strategy()).unwrap();
        let mut saw_slice = false;
        for op in &layout.sync_ops {
            match op {
                SyncOp::AllReduce { key, devs } => {
                    let key = layout.key(*key);
                    // only gains stay whole-tensor (3 holders: 0, 1, 2)
                    assert!(key.ends_with(".g1") || key.ends_with(".g2"), "{key}");
                    assert_eq!(devs.len(), 3);
                }
                SyncOp::SliceReduce { key, parts } => {
                    let key = layout.key(*key);
                    saw_slice = true;
                    assert_eq!(parts.len(), 2, "{key}: tp2 shard + tp1 sub-slice");
                    // extents agree across parts
                    let e0: Vec<u64> =
                        parts[0].1.iter().map(|iv| iv.len()).collect();
                    let e1: Vec<u64> =
                        parts[1].1.iter().map(|iv| iv.len()).collect();
                    assert_eq!(e0, e1, "{key}");
                }
            }
        }
        assert!(saw_slice);
    }

    #[test]
    fn annotations_describe_asymmetric_sharding() {
        let cfg = native::tiny_config();
        let layout = ShardLayout::build(&cfg, &hetero_strategy()).unwrap();
        // wq (param index 1) is column-split
        let a = layout.annotation(0, 1).unwrap();
        assert_eq!(a.hsize(), 2);
        assert_eq!(a.groups[0].dg.ranks(), &[0, 1]);
        assert_eq!(a.groups[1].dg.ranks(), &[2]);
        let shape = full_shape(&cfg, "wq");
        let regs = crate::hspmd::slices::regions(&a, &shape).unwrap();
        // pipeline 0 splits columns, pipeline 1 holds the full tensor
        assert_eq!(regs[0].region[1], Interval { lo: 0, hi: shape[1] / 2 });
        assert_eq!(regs[2].region[1], Interval { lo: 0, hi: shape[1] });
    }

    #[test]
    fn zero_groups_partition_replica_sets() {
        let cfg = native::tiny_config();
        // dp2tp2: every block shard is held identically by 2 devices (one
        // per replica); gains by 4. Roots replicate 2-ways.
        let s = EngineStrategy::uniform("dp2tp2", 2, 2, 1, 8, 1);
        let layout = ShardLayout::build(&cfg, &s).unwrap();
        assert!(!layout.zero_groups.is_empty());
        for g in &layout.zero_groups {
            let key = layout.key(g.key);
            assert!(g.members.len() >= 2, "{key}");
            // partitions tile dim 0 of the shard exactly
            let total: u64 = g.parts.iter().map(|(_, r)| r[0].len()).sum();
            let mut next = 0u64;
            for (_, r) in &g.parts {
                assert_eq!(r[0].lo, next, "{key}: gap in partition");
                next = r[0].hi;
            }
            assert_eq!(total, next);
            // every owner is a member
            for (d, _) in &g.parts {
                assert!(g.members.contains(d));
            }
        }
        // lookups agree with the groups
        let wq = pkey(0, "wq");
        let part = layout.zero_part(0, &wq);
        assert!(matches!(part, Some(Some(_))), "device 0 owns a wq partition");
        assert!(layout.zero_part(0, "no-such-key").is_none());
        // hetero-TP (ragged) sharings stay replicated
        let h = ShardLayout::build(&cfg, &hetero_strategy()).unwrap();
        assert!(
            h.zero_groups.iter().all(|g| !h.key(g.key).ends_with(".wq")),
            "ragged wq sharing must not zero-shard"
        );
        // ...but its identically-held gains do form a group
        assert!(h.zero_groups.iter().any(|g| h.key(g.key).ends_with(".g1")));
    }

    #[test]
    fn ownership_map_and_roots() {
        let cfg = native::tiny_config();
        let s = EngineStrategy::uniform("dp2pp2", 2, 1, 2, 8, 1);
        let layout = ShardLayout::build(&cfg, &s).unwrap();
        assert_eq!(layout.first_roots, vec![0, 2]);
        assert_eq!(layout.last_roots, vec![1, 3]);
        let d0 = layout.owned_keys(0).unwrap();
        assert!(d0.contains(&layout.key_id("emb").unwrap()));
        assert!(d0.contains(&layout.key_id("L0.wq").unwrap()));
        assert!(!d0.contains(&layout.key_id("L7.wq").unwrap()));
        assert!(layout.owned_keys(9).is_none());
        assert!(layout.region_of(0, 1, 0).is_some());
        assert!(layout.region_of(7, 1, 0).is_none());
    }

}
