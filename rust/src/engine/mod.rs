//! The real-numerics distributed training engine.
//!
//! Executes a distributed training program — TP-sharded parallel blocks,
//! pipeline stages, data-parallel replicas — over a [`Mesh`] of simulated
//! devices, with all model compute performed by artifact calls through the
//! [`Runtime`] (PJRT AOT artifacts when present, the native Rust reference
//! backend otherwise) and every inter-device byte moved by the real
//! [`collectives`](crate::collectives). Distributed numerics are exact:
//! tests compare multi-device losses/gradients against the single-device
//! oracle configuration.
//!
//! The engine is layered (DESIGN.md §4, §7): [`layout`] holds the
//! [`ShardLayout`] — the typed `(layer, param, shard)` ownership map with
//! cached sync/update/ownership plans, computed once per strategy, whose
//! region-based bookkeeping also enables per-layer heterogeneous TP;
//! [`specialize`] lowers a strategy + layout + schedule into per-rank
//! [`RankPlan`] timelines with communication as explicit tasks; [`exec`]
//! is the event-driven executor over those timelines (plus the legacy
//! global interpreter, kept as the differential numerics oracle);
//! [`switch`] executes §6 strategy transitions from a
//! [`comm::FusedBsrPlan`](crate::comm::FusedBsrPlan), handing its
//! per-sender delivery batches to the first post-switch step for the
//! §6.2 measured interleave; [`optim`] is AdamW on each device's local
//! shards.

pub mod compile;
pub mod exec;
pub mod intern;
pub mod layout;
pub mod optim;
pub mod specialize;
pub mod switch;
pub mod thread;

use std::sync::Arc;

use crate::cluster::Cluster;
use crate::collectives::Mesh;
use crate::runtime::{ManifestConfig, Runtime};
use crate::spec::schedule::ScheduleKind;
use crate::{Error, Result};

pub use compile::{
    compile_program, CompiledOp, CompiledProgram, FusedCall, FusedKind, Seg, ShapeClass,
};
pub use intern::{KeyId, KeyInterner};
pub use layout::{ShardLayout, SyncOp, ZeroGroup};
pub use optim::AdamW;
pub use specialize::{specialize, HandoffEdge, RankPlan, SpecTask, SpecTaskKind, SpecializedPlan};
pub use switch::{build_moves, plan_switch, EngineSwitchReport, MoveTarget, SwitchPlan};

/// The 8 per-block parameter names, artifact input order.
pub const BLOCK_PARAMS: [&str; 8] = ["g1", "wq", "wk", "wv", "wo", "g2", "w1", "w2"];

/// One pipeline stage: an ordered TP group owning a contiguous layer range.
#[derive(Clone, Debug, PartialEq)]
pub struct EngineStage {
    /// Mesh device ids (TP group, position = shard index).
    pub devices: Vec<usize>,
    /// Layer range `[lo, hi)`.
    pub layers: (u32, u32),
}

impl EngineStage {
    /// TP degree.
    pub fn tp(&self) -> usize {
        self.devices.len()
    }
}

/// One pipeline.
#[derive(Clone, Debug, PartialEq)]
pub struct EnginePipeline {
    /// Stages in order.
    pub stages: Vec<EngineStage>,
    /// Micro-batches per step.
    pub num_microbatches: usize,
}

/// A full engine strategy (the runnable mirror of
/// [`crate::strategy::ParallelStrategy`] at tiny-model scale, produced by
/// hand or by [`crate::strategy::lower::lower`]).
#[derive(Clone, Debug, PartialEq)]
pub struct EngineStrategy {
    /// Strategy label.
    pub name: String,
    /// Pipelines (DP across them).
    pub pipelines: Vec<EnginePipeline>,
    /// Pipeline schedule the interpreter follows ([`exec`] consumes the
    /// task orders of [`crate::spec::schedule`], so the same strategy runs
    /// under GPipe and 1F1B with identical numerics up to f32 reordering).
    pub schedule: ScheduleKind,
}

impl EngineStrategy {
    /// Uniform DP×TP×PP over devices `0..dp*tp*pp` (GPipe schedule).
    pub fn uniform(name: &str, dp: usize, tp: usize, pp: usize, layers: u32, num_mb: usize) -> Self {
        let mut pipelines = vec![];
        let mut dev = 0usize;
        for _ in 0..dp {
            let mut stages = vec![];
            let mut l = 0u32;
            for s in 0..pp {
                let hi = layers * (s as u32 + 1) / pp as u32;
                stages.push(EngineStage { devices: (dev..dev + tp).collect(), layers: (l, hi) });
                dev += tp;
                l = hi;
            }
            pipelines.push(EnginePipeline { stages, num_microbatches: num_mb });
        }
        EngineStrategy { name: name.into(), pipelines, schedule: ScheduleKind::GPipe }
    }

    /// The same strategy under a different pipeline schedule.
    pub fn with_schedule(mut self, kind: ScheduleKind) -> Self {
        self.schedule = kind;
        self
    }

    /// Total devices used.
    pub fn num_devices(&self) -> usize {
        self.pipelines.iter().flat_map(|p| p.stages.iter()).map(|s| s.devices.len()).sum()
    }

    /// One past the highest mesh device id the strategy schedules (0 when
    /// it schedules none) — the mesh-size / topology-coverage bound used
    /// by engine construction, switching, and the pool.
    pub fn max_device_bound(&self) -> usize {
        self.pipelines
            .iter()
            .flat_map(|p| p.stages.iter().flat_map(|s| s.devices.iter().copied()))
            .max()
            .map(|m| m + 1)
            .unwrap_or(0)
    }

    /// Validate against the model config + supported TP degrees. Per-layer
    /// heterogeneous TP across DP replicas is allowed: the [`ShardLayout`]
    /// reduces shared slices region-wise (DESIGN.md §4).
    pub fn validate(&self, cfg: &ManifestConfig, tp_degrees: &[usize]) -> Result<()> {
        for p in &self.pipelines {
            let mut next = 0u32;
            for s in &p.stages {
                if s.layers.0 != next {
                    return Err(Error::Engine(format!(
                        "{}: stage layers not contiguous at {}",
                        self.name, s.layers.0
                    )));
                }
                if s.layers.1 <= s.layers.0 {
                    return Err(Error::Engine(format!(
                        "{}: empty stage at layer {}",
                        self.name, s.layers.0
                    )));
                }
                next = s.layers.1;
                if !tp_degrees.contains(&s.tp()) {
                    return Err(Error::Engine(format!(
                        "{}: no block artifact for tp={} (have {tp_degrees:?})",
                        self.name,
                        s.tp()
                    )));
                }
            }
            if next != cfg.layers {
                return Err(Error::Engine(format!(
                    "{}: pipeline covers {next}/{} layers",
                    self.name, cfg.layers
                )));
            }
            if p.num_microbatches == 0 {
                return Err(Error::Engine("zero microbatches".into()));
            }
        }
        Ok(())
    }
}

/// A training batch for one *ragged* micro-batch: `[n_seqs, seq_len]`
/// token/target ids. Each row is one packed data window; rows may be
/// right-padded, with padding marked by target `-1` (the padding mask) —
/// masked positions contribute no loss and no gradient, and the loss
/// normalizes over real positions only, so a padded batch is numerically
/// identical to executing every window at its true length (asserted in
/// `rust/tests/engine_integration.rs`).
#[derive(Clone, Debug)]
pub struct MicroBatch {
    /// Input token ids, row-major `[n_seqs, seq_len]` (pad positions hold
    /// token 0 — masked from loss, so the id is arbitrary).
    pub tokens: Vec<i32>,
    /// Next-token targets; `-1` marks a padded position.
    pub targets: Vec<i32>,
    /// Rows (packed windows) in this micro-batch.
    pub n_seqs: usize,
    /// Row width in tokens (the longest window; shorter rows are padded).
    pub seq_len: usize,
}

impl MicroBatch {
    /// Real (unmasked) token positions.
    pub fn real_tokens(&self) -> u64 {
        self.targets.iter().filter(|&&t| t >= 0).count() as u64
    }

    /// Real (unmasked) token positions of one row — the same `-1`
    /// padding sentinel as [`MicroBatch::real_tokens`], kept in one
    /// place so the window-contract validation and the token-weighted
    /// sync can never disagree on the mask convention.
    pub fn real_tokens_in_row(&self, row: usize) -> usize {
        self.targets[row * self.seq_len..(row + 1) * self.seq_len]
            .iter()
            .filter(|&&t| t >= 0)
            .count()
    }

    /// All positions, padding included (`n_seqs · seq_len`).
    pub fn positions(&self) -> u64 {
        (self.n_seqs * self.seq_len) as u64
    }
}

/// The shape contract of one ragged engine micro-batch (the §5.5 symbolic
/// shape the temporal dispatcher prescribes per step): each entry of
/// `rows` is one packed window's real length in engine tokens; rows
/// shorter than `seq_len` are right-padded and masked.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct WindowShape {
    /// Per-row real window lengths.
    pub rows: Vec<usize>,
    /// Row width (`max(rows)`; shorter rows pad up to it).
    pub seq_len: usize,
}

impl WindowShape {
    /// Rows in the micro-batch.
    pub fn n_seqs(&self) -> usize {
        self.rows.len()
    }

    /// Real token cells across the rows.
    pub fn real_cells(&self) -> usize {
        self.rows.iter().sum()
    }

    /// Well-formedness: at least one row and every row in `1..=seq_len`
    /// (a width beyond the longest row is legal — it is just padding, and
    /// padding is masked).
    pub fn validate(&self) -> Result<()> {
        if self.rows.is_empty() {
            return Err(Error::Engine("window shape: no rows".into()));
        }
        if self.rows.iter().any(|&r| r == 0 || r > self.seq_len) {
            return Err(Error::Engine(format!(
                "window shape: rows {:?} outside (0, {}]",
                self.rows, self.seq_len
            )));
        }
        Ok(())
    }
}

/// Step outcome.
#[derive(Clone, Debug)]
pub struct StepStats {
    /// Token-weighted mean loss over all micro-batches of all pipelines
    /// (equals the plain mean when every micro-batch has the same shape).
    pub loss: f32,
    /// Elements moved between devices this step.
    pub wire_elems: u64,
    /// Communication ops issued this step.
    pub comm_ops: u64,
    /// Parallel step seconds. **What this measures depends on
    /// [`ExecMode`]** (DESIGN.md §8): under the default
    /// [`ExecMode::EventDriven`] (and [`ExecMode::Compiled`]) it is a
    /// *replayed estimate* — per-task wall times measured while
    /// interpreting the schedule, replayed through the pipeline dependency
    /// structure (TP members concurrent, pipelines concurrent), the
    /// engine-side quantity cross-validated against [`crate::sim`]'s step
    /// ranking. Under [`ExecMode::Threaded`] (and
    /// [`ExecMode::CompiledThreaded`]) it is **measured wall clock**: the
    /// elapsed time of the per-rank OS threads from step start to join.
    /// Never mix the two in one comparison; benches label them `modeled`
    /// vs `wall`. When tracing is on, [`StepStats::breakdown`] attributes
    /// this same quantity from recorded spans.
    pub makespan_s: f64,
    /// Real (unmasked) tokens processed across all micro-batches.
    pub tokens: u64,
    /// Padded (masked) positions executed — 0 when every window ran at
    /// its true ragged length.
    pub padded: u64,
    /// Switch seconds this step could *not* hide — the §6.2 **measured**
    /// interleave: a preceding switch's per-sender delivery batches ride
    /// each sender's wire lane from step start, concurrent with the
    /// step's compute timelines, and only the overhang beyond the compute
    /// critical path is exposed. Back-to-back switches serialize per
    /// sender (not per switch), so this is ≤ the old accounted
    /// `max(0, Σ delivery − makespan)` bound. 0 when no switch preceded
    /// the step.
    pub exposed_switch_s: f64,
    /// Longest per-sender wire lane among the deliveries this step
    /// interleaved (0 when none were pending).
    pub switch_delivery_s: f64,
    /// Measured span-derived attribution of `makespan_s`
    /// (compute/comm/optimizer/bubble/switch seconds; DESIGN.md §10).
    /// `Some` only when [`Engine::set_tracing`] is on — the reference
    /// interpreter and untraced steps leave it `None`.
    pub breakdown: Option<crate::obs::breakdown::StepBreakdown>,
    /// Native kernel launches this step (each `*_into` kernel counts one;
    /// a fused-lowered step issues fewer than the unfused tape — DESIGN.md
    /// §12's launch accounting). 0 under a non-native runtime.
    pub kernel_launches: u64,
    /// Bytes heap-allocated *inside* native kernels this step (allocating
    /// wrapper kernels only; the fused workspace path allocates none, so
    /// a warm fused compiled step reports 0 — the kernel-layer half of the
    /// zero-alloc contract in `tests/compiled_alloc.rs`).
    pub kernel_bytes_alloc: u64,
}

/// Which executor [`Engine::train_step`] drives the specialized plan
/// with (DESIGN.md §8). Both are numerically bit-identical; they differ
/// in *how* the per-rank timelines run and what `makespan_s` means.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum ExecMode {
    /// The single-thread event-driven executor ([`exec`]): tasks fire as
    /// dependencies resolve, per-task wall times are replayed through the
    /// dependency structure, so the makespan is *modeled*.
    #[default]
    EventDriven,
    /// The concurrent executor ([`thread`]): one OS thread per rank,
    /// comm tasks as typed channel messages, so the makespan is measured
    /// *wall-clock*. Requires the native backend (the PJRT client is not
    /// `Send`).
    Threaded,
    /// Replay the cached [`CompiledProgram`] tape over the event-driven
    /// path (DESIGN.md §9): one ready check per fused segment, every
    /// key/endpoint/group frozen at compile time — the dispatch-only hot
    /// loop, bit-identical to [`ExecMode::EventDriven`].
    Compiled,
    /// The threaded executor replaying each rank's compiled tape on its
    /// thread (precomputed keys and channel endpoints; same wall-clock
    /// makespan semantics as [`ExecMode::Threaded`]).
    CompiledThreaded,
}

/// The engine: runtime + mesh + strategy + cached layout + optimizer.
pub struct Engine {
    /// Artifact runtime.
    pub runtime: Runtime,
    /// Device stores.
    pub mesh: Mesh,
    /// Current strategy.
    pub strategy: EngineStrategy,
    /// Ownership/sync/update plans for the current strategy (rebuilt only
    /// on [`Engine::switch_to`]; shared with the temporal pool's cached
    /// copy, so hot switches hand it over allocation-free).
    pub layout: Arc<ShardLayout>,
    /// The ragged per-pipeline micro-batch shape contract set by
    /// [`Engine::set_microbatches`] (`None` → the compiled uniform shape).
    /// [`Engine::train_step`] rejects provided micro-batches that do not
    /// match; cleared on every strategy switch.
    pub mb_windows: Option<Vec<Vec<WindowShape>>>,
    /// TP degrees the runtime has block artifacts for.
    pub tp_degrees: Vec<usize>,
    /// Optimizer.
    pub opt: AdamW,
    /// Physical topology behind the mesh device ids, when known. Threaded
    /// into the §6.2 fused-BSR planner so sender selection uses the
    /// bandwidth heuristic (2) at engine scale; `None` falls back to
    /// [`crate::comm::UniformBandwidth`].
    pub topology: Option<Cluster>,
    /// ZeRO-1: shard optimizer moments over the DP axis (each replica set
    /// with identical parameter regions keeps only a contiguous dim-0
    /// partition of `m.*`/`v.*`, exchanging updated parameter slices after
    /// the optimizer step). See [`layout::ZeroGroup`].
    pub zero1: bool,
    /// Executor the specialized plan runs under (event-driven replay or
    /// per-rank OS threads); see [`ExecMode`].
    pub exec_mode: ExecMode,
    /// Kernel-level fusion for compiled segments (DESIGN.md §12): when on
    /// (the default) and the backend is native, compilation lowers each
    /// `Seg` compute run into a frozen [`FusedCall`] replayed through
    /// preplanned workspaces and prepacked weight panels. Numerics are
    /// bit-identical either way; toggle with
    /// [`Engine::set_kernel_fusion`] to measure the unfused tape.
    pub kernel_fusion: bool,
    /// Determinism-stress scheduling jitter for the threaded executor:
    /// `Some(seed)` sleeps a hashed 0–200 µs before every task, shaking
    /// thread interleavings without touching any reduction order (the
    /// concurrent-determinism tests sweep this).
    pub exec_jitter: Option<u64>,
    /// The cached per-rank specialization of the current strategy
    /// (DESIGN.md §7): built on first use, rebuilt whenever the strategy,
    /// micro-batch counts, or ZeRO-1 mode change. `None` ⇒ the next
    /// [`Engine::train_step`] re-specializes.
    pub(crate) spec: Option<Arc<SpecializedPlan>>,
    /// The cached compiled MPMD artifact of the current strategy
    /// (DESIGN.md §9): the specialized plan frozen into a dispatch tape.
    /// Invalidated on exactly the events that invalidate `spec`
    /// (switches, ZeRO-1 toggles); shape changes revalidate per step.
    pub(crate) compiled: Option<Arc<CompiledProgram>>,
    /// Reusable tape-walk scratch of the compiled executor (warm steps
    /// allocate nothing in the dispatch layer).
    pub(crate) replay: compile::ReplayScratch,
    /// Preallocated per-step arena of the compiled executor (head-result
    /// slots + per-member timing scratch).
    pub(crate) arena: compile::CompiledArena,
    /// Per-sender delivery batches of switches executed since the last
    /// step, injected into the next step's timelines as wire-lane tasks
    /// (§6.2 measured interleave); drained by [`Engine::train_step`].
    pub(crate) pending_deliveries: Vec<(usize, f64)>,
    /// Span tracing armed ([`Engine::set_tracing`]): every executor
    /// records per-rank spans into `recorder` each step. Off by default —
    /// the recorder is then a branch-only no-op on the hot paths.
    pub(crate) trace_on: bool,
    /// The per-step span ring (DESIGN.md §10). Preallocated on the first
    /// traced step per plan shape; warm traced steps allocate nothing.
    pub(crate) recorder: crate::obs::trace::SpanRecorder,
    pub(crate) step: u64,
}

impl Engine {
    /// Build an engine: open artifacts (native-backend fallback when
    /// `artifacts_dir` has no manifest), validate the strategy, and
    /// initialize parameters deterministically across DP replicas.
    pub fn new(artifacts_dir: &str, strategy: EngineStrategy, seed: u64, lr: f32) -> Result<Engine> {
        let runtime = Runtime::open_or_native(artifacts_dir)?;
        Engine::with_runtime(runtime, strategy, seed, lr)
    }

    /// Build an engine over an explicit [`Runtime`] (tests and benches use
    /// this with [`Runtime::native`]).
    pub fn with_runtime(
        runtime: Runtime,
        strategy: EngineStrategy,
        seed: u64,
        lr: f32,
    ) -> Result<Engine> {
        let cfg = runtime.config;
        let tp_degrees: Vec<usize> = [1usize, 2, 4]
            .into_iter()
            .filter(|d| runtime.metas_has(&format!("block_fwd_tp{d}")))
            .collect();
        strategy.validate(&cfg, &tp_degrees)?;
        let layout = Arc::new(ShardLayout::build(&cfg, &strategy)?);
        let mut mesh = Mesh::new(strategy.num_devices().max(strategy.max_device_bound()));
        exec::init_params(&runtime, &layout, &mut mesh, seed)?;
        Ok(Engine {
            runtime,
            mesh,
            strategy,
            layout,
            mb_windows: None,
            tp_degrees,
            opt: AdamW::new(lr),
            topology: None,
            zero1: false,
            exec_mode: ExecMode::default(),
            kernel_fusion: true,
            exec_jitter: None,
            spec: None,
            compiled: None,
            replay: compile::ReplayScratch::default(),
            arena: compile::CompiledArena::default(),
            pending_deliveries: vec![],
            trace_on: false,
            recorder: crate::obs::trace::SpanRecorder::default(),
            step: 0,
        })
    }

    /// Enable/disable ZeRO-1 optimizer-state sharding. Must be called
    /// before the first training step: existing moments are shaped by the
    /// previous setting and would corrupt the partition bookkeeping.
    pub fn set_zero1(&mut self, on: bool) -> Result<()> {
        if self.step > 0 {
            return Err(Error::Engine(
                "set_zero1: optimizer moments already exist; toggle before step 1".into(),
            ));
        }
        self.zero1 = on;
        self.spec = None; // the ZeroExchange task appears/disappears
        self.compiled = None; // ... and so does its tape op
        Ok(())
    }

    /// Select the executor for subsequent steps (both modes are
    /// bit-identical; [`ExecMode::Threaded`] measures wall-clock
    /// makespans but requires the native backend). Takes effect on the
    /// next [`Engine::train_step`]; the specialized plan is shared, so no
    /// re-specialization happens.
    pub fn set_exec_mode(&mut self, mode: ExecMode) {
        self.exec_mode = mode;
    }

    /// Enable/disable kernel-level fusion for compiled segments (on by
    /// default). Invalidates the compiled tape so the next compiled step
    /// relowers; the specialized plan and all numerics are unaffected
    /// (fused and unfused paths are bit-identical — the toggle exists for
    /// the fused-vs-unfused bench rows and differential tests).
    pub fn set_kernel_fusion(&mut self, on: bool) {
        if self.kernel_fusion != on {
            self.kernel_fusion = on;
            self.compiled = None;
        }
    }

    /// True when compiled steps lower to fused workspace kernels: fusion
    /// is requested *and* the backend is native (the PJRT path keeps its
    /// artifact calls).
    pub(crate) fn fusion_active(&self) -> bool {
        self.kernel_fusion && self.runtime.is_native()
    }

    /// Set (or clear) the threaded executor's scheduling-jitter seed —
    /// the determinism stress knob; no effect under
    /// [`ExecMode::EventDriven`].
    pub fn set_exec_jitter(&mut self, seed: Option<u64>) {
        self.exec_jitter = seed;
    }

    /// Arm (or disarm) per-rank span tracing (DESIGN.md §10). When on,
    /// every executor records a [`crate::obs::trace::Span`] per
    /// `(task, rank)` into a preallocated ring each step,
    /// [`StepStats::breakdown`] is populated, and
    /// [`Engine::export_chrome_trace`] renders the last step. Off (the
    /// default), recording is a branch-only no-op. Numerics are identical
    /// either way — tracing touches only timestamps.
    pub fn set_tracing(&mut self, on: bool) {
        self.trace_on = on;
    }

    /// True when span tracing is armed.
    pub fn tracing(&self) -> bool {
        self.trace_on
    }

    /// The last traced step's spans in record order (empty when tracing
    /// was off for that step).
    pub fn last_step_spans(&mut self) -> &[crate::obs::trace::Span] {
        self.recorder.contiguous()
    }

    /// Export the last traced step as Chrome trace-event JSON
    /// (`chrome://tracing` / Perfetto): one track per rank, flow arrows
    /// on the p2p hand-off edges. Errors when no step has been traced.
    pub fn export_chrome_trace(&mut self) -> Result<String> {
        if !self.recorder.is_active() || self.recorder.is_empty() {
            return Err(Error::Engine(
                "export_chrome_trace: no traced step (call set_tracing(true), then train_step)"
                    .into(),
            ));
        }
        let plan = self.specialized_plan()?;
        let step = self.step.saturating_sub(1);
        crate::obs::chrome::chrome_trace(self.recorder.contiguous(), &plan, step)
    }

    /// True once optimizer moments exist (after the first step). Switch
    /// planning uses this to decide whether `m.*`/`v.*` ride along. Scans
    /// the update list rather than sampling one op: under ZeRO-1 a
    /// spectator device (empty partition) legitimately stores no moments.
    pub fn has_moments(&self) -> bool {
        self.layout
            .update_ops
            .iter()
            .any(|(dev, pk, _)| {
                self.mesh.devices[*dev].has(&format!("m.{}", self.layout.key(*pk)))
            })
    }

    /// Set the per-pipeline *ragged micro-batch windows* for subsequent
    /// steps: one [`WindowShape`] per micro-batch, per pipeline — the
    /// temporal dispatcher hands the engine the real packed-window shapes
    /// of each step's batch (no quota stand-in). The shard layout does not
    /// depend on micro-batch shapes, so no replan is needed; the
    /// token-weighted gradient sync keeps uneven shapes and counts exact
    /// data parallelism. [`Engine::train_step`] validates every provided
    /// micro-batch against this contract. Cleared on strategy switches.
    pub fn set_microbatches(&mut self, windows: &[Vec<WindowShape>]) -> Result<()> {
        if windows.len() != self.strategy.pipelines.len() {
            return Err(Error::Engine(format!(
                "set_microbatches: {} window lists for {} pipelines",
                windows.len(),
                self.strategy.pipelines.len()
            )));
        }
        for ws in windows {
            if ws.is_empty() {
                return Err(Error::Engine("set_microbatches: zero micro-batches".into()));
            }
            for w in ws {
                w.validate()?;
            }
        }
        for (p, ws) in self.strategy.pipelines.iter_mut().zip(windows.iter()) {
            p.num_microbatches = ws.len();
        }
        self.mb_windows = Some(windows.to_vec());
        // no spec invalidation: the rank timelines depend only on the
        // per-pipeline counts, which `specialized_plan` revalidates —
        // repeated equal-count steps keep the cached specialization
        Ok(())
    }

    /// Set uniform per-pipeline micro-batch *counts* at the compiled
    /// `[batch, seq]` shape (the pre-ragged contract, kept for fixed-shape
    /// callers and tests). Clears any ragged window contract.
    pub fn set_microbatch_counts(&mut self, counts: &[usize]) -> Result<()> {
        if counts.len() != self.strategy.pipelines.len() {
            return Err(Error::Engine(format!(
                "set_microbatch_counts: {} counts for {} pipelines",
                counts.len(),
                self.strategy.pipelines.len()
            )));
        }
        if counts.iter().any(|&c| c == 0) {
            return Err(Error::Engine("set_microbatch_counts: zero micro-batches".into()));
        }
        for (p, &c) in self.strategy.pipelines.iter_mut().zip(counts.iter()) {
            p.num_microbatches = c;
        }
        self.mb_windows = None;
        // `specialized_plan` revalidates the counts (see set_microbatches)
        Ok(())
    }

    /// Attach the physical topology behind the mesh device ids (bandwidth-
    /// aware sender selection during switches). Must cover at least every
    /// mesh device id; `switch_to_avoiding` rejects undersized topologies
    /// with a typed error.
    pub fn set_topology(&mut self, topology: Cluster) {
        self.topology = Some(topology);
    }

    /// Typed error unless the attached topology (when present) covers
    /// `need` devices — the shared guard of every switch-planning path
    /// (`switch_to_avoiding`, `StrategyPool::switch_engine`), so the
    /// bandwidth callbacks can never index past the cluster.
    pub fn require_topology_coverage(&self, need: usize) -> Result<()> {
        if let Some(c) = &self.topology {
            if c.len() < need {
                return Err(Error::Engine(format!(
                    "topology covers {} devices but the switch needs {need}",
                    c.len()
                )));
            }
        }
        Ok(())
    }

    /// Validate and prefetch one step's micro-batches in pipeline-major
    /// slot order (the data-stream contract), checking each ragged shape
    /// — internally and, when a window contract is set, against the
    /// prescribed per-slot shapes. Returns the batches plus the total
    /// executed positions (padding included).
    fn prefetch_batches(
        &self,
        data: &mut dyn FnMut(usize, usize) -> MicroBatch,
    ) -> Result<(Vec<Vec<MicroBatch>>, u64)> {
        let mut batches: Vec<Vec<MicroBatch>> =
            Vec::with_capacity(self.strategy.pipelines.len());
        let mut positions = 0u64;
        for (pi, p) in self.strategy.pipelines.iter().enumerate() {
            let mut v = Vec::with_capacity(p.num_microbatches);
            for mb in 0..p.num_microbatches {
                let batch = data(pi, mb);
                if batch.tokens.len() != batch.n_seqs * batch.seq_len
                    || batch.targets.len() != batch.tokens.len()
                {
                    return Err(Error::Engine(format!(
                        "train_step: micro-batch ({pi},{mb}) claims shape {}x{} but holds \
                         {} tokens / {} targets",
                        batch.n_seqs,
                        batch.seq_len,
                        batch.tokens.len(),
                        batch.targets.len()
                    )));
                }
                if let Some(shape) =
                    self.mb_windows.as_ref().and_then(|ws| ws.get(pi)).and_then(|w| w.get(mb))
                {
                    if batch.n_seqs != shape.n_seqs() || batch.seq_len != shape.seq_len {
                        return Err(Error::Engine(format!(
                            "train_step: micro-batch ({pi},{mb}) is {}x{} but the window \
                             contract prescribes {}x{}",
                            batch.n_seqs,
                            batch.seq_len,
                            shape.n_seqs(),
                            shape.seq_len
                        )));
                    }
                    // the per-row real lengths are part of the contract
                    // too: a row with the wrong unmasked count would
                    // silently skew the token-weighted sync and the
                    // padded-position accounting
                    for (row, &want) in shape.rows.iter().enumerate() {
                        let real = batch.real_tokens_in_row(row);
                        if real != want {
                            return Err(Error::Engine(format!(
                                "train_step: micro-batch ({pi},{mb}) row {row} holds \
                                 {real} real tokens but the window contract prescribes \
                                 {want}"
                            )));
                        }
                    }
                }
                positions += batch.positions();
                v.push(batch);
            }
            batches.push(v);
        }
        Ok((batches, positions))
    }

    /// The per-rank specialization of the current strategy (DESIGN.md
    /// §7), from the engine's cache when the strategy, schedule, and
    /// micro-batch counts are unchanged — otherwise rebuilt (the
    /// per-switch re-specialization cost the `hotpath_micro` "specialize"
    /// row tracks).
    pub fn specialized_plan(&mut self) -> Result<Arc<SpecializedPlan>> {
        let counts: Vec<usize> =
            self.strategy.pipelines.iter().map(|p| p.num_microbatches).collect();
        if let Some(p) = &self.spec {
            if p.num_microbatches == counts && p.schedule == self.strategy.schedule {
                return Ok(Arc::clone(p));
            }
        }
        let p = Arc::new(specialize(&self.strategy, &self.layout, self.zero1)?);
        self.spec = Some(Arc::clone(&p));
        Ok(p)
    }

    /// Run one training step over per-pipeline micro-batch providers.
    ///
    /// `data(pipeline, mb)` returns the micro-batch for that slot; it is
    /// called in pipeline-major order (pipeline 0 slots first), so a
    /// stateful corpus feeds every strategy the same stream.
    ///
    /// The step executes the **specialize-then-execute pipeline**
    /// (DESIGN.md §7): the strategy's cached per-rank [`RankPlan`]
    /// timelines — compute tasks from the strategy's [`ScheduleKind`]
    /// (GPipe or 1F1B), communication as explicit tasks — run under the
    /// event-driven executor, numerically bit-identical to the legacy
    /// global interpreter ([`Engine::train_step_reference`]). Gradients
    /// are synchronized with token weighting, so pipelines may run
    /// *different* micro-batch counts (the paper's uneven apportioning)
    /// and still reduce to the exact global-mean gradient. A preceding
    /// switch's per-sender delivery batches are injected into this step's
    /// timelines and only their non-overlapped remainder is exposed
    /// ([`StepStats::exposed_switch_s`]).
    pub fn train_step(
        &mut self,
        data: &mut dyn FnMut(usize, usize) -> MicroBatch,
    ) -> Result<StepStats> {
        let wire0 = self.mesh.wire_elems;
        let ops0 = self.mesh.ops;
        let (batches, positions) = self.prefetch_batches(data)?;
        let pipelines = self.strategy.pipelines.clone();
        let plan = self.specialized_plan()?;
        let deliveries = std::mem::take(&mut self.pending_deliveries);
        let (launches0, kbytes0) = crate::runtime::native::counters::snapshot();
        let out = self.run_specialized(&plan, &pipelines, &batches, &deliveries)?;
        let (launches1, kbytes1) = crate::runtime::native::counters::snapshot();
        self.step += 1;
        let breakdown = self.recorder.is_active().then(|| {
            crate::obs::breakdown::fold_spans(
                self.recorder.contiguous(),
                out.makespan_s,
                out.exposed_switch_s,
            )
        });
        Ok(StepStats {
            loss: (out.weighted_loss / out.tokens as f64) as f32,
            wire_elems: self.mesh.wire_elems - wire0,
            comm_ops: self.mesh.ops - ops0,
            makespan_s: out.makespan_s,
            tokens: out.tokens,
            padded: positions.saturating_sub(out.tokens),
            exposed_switch_s: out.exposed_switch_s,
            switch_delivery_s: out.delivery_lane_s,
            breakdown,
            kernel_launches: launches1.wrapping_sub(launches0),
            kernel_bytes_alloc: kbytes1.wrapping_sub(kbytes0),
        })
    }

    /// One training step through the **pre-specialization global
    /// interpreter** — the sequential-pipelines schedule replay the
    /// engine ran before DESIGN.md §7. Kept as the differential numerics
    /// oracle: `rust/tests/specialize_sweep.rs` asserts
    /// [`Engine::train_step`]'s losses are bit-identical to this path on
    /// the lowered C1/C2/C6 strategies under both schedules. Ignores
    /// pending switch deliveries (the §6.2 interleave is executor-only).
    pub fn train_step_reference(
        &mut self,
        data: &mut dyn FnMut(usize, usize) -> MicroBatch,
    ) -> Result<StepStats> {
        let wire0 = self.mesh.wire_elems;
        let ops0 = self.mesh.ops;
        let (batches, positions) = self.prefetch_batches(data)?;
        let pipelines = self.strategy.pipelines.clone();
        let kind = self.strategy.schedule;
        let (launches0, kbytes0) = crate::runtime::native::counters::snapshot();

        let mut weighted_loss = 0f64;
        let mut total_tokens = 0u64;
        let mut makespan = 0f64;
        for (pi, pipe) in pipelines.iter().enumerate() {
            let run = self.run_pipeline(pipe, &batches[pi], kind)?;
            weighted_loss += run.weighted_loss;
            total_tokens += run.tokens;
            makespan = makespan.max(run.makespan_s);
        }
        if total_tokens == 0 {
            return Err(Error::Engine("train_step: no tokens processed".into()));
        }

        let t_sync = std::time::Instant::now();
        self.sync_gradients(total_tokens)?;
        self.apply_updates()?;
        let sync_s = t_sync.elapsed().as_secs_f64();
        // sync + update work is spread over the devices and runs
        // concurrently in a deployment; charge the per-device share.
        let ndev = self.strategy.num_devices().max(1);
        self.step += 1;
        let (launches1, kbytes1) = crate::runtime::native::counters::snapshot();
        Ok(StepStats {
            loss: (weighted_loss / total_tokens as f64) as f32,
            wire_elems: self.mesh.wire_elems - wire0,
            comm_ops: self.mesh.ops - ops0,
            makespan_s: makespan + sync_s / ndev as f64,
            tokens: total_tokens,
            padded: positions.saturating_sub(total_tokens),
            exposed_switch_s: 0.0,
            switch_delivery_s: 0.0,
            breakdown: None,
            kernel_launches: launches1.wrapping_sub(launches0),
            kernel_bytes_alloc: kbytes1.wrapping_sub(kbytes0),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn uniform_strategy_shapes() {
        let s = EngineStrategy::uniform("dp2tp2pp2", 2, 2, 2, 8, 4);
        assert_eq!(s.num_devices(), 8);
        assert_eq!(s.pipelines.len(), 2);
        assert_eq!(s.pipelines[0].stages[0].layers, (0, 4));
        assert_eq!(s.pipelines[1].stages[1].devices, vec![6, 7]);
    }

    #[test]
    fn validate_catches_bad_tp() {
        let cfg = ManifestConfig { layers: 8, ..Default::default() };
        let s = EngineStrategy::uniform("tp3", 1, 3, 1, 8, 1);
        assert!(s.validate(&cfg, &[1, 2, 4]).is_err());
        let ok = EngineStrategy::uniform("tp2", 1, 2, 1, 8, 1);
        ok.validate(&cfg, &[1, 2, 4]).unwrap();
    }

    #[test]
    fn validate_allows_hetero_tp_per_layer() {
        // the same layers held at TP2 and TP1 across DP replicas used to be
        // "plan-level only"; the shard-layout layer executes it now.
        let cfg = ManifestConfig { layers: 4, ..Default::default() };
        let s = EngineStrategy {
            name: "hetero".into(),
            pipelines: vec![
                EnginePipeline {
                    stages: vec![EngineStage { devices: vec![0, 1], layers: (0, 4) }],
                    num_microbatches: 1,
                },
                EnginePipeline {
                    stages: vec![EngineStage { devices: vec![2], layers: (0, 4) }],
                    num_microbatches: 1,
                },
            ],
            schedule: ScheduleKind::GPipe,
        };
        s.validate(&cfg, &[1, 2, 4]).unwrap();
    }

    #[test]
    fn set_microbatches_revalidates_counts() {
        use crate::runtime::Runtime;
        let s = EngineStrategy::uniform("dp2", 2, 1, 1, 8, 1);
        let mut eng =
            Engine::with_runtime(Runtime::native(crate::runtime::native::tiny_config()), s, 1, 1e-3)
                .unwrap();
        eng.set_microbatch_counts(&[3, 1]).unwrap();
        assert_eq!(eng.strategy.pipelines[0].num_microbatches, 3);
        assert_eq!(eng.strategy.pipelines[1].num_microbatches, 1);
        assert!(eng.mb_windows.is_none());
        assert!(eng.set_microbatch_counts(&[1]).is_err());
        assert!(eng.set_microbatch_counts(&[0, 1]).is_err());
        assert!(!eng.has_moments());
    }

    #[test]
    fn set_microbatches_installs_ragged_window_contract() {
        use crate::runtime::Runtime;
        let s = EngineStrategy::uniform("dp2", 2, 1, 1, 8, 1);
        let mut eng =
            Engine::with_runtime(Runtime::native(crate::runtime::native::tiny_config()), s, 1, 1e-3)
                .unwrap();
        let windows = vec![
            vec![
                WindowShape { rows: vec![2, 2], seq_len: 2 },
                WindowShape { rows: vec![4], seq_len: 4 },
            ],
            vec![WindowShape { rows: vec![3, 1], seq_len: 3 }],
        ];
        eng.set_microbatches(&windows).unwrap();
        assert_eq!(eng.strategy.pipelines[0].num_microbatches, 2);
        assert_eq!(eng.strategy.pipelines[1].num_microbatches, 1);
        assert_eq!(eng.mb_windows.as_deref(), Some(&windows[..]));
        // arity, empty pipelines, and malformed shapes are rejected
        assert!(eng.set_microbatches(&windows[..1]).is_err());
        assert!(eng
            .set_microbatches(&[vec![], vec![WindowShape { rows: vec![1], seq_len: 1 }]])
            .is_err());
        assert!(eng
            .set_microbatches(&[
                vec![WindowShape { rows: vec![], seq_len: 1 }],
                vec![WindowShape { rows: vec![1], seq_len: 1 }],
            ])
            .is_err());
        assert!(eng
            .set_microbatches(&[
                vec![WindowShape { rows: vec![5], seq_len: 4 }],
                vec![WindowShape { rows: vec![1], seq_len: 1 }],
            ])
            .is_err());
        // the counts path clears the ragged contract
        eng.set_microbatch_counts(&[1, 1]).unwrap();
        assert!(eng.mb_windows.is_none());
    }

    #[test]
    fn validate_catches_partial_layer_coverage() {
        let cfg = ManifestConfig { layers: 8, ..Default::default() };
        let stages = vec![EngineStage { devices: vec![0], layers: (0, 6) }];
        let pipelines = vec![EnginePipeline { stages, num_microbatches: 1 }];
        let s =
            EngineStrategy { name: "short".into(), pipelines, schedule: ScheduleKind::GPipe };
        assert!(s.validate(&cfg, &[1, 2, 4]).is_err());
    }
}
