//! The real-numerics distributed training engine.
//!
//! Executes a distributed training program — TP-sharded parallel blocks,
//! pipeline stages, data-parallel replicas — over a [`Mesh`] of simulated
//! devices, with all model compute performed by the AOT artifacts through
//! PJRT ([`Runtime`]) and every inter-device byte moved by the real
//! [`collectives`](crate::collectives). Distributed numerics are exact:
//! tests compare multi-device losses/gradients against the single-device
//! oracle configuration.
//!
//! Execution contract with the L2 artifacts (see `python/compile/model.py`):
//!
//! * block forward returns a *partial* output; the engine all-reduces over
//!   the TP group and adds the residual;
//! * block backward returns `(dx_partial, dparams_shard)`; the engine
//!   computes `dx = dy + AllReduce(dx_partial)`; replicated RMSNorm gains'
//!   gradients are all-reduced within the TP group;
//! * DP replicas all-reduce gradients layer-by-layer, then every device
//!   runs AdamW locally on its shards.

pub mod optim;

use std::collections::BTreeMap;

use crate::collectives::Mesh;
use crate::runtime::{HostTensor, ManifestConfig, Runtime};
use crate::testutil::Rng;
use crate::{Error, Result};

pub use optim::AdamW;

/// The 8 per-block parameter names, artifact input order.
pub const BLOCK_PARAMS: [&str; 8] = ["g1", "wq", "wk", "wv", "wo", "g2", "w1", "w2"];

/// One pipeline stage: an ordered TP group owning a contiguous layer range.
#[derive(Clone, Debug, PartialEq)]
pub struct EngineStage {
    /// Mesh device ids (TP group, position = shard index).
    pub devices: Vec<usize>,
    /// Layer range `[lo, hi)`.
    pub layers: (u32, u32),
}

impl EngineStage {
    /// TP degree.
    pub fn tp(&self) -> usize {
        self.devices.len()
    }
}

/// One pipeline.
#[derive(Clone, Debug, PartialEq)]
pub struct EnginePipeline {
    /// Stages in order.
    pub stages: Vec<EngineStage>,
    /// Micro-batches per step.
    pub num_microbatches: usize,
}

/// A full engine strategy (the runnable mirror of
/// [`crate::strategy::ParallelStrategy`] at tiny-model scale).
#[derive(Clone, Debug, PartialEq)]
pub struct EngineStrategy {
    /// Strategy label.
    pub name: String,
    /// Pipelines (DP across them).
    pub pipelines: Vec<EnginePipeline>,
}

impl EngineStrategy {
    /// Uniform DP×TP×PP over devices `0..dp*tp*pp`.
    pub fn uniform(name: &str, dp: usize, tp: usize, pp: usize, layers: u32, num_mb: usize) -> Self {
        let mut pipelines = vec![];
        let mut dev = 0usize;
        for _ in 0..dp {
            let mut stages = vec![];
            let mut l = 0u32;
            for s in 0..pp {
                let hi = layers * (s as u32 + 1) / pp as u32;
                stages.push(EngineStage { devices: (dev..dev + tp).collect(), layers: (l, hi) });
                dev += tp;
                l = hi;
            }
            pipelines.push(EnginePipeline { stages, num_microbatches: num_mb });
        }
        EngineStrategy { name: name.into(), pipelines }
    }

    /// Total devices used.
    pub fn num_devices(&self) -> usize {
        self.pipelines.iter().flat_map(|p| p.stages.iter()).map(|s| s.devices.len()).sum()
    }

    /// Validate against the model config + supported TP degrees.
    pub fn validate(&self, cfg: &ManifestConfig, tp_degrees: &[usize]) -> Result<()> {
        let mut tp_of_layer: BTreeMap<u32, usize> = BTreeMap::new();
        for p in &self.pipelines {
            let mut next = 0u32;
            for s in &p.stages {
                if s.layers.0 != next {
                    return Err(Error::Engine(format!(
                        "{}: stage layers not contiguous at {}",
                        self.name, s.layers.0
                    )));
                }
                next = s.layers.1;
                if !tp_degrees.contains(&s.tp()) {
                    return Err(Error::Engine(format!(
                        "{}: no block artifact for tp={} (have {tp_degrees:?})",
                        self.name,
                        s.tp()
                    )));
                }
                for l in s.layers.0..s.layers.1 {
                    if let Some(&prev) = tp_of_layer.get(&l) {
                        if prev != s.tp() {
                            return Err(Error::Engine(format!(
                                "{}: layer {l} held at tp {prev} and {} — hetero TP per layer \
                                 is plan-level only (DESIGN.md §2)",
                                self.name,
                                s.tp()
                            )));
                        }
                    } else {
                        tp_of_layer.insert(l, s.tp());
                    }
                }
            }
            if next != cfg.layers {
                return Err(Error::Engine(format!(
                    "{}: pipeline covers {next}/{} layers",
                    self.name, cfg.layers
                )));
            }
            if p.num_microbatches == 0 {
                return Err(Error::Engine("zero microbatches".into()));
            }
        }
        Ok(())
    }
}

/// A training batch for one micro-batch: `[B, S]` token/target ids.
#[derive(Clone, Debug)]
pub struct MicroBatch {
    /// Input token ids.
    pub tokens: Vec<i32>,
    /// Next-token targets.
    pub targets: Vec<i32>,
}

/// Step outcome.
#[derive(Clone, Debug)]
pub struct StepStats {
    /// Mean loss over all micro-batches of all pipelines.
    pub loss: f32,
    /// Elements moved between devices this step.
    pub wire_elems: u64,
    /// Communication ops issued this step.
    pub comm_ops: u64,
}

/// The engine: runtime + mesh + strategy + optimizer.
pub struct Engine {
    /// Artifact runtime.
    pub runtime: Runtime,
    /// Device stores.
    pub mesh: Mesh,
    /// Current strategy.
    pub strategy: EngineStrategy,
    /// Optimizer.
    pub opt: AdamW,
    step: u64,
}

fn pkey(l: u32, p: &str) -> String {
    format!("L{l}.{p}")
}
fn gkey(l: u32, p: &str) -> String {
    format!("grad.L{l}.{p}")
}

impl Engine {
    /// Build an engine: open artifacts, validate the strategy, initialize
    /// parameters deterministically (identical across DP replicas).
    pub fn new(artifacts_dir: &str, strategy: EngineStrategy, seed: u64, lr: f32) -> Result<Engine> {
        let runtime = Runtime::open(artifacts_dir)?;
        let cfg = runtime.config;
        let tp_degrees: Vec<usize> = [1usize, 2, 4]
            .into_iter()
            .filter(|d| runtime.metas_has(&format!("block_fwd_tp{d}")))
            .collect();
        strategy.validate(&cfg, &tp_degrees)?;
        let mut mesh = Mesh::new(strategy.num_devices().max(
            strategy
                .pipelines
                .iter()
                .flat_map(|p| p.stages.iter().flat_map(|s| s.devices.iter().copied()))
                .max()
                .map(|m| m + 1)
                .unwrap_or(0),
        ));
        let mut eng = Engine {
            runtime,
            mesh: Mesh::new(0),
            strategy: strategy.clone(),
            opt: AdamW::new(lr),
            step: 0,
        };
        eng.init_params(&mut mesh, seed)?;
        eng.mesh = mesh;
        Ok(eng)
    }

    /// Deterministic parameter init: full tensors are generated from a
    /// per-tensor seed and sharded identically for every DP replica.
    fn init_params(&self, mesh: &mut Mesh, seed: u64) -> Result<()> {
        let cfg = self.runtime.config;
        let (h, f, v) = (cfg.hidden, cfg.ffn, cfg.vocab);
        let full_shapes: [(&str, Vec<usize>); 8] = [
            ("g1", vec![h]),
            ("wq", vec![h, h]),
            ("wk", vec![h, h]),
            ("wv", vec![h, h]),
            ("wo", vec![h, h]),
            ("g2", vec![h]),
            ("w1", vec![h, f]),
            ("w2", vec![f, h]),
        ];
        for p in &self.strategy.pipelines {
            for s in &p.stages {
                let tp = s.tp();
                for l in s.layers.0..s.layers.1 {
                    for (name, shape) in &full_shapes {
                        let full = init_tensor(seed, l, name, shape, h);
                        for (j, &d) in s.devices.iter().enumerate() {
                            let shard = shard_param(&full, name, tp, j)?;
                            mesh.devices[d].put(&pkey(l, name), shard);
                        }
                    }
                }
            }
            // embedding on stage-0 rank 0; head on last-stage rank 0
            let emb = init_tensor(seed, 10_000, "emb", &vec![v, h], h);
            mesh.devices[p.stages[0].devices[0]].put("emb", emb);
            let gf = HostTensor::f32(vec![h], vec![1.0; h])?;
            let wout = init_tensor(seed, 10_001, "wout", &vec![h, v], h);
            let last = *p.stages.last().unwrap().devices.first().unwrap();
            mesh.devices[last].put("gf", gf);
            mesh.devices[last].put("wout", wout);
        }
        Ok(())
    }

    /// Run one training step over per-pipeline micro-batch providers.
    ///
    /// `data(pipeline, mb)` returns the micro-batch for that slot.
    pub fn train_step(
        &mut self,
        data: &mut dyn FnMut(usize, usize) -> MicroBatch,
    ) -> Result<StepStats> {
        let cfg = self.runtime.config;
        let wire0 = self.mesh.wire_elems;
        let ops0 = self.mesh.ops;
        let mut total_loss = 0f32;
        let mut total_mb = 0usize;

        let pipelines = self.strategy.pipelines.clone();
        for (pi, pipe) in pipelines.iter().enumerate() {
            for mb in 0..pipe.num_microbatches {
                let batch = data(pi, mb);
                let loss = self.forward_backward(pipe, mb, &batch)?;
                total_loss += loss;
                total_mb += 1;
            }
        }

        self.sync_gradients(&pipelines, total_mb)?;
        self.apply_updates(&pipelines)?;
        self.step += 1;
        let _ = cfg;
        Ok(StepStats {
            loss: total_loss / total_mb as f32,
            wire_elems: self.mesh.wire_elems - wire0,
            comm_ops: self.mesh.ops - ops0,
        })
    }

    /// One micro-batch through one pipeline (GPipe order inside the
    /// deterministic interpreter: fwd all stages, then bwd reversed).
    fn forward_backward(
        &mut self,
        pipe: &EnginePipeline,
        mb: usize,
        batch: &MicroBatch,
    ) -> Result<f32> {
        let cfg = self.runtime.config;
        let (b, s) = (cfg.batch, cfg.seq);
        let tok = HostTensor::i32(vec![b, s], batch.tokens.clone())?;
        let tgt = HostTensor::i32(vec![b, s], batch.targets.clone())?;

        // ---- forward
        let first = &pipe.stages[0];
        let root0 = first.devices[0];
        let x0 = {
            let emb = self.mesh.devices[root0].get("emb")?;
            let out = self.runtime.call_refs("embed_fwd", &[emb, &tok])?;
            out.into_iter().next().unwrap()
        };
        self.mesh.devices[root0].put("act", x0);
        self.mesh.broadcast(root0, &first.devices, "act")?;

        for (si, stage) in pipe.stages.iter().enumerate() {
            if si > 0 {
                let prev_root = pipe.stages[si - 1].devices[0];
                self.mesh.send(prev_root, stage.devices[0], "act")?;
                self.mesh.broadcast(stage.devices[0], &stage.devices, "act")?;
            }
            let tp = stage.tp();
            let art = format!("block_fwd_tp{tp}");
            for l in stage.layers.0..stage.layers.1 {
                // save block input for recompute-in-backward
                for &d in &stage.devices {
                    let x = self.mesh.devices[d].get("act")?.clone();
                    self.mesh.devices[d].put(&format!("save.mb{mb}.L{l}"), x);
                }
                for &d in &stage.devices {
                    let dev = &self.mesh.devices[d];
                    let mut inputs: Vec<&HostTensor> = Vec::with_capacity(9);
                    for p in BLOCK_PARAMS {
                        inputs.push(dev.get(&pkey(l, p))?);
                    }
                    inputs.push(dev.get("act")?);
                    let y_part =
                        self.runtime.call_refs(&art, &inputs)?.into_iter().next().unwrap();
                    self.mesh.devices[d].put("part", y_part);
                }
                self.mesh.all_reduce(&stage.devices, "part")?;
                for &d in &stage.devices {
                    let part = self.mesh.devices[d].get("part")?.clone();
                    let x = self.mesh.devices[d].get_mut("act")?;
                    x.add_assign(&part)?;
                }
            }
        }

        // ---- head: loss + all gradients in one fused artifact call
        let last_stage = pipe.stages.last().unwrap();
        let last_root = last_stage.devices[0];
        let (loss, dx) = {
            let dev = &self.mesh.devices[last_root];
            let out = self.runtime.call_refs(
                "head_step",
                &[dev.get("gf")?, dev.get("wout")?, dev.get("act")?, &tgt],
            )?;
            let mut it = out.into_iter();
            let loss = it.next().unwrap();
            let dx = it.next().unwrap();
            accumulate(&mut self.mesh.devices[last_root], "grad.gf", it.next().unwrap())?;
            accumulate(&mut self.mesh.devices[last_root], "grad.wout", it.next().unwrap())?;
            (loss.as_f32()?[0], dx)
        };
        self.mesh.devices[last_root].put("dact", dx);
        self.mesh.broadcast(last_root, &last_stage.devices, "dact")?;

        // ---- backward
        for (si, stage) in pipe.stages.iter().enumerate().rev() {
            let tp = stage.tp();
            let art = format!("block_bwd_tp{tp}");
            for l in (stage.layers.0..stage.layers.1).rev() {
                for &d in &stage.devices {
                    let dev = &self.mesh.devices[d];
                    let mut inputs: Vec<&HostTensor> = Vec::with_capacity(10);
                    for p in BLOCK_PARAMS {
                        inputs.push(dev.get(&pkey(l, p))?);
                    }
                    inputs.push(dev.get(&format!("save.mb{mb}.L{l}"))?);
                    inputs.push(dev.get("dact")?);
                    let outs = self.runtime.call_refs(&art, &inputs)?;
                    let mut it = outs.into_iter();
                    let dx_part = it.next().unwrap();
                    self.mesh.devices[d].put("dpart", dx_part);
                    for p in BLOCK_PARAMS {
                        accumulate(&mut self.mesh.devices[d], &gkey(l, p), it.next().unwrap())?;
                    }
                    // free the saved activation
                    let _ = self.mesh.devices[d].take(&format!("save.mb{mb}.L{l}"));
                }
                self.mesh.all_reduce(&stage.devices, "dpart")?;
                for &d in &stage.devices {
                    let dpart = self.mesh.devices[d].get("dpart")?.clone();
                    let dx = self.mesh.devices[d].get_mut("dact")?;
                    dx.add_assign(&dpart)?;
                }
            }
            if si > 0 {
                let prev = &pipe.stages[si - 1];
                self.mesh.send(stage.devices[0], prev.devices[0], "dact")?;
                self.mesh.broadcast(prev.devices[0], &prev.devices, "dact")?;
            }
        }

        // ---- embedding gradient
        let root0 = pipe.stages[0].devices[0];
        let dx0 = self.mesh.devices[root0].get("dact")?;
        let demb = self.runtime.call_refs("embed_bwd", &[&tok, dx0])?.into_iter().next().unwrap();
        accumulate(&mut self.mesh.devices[root0], "grad.emb", demb)?;

        Ok(loss)
    }

    /// Gradient synchronization: replicated RMSNorm gains all-reduce within
    /// each TP group; every (layer, shard) all-reduces across the pipelines
    /// holding it; embedding/head across pipeline roots. All grads scale by
    /// `1/total_microbatches`.
    fn sync_gradients(&mut self, pipelines: &[EnginePipeline], total_mb: usize) -> Result<()> {
        // TP-internal gain sync (per stage)
        for p in pipelines {
            for s in &p.stages {
                if s.tp() > 1 {
                    for l in s.layers.0..s.layers.1 {
                        for p_name in ["g1", "g2"] {
                            self.mesh.all_reduce(&s.devices, &gkey(l, p_name))?;
                        }
                    }
                }
            }
        }
        // DP sync: group devices by (layer, param, shard index)
        let mut groups: BTreeMap<(u32, &str, usize), Vec<usize>> = BTreeMap::new();
        for p in pipelines {
            for s in &p.stages {
                for l in s.layers.0..s.layers.1 {
                    for (j, &d) in s.devices.iter().enumerate() {
                        for p_name in BLOCK_PARAMS {
                            groups.entry((l, p_name, j)).or_default().push(d);
                        }
                    }
                }
            }
        }
        for ((l, p_name, _), devs) in groups {
            if devs.len() > 1 {
                self.mesh.all_reduce(&devs, &gkey(l, p_name))?;
            }
        }
        // embedding / head across pipeline roots
        let first_roots: Vec<usize> =
            pipelines.iter().map(|p| p.stages[0].devices[0]).collect();
        let last_roots: Vec<usize> =
            pipelines.iter().map(|p| p.stages.last().unwrap().devices[0]).collect();
        self.mesh.all_reduce(&first_roots, "grad.emb")?;
        self.mesh.all_reduce(&last_roots, "grad.gf")?;
        self.mesh.all_reduce(&last_roots, "grad.wout")?;

        // scale by 1/total_mb
        let scale = 1.0 / total_mb as f32;
        for d in 0..self.mesh.len() {
            for key in self.mesh.devices[d].keys() {
                if key.starts_with("grad.") {
                    self.mesh.devices[d].get_mut(&key)?.scale(scale)?;
                }
            }
        }
        Ok(())
    }

    /// AdamW on every device's owned parameters; gradients are consumed.
    fn apply_updates(&mut self, pipelines: &[EnginePipeline]) -> Result<()> {
        let step = self.step + 1;
        for p in pipelines {
            for s in &p.stages {
                for l in s.layers.0..s.layers.1 {
                    for &d in &s.devices {
                        for p_name in BLOCK_PARAMS {
                            self.opt.update(&mut self.mesh.devices[d], &pkey(l, p_name), &gkey(l, p_name), step)?;
                        }
                    }
                }
            }
            let root0 = p.stages[0].devices[0];
            self.opt.update(&mut self.mesh.devices[root0], "emb", "grad.emb", step)?;
            let last = p.stages.last().unwrap().devices[0];
            self.opt.update(&mut self.mesh.devices[last], "gf", "grad.gf", step)?;
            self.opt.update(&mut self.mesh.devices[last], "wout", "grad.wout", step)?;
        }
        Ok(())
    }

    /// §6 graph switching at engine level: repartition every parameter
    /// (and optimizer state) from the current strategy's layout to `new`.
    /// Senders are chosen by lowest cumulative load among replicas (the
    /// fused-BSR heuristics over the mesh). Returns `(messages, elems)`.
    pub fn switch_to(&mut self, new: EngineStrategy) -> Result<(u64, u64)> {
        let cfg = self.runtime.config;
        let tp_degrees = [1usize, 2, 4];
        new.validate(&cfg, &tp_degrees)?;
        // grow the mesh if the new strategy brings devices online
        let need = new
            .pipelines
            .iter()
            .flat_map(|p| p.stages.iter().flat_map(|s| s.devices.iter().copied()))
            .max()
            .map(|m| m + 1)
            .unwrap_or(0);
        while self.mesh.devices.len() < need {
            self.mesh.devices.push(Default::default());
        }
        // owners under the old strategy: (layer, param, shard) -> devices
        let mut owners: BTreeMap<(u32, String, usize), Vec<usize>> = BTreeMap::new();
        let old = self.strategy.clone();
        for p in &old.pipelines {
            for s in &p.stages {
                for l in s.layers.0..s.layers.1 {
                    for (j, &d) in s.devices.iter().enumerate() {
                        for p_name in BLOCK_PARAMS {
                            owners.entry((l, p_name.to_string(), j)).or_default().push(d);
                        }
                    }
                }
            }
        }
        let wire0 = self.mesh.wire_elems;
        let ops0 = self.mesh.ops;
        let mut load: BTreeMap<usize, u64> = BTreeMap::new();
        let mut staged: Vec<(usize, String, HostTensor)> = vec![];
        for p in &new.pipelines {
            for s in &p.stages {
                for l in s.layers.0..s.layers.1 {
                    let old_tp = old_tp_of_layer(&old, l).ok_or_else(|| {
                        Error::Engine(format!("switch: no prior owner of layer {l}"))
                    })?;
                    let new_tp = s.tp();
                    for (j, &d) in s.devices.iter().enumerate() {
                        for p_name in BLOCK_PARAMS {
                            let key = pkey(l, p_name);
                            if old_tp == new_tp {
                                // same sharding: whole-shard move (heuristic
                                // 1 local copy; 3 lowest-load sender)
                                if self.mesh.devices[d].has(&key) {
                                    continue;
                                }
                                let own = owners
                                    .get(&(l, p_name.to_string(), j))
                                    .ok_or_else(|| {
                                        Error::Engine(format!(
                                            "no owner for layer {l} shard {j}"
                                        ))
                                    })?
                                    .clone();
                                let from = *own
                                    .iter()
                                    .min_by_key(|&&o| (load.get(&o).copied().unwrap_or(0), o))
                                    .unwrap();
                                self.mesh.send(from, d, &key)?;
                                *load.entry(from).or_insert(0) +=
                                    self.mesh.devices[d].get(&key)?.len() as u64;
                                for st in ["m", "v"] {
                                    let skey = format!("{st}.{key}");
                                    if self.mesh.devices[from].has(&skey) {
                                        self.mesh.send(from, d, &skey)?;
                                    }
                                }
                            } else {
                                // TP degree changed: reslice (the C2-style
                                // 4→2→1 tail reconfiguration), for the
                                // parameter and its optimizer moments alike.
                                // Writes are staged and committed after the
                                // whole plan so sources are never clobbered
                                // mid-switch.
                                for prefix in ["", "m.", "v."] {
                                    self.reshard_param(
                                        &owners, &mut load, l, p_name, prefix, old_tp, new_tp,
                                        j, d, &mut staged,
                                    )?;
                                }
                            }
                        }
                    }
                }
            }
            // embedding/head to new roots
            let old_r0 = old.pipelines[0].stages[0].devices[0];
            let new_r0 = p.stages[0].devices[0];
            for key in ["emb", "m.emb", "v.emb"] {
                if self.mesh.devices[old_r0].has(key) && !self.mesh.devices[new_r0].has(key) {
                    self.mesh.send(old_r0, new_r0, key)?;
                }
            }
            let old_last = old.pipelines[0].stages.last().unwrap().devices[0];
            let new_last = p.stages.last().unwrap().devices[0];
            for key in ["gf", "wout", "m.gf", "v.gf", "m.wout", "v.wout"] {
                if self.mesh.devices[old_last].has(key) && !self.mesh.devices[new_last].has(key) {
                    self.mesh.send(old_last, new_last, key)?;
                }
            }
        }
        // commit resharded tensors (deferred so every source read during
        // planning saw the pre-switch state)
        for (d, key, t) in staged {
            self.mesh.devices[d].put(&key, t);
        }
        self.strategy = new;
        Ok((self.mesh.ops - ops0, self.mesh.wire_elems - wire0))
    }
}

impl Engine {
    /// Move one resliced shard during a TP-degree-changing switch: new
    /// shard `j` of `new_tp` assembles its slice range from the old
    /// `old_tp` shards (replicated gains copy whole; split tensors take
    /// the overlapping row/column segments from each old owner).
    #[allow(clippy::too_many_arguments)]
    fn reshard_param(
        &mut self,
        owners: &BTreeMap<(u32, String, usize), Vec<usize>>,
        load: &mut BTreeMap<usize, u64>,
        l: u32,
        p_name: &str,
        prefix: &str,
        old_tp: usize,
        new_tp: usize,
        j: usize,
        dst: usize,
        staged: &mut Vec<(usize, String, HostTensor)>,
    ) -> Result<()> {
        let key = format!("{prefix}{}", pkey(l, p_name));
        let pick = |owners: &Vec<usize>, load: &BTreeMap<usize, u64>| {
            *owners.iter().min_by_key(|&&o| (load.get(&o).copied().unwrap_or(0), o)).unwrap()
        };
        // replicated gains: copy from any old shard-0 owner
        if p_name.starts_with('g') {
            let own = owners
                .get(&(l, p_name.to_string(), 0))
                .ok_or_else(|| Error::Engine(format!("no owner for layer {l}")))?;
            let from = pick(own, load);
            if !self.mesh.devices[from].has(&key) {
                return Ok(()); // moments may not exist before the first step
            }
            if from != dst || !self.mesh.devices[dst].has(&key) {
                let t = self.mesh.devices[from].get(&key)?.clone();
                *load.entry(from).or_insert(0) += t.len() as u64;
                if from != dst {
                    self.mesh.wire_elems += t.len() as u64;
                    self.mesh.ops += 1;
                }
                staged.push((dst, key, t));
            }
            return Ok(());
        }
        let col_split = matches!(p_name, "wq" | "wk" | "wv" | "w1");
        // global extent of the split axis = old shard extent × old_tp
        let probe_own = owners
            .get(&(l, p_name.to_string(), 0))
            .ok_or_else(|| Error::Engine(format!("no owner for layer {l}")))?;
        let probe_dev = probe_own[0];
        if !self.mesh.devices[probe_dev].has(&key) {
            return Ok(()); // optimizer moments absent before step 1
        }
        let old_shape = self.mesh.devices[probe_dev].get(&key)?.shape.clone();
        let (rows, cols) = (old_shape[0], old_shape[1]);
        let global = if col_split { cols * old_tp } else { rows * old_tp };
        let (lo, hi) = (j * global / new_tp, (j + 1) * global / new_tp);
        // assemble the [lo, hi) range from overlapping old shards
        let mut parts: Vec<HostTensor> = vec![];
        let per_old = global / old_tp;
        let mut pos = lo;
        while pos < hi {
            let i = pos / per_old; // old shard index
            let seg_hi = hi.min((i + 1) * per_old);
            let own = owners
                .get(&(l, p_name.to_string(), i))
                .ok_or_else(|| Error::Engine(format!("no owner for layer {l} old shard {i}")))?;
            let from = pick(own, load);
            let src = self.mesh.devices[from].get(&key)?;
            let (a, b) = (pos - i * per_old, seg_hi - i * per_old);
            let piece = if col_split {
                extract_cols(src, a, b)?
            } else {
                extract_rows(src, a, b)?
            };
            *load.entry(from).or_insert(0) += piece.len() as u64;
            if from != dst {
                self.mesh.wire_elems += piece.len() as u64;
                self.mesh.ops += 1;
            }
            parts.push(piece);
            pos = seg_hi;
        }
        let assembled = if col_split { concat_cols(&parts)? } else { concat_rows(&parts)? };
        staged.push((dst, key, assembled));
        Ok(())
    }
}

/// Columns `[lo, hi)` of a 2-D tensor.
fn extract_cols(t: &HostTensor, lo: usize, hi: usize) -> Result<HostTensor> {
    let (r, c) = (t.shape[0], t.shape[1]);
    let src = t.as_f32()?;
    let w = hi - lo;
    let mut out = Vec::with_capacity(r * w);
    for row in 0..r {
        out.extend_from_slice(&src[row * c + lo..row * c + hi]);
    }
    HostTensor::f32(vec![r, w], out)
}

/// Rows `[lo, hi)` of a 2-D tensor.
fn extract_rows(t: &HostTensor, lo: usize, hi: usize) -> Result<HostTensor> {
    let c = t.shape[1];
    let src = t.as_f32()?;
    HostTensor::f32(vec![hi - lo, c], src[lo * c..hi * c].to_vec())
}

/// Horizontal concatenation of equal-row 2-D tensors.
fn concat_cols(parts: &[HostTensor]) -> Result<HostTensor> {
    if parts.len() == 1 {
        return Ok(parts[0].clone());
    }
    let r = parts[0].shape[0];
    let total_c: usize = parts.iter().map(|p| p.shape[1]).sum();
    let mut out = Vec::with_capacity(r * total_c);
    for row in 0..r {
        for p in parts {
            let c = p.shape[1];
            out.extend_from_slice(&p.as_f32()?[row * c..(row + 1) * c]);
        }
    }
    HostTensor::f32(vec![r, total_c], out)
}

/// Vertical concatenation of equal-column 2-D tensors.
fn concat_rows(parts: &[HostTensor]) -> Result<HostTensor> {
    if parts.len() == 1 {
        return Ok(parts[0].clone());
    }
    let c = parts[0].shape[1];
    let total_r: usize = parts.iter().map(|p| p.shape[0]).sum();
    let mut out = Vec::with_capacity(total_r * c);
    for p in parts {
        out.extend_from_slice(p.as_f32()?);
    }
    HostTensor::f32(vec![total_r, c], out)
}

fn old_tp_of_layer(s: &EngineStrategy, l: u32) -> Option<usize> {
    for p in &s.pipelines {
        for st in &p.stages {
            if st.layers.0 <= l && l < st.layers.1 {
                return Some(st.tp());
            }
        }
    }
    None
}

/// Accumulate (or initialize) a gradient buffer.
fn accumulate(dev: &mut crate::collectives::DeviceMem, key: &str, t: HostTensor) -> Result<()> {
    if dev.has(key) {
        dev.get_mut(key)?.add_assign(&t)
    } else {
        dev.put(key, t);
        Ok(())
    }
}

/// Deterministic N(0, 0.02) init for a named tensor (gains = 1).
fn init_tensor(seed: u64, layer: u32, name: &str, shape: &[usize], _hidden: usize) -> HostTensor {
    let n: usize = shape.iter().product();
    if name.starts_with('g') {
        return HostTensor::f32(shape.to_vec(), vec![1.0; n]).unwrap();
    }
    let tag: u64 = name.bytes().fold(0u64, |a, b| a.wrapping_mul(131).wrapping_add(b as u64));
    let mut rng = Rng::new(seed ^ (layer as u64) << 32 ^ tag);
    let mut data = Vec::with_capacity(n);
    // Box–Muller
    while data.len() < n {
        let u1 = rng.f64().max(1e-12);
        let u2 = rng.f64();
        let r = (-2.0 * u1.ln()).sqrt();
        let th = 2.0 * std::f64::consts::PI * u2;
        data.push((r * th.cos() * 0.02) as f32);
        if data.len() < n {
            data.push((r * th.sin() * 0.02) as f32);
        }
    }
    HostTensor::f32(shape.to_vec(), data).unwrap()
}

/// Slice a full parameter into its Megatron TP shard `j` of `tp`.
fn shard_param(full: &HostTensor, name: &str, tp: usize, j: usize) -> Result<HostTensor> {
    if tp == 1 {
        return Ok(full.clone());
    }
    match name {
        "g1" | "g2" => Ok(full.clone()), // replicated gains
        "wq" | "wk" | "wv" | "w1" => slice_cols(full, tp, j),
        "wo" | "w2" => slice_rows(full, tp, j),
        other => Err(Error::Engine(format!("unknown param `{other}`"))),
    }
}

fn slice_cols(t: &HostTensor, tp: usize, j: usize) -> Result<HostTensor> {
    let (r, c) = (t.shape[0], t.shape[1]);
    let w = c / tp;
    let src = t.as_f32()?;
    let mut out = Vec::with_capacity(r * w);
    for row in 0..r {
        out.extend_from_slice(&src[row * c + j * w..row * c + (j + 1) * w]);
    }
    HostTensor::f32(vec![r, w], out)
}

fn slice_rows(t: &HostTensor, tp: usize, j: usize) -> Result<HostTensor> {
    let (r, c) = (t.shape[0], t.shape[1]);
    let h = r / tp;
    let src = t.as_f32()?;
    HostTensor::f32(vec![h, c], src[j * h * c..(j + 1) * h * c].to_vec())
}

/// Helper: does the runtime have an artifact? (used during validation)
impl Runtime {
    /// True if the manifest lists `name`.
    pub fn metas_has(&self, name: &str) -> bool {
        self.meta(name).is_ok()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn uniform_strategy_shapes() {
        let s = EngineStrategy::uniform("dp2tp2pp2", 2, 2, 2, 8, 4);
        assert_eq!(s.num_devices(), 8);
        assert_eq!(s.pipelines.len(), 2);
        assert_eq!(s.pipelines[0].stages[0].layers, (0, 4));
        assert_eq!(s.pipelines[1].stages[1].devices, vec![6, 7]);
    }

    #[test]
    fn validate_catches_bad_tp() {
        let cfg = ManifestConfig { layers: 8, ..Default::default() };
        let s = EngineStrategy::uniform("tp3", 1, 3, 1, 8, 1);
        assert!(s.validate(&cfg, &[1, 2, 4]).is_err());
        let ok = EngineStrategy::uniform("tp2", 1, 2, 1, 8, 1);
        ok.validate(&cfg, &[1, 2, 4]).unwrap();
    }

    #[test]
    fn shard_slicing_tiles_full_tensor() {
        let full = HostTensor::f32(vec![4, 6], (0..24).map(|x| x as f32).collect()).unwrap();
        // columns
        let c0 = slice_cols(&full, 2, 0).unwrap();
        let c1 = slice_cols(&full, 2, 1).unwrap();
        assert_eq!(c0.shape, vec![4, 3]);
        assert_eq!(c0.as_f32().unwrap()[..3], [0.0, 1.0, 2.0]);
        assert_eq!(c1.as_f32().unwrap()[..3], [3.0, 4.0, 5.0]);
        // rows
        let r1 = slice_rows(&full, 2, 1).unwrap();
        assert_eq!(r1.shape, vec![2, 6]);
        assert_eq!(r1.as_f32().unwrap()[0], 12.0);
    }

    #[test]
    fn init_is_deterministic_and_scaled() {
        let a = init_tensor(7, 3, "wq", &[32, 32], 32);
        let b = init_tensor(7, 3, "wq", &[32, 32], 32);
        assert_eq!(a, b);
        let c = init_tensor(7, 4, "wq", &[32, 32], 32);
        assert_ne!(a, c);
        let mean: f32 = a.as_f32().unwrap().iter().sum::<f32>() / 1024.0;
        assert!(mean.abs() < 0.01);
        let g = init_tensor(7, 0, "g1", &[8], 8);
        assert_eq!(g.as_f32().unwrap(), &[1.0; 8]);
    }

    #[test]
    fn validate_catches_hetero_tp_per_layer() {
        let cfg = ManifestConfig { layers: 4, ..Default::default() };
        let s = EngineStrategy {
            name: "bad".into(),
            pipelines: vec![
                EnginePipeline {
                    stages: vec![EngineStage { devices: vec![0, 1], layers: (0, 4) }],
                    num_microbatches: 1,
                },
                EnginePipeline {
                    stages: vec![EngineStage { devices: vec![2], layers: (0, 4) }],
                    num_microbatches: 1,
                },
            ],
        };
        assert!(s.validate(&cfg, &[1, 2, 4]).is_err());
    }
}
