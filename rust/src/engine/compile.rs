//! MPMD compilation of specialized plans (DESIGN.md §9, ROADMAP item 3).
//!
//! [`specialize`](super::specialize) lowers a strategy into per-rank
//! [`RankPlan`](super::specialize::RankPlan) timelines, but both executors
//! still *interpret* them: every step re-resolves dependencies, formats
//! tensor keys, and re-derives channel endpoints per task. This pass runs
//! once per `(strategy, layout, schedule, zero1, micro-batch shape class)`
//! and freezes all of that into a [`CompiledProgram`]:
//!
//! * a **flat instruction tape** ([`CompiledOp`]) in the plan's task order
//!   — a topological linear extension of the dependency DAG, so replaying
//!   it sequentially respects every rank's program order and therefore
//!   every per-device f32 accumulation order (losses stay bit-identical
//!   to the event-driven executor and the global interpreter);
//! * **fused compute segments** ([`Seg`]): consecutive tape ops that run
//!   on the same device set with no interleaved communication collapse
//!   into one dispatch unit, so the replay loop touches one ready check
//!   per segment instead of one per task;
//! * a **static comm schedule**: every hand-off's sender/receiver
//!   endpoints, every collective's group (in plan-group reduction order),
//!   and every tensor key are resolved at compile time — the hot loop
//!   performs zero key formatting and zero routing;
//! * a **preallocated arena** sized from the plan ([`CompiledArena`]):
//!   head results land in fixed slots (`slot = base[pipeline] + mb`), and
//!   the replay scratch ([`ReplayScratch`]) reuses its buffers across
//!   steps — after warm-up the dispatch layer allocates nothing
//!   (asserted with a counting allocator in `rust/tests/compiled_alloc.rs`;
//!   kernel outputs and tensor transfers allocate by design).
//!
//! The program is cached on the engine (invalidated exactly when the
//! specialized plan is: strategy switches and ZeRO-1 toggles; micro-batch
//! shape changes are revalidated per step) and pooled across switches in
//! [`StrategyPool`](crate::temporal::StrategyPool) keyed by
//! `(entry, schedule, zero1, shape class)`.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::Instant;

use crate::collectives::DeviceMem;
use crate::runtime::workspace::{
    block_bwd_ws, block_fwd_ws, grad_shape, head_step_ws, BlockDims, KernelWorkspace,
    PanelCache, WorkspacePlan,
};
use crate::runtime::{native, HostTensor, ManifestConfig};
use crate::spec::schedule::ScheduleKind;
use crate::{Error, Result};

use super::exec::{accumulate, task_duration, SpecRunOutcome};
use super::intern::{KeyId, KeyInterner};
use super::layout::{gkey, pkey};
use super::specialize::{SpecTask, SpecTaskKind, SpecializedPlan};
use super::{Engine, EnginePipeline, MicroBatch, BLOCK_PARAMS};

/// The micro-batch **shape class** of a step: per pipeline, per
/// micro-batch `(n_seqs, seq_len)`. Two steps in the same class replay
/// the same compiled program (tensor extents, hand-off sizes, and the
/// token-independent structure all match); the class is part of the
/// pool's artifact cache key.
#[derive(Clone, Debug, PartialEq, Eq, Hash)]
pub struct ShapeClass(Vec<Vec<(usize, usize)>>);

impl ShapeClass {
    /// The class of an actual prefetched step batch.
    pub fn of_batches(batches: &[Vec<MicroBatch>]) -> ShapeClass {
        ShapeClass(
            batches
                .iter()
                .map(|bs| bs.iter().map(|b| (b.n_seqs, b.seq_len)).collect())
                .collect(),
        )
    }

    /// The class the engine's current contract prescribes: the ragged
    /// window shapes when [`Engine::set_microbatches`] installed them,
    /// else the compiled uniform `[batch, seq]` at the current per-
    /// pipeline counts. This is the pool-side key — it matches
    /// [`ShapeClass::of_batches`] for every batch the engine accepts.
    pub fn of_engine(engine: &Engine) -> ShapeClass {
        if let Some(ws) = &engine.mb_windows {
            return ShapeClass(
                ws.iter()
                    .map(|pws| pws.iter().map(|w| (w.n_seqs(), w.seq_len)).collect())
                    .collect(),
            );
        }
        let counts: Vec<usize> =
            engine.strategy.pipelines.iter().map(|p| p.num_microbatches).collect();
        ShapeClass::uniform(&counts, engine.runtime.config.batch, engine.runtime.config.seq)
    }

    /// Uniform `[n_seqs, seq_len]` micro-batches at per-pipeline counts.
    pub fn uniform(counts: &[usize], n_seqs: usize, seq_len: usize) -> ShapeClass {
        ShapeClass(counts.iter().map(|&c| vec![(n_seqs, seq_len); c]).collect())
    }

    /// Allocation-free revalidation of a prefetched step batch against
    /// this class — the hot-loop cache check.
    pub fn matches_batches(&self, batches: &[Vec<MicroBatch>]) -> bool {
        self.0.len() == batches.len()
            && self.0.iter().zip(batches).all(|(ps, bs)| {
                ps.len() == bs.len()
                    && ps
                        .iter()
                        .zip(bs)
                        .all(|(&(n, s), b)| b.n_seqs == n && b.seq_len == s)
            })
    }

    /// Per-pipeline micro-batch counts of the class.
    pub fn counts(&self) -> Vec<usize> {
        self.0.iter().map(|p| p.len()).collect()
    }
}

/// One frozen tape instruction. Index `i` of [`CompiledProgram::ops`] is
/// task `i` of the source plan; every tensor key, channel endpoint,
/// collective group (plan-group reduction order), artifact name, and
/// arena slot is resolved at compile time. Keys and artifact names are
/// interned [`KeyId`]s into the program's own [`KeyInterner`] — resolve
/// with [`CompiledProgram::key`] (pure array indexing); the op itself
/// stores only dense `u32` ids, so a tape stays compact at thousands of
/// ranks and the hot loop never hashes or formats a string.
#[derive(Clone, Debug)]
pub enum CompiledOp {
    /// Stage-0 forward input: embed the micro-batch on `root`, broadcast
    /// over the TP `group`.
    FwdEmbed {
        /// Pipeline.
        pi: usize,
        /// Micro-batch.
        mb: usize,
        /// Stage root device.
        root: usize,
        /// Stage devices (TP-group order).
        group: Vec<usize>,
        /// Activation key (interned).
        akey: KeyId,
    },
    /// Later-stage forward input: receive the activation hand-off
    /// `src_root → root`, free the producers' dead copies, broadcast.
    FwdRecv {
        /// Sending endpoint (producing stage's root).
        src_root: usize,
        /// Receiving endpoint (this stage's root).
        root: usize,
        /// Producer devices whose copies are freed.
        frees: Vec<usize>,
        /// Stage devices (TP-group order).
        group: Vec<usize>,
        /// Activation key (interned).
        akey: KeyId,
    },
    /// One layer's forward GEMMs: save the block input, run every TP
    /// member's partial forward.
    FwdGemm {
        /// Stage devices (TP-group order).
        group: Vec<usize>,
        /// Activation key (interned).
        akey: KeyId,
        /// Saved-block-input key (interned).
        skey: KeyId,
        /// Artifact name (`block_fwd_tp{n}`, interned).
        art: KeyId,
        /// The 8 parameter keys, artifact input order (interned).
        pkeys: [KeyId; 8],
    },
    /// Forward TP sync: partial-sum all-reduce (group order) + residual
    /// add.
    FwdTpSync {
        /// TP group (reduction order).
        group: Vec<usize>,
        /// Activation key (interned).
        akey: KeyId,
    },
    /// Last-stage backward input: fused head on `root` (loss + token-
    /// scaled head gradients, freeing the stage activation), broadcast
    /// the gradient; the `(loss, tokens)` pair lands in arena `slot`.
    HeadBwd {
        /// Pipeline.
        pi: usize,
        /// Micro-batch.
        mb: usize,
        /// Stage root device.
        root: usize,
        /// Stage devices (TP-group order).
        group: Vec<usize>,
        /// Activation key (interned, consumed).
        akey: KeyId,
        /// Incoming-gradient key (interned, produced).
        dkey: KeyId,
        /// Arena head slot (`base[pi] + mb`).
        slot: usize,
    },
    /// Earlier-stage backward input: receive the gradient hand-off
    /// `src_root → root`, free the producers' copies, broadcast.
    BwdRecv {
        /// Sending endpoint (next stage's root).
        src_root: usize,
        /// Receiving endpoint (this stage's root).
        root: usize,
        /// Producer devices whose copies are freed.
        frees: Vec<usize>,
        /// Stage devices (TP-group order).
        group: Vec<usize>,
        /// Incoming-gradient key (interned).
        dkey: KeyId,
    },
    /// One layer's backward GEMMs + parameter-gradient accumulation
    /// (frees the saved block input).
    BwdGemm {
        /// Stage devices (TP-group order).
        group: Vec<usize>,
        /// Saved-block-input key (interned, consumed).
        skey: KeyId,
        /// Incoming-gradient key (interned).
        dkey: KeyId,
        /// Artifact name (`block_bwd_tp{n}`, interned).
        art: KeyId,
        /// The 8 parameter keys, artifact input order (interned).
        pkeys: [KeyId; 8],
        /// The 8 gradient keys, accumulation order (interned).
        gkeys: [KeyId; 8],
    },
    /// Backward TP sync: dx-partial all-reduce (group order) + add.
    BwdTpSync {
        /// TP group (reduction order).
        group: Vec<usize>,
        /// Incoming-gradient key (interned).
        dkey: KeyId,
    },
    /// Stage-0 backward epilogue: embedding gradient on `root`, free the
    /// incoming gradient on the whole stage.
    EmbedBwd {
        /// Pipeline.
        pi: usize,
        /// Micro-batch.
        mb: usize,
        /// Stage root device.
        root: usize,
        /// Stage devices.
        group: Vec<usize>,
        /// Incoming-gradient key (interned, consumed).
        dkey: KeyId,
    },
    /// Token-weighted DP gradient reduction (the layout's cached plan).
    GradReduce {
        /// Devices the phase's wall time is spread over.
        ndev: usize,
    },
    /// Optimizer application on local shards.
    OptimStep {
        /// Devices the phase's wall time is spread over.
        ndev: usize,
    },
    /// ZeRO-1 updated-parameter slice exchange.
    ZeroExchange {
        /// Devices the phase's wall time is spread over.
        ndev: usize,
    },
}

impl CompiledOp {
    /// Precomputed activation key id, when the op carries one.
    pub fn act_key(&self) -> Option<KeyId> {
        match self {
            CompiledOp::FwdEmbed { akey, .. }
            | CompiledOp::FwdRecv { akey, .. }
            | CompiledOp::FwdGemm { akey, .. }
            | CompiledOp::FwdTpSync { akey, .. }
            | CompiledOp::HeadBwd { akey, .. } => Some(*akey),
            _ => None,
        }
    }

    /// Precomputed incoming-gradient key id, when the op carries one.
    pub fn grad_key(&self) -> Option<KeyId> {
        match self {
            CompiledOp::HeadBwd { dkey, .. }
            | CompiledOp::BwdRecv { dkey, .. }
            | CompiledOp::BwdGemm { dkey, .. }
            | CompiledOp::BwdTpSync { dkey, .. }
            | CompiledOp::EmbedBwd { dkey, .. } => Some(*dkey),
            _ => None,
        }
    }

    /// Precomputed saved-block-input key id (GEMM ops).
    pub fn save_key(&self) -> Option<KeyId> {
        match self {
            CompiledOp::FwdGemm { skey, .. } | CompiledOp::BwdGemm { skey, .. } => Some(*skey),
            _ => None,
        }
    }

    /// Precomputed artifact name id (GEMM ops).
    pub fn artifact(&self) -> Option<KeyId> {
        match self {
            CompiledOp::FwdGemm { art, .. } | CompiledOp::BwdGemm { art, .. } => Some(*art),
            _ => None,
        }
    }

    /// Precomputed parameter key ids (GEMM ops, artifact input order).
    pub fn param_keys(&self) -> Option<&[KeyId; 8]> {
        match self {
            CompiledOp::FwdGemm { pkeys, .. } | CompiledOp::BwdGemm { pkeys, .. } => {
                Some(pkeys)
            }
            _ => None,
        }
    }

    /// Precomputed gradient key ids (backward GEMMs, accumulation order).
    pub fn grad_param_keys(&self) -> Option<&[KeyId; 8]> {
        match self {
            CompiledOp::BwdGemm { gkeys, .. } => Some(gkeys),
            _ => None,
        }
    }
}

/// One fused dispatch segment: a contiguous tape range running on one
/// device set, replayed with a single ready check. Ranges index the
/// program's flat side tables so a segment is `Copy`-cheap and the walk
/// touches no per-step allocation.
#[derive(Clone, Copy, Debug)]
pub struct Seg {
    /// `[start, end)` into [`CompiledProgram::ops`].
    pub ops: (u32, u32),
    /// `[start, end)` into [`CompiledProgram::part_ranks`] — the
    /// participating timelines (plan-rank positions).
    pub parts: (u32, u32),
    /// `[start, end)` into [`CompiledProgram::dep_segs`] — segments that
    /// must finish first (deduplicated; intra-segment chains elided).
    pub deps: (u32, u32),
}

/// Which fused kernel driver replays a lowered compute op (DESIGN.md
/// §12). Frozen per tape op at compile time, so the hot loop's only
/// branch is `fused[oi].is_some()`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FusedKind {
    /// Transformer-block forward → `workspace::block_fwd_ws`.
    FwdBlock,
    /// Transformer-block backward → `workspace::block_bwd_ws`.
    BwdBlock,
    /// Stage-0 embedding gather → `native::embed_fwd_into`.
    EmbedFwd,
    /// Fused head (loss + grads) → `workspace::head_step_ws`.
    Head,
    /// Embedding-gradient scatter → `native::embed_bwd_into`.
    EmbedBwd,
}

/// One compile-time-lowered kernel call: driver choice, frozen block
/// geometry, and the exact per-device workspace reservation. Everything
/// the executor needs to run the op with zero kernel-layer allocation.
#[derive(Clone, Copy, Debug)]
pub struct FusedCall {
    /// Fused driver to dispatch.
    pub kind: FusedKind,
    /// Frozen geometry (micro-batch shape × TP-local widths).
    pub dims: BlockDims,
    /// Floats this call carves from the device's [`KernelWorkspace`].
    pub ws_floats: usize,
}

/// Monotonic compiled-program identity (see [`CompiledProgram::uid`]).
/// Starts at 1 so a default arena tag (0) never matches a real program.
static PROGRAM_UID: AtomicU64 = AtomicU64::new(1);

/// A compiled MPMD step program: the frozen union of every rank's tape.
/// Replayed front to back ([`walk`]) it reproduces the event-driven
/// executor bit-for-bit; sliced by participant it is one
/// `CompiledRankProgram` per rank (the threaded executor replays each
/// rank's ops by index on its own thread).
#[derive(Debug)]
pub struct CompiledProgram {
    /// The instruction tape, index-aligned with the source plan's tasks
    /// (a topological linear extension of the dependency DAG).
    pub ops: Vec<CompiledOp>,
    /// Fused dispatch segments, in tape order.
    pub segs: Vec<Seg>,
    /// Flat participant table ([`Seg::parts`] ranges): plan-rank
    /// positions, TP-group order.
    pub part_ranks: Vec<u32>,
    /// Flat dependency table ([`Seg::deps`] ranges): segment indices.
    pub dep_segs: Vec<u32>,
    /// Timelines (= ranks) in the source plan.
    pub nranks: usize,
    /// Head-result arena slots (Σ per-pipeline micro-batch counts).
    pub head_slots: usize,
    /// Per pipeline: arena slots in the interpreter's loss-accumulation
    /// order (the plan's head-retirement order, slot-resolved).
    pub head_order: Vec<Vec<u32>>,
    /// Schedule the program was compiled from.
    pub schedule: ScheduleKind,
    /// Per-pipeline micro-batch counts at compile time.
    pub num_microbatches: Vec<usize>,
    /// Micro-batch shape class the tape is specialized to.
    pub shape: ShapeClass,
    /// Whether the tape carries the ZeRO-1 slice exchange.
    pub zero1: bool,
    /// Frozen span identities, index-aligned with `ops` (DESIGN.md §10):
    /// the traced hot loop reads its [`SpanKind`](crate::obs::trace::SpanKind)
    /// here instead of matching on the op — fixed-size ring entries, no
    /// plan in sight.
    pub spans: Vec<crate::obs::trace::SpanKind>,
    /// Plan-rank position → mesh rank id (what a span's `rank` field
    /// carries; positions are what [`Seg::parts`] indexes).
    pub part_rank_ids: Vec<u32>,
    /// Exact spans one traced step emits (Σ over segments of
    /// ops × participants) — the recorder's ring capacity, frozen at
    /// compile time so the warm traced step never grows the ring.
    pub trace_slots: usize,
    /// Kernel-level lowering, index-aligned with `ops`: `Some` when the
    /// op replays through a fused zero-allocation driver, `None` when it
    /// falls back to the allocating oracle kernels (non-native runtime,
    /// fusion disabled, or non-divisible TP widths).
    pub fused: Vec<Option<FusedCall>>,
    /// Per-device workspace reservations implied by `fused` (max over
    /// the device's fused ops) — the compile-time arena sizing rule.
    pub ws_plan: WorkspacePlan,
    /// Whether this program was compiled with kernel fusion requested
    /// (cache-revalidation key next to schedule/zero1/shape).
    pub fused_kernels: bool,
    /// Process-unique program identity. Workspaces and panel caches in
    /// [`CompiledArena`] are keyed to it: interned [`KeyId`]s are only
    /// meaningful within one program, so a uid change drops the panels
    /// (an `Arc` pointer could ABA through the allocator; this cannot).
    pub uid: u64,
    /// The program's own key interner: every [`KeyId`] on the tape
    /// resolves here. Owned by the program (shared through its `Arc`), so
    /// pooled artifacts stay self-contained across strategy switches.
    keys: KeyInterner,
}

impl CompiledProgram {
    /// Fused segments (one ready check each) vs raw tape ops — the
    /// dispatch-reduction the fusion rule buys.
    pub fn num_segs(&self) -> usize {
        self.segs.len()
    }

    /// Resolve a tape key id to its string — pure array indexing, no
    /// hashing, no allocation (this is what the hot loop and the trace
    /// boundary call).
    #[inline]
    pub fn key(&self, id: KeyId) -> &str {
        self.keys.resolve(id)
    }

    /// Distinct keys the tape interns (diagnostics).
    pub fn num_keys(&self) -> usize {
        self.keys.len()
    }

    /// True when the program still describes `pipelines` (counts match).
    pub fn counts_match(&self, pipelines: &[EnginePipeline]) -> bool {
        self.num_microbatches.len() == pipelines.len()
            && self
                .num_microbatches
                .iter()
                .zip(pipelines)
                .all(|(&m, p)| m == p.num_microbatches)
    }
}

/// Fusion rule: an op may join a segment when it is pure device-local
/// compute — GEMMs, the stage-0 embedding epilogue, and *degenerate*
/// (single-member) TP syncs, whose all-reduce is a no-op and whose
/// residual add is local. Real collectives, hand-offs, head/embed
/// boundary ops, and the global phases always cut a segment.
fn fusable(t: &SpecTask) -> bool {
    match t.kind {
        SpecTaskKind::FwdGemm { .. }
        | SpecTaskKind::BwdGemm { .. }
        | SpecTaskKind::EmbedBwd { .. } => true,
        SpecTaskKind::FwdTpSync { .. } | SpecTaskKind::BwdTpSync { .. } => t.ranks.len() == 1,
        _ => false,
    }
}

/// The 8 parameter and 8 gradient key ids of `layer`, formatted and
/// interned once per layer no matter how many (mb × dp) GEMM tasks touch
/// it — at cluster scale this is the difference between O(layers) and
/// O(tasks) string work in the compiler.
fn layer_key_ids(
    cache: &mut BTreeMap<u32, ([KeyId; 8], [KeyId; 8])>,
    keys: &mut KeyInterner,
    layer: u32,
) -> ([KeyId; 8], [KeyId; 8]) {
    *cache.entry(layer).or_insert_with(|| {
        let mut pk = [KeyId(0); 8];
        let mut gk = [KeyId(0); 8];
        for (i, p) in BLOCK_PARAMS.iter().enumerate() {
            pk[i] = keys.intern(&pkey(layer, p));
            gk[i] = keys.intern(&gkey(layer, p));
        }
        (pk, gk)
    })
}

/// Compile a specialized plan into a frozen MPMD program.
///
/// `pipelines` must be the strategy snapshot the plan was specialized
/// from; `shape` is the micro-batch shape class the program is keyed
/// under; `cfg` supplies the model geometry the kernel lowering freezes;
/// `fuse_kernels` lowers compute ops into [`FusedCall`]s (pass false for
/// non-native runtimes — the fused drivers call the native kernels
/// directly). Structural mismatches are typed errors, not panics — the
/// compiler re-validates what it freezes.
pub fn compile_program(
    plan: &SpecializedPlan,
    pipelines: &[EnginePipeline],
    zero1: bool,
    shape: ShapeClass,
    cfg: &ManifestConfig,
    fuse_kernels: bool,
) -> Result<CompiledProgram> {
    if plan.num_microbatches.len() != pipelines.len() {
        return Err(Error::Engine(format!(
            "compile: plan has {} pipelines, strategy has {}",
            plan.num_microbatches.len(),
            pipelines.len()
        )));
    }
    if shape.counts() != plan.num_microbatches {
        return Err(Error::Engine(format!(
            "compile: shape class counts {:?} do not match the plan's {:?}",
            shape.counts(),
            plan.num_microbatches
        )));
    }
    let ndev = plan.ranks.len().max(1);
    // arena slot layout: per-pipeline contiguous head slots
    let mut slot_base = Vec::with_capacity(pipelines.len());
    let mut head_slots = 0usize;
    for &m in &plan.num_microbatches {
        slot_base.push(head_slots);
        head_slots += m;
    }

    let stage_of = |pi: usize, si: usize, ranks: &[usize]| -> Result<()> {
        if pipelines[pi].stages[si].devices != ranks {
            return Err(Error::Engine(format!(
                "compile: task on pipeline {pi} stage {si} runs on {ranks:?} but the \
                 stage owns {:?}",
                pipelines[pi].stages[si].devices
            )));
        }
        Ok(())
    };

    // The program's interner. Hot-key families are formatted exactly once
    // here — per (pipeline, micro-batch) activations/gradients up front,
    // per-layer parameter/gradient octets and per-TP-width artifact names
    // on first use — so compile cost does O(keys) string work instead of
    // O(tasks), and the tape stores dense u32 ids.
    let mut keys = KeyInterner::new();
    let mut ak: Vec<Vec<KeyId>> = Vec::with_capacity(plan.num_microbatches.len());
    let mut dk: Vec<Vec<KeyId>> = Vec::with_capacity(plan.num_microbatches.len());
    for (pi, &m) in plan.num_microbatches.iter().enumerate() {
        ak.push((0..m).map(|mb| keys.intern(&Engine::akey(pi, mb))).collect());
        dk.push((0..m).map(|mb| keys.intern(&Engine::dkey(pi, mb))).collect());
    }
    let mut layer_cache: BTreeMap<u32, ([KeyId; 8], [KeyId; 8])> = BTreeMap::new();
    let mut art_cache: BTreeMap<(bool, usize), KeyId> = BTreeMap::new();

    let mut ops: Vec<CompiledOp> = Vec::with_capacity(plan.tasks.len());
    for (ti, t) in plan.tasks.iter().enumerate() {
        let op = match t.kind {
            SpecTaskKind::FwdIn { pipe, stage, mb } => {
                stage_of(pipe, stage, &t.ranks)?;
                if stage == 0 {
                    CompiledOp::FwdEmbed {
                        pi: pipe,
                        mb,
                        root: t.ranks[0],
                        group: t.ranks.clone(),
                        akey: ak[pipe][mb],
                    }
                } else {
                    let Some(&src_root) = t.src.first() else {
                        return Err(Error::Engine(format!(
                            "compile: hand-off task {ti} names no producers"
                        )));
                    };
                    CompiledOp::FwdRecv {
                        src_root,
                        root: t.ranks[0],
                        frees: t.src.iter().copied().filter(|d| !t.ranks.contains(d)).collect(),
                        group: t.ranks.clone(),
                        akey: ak[pipe][mb],
                    }
                }
            }
            SpecTaskKind::FwdGemm { pipe, stage, mb, layer } => {
                stage_of(pipe, stage, &t.ranks)?;
                let (pk, _) = layer_key_ids(&mut layer_cache, &mut keys, layer);
                let n = t.ranks.len();
                CompiledOp::FwdGemm {
                    group: t.ranks.clone(),
                    akey: ak[pipe][mb],
                    skey: keys.intern(&Engine::skey(pipe, mb, layer)),
                    art: *art_cache
                        .entry((true, n))
                        .or_insert_with(|| keys.intern(&format!("block_fwd_tp{n}"))),
                    pkeys: pk,
                }
            }
            SpecTaskKind::FwdTpSync { pipe, stage, mb, .. } => {
                stage_of(pipe, stage, &t.ranks)?;
                CompiledOp::FwdTpSync { group: t.ranks.clone(), akey: ak[pipe][mb] }
            }
            SpecTaskKind::BwdIn { pipe, stage, mb } => {
                stage_of(pipe, stage, &t.ranks)?;
                if stage + 1 == pipelines[pipe].stages.len() {
                    CompiledOp::HeadBwd {
                        pi: pipe,
                        mb,
                        root: t.ranks[0],
                        group: t.ranks.clone(),
                        akey: ak[pipe][mb],
                        dkey: dk[pipe][mb],
                        slot: slot_base[pipe] + mb,
                    }
                } else {
                    let Some(&src_root) = t.src.first() else {
                        return Err(Error::Engine(format!(
                            "compile: hand-off task {ti} names no producers"
                        )));
                    };
                    CompiledOp::BwdRecv {
                        src_root,
                        root: t.ranks[0],
                        frees: t.src.iter().copied().filter(|d| !t.ranks.contains(d)).collect(),
                        group: t.ranks.clone(),
                        dkey: dk[pipe][mb],
                    }
                }
            }
            SpecTaskKind::BwdGemm { pipe, stage, mb, layer } => {
                stage_of(pipe, stage, &t.ranks)?;
                let (pk, gk) = layer_key_ids(&mut layer_cache, &mut keys, layer);
                let n = t.ranks.len();
                CompiledOp::BwdGemm {
                    group: t.ranks.clone(),
                    skey: keys.intern(&Engine::skey(pipe, mb, layer)),
                    dkey: dk[pipe][mb],
                    art: *art_cache
                        .entry((false, n))
                        .or_insert_with(|| keys.intern(&format!("block_bwd_tp{n}"))),
                    pkeys: pk,
                    gkeys: gk,
                }
            }
            SpecTaskKind::BwdTpSync { pipe, stage, mb, .. } => {
                stage_of(pipe, stage, &t.ranks)?;
                CompiledOp::BwdTpSync { group: t.ranks.clone(), dkey: dk[pipe][mb] }
            }
            SpecTaskKind::EmbedBwd { pipe, mb } => {
                stage_of(pipe, 0, &t.ranks)?;
                CompiledOp::EmbedBwd {
                    pi: pipe,
                    mb,
                    root: t.ranks[0],
                    group: t.ranks.clone(),
                    dkey: dk[pipe][mb],
                }
            }
            SpecTaskKind::GradReduce => CompiledOp::GradReduce { ndev },
            SpecTaskKind::OptimStep => CompiledOp::OptimStep { ndev },
            SpecTaskKind::ZeroExchange => CompiledOp::ZeroExchange { ndev },
        };
        ops.push(op);
    }

    // Kernel-level lowering: freeze a FusedCall per compute op. Block
    // GEMMs gate on exact TP divisibility (the fused drivers assume the
    // artifact's per-shard widths); embed/head ops have no TP split and
    // always lower. Per-device workspace reservations fold into the
    // plan here — block ops carve on every group member, embed/head on
    // the stage root only.
    let mut fused: Vec<Option<FusedCall>> = vec![None; plan.tasks.len()];
    let mut ws_plan = WorkspacePlan::default();
    if fuse_kernels {
        let div_ok = |tp: usize| {
            tp > 0
                && cfg.heads != 0
                && cfg.hidden % cfg.heads == 0
                && cfg.hidden % tp == 0
                && cfg.ffn % tp == 0
                && cfg.heads % tp == 0
        };
        // embed/head geometry: no TP split, no per-head arithmetic
        let root_dims = |ns: usize, sl: usize| BlockDims {
            n: ns * sl,
            b: ns,
            s: sl,
            h: cfg.hidden,
            hl: cfg.hidden,
            fl: cfg.ffn,
            nh: 1,
            hd: cfg.hidden,
            v: cfg.vocab,
        };
        for (ti, t) in plan.tasks.iter().enumerate() {
            let fc = match t.kind {
                SpecTaskKind::FwdGemm { pipe, mb, .. } => {
                    let tp = t.ranks.len();
                    if !div_ok(tp) {
                        continue;
                    }
                    let (ns, sl) = shape.0[pipe][mb];
                    let dims = BlockDims::new(cfg, tp, ns, sl);
                    FusedCall {
                        kind: FusedKind::FwdBlock,
                        dims,
                        ws_floats: dims.fwd_ws_floats(),
                    }
                }
                SpecTaskKind::BwdGemm { pipe, mb, .. } => {
                    let tp = t.ranks.len();
                    if !div_ok(tp) {
                        continue;
                    }
                    let (ns, sl) = shape.0[pipe][mb];
                    let dims = BlockDims::new(cfg, tp, ns, sl);
                    FusedCall {
                        kind: FusedKind::BwdBlock,
                        dims,
                        ws_floats: dims.bwd_ws_floats(),
                    }
                }
                SpecTaskKind::FwdIn { pipe, stage, mb } if stage == 0 => {
                    let (ns, sl) = shape.0[pipe][mb];
                    FusedCall { kind: FusedKind::EmbedFwd, dims: root_dims(ns, sl), ws_floats: 0 }
                }
                SpecTaskKind::BwdIn { pipe, stage, mb }
                    if stage + 1 == pipelines[pipe].stages.len() =>
                {
                    let (ns, sl) = shape.0[pipe][mb];
                    let dims = root_dims(ns, sl);
                    FusedCall { kind: FusedKind::Head, dims, ws_floats: dims.head_ws_floats() }
                }
                SpecTaskKind::EmbedBwd { pipe, mb } => {
                    let (ns, sl) = shape.0[pipe][mb];
                    let dims = root_dims(ns, sl);
                    FusedCall {
                        kind: FusedKind::EmbedBwd,
                        dims,
                        ws_floats: dims.embed_bwd_ws_floats(),
                    }
                }
                _ => continue,
            };
            match fc.kind {
                FusedKind::FwdBlock | FusedKind::BwdBlock => {
                    for &r in &t.ranks {
                        ws_plan.note(r, fc.ws_floats);
                    }
                }
                _ => ws_plan.note(t.ranks[0], fc.ws_floats),
            }
            fused[ti] = Some(fc);
        }
    }

    // Segment fusion. An op joins the previous segment only when it is
    // fusable, runs on the same device set, and its sole dependency is
    // the op right before it (the specializer's intra-group chain) — so a
    // segment's external dependencies are exactly its first op's, and
    // replaying the segment as one unit reproduces the event-driven
    // executor's per-op timing accumulation.
    let mut segs: Vec<Seg> = vec![];
    let mut seg_of: Vec<u32> = Vec::with_capacity(plan.tasks.len());
    let mut part_ranks: Vec<u32> = vec![];
    let mut dep_segs: Vec<u32> = vec![];
    for (ti, t) in plan.tasks.iter().enumerate() {
        let fuse = ti > 0
            && fusable(t)
            && fusable(&plan.tasks[ti - 1])
            && t.ranks == plan.tasks[ti - 1].ranks
            && matches!(t.deps.as_slice(), &[d] if d == ti - 1);
        if fuse {
            let last = segs.last_mut().expect("fuse implies a previous segment");
            last.ops.1 = ti as u32 + 1;
            seg_of.push((segs.len() - 1) as u32);
            continue;
        }
        let p0 = part_ranks.len() as u32;
        for &r in &t.ranks {
            let pos = plan.rank_index(r).ok_or_else(|| {
                Error::Engine(format!("compile: task {ti} runs on rank {r} with no timeline"))
            })?;
            part_ranks.push(pos as u32);
        }
        let d0 = dep_segs.len() as u32;
        let mut ds: Vec<u32> = t.deps.iter().map(|&d| seg_of[d]).collect();
        ds.sort_unstable();
        ds.dedup();
        dep_segs.extend(ds);
        seg_of.push(segs.len() as u32);
        segs.push(Seg {
            ops: (ti as u32, ti as u32 + 1),
            parts: (p0, part_ranks.len() as u32),
            deps: (d0, dep_segs.len() as u32),
        });
    }

    let head_order: Vec<Vec<u32>> = plan
        .head_order
        .iter()
        .enumerate()
        .map(|(pi, ord)| ord.iter().map(|&mb| (slot_base[pi] + mb) as u32).collect())
        .collect();

    // freeze the span identities: kind per op (kernel-fused block GEMMs
    // get their own kinds so traces show the fusion), mesh rank per plan
    // position, and the exact per-step span count (fused ops share their
    // segment's participant set, so ops × parts is exact per segment)
    let spans: Vec<crate::obs::trace::SpanKind> = plan
        .tasks
        .iter()
        .zip(&fused)
        .map(|(t, f)| {
            use crate::obs::trace::SpanKind;
            match (SpanKind::of_task(&t.kind), f) {
                (SpanKind::FwdGemm, Some(_)) => SpanKind::FwdGemmFused,
                (SpanKind::BwdGemm, Some(_)) => SpanKind::BwdGemmFused,
                (k, _) => k,
            }
        })
        .collect();
    let part_rank_ids: Vec<u32> = plan.ranks.iter().map(|rp| rp.rank as u32).collect();
    let trace_slots: usize = segs
        .iter()
        .map(|s| (s.ops.1 - s.ops.0) as usize * (s.parts.1 - s.parts.0) as usize)
        .sum();

    Ok(CompiledProgram {
        ops,
        segs,
        part_ranks,
        dep_segs,
        nranks: plan.ranks.len(),
        head_slots,
        head_order,
        schedule: plan.schedule,
        num_microbatches: plan.num_microbatches.clone(),
        shape,
        zero1,
        spans,
        part_rank_ids,
        trace_slots,
        fused,
        ws_plan,
        fused_kernels: fuse_kernels,
        uid: PROGRAM_UID.fetch_add(1, Ordering::Relaxed),
        keys,
    })
}

/// Replay scratch of the tape walk: segment finish times, per-timeline
/// clocks. Buffers are reused across steps (`mem::take`n out of the
/// engine per step), so a warm walk allocates nothing.
#[derive(Default)]
pub struct ReplayScratch {
    finish: Vec<f64>,
    clock: Vec<f64>,
}

impl ReplayScratch {
    fn reset(&mut self, nsegs: usize, nranks: usize) {
        self.finish.clear();
        self.finish.resize(nsegs, 0.0);
        self.clock.clear();
        self.clock.resize(nranks, 0.0);
    }
}

/// The preallocated per-step arena: head results in fixed slots, the
/// per-member compute-time scratch of fused GEMM dispatch, and the
/// kernel layer's per-device workspaces and prepacked-panel caches.
/// Reused across steps — after the first step at a program, nothing
/// here allocates.
#[derive(Default)]
pub struct CompiledArena {
    /// `(mean loss, real tokens)` per head slot.
    head_vals: Vec<(f32, u64)>,
    /// Per-TP-member compute seconds of the op in flight.
    member_s: Vec<f64>,
    /// Per-device kernel workspaces, sized by the program's plan.
    ws: Vec<KernelWorkspace>,
    /// Per-device prepacked-weight panels, indexed by interned `KeyId`.
    panels: Vec<PanelCache>,
    /// Uid of the program `ws`/`panels` belong to. `KeyId` panel indices
    /// are program-scoped, so a uid change clears the panel caches.
    prog_tag: u64,
}

impl CompiledArena {
    fn reset(&mut self, head_slots: usize) {
        self.head_vals.clear();
        self.head_vals.resize(head_slots, (0.0, 0));
    }

    /// Bind the kernel-layer state to `prog`: on a program change, drop
    /// panels (stale `KeyId` space) and re-ensure per-device workspaces;
    /// warm re-entry with the same program is allocation-free.
    fn prepare(&mut self, prog: &CompiledProgram, ndev: usize) {
        if self.prog_tag != prog.uid {
            self.ws.clear();
            self.ws.resize_with(ndev, KernelWorkspace::default);
            self.panels.clear();
            self.panels.resize_with(ndev, PanelCache::default);
            for (d, w) in self.ws.iter_mut().enumerate() {
                w.ensure(prog.ws_plan.floats_for(d));
            }
            self.prog_tag = prog.uid;
        }
    }

    /// Panel-cache counters summed over devices: `(hits, misses,
    /// repacks)` (diagnostics; tests assert the steady state repacks
    /// without missing).
    pub fn panel_stats(&self) -> (u64, u64, u64) {
        self.panels.iter().fold((0, 0, 0), |(h, m, r), p| {
            (h + p.hits, m + p.misses, r + p.repacks)
        })
    }
}

/// Timing outcome of one tape walk.
pub(crate) struct WalkOutcome {
    pub makespan_s: f64,
    pub exposed_switch_s: f64,
    pub delivery_lane_s: f64,
}

/// Replay the tape front to back: per segment one ready check (max over
/// participant clocks and dependency finishes), then the segment's ops
/// through `exec`, then the clock propagation — the event-driven
/// executor's timing semantics over the frozen structure, with zero
/// dependency *resolution* (no readiness scans, no per-task maps) and
/// zero allocation on the warm path.
pub(crate) fn walk(
    prog: &CompiledProgram,
    scratch: &mut ReplayScratch,
    deliveries: &[(usize, f64)],
    rec: &mut crate::obs::trace::SpanRecorder,
    mut exec: impl FnMut(usize, &CompiledOp) -> Result<f64>,
) -> Result<WalkOutcome> {
    scratch.reset(prog.segs.len(), prog.nranks);
    for (si, seg) in prog.segs.iter().enumerate() {
        let parts = &prog.part_ranks[seg.parts.0 as usize..seg.parts.1 as usize];
        let mut ready = 0f64;
        for &p in parts {
            ready = ready.max(scratch.clock[p as usize]);
        }
        for &d in &prog.dep_segs[seg.deps.0 as usize..seg.deps.1 as usize] {
            ready = ready.max(scratch.finish[d as usize]);
        }
        let mut dur = 0f64;
        for oi in seg.ops.0..seg.ops.1 {
            let d = exec(oi as usize, &prog.ops[oi as usize])?;
            // frozen-identity spans: kind and rank come from compile-time
            // tables, timestamps from the replayed clock — fixed-size ring
            // stores, no allocation (`prog.trace_slots` sized the ring)
            if rec.is_active() {
                let sk = prog.spans[oi as usize];
                let (t0, t1) = (ready + dur, ready + dur + d);
                for &p in parts {
                    rec.record(oi, sk, prog.part_rank_ids[p as usize], t0, t1);
                }
            }
            dur += d;
        }
        let end = ready + dur;
        scratch.finish[si] = end;
        for &p in parts {
            scratch.clock[p as usize] = end;
        }
    }
    let makespan_s = scratch.clock.iter().copied().fold(0.0, f64::max);
    // §6.2 measured interleave: per-sender delivery lanes, computed
    // quadratically over the (small) delivery list to stay allocation-free.
    let mut delivery_lane_s = 0f64;
    for (i, &(sender, _)) in deliveries.iter().enumerate() {
        if deliveries[..i].iter().any(|&(s, _)| s == sender) {
            continue;
        }
        let lane: f64 = deliveries
            .iter()
            .filter(|&&(s, _)| s == sender)
            .map(|&(_, secs)| secs.max(0.0))
            .sum();
        delivery_lane_s = delivery_lane_s.max(lane);
    }
    let exposed_switch_s = (delivery_lane_s - makespan_s).max(0.0);
    Ok(WalkOutcome { makespan_s, exposed_switch_s, delivery_lane_s })
}

/// Accumulate (or initialize) a gradient buffer from a workspace slice —
/// the fused drivers' counterpart of [`accumulate`]: same elementwise
/// `+=` order, but warm accumulation writes into the existing tensor in
/// place (no intermediate `HostTensor`, no allocation). `shape` is only
/// invoked on the cold insert.
fn accumulate_slice(
    dev: &mut DeviceMem,
    key: &str,
    src: &[f32],
    shape: impl FnOnce() -> Vec<usize>,
) -> Result<()> {
    if dev.has(key) {
        let dst = dev.get_mut(key)?.as_f32_mut()?;
        if dst.len() != src.len() {
            return Err(Error::Engine(format!(
                "accumulate: gradient `{key}` changed size ({} vs {})",
                dst.len(),
                src.len()
            )));
        }
        for (d, s) in dst.iter_mut().zip(src) {
            *d += s;
        }
        Ok(())
    } else {
        dev.put(key, HostTensor::f32(shape(), src.to_vec())?);
        Ok(())
    }
}

impl Engine {
    /// The compiled program for the current strategy at the shape class
    /// of `batches` — the hot-loop entry: an allocation-free revalidation
    /// against the cached program, recompiling only when the schedule,
    /// ZeRO-1 mode, kernel-fusion setting, or micro-batch shapes changed
    /// (strategy switches and ZeRO-1 toggles clear the cache outright,
    /// exactly like `spec`).
    pub(crate) fn compiled_program_for(
        &mut self,
        batches: &[Vec<MicroBatch>],
    ) -> Result<Arc<CompiledProgram>> {
        if let Some(p) = &self.compiled {
            if p.schedule == self.strategy.schedule
                && p.zero1 == self.zero1
                && p.fused_kernels == self.fusion_active()
                && p.shape.matches_batches(batches)
            {
                return Ok(Arc::clone(p));
            }
        }
        self.build_compiled(ShapeClass::of_batches(batches))
    }

    /// The compiled program at the engine's *contract* shape class
    /// ([`ShapeClass::of_engine`]) — the pool-side compile/lookup path.
    pub fn compiled_program_cached(&mut self) -> Result<Arc<CompiledProgram>> {
        let shape = ShapeClass::of_engine(self);
        if let Some(p) = &self.compiled {
            if p.schedule == self.strategy.schedule
                && p.zero1 == self.zero1
                && p.fused_kernels == self.fusion_active()
                && p.shape == shape
            {
                return Ok(Arc::clone(p));
            }
        }
        self.build_compiled(shape)
    }

    fn build_compiled(&mut self, shape: ShapeClass) -> Result<Arc<CompiledProgram>> {
        let plan = self.specialized_plan()?;
        let fuse = self.fusion_active();
        let prog = Arc::new(compile_program(
            &plan,
            &self.strategy.pipelines,
            self.zero1,
            shape,
            &self.runtime.config,
            fuse,
        )?);
        self.compiled = Some(Arc::clone(&prog));
        Ok(prog)
    }

    /// Install a pooled program as the engine's cached artifact. Typed
    /// error when the program does not describe this engine — the pool's
    /// key logic is re-checked at the boundary, so a stale artifact can
    /// never replay against the wrong strategy.
    pub fn install_compiled(&mut self, prog: Arc<CompiledProgram>) -> Result<()> {
        if prog.schedule != self.strategy.schedule
            || prog.zero1 != self.zero1
            || prog.fused_kernels != self.fusion_active()
            || !prog.counts_match(&self.strategy.pipelines)
            || prog.shape != ShapeClass::of_engine(self)
        {
            return Err(Error::Engine(
                "install_compiled: program does not describe this engine's strategy/\
                 schedule/zero1/shape"
                    .into(),
            ));
        }
        self.compiled = Some(prog);
        Ok(())
    }

    /// The engine's cached compiled program, if any (None after every
    /// invalidation event — strategy switch, ZeRO-1 toggle).
    pub fn compiled_cached(&self) -> Option<&Arc<CompiledProgram>> {
        self.compiled.as_ref()
    }

    /// Drop the cached compiled program (the next compiled step, or
    /// [`Engine::compiled_program_cached`], recompiles). Benches use this
    /// to measure cold compile cost.
    pub fn invalidate_compiled(&mut self) {
        self.compiled = None;
    }

    /// Walk the tape with a null executor: full dependency resolution and
    /// clock propagation, no kernels. Returns the (zero-duration)
    /// makespan. This is the dispatch layer in isolation — the
    /// counting-allocator test asserts a warm replay performs **zero**
    /// heap allocation.
    pub fn replay_compiled_tape(&mut self, prog: &CompiledProgram) -> Result<f64> {
        let mut replay = std::mem::take(&mut self.replay);
        let mut rec = std::mem::take(&mut self.recorder);
        rec.begin_step(prog.trace_slots, self.trace_on);
        let out = walk(prog, &mut replay, &[], &mut rec, |_, _| Ok(0.0)).map(|w| w.makespan_s);
        self.recorder = rec;
        self.replay = replay;
        out
    }

    /// Execute one step by replaying a compiled program
    /// ([`ExecMode::Compiled`](super::ExecMode::Compiled)): the hot loop
    /// is segment dispatch over the frozen tape — no dependency
    /// resolution, no key formatting, no routing, no dispatch-layer
    /// allocation. Numerically bit-identical to the event-driven
    /// executor (same per-device op order, same reduction orders, same
    /// f64 loss accumulation).
    pub(crate) fn run_compiled(
        &mut self,
        prog: &Arc<CompiledProgram>,
        batches: &[Vec<MicroBatch>],
        deliveries: &[(usize, f64)],
    ) -> Result<SpecRunOutcome> {
        let prog = Arc::clone(prog);
        let mut replay = std::mem::take(&mut self.replay);
        let mut arena = std::mem::take(&mut self.arena);
        let mut rec = std::mem::take(&mut self.recorder);
        rec.begin_step(prog.trace_slots, self.trace_on);
        arena.reset(prog.head_slots);
        arena.prepare(&prog, self.mesh.devices.len());
        let walked = walk(&prog, &mut replay, deliveries, &mut rec, |oi, op| {
            self.exec_compiled_op(&prog, oi, op, batches, &mut arena)
        });
        let out = walked.map(|w| {
            // f64 loss accumulation in the interpreter's order: pipeline-
            // major, per-pipeline sub-sums over the frozen slot order.
            let mut weighted_loss = 0f64;
            for order in &prog.head_order {
                let mut wp = 0f64;
                for &slot in order {
                    let (loss, n_tok) = arena.head_vals[slot as usize];
                    if n_tok > 0 {
                        wp += loss as f64 * n_tok as f64;
                    }
                }
                weighted_loss += wp;
            }
            let tokens: u64 = arena.head_vals.iter().map(|&(_, n)| n).sum();
            SpecRunOutcome {
                weighted_loss,
                tokens,
                makespan_s: w.makespan_s,
                exposed_switch_s: w.exposed_switch_s,
                delivery_lane_s: w.delivery_lane_s,
            }
        });
        self.recorder = rec;
        self.replay = replay;
        self.arena = arena;
        out
    }

    /// Execute one tape op. Each arm mirrors the event-driven executor's
    /// task body exactly (`spec_fwd_in` etc. in [`super::exec`]) with
    /// every key, endpoint, and group read from the frozen op; interned
    /// key ids resolve through `prog` by array indexing (no hashing, no
    /// allocation on the dispatch layer). Ops with a frozen [`FusedCall`]
    /// replay through the zero-allocation fused drivers instead of the
    /// allocating oracle kernels — bit-identical by the `_into`-kernel
    /// contract (DESIGN.md §12), asserted in `tests/compiled_identity.rs`.
    fn exec_compiled_op(
        &mut self,
        prog: &CompiledProgram,
        oi: usize,
        op: &CompiledOp,
        batches: &[Vec<MicroBatch>],
        arena: &mut CompiledArena,
    ) -> Result<f64> {
        let fc = prog.fused.get(oi).and_then(|f| f.as_ref());
        match op {
            CompiledOp::FwdEmbed { pi, mb, root, group, akey } => {
                let akey = prog.key(*akey);
                let batch = &batches[*pi][*mb];
                let t0 = Instant::now();
                if let Some(fc) = fc {
                    // fused: gather straight from the token slice — no
                    // token-tensor clone, no kernel-layer allocation (the
                    // activation itself is store-layer by design)
                    let (h, v) = (fc.dims.h, fc.dims.v);
                    let mut out = vec![0.0f32; fc.dims.n * h];
                    {
                        let emb = self.mesh.devices[*root].get("emb")?.as_f32()?;
                        native::embed_fwd_into(emb, &batch.tokens, h, v, &mut out)?;
                    }
                    let x0 = HostTensor::f32(vec![batch.n_seqs, batch.seq_len, h], out)?;
                    self.mesh.devices[*root].put(akey, x0);
                } else {
                    let tok = HostTensor::i32(
                        vec![batch.n_seqs, batch.seq_len],
                        batch.tokens.clone(),
                    )?;
                    let x0 = {
                        let emb = self.mesh.devices[*root].get("emb")?;
                        let out = self.runtime.call_refs("embed_fwd", &[emb, &tok])?;
                        out.into_iter().next().unwrap()
                    };
                    self.mesh.devices[*root].put(akey, x0);
                }
                self.mesh.broadcast(*root, group, akey)?;
                Ok(t0.elapsed().as_secs_f64())
            }
            CompiledOp::FwdRecv { src_root, root, frees, group, akey } => {
                let akey = prog.key(*akey);
                let t0 = Instant::now();
                self.mesh.send(*src_root, *root, akey)?;
                for &d in frees {
                    let _ = self.mesh.devices[d].take(akey);
                }
                self.mesh.broadcast(*root, group, akey)?;
                Ok(t0.elapsed().as_secs_f64())
            }
            CompiledOp::FwdGemm { group, akey, skey, art, pkeys } => {
                let (akey, skey) = (prog.key(*akey), prog.key(*skey));
                let t0 = Instant::now();
                arena.member_s.clear();
                arena.member_s.resize(group.len(), 0.0);
                for &d in group {
                    let x = self.mesh.devices[d].get(akey)?.clone();
                    self.mesh.devices[d].put(skey, x);
                }
                if let Some(fc) = fc {
                    let dims = fc.dims;
                    let nh = dims.n * dims.h;
                    for (j, &dv) in group.iter().enumerate() {
                        // pack panels outside the member compute window
                        // (lazy: hit/repack warm, miss only on first touch)
                        {
                            let dev = &self.mesh.devices[dv];
                            let pc = &mut arena.panels[dv];
                            for &pk in pkeys.iter() {
                                pc.ensure(pk.index(), dev.get(prog.key(pk))?.as_f32()?);
                            }
                        }
                        let t1 = Instant::now();
                        {
                            let wsbuf = arena.ws[dv].slice(fc.ws_floats);
                            let (ybuf, rest) = wsbuf.split_at_mut(nh);
                            let pc = &arena.panels[dv];
                            let p: [&[f32]; 8] =
                                std::array::from_fn(|i| pc.get(pkeys[i].index()));
                            let x = self.mesh.devices[dv].get(akey)?.as_f32()?;
                            block_fwd_ws(&dims, &p, x, ybuf, rest);
                        }
                        arena.member_s[j] += t1.elapsed().as_secs_f64();
                        // store the partial: warm-reuse the device's
                        // existing "part" tensor in place (no String, no
                        // payload allocation), cold-insert otherwise
                        let src = &arena.ws[dv].data()[..nh];
                        let dev = &mut self.mesh.devices[dv];
                        let mut stored = false;
                        if dev.has("part") {
                            let t = dev.get_mut("part")?;
                            if t.shape == [dims.b, dims.s, dims.h] {
                                t.as_f32_mut()?.copy_from_slice(src);
                                stored = true;
                            }
                        }
                        if !stored {
                            dev.put(
                                "part",
                                HostTensor::f32(vec![dims.b, dims.s, dims.h], src.to_vec())?,
                            );
                        }
                    }
                } else {
                    let art = prog.key(*art);
                    for (j, &d) in group.iter().enumerate() {
                        let dev = &self.mesh.devices[d];
                        let inputs = [
                            dev.get(prog.key(pkeys[0]))?,
                            dev.get(prog.key(pkeys[1]))?,
                            dev.get(prog.key(pkeys[2]))?,
                            dev.get(prog.key(pkeys[3]))?,
                            dev.get(prog.key(pkeys[4]))?,
                            dev.get(prog.key(pkeys[5]))?,
                            dev.get(prog.key(pkeys[6]))?,
                            dev.get(prog.key(pkeys[7]))?,
                            dev.get(akey)?,
                        ];
                        let t1 = Instant::now();
                        let y_part =
                            self.runtime.call_refs(art, &inputs)?.into_iter().next().unwrap();
                        arena.member_s[j] += t1.elapsed().as_secs_f64();
                        self.mesh.devices[d].put("part", y_part);
                    }
                }
                Ok(task_duration(t0.elapsed().as_secs_f64(), &arena.member_s))
            }
            CompiledOp::FwdTpSync { group, akey } => {
                let akey = prog.key(*akey);
                let t0 = Instant::now();
                self.mesh.all_reduce(group, "part")?;
                for &d in group {
                    let part = self.mesh.devices[d].get("part")?.clone();
                    let x = self.mesh.devices[d].get_mut(akey)?;
                    x.add_assign(&part)?;
                }
                Ok(t0.elapsed().as_secs_f64())
            }
            CompiledOp::HeadBwd { pi, mb, root, group, akey, dkey, slot } => {
                let (akey, dkey) = (prog.key(*akey), prog.key(*dkey));
                let batch = &batches[*pi][*mb];
                let t0 = Instant::now();
                let tokens = batch.real_tokens();
                let w = tokens as f32;
                let loss = if let Some(fc) = fc {
                    // fused: targets read straight from the batch (no
                    // tensor clone), every head intermediate carved from
                    // the root's workspace; dx is the produced dkey
                    // tensor (store layer by design, like the oracle's)
                    let (n, h, v) = (fc.dims.n, fc.dims.h, fc.dims.v);
                    let mut dx = vec![0.0f32; n * h];
                    let (loss, hg) = {
                        let ws = arena.ws[*root].slice(fc.ws_floats);
                        let dev = &self.mesh.devices[*root];
                        head_step_ws(
                            n,
                            h,
                            v,
                            dev.get("gf")?.as_f32()?,
                            dev.get("wout")?.as_f32()?,
                            dev.get(akey)?.as_f32()?,
                            &batch.targets,
                            &mut dx,
                            ws,
                        )?
                    };
                    // token-weight scaling in place (oracle: tensor.scale)
                    for z in dx.iter_mut() {
                        *z *= w;
                    }
                    for z in hg.dgf.iter_mut() {
                        *z *= w;
                    }
                    for z in hg.dwout.iter_mut() {
                        *z *= w;
                    }
                    {
                        let dev = &mut self.mesh.devices[*root];
                        accumulate_slice(dev, "grad.gf", hg.dgf, || vec![h])?;
                        accumulate_slice(dev, "grad.wout", hg.dwout, || vec![h, v])?;
                    }
                    self.mesh.devices[*root].put(
                        dkey,
                        HostTensor::f32(vec![batch.n_seqs, batch.seq_len, h], dx)?,
                    );
                    loss
                } else {
                    let tgt = HostTensor::i32(
                        vec![batch.n_seqs, batch.seq_len],
                        batch.targets.clone(),
                    )?;
                    let (loss, mut dx, mut dgf, mut dwout) = {
                        let dev = &self.mesh.devices[*root];
                        let out = self.runtime.call_refs(
                            "head_step",
                            &[dev.get("gf")?, dev.get("wout")?, dev.get(akey)?, &tgt],
                        )?;
                        let mut it = out.into_iter();
                        let loss = it.next().unwrap().as_f32()?[0];
                        (loss, it.next().unwrap(), it.next().unwrap(), it.next().unwrap())
                    };
                    dx.scale(w)?;
                    dgf.scale(w)?;
                    dwout.scale(w)?;
                    accumulate(&mut self.mesh.devices[*root], "grad.gf", dgf)?;
                    accumulate(&mut self.mesh.devices[*root], "grad.wout", dwout)?;
                    self.mesh.devices[*root].put(dkey, dx);
                    loss
                };
                for &d in group {
                    let _ = self.mesh.devices[d].take(akey);
                }
                arena.head_vals[*slot] = (loss, tokens);
                self.mesh.broadcast(*root, group, dkey)?;
                Ok(t0.elapsed().as_secs_f64())
            }
            CompiledOp::BwdRecv { src_root, root, frees, group, dkey } => {
                let dkey = prog.key(*dkey);
                let t0 = Instant::now();
                self.mesh.send(*src_root, *root, dkey)?;
                for &d in frees {
                    let _ = self.mesh.devices[d].take(dkey);
                }
                self.mesh.broadcast(*root, group, dkey)?;
                Ok(t0.elapsed().as_secs_f64())
            }
            CompiledOp::BwdGemm { group, skey, dkey, art, pkeys, gkeys } => {
                let (skey, dkey) = (prog.key(*skey), prog.key(*dkey));
                let t0 = Instant::now();
                arena.member_s.clear();
                arena.member_s.resize(group.len(), 0.0);
                if let Some(fc) = fc {
                    let dims = fc.dims;
                    let nh = dims.n * dims.h;
                    for (j, &dv) in group.iter().enumerate() {
                        {
                            let dev = &self.mesh.devices[dv];
                            let pc = &mut arena.panels[dv];
                            for &pk in pkeys.iter() {
                                pc.ensure(pk.index(), dev.get(prog.key(pk))?.as_f32()?);
                            }
                        }
                        let t1 = Instant::now();
                        let (dx_slice, grads) = {
                            let wsbuf = arena.ws[dv].slice(fc.ws_floats);
                            let (dxbuf, rest) = wsbuf.split_at_mut(nh);
                            let pc = &arena.panels[dv];
                            let p: [&[f32]; 8] =
                                std::array::from_fn(|i| pc.get(pkeys[i].index()));
                            let dev = &self.mesh.devices[dv];
                            let x = dev.get(skey)?.as_f32()?;
                            let dy = dev.get(dkey)?.as_f32()?;
                            let g = block_bwd_ws(&dims, &p, x, dy, dxbuf, rest);
                            (&*dxbuf, g)
                        };
                        arena.member_s[j] += t1.elapsed().as_secs_f64();
                        let dev = &mut self.mesh.devices[dv];
                        let mut stored = false;
                        if dev.has("dpart") {
                            let t = dev.get_mut("dpart")?;
                            if t.shape == [dims.b, dims.s, dims.h] {
                                t.as_f32_mut()?.copy_from_slice(dx_slice);
                                stored = true;
                            }
                        }
                        if !stored {
                            dev.put(
                                "dpart",
                                HostTensor::f32(
                                    vec![dims.b, dims.s, dims.h],
                                    dx_slice.to_vec(),
                                )?,
                            );
                        }
                        for (i, &gk) in gkeys.iter().enumerate() {
                            accumulate_slice(dev, prog.key(gk), grads.by_index(i), || {
                                grad_shape(&dims, i)
                            })?;
                        }
                        let _ = dev.take(skey);
                    }
                } else {
                    let art = prog.key(*art);
                    for (j, &d) in group.iter().enumerate() {
                        let dev = &self.mesh.devices[d];
                        let inputs = [
                            dev.get(prog.key(pkeys[0]))?,
                            dev.get(prog.key(pkeys[1]))?,
                            dev.get(prog.key(pkeys[2]))?,
                            dev.get(prog.key(pkeys[3]))?,
                            dev.get(prog.key(pkeys[4]))?,
                            dev.get(prog.key(pkeys[5]))?,
                            dev.get(prog.key(pkeys[6]))?,
                            dev.get(prog.key(pkeys[7]))?,
                            dev.get(skey)?,
                            dev.get(dkey)?,
                        ];
                        let t1 = Instant::now();
                        let outs = self.runtime.call_refs(art, &inputs)?;
                        arena.member_s[j] += t1.elapsed().as_secs_f64();
                        let mut it = outs.into_iter();
                        let dx_part = it.next().unwrap();
                        self.mesh.devices[d].put("dpart", dx_part);
                        for &gk in gkeys {
                            accumulate(
                                &mut self.mesh.devices[d],
                                prog.key(gk),
                                it.next().unwrap(),
                            )?;
                        }
                        let _ = self.mesh.devices[d].take(skey);
                    }
                }
                Ok(task_duration(t0.elapsed().as_secs_f64(), &arena.member_s))
            }
            CompiledOp::BwdTpSync { group, dkey } => {
                let dkey = prog.key(*dkey);
                let t0 = Instant::now();
                self.mesh.all_reduce(group, "dpart")?;
                for &d in group {
                    let dpart = self.mesh.devices[d].get("dpart")?.clone();
                    let dx = self.mesh.devices[d].get_mut(dkey)?;
                    dx.add_assign(&dpart)?;
                }
                Ok(t0.elapsed().as_secs_f64())
            }
            CompiledOp::EmbedBwd { pi, mb, root, group, dkey } => {
                let dkey = prog.key(*dkey);
                let batch = &batches[*pi][*mb];
                let t0 = Instant::now();
                if let Some(fc) = fc {
                    // fused: scatter into the workspace's [v, h] panel,
                    // accumulate in place — no token clone, no fresh demb
                    let (h, v) = (fc.dims.h, fc.dims.v);
                    {
                        let ws = arena.ws[*root].slice(fc.ws_floats);
                        let dx0 = self.mesh.devices[*root].get(dkey)?.as_f32()?;
                        native::embed_bwd_into(&batch.tokens, dx0, h, v, ws)?;
                    }
                    let src = &arena.ws[*root].data()[..v * h];
                    let dev = &mut self.mesh.devices[*root];
                    accumulate_slice(dev, "grad.emb", src, || vec![v, h])?;
                } else {
                    let tok = HostTensor::i32(
                        vec![batch.n_seqs, batch.seq_len],
                        batch.tokens.clone(),
                    )?;
                    let demb = {
                        let dx0 = self.mesh.devices[*root].get(dkey)?;
                        self.runtime
                            .call_refs("embed_bwd", &[&tok, dx0])?
                            .into_iter()
                            .next()
                            .unwrap()
                    };
                    accumulate(&mut self.mesh.devices[*root], "grad.emb", demb)?;
                }
                for &d in group {
                    let _ = self.mesh.devices[d].take(dkey);
                }
                Ok(t0.elapsed().as_secs_f64())
            }
            CompiledOp::GradReduce { ndev } => {
                let tokens: u64 = arena.head_vals.iter().map(|&(_, n)| n).sum();
                if tokens == 0 {
                    return Err(Error::Engine("train_step: no tokens processed".into()));
                }
                let t0 = Instant::now();
                self.sync_gradients(tokens)?;
                Ok(t0.elapsed().as_secs_f64() / *ndev as f64)
            }
            CompiledOp::OptimStep { ndev } => {
                let t0 = Instant::now();
                self.apply_updates_local()?;
                // parameters changed: mark every prepacked panel stale.
                // Storage is retained — next step repacks in place.
                for pc in &mut arena.panels {
                    pc.invalidate();
                }
                Ok(t0.elapsed().as_secs_f64() / *ndev as f64)
            }
            CompiledOp::ZeroExchange { ndev } => {
                let t0 = Instant::now();
                self.exchange_zero1_slices()?;
                Ok(t0.elapsed().as_secs_f64() / *ndev as f64)
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::layout::ShardLayout;
    use crate::engine::specialize::specialize;
    use crate::engine::EngineStrategy;
    use crate::runtime::native;

    fn compiled(s: &EngineStrategy, zero1: bool) -> (SpecializedPlan, CompiledProgram) {
        let cfg = native::tiny_config();
        let layout = ShardLayout::build(&cfg, s).unwrap();
        let plan = specialize(s, &layout, zero1).unwrap();
        let counts: Vec<usize> = s.pipelines.iter().map(|p| p.num_microbatches).collect();
        let shape = ShapeClass::uniform(&counts, cfg.batch, cfg.seq);
        let prog = compile_program(&plan, &s.pipelines, zero1, shape, &cfg, true).unwrap();
        (plan, prog)
    }

    #[test]
    fn tape_is_index_aligned_and_topologically_frozen() {
        let s = EngineStrategy::uniform("dp2tp2pp2", 2, 2, 2, 8, 3);
        let (plan, prog) = compiled(&s, true);
        assert_eq!(prog.ops.len(), plan.tasks.len());
        assert_eq!(prog.nranks, plan.ranks.len());
        // segments tile the tape contiguously and deps point backward
        let mut next = 0u32;
        for (si, seg) in prog.segs.iter().enumerate() {
            assert_eq!(seg.ops.0, next, "segment {si} contiguous");
            assert!(seg.ops.1 > seg.ops.0);
            next = seg.ops.1;
            for &d in &prog.dep_segs[seg.deps.0 as usize..seg.deps.1 as usize] {
                assert!((d as usize) < si, "segment {si} dep {d} points backward");
            }
        }
        assert_eq!(next as usize, prog.ops.len());
        assert!(matches!(prog.ops.last(), Some(CompiledOp::ZeroExchange { .. })));
    }

    #[test]
    fn tp1_compute_chains_fuse_real_collectives_cut() {
        // TP1 stages: GEMM + degenerate sync chains collapse, so the
        // program dispatches far fewer segments than tape ops.
        let s = EngineStrategy::uniform("pp2", 1, 1, 2, 8, 3);
        let (plan, prog) = compiled(&s, false);
        assert!(
            prog.num_segs() < plan.tasks.len() / 2,
            "{} segs for {} ops",
            prog.num_segs(),
            plan.tasks.len()
        );
        // TP2: every sync is a real collective — only GEMM runs fuse
        let s2 = EngineStrategy::uniform("tp2pp2", 1, 2, 2, 8, 2);
        let (_, prog2) = compiled(&s2, false);
        for seg in &prog2.segs {
            for op in &prog2.ops[seg.ops.0 as usize..seg.ops.1 as usize] {
                if seg.ops.1 - seg.ops.0 > 1 {
                    assert!(
                        matches!(
                            op,
                            CompiledOp::FwdGemm { .. }
                                | CompiledOp::BwdGemm { .. }
                                | CompiledOp::EmbedBwd { .. }
                        ),
                        "fused segment holds a comm op: {op:?}"
                    );
                }
            }
        }
    }

    #[test]
    fn shape_class_revalidates_batches() {
        let sc = ShapeClass::uniform(&[2, 1], 2, 16);
        let mk = |n, s| MicroBatch {
            tokens: vec![0; n * s],
            targets: vec![0; n * s],
            n_seqs: n,
            seq_len: s,
        };
        let good = vec![vec![mk(2, 16), mk(2, 16)], vec![mk(2, 16)]];
        assert!(sc.matches_batches(&good));
        let ragged = vec![vec![mk(2, 16), mk(1, 16)], vec![mk(2, 16)]];
        assert!(!sc.matches_batches(&ragged));
        let short = vec![vec![mk(2, 16)], vec![mk(2, 16)]];
        assert!(!sc.matches_batches(&short));
        assert_eq!(sc.counts(), vec![2, 1]);
        assert_eq!(ShapeClass::of_batches(&ragged).counts(), vec![2, 1]);
    }

    #[test]
    fn kernel_lowering_freezes_fused_calls_and_workspace_plan() {
        let cfg = native::tiny_config();
        let s = EngineStrategy::uniform("dp2tp2pp2", 2, 2, 2, 8, 3);
        let (plan, prog) = compiled(&s, false);
        assert!(prog.fused_kernels);
        assert_eq!(prog.fused.len(), prog.ops.len());
        // every compute op lowers at tiny-48 (all widths divide); comm
        // and phase ops never do
        for (op, f) in prog.ops.iter().zip(&prog.fused) {
            match op {
                CompiledOp::FwdGemm { group, .. } => {
                    let f = f.as_ref().expect("fwd gemm lowers");
                    assert_eq!(f.kind, FusedKind::FwdBlock);
                    assert_eq!(f.dims.hl, cfg.hidden / group.len());
                    assert_eq!(f.ws_floats, f.dims.fwd_ws_floats());
                }
                CompiledOp::BwdGemm { .. } => {
                    assert_eq!(f.as_ref().unwrap().kind, FusedKind::BwdBlock);
                }
                CompiledOp::FwdEmbed { .. } => {
                    let f = f.as_ref().expect("embed fwd lowers");
                    assert_eq!(f.kind, FusedKind::EmbedFwd);
                    assert_eq!(f.ws_floats, 0);
                }
                CompiledOp::HeadBwd { .. } => {
                    assert_eq!(f.as_ref().unwrap().kind, FusedKind::Head);
                }
                CompiledOp::EmbedBwd { .. } => {
                    assert_eq!(f.as_ref().unwrap().kind, FusedKind::EmbedBwd);
                }
                _ => assert!(f.is_none(), "non-compute op lowered: {op:?}"),
            }
        }
        // the plan reserves the per-device max over fused ops, on every
        // device that runs one
        let mut want = WorkspacePlan::default();
        for (t, f) in plan.tasks.iter().zip(&prog.fused) {
            if let Some(f) = f {
                match f.kind {
                    FusedKind::FwdBlock | FusedKind::BwdBlock => {
                        for &r in &t.ranks {
                            want.note(r, f.ws_floats);
                        }
                    }
                    _ => want.note(t.ranks[0], f.ws_floats),
                }
            }
        }
        assert_eq!(prog.ws_plan, want);
        assert!(want.per_device_floats.iter().any(|&f| f > 0));
        // fused block GEMMs carry the fused span kinds
        for (sk, f) in prog.spans.iter().zip(&prog.fused) {
            use crate::obs::trace::SpanKind;
            match sk {
                SpanKind::FwdGemmFused | SpanKind::BwdGemmFused => assert!(f.is_some()),
                SpanKind::FwdGemm | SpanKind::BwdGemm => {
                    panic!("unfused gemm span in a fused program")
                }
                _ => {}
            }
        }

        // fusion off: no lowering, no reservations, plain gemm spans
        let layout = ShardLayout::build(&cfg, &s).unwrap();
        let plan2 = specialize(&s, &layout, false).unwrap();
        let counts: Vec<usize> = s.pipelines.iter().map(|p| p.num_microbatches).collect();
        let shape = ShapeClass::uniform(&counts, cfg.batch, cfg.seq);
        let off = compile_program(&plan2, &s.pipelines, false, shape, &cfg, false).unwrap();
        assert!(!off.fused_kernels);
        assert!(off.fused.iter().all(|f| f.is_none()));
        assert!(off.ws_plan.per_device_floats.iter().all(|&f| f == 0));
        assert_ne!(off.uid, prog.uid, "every compile gets a fresh identity");
    }

    #[test]
    fn head_slots_resolve_the_retirement_order() {
        let s = EngineStrategy::uniform("dp2", 2, 1, 1, 8, 3);
        let (plan, prog) = compiled(&s, false);
        assert_eq!(prog.head_slots, 6);
        // GPipe retires LIFO; pipeline 1's slots are offset by its base
        assert_eq!(prog.head_order, vec![vec![2, 1, 0], vec![5, 4, 3]]);
        assert_eq!(plan.head_order, vec![vec![2, 1, 0], vec![2, 1, 0]]);
    }
}
