//! String interning for tensor keys.
//!
//! The planning stack (layout → specialize → compile) names every tensor
//! with a formatted string key ("L3.wq", "grad.L3.wq", "act.p0.mb2", ...).
//! At 8 ranks that is fine; at 1024 generated ranks the plans hold hundreds
//! of thousands of key references and `String` keys make build cost scale
//! with formatting + string comparison, and tape storage with heap churn.
//!
//! `KeyInterner` maps each distinct key string to a dense `u32` [`KeyId`]
//! exactly once. Plans and frozen tapes store `KeyId` (4 bytes, `Copy`,
//! integer compare); resolution back to `&str` is a plain array index — no
//! hashing, no allocation — so the compiled dispatch hot loop keeps its
//! zero-alloc contract while the device stores (`DeviceMem`) keep their
//! string-keyed API at the boundary.
//!
//! Each `Arc`-shared planning artifact owns its interner (`ShardLayout`
//! builds one during `build()`, `CompiledProgram` one at compile time), so
//! a `KeyId` is only meaningful relative to the artifact that minted it.
//! Formatted strings survive only at trace/debug boundaries (`obs/`) and
//! at the `DeviceMem` get/put surface.

use std::collections::HashMap;

/// Dense handle for an interned key string. Only meaningful relative to
/// the [`KeyInterner`] (and thus the planning artifact) that minted it.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct KeyId(pub u32);

impl KeyId {
    /// Index into the interner's dense table.
    #[inline]
    pub fn index(self) -> usize {
        self.0 as usize
    }
}

/// String ↔ `KeyId` table. Interning is append-only: ids are dense,
/// starting at 0, in first-intern order (deterministic for a
/// deterministic build order, which keeps plans reproducible).
#[derive(Debug, Clone, Default)]
pub struct KeyInterner {
    strings: Vec<String>,
    index: HashMap<String, u32>,
}

impl KeyInterner {
    pub fn new() -> Self {
        Self::default()
    }

    /// Intern `key`, returning its dense id. Idempotent: the same string
    /// always maps to the same id within one interner.
    pub fn intern(&mut self, key: &str) -> KeyId {
        if let Some(&id) = self.index.get(key) {
            return KeyId(id);
        }
        let id = u32::try_from(self.strings.len()).expect("interner overflow");
        self.strings.push(key.to_string());
        self.index.insert(key.to_string(), id);
        KeyId(id)
    }

    /// Resolve an id back to its string. Pure array indexing: no hash,
    /// no allocation — safe in the zero-alloc dispatch loop.
    #[inline]
    pub fn resolve(&self, id: KeyId) -> &str {
        &self.strings[id.index()]
    }

    /// Look up an existing id without interning.
    pub fn lookup(&self, key: &str) -> Option<KeyId> {
        self.index.get(key).map(|&id| KeyId(id))
    }

    /// Number of distinct interned keys.
    pub fn len(&self) -> usize {
        self.strings.len()
    }

    pub fn is_empty(&self) -> bool {
        self.strings.is_empty()
    }

    /// Iterate `(id, key)` pairs in id order.
    pub fn iter(&self) -> impl Iterator<Item = (KeyId, &str)> {
        self.strings
            .iter()
            .enumerate()
            .map(|(i, s)| (KeyId(i as u32), s.as_str()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn intern_is_idempotent_and_dense() {
        let mut t = KeyInterner::new();
        let a = t.intern("L0.wq");
        let b = t.intern("grad.L0.wq");
        let a2 = t.intern("L0.wq");
        assert_eq!(a, a2);
        assert_ne!(a, b);
        assert_eq!(a.index(), 0);
        assert_eq!(b.index(), 1);
        assert_eq!(t.len(), 2);
        assert_eq!(t.resolve(a), "L0.wq");
        assert_eq!(t.resolve(b), "grad.L0.wq");
    }

    #[test]
    fn lookup_does_not_intern() {
        let mut t = KeyInterner::new();
        assert!(t.lookup("emb").is_none());
        let id = t.intern("emb");
        assert_eq!(t.lookup("emb"), Some(id));
        assert_eq!(t.len(), 1);
    }

    #[test]
    fn iter_walks_in_id_order() {
        let mut t = KeyInterner::new();
        let ids: Vec<KeyId> = ["emb", "gf", "wout"].iter().map(|k| t.intern(k)).collect();
        let walked: Vec<(KeyId, &str)> = t.iter().collect();
        assert_eq!(
            walked,
            vec![(ids[0], "emb"), (ids[1], "gf"), (ids[2], "wout")]
        );
    }
}
