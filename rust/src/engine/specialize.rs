//! Progressive per-rank graph specialization (DESIGN.md §7).
//!
//! The paper's answer to spatial heterogeneity is that every device ends
//! up with its *own* specialized execution logic — an MPMD program, not
//! one global schedule replayed for all devices at once. This pass lowers
//! an [`EngineStrategy`] + [`ShardLayout`] + pipeline schedule into
//! exactly that shape: one [`RankPlan`] (a device-local ordered timeline)
//! per mesh rank, whose **compute** tasks come from
//! [`crate::spec::schedule`] and whose **communication** — the p2p
//! activation/gradient hand-offs, the per-layer TP partial-sum syncs, the
//! token-weighted DP gradient reduction, and the ZeRO-1 slice exchange —
//! is materialized as explicit tasks with dependency edges.
//!
//! Contracts (property-swept in `rust/tests/specialize_sweep.rs`):
//!
//! * **Schedule reconstruction.** The union of all rank plans
//!   reconstructs the old global schedule exactly: restricting any stage
//!   device's timeline to that stage's [`FwdIn`](SpecTaskKind::FwdIn)/
//!   [`BwdIn`](SpecTaskKind::BwdIn) tasks yields precisely
//!   [`stage_schedule`](crate::spec::schedule::stage_schedule)'s task
//!   order, and the per-layer GEMM/sync tasks of each group tile the
//!   stage's layer range once.
//! * **Dependency preservation.** The cross-stage edges are the
//!   interpreter's ready conditions verbatim: `Fwd(m, s)` ⇐ `Fwd(m, s-1)`
//!   (via the hand-off task), `Bwd(m, s)` ⇐ `Bwd(m, s+1)`, and the last
//!   stage's backward ⇐ its own forward. Together with per-rank program
//!   order they admit exactly the same executions as the old global
//!   interpreter, so the event-driven executor
//!   ([`Engine::run_specialized`](super::Engine)) is numerically
//!   bit-identical to it.
//! * **Pull-model hand-offs.** A p2p hand-off task sits on the
//!   *consuming* stage's timelines (its `src` field names the producing
//!   devices) — the same pull semantics the interpreter used, which keeps
//!   1F1B free of send-side ordering deadlocks.
//!
//! Specialization runs once per `(strategy, micro-batch counts, zero1)`
//! and is cached on the engine; switches and micro-batch retuning
//! invalidate the cache (re-specialization is the per-switch cost the
//! `hotpath_micro` "specialize" row tracks). Because communication is
//! just tasks, the executor can inject a switch's per-sender delivery
//! batches into the first post-switch step's timelines — the §6.2
//! *measured* interleave (DESIGN.md §7.3).
//!
//! **Scale note (DESIGN.md §11).** Task and dependency structures here
//! are purely coordinate-based — `(pipe, stage, mb, layer)` integers and
//! task-index edges, no tensor-key strings. The string↔id boundary sits
//! one layer down: [`ShardLayout`] interns its keys as
//! [`KeyId`](super::intern::KeyId)s at build time, and the compile pass
//! freezes its own interned ids into the tape. That keeps specialization
//! of generated 1024-rank strategies free of per-task string work.

use std::collections::{BTreeMap, BTreeSet};

use crate::spec::schedule::{full_schedule, ScheduleKind, TaskKind};
use crate::{Error, Result};

use super::layout::ShardLayout;
use super::EngineStrategy;

/// What one specialized task does. Compute kinds carry the schedule
/// coordinates they were lowered from; comm kinds are the §6.2 comm-task
/// taxonomy (DESIGN.md §7.2).
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum SpecTaskKind {
    /// Forward stage input: stage 0 embeds the micro-batch on its root,
    /// later stages receive the p2p activation hand-off from the previous
    /// stage's root (freeing the producer's copies); both broadcast the
    /// activation over the TP group.
    FwdIn {
        /// Pipeline.
        pipe: usize,
        /// Stage.
        stage: usize,
        /// Micro-batch.
        mb: usize,
    },
    /// One layer's forward GEMMs — TP members run concurrently (the
    /// block input is saved for recompute-in-backward first).
    FwdGemm {
        /// Pipeline.
        pipe: usize,
        /// Stage.
        stage: usize,
        /// Micro-batch.
        mb: usize,
        /// Layer.
        layer: u32,
    },
    /// TP sync of one forward layer: partial-sum all-reduce over the TP
    /// group + residual add.
    FwdTpSync {
        /// Pipeline.
        pipe: usize,
        /// Stage.
        stage: usize,
        /// Micro-batch.
        mb: usize,
        /// Layer.
        layer: u32,
    },
    /// Backward stage input: the last stage runs the fused head (loss +
    /// token-scaled head gradients, freeing the stage activation),
    /// earlier stages receive the p2p gradient hand-off; both broadcast
    /// the incoming gradient over the TP group.
    BwdIn {
        /// Pipeline.
        pipe: usize,
        /// Stage.
        stage: usize,
        /// Micro-batch.
        mb: usize,
    },
    /// One layer's backward GEMMs + parameter-gradient accumulation (the
    /// saved block input is consumed and freed).
    BwdGemm {
        /// Pipeline.
        pipe: usize,
        /// Stage.
        stage: usize,
        /// Micro-batch.
        mb: usize,
        /// Layer.
        layer: u32,
    },
    /// TP sync of one backward layer: dx-partial all-reduce + add.
    BwdTpSync {
        /// Pipeline.
        pipe: usize,
        /// Stage.
        stage: usize,
        /// Micro-batch.
        mb: usize,
        /// Layer.
        layer: u32,
    },
    /// Stage-0 backward epilogue: embedding gradient + dact free.
    EmbedBwd {
        /// Pipeline.
        pipe: usize,
        /// Micro-batch.
        mb: usize,
    },
    /// Token-weighted DP gradient reduction — the [`ShardLayout`]'s
    /// cached slice-grid plan plus the embedding/head reductions and the
    /// `1/total_tokens` scaling.
    GradReduce,
    /// Optimizer application on every device's local shards (ZeRO-1
    /// partition owners update only their slice).
    OptimStep,
    /// ZeRO-1 updated-parameter slice exchange after the optimizer (only
    /// present when the engine shards optimizer states).
    ZeroExchange,
}

impl SpecTaskKind {
    /// True for communication tasks (the §6.2 taxonomy); compute kinds
    /// return false. `FwdIn`/`BwdIn` count as comm: the stage-0 embed and
    /// last-stage head calls are folded into the hand-off slot and
    /// charged serially, exactly as the old interpreter accounted them.
    pub fn is_comm(&self) -> bool {
        !matches!(
            self,
            SpecTaskKind::FwdGemm { .. }
                | SpecTaskKind::BwdGemm { .. }
                | SpecTaskKind::EmbedBwd { .. }
                | SpecTaskKind::OptimStep
        )
    }

    /// The `(pipe, stage, mb)` coordinates of a per-group task, `None`
    /// for the global step phases.
    pub fn group(&self) -> Option<(usize, usize, usize)> {
        match *self {
            SpecTaskKind::FwdIn { pipe, stage, mb }
            | SpecTaskKind::FwdGemm { pipe, stage, mb, .. }
            | SpecTaskKind::FwdTpSync { pipe, stage, mb, .. }
            | SpecTaskKind::BwdIn { pipe, stage, mb }
            | SpecTaskKind::BwdGemm { pipe, stage, mb, .. }
            | SpecTaskKind::BwdTpSync { pipe, stage, mb, .. } => Some((pipe, stage, mb)),
            SpecTaskKind::EmbedBwd { pipe, mb } => Some((pipe, 0, mb)),
            _ => None,
        }
    }
}

/// One specialized task: what it does, the ranks whose timelines carry
/// it, the sending endpoints of a p2p hand-off, and its dependency edges.
#[derive(Clone, Debug)]
pub struct SpecTask {
    /// The task.
    pub kind: SpecTaskKind,
    /// Mesh ranks executing the task (TP-group order). For p2p hand-offs
    /// these are the *consuming* stage's devices — the pull model; the
    /// producing endpoints are in `src`.
    pub ranks: Vec<usize>,
    /// Sending endpoints of a p2p hand-off (the adjacent stage's
    /// devices); empty for intra-stage comm and compute tasks.
    pub src: Vec<usize>,
    /// Task indices (into [`SpecializedPlan::tasks`]) that must complete
    /// before this one starts, in addition to per-rank program order.
    pub deps: Vec<usize>,
}

/// A device-local timeline: the ordered task indices one mesh rank
/// executes — its *specialized program*.
#[derive(Clone, Debug)]
pub struct RankPlan {
    /// Mesh rank.
    pub rank: usize,
    /// Ordered indices into the owning [`SpecializedPlan::tasks`].
    pub tasks: Vec<usize>,
}

/// One specialized step: the task table, the per-rank timelines, and the
/// bookkeeping the executor needs to reproduce the old interpreter's
/// accumulation order bit-for-bit.
#[derive(Clone, Debug)]
pub struct SpecializedPlan {
    /// Every task of the step (compute + comm).
    pub tasks: Vec<SpecTask>,
    /// Device-local timelines, ascending by mesh rank.
    pub ranks: Vec<RankPlan>,
    /// Per pipeline: micro-batch indices in the order the last stage's
    /// schedule retires backward tasks — the loss-accumulation order of
    /// the pre-specialization interpreter (keeps the f64 loss sum
    /// bit-identical).
    pub head_order: Vec<Vec<usize>>,
    /// Schedule the compute tasks were lowered from.
    pub schedule: ScheduleKind,
    /// Per-pipeline micro-batch counts at specialization time; the plan
    /// is rebuilt when these change (`Engine::set_microbatches`).
    pub num_microbatches: Vec<usize>,
}

impl SpecializedPlan {
    /// Total tasks.
    pub fn len(&self) -> usize {
        self.tasks.len()
    }

    /// True when the plan has no tasks (never: every strategy has at
    /// least the global phases).
    pub fn is_empty(&self) -> bool {
        self.tasks.is_empty()
    }

    /// Communication tasks in the plan (the §6.2 taxonomy entries).
    pub fn num_comm_tasks(&self) -> usize {
        self.tasks.iter().filter(|t| t.kind.is_comm()).count()
    }

    /// Position of `rank`'s timeline in [`SpecializedPlan::ranks`].
    pub fn rank_index(&self, rank: usize) -> Option<usize> {
        self.ranks.binary_search_by_key(&rank, |rp| rp.rank).ok()
    }

    /// Derive the plan's p2p **hand-off edges** — the channel topology of
    /// the threaded executor ([`super::thread`]). Every pull-model
    /// boundary task (a [`FwdIn`](SpecTaskKind::FwdIn)/
    /// [`BwdIn`](SpecTaskKind::BwdIn) with non-empty `src`) has exactly
    /// one dependency: the producing stage's tail task on exactly the
    /// `src` devices. That invariant is what lets the executor relocate
    /// the transfer to the *sending* side (a typed message fired as a
    /// post-action of the producer tail) without changing semantics;
    /// violations are structural specializer bugs and surface as typed
    /// errors.
    pub fn handoff_edges(&self) -> Result<Vec<HandoffEdge>> {
        let mut edges = vec![];
        for (ti, t) in self.tasks.iter().enumerate() {
            if t.src.is_empty() {
                continue;
            }
            if !matches!(t.kind, SpecTaskKind::FwdIn { .. } | SpecTaskKind::BwdIn { .. }) {
                return Err(Error::Engine(format!(
                    "handoff_edges: task {ti} ({:?}) has producers but is not a \
                     boundary task",
                    t.kind
                )));
            }
            let &[tail] = &t.deps[..] else {
                return Err(Error::Engine(format!(
                    "handoff_edges: boundary task {ti} has {} deps (want exactly the \
                     producer tail)",
                    t.deps.len()
                )));
            };
            if self.tasks[tail].ranks != t.src {
                return Err(Error::Engine(format!(
                    "handoff_edges: task {ti}'s dep {tail} runs on {:?} but its \
                     producers are {:?}",
                    self.tasks[tail].ranks, t.src
                )));
            }
            edges.push(HandoffEdge {
                task: ti,
                producer_tail: tail,
                producers: t.src.clone(),
                consumer_root: t.ranks[0],
            });
        }
        Ok(edges)
    }
}

/// One p2p boundary transfer of the plan, sender-side view: after
/// `producer_tail` completes, `producers[0]` sends the boundary tensor to
/// `consumer_root` (the consuming `task`'s root), and the remaining
/// producers free their dead copies. Derived, not stored — the edges are
/// a reading of [`SpecTask::src`]/[`SpecTask::deps`].
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct HandoffEdge {
    /// The consuming boundary task (`FwdIn`/`BwdIn`).
    pub task: usize,
    /// The producing stage's tail task (the edge's dependency).
    pub producer_tail: usize,
    /// Producing stage devices (TP-group order; `[0]` is the sender).
    pub producers: Vec<usize>,
    /// Consuming stage's root rank (the receiver).
    pub consumer_root: usize,
}

/// Append a task, threading it onto every participating rank's timeline.
fn push_task(
    tasks: &mut Vec<SpecTask>,
    rank_tasks: &mut BTreeMap<usize, Vec<usize>>,
    kind: SpecTaskKind,
    ranks: Vec<usize>,
    src: Vec<usize>,
    deps: Vec<usize>,
) -> usize {
    let idx = tasks.len();
    for &r in &ranks {
        rank_tasks.get_mut(&r).expect("specialize: rank registered").push(idx);
    }
    tasks.push(SpecTask { kind, ranks, src, deps });
    idx
}

/// Lower a strategy (+ its layout) into per-rank timelines.
///
/// Fails when a device appears in more than one stage: specialization is
/// *per rank* — a rank owns exactly one device-local program, so a device
/// shared between stages has no well-defined timeline. (The old global
/// interpreter tolerated sharing by construction; no lowered or
/// hand-built strategy in the tree uses it.)
pub fn specialize(
    strategy: &EngineStrategy,
    layout: &ShardLayout,
    zero1: bool,
) -> Result<SpecializedPlan> {
    let mut seen: BTreeSet<usize> = BTreeSet::new();
    for p in &strategy.pipelines {
        for s in &p.stages {
            for &d in &s.devices {
                if !seen.insert(d) {
                    return Err(Error::Engine(format!(
                        "specialize: device {d} appears in more than one stage; \
                         per-rank timelines need device-disjoint stages"
                    )));
                }
            }
        }
    }
    // The layout must describe this strategy (it carries the sync plan
    // GradReduce executes): cheap root cross-check.
    let roots: Vec<usize> =
        strategy.pipelines.iter().map(|p| p.stages[0].devices[0]).collect();
    if layout.first_roots != roots {
        return Err(Error::Engine(
            "specialize: layout does not match the strategy (stage-0 roots differ)".into(),
        ));
    }

    let mut tasks: Vec<SpecTask> = vec![];
    let mut rank_tasks: BTreeMap<usize, Vec<usize>> =
        seen.iter().map(|&d| (d, vec![])).collect();
    let mut head_order: Vec<Vec<usize>> = Vec::with_capacity(strategy.pipelines.len());
    let mut step_deps: Vec<usize> = vec![];

    for (pi, pipe) in strategy.pipelines.iter().enumerate() {
        let s_count = pipe.stages.len();
        let m = pipe.num_microbatches;
        let sched = full_schedule(strategy.schedule, s_count, m);
        head_order.push(sched.bwd_retirement_order(s_count - 1));

        // Pass 1: allocate every (stage, mb, direction) group's tasks in
        // per-stage queue order — which *is* each rank's program order —
        // chaining intra-group dependencies as they are created.
        let mut fwd_head = vec![vec![usize::MAX; m]; s_count];
        let mut fwd_tail = vec![vec![usize::MAX; m]; s_count];
        let mut bwd_head = vec![vec![usize::MAX; m]; s_count];
        let mut bwd_tail = vec![vec![usize::MAX; m]; s_count];
        for (si, stage_tasks) in sched.tasks.iter().enumerate() {
            let stage = &pipe.stages[si];
            for t in stage_tasks {
                let mb = t.microbatch;
                match t.kind {
                    TaskKind::Fwd => {
                        let src = if si > 0 {
                            pipe.stages[si - 1].devices.clone()
                        } else {
                            vec![]
                        };
                        let mut prev = push_task(
                            &mut tasks,
                            &mut rank_tasks,
                            SpecTaskKind::FwdIn { pipe: pi, stage: si, mb },
                            stage.devices.clone(),
                            src,
                            vec![],
                        );
                        fwd_head[si][mb] = prev;
                        for l in stage.layers.0..stage.layers.1 {
                            prev = push_task(
                                &mut tasks,
                                &mut rank_tasks,
                                SpecTaskKind::FwdGemm { pipe: pi, stage: si, mb, layer: l },
                                stage.devices.clone(),
                                vec![],
                                vec![prev],
                            );
                            prev = push_task(
                                &mut tasks,
                                &mut rank_tasks,
                                SpecTaskKind::FwdTpSync { pipe: pi, stage: si, mb, layer: l },
                                stage.devices.clone(),
                                vec![],
                                vec![prev],
                            );
                        }
                        fwd_tail[si][mb] = prev;
                    }
                    TaskKind::Bwd => {
                        let src = if si + 1 < s_count {
                            pipe.stages[si + 1].devices.clone()
                        } else {
                            vec![]
                        };
                        let mut prev = push_task(
                            &mut tasks,
                            &mut rank_tasks,
                            SpecTaskKind::BwdIn { pipe: pi, stage: si, mb },
                            stage.devices.clone(),
                            src,
                            vec![],
                        );
                        bwd_head[si][mb] = prev;
                        for l in (stage.layers.0..stage.layers.1).rev() {
                            prev = push_task(
                                &mut tasks,
                                &mut rank_tasks,
                                SpecTaskKind::BwdGemm { pipe: pi, stage: si, mb, layer: l },
                                stage.devices.clone(),
                                vec![],
                                vec![prev],
                            );
                            prev = push_task(
                                &mut tasks,
                                &mut rank_tasks,
                                SpecTaskKind::BwdTpSync { pipe: pi, stage: si, mb, layer: l },
                                stage.devices.clone(),
                                vec![],
                                vec![prev],
                            );
                        }
                        if si == 0 {
                            prev = push_task(
                                &mut tasks,
                                &mut rank_tasks,
                                SpecTaskKind::EmbedBwd { pipe: pi, mb },
                                stage.devices.clone(),
                                vec![],
                                vec![prev],
                            );
                        }
                        bwd_tail[si][mb] = prev;
                    }
                }
            }
        }

        // Pass 2: the cross-stage edges — the interpreter's ready
        // conditions verbatim.
        for si in 0..s_count {
            for mb in 0..m {
                if si > 0 {
                    let h = fwd_head[si][mb];
                    tasks[h].deps.push(fwd_tail[si - 1][mb]);
                }
                let h = bwd_head[si][mb];
                let d = if si + 1 == s_count {
                    fwd_tail[si][mb]
                } else {
                    bwd_tail[si + 1][mb]
                };
                tasks[h].deps.push(d);
                step_deps.push(bwd_tail[si][mb]);
            }
        }
    }

    // The global step phases, appended to every rank's timeline; the
    // explicit edges (not just rank order) encode the phase barrier.
    let all_ranks: Vec<usize> = rank_tasks.keys().copied().collect();
    let gr = push_task(
        &mut tasks,
        &mut rank_tasks,
        SpecTaskKind::GradReduce,
        all_ranks.clone(),
        vec![],
        step_deps,
    );
    let opt = push_task(
        &mut tasks,
        &mut rank_tasks,
        SpecTaskKind::OptimStep,
        all_ranks.clone(),
        vec![],
        vec![gr],
    );
    if zero1 {
        push_task(
            &mut tasks,
            &mut rank_tasks,
            SpecTaskKind::ZeroExchange,
            all_ranks,
            vec![],
            vec![opt],
        );
    }

    let ranks: Vec<RankPlan> = rank_tasks
        .into_iter()
        .map(|(rank, tasks)| RankPlan { rank, tasks })
        .collect();
    Ok(SpecializedPlan {
        tasks,
        ranks,
        head_order,
        schedule: strategy.schedule,
        num_microbatches: strategy.pipelines.iter().map(|p| p.num_microbatches).collect(),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::native;
    use crate::spec::schedule::{stage_schedule, Task};

    fn plan_for(strategy: &EngineStrategy, zero1: bool) -> SpecializedPlan {
        let cfg = native::tiny_config();
        let layout = ShardLayout::build(&cfg, strategy).unwrap();
        specialize(strategy, &layout, zero1).unwrap()
    }

    #[test]
    fn rank_timelines_replay_the_stage_schedule() {
        let s = EngineStrategy::uniform("pp2", 1, 1, 2, 8, 3)
            .with_schedule(ScheduleKind::OneFOneB);
        let plan = plan_for(&s, false);
        assert_eq!(plan.ranks.len(), 2);
        assert_eq!(plan.num_microbatches, vec![3]);
        // restricting a stage device's timeline to its FwdIn/BwdIn tasks
        // reconstructs exactly the stage's schedule
        for (si, rp) in plan.ranks.iter().enumerate() {
            let got: Vec<Task> = rp
                .tasks
                .iter()
                .filter_map(|&ti| match plan.tasks[ti].kind {
                    SpecTaskKind::FwdIn { stage, mb, .. } if stage == si => {
                        Some(Task { kind: TaskKind::Fwd, microbatch: mb })
                    }
                    SpecTaskKind::BwdIn { stage, mb, .. } if stage == si => {
                        Some(Task { kind: TaskKind::Bwd, microbatch: mb })
                    }
                    _ => None,
                })
                .collect();
            assert_eq!(got, stage_schedule(ScheduleKind::OneFOneB, 2, si, 3), "stage {si}");
        }
        // global phases close every timeline (no ZeroExchange here)
        for rp in &plan.ranks {
            let n = rp.tasks.len();
            assert!(matches!(plan.tasks[rp.tasks[n - 1]].kind, SpecTaskKind::OptimStep));
            assert!(matches!(plan.tasks[rp.tasks[n - 2]].kind, SpecTaskKind::GradReduce));
        }
        assert!(plan.num_comm_tasks() > 0);
    }

    #[test]
    fn cross_stage_edges_mirror_interpreter_ready_rules() {
        let s = EngineStrategy::uniform("pp2", 1, 1, 2, 8, 2);
        let plan = plan_for(&s, false);
        for (ti, t) in plan.tasks.iter().enumerate() {
            match t.kind {
                SpecTaskKind::FwdIn { stage, mb, .. } => {
                    if stage == 0 {
                        assert!(t.deps.is_empty(), "stage-0 fwd input has no deps");
                        assert!(t.src.is_empty());
                    } else {
                        assert_eq!(t.deps.len(), 1, "task {ti}");
                        // the dep is the producing stage's last fwd task
                        match plan.tasks[t.deps[0]].kind {
                            SpecTaskKind::FwdTpSync { stage: ps, mb: pm, .. } => {
                                assert_eq!((ps, pm), (stage - 1, mb));
                            }
                            ref k => panic!("fwd hand-off depends on {k:?}"),
                        }
                        assert!(!t.src.is_empty(), "hand-off names its producers");
                    }
                }
                SpecTaskKind::BwdIn { stage, mb, .. } => {
                    assert_eq!(t.deps.len(), 1);
                    match plan.tasks[t.deps[0]].kind {
                        // last stage: its own forward; earlier: the next
                        // stage's backward tail
                        SpecTaskKind::FwdTpSync { stage: ps, mb: pm, .. } => {
                            assert_eq!((ps, pm), (stage, mb));
                            assert_eq!(stage, 1, "only the last stage starts from its fwd");
                        }
                        SpecTaskKind::EmbedBwd { .. } => {
                            panic!("bwd hand-off cannot depend on stage-0 epilogue")
                        }
                        SpecTaskKind::BwdTpSync { stage: ps, mb: pm, .. } => {
                            assert_eq!((ps, pm), (stage + 1, mb));
                        }
                        ref k => panic!("bwd hand-off depends on {k:?}"),
                    }
                }
                _ => {}
            }
        }
    }

    #[test]
    fn gemm_tasks_tile_each_stage_layer_range_once() {
        let s = EngineStrategy::uniform("dp2pp2", 2, 1, 2, 8, 2);
        let plan = plan_for(&s, true);
        // ZeRO-1 plans end with the slice exchange
        let last = plan.tasks.last().unwrap();
        assert!(matches!(last.kind, SpecTaskKind::ZeroExchange));
        let mut fwd_layers: BTreeMap<(usize, usize, usize), Vec<u32>> = BTreeMap::new();
        for t in &plan.tasks {
            if let SpecTaskKind::FwdGemm { pipe, stage, mb, layer } = t.kind {
                fwd_layers.entry((pipe, stage, mb)).or_default().push(layer);
            }
        }
        for ((pipe, stage, _mb), layers) in fwd_layers {
            let (lo, hi) = s.pipelines[pipe].stages[stage].layers;
            assert_eq!(layers, (lo..hi).collect::<Vec<u32>>());
        }
    }

    #[test]
    fn shared_devices_are_rejected() {
        use crate::engine::{EnginePipeline, EngineStage};
        let cfg = native::tiny_config();
        let shared = EngineStrategy {
            name: "shared".into(),
            pipelines: vec![
                EnginePipeline {
                    stages: vec![EngineStage { devices: vec![0], layers: (0, 8) }],
                    num_microbatches: 1,
                },
                EnginePipeline {
                    stages: vec![EngineStage { devices: vec![0], layers: (0, 8) }],
                    num_microbatches: 1,
                },
            ],
            schedule: ScheduleKind::GPipe,
        };
        let layout = ShardLayout::build(&cfg, &shared).unwrap();
        assert!(specialize(&shared, &layout, false).is_err());
    }

    #[test]
    fn handoff_edges_cover_every_stage_boundary_crossing() {
        let s = EngineStrategy::uniform("dp2tp2pp2", 2, 2, 2, 8, 3);
        let plan = plan_for(&s, false);
        let edges = plan.handoff_edges().unwrap();
        // per pipeline: one fwd + one bwd crossing per micro-batch over
        // the single stage boundary
        assert_eq!(edges.len(), 2 * 2 * 3);
        for e in &edges {
            let t = &plan.tasks[e.task];
            assert_eq!(t.src, e.producers);
            assert_eq!(t.ranks[0], e.consumer_root);
            assert_eq!(t.deps, vec![e.producer_tail]);
            assert_eq!(plan.tasks[e.producer_tail].ranks, e.producers);
            // producers and consumers are disjoint (device-disjoint stages)
            assert!(!e.producers.contains(&e.consumer_root));
        }
    }

    #[test]
    fn head_order_is_the_last_stage_bwd_retirement_order() {
        // GPipe retires backwards m-1..0; 1F1B retires FIFO
        let g = plan_for(&EngineStrategy::uniform("pp2", 1, 1, 2, 8, 3), false);
        assert_eq!(g.head_order, vec![vec![2, 1, 0]]);
        let f = plan_for(
            &EngineStrategy::uniform("pp2", 1, 1, 2, 8, 3)
                .with_schedule(ScheduleKind::OneFOneB),
            false,
        );
        assert_eq!(f.head_order, vec![vec![0, 1, 2]]);
    }
}
