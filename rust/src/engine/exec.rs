//! The forward/backward interpreter: per-microbatch pipeline execution,
//! layout-driven parameter init, gradient synchronization, and optimizer
//! application.
//!
//! Execution contract with the model artifacts (PJRT or native — see
//! `python/compile/model.py` and [`crate::runtime::native`]):
//!
//! * block forward returns a *partial* output; the engine all-reduces over
//!   the TP group and adds the residual;
//! * block backward returns `(dx_partial, dparams_shard)`; the engine
//!   computes `dx = dy + AllReduce(dx_partial)`;
//! * gradient sync runs the [`ShardLayout`]'s cached slice-grid plan: one
//!   reduction per shared atomic slice (replicated gains reduce raw
//!   per-device partials across all holders in a single pass), then the
//!   embedding/head reductions across pipeline roots, then `1/total_mb`
//!   scaling over the layout's cached gradient-key list — nothing is
//!   re-derived or scanned per step.

use crate::collectives::{extract_region, DeviceMem, Mesh};
use crate::runtime::{HostTensor, Runtime};
use crate::testutil::Rng;
use crate::Result;

use super::layout::{full_shape, gkey, pkey, ShardLayout, SyncOp};
use super::{Engine, EnginePipeline, MicroBatch, BLOCK_PARAMS};

/// Deterministic parameter init: full tensors are generated from a
/// per-tensor seed and region-sliced identically for every replica, so
/// every strategy (including hetero-TP) starts from the same global
/// parameters as the single-device oracle.
pub(crate) fn init_params(
    runtime: &Runtime,
    layout: &ShardLayout,
    mesh: &mut Mesh,
    seed: u64,
) -> Result<()> {
    let cfg = runtime.config;
    let h = cfg.hidden;
    for ((l, pidx), hs) in layout.iter_holdings() {
        let name = BLOCK_PARAMS[*pidx];
        let shape: Vec<usize> =
            full_shape(&cfg, name).iter().map(|&n| n as usize).collect();
        let full = init_tensor(seed, *l, name, &shape, h);
        for holding in hs {
            let piece = extract_region(&full, &holding.region)?;
            mesh.devices[holding.dev].put(&pkey(*l, name), piece);
        }
    }
    let v = cfg.vocab;
    for (&fr, &lr) in layout.first_roots.iter().zip(layout.last_roots.iter()) {
        let emb = init_tensor(seed, 10_000, "emb", &[v, h], h);
        mesh.devices[fr].put("emb", emb);
        let gf = HostTensor::f32(vec![h], vec![1.0; h])?;
        let wout = init_tensor(seed, 10_001, "wout", &[h, v], h);
        mesh.devices[lr].put("gf", gf);
        mesh.devices[lr].put("wout", wout);
    }
    Ok(())
}

impl Engine {
    /// One micro-batch through one pipeline (GPipe order inside the
    /// deterministic interpreter: fwd all stages, then bwd reversed).
    pub(crate) fn forward_backward(
        &mut self,
        pipe: &EnginePipeline,
        mb: usize,
        batch: &MicroBatch,
    ) -> Result<f32> {
        let cfg = self.runtime.config;
        let (b, s) = (cfg.batch, cfg.seq);
        let tok = HostTensor::i32(vec![b, s], batch.tokens.clone())?;
        let tgt = HostTensor::i32(vec![b, s], batch.targets.clone())?;

        // ---- forward
        let first = &pipe.stages[0];
        let root0 = first.devices[0];
        let x0 = {
            let emb = self.mesh.devices[root0].get("emb")?;
            let out = self.runtime.call_refs("embed_fwd", &[emb, &tok])?;
            out.into_iter().next().unwrap()
        };
        self.mesh.devices[root0].put("act", x0);
        self.mesh.broadcast(root0, &first.devices, "act")?;

        for (si, stage) in pipe.stages.iter().enumerate() {
            if si > 0 {
                let prev_root = pipe.stages[si - 1].devices[0];
                self.mesh.send(prev_root, stage.devices[0], "act")?;
                self.mesh.broadcast(stage.devices[0], &stage.devices, "act")?;
            }
            let tp = stage.tp();
            let art = format!("block_fwd_tp{tp}");
            for l in stage.layers.0..stage.layers.1 {
                // save block input for recompute-in-backward
                for &d in &stage.devices {
                    let x = self.mesh.devices[d].get("act")?.clone();
                    self.mesh.devices[d].put(&format!("save.mb{mb}.L{l}"), x);
                }
                for &d in &stage.devices {
                    let dev = &self.mesh.devices[d];
                    let mut inputs: Vec<&HostTensor> = Vec::with_capacity(9);
                    for p in BLOCK_PARAMS {
                        inputs.push(dev.get(&pkey(l, p))?);
                    }
                    inputs.push(dev.get("act")?);
                    let y_part =
                        self.runtime.call_refs(&art, &inputs)?.into_iter().next().unwrap();
                    self.mesh.devices[d].put("part", y_part);
                }
                self.mesh.all_reduce(&stage.devices, "part")?;
                for &d in &stage.devices {
                    let part = self.mesh.devices[d].get("part")?.clone();
                    let x = self.mesh.devices[d].get_mut("act")?;
                    x.add_assign(&part)?;
                }
            }
        }

        // ---- head: loss + all gradients in one fused artifact call
        let last_stage = pipe.stages.last().unwrap();
        let last_root = last_stage.devices[0];
        let (loss, dx) = {
            let dev = &self.mesh.devices[last_root];
            let out = self.runtime.call_refs(
                "head_step",
                &[dev.get("gf")?, dev.get("wout")?, dev.get("act")?, &tgt],
            )?;
            let mut it = out.into_iter();
            let loss = it.next().unwrap();
            let dx = it.next().unwrap();
            accumulate(&mut self.mesh.devices[last_root], "grad.gf", it.next().unwrap())?;
            accumulate(&mut self.mesh.devices[last_root], "grad.wout", it.next().unwrap())?;
            (loss.as_f32()?[0], dx)
        };
        self.mesh.devices[last_root].put("dact", dx);
        self.mesh.broadcast(last_root, &last_stage.devices, "dact")?;

        // ---- backward
        for (si, stage) in pipe.stages.iter().enumerate().rev() {
            let tp = stage.tp();
            let art = format!("block_bwd_tp{tp}");
            for l in (stage.layers.0..stage.layers.1).rev() {
                for &d in &stage.devices {
                    let dev = &self.mesh.devices[d];
                    let mut inputs: Vec<&HostTensor> = Vec::with_capacity(10);
                    for p in BLOCK_PARAMS {
                        inputs.push(dev.get(&pkey(l, p))?);
                    }
                    inputs.push(dev.get(&format!("save.mb{mb}.L{l}"))?);
                    inputs.push(dev.get("dact")?);
                    let outs = self.runtime.call_refs(&art, &inputs)?;
                    let mut it = outs.into_iter();
                    let dx_part = it.next().unwrap();
                    self.mesh.devices[d].put("dpart", dx_part);
                    for p in BLOCK_PARAMS {
                        accumulate(&mut self.mesh.devices[d], &gkey(l, p), it.next().unwrap())?;
                    }
                    // free the saved activation
                    let _ = self.mesh.devices[d].take(&format!("save.mb{mb}.L{l}"));
                }
                self.mesh.all_reduce(&stage.devices, "dpart")?;
                for &d in &stage.devices {
                    let dpart = self.mesh.devices[d].get("dpart")?.clone();
                    let dx = self.mesh.devices[d].get_mut("dact")?;
                    dx.add_assign(&dpart)?;
                }
            }
            if si > 0 {
                let prev = &pipe.stages[si - 1];
                self.mesh.send(stage.devices[0], prev.devices[0], "dact")?;
                self.mesh.broadcast(prev.devices[0], &prev.devices, "dact")?;
            }
        }

        // ---- embedding gradient
        let root0 = pipe.stages[0].devices[0];
        let dx0 = self.mesh.devices[root0].get("dact")?;
        let demb = self.runtime.call_refs("embed_bwd", &[&tok, dx0])?.into_iter().next().unwrap();
        accumulate(&mut self.mesh.devices[root0], "grad.emb", demb)?;

        Ok(loss)
    }

    /// Gradient synchronization from the cached [`ShardLayout`] plan, then
    /// embedding/head reductions across pipeline roots, then `1/total_mb`
    /// scaling over the cached gradient-key list.
    pub(crate) fn sync_gradients(&mut self, total_mb: usize) -> Result<()> {
        for op in &self.layout.sync_ops {
            match op {
                SyncOp::AllReduce { key, devs } => self.mesh.all_reduce(devs, key)?,
                SyncOp::SliceReduce { key, parts } => {
                    self.mesh.all_reduce_region(parts, key)?
                }
            }
        }
        self.mesh.all_reduce(&self.layout.first_roots, "grad.emb")?;
        self.mesh.all_reduce(&self.layout.last_roots, "grad.gf")?;
        self.mesh.all_reduce(&self.layout.last_roots, "grad.wout")?;

        let scale = 1.0 / total_mb as f32;
        for (dev, key) in &self.layout.grad_keys {
            self.mesh.devices[*dev].get_mut(key)?.scale(scale)?;
        }
        Ok(())
    }

    /// AdamW over the layout's cached `(device, param, grad)` list;
    /// gradients are consumed.
    pub(crate) fn apply_updates(&mut self) -> Result<()> {
        let step = self.step + 1;
        for (dev, param_key, grad_key) in &self.layout.update_ops {
            self.opt.update(&mut self.mesh.devices[*dev], param_key, grad_key, step)?;
        }
        Ok(())
    }
}

/// Accumulate (or initialize) a gradient buffer.
pub(crate) fn accumulate(dev: &mut DeviceMem, key: &str, t: HostTensor) -> Result<()> {
    if dev.has(key) {
        dev.get_mut(key)?.add_assign(&t)
    } else {
        dev.put(key, t);
        Ok(())
    }
}

/// Deterministic N(0, 0.02) init for a named tensor (gains = 1).
pub(crate) fn init_tensor(
    seed: u64,
    layer: u32,
    name: &str,
    shape: &[usize],
    _hidden: usize,
) -> HostTensor {
    let n: usize = shape.iter().product();
    if name.starts_with('g') {
        return HostTensor::f32(shape.to_vec(), vec![1.0; n]).unwrap();
    }
    let tag: u64 = name.bytes().fold(0u64, |a, b| a.wrapping_mul(131).wrapping_add(b as u64));
    let mut rng = Rng::new(seed ^ (layer as u64) << 32 ^ tag);
    let mut data = Vec::with_capacity(n);
    // Box–Muller
    while data.len() < n {
        let u1 = rng.f64().max(1e-12);
        let u2 = rng.f64();
        let r = (-2.0 * u1.ln()).sqrt();
        let th = 2.0 * std::f64::consts::PI * u2;
        data.push((r * th.cos() * 0.02) as f32);
        if data.len() < n {
            data.push((r * th.sin() * 0.02) as f32);
        }
    }
    HostTensor::f32(shape.to_vec(), data).unwrap()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn init_is_deterministic_and_scaled() {
        let a = init_tensor(7, 3, "wq", &[32, 32], 32);
        let b = init_tensor(7, 3, "wq", &[32, 32], 32);
        assert_eq!(a, b);
        let c = init_tensor(7, 4, "wq", &[32, 32], 32);
        assert_ne!(a, c);
        let mean: f32 = a.as_f32().unwrap().iter().sum::<f32>() / 1024.0;
        assert!(mean.abs() < 0.01);
        let g = init_tensor(7, 0, "g1", &[8], 8);
        assert_eq!(g.as_f32().unwrap(), &[1.0; 8]);
    }

    #[test]
    fn region_slicing_tiles_full_tensor() {
        use super::super::layout::{shard_region, SplitAxis};
        let full = HostTensor::f32(vec![4, 6], (0..24).map(|x| x as f32).collect()).unwrap();
        let c0 = extract_region(&full, &shard_region(&[4, 6], SplitAxis::Col, 2, 0)).unwrap();
        let c1 = extract_region(&full, &shard_region(&[4, 6], SplitAxis::Col, 2, 1)).unwrap();
        assert_eq!(c0.shape, vec![4, 3]);
        assert_eq!(c0.as_f32().unwrap()[..3], [0.0, 1.0, 2.0]);
        assert_eq!(c1.as_f32().unwrap()[..3], [3.0, 4.0, 5.0]);
        let r1 = extract_region(&full, &shard_region(&[4, 6], SplitAxis::Row, 2, 1)).unwrap();
        assert_eq!(r1.shape, vec![2, 6]);
        assert_eq!(r1.as_f32().unwrap()[0], 12.0);
        let rep = extract_region(&full, &shard_region(&[4, 6], SplitAxis::Replicated, 2, 1))
            .unwrap();
        assert_eq!(rep, full);
    }

    #[test]
    fn accumulate_initializes_then_adds() {
        let mut dev = DeviceMem::default();
        let t = HostTensor::f32(vec![2], vec![1.0, 2.0]).unwrap();
        accumulate(&mut dev, "g", t.clone()).unwrap();
        accumulate(&mut dev, "g", t).unwrap();
        assert_eq!(dev.get("g").unwrap().as_f32().unwrap(), &[2.0, 4.0]);
    }
}
