//! Execution: the **event-driven per-rank executor** over specialized
//! timelines ([`Engine::run_specialized`], DESIGN.md §7) plus the legacy
//! global interpreter kept as the differential numerics oracle
//! ([`Engine::run_pipeline`] / `Engine::train_step_reference`).
//!
//! Execution contract with the model artifacts (PJRT or native — see
//! `python/compile/model.py` and [`crate::runtime::native`]):
//!
//! * block forward returns a *partial* output; the engine all-reduces over
//!   the TP group and adds the residual;
//! * block backward returns `(dx_partial, dparams_shard)`; the engine
//!   computes `dx = dy + AllReduce(dx_partial)`;
//! * compute-task orders come from
//!   [`stage_schedule`](crate::spec::schedule::stage_schedule): the same
//!   orders the simulator replays, so GPipe and 1F1B run through one code
//!   path with identical numerics (losses bit-identical, gradients equal up
//!   to f32 accumulation order);
//! * gradient sync runs the [`ShardLayout`]'s cached slice-grid plan: one
//!   reduction per shared atomic slice (replicated gains reduce raw
//!   per-device partials across all holders in a single pass), then the
//!   embedding/head reductions across pipeline roots, then **token-
//!   weighted** scaling over the layout's cached gradient-key list. Each
//!   micro-batch's loss-side gradient is pre-scaled by its token count and
//!   the final pass divides by the step's total tokens, so pipelines
//!   running *different* micro-batch counts (uneven apportioning, §5) still
//!   produce the exact global-mean gradient.
//!
//! The executor walks each rank's
//! [`RankPlan`](super::specialize::RankPlan) timeline with a ready rule
//! (all dependency edges finished ∧ every participant rank is at the task)
//! and measures every task's wall seconds; finish times propagate through
//! the dependency edges (TP members concurrent, pipelines concurrent,
//! global phases charged per-device) to the measured-makespan estimate in
//! [`StepStats`](super::StepStats), cross-validated against the
//! [`crate::sim`] step ranking. Because per-rank program order and the
//! dependency edges are exactly the old interpreter's ready conditions,
//! and the f64 loss sum replays [`SpecializedPlan::head_order`], the
//! executor's losses are **bit-identical** to the interpreter's
//! (`rust/tests/specialize_sweep.rs`). Injected switch deliveries ride
//! per-sender *wire lanes* concurrent with compute — the §6.2 measured
//! interleave (DESIGN.md §7.3).

use std::collections::BTreeMap;
use std::time::Instant;

use crate::collectives::{extract_region, write_region, DeviceMem, Mesh};
use crate::runtime::{HostTensor, Runtime};
use crate::spec::schedule::{stage_schedule, ScheduleKind, Task, TaskKind};
use crate::testutil::Rng;
use crate::{Error, Result};

use super::layout::{full_shape, gkey, pkey, ShardLayout, SyncOp};
use super::specialize::{SpecTaskKind, SpecializedPlan};
use super::{Engine, EnginePipeline, ExecMode, MicroBatch, BLOCK_PARAMS};

/// Deterministic parameter init: full tensors are generated from a
/// per-tensor seed and region-sliced identically for every replica, so
/// every strategy (including hetero-TP) starts from the same global
/// parameters as the single-device oracle.
pub(crate) fn init_params(
    runtime: &Runtime,
    layout: &ShardLayout,
    mesh: &mut Mesh,
    seed: u64,
) -> Result<()> {
    let cfg = runtime.config;
    let h = cfg.hidden;
    for ((l, pidx), hs) in layout.iter_holdings() {
        let name = BLOCK_PARAMS[*pidx];
        let shape: Vec<usize> =
            full_shape(&cfg, name).iter().map(|&n| n as usize).collect();
        let full = init_tensor(seed, *l, name, &shape, h);
        for holding in hs {
            let piece = extract_region(&full, &holding.region)?;
            mesh.devices[holding.dev].put(&pkey(*l, name), piece);
        }
    }
    let v = cfg.vocab;
    for (&fr, &lr) in layout.first_roots.iter().zip(layout.last_roots.iter()) {
        let emb = init_tensor(seed, 10_000, "emb", &[v, h], h);
        mesh.devices[fr].put("emb", emb);
        let gf = HostTensor::f32(vec![h], vec![1.0; h])?;
        let wout = init_tensor(seed, 10_001, "wout", &[h, v], h);
        mesh.devices[lr].put("gf", gf);
        mesh.devices[lr].put("wout", wout);
    }
    Ok(())
}

/// Outcome of one pipeline's scheduled execution within a step.
pub(crate) struct PipelineRun {
    /// Σ over micro-batches of `tokens · mean loss`.
    pub weighted_loss: f64,
    /// Tokens processed by this pipeline.
    pub tokens: u64,
    /// Critical-path seconds from measured per-task durations replayed
    /// through the schedule's dependency structure.
    pub makespan_s: f64,
}

/// Outcome of one specialized (event-driven) step execution.
pub(crate) struct SpecRunOutcome {
    /// Σ over micro-batches of `tokens · mean loss`, accumulated in the
    /// old interpreter's pipeline-major head order (bit-identical f64).
    pub weighted_loss: f64,
    /// Real (unmasked) tokens processed.
    pub tokens: u64,
    /// Compute critical path through the per-rank timelines (global
    /// phases charged per-device, as before).
    pub makespan_s: f64,
    /// Switch seconds the step could not hide: injected per-sender
    /// delivery batches ride each sender's wire lane from step start,
    /// concurrent with compute; the overhang beyond the compute critical
    /// path is exposed (§6.2 measured interleave).
    pub exposed_switch_s: f64,
    /// Longest per-sender wire lane among the injected deliveries.
    pub delivery_lane_s: f64,
}

impl Engine {
    /// Execute one pipeline's full step in the task order its schedule
    /// prescribes — the **pre-specialization global interpreter**, kept
    /// as the differential numerics oracle for the event-driven executor
    /// (`Engine::train_step_reference`). Tasks run as soon as their
    /// cross-stage dependency is satisfied, exactly like the
    /// discrete-event simulator; per-stage clocks accumulate the
    /// *measured* task durations to produce the pipeline makespan.
    pub(crate) fn run_pipeline(
        &mut self,
        pipe: &EnginePipeline,
        batches: &[MicroBatch],
        kind: ScheduleKind,
    ) -> Result<PipelineRun> {
        let s_count = pipe.stages.len();
        let m = pipe.num_microbatches;
        let queues: Vec<Vec<Task>> =
            (0..s_count).map(|s| stage_schedule(kind, s_count, s, m)).collect();
        let total: usize = queues.iter().map(|q| q.len()).sum();
        let mut q_head = vec![0usize; s_count];
        let mut clock = vec![0f64; s_count];
        let mut fwd_done = vec![vec![f64::NAN; s_count]; m];
        let mut bwd_done = vec![vec![f64::NAN; s_count]; m];

        let mut weighted_loss = 0f64;
        let mut tokens = 0u64;
        let mut executed = 0usize;
        while executed < total {
            let mut progressed = false;
            for s in 0..s_count {
                while q_head[s] < queues[s].len() {
                    let task = queues[s][q_head[s]];
                    let mbi = task.microbatch;
                    let ready = match task.kind {
                        TaskKind::Fwd if s == 0 => Some(0.0),
                        TaskKind::Fwd => {
                            let d = fwd_done[mbi][s - 1];
                            if d.is_nan() {
                                None
                            } else {
                                Some(d)
                            }
                        }
                        TaskKind::Bwd if s == s_count - 1 => {
                            let f = fwd_done[mbi][s];
                            if f.is_nan() {
                                None
                            } else {
                                Some(f)
                            }
                        }
                        TaskKind::Bwd => {
                            let d = bwd_done[mbi][s + 1];
                            if d.is_nan() {
                                None
                            } else {
                                Some(d)
                            }
                        }
                    };
                    let Some(ready) = ready else { break };
                    let dur = match task.kind {
                        TaskKind::Fwd => self.fwd_task(pipe, s, mbi, &batches[mbi])?,
                        TaskKind::Bwd => {
                            let (dur, head) = self.bwd_task(pipe, s, mbi, &batches[mbi])?;
                            if let Some((loss, n)) = head {
                                weighted_loss += loss as f64 * n as f64;
                                tokens += n;
                            }
                            dur
                        }
                    };
                    let finish = clock[s].max(ready) + dur;
                    clock[s] = finish;
                    match task.kind {
                        TaskKind::Fwd => fwd_done[mbi][s] = finish,
                        TaskKind::Bwd => bwd_done[mbi][s] = finish,
                    }
                    q_head[s] += 1;
                    executed += 1;
                    progressed = true;
                }
            }
            if !progressed {
                return Err(Error::Engine(format!(
                    "schedule deadlock at {executed}/{total} tasks ({kind:?}, {s_count} stages)"
                )));
            }
        }
        let makespan_s = clock.iter().copied().fold(0.0, f64::max);
        Ok(PipelineRun { weighted_loss, tokens, makespan_s })
    }

    /// Event-driven execution of a specialized step (DESIGN.md §7): walk
    /// every rank's timeline, executing each task once all its dependency
    /// edges are finished and every participant rank has reached it, and
    /// replay the measured per-task durations through the same structure
    /// for the makespan. `deliveries` are a preceding switch's per-sender
    /// batches, injected onto per-sender wire lanes (§6.2 measured
    /// interleave).
    ///
    /// `pipelines` must be the strategy snapshot the plan was specialized
    /// from (the caller clones it, as the interpreter did); `batches` are
    /// indexed `[pipeline][microbatch]`.
    pub(crate) fn run_specialized(
        &mut self,
        plan: &SpecializedPlan,
        pipelines: &[EnginePipeline],
        batches: &[Vec<MicroBatch>],
        deliveries: &[(usize, f64)],
    ) -> Result<SpecRunOutcome> {
        match self.exec_mode {
            ExecMode::Threaded => {
                return self.run_specialized_threaded(plan, pipelines, batches, deliveries, None)
            }
            ExecMode::CompiledThreaded => {
                // replay each rank's frozen tape on its thread: the
                // compiled program supplies precomputed keys/endpoints
                let prog = self.compiled_program_for(batches)?;
                return self.run_specialized_threaded(
                    plan,
                    pipelines,
                    batches,
                    deliveries,
                    Some(&prog),
                );
            }
            ExecMode::Compiled => {
                // dispatch-only hot loop over the frozen segment tape
                let prog = self.compiled_program_for(batches)?;
                return self.run_compiled(&prog, batches, deliveries);
            }
            ExecMode::EventDriven => {}
        }
        // arm the span ring: one span per (task, participant) — exact, so
        // a traced warm step writes into preallocated slots only
        let span_cap: usize = plan.tasks.iter().map(|t| t.ranks.len()).sum();
        self.recorder.begin_step(span_cap, self.trace_on);
        let n = plan.tasks.len();
        let nranks = plan.ranks.len();
        let rank_pos = |r: usize| {
            plan.rank_index(r).expect("run_specialized: participant rank has a timeline")
        };
        let mut done = vec![false; n];
        let mut finish = vec![0f64; n];
        let mut clock = vec![0f64; nranks];
        let mut head = vec![0usize; nranks];
        let mut head_loss: BTreeMap<(usize, usize), (f32, u64)> = BTreeMap::new();
        let mut tokens = 0u64;
        let ndev = nranks.max(1) as f64;

        let mut executed = 0usize;
        while executed < n {
            let mut progressed = false;
            for ri in 0..nranks {
                'rank: loop {
                    let Some(&ti) = plan.ranks[ri].tasks.get(head[ri]) else { break };
                    if done[ti] {
                        head[ri] += 1;
                        continue;
                    }
                    let task = &plan.tasks[ti];
                    if !task.deps.iter().all(|&d| done[d]) {
                        break 'rank;
                    }
                    // every participant rank must have reached this task
                    let mut ready = 0f64;
                    for &r in &task.ranks {
                        let pos = rank_pos(r);
                        if plan.ranks[pos].tasks.get(head[pos]) != Some(&ti) {
                            break 'rank;
                        }
                        ready = ready.max(clock[pos]);
                    }
                    for &d in &task.deps {
                        ready = ready.max(finish[d]);
                    }

                    let dur = match &task.kind {
                        SpecTaskKind::FwdIn { pipe, stage, mb } => self.spec_fwd_in(
                            &pipelines[*pipe],
                            *pipe,
                            *stage,
                            *mb,
                            &batches[*pipe][*mb],
                        )?,
                        SpecTaskKind::FwdGemm { pipe, stage, mb, layer } => {
                            self.spec_fwd_gemm(&pipelines[*pipe], *pipe, *stage, *mb, *layer)?
                        }
                        SpecTaskKind::FwdTpSync { pipe, stage, mb, .. } => {
                            self.spec_fwd_tp_sync(&pipelines[*pipe], *pipe, *stage, *mb)?
                        }
                        SpecTaskKind::BwdIn { pipe, stage, mb } => {
                            let (dur, head_out) = self.spec_bwd_in(
                                &pipelines[*pipe],
                                *pipe,
                                *stage,
                                *mb,
                                &batches[*pipe][*mb],
                            )?;
                            if let Some((loss, n_tok)) = head_out {
                                head_loss.insert((*pipe, *mb), (loss, n_tok));
                                tokens += n_tok;
                            }
                            dur
                        }
                        SpecTaskKind::BwdGemm { pipe, stage, mb, layer } => {
                            self.spec_bwd_gemm(&pipelines[*pipe], *pipe, *stage, *mb, *layer)?
                        }
                        SpecTaskKind::BwdTpSync { pipe, stage, mb, .. } => {
                            self.spec_bwd_tp_sync(&pipelines[*pipe], *pipe, *stage, *mb)?
                        }
                        SpecTaskKind::EmbedBwd { pipe, mb } => self.spec_embed_bwd(
                            &pipelines[*pipe],
                            *pipe,
                            *mb,
                            &batches[*pipe][*mb],
                        )?,
                        SpecTaskKind::GradReduce => {
                            if tokens == 0 {
                                return Err(Error::Engine(
                                    "train_step: no tokens processed".into(),
                                ));
                            }
                            let t0 = Instant::now();
                            self.sync_gradients(tokens)?;
                            // spread over the devices, concurrent in a
                            // deployment: charge the per-device share
                            t0.elapsed().as_secs_f64() / ndev
                        }
                        SpecTaskKind::OptimStep => {
                            let t0 = Instant::now();
                            self.apply_updates_local()?;
                            t0.elapsed().as_secs_f64() / ndev
                        }
                        SpecTaskKind::ZeroExchange => {
                            let t0 = Instant::now();
                            self.exchange_zero1_slices()?;
                            t0.elapsed().as_secs_f64() / ndev
                        }
                    };

                    let end = ready + dur;
                    // replayed-clock spans: one per participant rank, on
                    // the same epoch as the modeled makespan
                    if self.recorder.is_active() {
                        let sk = crate::obs::trace::SpanKind::of_task(&task.kind);
                        for &r in &task.ranks {
                            self.recorder.record(ti as u32, sk, r as u32, ready, end);
                        }
                    }
                    finish[ti] = end;
                    done[ti] = true;
                    executed += 1;
                    progressed = true;
                    // advance every participant past consecutive done tasks
                    for &r in &plan.tasks[ti].ranks {
                        let pos = rank_pos(r);
                        clock[pos] = end;
                        while let Some(&x) = plan.ranks[pos].tasks.get(head[pos]) {
                            if done[x] {
                                head[pos] += 1;
                            } else {
                                break;
                            }
                        }
                    }
                }
            }
            if !progressed {
                return Err(Error::Engine(format!(
                    "specialized plan deadlock at {executed}/{n} tasks ({:?})",
                    plan.schedule
                )));
            }
        }

        // f64 loss accumulation in the interpreter's order: pipeline-major,
        // each pipeline summed separately in its head-retirement order,
        // then added — bit-identical to the sequential-pipeline sums.
        let mut weighted_loss = 0f64;
        for (pi, order) in plan.head_order.iter().enumerate() {
            let mut wp = 0f64;
            for &mb in order {
                if let Some(&(loss, n_tok)) = head_loss.get(&(pi, mb)) {
                    wp += loss as f64 * n_tok as f64;
                }
            }
            weighted_loss += wp;
        }

        let makespan_s = clock.iter().copied().fold(0.0, f64::max);
        // §6.2 measured interleave: deliveries occupy per-sender wire
        // lanes from step start (shards stream in first-use order ahead
        // of need, the paper's overlap premise), concurrent with compute;
        // back-to-back switches serialize per *sender*, not per switch,
        // so the exposure is ≤ the old per-switch scalar bound
        // max(0, Σ_switch delivery − makespan) — asserted in tests.
        let mut lanes: BTreeMap<usize, f64> = BTreeMap::new();
        for &(sender, secs) in deliveries {
            *lanes.entry(sender).or_insert(0.0) += secs.max(0.0);
        }
        let delivery_lane_s = lanes.values().copied().fold(0.0, f64::max);
        let exposed_switch_s = (delivery_lane_s - makespan_s).max(0.0);
        Ok(SpecRunOutcome {
            weighted_loss,
            tokens,
            makespan_s,
            exposed_switch_s,
            delivery_lane_s,
        })
    }

    /// Activation key of one `(pipeline, micro-batch)` slot (shared with
    /// the threaded executor, [`super::thread`]).
    pub(crate) fn akey(pi: usize, mb: usize) -> String {
        format!("act.p{pi}.mb{mb}")
    }

    /// Incoming-gradient key of one `(pipeline, micro-batch)` slot.
    pub(crate) fn dkey(pi: usize, mb: usize) -> String {
        format!("dact.p{pi}.mb{mb}")
    }

    /// Saved-block-input key (recompute-in-backward).
    pub(crate) fn skey(pi: usize, mb: usize, l: u32) -> String {
        format!("save.p{pi}.mb{mb}.L{l}")
    }

    /// [`SpecTaskKind::FwdIn`]: stage 0 embeds the micro-batch on its
    /// root; later stages receive the activation hand-off from the
    /// previous stage's root (freeing the producer's copies); both
    /// broadcast over the TP group. Charged serially (root/boundary
    /// work), as the interpreter did.
    fn spec_fwd_in(
        &mut self,
        pipe: &EnginePipeline,
        pi: usize,
        si: usize,
        mb: usize,
        batch: &MicroBatch,
    ) -> Result<f64> {
        let stage = &pipe.stages[si];
        let akey = Self::akey(pi, mb);
        let t0 = Instant::now();
        if si == 0 {
            let (b, s) = (batch.n_seqs, batch.seq_len);
            let root = stage.devices[0];
            let tok = HostTensor::i32(vec![b, s], batch.tokens.clone())?;
            let x0 = {
                let emb = self.mesh.devices[root].get("emb")?;
                let out = self.runtime.call_refs("embed_fwd", &[emb, &tok])?;
                out.into_iter().next().unwrap()
            };
            self.mesh.devices[root].put(&akey, x0);
        } else {
            let prev = &pipe.stages[si - 1];
            self.mesh.send(prev.devices[0], stage.devices[0], &akey)?;
            // the producer's copies are no longer needed
            for &d in &prev.devices {
                if !stage.devices.contains(&d) {
                    let _ = self.mesh.devices[d].take(&akey);
                }
            }
        }
        self.mesh.broadcast(stage.devices[0], &stage.devices, &akey)?;
        Ok(t0.elapsed().as_secs_f64())
    }

    /// [`SpecTaskKind::FwdGemm`]: save the block input for
    /// recompute-in-backward, then run every TP member's partial forward
    /// GEMMs. TP members are concurrent: the duration is the slowest
    /// member plus the serial remainder.
    fn spec_fwd_gemm(
        &mut self,
        pipe: &EnginePipeline,
        pi: usize,
        si: usize,
        mb: usize,
        l: u32,
    ) -> Result<f64> {
        let stage = &pipe.stages[si];
        let akey = Self::akey(pi, mb);
        let art = format!("block_fwd_tp{}", stage.tp());
        let t0 = Instant::now();
        let mut compute = vec![0f64; stage.devices.len()];
        for &d in &stage.devices {
            let x = self.mesh.devices[d].get(&akey)?.clone();
            self.mesh.devices[d].put(&Self::skey(pi, mb, l), x);
        }
        for (j, &d) in stage.devices.iter().enumerate() {
            let dev = &self.mesh.devices[d];
            let mut inputs: Vec<&HostTensor> = Vec::with_capacity(9);
            for p in BLOCK_PARAMS {
                inputs.push(dev.get(&pkey(l, p))?);
            }
            inputs.push(dev.get(&akey)?);
            let t1 = Instant::now();
            let y_part = self.runtime.call_refs(&art, &inputs)?.into_iter().next().unwrap();
            compute[j] += t1.elapsed().as_secs_f64();
            self.mesh.devices[d].put("part", y_part);
        }
        Ok(task_duration(t0.elapsed().as_secs_f64(), &compute))
    }

    /// [`SpecTaskKind::FwdTpSync`]: partial-sum all-reduce over the TP
    /// group + residual add (serial comm charge).
    fn spec_fwd_tp_sync(
        &mut self,
        pipe: &EnginePipeline,
        pi: usize,
        si: usize,
        mb: usize,
    ) -> Result<f64> {
        let stage = &pipe.stages[si];
        let akey = Self::akey(pi, mb);
        let t0 = Instant::now();
        self.mesh.all_reduce(&stage.devices, "part")?;
        for &d in &stage.devices {
            let part = self.mesh.devices[d].get("part")?.clone();
            let x = self.mesh.devices[d].get_mut(&akey)?;
            x.add_assign(&part)?;
        }
        Ok(t0.elapsed().as_secs_f64())
    }

    /// [`SpecTaskKind::BwdIn`]: the last stage runs the fused head (loss
    /// + head gradients pre-scaled by the micro-batch's real token count,
    /// freeing the stage activation); earlier stages receive the gradient
    /// hand-off; both broadcast. Returns the duration and, on the last
    /// stage, `(mean loss, tokens)`.
    fn spec_bwd_in(
        &mut self,
        pipe: &EnginePipeline,
        pi: usize,
        si: usize,
        mb: usize,
        batch: &MicroBatch,
    ) -> Result<(f64, Option<(f32, u64)>)> {
        let stage = &pipe.stages[si];
        let last = pipe.stages.len() - 1;
        let akey = Self::akey(pi, mb);
        let dkey = Self::dkey(pi, mb);
        let t0 = Instant::now();
        let mut head_out = None;
        if si == last {
            let (b, s) = (batch.n_seqs, batch.seq_len);
            // token weighting counts *real* (unmasked) positions
            let tokens = batch.real_tokens();
            let w = tokens as f32;
            let root = stage.devices[0];
            let tgt = HostTensor::i32(vec![b, s], batch.targets.clone())?;
            let (loss, mut dx, mut dgf, mut dwout) = {
                let dev = &self.mesh.devices[root];
                let out = self.runtime.call_refs(
                    "head_step",
                    &[dev.get("gf")?, dev.get("wout")?, dev.get(&akey)?, &tgt],
                )?;
                let mut it = out.into_iter();
                let loss = it.next().unwrap().as_f32()?[0];
                (loss, it.next().unwrap(), it.next().unwrap(), it.next().unwrap())
            };
            dx.scale(w)?;
            dgf.scale(w)?;
            dwout.scale(w)?;
            accumulate(&mut self.mesh.devices[root], "grad.gf", dgf)?;
            accumulate(&mut self.mesh.devices[root], "grad.wout", dwout)?;
            self.mesh.devices[root].put(&dkey, dx);
            for &d in &stage.devices {
                let _ = self.mesh.devices[d].take(&akey);
            }
            head_out = Some((loss, tokens));
        } else {
            let next = &pipe.stages[si + 1];
            self.mesh.send(next.devices[0], stage.devices[0], &dkey)?;
            for &d in &next.devices {
                if !stage.devices.contains(&d) {
                    let _ = self.mesh.devices[d].take(&dkey);
                }
            }
        }
        self.mesh.broadcast(stage.devices[0], &stage.devices, &dkey)?;
        Ok((t0.elapsed().as_secs_f64(), head_out))
    }

    /// [`SpecTaskKind::BwdGemm`]: every TP member's backward GEMMs for
    /// one layer, accumulating parameter gradients and freeing the saved
    /// block input.
    fn spec_bwd_gemm(
        &mut self,
        pipe: &EnginePipeline,
        pi: usize,
        si: usize,
        mb: usize,
        l: u32,
    ) -> Result<f64> {
        let stage = &pipe.stages[si];
        let dkey = Self::dkey(pi, mb);
        let skey = Self::skey(pi, mb, l);
        let art = format!("block_bwd_tp{}", stage.tp());
        let t0 = Instant::now();
        let mut compute = vec![0f64; stage.devices.len()];
        for (j, &d) in stage.devices.iter().enumerate() {
            let dev = &self.mesh.devices[d];
            let mut inputs: Vec<&HostTensor> = Vec::with_capacity(10);
            for p in BLOCK_PARAMS {
                inputs.push(dev.get(&pkey(l, p))?);
            }
            inputs.push(dev.get(&skey)?);
            inputs.push(dev.get(&dkey)?);
            let t1 = Instant::now();
            let outs = self.runtime.call_refs(&art, &inputs)?;
            compute[j] += t1.elapsed().as_secs_f64();
            let mut it = outs.into_iter();
            let dx_part = it.next().unwrap();
            self.mesh.devices[d].put("dpart", dx_part);
            for p in BLOCK_PARAMS {
                accumulate(&mut self.mesh.devices[d], &gkey(l, p), it.next().unwrap())?;
            }
            // free the saved activation
            let _ = self.mesh.devices[d].take(&skey);
        }
        Ok(task_duration(t0.elapsed().as_secs_f64(), &compute))
    }

    /// [`SpecTaskKind::BwdTpSync`]: dx-partial all-reduce + add.
    fn spec_bwd_tp_sync(
        &mut self,
        pipe: &EnginePipeline,
        pi: usize,
        si: usize,
        mb: usize,
    ) -> Result<f64> {
        let stage = &pipe.stages[si];
        let dkey = Self::dkey(pi, mb);
        let t0 = Instant::now();
        self.mesh.all_reduce(&stage.devices, "dpart")?;
        for &d in &stage.devices {
            let dpart = self.mesh.devices[d].get("dpart")?.clone();
            let dx = self.mesh.devices[d].get_mut(&dkey)?;
            dx.add_assign(&dpart)?;
        }
        Ok(t0.elapsed().as_secs_f64())
    }

    /// [`SpecTaskKind::EmbedBwd`]: stage-0 epilogue — embedding gradient
    /// on the root, then free the incoming gradient on the whole stage.
    fn spec_embed_bwd(
        &mut self,
        pipe: &EnginePipeline,
        pi: usize,
        mb: usize,
        batch: &MicroBatch,
    ) -> Result<f64> {
        let stage = &pipe.stages[0];
        let dkey = Self::dkey(pi, mb);
        let (b, s) = (batch.n_seqs, batch.seq_len);
        let t0 = Instant::now();
        let root = stage.devices[0];
        let tok = HostTensor::i32(vec![b, s], batch.tokens.clone())?;
        let demb = {
            let dx0 = self.mesh.devices[root].get(&dkey)?;
            self.runtime.call_refs("embed_bwd", &[&tok, dx0])?.into_iter().next().unwrap()
        };
        accumulate(&mut self.mesh.devices[root], "grad.emb", demb)?;
        for &d in &stage.devices {
            let _ = self.mesh.devices[d].take(&dkey);
        }
        Ok(t0.elapsed().as_secs_f64())
    }

    /// Forward of micro-batch `mb` through stage `si`: receive (or embed)
    /// the stage input, run the stage's layers with TP partial-sum
    /// all-reduces, and leave the stage output under `act.mb{mb}`. Returns
    /// the task-duration estimate (slowest TP member's compute plus the
    /// serial comm/root remainder).
    fn fwd_task(
        &mut self,
        pipe: &EnginePipeline,
        si: usize,
        mb: usize,
        batch: &MicroBatch,
    ) -> Result<f64> {
        // ragged: the micro-batch carries its own [n_seqs, seq_len] shape
        // (§5.5 symbolic shapes) — the native artifacts bind it per call,
        // so attention and the measured task seconds cost the *true*
        // window length, not the compiled padded context
        let (b, s) = (batch.n_seqs, batch.seq_len);
        let stage = &pipe.stages[si];
        let akey = format!("act.mb{mb}");
        let t_task = Instant::now();
        let mut compute = vec![0f64; stage.devices.len()];

        if si == 0 {
            let root = stage.devices[0];
            let tok = HostTensor::i32(vec![b, s], batch.tokens.clone())?;
            let x0 = {
                let emb = self.mesh.devices[root].get("emb")?;
                let out = self.runtime.call_refs("embed_fwd", &[emb, &tok])?;
                out.into_iter().next().unwrap()
            };
            self.mesh.devices[root].put(&akey, x0);
        } else {
            let prev = &pipe.stages[si - 1];
            self.mesh.send(prev.devices[0], stage.devices[0], &akey)?;
            // the producer's copies are no longer needed
            for &d in &prev.devices {
                if !stage.devices.contains(&d) {
                    let _ = self.mesh.devices[d].take(&akey);
                }
            }
        }
        self.mesh.broadcast(stage.devices[0], &stage.devices, &akey)?;

        let tp = stage.tp();
        let art = format!("block_fwd_tp{tp}");
        for l in stage.layers.0..stage.layers.1 {
            // save block input for recompute-in-backward
            for &d in &stage.devices {
                let x = self.mesh.devices[d].get(&akey)?.clone();
                self.mesh.devices[d].put(&format!("save.mb{mb}.L{l}"), x);
            }
            for (j, &d) in stage.devices.iter().enumerate() {
                let dev = &self.mesh.devices[d];
                let mut inputs: Vec<&HostTensor> = Vec::with_capacity(9);
                for p in BLOCK_PARAMS {
                    inputs.push(dev.get(&pkey(l, p))?);
                }
                inputs.push(dev.get(&akey)?);
                let t0 = Instant::now();
                let y_part =
                    self.runtime.call_refs(&art, &inputs)?.into_iter().next().unwrap();
                compute[j] += t0.elapsed().as_secs_f64();
                self.mesh.devices[d].put("part", y_part);
            }
            self.mesh.all_reduce(&stage.devices, "part")?;
            for &d in &stage.devices {
                let part = self.mesh.devices[d].get("part")?.clone();
                let x = self.mesh.devices[d].get_mut(&akey)?;
                x.add_assign(&part)?;
            }
        }
        Ok(task_duration(t_task.elapsed().as_secs_f64(), &compute))
    }

    /// Backward of micro-batch `mb` through stage `si`. On the last stage
    /// this starts with the fused head artifact (loss + head gradients,
    /// pre-scaled by the micro-batch's token count for the token-weighted
    /// sync); on stage 0 it ends with the embedding gradient. Returns the
    /// task-duration estimate and, on the last stage, `(mean loss, tokens)`.
    fn bwd_task(
        &mut self,
        pipe: &EnginePipeline,
        si: usize,
        mb: usize,
        batch: &MicroBatch,
    ) -> Result<(f64, Option<(f32, u64)>)> {
        let (b, s) = (batch.n_seqs, batch.seq_len); // ragged per-window shape
        let stage = &pipe.stages[si];
        let last = pipe.stages.len() - 1;
        let akey = format!("act.mb{mb}");
        let dkey = format!("dact.mb{mb}");
        let t_task = Instant::now();
        let mut compute = vec![0f64; stage.devices.len()];
        let mut head_out = None;

        if si == last {
            // token weighting counts *real* (unmasked) positions: padded
            // tails contribute no loss and no gradient, so they must not
            // dilute the global mean either
            let tokens = batch.real_tokens();
            let w = tokens as f32;
            let root = stage.devices[0];
            let tgt = HostTensor::i32(vec![b, s], batch.targets.clone())?;
            let (loss, mut dx, mut dgf, mut dwout) = {
                let dev = &self.mesh.devices[root];
                let out = self.runtime.call_refs(
                    "head_step",
                    &[dev.get("gf")?, dev.get("wout")?, dev.get(&akey)?, &tgt],
                )?;
                let mut it = out.into_iter();
                let loss = it.next().unwrap().as_f32()?[0];
                (loss, it.next().unwrap(), it.next().unwrap(), it.next().unwrap())
            };
            // token weighting: the head emits the gradient of this
            // micro-batch's *mean* loss; scale by its token count here and
            // divide by the step's total tokens in `sync_gradients`.
            dx.scale(w)?;
            dgf.scale(w)?;
            dwout.scale(w)?;
            accumulate(&mut self.mesh.devices[root], "grad.gf", dgf)?;
            accumulate(&mut self.mesh.devices[root], "grad.wout", dwout)?;
            self.mesh.devices[root].put(&dkey, dx);
            for &d in &stage.devices {
                let _ = self.mesh.devices[d].take(&akey);
            }
            head_out = Some((loss, tokens));
        } else {
            let next = &pipe.stages[si + 1];
            self.mesh.send(next.devices[0], stage.devices[0], &dkey)?;
            for &d in &next.devices {
                if !stage.devices.contains(&d) {
                    let _ = self.mesh.devices[d].take(&dkey);
                }
            }
        }
        self.mesh.broadcast(stage.devices[0], &stage.devices, &dkey)?;

        let tp = stage.tp();
        let art = format!("block_bwd_tp{tp}");
        for l in (stage.layers.0..stage.layers.1).rev() {
            for (j, &d) in stage.devices.iter().enumerate() {
                let dev = &self.mesh.devices[d];
                let mut inputs: Vec<&HostTensor> = Vec::with_capacity(10);
                for p in BLOCK_PARAMS {
                    inputs.push(dev.get(&pkey(l, p))?);
                }
                inputs.push(dev.get(&format!("save.mb{mb}.L{l}"))?);
                inputs.push(dev.get(&dkey)?);
                let t0 = Instant::now();
                let outs = self.runtime.call_refs(&art, &inputs)?;
                compute[j] += t0.elapsed().as_secs_f64();
                let mut it = outs.into_iter();
                let dx_part = it.next().unwrap();
                self.mesh.devices[d].put("dpart", dx_part);
                for p in BLOCK_PARAMS {
                    accumulate(&mut self.mesh.devices[d], &gkey(l, p), it.next().unwrap())?;
                }
                // free the saved activation
                let _ = self.mesh.devices[d].take(&format!("save.mb{mb}.L{l}"));
            }
            self.mesh.all_reduce(&stage.devices, "dpart")?;
            for &d in &stage.devices {
                let dpart = self.mesh.devices[d].get("dpart")?.clone();
                let dx = self.mesh.devices[d].get_mut(&dkey)?;
                dx.add_assign(&dpart)?;
            }
        }

        if si == 0 {
            let root = stage.devices[0];
            let tok = HostTensor::i32(vec![b, s], batch.tokens.clone())?;
            let demb = {
                let dx0 = self.mesh.devices[root].get(&dkey)?;
                self.runtime.call_refs("embed_bwd", &[&tok, dx0])?.into_iter().next().unwrap()
            };
            accumulate(&mut self.mesh.devices[root], "grad.emb", demb)?;
            for &d in &stage.devices {
                let _ = self.mesh.devices[d].take(&dkey);
            }
        }
        Ok((task_duration(t_task.elapsed().as_secs_f64(), &compute), head_out))
    }

    /// Gradient synchronization from the cached [`ShardLayout`] plan, then
    /// embedding/head reductions across pipeline roots, then the token-
    /// weighted `1/total_tokens` scaling over the cached gradient-key list
    /// (every accumulated gradient was pre-scaled by its micro-batch's
    /// token count in the head task).
    pub(crate) fn sync_gradients(&mut self, total_tokens: u64) -> Result<()> {
        for op in &self.layout.sync_ops {
            match op {
                SyncOp::AllReduce { key, devs } => {
                    self.mesh.all_reduce(devs, self.layout.key(*key))?
                }
                SyncOp::SliceReduce { key, parts } => {
                    self.mesh.all_reduce_region(parts, self.layout.key(*key))?
                }
            }
        }
        self.mesh.all_reduce(&self.layout.first_roots, "grad.emb")?;
        self.mesh.all_reduce(&self.layout.last_roots, "grad.gf")?;
        self.mesh.all_reduce(&self.layout.last_roots, "grad.wout")?;

        let scale = 1.0 / total_tokens as f32;
        for (dev, key) in &self.layout.grad_keys {
            self.mesh.devices[*dev].get_mut(self.layout.key(*key))?.scale(scale)?;
        }
        Ok(())
    }

    /// AdamW over the layout's cached `(device, param, grad)` list;
    /// gradients are consumed.
    ///
    /// Under ZeRO-1 (`Engine::set_zero1`) each replica-set member updates
    /// only its DP partition (partition-sized moments), spectators drop
    /// their gradient, and the updated parameter slices are exchanged
    /// afterwards ([`Engine::exchange_zero1_slices`]) — the ZeRO-1
    /// all-gather, accounted on the mesh wire. Because AdamW is
    /// elementwise over slice-synced gradients, the trajectory is
    /// bit-identical to the replicated path.
    ///
    /// The specialized executor runs the two halves as distinct tasks
    /// ([`SpecTaskKind::OptimStep`] compute, then the
    /// [`SpecTaskKind::ZeroExchange`] comm task); this composition serves
    /// the reference interpreter path.
    pub(crate) fn apply_updates(&mut self) -> Result<()> {
        self.apply_updates_local()?;
        if self.zero1 {
            self.exchange_zero1_slices()?;
        }
        Ok(())
    }

    /// The local half of the optimizer step: AdamW on every device's own
    /// shards (ZeRO-1 partition owners update only their slice,
    /// spectators drop their gradient). No wire traffic.
    pub(crate) fn apply_updates_local(&mut self) -> Result<()> {
        let step = self.step + 1;
        if !self.zero1 {
            for (dev, param_key, grad_key) in &self.layout.update_ops {
                self.opt.update(
                    &mut self.mesh.devices[*dev],
                    self.layout.key(*param_key),
                    self.layout.key(*grad_key),
                    step,
                )?;
            }
            return Ok(());
        }
        for (dev, param_key, grad_key) in &self.layout.update_ops {
            match self.layout.zero_part_id(*dev, *param_key) {
                Some(Some(region)) => self.opt.update_region(
                    &mut self.mesh.devices[*dev],
                    self.layout.key(*param_key),
                    self.layout.key(*grad_key),
                    region,
                    step,
                )?,
                Some(None) => {
                    let _ = self.mesh.devices[*dev].take(self.layout.key(*grad_key));
                }
                None => self.opt.update(
                    &mut self.mesh.devices[*dev],
                    self.layout.key(*param_key),
                    self.layout.key(*grad_key),
                    step,
                )?,
            }
        }
        Ok(())
    }

    /// The comm half of the ZeRO-1 optimizer step: exchange updated
    /// parameter slices within each replica set (one grouped all-gather
    /// per set, accounted on the mesh wire).
    pub(crate) fn exchange_zero1_slices(&mut self) -> Result<()> {
        for g in &self.layout.zero_groups {
            let key = self.layout.key(g.key);
            for (owner, region) in &g.parts {
                let piece = extract_region(self.mesh.devices[*owner].get(key)?, region)?;
                for &m in &g.members {
                    if m != *owner {
                        write_region(self.mesh.devices[m].get_mut(key)?, region, &piece)?;
                        self.mesh.wire_elems += piece.len() as u64;
                    }
                }
            }
            self.mesh.ops += 1; // one grouped all-gather per replica set
        }
        Ok(())
    }

    /// ZeRO-1 → full moments: before a switch, reassemble each replica
    /// set's partitioned `m.*`/`v.*` into full shard-shaped tensors on
    /// every member, so the switch plan's param-shaped moment moves can
    /// extract from them. Only parameters in `moved` (the plan's moment
    /// moves) gather; `dead` devices contribute nothing — their partition
    /// is lost and stays zero in the reassembled tensors. Wire volume is
    /// accounted (it is the real cost the paper's App.-A fault-tolerance
    /// trade-off pays).
    pub(crate) fn gather_zero1_moments(
        &mut self,
        moved: &std::collections::BTreeSet<&str>,
        dead: &[usize],
    ) -> Result<()> {
        for g in &self.layout.zero_groups {
            let gk = self.layout.key(g.key);
            if !moved.contains(gk) {
                continue;
            }
            for pre in ["m.", "v."] {
                let key = format!("{pre}{gk}");
                let mut pieces: Vec<(usize, &crate::hspmd::slices::Region, HostTensor)> = vec![];
                for (owner, region) in &g.parts {
                    if !dead.contains(owner) && self.mesh.devices[*owner].has(&key) {
                        let t = self.mesh.devices[*owner].get(&key)?.clone();
                        pieces.push((*owner, region, t));
                    }
                }
                if pieces.is_empty() {
                    continue;
                }
                for &m in &g.members {
                    if dead.contains(&m) {
                        continue; // dead members are evicted, not restocked
                    }
                    let shape = self.mesh.devices[m].get(gk)?.shape.clone();
                    let mut full = HostTensor::zeros(shape);
                    for (owner, region, piece) in &pieces {
                        write_region(&mut full, region, piece)?;
                        if *owner != m {
                            self.mesh.wire_elems += piece.len() as u64;
                        }
                    }
                    self.mesh.devices[m].put(&key, full);
                }
                self.mesh.ops += 1;
            }
        }
        Ok(())
    }

    /// Full → ZeRO-1 moments: after a switch, trim each member's full
    /// moment shards back to its DP partition under the (new) layout;
    /// spectators drop their copy. Only parameters in `moved` re-shard —
    /// unmoved ones kept their (still valid) partitions.
    pub(crate) fn reshard_zero1_moments(
        &mut self,
        moved: &std::collections::BTreeSet<&str>,
    ) -> Result<()> {
        for g in &self.layout.zero_groups {
            let gk = self.layout.key(g.key);
            if !moved.contains(gk) {
                continue;
            }
            for pre in ["m.", "v."] {
                let key = format!("{pre}{gk}");
                for &m in &g.members {
                    if !self.mesh.devices[m].has(&key) {
                        continue;
                    }
                    let full = self.mesh.devices[m].take(&key)?;
                    if let Some(Some(region)) = self.layout.zero_part_id(m, g.key) {
                        let part = extract_region(&full, region)?;
                        self.mesh.devices[m].put(&key, part);
                    }
                }
            }
        }
        Ok(())
    }
}

/// Collapse a task's measured timings into its duration estimate: TP
/// members run concurrently (slowest bounds the group), everything else in
/// the task — collectives, boundary sends, root-only head/embed calls —
/// is charged serially.
pub(crate) fn task_duration(task_wall_s: f64, per_member_compute_s: &[f64]) -> f64 {
    let sum: f64 = per_member_compute_s.iter().sum();
    let max = per_member_compute_s.iter().copied().fold(0.0, f64::max);
    (task_wall_s - sum).max(0.0) + max
}

/// Accumulate (or initialize) a gradient buffer.
pub(crate) fn accumulate(dev: &mut DeviceMem, key: &str, t: HostTensor) -> Result<()> {
    if dev.has(key) {
        dev.get_mut(key)?.add_assign(&t)
    } else {
        dev.put(key, t);
        Ok(())
    }
}

/// Deterministic N(0, 0.02) init for a named tensor (gains = 1).
pub(crate) fn init_tensor(
    seed: u64,
    layer: u32,
    name: &str,
    shape: &[usize],
    _hidden: usize,
) -> HostTensor {
    let n: usize = shape.iter().product();
    if name.starts_with('g') {
        return HostTensor::f32(shape.to_vec(), vec![1.0; n]).unwrap();
    }
    let tag: u64 = name.bytes().fold(0u64, |a, b| a.wrapping_mul(131).wrapping_add(b as u64));
    let mut rng = Rng::new(seed ^ (layer as u64) << 32 ^ tag);
    let mut data = Vec::with_capacity(n);
    // Box–Muller
    while data.len() < n {
        let u1 = rng.f64().max(1e-12);
        let u2 = rng.f64();
        let r = (-2.0 * u1.ln()).sqrt();
        let th = 2.0 * std::f64::consts::PI * u2;
        data.push((r * th.cos() * 0.02) as f32);
        if data.len() < n {
            data.push((r * th.sin() * 0.02) as f32);
        }
    }
    HostTensor::f32(shape.to_vec(), data).unwrap()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn init_is_deterministic_and_scaled() {
        let a = init_tensor(7, 3, "wq", &[32, 32], 32);
        let b = init_tensor(7, 3, "wq", &[32, 32], 32);
        assert_eq!(a, b);
        let c = init_tensor(7, 4, "wq", &[32, 32], 32);
        assert_ne!(a, c);
        let mean: f32 = a.as_f32().unwrap().iter().sum::<f32>() / 1024.0;
        assert!(mean.abs() < 0.01);
        let g = init_tensor(7, 0, "g1", &[8], 8);
        assert_eq!(g.as_f32().unwrap(), &[1.0; 8]);
    }

    #[test]
    fn region_slicing_tiles_full_tensor() {
        use super::super::layout::{shard_region, SplitAxis};
        let full = HostTensor::f32(vec![4, 6], (0..24).map(|x| x as f32).collect()).unwrap();
        let c0 = extract_region(&full, &shard_region(&[4, 6], SplitAxis::Col, 2, 0)).unwrap();
        let c1 = extract_region(&full, &shard_region(&[4, 6], SplitAxis::Col, 2, 1)).unwrap();
        assert_eq!(c0.shape, vec![4, 3]);
        assert_eq!(c0.as_f32().unwrap()[..3], [0.0, 1.0, 2.0]);
        assert_eq!(c1.as_f32().unwrap()[..3], [3.0, 4.0, 5.0]);
        let r1 = extract_region(&full, &shard_region(&[4, 6], SplitAxis::Row, 2, 1)).unwrap();
        assert_eq!(r1.shape, vec![2, 6]);
        assert_eq!(r1.as_f32().unwrap()[0], 12.0);
        let rep = extract_region(&full, &shard_region(&[4, 6], SplitAxis::Replicated, 2, 1))
            .unwrap();
        assert_eq!(rep, full);
    }

    #[test]
    fn accumulate_initializes_then_adds() {
        let mut dev = DeviceMem::default();
        let t = HostTensor::f32(vec![2], vec![1.0, 2.0]).unwrap();
        accumulate(&mut dev, "g", t.clone()).unwrap();
        accumulate(&mut dev, "g", t).unwrap();
        assert_eq!(dev.get("g").unwrap().as_f32().unwrap(), &[2.0, 4.0]);
    }

    #[test]
    fn task_duration_overlaps_tp_members() {
        // 3 members: 1+2+3 = 6ms of member compute inside a 10ms task wall
        // → 4ms serial remainder + the 3ms slowest member.
        let d = task_duration(0.010, &[0.001, 0.002, 0.003]);
        assert!((d - 0.007).abs() < 1e-12);
        // degenerate: clock jitter making wall < sum clamps the remainder
        let d2 = task_duration(0.001, &[0.002]);
        assert!((d2 - 0.002).abs() < 1e-12);
    }
}
