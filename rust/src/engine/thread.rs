//! The **concurrent multi-rank executor** (DESIGN.md §8): one OS thread
//! per mesh rank, each running its [`RankPlan`](super::RankPlan) timeline
//! in program order, with every communication task turned into typed
//! messages over `std::sync::mpsc` channels — so wall-clock step time *is*
//! the makespan instead of the event-driven executor's modeled replay.
//!
//! The channel topology is derived mechanically from the specialized
//! plan's dependency edges ([`SpecializedPlan::handoff_edges`]): a p2p
//! activation/gradient hand-off becomes a [`Msg::Handoff`] from the
//! producing stage's root fired as a *post-action* of the producer-side
//! tail task; a TP partial-sum sync becomes a rank-ordered gather of
//! [`Msg::Partial`]s at the group leader plus a [`Msg::Result`] scatter;
//! stage-input broadcasts reuse the `Result` lane. The token-weighted
//! `GradReduce` and the ZeRO-1 `ZeroExchange` run leader-driven once every
//! rank has parked at the phase barrier (their dependency edges cover all
//! backward tails, so no other thread holds work).
//!
//! **Deterministic-reduction contract** (the bit-identity argument): every
//! collective reduces in *rank order regardless of message arrival* — the
//! TP leader awaits each member's partial in group order, the gradient
//! reduction replays the [`ShardLayout`]'s cached op list on one thread,
//! and the f64 loss sum replays `head_order` exactly as the single-thread
//! executor does. Per-device accumulation order is per-rank program order,
//! identical to both oracles, so losses, parameters, wire elements, and
//! comm-op counts are **bit-identical** to `Engine::train_step_reference`
//! and to the event-driven executor (asserted here and in
//! `rust/tests/concurrent_determinism.rs`, including under scheduling
//! jitter).
//!
//! Wire/ops accounting replicates [`Mesh`](crate::collectives::Mesh)'s
//! semantics operation for operation (gather `(n−1)·elems` + scatter
//! `n·elems` + one op per all-reduce, one op per broadcast, one per send)
//! into shared atomics, folded back into the mesh after the join.
//!
//! This path requires the native backend: the PJRT client is `Rc`-based
//! (not `Send`), so artifact calls go straight to
//! [`native::call`](crate::runtime::native::call) with the `Copy` config.

use std::borrow::Cow;
use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender};
use std::sync::{Condvar, Mutex, MutexGuard};
use std::time::{Duration, Instant};

use crate::collectives::{extract_region, write_region, DeviceMem};
use crate::hspmd::slices::Region;
use crate::runtime::workspace::{
    block_bwd_ws, block_fwd_ws, grad_shape, KernelWorkspace, PanelCache,
};
use crate::runtime::{native, HostTensor, ManifestConfig};
use crate::temporal::overlap::SwitchOverlap;
use crate::{Error, Result};

use super::compile::{CompiledOp, CompiledProgram, FusedCall};
use super::exec::{accumulate, SpecRunOutcome};
use super::intern::KeyId;
use super::layout::{gkey, pkey, ShardLayout, SyncOp};
use super::specialize::{SpecTaskKind, SpecializedPlan};
use super::{AdamW, Engine, EnginePipeline, MicroBatch, BLOCK_PARAMS};
use crate::obs::trace::{Span, SpanKind};

/// How long any single wait (dependency, phase, or receive) may stall
/// before the executor reports a deadlock instead of hanging the step.
const WAIT_CAP: Duration = Duration::from_secs(120);

/// Condvar/receive polling quantum (also the failure-flag check cadence).
const POLL: Duration = Duration::from_millis(50);

/// A typed message between rank threads — the materialized form of a comm
/// task's data movement.
enum Msg {
    /// A TP member's partial sum, gathered by the group leader in rank
    /// order (the fixed reduction order of the determinism contract).
    Partial {
        /// Plan index of the TP-sync task this partial belongs to.
        task: usize,
        /// Sending mesh rank.
        from: usize,
        /// The partial tensor.
        t: HostTensor,
    },
    /// A reduced/broadcast tensor scattered from a group leader to a
    /// member (TP-sync results and stage-input broadcasts).
    Result {
        /// Plan index of the task this result belongs to.
        task: usize,
        /// The tensor.
        t: HostTensor,
    },
    /// A cross-stage p2p boundary hand-off (activation forward, gradient
    /// backward) from the producing stage's root to the consuming root.
    Handoff {
        /// Plan index of the consuming `FwdIn`/`BwdIn` task.
        task: usize,
        /// The boundary tensor (moved, not cloned: the producer frees it).
        t: HostTensor,
    },
}

/// What a producer rank does right after finishing its share of a
/// hand-off's producer-side tail task.
#[derive(Clone, Debug)]
enum PostAction {
    /// Producer root: take the boundary tensor off the own device and
    /// fire it at the consumer root (accounts one send on the wire).
    Send {
        /// Plan index of the consuming boundary task.
        handoff: usize,
        /// Boundary tensor key.
        key: String,
        /// Consuming stage's root rank.
        to: usize,
    },
    /// Non-root producer: free the own (now dead) boundary copy, exactly
    /// when the event-driven executor frees the producer copies.
    Drop {
        /// Boundary tensor key.
        key: String,
    },
}

/// Completion state shared by all rank threads.
struct Progress {
    /// Task finished (all shares done / global phase done).
    done: Vec<bool>,
    /// Participant shares still outstanding per task.
    remaining: Vec<usize>,
    /// A thread failed; everyone unwinds.
    failed: bool,
}

/// Everything the rank threads share for one step.
struct Shared<'e> {
    plan: &'e SpecializedPlan,
    /// Index-aligned compiled tape
    /// ([`ExecMode::CompiledThreaded`](super::ExecMode)): each worker
    /// replays its rank's ops by plan index, reading the frozen keys,
    /// artifact names, and groups instead of re-formatting them per task.
    /// `None` falls back to the interpreting path.
    prog: Option<&'e CompiledProgram>,
    pipelines: &'e [EnginePipeline],
    batches: &'e [Vec<MicroBatch>],
    layout: &'e ShardLayout,
    /// One lock per mesh rank; each thread only ever locks its *own*
    /// device (global phases excepted, which run at a full barrier).
    devs: &'e [Mutex<DeviceMem>],
    /// `(producer rank, producer tail task) → post-actions`.
    post: BTreeMap<(usize, usize), Vec<PostAction>>,
    cfg: ManifestConfig,
    opt: AdamW,
    zero1: bool,
    step: u64,
    /// Determinism-stress jitter seed (hashed 0–200 µs pre-task sleeps).
    jitter: Option<u64>,
    progress: Mutex<Progress>,
    cv: Condvar,
    /// The step's wall-clock epoch: every span timestamp is seconds since
    /// this instant, so all rank tracks share one timeline.
    start: Instant,
    /// Span tracing (DESIGN.md §10): one buffer per plan position,
    /// preallocated to the rank's task count; each worker locks only its
    /// *own* buffer (uncontended), pushing real-thread wall spans.
    /// `None` ⇒ tracing off, zero writes.
    trace: Option<Vec<Mutex<Vec<Span>>>>,
    /// Per-`(pipeline, micro-batch)` head outcomes `(mean loss, tokens)`.
    losses: Mutex<BTreeMap<(usize, usize), (f32, u64)>>,
    /// First error wins; later "aborted" errors are dropped.
    err: Mutex<Option<Error>>,
    wire: AtomicU64,
    ops: AtomicU64,
}

/// Poison-tolerant lock: a panicked peer must not cascade into unwrap
/// panics — the failure flag carries the abort instead.
fn plock<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(|p| p.into_inner())
}

/// Frozen key when the compiled tape carries one (resolved through the
/// program's interner — pure array indexing), else the formatted
/// fallback — the threaded dispatch's zero-format fast path.
fn key_or<'a>(
    prog: Option<&'a CompiledProgram>,
    id: Option<KeyId>,
    make: impl FnOnce() -> String,
) -> Cow<'a, str> {
    match (prog, id) {
        (Some(p), Some(id)) => Cow::Borrowed(p.key(id)),
        _ => Cow::Owned(make()),
    }
}

/// SplitMix64 — the stateless per-`(task, rank)` jitter hash.
fn splitmix64(x: u64) -> u64 {
    let mut z = x.wrapping_add(0x9e37_79b9_7f4a_7c15);
    z = (z ^ (z >> 30)).wrapping_mul(0xbf58_476d_1ce4_e5b9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94d0_49bb_1331_11eb);
    z ^ (z >> 31)
}

impl<'e> Shared<'e> {
    fn lock_dev(&self, rank: usize) -> MutexGuard<'_, DeviceMem> {
        plock(&self.devs[rank])
    }

    /// Plan position of a mesh rank (channel index).
    fn pos_of(&self, rank: usize) -> usize {
        self.plan.rank_index(rank).expect("threaded: participant rank has a timeline")
    }

    /// Randomized pre-task sleep under a jitter seed: shakes thread
    /// interleavings for the determinism stress tests without touching
    /// any reduction order.
    fn jitter_sleep(&self, ti: usize, rank: usize) {
        if let Some(seed) = self.jitter {
            let h = splitmix64(seed ^ ((ti as u64) << 20) ^ rank as u64);
            std::thread::sleep(Duration::from_micros(h % 200));
        }
    }

    /// Block until every dependency edge of `ti` is done.
    fn wait_deps(&self, ti: usize) -> Result<()> {
        let deps = &self.plan.tasks[ti].deps;
        if deps.is_empty() {
            return Ok(());
        }
        let deadline = Instant::now() + WAIT_CAP;
        let mut st = plock(&self.progress);
        loop {
            if st.failed {
                return Err(Error::Engine("threaded: aborted".into()));
            }
            if deps.iter().all(|&d| st.done[d]) {
                return Ok(());
            }
            if Instant::now() > deadline {
                return Err(Error::Engine(format!(
                    "threaded: dependency wait timed out at task {ti} (deadlock?)"
                )));
            }
            st = self.cv.wait_timeout(st, POLL).unwrap_or_else(|p| p.into_inner()).0;
        }
    }

    /// This rank finished its share of a per-group task.
    fn finish_share(&self, ti: usize) {
        let mut st = plock(&self.progress);
        st.remaining[ti] -= 1;
        if st.remaining[ti] == 0 {
            st.done[ti] = true;
            self.cv.notify_all();
        }
    }

    /// The leader finished a global phase on behalf of every rank.
    fn finish_global(&self, ti: usize) {
        let mut st = plock(&self.progress);
        st.remaining[ti] = 0;
        st.done[ti] = true;
        self.cv.notify_all();
    }

    /// Block until `ti` is done (non-leader side of a global phase).
    fn wait_done(&self, ti: usize) -> Result<()> {
        let deadline = Instant::now() + WAIT_CAP;
        let mut st = plock(&self.progress);
        loop {
            if st.done[ti] {
                return Ok(());
            }
            if st.failed {
                return Err(Error::Engine("threaded: aborted".into()));
            }
            if Instant::now() > deadline {
                return Err(Error::Engine(format!(
                    "threaded: phase wait timed out at task {ti} (deadlock?)"
                )));
            }
            st = self.cv.wait_timeout(st, POLL).unwrap_or_else(|p| p.into_inner()).0;
        }
    }

    /// Record the first error and raise the abort flag.
    fn fail(&self, e: Error) {
        {
            let mut err = plock(&self.err);
            if err.is_none() {
                *err = Some(e);
            }
        }
        plock(&self.progress).failed = true;
        self.cv.notify_all();
    }

    /// Typed abort when a peer has already failed.
    fn check_failed(&self) -> Result<()> {
        if plock(&self.progress).failed {
            return Err(Error::Engine("threaded: aborted".into()));
        }
        Ok(())
    }

    /// Leader replica of [`Mesh::all_reduce`](crate::collectives::Mesh):
    /// reduce in group order, scatter to every member, identical wire/ops
    /// accounting. Runs only at the GradReduce barrier (all ranks parked).
    fn all_reduce_mesh(&self, group: &[usize], key: &str) -> Result<()> {
        if group.len() <= 1 {
            return Ok(());
        }
        let mut acc = self.lock_dev(group[0]).get(key)?.clone();
        for &d in &group[1..] {
            let t = self.lock_dev(d).get(key)?.clone();
            acc.add_assign(&t)?;
            self.wire.fetch_add(t.len() as u64, Ordering::Relaxed);
        }
        for &d in group {
            self.wire.fetch_add(acc.len() as u64, Ordering::Relaxed);
            self.lock_dev(d).put(key, acc.clone());
        }
        self.ops.fetch_add(1, Ordering::Relaxed);
        Ok(())
    }

    /// Leader replica of
    /// [`Mesh::all_reduce_region`](crate::collectives::Mesh) (hetero-TP
    /// shared-slice gradient sync), same reduction order and accounting.
    fn all_reduce_region_mesh(&self, parts: &[(usize, Region)], key: &str) -> Result<()> {
        if parts.len() <= 1 {
            return Ok(());
        }
        let (d0, r0) = &parts[0];
        let mut acc = extract_region(self.lock_dev(*d0).get(key)?, r0)?;
        for (d, r) in &parts[1..] {
            let piece = extract_region(self.lock_dev(*d).get(key)?, r)?;
            acc.add_assign(&piece)?;
            self.wire.fetch_add(piece.len() as u64, Ordering::Relaxed);
        }
        for (d, r) in parts {
            self.wire.fetch_add(acc.len() as u64, Ordering::Relaxed);
            write_region(self.lock_dev(*d).get_mut(key)?, r, &acc)?;
        }
        self.ops.fetch_add(1, Ordering::Relaxed);
        Ok(())
    }

    /// The token-weighted gradient reduction (leader-driven): the layout's
    /// cached sync plan in its fixed order, then the embedding/head
    /// reductions, then `1/total_tokens` scaling — byte-for-byte the
    /// single-thread `sync_gradients`.
    fn grad_reduce(&self) -> Result<()> {
        let mut tokens = 0u64;
        for &(_, n) in plock(&self.losses).values() {
            tokens += n;
        }
        if tokens == 0 {
            return Err(Error::Engine("train_step: no tokens processed".into()));
        }
        for op in &self.layout.sync_ops {
            match op {
                SyncOp::AllReduce { key, devs } => {
                    self.all_reduce_mesh(devs, self.layout.key(*key))?
                }
                SyncOp::SliceReduce { key, parts } => {
                    self.all_reduce_region_mesh(parts, self.layout.key(*key))?
                }
            }
        }
        self.all_reduce_mesh(&self.layout.first_roots, "grad.emb")?;
        self.all_reduce_mesh(&self.layout.last_roots, "grad.gf")?;
        self.all_reduce_mesh(&self.layout.last_roots, "grad.wout")?;
        let scale = 1.0 / tokens as f32;
        for (dev, key) in &self.layout.grad_keys {
            self.lock_dev(*dev).get_mut(self.layout.key(*key))?.scale(scale)?;
        }
        Ok(())
    }

    /// The ZeRO-1 updated-slice exchange (leader-driven), identical to
    /// `exchange_zero1_slices` including the one-grouped-op accounting.
    fn zero_exchange(&self) -> Result<()> {
        for g in &self.layout.zero_groups {
            let key = self.layout.key(g.key);
            for (owner, region) in &g.parts {
                let piece = extract_region(self.lock_dev(*owner).get(key)?, region)?;
                for &m in &g.members {
                    if m != *owner {
                        write_region(self.lock_dev(m).get_mut(key)?, region, &piece)?;
                        self.wire.fetch_add(piece.len() as u64, Ordering::Relaxed);
                    }
                }
            }
            self.ops.fetch_add(1, Ordering::Relaxed);
        }
        Ok(())
    }
}

/// Out-of-order message buffer: channels deliver in send order across
/// *all* peers, but a rank may legitimately receive (say) a GPipe
/// hand-off for micro-batch 3 while waiting on a TP partial for
/// micro-batch 1 — non-matching messages are stashed, not dropped.
struct Inbox {
    rx: Receiver<Msg>,
    stash: Vec<Msg>,
}

impl Inbox {
    fn recv_where(&mut self, sh: &Shared<'_>, pred: impl Fn(&Msg) -> bool) -> Result<Msg> {
        if let Some(i) = self.stash.iter().position(&pred) {
            return Ok(self.stash.remove(i));
        }
        let deadline = Instant::now() + WAIT_CAP;
        loop {
            match self.rx.recv_timeout(POLL) {
                Ok(m) if pred(&m) => return Ok(m),
                Ok(m) => self.stash.push(m),
                Err(RecvTimeoutError::Timeout) => {
                    sh.check_failed()?;
                    if Instant::now() > deadline {
                        return Err(Error::Engine(
                            "threaded: receive timed out (deadlock?)".into(),
                        ));
                    }
                }
                Err(RecvTimeoutError::Disconnected) => {
                    return Err(Error::Engine("threaded: peer channel closed".into()));
                }
            }
        }
    }
}

/// One rank thread: its plan position, mesh rank, the shared step state,
/// a sender per plan position, and the own receive buffer.
struct Worker<'s, 'e> {
    ri: usize,
    rank: usize,
    sh: &'s Shared<'e>,
    txs: Vec<Sender<Msg>>,
    inbox: Inbox,
    /// Thread-local kernel arena for fused block replay (DESIGN.md §12).
    /// Workers live one step, so the arena grows on the step's first
    /// fused call and is reused across this rank's micro-batches.
    ws: KernelWorkspace,
    /// Thread-local prepacked-panel cache, same lifetime. The compiled
    /// event-driven path keeps its caches across steps; here embed/head
    /// stay interpreted and panels repack per step — bit-identical either
    /// way, and the wall-clock contract (not zero-alloc) governs this
    /// executor.
    panels: PanelCache,
}

impl Worker<'_, '_> {
    /// Walk the own timeline in program order — the whole specialized
    /// program of this rank.
    fn run(&mut self) -> Result<()> {
        let sh = self.sh;
        for &ti in &sh.plan.ranks[self.ri].tasks {
            sh.jitter_sleep(ti, self.rank);
            sh.wait_deps(ti)?;
            // span opens after the dependency wait (idle shows as bubble,
            // in-task receive waits count as comm) and closes after the
            // post-actions (producer-side sends belong to the producer)
            let t0_s = sh.trace.is_some().then(|| sh.start.elapsed().as_secs_f64());
            let task = &sh.plan.tasks[ti];
            // the tape is index-aligned with the plan: op `ti` carries
            // the frozen keys/endpoints for task `ti`
            let cop = sh.prog.map(|p| &p.ops[ti]);
            // frozen fused-kernel lowering for this op, when the tape
            // carries one (block GEMMs replay the workspace drivers;
            // embed/head stay interpreted on this executor)
            let fc = sh.prog.and_then(|p| p.fused.get(ti).and_then(|f| f.as_ref()));
            match task.kind {
                SpecTaskKind::GradReduce | SpecTaskKind::ZeroExchange => {
                    self.global_phase(ti, &task.kind)?;
                }
                _ => {
                    match task.kind {
                        SpecTaskKind::FwdIn { pipe, stage, mb } => {
                            self.fwd_in(ti, pipe, stage, mb, cop)?
                        }
                        SpecTaskKind::FwdGemm { pipe, stage, mb, layer } => {
                            self.fwd_gemm(pipe, stage, mb, layer, cop, fc)?
                        }
                        SpecTaskKind::FwdTpSync { pipe, stage, mb, .. } => {
                            self.tp_sync(ti, pipe, stage, mb, true, cop)?
                        }
                        SpecTaskKind::BwdIn { pipe, stage, mb } => {
                            self.bwd_in(ti, pipe, stage, mb, cop)?
                        }
                        SpecTaskKind::BwdGemm { pipe, stage, mb, layer } => {
                            self.bwd_gemm(pipe, stage, mb, layer, cop, fc)?
                        }
                        SpecTaskKind::BwdTpSync { pipe, stage, mb, .. } => {
                            self.tp_sync(ti, pipe, stage, mb, false, cop)?
                        }
                        SpecTaskKind::EmbedBwd { pipe, mb } => self.embed_bwd(pipe, mb, cop)?,
                        SpecTaskKind::OptimStep => self.optim_step()?,
                        SpecTaskKind::GradReduce | SpecTaskKind::ZeroExchange => {
                            unreachable!("global phases handled above")
                        }
                    }
                    sh.finish_share(ti);
                }
            }
            self.post_actions(ti)?;
            if let (Some(t0_s), Some(bufs)) = (t0_s, sh.trace.as_ref()) {
                plock(&bufs[self.ri]).push(Span {
                    task: ti as u32,
                    // fused block GEMMs carry the tape's frozen fused span
                    // kind, so the trace shows which ops ran fused
                    kind: match (fc, SpanKind::of_task(&task.kind)) {
                        (Some(_), SpanKind::FwdGemm) => SpanKind::FwdGemmFused,
                        (Some(_), SpanKind::BwdGemm) => SpanKind::BwdGemmFused,
                        (_, k) => k,
                    },
                    rank: self.rank as u32,
                    t0_s,
                    t1_s: sh.start.elapsed().as_secs_f64(),
                });
            }
        }
        Ok(())
    }

    fn send_to(&self, rank: usize, msg: Msg) {
        // a closed peer means the step is already failing; the abort flag
        // carries the error, so a dead letter is fine
        let _ = self.txs[self.sh.pos_of(rank)].send(msg);
    }

    fn recv_partial(&mut self, ti: usize, from: usize) -> Result<HostTensor> {
        let m = self.inbox.recv_where(self.sh, |m| {
            matches!(m, Msg::Partial { task, from: f, .. } if *task == ti && *f == from)
        })?;
        match m {
            Msg::Partial { t, .. } => Ok(t),
            _ => unreachable!("predicate admits only partials"),
        }
    }

    fn recv_result(&mut self, ti: usize) -> Result<HostTensor> {
        let m = self
            .inbox
            .recv_where(self.sh, |m| matches!(m, Msg::Result { task, .. } if *task == ti))?;
        match m {
            Msg::Result { t, .. } => Ok(t),
            _ => unreachable!("predicate admits only results"),
        }
    }

    fn recv_handoff(&mut self, ti: usize) -> Result<HostTensor> {
        let m = self
            .inbox
            .recv_where(self.sh, |m| matches!(m, Msg::Handoff { task, .. } if *task == ti))?;
        match m {
            Msg::Handoff { t, .. } => Ok(t),
            _ => unreachable!("predicate admits only hand-offs"),
        }
    }

    /// Root-fanout broadcast over the stage's TP group, with
    /// [`Mesh::broadcast`](crate::collectives::Mesh) accounting (one op
    /// always, wire per non-root member).
    fn broadcast_group(&self, ti: usize, devices: &[usize], key: &str) -> Result<()> {
        let sh = self.sh;
        let t = sh.lock_dev(self.rank).get(key)?.clone();
        for &d in devices {
            if d != self.rank {
                sh.wire.fetch_add(t.len() as u64, Ordering::Relaxed);
                self.send_to(d, Msg::Result { task: ti, t: t.clone() });
            }
        }
        sh.ops.fetch_add(1, Ordering::Relaxed);
        Ok(())
    }

    /// [`SpecTaskKind::FwdIn`]: stage 0 embeds on the root, later stages'
    /// roots await the producer's [`Msg::Handoff`]; the root then
    /// broadcasts to the TP members, who just install the copy.
    fn fwd_in(
        &mut self,
        ti: usize,
        pi: usize,
        si: usize,
        mb: usize,
        cop: Option<&CompiledOp>,
    ) -> Result<()> {
        let sh = self.sh;
        let stage = &sh.pipelines[pi].stages[si];
        let akey = key_or(sh.prog, cop.and_then(|o| o.act_key()), || Engine::akey(pi, mb));
        if self.rank == stage.devices[0] {
            if si == 0 {
                let batch = &sh.batches[pi][mb];
                let tok = HostTensor::i32(
                    vec![batch.n_seqs, batch.seq_len],
                    batch.tokens.clone(),
                )?;
                let mut dev = sh.lock_dev(self.rank);
                let x0 = {
                    let emb = dev.get("emb")?;
                    native::call(&sh.cfg, "embed_fwd", &[emb, &tok])?
                        .into_iter()
                        .next()
                        .unwrap()
                };
                dev.put(&akey, x0);
            } else {
                let x = self.recv_handoff(ti)?;
                sh.lock_dev(self.rank).put(&akey, x);
            }
            self.broadcast_group(ti, &stage.devices, &akey)?;
        } else {
            let x = self.recv_result(ti)?;
            sh.lock_dev(self.rank).put(&akey, x);
        }
        Ok(())
    }

    /// [`SpecTaskKind::FwdGemm`]: save the block input for recompute,
    /// then the own partial forward GEMMs — all on the own device. With a
    /// frozen [`FusedCall`] the partial runs through the fused workspace
    /// driver (prepacked panels, one carved arena) — bit-identical to the
    /// artifact call.
    fn fwd_gemm(
        &mut self,
        pi: usize,
        si: usize,
        mb: usize,
        l: u32,
        cop: Option<&CompiledOp>,
        fc: Option<&FusedCall>,
    ) -> Result<()> {
        let sh = self.sh;
        let stage = &sh.pipelines[pi].stages[si];
        let akey = key_or(sh.prog, cop.and_then(|o| o.act_key()), || Engine::akey(pi, mb));
        let skey = key_or(sh.prog, cop.and_then(|o| o.save_key()), || Engine::skey(pi, mb, l));
        if let (Some(prog), Some(fc), Some(ids)) =
            (sh.prog, fc, cop.and_then(|o| o.param_keys()))
        {
            let dims = fc.dims;
            let nh = dims.n * dims.h;
            let mut dev = sh.lock_dev(self.rank);
            let x = dev.get(&akey)?.clone();
            dev.put(&skey, x);
            for &pk in ids.iter() {
                self.panels.ensure(pk.index(), dev.get(prog.key(pk))?.as_f32()?);
            }
            {
                let panels = &self.panels;
                let p: [&[f32]; 8] = std::array::from_fn(|i| panels.get(ids[i].index()));
                let wsbuf = self.ws.slice(fc.ws_floats);
                let (ybuf, rest) = wsbuf.split_at_mut(nh);
                let x = dev.get(&akey)?.as_f32()?;
                block_fwd_ws(&dims, &p, x, ybuf, rest);
            }
            let y_part =
                HostTensor::f32(vec![dims.b, dims.s, dims.h], self.ws.data()[..nh].to_vec())?;
            dev.put("part", y_part);
            return Ok(());
        }
        let art = key_or(sh.prog, cop.and_then(|o| o.artifact()), || {
            format!("block_fwd_tp{}", stage.tp())
        });
        let pk_owned: [String; 8];
        let pkeys: [&str; 8] = match (sh.prog, cop.and_then(|o| o.param_keys())) {
            (Some(p), Some(ids)) => ids.map(|id| p.key(id)),
            _ => {
                pk_owned = std::array::from_fn(|i| pkey(l, BLOCK_PARAMS[i]));
                std::array::from_fn(|i| pk_owned[i].as_str())
            }
        };
        let mut dev = sh.lock_dev(self.rank);
        let x = dev.get(&akey)?.clone();
        dev.put(&skey, x);
        let y_part = {
            let mut inputs: Vec<&HostTensor> = Vec::with_capacity(9);
            for p in pkeys {
                inputs.push(dev.get(p)?);
            }
            inputs.push(dev.get(&akey)?);
            native::call(&sh.cfg, &art, &inputs)?.into_iter().next().unwrap()
        };
        dev.put("part", y_part);
        Ok(())
    }

    /// [`SpecTaskKind::FwdTpSync`]/[`SpecTaskKind::BwdTpSync`]: the TP
    /// partial-sum all-reduce as messages. The group leader gathers
    /// [`Msg::Partial`]s **in group order** (fixed reduction order),
    /// scatters the sum, and every member adds it into the running
    /// activation/gradient — wire/ops accounting exactly as
    /// [`Mesh::all_reduce`](crate::collectives::Mesh).
    fn tp_sync(
        &mut self,
        ti: usize,
        pi: usize,
        si: usize,
        mb: usize,
        fwd: bool,
        cop: Option<&CompiledOp>,
    ) -> Result<()> {
        let sh = self.sh;
        let stage = &sh.pipelines[pi].stages[si];
        let group = &stage.devices;
        let (part_key, xkey) = if fwd {
            ("part", key_or(sh.prog, cop.and_then(|o| o.act_key()), || Engine::akey(pi, mb)))
        } else {
            ("dpart", key_or(sh.prog, cop.and_then(|o| o.grad_key()), || Engine::dkey(pi, mb)))
        };
        if group.len() <= 1 {
            // degenerate group: the mesh all-reduce is a no-op (no wire,
            // no op), only the local residual add remains
            let mut dev = sh.lock_dev(self.rank);
            let part = dev.get(part_key)?.clone();
            dev.get_mut(&xkey)?.add_assign(&part)?;
            return Ok(());
        }
        let leader = group[0];
        if self.rank == leader {
            let mut acc = sh.lock_dev(self.rank).get(part_key)?.clone();
            for &r in &group[1..] {
                let t = self.recv_partial(ti, r)?;
                acc.add_assign(&t)?;
                sh.wire.fetch_add(t.len() as u64, Ordering::Relaxed);
            }
            for &r in group.iter() {
                sh.wire.fetch_add(acc.len() as u64, Ordering::Relaxed);
                if r != leader {
                    self.send_to(r, Msg::Result { task: ti, t: acc.clone() });
                }
            }
            sh.ops.fetch_add(1, Ordering::Relaxed);
            let mut dev = sh.lock_dev(self.rank);
            dev.put(part_key, acc.clone());
            dev.get_mut(&xkey)?.add_assign(&acc)?;
        } else {
            let part = sh.lock_dev(self.rank).get(part_key)?.clone();
            self.send_to(leader, Msg::Partial { task: ti, from: self.rank, t: part });
            let acc = self.recv_result(ti)?;
            let mut dev = sh.lock_dev(self.rank);
            dev.put(part_key, acc.clone());
            dev.get_mut(&xkey)?.add_assign(&acc)?;
        }
        Ok(())
    }

    /// [`SpecTaskKind::BwdIn`]: the last stage's root runs the fused head
    /// (loss + token-scaled head gradients) and every member frees its own
    /// stage activation; earlier stages' roots await the gradient
    /// hand-off. Both broadcast the incoming gradient over the group.
    fn bwd_in(
        &mut self,
        ti: usize,
        pi: usize,
        si: usize,
        mb: usize,
        cop: Option<&CompiledOp>,
    ) -> Result<()> {
        let sh = self.sh;
        let pipe = &sh.pipelines[pi];
        let stage = &pipe.stages[si];
        let last = pipe.stages.len() - 1;
        let akey = key_or(sh.prog, cop.and_then(|o| o.act_key()), || Engine::akey(pi, mb));
        let dkey = key_or(sh.prog, cop.and_then(|o| o.grad_key()), || Engine::dkey(pi, mb));
        if self.rank == stage.devices[0] {
            if si == last {
                let batch = &sh.batches[pi][mb];
                let tokens = batch.real_tokens();
                let w = tokens as f32;
                let tgt = HostTensor::i32(
                    vec![batch.n_seqs, batch.seq_len],
                    batch.targets.clone(),
                )?;
                let loss = {
                    let mut dev = sh.lock_dev(self.rank);
                    let (loss, mut dx, mut dgf, mut dwout) = {
                        let out = native::call(
                            &sh.cfg,
                            "head_step",
                            &[dev.get("gf")?, dev.get("wout")?, dev.get(&akey)?, &tgt],
                        )?;
                        let mut it = out.into_iter();
                        let loss = it.next().unwrap().as_f32()?[0];
                        (loss, it.next().unwrap(), it.next().unwrap(), it.next().unwrap())
                    };
                    dx.scale(w)?;
                    dgf.scale(w)?;
                    dwout.scale(w)?;
                    accumulate(&mut dev, "grad.gf", dgf)?;
                    accumulate(&mut dev, "grad.wout", dwout)?;
                    dev.put(&dkey, dx);
                    let _ = dev.take(&akey);
                    loss
                };
                plock(&sh.losses).insert((pi, mb), (loss, tokens));
            } else {
                let dx = self.recv_handoff(ti)?;
                sh.lock_dev(self.rank).put(&dkey, dx);
            }
            self.broadcast_group(ti, &stage.devices, &dkey)?;
        } else {
            let dx = self.recv_result(ti)?;
            let mut dev = sh.lock_dev(self.rank);
            if si == last {
                let _ = dev.take(&akey);
            }
            dev.put(&dkey, dx);
        }
        Ok(())
    }

    /// [`SpecTaskKind::BwdGemm`]: the own backward GEMMs for one layer,
    /// gradient accumulation, and the saved-input free. With a frozen
    /// [`FusedCall`] the layer replays the fused workspace driver.
    fn bwd_gemm(
        &mut self,
        pi: usize,
        si: usize,
        mb: usize,
        l: u32,
        cop: Option<&CompiledOp>,
        fc: Option<&FusedCall>,
    ) -> Result<()> {
        let sh = self.sh;
        let stage = &sh.pipelines[pi].stages[si];
        let dkey = key_or(sh.prog, cop.and_then(|o| o.grad_key()), || Engine::dkey(pi, mb));
        let skey = key_or(sh.prog, cop.and_then(|o| o.save_key()), || Engine::skey(pi, mb, l));
        if let (Some(prog), Some(fc), Some(ids), Some(gids)) = (
            sh.prog,
            fc,
            cop.and_then(|o| o.param_keys()),
            cop.and_then(|o| o.grad_param_keys()),
        ) {
            let dims = fc.dims;
            let nh = dims.n * dims.h;
            let mut dev = sh.lock_dev(self.rank);
            for &pk in ids.iter() {
                self.panels.ensure(pk.index(), dev.get(prog.key(pk))?.as_f32()?);
            }
            let (dx_t, grads_t) = {
                let panels = &self.panels;
                let p: [&[f32]; 8] = std::array::from_fn(|i| panels.get(ids[i].index()));
                let wsbuf = self.ws.slice(fc.ws_floats);
                let (dxbuf, rest) = wsbuf.split_at_mut(nh);
                let x = dev.get(&skey)?.as_f32()?;
                let dy = dev.get(&dkey)?.as_f32()?;
                let g = block_bwd_ws(&dims, &p, x, dy, dxbuf, rest);
                let mut grads_t: Vec<HostTensor> = Vec::with_capacity(8);
                for i in 0..8 {
                    grads_t.push(HostTensor::f32(grad_shape(&dims, i), g.by_index(i).to_vec())?);
                }
                (HostTensor::f32(vec![dims.b, dims.s, dims.h], dxbuf.to_vec())?, grads_t)
            };
            dev.put("dpart", dx_t);
            for (&gk, gt) in gids.iter().zip(grads_t) {
                accumulate(&mut dev, prog.key(gk), gt)?;
            }
            let _ = dev.take(&skey);
            return Ok(());
        }
        let art = key_or(sh.prog, cop.and_then(|o| o.artifact()), || {
            format!("block_bwd_tp{}", stage.tp())
        });
        let pk_owned: [String; 8];
        let pkeys: [&str; 8] = match (sh.prog, cop.and_then(|o| o.param_keys())) {
            (Some(p), Some(ids)) => ids.map(|id| p.key(id)),
            _ => {
                pk_owned = std::array::from_fn(|i| pkey(l, BLOCK_PARAMS[i]));
                std::array::from_fn(|i| pk_owned[i].as_str())
            }
        };
        let gk_owned: [String; 8];
        let gkeys: [&str; 8] = match (sh.prog, cop.and_then(|o| o.grad_param_keys())) {
            (Some(p), Some(ids)) => ids.map(|id| p.key(id)),
            _ => {
                gk_owned = std::array::from_fn(|i| gkey(l, BLOCK_PARAMS[i]));
                std::array::from_fn(|i| gk_owned[i].as_str())
            }
        };
        let mut dev = sh.lock_dev(self.rank);
        let outs = {
            let mut inputs: Vec<&HostTensor> = Vec::with_capacity(10);
            for p in pkeys {
                inputs.push(dev.get(p)?);
            }
            inputs.push(dev.get(&skey)?);
            inputs.push(dev.get(&dkey)?);
            native::call(&sh.cfg, &art, &inputs)?
        };
        let mut it = outs.into_iter();
        let dx_part = it.next().unwrap();
        dev.put("dpart", dx_part);
        for gk in gkeys {
            accumulate(&mut dev, gk, it.next().unwrap())?;
        }
        let _ = dev.take(&skey);
        Ok(())
    }

    /// [`SpecTaskKind::EmbedBwd`]: the root accumulates the embedding
    /// gradient; every member frees its own incoming-gradient copy.
    fn embed_bwd(&mut self, pi: usize, mb: usize, cop: Option<&CompiledOp>) -> Result<()> {
        let sh = self.sh;
        let stage = &sh.pipelines[pi].stages[0];
        let dkey = key_or(sh.prog, cop.and_then(|o| o.grad_key()), || Engine::dkey(pi, mb));
        let mut dev = sh.lock_dev(self.rank);
        if self.rank == stage.devices[0] {
            let batch = &sh.batches[pi][mb];
            let tok =
                HostTensor::i32(vec![batch.n_seqs, batch.seq_len], batch.tokens.clone())?;
            let demb = {
                let dx0 = dev.get(&dkey)?;
                native::call(&sh.cfg, "embed_bwd", &[&tok, dx0])?
                    .into_iter()
                    .next()
                    .unwrap()
            };
            accumulate(&mut dev, "grad.emb", demb)?;
        }
        let _ = dev.take(&dkey);
        Ok(())
    }

    /// [`SpecTaskKind::OptimStep`]: AdamW on the own shards, walking the
    /// layout's update list in its fixed order restricted to this rank —
    /// per-device order identical to `apply_updates_local`.
    fn optim_step(&mut self) -> Result<()> {
        let sh = self.sh;
        let step = sh.step + 1;
        let mut dev = sh.lock_dev(self.rank);
        for (d, param_key, grad_key) in &sh.layout.update_ops {
            if *d != self.rank {
                continue;
            }
            let (pk, gk) = (sh.layout.key(*param_key), sh.layout.key(*grad_key));
            if !sh.zero1 {
                sh.opt.update(&mut dev, pk, gk, step)?;
                continue;
            }
            match sh.layout.zero_part_id(*d, *param_key) {
                Some(Some(region)) => {
                    sh.opt.update_region(&mut dev, pk, gk, region, step)?
                }
                Some(None) => {
                    let _ = dev.take(gk);
                }
                None => sh.opt.update(&mut dev, pk, gk, step)?,
            }
        }
        Ok(())
    }

    /// A global phase: position 0 is the leader and executes the whole
    /// phase (its dependency edges cover every backward tail, so all
    /// other ranks have drained their timelines and parked); everyone
    /// else waits on the barrier.
    fn global_phase(&mut self, ti: usize, kind: &SpecTaskKind) -> Result<()> {
        let sh = self.sh;
        if self.ri == 0 {
            match kind {
                SpecTaskKind::GradReduce => sh.grad_reduce()?,
                SpecTaskKind::ZeroExchange => sh.zero_exchange()?,
                _ => unreachable!("global_phase on a per-group task"),
            }
            sh.finish_global(ti);
            Ok(())
        } else {
            sh.wait_done(ti)
        }
    }

    /// Fire the hand-off/free post-actions attached to this rank's share
    /// of a producer-side tail task (send accounting = `Mesh::send`).
    fn post_actions(&mut self, ti: usize) -> Result<()> {
        let sh = self.sh;
        let Some(actions) = sh.post.get(&(self.rank, ti)) else {
            return Ok(());
        };
        for a in actions {
            match a {
                PostAction::Send { handoff, key, to } => {
                    let t = sh.lock_dev(self.rank).take(key)?;
                    sh.wire.fetch_add(t.len() as u64, Ordering::Relaxed);
                    sh.ops.fetch_add(1, Ordering::Relaxed);
                    self.send_to(*to, Msg::Handoff { task: *handoff, t });
                }
                PostAction::Drop { key } => {
                    let _ = sh.lock_dev(self.rank).take(key);
                }
            }
        }
        Ok(())
    }
}

/// Derive the post-action table from the plan's hand-off edges: the
/// producer root sends the boundary tensor to the consumer root after its
/// share of the producer tail; the other producers free their dead copies
/// (the event-driven executor's frees, relocated to the sending side).
fn build_post(plan: &SpecializedPlan) -> Result<BTreeMap<(usize, usize), Vec<PostAction>>> {
    let mut post: BTreeMap<(usize, usize), Vec<PostAction>> = BTreeMap::new();
    for e in plan.handoff_edges()? {
        let key = match plan.tasks[e.task].kind {
            SpecTaskKind::FwdIn { pipe, mb, .. } => Engine::akey(pipe, mb),
            SpecTaskKind::BwdIn { pipe, mb, .. } => Engine::dkey(pipe, mb),
            ref k => {
                return Err(Error::Engine(format!(
                    "threaded: hand-off edge on non-boundary task {k:?}"
                )))
            }
        };
        post.entry((e.producers[0], e.producer_tail)).or_default().push(PostAction::Send {
            handoff: e.task,
            key: key.clone(),
            to: e.consumer_root,
        });
        for &d in &e.producers[1..] {
            post.entry((d, e.producer_tail))
                .or_default()
                .push(PostAction::Drop { key: key.clone() });
        }
    }
    Ok(post)
}

impl Engine {
    /// Execute a specialized step **concurrently**: one OS thread per
    /// rank, comm tasks as typed channel messages, wall-clock elapsed time
    /// as the makespan. Dispatch target of
    /// [`Engine::run_specialized`](Engine::run_specialized) under
    /// [`ExecMode::Threaded`](super::ExecMode) (`prog: None`) and
    /// [`ExecMode::CompiledThreaded`](super::ExecMode) (`prog` carries
    /// the index-aligned compiled tape, so each worker replays its rank's
    /// frozen ops — no per-task key formatting); numerics and wire
    /// accounting are bit-identical to the event-driven executor and the
    /// reference interpreter (module docs lay out the contract).
    pub(crate) fn run_specialized_threaded(
        &mut self,
        plan: &SpecializedPlan,
        pipelines: &[EnginePipeline],
        batches: &[Vec<MicroBatch>],
        deliveries: &[(usize, f64)],
        prog: Option<&CompiledProgram>,
    ) -> Result<SpecRunOutcome> {
        if !self.runtime.is_native() {
            return Err(Error::Engine(
                "threaded executor requires the native backend (the PJRT client is \
                 single-thread)"
                    .into(),
            ));
        }
        // a tape only replays against the exact plan it froze
        let prog = prog.filter(|p| p.ops.len() == plan.tasks.len());
        let post = build_post(plan)?;
        let n = plan.tasks.len();
        let nranks = plan.ranks.len();
        let cfg = self.runtime.config;
        let opt = self.opt;
        let zero1 = self.zero1;
        let step = self.step;
        let jitter = self.exec_jitter;
        // move every device store behind its own lock for the thread scope
        let devs: Vec<Mutex<DeviceMem>> =
            self.mesh.devices.iter_mut().map(|d| Mutex::new(std::mem::take(d))).collect();
        let layout: &ShardLayout = &self.layout;
        // per-rank span buffers, preallocated to each rank's task count
        let trace: Option<Vec<Mutex<Vec<Span>>>> = self.trace_on.then(|| {
            plan.ranks.iter().map(|rp| Mutex::new(Vec::with_capacity(rp.tasks.len()))).collect()
        });
        // the recorder holds last step's spans; rewind it now so an
        // untraced or failed threaded step never reports stale spans
        self.recorder.begin_step(0, false);
        let t0 = Instant::now();
        let mut shared = Shared {
            plan,
            prog,
            pipelines,
            batches,
            layout,
            devs: &devs,
            post,
            cfg,
            opt,
            zero1,
            step,
            jitter,
            progress: Mutex::new(Progress {
                done: vec![false; n],
                remaining: plan.tasks.iter().map(|t| t.ranks.len()).collect(),
                failed: false,
            }),
            cv: Condvar::new(),
            start: t0,
            trace,
            losses: Mutex::new(BTreeMap::new()),
            err: Mutex::new(None),
            wire: AtomicU64::new(0),
            ops: AtomicU64::new(0),
        };

        let mut txs: Vec<Sender<Msg>> = Vec::with_capacity(nranks);
        let mut rxs: Vec<Receiver<Msg>> = Vec::with_capacity(nranks);
        for _ in 0..nranks {
            let (tx, rx) = channel();
            txs.push(tx);
            rxs.push(rx);
        }

        std::thread::scope(|scope| {
            let mut handles = Vec::with_capacity(nranks);
            for (ri, rx) in rxs.into_iter().enumerate() {
                let rank = plan.ranks[ri].rank;
                let txs = txs.clone();
                let sh = &shared;
                handles.push(scope.spawn(move || {
                    let mut w = Worker {
                        ri,
                        rank,
                        sh,
                        txs,
                        inbox: Inbox { rx, stash: vec![] },
                        ws: KernelWorkspace::default(),
                        panels: PanelCache::default(),
                    };
                    if let Err(e) = w.run() {
                        sh.fail(e);
                    }
                }));
            }
            drop(txs); // workers own the only senders: exit ⇒ disconnect
            for h in handles {
                if h.join().is_err() {
                    shared.fail(Error::Engine("threaded: worker panicked".into()));
                }
            }
        });
        let makespan_s = t0.elapsed().as_secs_f64();

        let wire = shared.wire.load(Ordering::Relaxed);
        let ops = shared.ops.load(Ordering::Relaxed);
        let losses = std::mem::take(&mut *plock(&shared.losses));
        let err = plock(&shared.err).take();
        let trace_bufs = shared.trace.take();
        drop(shared);
        // always restore the device stores (and the accounting) before
        // surfacing any error — the mesh must stay usable
        for (d, m) in self.mesh.devices.iter_mut().zip(devs) {
            *d = m.into_inner().unwrap_or_else(|p| p.into_inner());
        }
        self.mesh.wire_elems += wire;
        self.mesh.ops += ops;
        if let Some(e) = err {
            return Err(e);
        }
        // fold the per-rank wall spans into the engine recorder so the
        // downstream consumers (breakdown, Chrome export) see one ring
        if let Some(bufs) = trace_bufs {
            let spans: Vec<Vec<Span>> = bufs
                .into_iter()
                .map(|m| m.into_inner().unwrap_or_else(|p| p.into_inner()))
                .collect();
            self.recorder.begin_step(spans.iter().map(Vec::len).sum(), true);
            for buf in &spans {
                for &s in buf {
                    self.recorder.record_span(s);
                }
            }
        }

        let mut tokens = 0u64;
        for &(_, n_tok) in losses.values() {
            tokens += n_tok;
        }
        // f64 loss accumulation in the interpreter's order (pipeline-
        // major, head-retirement within each pipeline) — bit-identical
        let mut weighted_loss = 0f64;
        for (pi, order) in plan.head_order.iter().enumerate() {
            let mut wp = 0f64;
            for &mb in order {
                if let Some(&(loss, n_tok)) = losses.get(&(pi, mb)) {
                    wp += loss as f64 * n_tok as f64;
                }
            }
            weighted_loss += wp;
        }

        // §6.2 measured interleave over *wall-clock* makespan: per-sender
        // delivery lanes, exposure = overhang beyond the step
        let mut lanes: BTreeMap<usize, f64> = BTreeMap::new();
        for &(sender, secs) in deliveries {
            *lanes.entry(sender).or_insert(0.0) += secs.max(0.0);
        }
        let delivery_lane_s = lanes.values().copied().fold(0.0, f64::max);
        let exposed_switch_s = (delivery_lane_s - makespan_s).max(0.0);
        debug_assert!({
            // lane-wise exposure stays within the scalar overlap bound
            let mut bound = SwitchOverlap::new();
            for &(_, secs) in deliveries {
                bound.on_switch(secs);
            }
            exposed_switch_s <= bound.on_step(makespan_s) + 1e-9
        });
        Ok(SpecRunOutcome {
            weighted_loss,
            tokens,
            makespan_s,
            exposed_switch_s,
            delivery_lane_s,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::{EngineStrategy, ExecMode, StepStats};
    use crate::runtime::Runtime;
    use crate::spec::schedule::ScheduleKind;
    use crate::testutil::Rng;

    fn engine(s: &EngineStrategy) -> Engine {
        Engine::with_runtime(Runtime::native(native::tiny_config()), s.clone(), 11, 1e-3)
            .unwrap()
    }

    fn batch(seed: u64) -> MicroBatch {
        let cfg = native::tiny_config();
        let mut rng = Rng::new(seed);
        let n = cfg.batch * cfg.seq;
        let mut tokens = Vec::with_capacity(n);
        let mut targets = Vec::with_capacity(n);
        for _ in 0..n {
            tokens.push((rng.f64() * cfg.vocab as f64) as i32);
            targets.push((rng.f64() * cfg.vocab as f64) as i32);
        }
        MicroBatch { tokens, targets, n_seqs: cfg.batch, seq_len: cfg.seq }
    }

    fn step(eng: &mut Engine, salt: u64) -> StepStats {
        eng.train_step(&mut |pi, mb| batch(salt ^ ((pi as u64) << 8) ^ mb as u64)).unwrap()
    }

    fn assert_stats_match(a: &StepStats, b: &StepStats) {
        assert_eq!(a.loss.to_bits(), b.loss.to_bits(), "loss bits diverge");
        assert_eq!(a.tokens, b.tokens);
        assert_eq!(a.wire_elems, b.wire_elems, "wire accounting diverges");
        assert_eq!(a.comm_ops, b.comm_ops, "comm-op accounting diverges");
    }

    #[test]
    fn threaded_matches_reference_dp2tp2() {
        let s = EngineStrategy::uniform("dp2tp2", 2, 2, 1, 8, 2);
        let mut thr = engine(&s);
        thr.set_exec_mode(ExecMode::Threaded);
        let mut refr = engine(&s);
        for k in 0..2u64 {
            let a = step(&mut thr, 900 + k);
            let b = refr
                .train_step_reference(&mut |pi, mb| {
                    batch((900 + k) ^ ((pi as u64) << 8) ^ mb as u64)
                })
                .unwrap();
            assert_stats_match(&a, &b);
        }
    }

    #[test]
    fn threaded_matches_reference_pp2_1f1b() {
        let s = EngineStrategy::uniform("pp2", 1, 1, 2, 8, 3)
            .with_schedule(ScheduleKind::OneFOneB);
        let mut thr = engine(&s);
        thr.set_exec_mode(ExecMode::Threaded);
        let mut refr = engine(&s);
        for k in 0..2u64 {
            let a = step(&mut thr, 40 + k);
            let b = refr
                .train_step_reference(&mut |pi, mb| {
                    batch((40 + k) ^ ((pi as u64) << 8) ^ mb as u64)
                })
                .unwrap();
            assert_stats_match(&a, &b);
        }
    }

    #[test]
    fn threaded_matches_event_driven_with_zero1_and_jitter() {
        let s = EngineStrategy::uniform("dp2pp2", 2, 1, 2, 8, 2);
        let mut thr = engine(&s);
        thr.set_zero1(true).unwrap();
        thr.set_exec_mode(ExecMode::Threaded);
        thr.set_exec_jitter(Some(7));
        let mut evd = engine(&s);
        evd.set_zero1(true).unwrap();
        for k in 0..2u64 {
            let a = step(&mut thr, 77 + k);
            let b = step(&mut evd, 77 + k);
            assert_stats_match(&a, &b);
        }
    }

    #[test]
    fn compiled_threaded_matches_reference_dp2tp2() {
        let s = EngineStrategy::uniform("dp2tp2", 2, 2, 1, 8, 2);
        let mut thr = engine(&s);
        thr.set_exec_mode(ExecMode::CompiledThreaded);
        let mut refr = engine(&s);
        for k in 0..2u64 {
            let a = step(&mut thr, 310 + k);
            let b = refr
                .train_step_reference(&mut |pi, mb| {
                    batch((310 + k) ^ ((pi as u64) << 8) ^ mb as u64)
                })
                .unwrap();
            assert_stats_match(&a, &b);
        }
        assert!(thr.compiled_cached().is_some(), "tape cached after compiled steps");
    }

    #[test]
    fn splitmix_is_deterministic() {
        assert_eq!(splitmix64(42), splitmix64(42));
        assert_ne!(splitmix64(42), splitmix64(43));
        assert_ne!(splitmix64(0), 0);
    }
}
