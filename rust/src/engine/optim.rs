//! AdamW optimizer, applied by each device to its local parameter shards.
//!
//! Elementwise math runs in Rust (f32): the optimizer has no matmuls, so
//! keeping it on the L3 side avoids one AOT artifact per distinct parameter
//! shape while preserving the "Python never on the training path" property.

use crate::collectives::{extract_region, write_region, DeviceMem};
use crate::hspmd::slices::Region;
use crate::runtime::HostTensor;
use crate::Result;

/// AdamW with decoupled weight decay.
#[derive(Clone, Copy, Debug)]
pub struct AdamW {
    /// Learning rate.
    pub lr: f32,
    /// β1.
    pub beta1: f32,
    /// β2.
    pub beta2: f32,
    /// ε.
    pub eps: f32,
    /// Decoupled weight decay.
    pub weight_decay: f32,
}

impl AdamW {
    /// Default hyperparameters at a given learning rate.
    pub fn new(lr: f32) -> AdamW {
        AdamW { lr, beta1: 0.9, beta2: 0.95, eps: 1e-8, weight_decay: 0.01 }
    }

    /// Update `param_key` on `dev` using `grad_key` (consumed). Moments are
    /// lazily initialized as `m.<param>` / `v.<param>`. No-op if the grad
    /// is absent (device does not own this parameter).
    pub fn update(&self, dev: &mut DeviceMem, param_key: &str, grad_key: &str, step: u64) -> Result<()> {
        if !dev.has(grad_key) {
            return Ok(());
        }
        let grad = dev.take(grad_key)?;
        let mkey = format!("m.{param_key}");
        let vkey = format!("v.{param_key}");
        if !dev.has(&mkey) {
            dev.put(&mkey, HostTensor::zeros(grad.shape.clone()));
            dev.put(&vkey, HostTensor::zeros(grad.shape.clone()));
        }
        let g = grad.as_f32()?;
        let bc1 = 1.0 - self.beta1.powi(step as i32);
        let bc2 = 1.0 - self.beta2.powi(step as i32);

        // split borrows: take moments out, update, put back
        let mut m = dev.take(&mkey)?;
        let mut v = dev.take(&vkey)?;
        {
            let mm = m.as_f32_mut()?;
            let vv = v.as_f32_mut()?;
            let p = dev.get_mut(param_key)?.as_f32_mut()?;
            for i in 0..g.len() {
                mm[i] = self.beta1 * mm[i] + (1.0 - self.beta1) * g[i];
                vv[i] = self.beta2 * vv[i] + (1.0 - self.beta2) * g[i] * g[i];
                let mhat = mm[i] / bc1;
                let vhat = vv[i] / bc2;
                p[i] -= self.lr * (mhat / (vhat.sqrt() + self.eps) + self.weight_decay * p[i]);
            }
        }
        dev.put(&mkey, m);
        dev.put(&vkey, v);
        Ok(())
    }

    /// ZeRO-1 update: apply AdamW only to `region` (the device's DP
    /// partition, in the shard's local coordinates). Moments are stored
    /// partition-sized under the usual `m.*`/`v.*` keys; the gradient is
    /// consumed whole (the rest of it belongs to other partition owners).
    /// Because AdamW is elementwise and the synchronized gradient is equal
    /// across replicas, the partitioned update is bit-identical to the
    /// replicated one.
    pub fn update_region(
        &self,
        dev: &mut DeviceMem,
        param_key: &str,
        grad_key: &str,
        region: &Region,
        step: u64,
    ) -> Result<()> {
        if !dev.has(grad_key) {
            return Ok(());
        }
        let grad = dev.take(grad_key)?;
        let g_part = extract_region(&grad, region)?;
        let g = g_part.as_f32()?;
        let mkey = format!("m.{param_key}");
        let vkey = format!("v.{param_key}");
        if !dev.has(&mkey) {
            dev.put(&mkey, HostTensor::zeros(g_part.shape.clone()));
            dev.put(&vkey, HostTensor::zeros(g_part.shape.clone()));
        }
        let bc1 = 1.0 - self.beta1.powi(step as i32);
        let bc2 = 1.0 - self.beta2.powi(step as i32);

        let mut m = dev.take(&mkey)?;
        let mut v = dev.take(&vkey)?;
        let mut p_part = extract_region(dev.get(param_key)?, region)?;
        {
            let mm = m.as_f32_mut()?;
            let vv = v.as_f32_mut()?;
            let p = p_part.as_f32_mut()?;
            for i in 0..g.len() {
                mm[i] = self.beta1 * mm[i] + (1.0 - self.beta1) * g[i];
                vv[i] = self.beta2 * vv[i] + (1.0 - self.beta2) * g[i] * g[i];
                let mhat = mm[i] / bc1;
                let vhat = vv[i] / bc2;
                p[i] -= self.lr * (mhat / (vhat.sqrt() + self.eps) + self.weight_decay * p[i]);
            }
        }
        write_region(dev.get_mut(param_key)?, region, &p_part)?;
        dev.put(&mkey, m);
        dev.put(&vkey, v);
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn adamw_descends_a_quadratic() {
        // minimize f(x) = x² via its gradient 2x
        let mut dev = DeviceMem::default();
        dev.put("x", HostTensor::f32(vec![1], vec![5.0]).unwrap());
        let opt = AdamW { weight_decay: 0.0, ..AdamW::new(0.1) };
        for step in 1..=200 {
            let x = dev.get("x").unwrap().as_f32().unwrap()[0];
            dev.put("g", HostTensor::f32(vec![1], vec![2.0 * x]).unwrap());
            opt.update(&mut dev, "x", "g", step).unwrap();
        }
        let x = dev.get("x").unwrap().as_f32().unwrap()[0];
        assert!(x.abs() < 0.5, "x = {x}");
    }

    #[test]
    fn missing_grad_is_noop() {
        let mut dev = DeviceMem::default();
        dev.put("x", HostTensor::f32(vec![1], vec![1.0]).unwrap());
        AdamW::new(0.1).update(&mut dev, "x", "g", 1).unwrap();
        assert_eq!(dev.get("x").unwrap().as_f32().unwrap(), &[1.0]);
    }

    #[test]
    fn grad_is_consumed_and_moments_created() {
        let mut dev = DeviceMem::default();
        dev.put("x", HostTensor::f32(vec![2], vec![1.0, 2.0]).unwrap());
        dev.put("g", HostTensor::f32(vec![2], vec![0.1, 0.2]).unwrap());
        AdamW::new(0.01).update(&mut dev, "x", "g", 1).unwrap();
        assert!(!dev.has("g"));
        assert!(dev.has("m.x") && dev.has("v.x"));
    }

    #[test]
    fn update_region_matches_full_update_on_the_partition() {
        use crate::hspmd::slices::Interval;
        // full update on one device, partitioned updates on another: the
        // partition rows must match the full update exactly.
        let shape = vec![4usize, 2];
        let p0: Vec<f32> = (0..8).map(|i| 0.5 + 0.1 * i as f32).collect();
        let g0: Vec<f32> = (0..8).map(|i| 0.05 * (i as f32 - 3.0)).collect();
        let opt = AdamW::new(0.01);

        let mut full = DeviceMem::default();
        full.put("x", HostTensor::f32(shape.clone(), p0.clone()).unwrap());
        let mut part = DeviceMem::default();
        part.put("x", HostTensor::f32(shape.clone(), p0).unwrap());
        let region: Region = vec![Interval { lo: 1, hi: 3 }, Interval { lo: 0, hi: 2 }];
        for step in 1..=3 {
            full.put("g", HostTensor::f32(shape.clone(), g0.clone()).unwrap());
            part.put("g", HostTensor::f32(shape.clone(), g0.clone()).unwrap());
            opt.update(&mut full, "x", "g", step).unwrap();
            opt.update_region(&mut part, "x", "g", &region, step).unwrap();
        }
        let f = full.get("x").unwrap().as_f32().unwrap();
        let p = part.get("x").unwrap().as_f32().unwrap();
        // rows 1..3 updated identically; rows 0 and 3 untouched on `part`
        assert_eq!(&f[2..6], &p[2..6]);
        assert_eq!(p[0], 0.5);
        // moments are partition-sized
        assert_eq!(part.get("m.x").unwrap().shape, vec![2, 2]);
        assert!(!part.has("g"));
    }

    #[test]
    fn weight_decay_shrinks_params() {
        let mut dev = DeviceMem::default();
        dev.put("x", HostTensor::f32(vec![1], vec![10.0]).unwrap());
        dev.put("g", HostTensor::f32(vec![1], vec![0.0]).unwrap());
        let opt = AdamW { weight_decay: 0.1, ..AdamW::new(0.1) };
        opt.update(&mut dev, "x", "g", 1).unwrap();
        let x = dev.get("x").unwrap().as_f32().unwrap()[0];
        assert!(x < 10.0);
    }
}
