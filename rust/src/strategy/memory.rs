//! Per-device memory planning.
//!
//! A faithful account of what bounds the paper's strategy choices: weights
//! + gradients + optimizer states (ZeRO-sharded or not, App. A: disabling
//! ZeRO-1 for fault tolerance costs ~15% because the memory headroom
//! shrinks) + activations under the schedule's liveness profile (1F1B keeps
//! ≤ `num_stages − stage` micro-batches resident; GPipe keeps all of them).

use crate::cluster::Cluster;
use crate::costmodel::CostModel;
use crate::strategy::ParallelStrategy;

/// Memory breakdown for one stage's devices (GiB).
#[derive(Clone, Copy, Debug)]
pub struct StageMemory {
    /// bf16 weights.
    pub weights_gib: f64,
    /// bf16 gradients.
    pub grads_gib: f64,
    /// fp32 master + Adam moments (ZeRO-sharded if enabled).
    pub optimizer_gib: f64,
    /// Activations at peak liveness.
    pub activations_gib: f64,
}

impl StageMemory {
    /// Total GiB.
    pub fn total_gib(&self) -> f64 {
        self.weights_gib + self.grads_gib + self.optimizer_gib + self.activations_gib
    }
}

/// Peak resident micro-batches for a stage under the schedule.
pub fn resident_microbatches(
    schedule: crate::spec::schedule::ScheduleKind,
    num_stages: usize,
    stage: usize,
    num_microbatches: u32,
) -> u32 {
    match schedule {
        crate::spec::schedule::ScheduleKind::GPipe => num_microbatches,
        crate::spec::schedule::ScheduleKind::OneFOneB => {
            ((num_stages - stage) as u32).min(num_microbatches)
        }
    }
}

/// Activation bytes one token costs on stage `(p, s)` (per layer, after
/// TP sharding; 2 bytes/elem with activation checkpointing, the 34-byte
/// transformer liveness rule without). Shared by the padded and ragged
/// accountings so the two can never drift apart.
fn act_bytes_per_token(cm: &CostModel, strat: &ParallelStrategy, p: usize, s: usize) -> f64 {
    let stage = &strat.pipelines[p].stages[s];
    (if strat.ac { 2.0 } else { 34.0 }) * cm.model.hidden as f64 / stage.tp() as f64
}

/// Memory breakdown of pipeline `p`, stage `s` of a strategy.
pub fn stage_memory(cm: &CostModel, strat: &ParallelStrategy, p: usize, s: usize) -> StageMemory {
    let pipe = &strat.pipelines[p];
    let stage = &pipe.stages[s];
    let params = cm.model.params_per_layer() as f64 * stage.num_layers() as f64 / stage.tp() as f64;
    let zero_dp = if strat.zero1 { strat.pipelines.len().max(1) as f64 } else { 1.0 };
    let tokens_mb = pipe.microbatch_size as u64 * strat.seq_len;
    let resident =
        resident_microbatches(strat.schedule, pipe.stages.len(), s, pipe.num_microbatches);
    let act_per_token = act_bytes_per_token(cm, strat, p, s);
    let gib = (1u64 << 30) as f64;
    StageMemory {
        weights_gib: 2.0 * params / gib,
        grads_gib: 2.0 * params / gib,
        optimizer_gib: 12.0 * params / zero_dp / gib,
        activations_gib: act_per_token * tokens_mb as f64 * stage.num_layers() as f64
            * resident as f64
            / gib,
    }
}

/// Peak activation tokens resident on stage `s` under the schedule for
/// *ragged* per-micro-batch token counts — the measured window fills the
/// engine actually executes, instead of the padded
/// `microbatch_size × seq_len` estimate. GPipe keeps every micro-batch
/// live at once; 1F1B keeps at most `num_stages − stage`, so the worst
/// case is the largest such subset.
pub fn ragged_resident_tokens(
    schedule: crate::spec::schedule::ScheduleKind,
    num_stages: usize,
    stage: usize,
    mb_tokens: &[u64],
) -> u64 {
    match schedule {
        crate::spec::schedule::ScheduleKind::GPipe => mb_tokens.iter().sum(),
        crate::spec::schedule::ScheduleKind::OneFOneB => {
            let keep = num_stages.saturating_sub(stage).min(mb_tokens.len());
            let mut v = mb_tokens.to_vec();
            v.sort_unstable_by(|a, b| b.cmp(a));
            v[..keep].iter().sum()
        }
    }
}

/// [`stage_memory`] with measured ragged micro-batch token counts
/// (`mb_tokens[i]` = real tokens of micro-batch `i`): the activation term
/// charges the actually-resident window tokens; weights, gradients, and
/// optimizer states are shape-independent and unchanged. With every
/// micro-batch padded full this reduces to [`stage_memory`]; with the
/// engine's ragged windows it is what the dispatcher's strategies truly
/// hold — the §5.5 symbolic-shape memory rule.
pub fn stage_memory_ragged(
    cm: &CostModel,
    strat: &ParallelStrategy,
    p: usize,
    s: usize,
    mb_tokens: &[u64],
) -> StageMemory {
    let padded = stage_memory(cm, strat, p, s);
    let stage = &strat.pipelines[p].stages[s];
    let act_per_token = act_bytes_per_token(cm, strat, p, s);
    let resident =
        ragged_resident_tokens(strat.schedule, strat.pipelines[p].stages.len(), s, mb_tokens);
    let gib = (1u64 << 30) as f64;
    StageMemory {
        activations_gib: act_per_token * resident as f64 * stage.num_layers() as f64 / gib,
        ..padded
    }
}

/// Elements of ONE optimizer-moment tensor family (`m.*`; double for
/// `m` + `v`) the engine stores under `layout` — replicated, or ZeRO-1
/// sharded over the DP axis (each replica set stores exactly one copy,
/// split across its members). This is the engine-scale mirror of
/// [`stage_memory`]'s `optimizer_gib / zero_dp` accounting; the
/// integration tests assert the engine's *actual* stores match it (the
/// memory-accounting side of the App.-A "disabling ZeRO-1 costs ~15%
/// because the headroom shrinks" trade-off).
pub fn engine_moment_elems(
    cfg: &crate::runtime::ManifestConfig,
    layout: &crate::engine::ShardLayout,
    zero1: bool,
) -> u64 {
    use crate::engine::layout::{pkey, special_shape};
    use crate::engine::BLOCK_PARAMS;
    use crate::hspmd::slices::region_elems;
    use std::collections::BTreeSet;

    fn one(
        layout: &crate::engine::ShardLayout,
        dev: usize,
        key: &str,
        full: u64,
        zero1: bool,
    ) -> u64 {
        if !zero1 {
            return full;
        }
        match layout.zero_part(dev, key) {
            None => full,
            Some(None) => 0,
            Some(Some(r)) => region_elems(r),
        }
    }

    let mut total = 0u64;
    let mut seen: BTreeSet<(usize, String)> = BTreeSet::new();
    for ((l, pidx), hs) in layout.iter_holdings() {
        let key = pkey(*l, BLOCK_PARAMS[*pidx]);
        for h in hs {
            if seen.insert((h.dev, key.clone())) {
                total += one(layout, h.dev, &key, region_elems(&h.region), zero1);
            }
        }
    }
    for (name, roots) in [
        ("emb", &layout.first_roots),
        ("gf", &layout.last_roots),
        ("wout", &layout.last_roots),
    ] {
        let full: u64 = special_shape(cfg, name).iter().product();
        for &d in roots.iter() {
            if seen.insert((d, name.to_string())) {
                total += one(layout, d, name, full, zero1);
            }
        }
    }
    total
}

/// The strategy's peak per-device memory and whether it fits the cluster.
pub fn plan(cm: &CostModel, cluster: &Cluster, strat: &ParallelStrategy) -> (f64, bool) {
    let mut peak = 0f64;
    let mut fits = true;
    for (pi, p) in strat.pipelines.iter().enumerate() {
        for (si, s) in p.stages.iter().enumerate() {
            let m = stage_memory(cm, strat, pi, si).total_gib();
            peak = peak.max(m);
            let have = s
                .ranks
                .iter()
                .map(|&r| cluster.device(r).kind.mem_gib)
                .fold(f64::INFINITY, f64::min);
            if m > have {
                fits = false;
            }
        }
    }
    (peak, fits)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::costmodel::ModelCfg;
    use crate::spec::schedule::ScheduleKind;
    use crate::strategy::{tables, uniform};

    #[test]
    fn one_f_one_b_caps_activation_liveness() {
        assert_eq!(resident_microbatches(ScheduleKind::OneFOneB, 4, 0, 32), 4);
        assert_eq!(resident_microbatches(ScheduleKind::OneFOneB, 4, 3, 32), 1);
        assert_eq!(resident_microbatches(ScheduleKind::GPipe, 4, 0, 32), 32);
    }

    #[test]
    fn gpipe_needs_more_activation_memory_than_1f1b() {
        let cm = CostModel::new(ModelCfg::llama_32b());
        let ranks: Vec<u32> = (0..16).collect();
        let mut s =
            uniform("x", &ranks, 1, 4, 4, 60, 32, 1, 4096, ScheduleKind::OneFOneB, true, false)
                .unwrap();
        let m_1f1b = stage_memory(&cm, &s, 0, 0);
        s.schedule = ScheduleKind::GPipe;
        let m_gpipe = stage_memory(&cm, &s, 0, 0);
        assert!(m_gpipe.activations_gib > 4.0 * m_1f1b.activations_gib);
    }

    #[test]
    fn ragged_activation_accounting_undercuts_padded_estimate() {
        let cm = CostModel::new(ModelCfg::llama_32b());
        let ranks: Vec<u32> = (0..4).collect();
        let s = uniform("pp4", &ranks, 1, 1, 4, 60, 8, 1, 4096, ScheduleKind::OneFOneB, false, false)
            .unwrap();
        // padded estimate: 8 micro-batches × 1 × 4096 tokens each
        let padded = stage_memory(&cm, &s, 0, 0);
        // full ragged windows reproduce it exactly
        let full = stage_memory_ragged(&cm, &s, 0, 0, &[4096; 8]);
        assert!((full.activations_gib - padded.activations_gib).abs() < 1e-12);
        assert_eq!(full.weights_gib, padded.weights_gib);
        assert_eq!(full.optimizer_gib, padded.optimizer_gib);
        // real mixed-length windows (97% short) sit well below the
        // padded-context estimate
        let ragged = stage_memory_ragged(&cm, &s, 0, 0, &[600, 900, 4096, 700, 650, 800, 700, 900]);
        assert!(
            ragged.activations_gib < 0.5 * padded.activations_gib,
            "ragged {} vs padded {}",
            ragged.activations_gib,
            padded.activations_gib
        );
        // 1F1B liveness keeps the LARGEST resident subset: stage 0 of 4
        // holds the top 4 windows, the last stage only the single largest
        assert_eq!(
            ragged_resident_tokens(ScheduleKind::OneFOneB, 4, 0, &[600, 900, 4096, 700]),
            4096 + 900 + 700 + 600
        );
        assert_eq!(
            ragged_resident_tokens(ScheduleKind::OneFOneB, 4, 3, &[600, 900, 4096, 700]),
            4096
        );
        assert_eq!(
            ragged_resident_tokens(ScheduleKind::GPipe, 4, 0, &[600, 900, 4096, 700]),
            600 + 900 + 4096 + 700
        );
    }

    #[test]
    fn zero1_shards_optimizer_states() {
        let cm = CostModel::new(ModelCfg::llama_32b());
        let mut c1 = tables::hetu_c1_32h20();
        let m_off = stage_memory(&cm, &c1, 0, 0);
        c1.zero1 = true;
        let m_on = stage_memory(&cm, &c1, 0, 0);
        assert!(m_on.optimizer_gib < m_off.optimizer_gib);
        assert_eq!(m_on.weights_gib, m_off.weights_gib);
    }

    #[test]
    fn engine_zero1_accounting_halves_dp2_moments() {
        use crate::engine::{EngineStrategy, ShardLayout};
        use crate::runtime::native;
        let cfg = native::tiny_config();
        // dp2: every parameter (incl. roots) is replicated exactly twice,
        // and every row count is even — ZeRO-1 stores exactly one copy.
        let dp2 = EngineStrategy::uniform("dp2", 2, 1, 1, 8, 1);
        let layout = ShardLayout::build(&cfg, &dp2).unwrap();
        let rep = engine_moment_elems(&cfg, &layout, false);
        let z1 = engine_moment_elems(&cfg, &layout, true);
        assert!(rep > 0);
        assert_eq!(z1 * 2, rep, "ZeRO-1 over dp2 stores exactly one copy");
        // solo: nothing replicates, ZeRO-1 changes nothing
        let solo = EngineStrategy::uniform("solo", 1, 1, 1, 8, 1);
        let l2 = ShardLayout::build(&cfg, &solo).unwrap();
        assert_eq!(
            engine_moment_elems(&cfg, &l2, true),
            engine_moment_elems(&cfg, &l2, false)
        );
        // the engine-scale ratio matches the paper-scale cost model's
        // `optimizer_gib / zero_dp` rule for uniform DP
        let cm = CostModel::new(ModelCfg::llama_32b());
        let ranks: Vec<u32> = (0..2).collect();
        let mut s =
            uniform("dp2", &ranks, 2, 1, 1, 60, 8, 1, 4096, ScheduleKind::GPipe, false, false)
                .unwrap();
        let m_off = stage_memory(&cm, &s, 0, 0);
        s.zero1 = true;
        let m_on = stage_memory(&cm, &s, 0, 0);
        let model_ratio = m_off.optimizer_gib / m_on.optimizer_gib;
        let engine_ratio = rep as f64 / z1 as f64;
        assert!(
            (model_ratio - engine_ratio).abs() < 1e-9,
            "cost-model ratio {model_ratio} vs engine ratio {engine_ratio}"
        );
    }

    #[test]
    fn paper_strategies_fit_their_devices() {
        let cm = CostModel::new(ModelCfg::llama_32b());
        let cluster = Cluster::h20(32);
        for s in [tables::hetu_c1_32h20(), tables::hetu_c2_31h20(), tables::hetu_c3_24h20()] {
            let (peak, fits) = plan(&cm, &cluster, &s);
            assert!(fits, "{} peak {peak:.1} GiB must fit 96 GiB H20s", s.name);
        }
    }

    #[test]
    fn whole_32b_on_one_gpu_does_not_fit() {
        let cm = CostModel::new(ModelCfg::llama_32b());
        let cluster = Cluster::h20(1);
        let ranks = vec![0u32];
        let s = uniform("solo", &ranks, 1, 1, 1, 60, 1, 1, 4096, ScheduleKind::OneFOneB, false, true)
            .unwrap();
        let (_, fits) = plan(&cm, &cluster, &s);
        assert!(!fits);
    }
}
