//! Heterogeneous strategy generation.
//!
//! The paper selects strategies "using pre-profiled results combined with a
//! cost model" (App. A.3) and notes that external strategy-search systems
//! compose with HSPMD by expressing their output as annotations (§9). This
//! module is that search for our simulator: given an arbitrary alive device
//! set (any mix of H800/H20, any count — including C2-style "31 of 32"
//! states), enumerate candidate heterogeneous layouts:
//!
//! * TP groups are formed within a node and within a device kind;
//! * pipelines interleave slow-kind stages first, fast-kind stages last
//!   (the paper's layout: H20 stages feed H800 stages);
//! * layers are assigned to stages proportionally to the stage's effective
//!   FLOPS (tp × device TFLOPS), which is exactly how Table 5/7/8 balance
//!   23-layer H800 stages against 7-layer H20 stages;
//! * leftover devices that cannot fill a TP group become asymmetric tail
//!   stages of width 2 then 1 (the C2 pattern).

use crate::cluster::{Cluster, DeviceKind};
use crate::costmodel::CostModel;
use crate::hspmd::dg::Rank;
use crate::spec::schedule::ScheduleKind;
use crate::strategy::{ParallelStrategy, PipelineSpec, StageSpec};
use crate::{Error, Result};

/// A TP group candidate: same-kind, same-node ranks.
#[derive(Clone, Debug)]
pub(crate) struct TpGroup {
    pub(crate) ranks: Vec<Rank>,
    pub(crate) kind: DeviceKind,
}

/// Form TP groups of width `tp` within nodes, same kind; returns groups and
/// the leftover ranks.
pub(crate) fn form_groups(
    cluster: &Cluster,
    alive: &[Rank],
    tp: u32,
) -> (Vec<TpGroup>, Vec<Rank>) {
    use std::collections::BTreeMap;
    let mut by_node: BTreeMap<(u32, &'static str), Vec<Rank>> = BTreeMap::new();
    for &r in alive {
        let d = cluster.device(r);
        by_node.entry((d.node, d.kind.name)).or_default().push(r);
    }
    let mut groups = vec![];
    let mut leftover = vec![];
    for ((_, _), ranks) in by_node {
        let mut i = 0;
        while i + (tp as usize) <= ranks.len() {
            groups.push(TpGroup {
                ranks: ranks[i..i + tp as usize].to_vec(),
                kind: cluster.device(ranks[i]).kind,
            });
            i += tp as usize;
        }
        leftover.extend_from_slice(&ranks[i..]);
    }
    (groups, leftover)
}

/// Assign `layers` across stages proportionally to effective FLOPS.
///
/// Callers must guarantee `stage_flops.len() <= layers`; each stage gets at
/// least one layer, so more stages than layers is infeasible (and would
/// underflow the clamp bound below).
pub(crate) fn assign_layers(layers: u32, stage_flops: &[f64]) -> Vec<(u32, u32)> {
    let total: f64 = stage_flops.iter().sum();
    let mut out = vec![];
    let mut assigned = 0u32;
    for (i, f) in stage_flops.iter().enumerate() {
        let take = if i + 1 == stage_flops.len() {
            layers - assigned
        } else {
            (((layers as f64) * f / total).round() as u32)
                .clamp(1, layers - assigned - (stage_flops.len() - 1 - i) as u32)
        };
        out.push((assigned, assigned + take));
        assigned += take;
    }
    out
}

/// Generate candidate strategies for the alive device set.
pub fn generate_candidates(
    cluster: &Cluster,
    layers: u32,
    global_batch: u64,
    seq_len: u64,
) -> Vec<ParallelStrategy> {
    let alive = cluster.alive_ranks();
    let mut out = vec![];
    for tp in [2u32, 4, 8] {
        for dp in [1u32, 2, 4] {
            if let Ok(s) =
                build_candidate(cluster, &alive, layers, global_batch, seq_len, tp, dp)
            {
                if s.validate(layers).is_ok() {
                    out.push(s);
                }
            }
        }
    }
    out
}

/// Build one candidate at (tp, dp).
pub(crate) fn build_candidate(
    cluster: &Cluster,
    alive: &[Rank],
    layers: u32,
    global_batch: u64,
    seq_len: u64,
    tp: u32,
    dp: u32,
) -> Result<ParallelStrategy> {
    let (mut groups, leftover) = form_groups(cluster, alive, tp);
    if groups.len() < dp as usize {
        return Err(Error::Strategy("not enough TP groups".into()));
    }
    // slow kinds first (they take early stages), fast kinds last
    groups.sort_by(|a, b| {
        a.kind
            .bf16_tflops
            .partial_cmp(&b.kind.bf16_tflops)
            .unwrap()
            .then(a.ranks[0].cmp(&b.ranks[0]))
    });
    // round-robin groups into dp pipelines, preserving slow→fast order
    let mut pipes: Vec<Vec<TpGroup>> = vec![vec![]; dp as usize];
    for (i, g) in groups.into_iter().enumerate() {
        pipes[i % dp as usize].push(g);
    }
    // asymmetric tail from leftovers: widths tp/2, then 1 (appended to the
    // last pipeline, C2-style)
    let mut tail: Vec<TpGroup> = vec![];
    let mut rest = leftover;
    for width in [tp / 2, 1] {
        if width == 0 {
            continue;
        }
        while rest.len() >= width as usize && width < tp {
            let take: Vec<Rank> = rest.drain(..width as usize).collect();
            let kind = cluster.device(take[0]).kind;
            tail.push(TpGroup { ranks: take, kind });
            if tail.len() >= 2 {
                break; // at most two tail stages (2-then-1 like C2)
            }
        }
    }
    if let Some(last) = pipes.last_mut() {
        last.extend(tail);
    }

    let per_dp = (global_batch / dp as u64).max(1);
    let mut pipelines = vec![];
    for groups in pipes {
        if groups.is_empty() {
            return Err(Error::Strategy("empty pipeline".into()));
        }
        // Each stage needs >= 1 layer; deeper pipelines are structurally
        // infeasible (and would underflow assign_layers' clamp bound). At
        // cluster scale this rejects e.g. 512-stage tp2/dp1 shapes cheaply.
        if groups.len() as u32 > layers {
            return Err(Error::Strategy(format!(
                "pipeline of {} stages exceeds {layers} layers",
                groups.len()
            )));
        }
        let flops: Vec<f64> =
            groups.iter().map(|g| g.kind.bf16_tflops * g.ranks.len() as f64).collect();
        let ranges = assign_layers(layers, &flops);
        let stages: Vec<StageSpec> = groups
            .iter()
            .zip(ranges)
            .map(|(g, l)| StageSpec { ranks: g.ranks.clone(), layers: l })
            .collect();
        pipelines.push(PipelineSpec {
            stages,
            num_microbatches: per_dp as u32,
            microbatch_size: 1,
        });
    }
    Ok(ParallelStrategy {
        name: format!("gen-tp{tp}dp{dp}"),
        pipelines,
        zero1: false,
        schedule: ScheduleKind::OneFOneB,
        seq_len,
        ac: false,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::costmodel::ModelCfg;
    use crate::sim::simulate_step;
    use crate::strategy::synth::{synthesize, SynthOptions};

    /// The generator's end-to-end search, via the synth pipeline over the
    /// frozen pre-synth space (tp ∈ {2,4,8} × dp ∈ {1,2,4}, mb 1, 1F1B).
    fn search(cluster: &Cluster, cm: &CostModel) -> (ParallelStrategy, f64) {
        let rep = synthesize(cluster, cm, &SynthOptions::legacy(64, 4096)).unwrap();
        rep.best().expect("feasible candidate").clone()
    }

    #[test]
    fn groups_respect_node_and_kind_boundaries() {
        let cluster = Cluster::h800_16_h20_16();
        let alive = cluster.alive_ranks();
        let (groups, leftover) = form_groups(&cluster, &alive, 4);
        assert_eq!(groups.len(), 8);
        assert!(leftover.is_empty());
        for g in &groups {
            let node = cluster.device(g.ranks[0]).node;
            let kind = cluster.device(g.ranks[0]).kind.name;
            assert!(g
                .ranks
                .iter()
                .all(|&r| cluster.device(r).node == node && cluster.device(r).kind.name == kind));
        }
    }

    #[test]
    fn layer_assignment_is_flops_proportional() {
        // two H20-ish stages + one 6.7x faster H800 stage
        let ranges = assign_layers(60, &[148.0 * 4.0, 148.0 * 4.0, 990.0 * 4.0]);
        let lens: Vec<u32> = ranges.iter().map(|(a, b)| b - a).collect();
        assert_eq!(lens.iter().sum::<u32>(), 60);
        assert!(lens[2] > 3 * lens[0], "H800 stage takes most layers: {lens:?}");
        // contiguous coverage
        assert_eq!(ranges[0].0, 0);
        assert_eq!(ranges[1].0, ranges[0].1);
        assert_eq!(ranges[2].1, 60);
    }

    #[test]
    fn generated_candidates_validate() {
        let cluster = Cluster::h800_16_h20_32();
        let cands = generate_candidates(&cluster, 60, 64, 4096);
        assert!(!cands.is_empty());
        for c in &cands {
            c.validate(60).unwrap_or_else(|e| panic!("{}: {e}", c.name));
        }
    }

    #[test]
    fn search_handles_the_c2_situation() {
        // 31 of 32 H20s: the generator must use more than 24 GPUs (beat the
        // Megatron discard-the-partial-node outcome).
        let mut cluster = Cluster::h20(32);
        cluster.fail_gpu(31);
        let cm = CostModel::new(ModelCfg::llama_32b());
        let (best, t) = search(&cluster, &cm);
        assert!(best.ranks().len() > 24, "uses {} GPUs", best.ranks().len());
        assert!(t > 0.0);
    }

    #[test]
    fn generated_hetero_layout_beats_uniform_megatron() {
        let cluster = Cluster::h800_16_h20_16();
        let cm = CostModel::new(ModelCfg::llama_32b());
        let (best, t_gen) = search(&cluster, &cm);
        let cfg = crate::baselines::megatron::table4("llama-32b", 16, 16).unwrap();
        let t_mega =
            crate::baselines::megatron::step_time(&cluster, &cm, cfg, 64, 4096).unwrap();
        assert!(
            t_gen < t_mega,
            "generated {} ({t_gen:.2}s) should beat uniform megatron ({t_mega:.2}s)",
            best.name
        );
        // and H800 stages hold more layers than H20 stages
        let p = &best.pipelines[0];
        let h800_layers: u32 = p
            .stages
            .iter()
            .filter(|s| cluster.device(s.ranks[0]).kind.name == "H800")
            .map(|s| s.num_layers())
            .sum();
        let h20_layers: u32 = p
            .stages
            .iter()
            .filter(|s| cluster.device(s.ranks[0]).kind.name == "H20")
            .map(|s| s.num_layers())
            .sum();
        if h800_layers > 0 && h20_layers > 0 {
            assert!(h800_layers > h20_layers, "H800 {h800_layers} vs H20 {h20_layers}");
        }
    }

    #[test]
    fn generated_best_is_comparable_to_the_papers_table5() {
        let cluster = Cluster::h800_16_h20_16();
        let cm = CostModel::new(ModelCfg::llama_32b());
        let (_, t_gen) = search(&cluster, &cm);
        let t_paper =
            simulate_step(&cluster, &cm, &crate::strategy::tables::hetu_32b_16h800_16h20())
                .unwrap()
                .step_s;
        // the hand-tuned Table 5 layout should be within 2x of our greedy
        // search, and vice versa (sanity that both live in the same regime)
        let ratio = (t_gen / t_paper).max(t_paper / t_gen);
        assert!(ratio < 2.0, "generated {t_gen:.2}s vs table5 {t_paper:.2}s");
    }
}
