//! Cluster-scale strategy synthesis.
//!
//! This is the consolidated strategy search for generated clusters: one
//! enumeration pass over (TP degree × DP width × micro-batch size ×
//! schedule), one memory-feasibility gate, and a branch-and-bound ranking
//! loop that keeps a 1024-rank search sub-second. It subsumed (and has
//! since replaced outright) the older `generate::search_best` /
//! `search::choose_best` pair; [`SynthOptions::legacy`] preserves their
//! exact search space for callers that want the frozen pre-synth behavior.
//!
//! Pruning is hierarchical, mirroring how the paper's planner scales:
//!
//! 1. **structural** — candidates that cannot exist (more stages than
//!    layers, not enough TP groups, batch not divisible by the micro-batch
//!    size) are rejected during enumeration without ever materialising a
//!    full strategy;
//! 2. **memory** — one shared feasibility gate
//!    ([`memory_feasible`], delegating to [`crate::strategy::memory`]);
//! 3. **bound** — survivors are sorted by a compute-only lower bound on
//!    step time ([`step_lower_bound`]) and simulated in that order; once
//!    `top_k` candidates are ranked, any candidate whose bound already
//!    exceeds the worst ranked time is discarded unsimulated.
//!
//! The bound is provably below the simulated step time (it counts only
//! per-stage forward+backward compute, no communication, no bubbles), so
//! bound-pruning never changes the top-k result — it only skips work.

use crate::cluster::Cluster;
use crate::costmodel::CostModel;
use crate::sim::simulate_step;
use crate::spec::schedule::ScheduleKind;
use crate::strategy::generate::{build_candidate, form_groups};
use crate::strategy::ParallelStrategy;
use crate::{Error, Result};

/// Search-space description for [`synthesize`].
#[derive(Clone, Debug)]
pub struct SynthOptions {
    /// Global batch size in samples.
    pub global_batch: u64,
    /// Sequence length in tokens.
    pub seq_len: u64,
    /// How many ranked strategies to keep (and how deep bound-pruning may
    /// cut; `k >= 1`).
    pub top_k: usize,
    /// TP degrees to try (each clamped to node-local same-kind groups by
    /// the generator).
    pub tp_candidates: Vec<u32>,
    /// DP widths to try; empty means "powers of two up to the number of TP
    /// groups the cluster can form at each TP degree".
    pub dp_candidates: Vec<u32>,
    /// Micro-batch sizes to try (must divide each pipeline's sample count).
    pub mb_sizes: Vec<u32>,
    /// Pipeline schedules to try.
    pub schedules: Vec<ScheduleKind>,
}

impl SynthOptions {
    /// Full search space with defaults suited to generated clusters.
    pub fn new(global_batch: u64, seq_len: u64) -> SynthOptions {
        SynthOptions {
            global_batch,
            seq_len,
            top_k: 3,
            tp_candidates: vec![2, 4, 8],
            dp_candidates: vec![],
            mb_sizes: vec![1, 2],
            schedules: vec![ScheduleKind::OneFOneB, ScheduleKind::GPipe],
        }
    }

    /// The exact search space of the removed pre-synth
    /// `generate::search_best` (tp ∈ {2,4,8} × dp ∈ {1,2,4}, micro-batch
    /// 1, 1F1B), frozen so migrated callers see identical results.
    pub fn legacy(global_batch: u64, seq_len: u64) -> SynthOptions {
        SynthOptions {
            global_batch,
            seq_len,
            top_k: 1,
            tp_candidates: vec![2, 4, 8],
            dp_candidates: vec![1, 2, 4],
            mb_sizes: vec![1],
            schedules: vec![ScheduleKind::OneFOneB],
        }
    }
}

/// Outcome of a [`synthesize`] run: the top-k ranked strategies plus the
/// pruning ledger (`generated == pruned_memory + pruned_bound + simulated`).
#[derive(Clone, Debug)]
pub struct SynthReport {
    /// Ranked `(strategy, simulated step seconds)`, fastest first; at most
    /// `top_k` entries.
    pub ranked: Vec<(ParallelStrategy, f64)>,
    /// Candidates that materialised as valid strategies.
    pub generated: usize,
    /// Shapes rejected during enumeration (stage/layer imbalance, group
    /// shortfall, indivisible batch).
    pub pruned_structural: usize,
    /// Valid strategies rejected by the memory gate.
    pub pruned_memory: usize,
    /// Strategies skipped unsimulated because their lower bound exceeded
    /// the current top-k.
    pub pruned_bound: usize,
    /// Strategies actually run through the event simulator.
    pub simulated: usize,
}

impl SynthReport {
    /// The fastest ranked strategy, if any candidate survived the gates.
    pub fn best(&self) -> Option<&(ParallelStrategy, f64)> {
        self.ranked.first()
    }
}

/// Check every stage of `strat` fits its devices' memory (delegates to the
/// per-stage planner in [`crate::strategy::memory`], which models schedule-
/// dependent activation liveness). This is the single memory gate shared by
/// [`synthesize`] and [`rank`].
pub fn memory_feasible(cluster: &Cluster, cm: &CostModel, strat: &ParallelStrategy) -> bool {
    crate::strategy::memory::plan(cm, cluster, strat).1
}

/// Compute-only lower bound on `strat`'s step time: the busiest stage must
/// run forward+backward for every micro-batch, serially, on its slowest
/// member device. Ignores all communication and pipeline bubbles, so it
/// never exceeds [`simulate_step`]'s `step_s`.
pub fn step_lower_bound(cluster: &Cluster, cm: &CostModel, strat: &ParallelStrategy) -> f64 {
    let mut cmx = *cm;
    if strat.ac {
        cmx.params.ac_recompute = 2.0;
    }
    let mut bound = 0.0f64;
    for p in &strat.pipelines {
        let tokens_mb = p.microbatch_size as u64 * strat.seq_len;
        for s in &p.stages {
            let dev = s
                .ranks
                .iter()
                .map(|&r| cluster.device(r).kind)
                .min_by(|a, b| a.bf16_tflops.partial_cmp(&b.bf16_tflops).unwrap())
                .unwrap();
            let per_mb = cmx.fwd_s(&dev, s.num_layers(), tokens_mb, strat.seq_len, s.tp())
                + cmx.bwd_s(&dev, s.num_layers(), tokens_mb, strat.seq_len, s.tp());
            bound = bound.max(p.num_microbatches as f64 * per_mb);
        }
    }
    bound
}

/// Rank externally supplied `candidates` with the consolidated gate
/// (memory + alive ranks + simulation), fastest first, truncated to `k`.
/// Returns `(index into candidates, step seconds)` pairs.
pub fn rank(
    cluster: &Cluster,
    cm: &CostModel,
    candidates: &[ParallelStrategy],
    k: usize,
) -> Vec<(usize, f64)> {
    let alive = cluster.alive_ranks();
    let mut out: Vec<(usize, f64)> = vec![];
    for (i, c) in candidates.iter().enumerate() {
        if !memory_feasible(cluster, cm, c) {
            continue;
        }
        if !c.ranks().iter().all(|r| alive.contains(r)) {
            continue;
        }
        if let Ok(rep) = simulate_step(cluster, cm, c) {
            out.push((i, rep.step_s));
        }
    }
    out.sort_by(|a, b| a.1.partial_cmp(&b.1).unwrap());
    out.truncate(k);
    out
}

/// Pick the fastest feasible candidate from an externally supplied list
/// (the direct replacement for the removed `search::choose_best`).
pub fn best(
    cluster: &Cluster,
    cm: &CostModel,
    candidates: &[ParallelStrategy],
) -> Result<(ParallelStrategy, f64)> {
    rank(cluster, cm, candidates, 1)
        .first()
        .map(|&(i, t)| (candidates[i].clone(), t))
        .ok_or_else(|| Error::Strategy("no feasible candidate strategy".into()))
}

/// Enumerate the candidate set for `opts`, returning valid strategies
/// (paired with their compute lower bound) and the structural-prune count.
fn enumerate(
    cluster: &Cluster,
    cm: &CostModel,
    opts: &SynthOptions,
) -> (Vec<(ParallelStrategy, f64)>, usize) {
    let alive = cluster.alive_ranks();
    let layers = cm.model.layers;
    let mut cands: Vec<(ParallelStrategy, f64)> = vec![];
    let mut structural = 0usize;
    for &tp in &opts.tp_candidates {
        let dps: Vec<u32> = if opts.dp_candidates.is_empty() {
            let groups = form_groups(cluster, &alive, tp).0.len() as u32;
            let mut v = vec![];
            let mut dp = 1u32;
            while dp <= groups.max(1) {
                v.push(dp);
                dp *= 2;
            }
            v
        } else {
            opts.dp_candidates.clone()
        };
        for dp in dps {
            let base = match build_candidate(
                cluster,
                &alive,
                layers,
                opts.global_batch,
                opts.seq_len,
                tp,
                dp,
            ) {
                Ok(s) => s,
                Err(_) => {
                    structural += 1;
                    continue;
                }
            };
            if base.validate(layers).is_err() {
                structural += 1;
                continue;
            }
            for &mbs in &opts.mb_sizes {
                for &sched in &opts.schedules {
                    let mut s = base.clone();
                    let mut ok = mbs >= 1;
                    for p in &mut s.pipelines {
                        let samples = p.num_microbatches as u64 * p.microbatch_size as u64;
                        if mbs as u64 > samples || samples % mbs as u64 != 0 {
                            ok = false;
                            break;
                        }
                        p.microbatch_size = mbs;
                        p.num_microbatches = (samples / mbs as u64) as u32;
                    }
                    if !ok {
                        structural += 1;
                        continue;
                    }
                    s.schedule = sched;
                    let sched_tag = match sched {
                        ScheduleKind::OneFOneB => "1f1b",
                        ScheduleKind::GPipe => "gpipe",
                    };
                    s.name = format!("synth-tp{tp}dp{dp}mb{mbs}-{sched_tag}");
                    let bound = step_lower_bound(cluster, cm, &s);
                    cands.push((s, bound));
                }
            }
        }
    }
    (cands, structural)
}

/// Synthesize a strategy for `cluster`: enumerate, gate on memory, then
/// rank by simulated step time with bound-pruning. Returns the top-k and
/// the pruning ledger; `ranked` is empty when nothing feasible exists.
pub fn synthesize(cluster: &Cluster, cm: &CostModel, opts: &SynthOptions) -> Result<SynthReport> {
    if opts.top_k == 0 {
        return Err(Error::Strategy("synth top_k must be >= 1".into()));
    }
    let (mut cands, pruned_structural) = enumerate(cluster, cm, opts);
    let generated = cands.len();
    let mut pruned_memory = 0usize;
    cands.retain(|(s, _)| {
        let keep = memory_feasible(cluster, cm, s);
        if !keep {
            pruned_memory += 1;
        }
        keep
    });
    // simulate in bound order; once top_k is full, a candidate whose lower
    // bound beats nothing in the current top-k cannot enter it
    cands.sort_by(|a, b| a.1.partial_cmp(&b.1).unwrap());
    let mut ranked: Vec<(ParallelStrategy, f64)> = vec![];
    let mut simulated = 0usize;
    let mut pruned_bound = 0usize;
    for (i, (s, bound)) in cands.iter().enumerate() {
        if ranked.len() >= opts.top_k && *bound >= ranked.last().unwrap().1 {
            pruned_bound += cands.len() - i;
            break;
        }
        simulated += 1;
        let t = match simulate_step(cluster, cm, s) {
            Ok(rep) => rep.step_s,
            Err(_) => continue,
        };
        let pos = ranked.partition_point(|(_, rt)| *rt <= t);
        ranked.insert(pos, (s.clone(), t));
        ranked.truncate(opts.top_k);
    }
    Ok(SynthReport {
        ranked,
        generated,
        pruned_structural,
        pruned_memory,
        pruned_bound,
        simulated,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::ClusterSpec;
    use crate::costmodel::ModelCfg;

    #[test]
    fn bound_never_exceeds_simulated_step() {
        let cluster = Cluster::h800_16_h20_16();
        let cm = CostModel::new(ModelCfg::llama_32b());
        let cands =
            crate::strategy::generate::generate_candidates(&cluster, cm.model.layers, 64, 4096);
        assert!(!cands.is_empty());
        for c in &cands {
            if let Ok(rep) = simulate_step(&cluster, &cm, c) {
                let b = step_lower_bound(&cluster, &cm, c);
                assert!(
                    b <= rep.step_s * (1.0 + 1e-9),
                    "{}: bound {b:.4} > sim {:.4}",
                    c.name,
                    rep.step_s
                );
            }
        }
    }

    #[test]
    fn synthesis_ledger_is_consistent_and_ranked_sorted() {
        let cluster = ClusterSpec::new(5, 8).build();
        let cm = CostModel::new(ModelCfg::llama_32b());
        let rep = synthesize(&cluster, &cm, &SynthOptions::new(64, 4096)).unwrap();
        assert_eq!(rep.generated, rep.pruned_memory + rep.pruned_bound + rep.simulated);
        assert!(!rep.ranked.is_empty(), "64-rank generated cluster must be feasible");
        assert!(rep.ranked.len() <= 3);
        for w in rep.ranked.windows(2) {
            assert!(w[0].1 <= w[1].1, "ranked must be ascending");
        }
        for (s, _) in &rep.ranked {
            s.validate(cm.model.layers).unwrap();
        }
    }

    #[test]
    fn bound_pruning_does_not_change_the_winner() {
        let cluster = ClusterSpec::new(9, 16).build();
        let cm = CostModel::new(ModelCfg::llama_32b());
        let opts = SynthOptions::new(64, 4096);
        let pruned = synthesize(&cluster, &cm, &opts).unwrap();
        // exhaustive reference: simulate everything via rank() on the same
        // candidate set (top_k = usize::MAX disables bound pruning's cut)
        let mut exhaustive = opts.clone();
        exhaustive.top_k = usize::MAX;
        let full = synthesize(&cluster, &cm, &exhaustive).unwrap();
        assert_eq!(full.pruned_bound, 0);
        let b = pruned.best().expect("feasible");
        let fb = full.best().expect("feasible");
        assert_eq!(b.0.name, fb.0.name);
        assert!((b.1 - fb.1).abs() < 1e-12);
    }

    #[test]
    fn infeasible_strategies_filtered() {
        // 32B on a single H20: cannot fit.
        let cluster = Cluster::h20(1);
        let cm = CostModel::new(ModelCfg::llama_32b());
        let s = crate::strategy::uniform(
            "solo",
            &[0],
            1,
            1,
            1,
            60,
            1,
            1,
            4096,
            ScheduleKind::OneFOneB,
            false,
            true,
        )
        .unwrap();
        assert!(!memory_feasible(&cluster, &cm, &s));
        assert!(best(&cluster, &cm, &[s]).is_err());
    }

    #[test]
    fn best_prefers_faster_strategy() {
        let cluster = Cluster::h20(32);
        let cm = CostModel::new(ModelCfg::llama_32b());
        let ranks: Vec<u32> = (0..32).collect();
        let good = crate::strategy::tables::hetu_c1_32h20();
        let bad = crate::strategy::uniform(
            "tp32",
            &ranks,
            1,
            32,
            1,
            60,
            64,
            1,
            4096,
            ScheduleKind::OneFOneB,
            false,
            false,
        )
        .unwrap();
        let (winner, t) = best(&cluster, &cm, &[bad, good.clone()]).unwrap();
        assert_eq!(winner.name, good.name);
        assert!(t > 0.0);
    }

    #[test]
    fn dead_ranks_disqualify() {
        let mut cluster = Cluster::h20(32);
        cluster.fail_gpu(31);
        let cm = CostModel::new(ModelCfg::llama_32b());
        let c1 = crate::strategy::tables::hetu_c1_32h20(); // uses rank 31
        let c2 = crate::strategy::tables::hetu_c2_31h20();
        let (winner, _) = best(&cluster, &cm, &[c1, c2.clone()]).unwrap();
        assert_eq!(winner.name, c2.name);
    }

    #[test]
    fn infeasible_cluster_yields_empty_ranking() {
        let cluster = Cluster::h20(1);
        let cm = CostModel::new(ModelCfg::llama_32b());
        let rep = synthesize(&cluster, &cm, &SynthOptions::new(64, 4096)).unwrap();
        assert!(rep.ranked.is_empty());
        assert!(rep.best().is_none());
    }
}
