//! Parallel strategy specifications.
//!
//! A [`ParallelStrategy`] is the coarse, human-readable form of the
//! Appendix-A tables: a set of pipelines, each a chain of stages, each
//! stage a TP group of ranks owning a contiguous layer range. Strategies
//! lower to HSPMD annotations ([`ParallelStrategy::weight_annotation`]) for
//! switch planning, are evaluated by the [`crate::sim`] discrete-event
//! simulator, and lower to runnable engine strategies at tiny-model scale
//! via [`lower`] (the plan↔execution bridge of DESIGN.md §4).

pub mod generate;
pub mod lower;
pub mod memory;
pub mod synth;
pub mod tables;

pub use lower::{lower, lower_onto, LowerOptions};
pub use synth::{synthesize, SynthOptions, SynthReport};

use crate::hspmd::dg::Rank;
use crate::hspmd::{Annotation, DeviceGroup, DistStates, Subgroup};
use crate::spec::schedule::ScheduleKind;
use crate::{Error, Result};

/// One pipeline stage: a TP group holding a contiguous layer range.
#[derive(Clone, Debug, PartialEq)]
pub struct StageSpec {
    /// Member ranks (TP group; degree = `ranks.len()`).
    pub ranks: Vec<Rank>,
    /// Layer range `[lo, hi)`.
    pub layers: (u32, u32),
}

impl StageSpec {
    /// Convenience constructor from inclusive rank/layer bounds (the
    /// notation of the paper's tables: "R16-19 / L0-6").
    pub fn r_l(r_lo: Rank, r_hi: Rank, l_lo: u32, l_hi: u32) -> StageSpec {
        StageSpec { ranks: (r_lo..=r_hi).collect(), layers: (l_lo, l_hi + 1) }
    }

    /// Tensor-parallel degree.
    pub fn tp(&self) -> u32 {
        self.ranks.len() as u32
    }

    /// Number of layers.
    pub fn num_layers(&self) -> u32 {
        self.layers.1 - self.layers.0
    }
}

/// One pipeline: ordered stages plus its micro-batching.
#[derive(Clone, Debug, PartialEq)]
pub struct PipelineSpec {
    /// Stages in order.
    pub stages: Vec<StageSpec>,
    /// Number of micro-batches this pipeline processes per step.
    pub num_microbatches: u32,
    /// Micro-batch size (samples).
    pub microbatch_size: u32,
}

impl PipelineSpec {
    /// All ranks in the pipeline.
    pub fn ranks(&self) -> Vec<Rank> {
        self.stages.iter().flat_map(|s| s.ranks.iter().copied()).collect()
    }

    /// Samples processed per step.
    pub fn samples(&self) -> u64 {
        self.num_microbatches as u64 * self.microbatch_size as u64
    }
}

/// A complete parallel strategy.
#[derive(Clone, Debug, PartialEq)]
pub struct ParallelStrategy {
    /// Human-readable name ("C2", "32B 16H800+32H20", …).
    pub name: String,
    /// Pipelines (data parallelism across them).
    pub pipelines: Vec<PipelineSpec>,
    /// ZeRO-1 optimizer-state sharding across data parallelism.
    pub zero1: bool,
    /// Pipeline schedule.
    pub schedule: ScheduleKind,
    /// Sequence length per sample.
    pub seq_len: u64,
    /// Activation checkpointing.
    pub ac: bool,
}

impl ParallelStrategy {
    /// Validate: each pipeline's stages cover `[0, layers)` contiguously,
    /// ranks are globally disjoint, every pipeline has ≥1 micro-batch.
    pub fn validate(&self, layers: u32) -> Result<()> {
        let mut seen = std::collections::BTreeSet::new();
        for (pi, p) in self.pipelines.iter().enumerate() {
            if p.num_microbatches == 0 || p.microbatch_size == 0 {
                return Err(Error::Strategy(format!("pipeline {pi}: zero micro-batches")));
            }
            let mut next = 0u32;
            for (si, s) in p.stages.iter().enumerate() {
                if s.layers.0 != next {
                    return Err(Error::Strategy(format!(
                        "pipeline {pi} stage {si}: layers start at {} expected {next}",
                        s.layers.0
                    )));
                }
                if s.layers.1 <= s.layers.0 {
                    return Err(Error::Strategy(format!("pipeline {pi} stage {si}: empty layers")));
                }
                next = s.layers.1;
                if s.ranks.is_empty() {
                    return Err(Error::Strategy(format!("pipeline {pi} stage {si}: no ranks")));
                }
                for &r in &s.ranks {
                    if !seen.insert(r) {
                        return Err(Error::Strategy(format!("rank {r} used twice")));
                    }
                }
            }
            if next != layers {
                return Err(Error::Strategy(format!(
                    "pipeline {pi} covers {next} of {layers} layers"
                )));
            }
        }
        Ok(())
    }

    /// All ranks used by the strategy.
    pub fn ranks(&self) -> Vec<Rank> {
        let mut v: Vec<Rank> = self.pipelines.iter().flat_map(|p| p.ranks()).collect();
        v.sort_unstable();
        v
    }

    /// Total samples per step (global batch).
    pub fn global_batch(&self) -> u64 {
        self.pipelines.iter().map(|p| p.samples()).sum()
    }

    /// Stages (across pipelines) holding layer `l`.
    pub fn holders_of_layer(&self, l: u32) -> Vec<&StageSpec> {
        self.pipelines
            .iter()
            .flat_map(|p| p.stages.iter())
            .filter(|s| s.layers.0 <= l && l < s.layers.1)
            .collect()
    }

    /// The HSPMD annotation of one layer's weight matrix under this
    /// strategy: every pipeline that holds layer `l` contributes a sharding
    /// subgroup (TP split along `tp_dim`), and subgroups replicate the
    /// weight across pipelines (`HDim = -1`, data parallelism).
    pub fn weight_annotation(&self, l: u32, tp_dim: u32) -> Result<Annotation> {
        let mut groups = vec![];
        for s in self.holders_of_layer(l) {
            let dg = DeviceGroup::new(s.ranks.clone())?;
            let ds = DistStates::split(tp_dim, s.tp());
            groups.push(Subgroup::new(dg, ds)?);
        }
        if groups.is_empty() {
            return Err(Error::Strategy(format!("no stage holds layer {l}")));
        }
        Annotation::new(groups, crate::hspmd::ds::DUPLICATE)
    }

    /// Compact description (for reports).
    pub fn describe(&self) -> String {
        let pipes: Vec<String> = self
            .pipelines
            .iter()
            .map(|p| {
                let st: Vec<String> = p
                    .stages
                    .iter()
                    .map(|s| {
                        format!(
                            "R{}-{}·L{}-{}",
                            s.ranks.first().unwrap(),
                            s.ranks.last().unwrap(),
                            s.layers.0,
                            s.layers.1 - 1
                        )
                    })
                    .collect();
                format!("{}×bs{} [{}]", p.num_microbatches, p.microbatch_size, st.join(" | "))
            })
            .collect();
        format!("{}: {}", self.name, pipes.join(" ;; "))
    }
}

/// Build a *uniform* strategy (the Megatron/DeepSpeed shape): `dp` identical
/// pipelines of `pp` stages × `tp` ranks, ranks assigned contiguously from
/// `ranks`, layers split evenly.
pub fn uniform(
    name: &str,
    ranks: &[Rank],
    dp: u32,
    tp: u32,
    pp: u32,
    layers: u32,
    global_batch: u64,
    microbatch_size: u32,
    seq_len: u64,
    schedule: ScheduleKind,
    zero1: bool,
    ac: bool,
) -> Result<ParallelStrategy> {
    let need = (dp * tp * pp) as usize;
    if ranks.len() < need {
        return Err(Error::Strategy(format!(
            "uniform {name}: need {need} ranks, have {}",
            ranks.len()
        )));
    }
    let per_dp = global_batch / dp as u64;
    let num_mb = (per_dp / microbatch_size as u64).max(1) as u32;
    let mut pipelines = vec![];
    let mut idx = 0usize;
    for _ in 0..dp {
        let mut stages = vec![];
        let mut l = 0u32;
        for s in 0..pp {
            let hi = layers * (s + 1) / pp;
            stages.push(StageSpec {
                ranks: ranks[idx..idx + tp as usize].to_vec(),
                layers: (l, hi),
            });
            idx += tp as usize;
            l = hi;
        }
        pipelines.push(PipelineSpec { stages, num_microbatches: num_mb, microbatch_size });
    }
    Ok(ParallelStrategy {
        name: name.to_string(),
        pipelines,
        zero1,
        schedule,
        seq_len,
        ac,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn uniform_constructs_and_validates() {
        let ranks: Vec<Rank> = (0..32).collect();
        let s = uniform("dp2tp4pp4", &ranks, 2, 4, 4, 60, 64, 2, 4096, ScheduleKind::OneFOneB, true, false)
            .unwrap();
        s.validate(60).unwrap();
        assert_eq!(s.pipelines.len(), 2);
        assert_eq!(s.pipelines[0].stages.len(), 4);
        assert_eq!(s.global_batch(), 64);
        assert_eq!(s.ranks().len(), 32);
    }

    #[test]
    fn validation_catches_gaps() {
        let s = ParallelStrategy {
            name: "bad".into(),
            pipelines: vec![PipelineSpec {
                stages: vec![StageSpec::r_l(0, 3, 0, 29), StageSpec::r_l(4, 7, 31, 59)],
                num_microbatches: 4,
                microbatch_size: 1,
            }],
            zero1: false,
            schedule: ScheduleKind::OneFOneB,
            seq_len: 4096,
            ac: false,
        };
        assert!(s.validate(60).is_err());
    }

    #[test]
    fn validation_catches_rank_reuse() {
        let s = ParallelStrategy {
            name: "bad".into(),
            pipelines: vec![
                PipelineSpec {
                    stages: vec![StageSpec::r_l(0, 3, 0, 59)],
                    num_microbatches: 4,
                    microbatch_size: 1,
                },
                PipelineSpec {
                    stages: vec![StageSpec::r_l(3, 6, 0, 59)],
                    num_microbatches: 4,
                    microbatch_size: 1,
                },
            ],
            zero1: false,
            schedule: ScheduleKind::OneFOneB,
            seq_len: 4096,
            ac: false,
        };
        assert!(s.validate(60).is_err());
    }

    #[test]
    fn weight_annotation_spans_pipelines() {
        let ranks: Vec<Rank> = (0..16).collect();
        let s = uniform("dp2tp4pp2", &ranks, 2, 4, 2, 60, 64, 2, 4096, ScheduleKind::OneFOneB, true, false)
            .unwrap();
        let ann = s.weight_annotation(0, 0).unwrap();
        assert_eq!(ann.hsize(), 2); // two pipelines hold layer 0
        assert_eq!(ann.hdim, crate::hspmd::ds::DUPLICATE);
        assert_eq!(ann.groups[0].ds.shards(0), 4);
        // heterogeneous second stage holds layer 59
        let ann59 = s.weight_annotation(59, 0).unwrap();
        assert_eq!(ann59.groups[0].dg.ranks(), &[4, 5, 6, 7]);
    }

    #[test]
    fn stage_shorthand_is_inclusive() {
        let st = StageSpec::r_l(16, 19, 0, 6);
        assert_eq!(st.ranks, vec![16, 17, 18, 19]);
        assert_eq!(st.layers, (0, 7));
        assert_eq!(st.tp(), 4);
        assert_eq!(st.num_layers(), 7);
    }
}
