//! Appendix-A strategy tables, encoded.
//!
//! Every Hetu strategy the paper reports (Tables 5, 7, 8, 11, 12) is
//! reproduced here as a constructor. Rank numbering follows the paper:
//! in heterogeneous clusters ranks 0–15 are H800 and 16–47 are H20; in the
//! homogeneous (mixed-length / elastic C1–C3) experiments all ranks are H20.

use super::{ParallelStrategy, PipelineSpec, StageSpec};
use crate::spec::schedule::ScheduleKind;

fn strat(name: &str, pipelines: Vec<PipelineSpec>, zero1: bool, seq: u64) -> ParallelStrategy {
    ParallelStrategy {
        name: name.to_string(),
        pipelines,
        zero1,
        schedule: ScheduleKind::OneFOneB,
        seq_len: seq,
        ac: false,
    }
}

fn pipe(stages: Vec<StageSpec>, num_mb: u32, bs: u32) -> PipelineSpec {
    PipelineSpec { stages, num_microbatches: num_mb, microbatch_size: bs }
}

// ---------------------------------------------------------------- Table 5

/// Table 5 — 32B on 16 H800 + 16 H20 (two 4.5-stage pipelines, 32×bs1).
pub fn hetu_32b_16h800_16h20() -> ParallelStrategy {
    strat(
        "hetu-32b-16h800-16h20",
        vec![
            pipe(
                vec![
                    StageSpec::r_l(16, 19, 0, 6),
                    StageSpec::r_l(20, 23, 7, 13),
                    StageSpec::r_l(0, 3, 14, 36),
                    StageSpec::r_l(4, 7, 37, 59),
                ],
                32,
                1,
            ),
            pipe(
                vec![
                    StageSpec::r_l(24, 27, 0, 6),
                    StageSpec::r_l(28, 31, 7, 13),
                    StageSpec::r_l(8, 11, 14, 36),
                    StageSpec::r_l(12, 15, 37, 59),
                ],
                32,
                1,
            ),
        ],
        true,
        4096,
    )
}

/// Table 5 — 32B on 16 H800 + 24 H20 (two 5.5-stage pipelines, 32×bs1).
pub fn hetu_32b_16h800_24h20() -> ParallelStrategy {
    strat(
        "hetu-32b-16h800-24h20",
        vec![
            pipe(
                vec![
                    StageSpec::r_l(16, 19, 0, 5),
                    StageSpec::r_l(20, 23, 6, 11),
                    StageSpec::r_l(24, 27, 12, 17),
                    StageSpec::r_l(0, 3, 18, 38),
                    StageSpec::r_l(4, 7, 39, 59),
                ],
                32,
                1,
            ),
            pipe(
                vec![
                    StageSpec::r_l(28, 31, 0, 5),
                    StageSpec::r_l(32, 35, 6, 11),
                    StageSpec::r_l(36, 39, 12, 17),
                    StageSpec::r_l(8, 11, 18, 38),
                    StageSpec::r_l(12, 15, 39, 59),
                ],
                32,
                1,
            ),
        ],
        true,
        4096,
    )
}

/// Table 5 — 32B on 16 H800 + 32 H20 (four 3-stage pipelines, 16×bs1).
pub fn hetu_32b_16h800_32h20() -> ParallelStrategy {
    let mk = |h20a: u32, h20b: u32, h800: u32| {
        pipe(
            vec![
                StageSpec::r_l(h20a, h20a + 3, 0, 10),
                StageSpec::r_l(h20b, h20b + 3, 11, 21),
                StageSpec::r_l(h800, h800 + 3, 22, 59),
            ],
            16,
            1,
        )
    };
    strat(
        "hetu-32b-16h800-32h20",
        vec![mk(16, 20, 0), mk(24, 28, 4), mk(32, 36, 8), mk(40, 44, 12)],
        true,
        4096,
    )
}

/// Table 5 — 70B on 16 H800 + 16 H20 (one 4-stage TP8 pipeline, 64×bs1).
pub fn hetu_70b_16h800_16h20() -> ParallelStrategy {
    strat(
        "hetu-70b-16h800-16h20",
        vec![pipe(
            vec![
                StageSpec::r_l(16, 23, 0, 10),
                StageSpec::r_l(24, 31, 11, 21),
                StageSpec::r_l(0, 7, 22, 50),
                StageSpec::r_l(8, 15, 51, 79),
            ],
            64,
            1,
        )],
        true,
        4096,
    )
}

/// Table 5 — 70B on 16 H800 + 24 H20 (one 5-stage TP8 pipeline, 64×bs1).
pub fn hetu_70b_16h800_24h20() -> ParallelStrategy {
    strat(
        "hetu-70b-16h800-24h20",
        vec![pipe(
            vec![
                StageSpec::r_l(16, 23, 0, 9),
                StageSpec::r_l(24, 31, 10, 19),
                StageSpec::r_l(32, 39, 20, 29),
                StageSpec::r_l(0, 7, 30, 54),
                StageSpec::r_l(8, 15, 55, 79),
            ],
            64,
            1,
        )],
        true,
        4096,
    )
}

/// Table 5 — 70B on 16 H800 + 32 H20 (two 3-stage TP8 pipelines, 32×bs1).
pub fn hetu_70b_16h800_32h20() -> ParallelStrategy {
    strat(
        "hetu-70b-16h800-32h20",
        vec![
            pipe(
                vec![
                    StageSpec::r_l(16, 23, 0, 16),
                    StageSpec::r_l(24, 31, 17, 33),
                    StageSpec::r_l(0, 7, 34, 79),
                ],
                32,
                1,
            ),
            pipe(
                vec![
                    StageSpec::r_l(32, 39, 0, 16),
                    StageSpec::r_l(40, 47, 17, 33),
                    StageSpec::r_l(8, 15, 34, 79),
                ],
                32,
                1,
            ),
        ],
        true,
        4096,
    )
}

// ---------------------------------------------------------------- Table 7

/// Table 7 — C1: 32 H20, two 4-stage TP4 pipelines, 16×bs2. ZeRO-1 disabled
/// for restart-free fault tolerance (§7.2).
pub fn hetu_c1_32h20() -> ParallelStrategy {
    let mk = |base: u32| {
        pipe(
            vec![
                StageSpec::r_l(base, base + 3, 0, 14),
                StageSpec::r_l(base + 4, base + 7, 15, 29),
                StageSpec::r_l(base + 8, base + 11, 30, 44),
                StageSpec::r_l(base + 12, base + 15, 45, 59),
            ],
            16,
            2,
        )
    };
    strat("C1", vec![mk(0), mk(16)], false, 4096)
}

/// Table 7 — C2: 31 H20 (rank 31 failed): a 4-stage pipeline (33×bs1) plus
/// an asymmetric 5-stage pipeline ending in a 2-GPU and a 1-GPU stage
/// (31×bs1).
pub fn hetu_c2_31h20() -> ParallelStrategy {
    strat(
        "C2",
        vec![
            pipe(
                vec![
                    StageSpec::r_l(0, 3, 0, 14),
                    StageSpec::r_l(4, 7, 15, 29),
                    StageSpec::r_l(8, 11, 30, 44),
                    StageSpec::r_l(12, 15, 45, 59),
                ],
                33,
                1,
            ),
            pipe(
                vec![
                    StageSpec::r_l(16, 19, 0, 15),
                    StageSpec::r_l(20, 23, 16, 31),
                    StageSpec::r_l(24, 27, 32, 47),
                    StageSpec::r_l(28, 29, 48, 55),
                    StageSpec::r_l(30, 30, 56, 59),
                ],
                31,
                1,
            ),
        ],
        false,
        4096,
    )
}

/// Table 7 — C3: 24 H20, two 3-stage TP4 pipelines, 32×bs1.
pub fn hetu_c3_24h20() -> ParallelStrategy {
    let mk = |base: u32| {
        pipe(
            vec![
                StageSpec::r_l(base, base + 3, 0, 19),
                StageSpec::r_l(base + 4, base + 7, 20, 39),
                StageSpec::r_l(base + 8, base + 11, 40, 59),
            ],
            32,
            1,
        )
    };
    strat("C3", vec![mk(0), mk(12)], false, 4096)
}

// ---------------------------------------------------------------- Table 8

/// Table 8 — C4: 16 H800 + 32 H20, two 6-stage pipelines, 32×bs1.
pub fn hetu_c4() -> ParallelStrategy {
    strat(
        "C4",
        vec![
            pipe(
                vec![
                    StageSpec::r_l(16, 19, 0, 4),
                    StageSpec::r_l(20, 23, 5, 10),
                    StageSpec::r_l(24, 27, 11, 16),
                    StageSpec::r_l(28, 31, 17, 22),
                    StageSpec::r_l(0, 3, 23, 40),
                    StageSpec::r_l(4, 7, 41, 59),
                ],
                32,
                1,
            ),
            pipe(
                vec![
                    StageSpec::r_l(32, 35, 0, 4),
                    StageSpec::r_l(36, 39, 5, 10),
                    StageSpec::r_l(40, 43, 11, 16),
                    StageSpec::r_l(44, 47, 17, 22),
                    StageSpec::r_l(8, 11, 23, 40),
                    StageSpec::r_l(12, 15, 41, 59),
                ],
                32,
                1,
            ),
        ],
        false,
        4096,
    )
}

/// Table 8 — C5: 16 H800 + 24 H20, two 5-stage pipelines, 32×bs1.
pub fn hetu_c5() -> ParallelStrategy {
    strat(
        "C5",
        vec![
            pipe(
                vec![
                    StageSpec::r_l(16, 19, 0, 5),
                    StageSpec::r_l(20, 23, 6, 11),
                    StageSpec::r_l(24, 27, 12, 17),
                    StageSpec::r_l(0, 3, 18, 38),
                    StageSpec::r_l(4, 7, 39, 59),
                ],
                32,
                1,
            ),
            pipe(
                vec![
                    StageSpec::r_l(28, 31, 0, 5),
                    StageSpec::r_l(32, 35, 6, 11),
                    StageSpec::r_l(36, 39, 12, 17),
                    StageSpec::r_l(8, 11, 18, 38),
                    StageSpec::r_l(12, 15, 39, 59),
                ],
                32,
                1,
            ),
        ],
        false,
        4096,
    )
}

/// Table 8 — C6: 15 H800 + 24 H20 (rank 15 failed): a 5-stage pipeline
/// (33×bs1) plus a 6-stage pipeline whose tail degrades to 2- and 1-GPU
/// stages (31×bs1).
pub fn hetu_c6() -> ParallelStrategy {
    strat(
        "C6",
        vec![
            pipe(
                vec![
                    StageSpec::r_l(16, 19, 0, 5),
                    StageSpec::r_l(20, 23, 6, 11),
                    StageSpec::r_l(24, 27, 12, 17),
                    StageSpec::r_l(0, 3, 18, 38),
                    StageSpec::r_l(4, 7, 39, 59),
                ],
                33,
                1,
            ),
            pipe(
                vec![
                    StageSpec::r_l(28, 31, 0, 5),
                    StageSpec::r_l(32, 35, 6, 11),
                    StageSpec::r_l(36, 39, 12, 17),
                    StageSpec::r_l(8, 11, 18, 39),
                    StageSpec::r_l(12, 13, 40, 52),
                    StageSpec::r_l(14, 14, 53, 59),
                ],
                31,
                1,
            ),
        ],
        false,
        4096,
    )
}

/// Table 8 — C7: 8 H800 + 24 H20 (node 1 failed), two 4-stage pipelines,
/// 32×bs1.
pub fn hetu_c7() -> ParallelStrategy {
    strat(
        "C7",
        vec![
            pipe(
                vec![
                    StageSpec::r_l(16, 19, 0, 8),
                    StageSpec::r_l(20, 23, 9, 18),
                    StageSpec::r_l(24, 27, 19, 28),
                    StageSpec::r_l(0, 3, 29, 59),
                ],
                32,
                1,
            ),
            pipe(
                vec![
                    StageSpec::r_l(28, 31, 0, 8),
                    StageSpec::r_l(32, 35, 9, 18),
                    StageSpec::r_l(36, 39, 19, 28),
                    StageSpec::r_l(4, 7, 29, 59),
                ],
                32,
                1,
            ),
        ],
        false,
        4096,
    )
}

// ----------------------------------------------------- Tables 11/12 (Hetu-B)

/// Table 11 — Hetu-B Strategy 1 (32K ctx, MaxSeqLen ∈ (16K, 32K]): one
/// TP16 long-sequence pipeline (R0–15) + four TP4 short-sequence pipelines.
/// Micro-batch counts are bound at dispatch time; the defaults here carry a
/// placeholder of 1 (callers override per step).
pub fn hetu_b_32k_strategy1(seq: u64) -> ParallelStrategy {
    let mut pipelines = vec![pipe(vec![StageSpec::r_l(0, 15, 0, 59)], 1, 1)];
    for base in [16u32, 20, 24, 28] {
        pipelines.push(pipe(vec![StageSpec::r_l(base, base + 3, 0, 59)], 1, 1));
    }
    strat("hetu-b-32k-s1", pipelines, true, seq)
}

/// Table 11 — Hetu-B Strategy 2 (32K ctx, MaxSeqLen ∈ (0, 16K]): one TP8
/// long-sequence pipeline (R0–7) + three 2-stage TP4 short pipelines.
pub fn hetu_b_32k_strategy2(seq: u64) -> ParallelStrategy {
    let mut pipelines = vec![pipe(vec![StageSpec::r_l(0, 7, 0, 59)], 1, 1)];
    for base in [8u32, 16, 24] {
        pipelines.push(pipe(
            vec![StageSpec::r_l(base, base + 3, 0, 29), StageSpec::r_l(base + 4, base + 7, 30, 59)],
            1,
            1,
        ));
    }
    strat("hetu-b-32k-s2", pipelines, true, seq)
}

/// Table 12 — Hetu-B Strategy 1 (16K ctx, MaxSeqLen ∈ (4K, 16K]): same
/// shape as the 32K Strategy 2.
pub fn hetu_b_16k_strategy1(seq: u64) -> ParallelStrategy {
    let mut s = hetu_b_32k_strategy2(seq);
    s.name = "hetu-b-16k-s1".into();
    s
}

/// Table 12 — Hetu-B Strategy 2 (16K ctx, MaxSeqLen ∈ (0, 4K]): uniform
/// DP4 TP4 PP2.
pub fn hetu_b_16k_strategy2(seq: u64) -> ParallelStrategy {
    let ranks: Vec<u32> = (0..32).collect();
    let mut s = super::uniform(
        "hetu-b-16k-s2",
        &ranks,
        4,
        4,
        2,
        60,
        4,
        1,
        seq,
        ScheduleKind::OneFOneB,
        true,
        false,
    )
    .unwrap();
    s.zero1 = true;
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn all_32b_strategies_validate() {
        for s in [
            hetu_32b_16h800_16h20(),
            hetu_32b_16h800_24h20(),
            hetu_32b_16h800_32h20(),
            hetu_c1_32h20(),
            hetu_c2_31h20(),
            hetu_c3_24h20(),
            hetu_c4(),
            hetu_c5(),
            hetu_c6(),
            hetu_c7(),
            hetu_b_32k_strategy1(32768),
            hetu_b_32k_strategy2(16384),
            hetu_b_16k_strategy1(16384),
            hetu_b_16k_strategy2(4096),
        ] {
            s.validate(60).unwrap_or_else(|e| panic!("{}: {e}", s.name));
        }
    }

    #[test]
    fn all_70b_strategies_validate() {
        for s in [hetu_70b_16h800_16h20(), hetu_70b_16h800_24h20(), hetu_70b_16h800_32h20()] {
            s.validate(80).unwrap_or_else(|e| panic!("{}: {e}", s.name));
        }
    }

    #[test]
    fn c2_uses_31_gpus_with_asymmetric_tail() {
        let c2 = hetu_c2_31h20();
        assert_eq!(c2.ranks().len(), 31);
        assert!(!c2.ranks().contains(&31));
        let p2 = &c2.pipelines[1];
        assert_eq!(p2.stages.len(), 5);
        assert_eq!(p2.stages[3].tp(), 2);
        assert_eq!(p2.stages[4].tp(), 1);
        // GBS preserved: 33 + 31 = 64
        assert_eq!(c2.global_batch(), 64);
    }

    #[test]
    fn hetero_strategies_put_more_layers_on_h800() {
        // In the 32B 16+16 strategy, H800 stages (R0-7) hold 23 layers vs 7
        // for H20 stages — the workload-balancing core of Fig 1(a).
        let s = hetu_32b_16h800_16h20();
        let p = &s.pipelines[0];
        assert_eq!(p.stages[0].num_layers(), 7); // H20
        assert_eq!(p.stages[2].num_layers(), 23); // H800
    }

    #[test]
    fn elastic_strategies_keep_gbs_64() {
        for s in [hetu_c1_32h20(), hetu_c2_31h20(), hetu_c3_24h20(), hetu_c4(), hetu_c5(), hetu_c6(), hetu_c7()] {
            assert_eq!(s.global_batch(), 64, "{}", s.name);
        }
    }

    #[test]
    fn c1_to_c2_weight_annotations_differ() {
        let c1 = hetu_c1_32h20();
        let c2 = hetu_c2_31h20();
        let a1 = c1.weight_annotation(59, 0).unwrap();
        let a2 = c2.weight_annotation(59, 0).unwrap();
        assert_ne!(a1, a2);
        // C2's last layer lives on TP4 {12..15} and the single GPU 30
        assert!(a2.groups.iter().any(|g| g.dg.ranks() == [30]));
    }
}
