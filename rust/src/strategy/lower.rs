//! Strategy lowering: Appendix-A [`ParallelStrategy`] encodings → runnable
//! [`EngineStrategy`] values at tiny-model scale (DESIGN.md §4).
//!
//! The paper's strategies are written against the 60/80-layer models on the
//! 48-GPU testbed; the engine trains the tiny configuration. Lowering
//! preserves exactly the structure the §5 spatial-heterogeneity claims rest
//! on:
//!
//! * **non-uniform layer splits** — stage boundaries rescale
//!   proportionally (round-half-up) onto the engine's layer count, with a
//!   monotone fix-up so every stage keeps ≥ 1 layer. A split that is
//!   already at engine scale lowers to itself, which is what makes
//!   [`EngineStrategy::uniform`] round-trip through the lowering (property
//!   sweep in `rust/tests/property_sweeps.rs`);
//! * **per-stage TP degrees** — clamped to the largest degree the runtime
//!   has a block artifact for (asymmetric tails like C2's TP4→TP2→TP1
//!   survive unchanged);
//! * **uneven micro-batching** — each pipeline's engine micro-batch count
//!   is its largest-remainder share of `total_microbatches`, weighted by
//!   its paper-scale samples-per-step, floored at one. The engine's
//!   token-weighted gradient sync makes the uneven counts exact (not
//!   approximate) data parallelism;
//! * **ranks → mesh devices** — dense renumbering in (pipeline, stage)
//!   order;
//! * the **schedule** (GPipe/1F1B) carries over verbatim — the engine
//!   interpreter consumes the same [`crate::spec::schedule`] orders the
//!   simulator replays.

use crate::engine::{EnginePipeline, EngineStage, EngineStrategy};
use crate::runtime::ManifestConfig;
use crate::{Error, Result};

use super::ParallelStrategy;

/// Lowering knobs.
#[derive(Clone, Debug)]
pub struct LowerOptions {
    /// Total micro-batches per step across all pipelines (apportioned by
    /// each pipeline's paper-scale sample share, at least one each).
    pub total_microbatches: usize,
    /// TP degrees the runtime has block artifacts for (any order).
    pub tp_degrees: Vec<usize>,
}

impl Default for LowerOptions {
    fn default() -> Self {
        LowerOptions {
            total_microbatches: 8,
            tp_degrees: crate::runtime::native::TP_DEGREES.to_vec(),
        }
    }
}

/// Lower a paper-scale strategy onto the engine's model configuration.
pub fn lower(
    strat: &ParallelStrategy,
    cfg: &ManifestConfig,
    opts: &LowerOptions,
) -> Result<EngineStrategy> {
    lower_impl(strat, cfg, opts, None)
}

/// Lower onto an explicit device list instead of the dense `0..n`
/// renumbering: stage slots are drawn from `devices` in (pipeline, stage)
/// order. This is how elastic re-synthesis maps a fresh strategy onto the
/// surviving mesh devices after a failure — the dead device indices simply
/// never appear in `devices`. Errors if the strategy needs more device
/// slots than provided.
pub fn lower_onto(
    strat: &ParallelStrategy,
    cfg: &ManifestConfig,
    opts: &LowerOptions,
    devices: &[usize],
) -> Result<EngineStrategy> {
    lower_impl(strat, cfg, opts, Some(devices))
}

fn lower_impl(
    strat: &ParallelStrategy,
    cfg: &ManifestConfig,
    opts: &LowerOptions,
    devices: Option<&[usize]>,
) -> Result<EngineStrategy> {
    let src_layers = strat
        .pipelines
        .iter()
        .flat_map(|p| p.stages.iter().map(|s| s.layers.1))
        .max()
        .unwrap_or(0);
    if src_layers == 0 {
        return Err(Error::Strategy(format!("{}: no layers to lower", strat.name)));
    }
    strat.validate(src_layers)?;

    let weights: Vec<u64> = strat.pipelines.iter().map(|p| p.samples()).collect();
    let num_mb = apportion(&weights, opts.total_microbatches)
        .map_err(|e| Error::Strategy(format!("{}: {e}", strat.name)))?;

    let mut pipelines = Vec::with_capacity(strat.pipelines.len());
    let mut dev = 0usize;
    for (pi, p) in strat.pipelines.iter().enumerate() {
        let bounds: Vec<u32> = p.stages.iter().map(|s| s.layers.1).collect();
        let scaled = scale_boundaries(&bounds, src_layers, cfg.layers).map_err(|e| {
            Error::Strategy(format!("{}: pipeline {pi}: {e}", strat.name))
        })?;
        let mut stages = Vec::with_capacity(p.stages.len());
        let mut lo = 0u32;
        for (s, hi) in p.stages.iter().zip(scaled.iter()) {
            let tp = opts
                .tp_degrees
                .iter()
                .copied()
                .filter(|&d| d <= s.ranks.len())
                .max()
                .ok_or_else(|| {
                    Error::Strategy(format!(
                        "{}: no supported TP degree ≤ {} (have {:?})",
                        strat.name,
                        s.ranks.len(),
                        opts.tp_degrees
                    ))
                })?;
            let slot: Vec<usize> = match devices {
                Some(ds) => {
                    if dev + tp > ds.len() {
                        return Err(Error::Strategy(format!(
                            "{}: needs more than the {} provided devices",
                            strat.name,
                            ds.len()
                        )));
                    }
                    ds[dev..dev + tp].to_vec()
                }
                None => (dev..dev + tp).collect(),
            };
            stages.push(EngineStage { devices: slot, layers: (lo, *hi) });
            dev += tp;
            lo = *hi;
        }
        pipelines.push(EnginePipeline { stages, num_microbatches: num_mb[pi] });
    }

    Ok(EngineStrategy {
        name: format!("{}@tiny", strat.name),
        pipelines,
        schedule: strat.schedule,
    })
}

/// Largest-remainder apportionment of `total` micro-batches over sample
/// weights, with a floor of one per pipeline. Shared with the temporal
/// dispatcher's per-step token-weighted apportioning
/// ([`crate::temporal::Dispatcher`]).
pub(crate) fn apportion(weights: &[u64], total: usize) -> std::result::Result<Vec<usize>, String> {
    let n = weights.len();
    if n == 0 {
        return Err("no pipelines".into());
    }
    if total < n {
        return Err(format!("{total} micro-batches cannot cover {n} pipelines"));
    }
    let w_sum: u128 = weights.iter().map(|&w| w as u128).sum();
    if w_sum == 0 {
        return Err("zero total samples".into());
    }
    let mut alloc = vec![0usize; n];
    let mut rem: Vec<(u128, usize)> = Vec::with_capacity(n);
    let mut used = 0usize;
    for (i, &w) in weights.iter().enumerate() {
        let num = w as u128 * total as u128;
        alloc[i] = (num / w_sum) as usize;
        used += alloc[i];
        rem.push((num % w_sum, i));
    }
    // leftover (< n) goes to the largest fractional shares; ties break on
    // pipeline index for determinism
    rem.sort_by(|a, b| b.0.cmp(&a.0).then(a.1.cmp(&b.1)));
    for k in 0..total - used {
        alloc[rem[k].1] += 1;
    }
    // floor of one: steal from the currently-largest allocation
    for i in 0..n {
        while alloc[i] == 0 {
            let j = (0..n).max_by_key(|&j| alloc[j]).unwrap();
            if alloc[j] <= 1 {
                return Err("cannot give every pipeline a micro-batch".into());
            }
            alloc[j] -= 1;
            alloc[i] += 1;
        }
    }
    Ok(alloc)
}

/// Rescale cumulative stage boundaries (each stage's exclusive layer end)
/// from `src_layers` onto `dst_layers`: proportional round-half-up, then a
/// monotone clamp guaranteeing every stage at least one layer and the last
/// boundary exactly `dst_layers`.
fn scale_boundaries(
    bounds: &[u32],
    src_layers: u32,
    dst_layers: u32,
) -> std::result::Result<Vec<u32>, String> {
    let s_count = bounds.len();
    if s_count as u32 > dst_layers {
        return Err(format!("{s_count} stages cannot split {dst_layers} layers"));
    }
    let mut out: Vec<u32> = Vec::with_capacity(s_count);
    for (k, &b) in bounds.iter().enumerate() {
        let scaled = ((b as u64 * dst_layers as u64 * 2 + src_layers as u64)
            / (2 * src_layers as u64)) as u32;
        let lo = out.last().copied().unwrap_or(0) + 1;
        let hi = dst_layers - (s_count - 1 - k) as u32;
        out.push(scaled.clamp(lo, hi));
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::native;
    use crate::spec::schedule::ScheduleKind;
    use crate::strategy::{tables, uniform};

    fn opts(total_mb: usize) -> LowerOptions {
        LowerOptions { total_microbatches: total_mb, tp_degrees: vec![1, 2, 4] }
    }

    #[test]
    fn boundaries_rescale_preserving_raggedness() {
        // C2 pipeline 1 tail: 60-layer bounds 16/32/48/56/60 → 8 layers
        let out = scale_boundaries(&[16, 32, 48, 56, 60], 60, 8).unwrap();
        assert_eq!(out, vec![2, 4, 6, 7, 8]);
        // identity when already at engine scale
        assert_eq!(scale_boundaries(&[3, 8], 8, 8).unwrap(), vec![3, 8]);
        // heavy skew keeps every stage non-empty
        assert_eq!(scale_boundaries(&[59, 60], 60, 8).unwrap(), vec![7, 8]);
        assert_eq!(scale_boundaries(&[1, 60], 60, 8).unwrap(), vec![1, 8]);
        assert!(scale_boundaries(&[1, 2, 3, 4, 5, 6, 7, 8, 9], 9, 8).is_err());
    }

    #[test]
    fn apportionment_is_weighted_and_floored() {
        assert_eq!(apportion(&[33, 31], 7).unwrap(), vec![4, 3]);
        assert_eq!(apportion(&[32, 32], 8).unwrap(), vec![4, 4]);
        // tiny share still gets one micro-batch
        assert_eq!(apportion(&[100, 1], 4).unwrap(), vec![3, 1]);
        assert!(apportion(&[1, 1, 1], 2).is_err());
    }

    #[test]
    fn c2_lowers_with_asymmetric_tail_and_uneven_microbatches() {
        let cfg = native::tiny_config();
        let c2 = tables::hetu_c2_31h20();
        let e = lower(&c2, &cfg, &opts(7)).unwrap();
        e.validate(&cfg, &[1, 2, 4]).unwrap();
        assert_eq!(e.schedule, ScheduleKind::OneFOneB);
        assert_eq!(e.num_devices(), 31);
        // uneven micro-batching survives (33:31 → 4:3)
        assert_eq!(e.pipelines[0].num_microbatches, 4);
        assert_eq!(e.pipelines[1].num_microbatches, 3);
        // the degraded TP tail survives: 4,4,4,2,1
        let tps: Vec<usize> = e.pipelines[1].stages.iter().map(|s| s.tp()).collect();
        assert_eq!(tps, vec![4, 4, 4, 2, 1]);
        // ragged 5-stage split of 8 layers
        let spans: Vec<u32> =
            e.pipelines[1].stages.iter().map(|s| s.layers.1 - s.layers.0).collect();
        assert_eq!(spans.iter().sum::<u32>(), cfg.layers);
        assert!(spans.iter().any(|&w| w != spans[0]), "split stays non-uniform: {spans:?}");
        // dense device renumbering
        let devs: Vec<usize> = e
            .pipelines
            .iter()
            .flat_map(|p| p.stages.iter().flat_map(|s| s.devices.iter().copied()))
            .collect();
        assert_eq!(devs, (0..31).collect::<Vec<_>>());
    }

    #[test]
    fn paper_tables_lower_and_validate() {
        let cfg = native::tiny_config();
        for s in [
            tables::hetu_32b_16h800_16h20(),
            tables::hetu_32b_16h800_32h20(),
            tables::hetu_c1_32h20(),
            tables::hetu_c2_31h20(),
            tables::hetu_c6(),
            tables::hetu_70b_16h800_24h20(), // TP8 clamps to TP4
        ] {
            let e = lower(&s, &cfg, &opts(8)).unwrap_or_else(|err| panic!("{}: {err}", s.name));
            e.validate(&cfg, &[1, 2, 4]).unwrap_or_else(|err| panic!("{}: {err}", s.name));
            let total: usize = e.pipelines.iter().map(|p| p.num_microbatches).sum();
            assert_eq!(total, 8, "{}", s.name);
        }
    }

    #[test]
    fn uniform_spec_at_engine_scale_lowers_to_engine_uniform() {
        let cfg = native::tiny_config();
        let ranks: Vec<u32> = (0..8).collect();
        let spec = uniform(
            "dp2tp2pp2",
            &ranks,
            2,
            2,
            2,
            cfg.layers,
            8,
            1,
            2048,
            ScheduleKind::GPipe,
            false,
            false,
        )
        .unwrap();
        let lowered = lower(&spec, &cfg, &opts(8)).unwrap();
        let direct = EngineStrategy::uniform("dp2tp2pp2", 2, 2, 2, cfg.layers, 4);
        assert_eq!(lowered.pipelines, direct.pipelines);
        assert_eq!(lowered.schedule, direct.schedule);
    }

    #[test]
    fn lower_onto_maps_slots_to_survivor_devices() {
        let cfg = native::tiny_config();
        let c2 = tables::hetu_c2_31h20(); // needs 31 device slots
        // survivors: a 40-device mesh with devices 3 and 17 dead
        let survivors: Vec<usize> = (0..40).filter(|d| *d != 3 && *d != 17).collect();
        let e = lower_onto(&c2, &cfg, &opts(7), &survivors).unwrap();
        e.validate(&cfg, &[1, 2, 4]).unwrap();
        let used: Vec<usize> = e
            .pipelines
            .iter()
            .flat_map(|p| p.stages.iter().flat_map(|s| s.devices.iter().copied()))
            .collect();
        assert_eq!(used, survivors[..31].to_vec(), "slots drawn in order from survivors");
        assert!(!used.contains(&3) && !used.contains(&17));
        // identical structure to the dense lowering, just renamed devices
        let dense = lower(&c2, &cfg, &opts(7)).unwrap();
        for (pe, pd) in e.pipelines.iter().zip(dense.pipelines.iter()) {
            assert_eq!(pe.num_microbatches, pd.num_microbatches);
            for (se, sd) in pe.stages.iter().zip(pd.stages.iter()) {
                assert_eq!(se.layers, sd.layers);
                assert_eq!(se.devices.len(), sd.devices.len());
            }
        }
        // too few devices is an error, not a truncation
        assert!(lower_onto(&c2, &cfg, &opts(7), &survivors[..20]).is_err());
    }
}
