//! Cost-model-driven strategy selection.
//!
//! The paper selects strategies "using pre-profiled results combined with a
//! cost model" (App. A.3). We reproduce that: candidate strategies are
//! filtered by per-device memory feasibility and ranked by simulated step
//! time.

use crate::cluster::Cluster;
use crate::costmodel::CostModel;
use crate::sim::simulate_step;
use crate::strategy::ParallelStrategy;
use crate::{Error, Result};

/// Check every stage of `strat` fits its devices' memory (delegates to the
/// per-stage planner in [`crate::strategy::memory`], which models schedule-
/// dependent activation liveness).
pub fn memory_feasible(cluster: &Cluster, cm: &CostModel, strat: &ParallelStrategy) -> bool {
    crate::strategy::memory::plan(cm, cluster, strat).1
}

/// Pick the memory-feasible candidate with the lowest simulated step time.
pub fn choose_best(
    cluster: &Cluster,
    cm: &CostModel,
    candidates: &[ParallelStrategy],
) -> Result<(ParallelStrategy, f64)> {
    let mut best: Option<(ParallelStrategy, f64)> = None;
    for c in candidates {
        if !memory_feasible(cluster, cm, c) {
            continue;
        }
        // strategies must only use alive devices
        let alive = cluster.alive_ranks();
        if !c.ranks().iter().all(|r| alive.contains(r)) {
            continue;
        }
        let t = match simulate_step(cluster, cm, c) {
            Ok(rep) => rep.step_s,
            Err(_) => continue,
        };
        if best.as_ref().map(|(_, bt)| t < *bt).unwrap_or(true) {
            best = Some((c.clone(), t));
        }
    }
    best.ok_or_else(|| Error::Strategy("no feasible candidate strategy".into()))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::costmodel::ModelCfg;
    use crate::spec::schedule::ScheduleKind;
    use crate::strategy::{tables, uniform};

    #[test]
    fn infeasible_strategies_filtered() {
        // 32B on a single H20: cannot fit.
        let cluster = Cluster::h20(1);
        let cm = CostModel::new(ModelCfg::llama_32b());
        let s = uniform("solo", &[0], 1, 1, 1, 60, 1, 1, 4096, ScheduleKind::OneFOneB, false, true)
            .unwrap();
        assert!(!memory_feasible(&cluster, &cm, &s));
        assert!(choose_best(&cluster, &cm, &[s]).is_err());
    }

    #[test]
    fn chooser_prefers_faster_strategy() {
        let cluster = Cluster::h20(32);
        let cm = CostModel::new(ModelCfg::llama_32b());
        let ranks: Vec<u32> = (0..32).collect();
        let good = tables::hetu_c1_32h20();
        let bad = uniform("tp32", &ranks, 1, 32, 1, 60, 64, 1, 4096, ScheduleKind::OneFOneB, false, false)
            .unwrap();
        let (best, t) = choose_best(&cluster, &cm, &[bad, good.clone()]).unwrap();
        assert_eq!(best.name, good.name);
        assert!(t > 0.0);
    }

    #[test]
    fn dead_ranks_disqualify() {
        let mut cluster = Cluster::h20(32);
        cluster.fail_gpu(31);
        let cm = CostModel::new(ModelCfg::llama_32b());
        let c1 = tables::hetu_c1_32h20(); // uses rank 31
        let c2 = tables::hetu_c2_31h20();
        let (best, _) = choose_best(&cluster, &cm, &[c1, c2.clone()]).unwrap();
        assert_eq!(best.name, c2.name);
    }
}
