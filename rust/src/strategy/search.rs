//! Cost-model-driven strategy selection (legacy surface).
//!
//! The paper selects strategies "using pre-profiled results combined with a
//! cost model" (App. A.3). The actual selection logic — one memory-
//! feasibility gate, alive-rank filtering, simulated ranking — now lives in
//! [`crate::strategy::synth`]; this module keeps the original entry points
//! as thin deprecated wrappers so older call sites keep compiling.

use crate::cluster::Cluster;
use crate::costmodel::CostModel;
use crate::strategy::ParallelStrategy;
use crate::Result;

/// Check every stage of `strat` fits its devices' memory.
#[deprecated(note = "use strategy::synth::memory_feasible")]
pub fn memory_feasible(cluster: &Cluster, cm: &CostModel, strat: &ParallelStrategy) -> bool {
    super::synth::memory_feasible(cluster, cm, strat)
}

/// Pick the memory-feasible candidate with the lowest simulated step time.
#[deprecated(note = "use strategy::synth::best")]
pub fn choose_best(
    cluster: &Cluster,
    cm: &CostModel,
    candidates: &[ParallelStrategy],
) -> Result<(ParallelStrategy, f64)> {
    super::synth::best(cluster, cm, candidates)
}

#[cfg(test)]
#[allow(deprecated)]
mod tests {
    use super::*;
    use crate::costmodel::ModelCfg;
    use crate::spec::schedule::ScheduleKind;
    use crate::strategy::{tables, uniform};

    #[test]
    fn infeasible_strategies_filtered() {
        // 32B on a single H20: cannot fit.
        let cluster = Cluster::h20(1);
        let cm = CostModel::new(ModelCfg::llama_32b());
        let s = uniform("solo", &[0], 1, 1, 1, 60, 1, 1, 4096, ScheduleKind::OneFOneB, false, true)
            .unwrap();
        assert!(!memory_feasible(&cluster, &cm, &s));
        assert!(choose_best(&cluster, &cm, &[s]).is_err());
    }

    #[test]
    fn chooser_prefers_faster_strategy() {
        let cluster = Cluster::h20(32);
        let cm = CostModel::new(ModelCfg::llama_32b());
        let ranks: Vec<u32> = (0..32).collect();
        let good = tables::hetu_c1_32h20();
        let bad = uniform("tp32", &ranks, 1, 32, 1, 60, 64, 1, 4096, ScheduleKind::OneFOneB, false, false)
            .unwrap();
        let (best, t) = choose_best(&cluster, &cm, &[bad, good.clone()]).unwrap();
        assert_eq!(best.name, good.name);
        assert!(t > 0.0);
    }

    #[test]
    fn dead_ranks_disqualify() {
        let mut cluster = Cluster::h20(32);
        cluster.fail_gpu(31);
        let cm = CostModel::new(ModelCfg::llama_32b());
        let c1 = tables::hetu_c1_32h20(); // uses rank 31
        let c2 = tables::hetu_c2_31h20();
        let (best, _) = choose_best(&cluster, &cm, &[c1, c2.clone()]).unwrap();
        assert_eq!(best.name, c2.name);
    }
}
