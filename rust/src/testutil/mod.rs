//! In-repo randomized property-testing harness.
//!
//! The build image cannot fetch `proptest`, so this module provides the small
//! subset we need: a seeded, reproducible PRNG (xorshift64*), generator
//! helpers, and a [`check`] driver that runs an invariant over many random
//! cases and reports the seed of the first failing case so it can be replayed
//! deterministically.

/// Deterministic xorshift64* PRNG. Not cryptographic; stable across
/// platforms, which is what reproducible property tests need.
#[derive(Clone, Debug)]
pub struct Rng {
    state: u64,
}

impl Rng {
    /// Create a PRNG from a seed (0 is remapped to a fixed odd constant).
    pub fn new(seed: u64) -> Self {
        Rng {
            state: if seed == 0 { 0x9E37_79B9_7F4A_7C15 } else { seed },
        }
    }

    /// Next raw 64-bit value.
    pub fn next_u64(&mut self) -> u64 {
        let mut x = self.state;
        x ^= x >> 12;
        x ^= x << 25;
        x ^= x >> 27;
        self.state = x;
        x.wrapping_mul(0x2545_F491_4F6C_DD1D)
    }

    /// Uniform in `[0, n)`. `n` must be > 0.
    pub fn below(&mut self, n: u64) -> u64 {
        debug_assert!(n > 0);
        // Rejection-free modulo is fine for test-case generation.
        self.next_u64() % n
    }

    /// Uniform usize in `[lo, hi]` inclusive.
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        debug_assert!(lo <= hi);
        lo + self.below((hi - lo + 1) as u64) as usize
    }

    /// Uniform f64 in `[0, 1)`.
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 / (1u64 << 53) as f64
    }

    /// Uniform f32 in `[-1, 1)` (handy for tensor payloads).
    pub fn f32_signed(&mut self) -> f32 {
        (self.f64() * 2.0 - 1.0) as f32
    }

    /// Bernoulli with probability `p`.
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Pick a random element of a non-empty slice.
    pub fn pick<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.range(0, xs.len() - 1)]
    }

    /// Shuffle a slice in place (Fisher–Yates).
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.range(0, i);
            xs.swap(i, j);
        }
    }

    /// Log-normal-ish sample via sum of uniforms (Irwin–Hall approximates a
    /// normal; exp of it gives the heavy-tailed shape we need for sequence
    /// lengths). `mu`/`sigma` are in log space.
    pub fn lognormal(&mut self, mu: f64, sigma: f64) -> f64 {
        let mut s = 0.0;
        for _ in 0..12 {
            s += self.f64();
        }
        let z = s - 6.0; // ~N(0,1)
        (mu + sigma * z).exp()
    }
}

/// Run `cases` random checks of `prop`, feeding each a fresh seeded [`Rng`].
/// Panics with the failing seed on first failure, so
/// `check_seed(<seed>, prop)` replays it.
pub fn check<F: FnMut(&mut Rng) -> std::result::Result<(), String>>(
    name: &str,
    cases: u64,
    mut prop: F,
) {
    for case in 0..cases {
        let seed = 0xC0FFEE ^ case.wrapping_mul(0x9E37_79B9_7F4A_7C15);
        let mut rng = Rng::new(seed);
        if let Err(msg) = prop(&mut rng) {
            panic!("property `{name}` failed (case {case}, seed {seed:#x}): {msg}");
        }
    }
}

/// Replay a single seed against a property (debugging helper).
pub fn check_seed<F: FnMut(&mut Rng) -> std::result::Result<(), String>>(seed: u64, mut prop: F) {
    let mut rng = Rng::new(seed);
    if let Err(msg) = prop(&mut rng) {
        panic!("property failed at seed {seed:#x}: {msg}");
    }
}

/// Assert two f32 slices are element-wise close.
pub fn assert_allclose(a: &[f32], b: &[f32], atol: f32, rtol: f32, what: &str) {
    assert_eq!(a.len(), b.len(), "{what}: length mismatch {} vs {}", a.len(), b.len());
    for (i, (x, y)) in a.iter().zip(b.iter()).enumerate() {
        let tol = atol + rtol * y.abs();
        assert!(
            (x - y).abs() <= tol,
            "{what}: element {i} differs: {x} vs {y} (tol {tol})"
        );
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rng_is_deterministic() {
        let mut a = Rng::new(7);
        let mut b = Rng::new(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn range_bounds_hold() {
        check("range bounds", 200, |rng| {
            let lo = rng.range(0, 50);
            let hi = lo + rng.range(0, 50);
            let v = rng.range(lo, hi);
            if v < lo || v > hi {
                return Err(format!("{v} outside [{lo},{hi}]"));
            }
            Ok(())
        });
    }

    #[test]
    fn shuffle_is_permutation() {
        check("shuffle permutation", 100, |rng| {
            let n = rng.range(1, 30);
            let mut xs: Vec<usize> = (0..n).collect();
            rng.shuffle(&mut xs);
            let mut sorted = xs.clone();
            sorted.sort_unstable();
            if sorted != (0..n).collect::<Vec<_>>() {
                return Err("not a permutation".into());
            }
            Ok(())
        });
    }

    #[test]
    fn lognormal_is_positive() {
        let mut rng = Rng::new(3);
        for _ in 0..1000 {
            assert!(rng.lognormal(2.0, 1.0) > 0.0);
        }
    }
}
