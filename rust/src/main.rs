//! `hetu` — the launcher CLI.
//!
//! Subcommands:
//!
//! * `train`    — run the real-numerics distributed engine on the tiny
//!   model artifacts (`--steps`, `--devices`, `--dp/--tp/--pp`, `--lr`).
//! * `figures`  — regenerate paper tables/figures (`fig13 fig14 fig15
//!   fig16 fig17 fig18 table2`, or `all`).
//! * `info`     — show artifact registry + cluster presets.

use hetu::config::{Cli, RunConfig};
use hetu::coordinator::Trainer;
use hetu::engine::EngineStrategy;

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let cli = Cli::parse(&args);
    let code = match cli.command.as_str() {
        "train" => cmd_train(&cli),
        "figures" => cmd_figures(&cli),
        "info" => cmd_info(&cli),
        "" | "help" => {
            usage();
            0
        }
        other => {
            eprintln!("unknown command `{other}`");
            usage();
            2
        }
    };
    std::process::exit(code);
}

fn usage() {
    eprintln!(
        "hetu — HSPMD distributed training (Hetu v2 reproduction)\n\
         \n\
         USAGE:\n\
           hetu train   [--steps N] [--dp N] [--tp N] [--pp N] [--microbatches N] [--lr F] [--artifacts DIR]\n\
           hetu figures [fig13|fig14|fig15|fig16|fig17|fig18|table2|all] [--steps N]\n\
           hetu info    [--artifacts DIR]"
    );
}

fn cmd_train(cli: &Cli) -> i32 {
    let run = || -> hetu::Result<()> {
        let cfg = RunConfig::from_cli(cli)?;
        let dp = cli.u64_opt("dp", 1)? as usize;
        let tp = cli.u64_opt("tp", 1)? as usize;
        let pp = cli.u64_opt("pp", 2)? as usize;
        let mb = cli.u64_opt("microbatches", 4)? as usize;
        // layers come from the artifact manifest at Engine::new; use the
        // tiny default (8) for strategy construction and let validation
        // correct us.
        let strategy = EngineStrategy::uniform("cli", dp, tp, pp, 8, mb);
        println!("strategy: dp{dp} tp{tp} pp{pp}, {mb} microbatches/pipeline");
        let mut trainer = Trainer::new(cfg.clone(), strategy)?;
        trainer.train(cfg.steps)?;
        for log in trainer.logs() {
            println!(
                "step {:>4}  loss {:.4}  {:>8.1}ms  wire {:>10} elems  [{}]",
                log.step,
                log.loss,
                log.wall_s * 1e3,
                log.wire_elems,
                log.strategy
            );
        }
        let (head, tail) = trainer.loss_improved()?;
        println!("loss: first-quarter mean {head:.4} -> last-quarter mean {tail:.4}");
        Ok(())
    };
    match run() {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("error: {e}");
            1
        }
    }
}

fn cmd_figures(cli: &Cli) -> i32 {
    let what = cli.positional.first().map(|s| s.as_str()).unwrap_or("all");
    let steps = cli.u64_opt("steps", 20).unwrap_or(20) as usize;
    let run = || -> hetu::Result<()> {
        let all = what == "all";
        if all || what == "fig13" {
            println!("{}", hetu::figures::fig13()?.0.markdown());
        }
        if all || what == "fig14" {
            for (_, t) in hetu::figures::fig14()? {
                println!("{}", t.markdown());
            }
        }
        if all || what == "fig15" {
            println!("{}", hetu::figures::fig15(steps)?.0.markdown());
        }
        if all || what == "fig16" {
            println!("{}", hetu::figures::fig16(steps)?.markdown());
        }
        if all || what == "fig17" {
            println!("{}", hetu::figures::fig17()?.markdown());
        }
        if all || what == "fig18" {
            println!("{}", hetu::figures::fig18_left()?.markdown());
            println!("{}", hetu::figures::fig18_right()?.markdown());
        }
        if all || what == "table2" {
            println!("{}", hetu::figures::table2()?.markdown());
        }
        Ok(())
    };
    match run() {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("error: {e}");
            1
        }
    }
}

fn cmd_info(cli: &Cli) -> i32 {
    let dir = cli.str_opt("artifacts", "artifacts");
    match hetu::runtime::Runtime::open_or_native(&dir) {
        Ok(rt) => {
            let c = rt.config;
            println!(
                "backend: {}",
                if rt.is_native() { "native reference (no artifacts found)" } else { "PJRT artifacts" }
            );
            println!(
                "model: {} layers, hidden {}, ffn {}, {} heads, vocab {} (compiled B={} S={})",
                c.layers, c.hidden, c.ffn, c.heads, c.vocab, c.batch, c.seq
            );
            println!("artifacts:");
            for name in rt.artifact_names() {
                let m = rt.meta(&name).unwrap();
                println!("  {:<16} {} inputs, {} outputs", name, m.inputs.len(), m.outputs);
            }
            let cluster = hetu::cluster::Cluster::h800_16_h20_32();
            println!(
                "\nsimulated testbed: {} devices ({} nodes), e.g. R0={} R16={}",
                cluster.len(),
                cluster.len() as u32 / hetu::cluster::GPUS_PER_NODE,
                cluster.device(0).kind.name,
                cluster.device(16).kind.name
            );
            0
        }
        Err(e) => {
            eprintln!("error: {e}");
            1
        }
    }
}
