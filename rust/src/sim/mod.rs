//! Discrete-event per-step training simulator.
//!
//! Evaluates a [`ParallelStrategy`] on a [`Cluster`] under the
//! [`CostModel`]: per-stage forward/backward task durations (compute + TP
//! collectives), cross-stage activation transfers, 1F1B/GPipe dependency
//! structure, and the end-of-step gradient synchronization across pipelines
//! (including the hetero-DP SplitAR case where pipelines shard layers at
//! different TP degrees).
//!
//! Output is a [`StepReport`]: total step time plus the per-rank
//! compute/comm/bubble breakdown the paper shows in Fig 18 (left).

use std::collections::BTreeMap;

use crate::cluster::Cluster;
use crate::costmodel::CostModel;
use crate::hspmd::dg::Rank;
use crate::spec::schedule::{stage_schedule, Task, TaskKind};
use crate::strategy::ParallelStrategy;
use crate::{Error, Result};

/// Per-rank time breakdown over one step (Fig 18-left).
#[derive(Clone, Copy, Debug, Default)]
pub struct RankBreakdown {
    /// Seconds of dense compute.
    pub compute_s: f64,
    /// Seconds of communication the rank participates in (TP sync, PP
    /// boundaries, gradient sync).
    pub comm_s: f64,
    /// Idle (pipeline bubble + waiting for stragglers).
    pub bubble_s: f64,
}

impl RankBreakdown {
    /// Busy + idle = step time.
    pub fn total_s(&self) -> f64 {
        self.compute_s + self.comm_s + self.bubble_s
    }
}

/// Simulation result for one training step.
#[derive(Clone, Debug)]
pub struct StepReport {
    /// End-to-end step seconds (slowest pipeline + gradient sync).
    pub step_s: f64,
    /// Per-pipeline makespan (before gradient sync).
    pub pipeline_s: Vec<f64>,
    /// Gradient synchronization seconds (max over ranks).
    pub grad_sync_s: f64,
    /// Per-rank breakdown.
    pub per_rank: BTreeMap<Rank, RankBreakdown>,
}

/// Simulator options (baseline-system handicaps).
#[derive(Clone, Copy, Debug)]
pub struct SimOptions {
    /// Multiplier on pipeline-boundary transfer time (HexiScale's
    /// coarse-grained broadcast between stages = destination TP degree).
    pub boundary_factor: f64,
}

impl Default for SimOptions {
    fn default() -> Self {
        SimOptions { boundary_factor: 1.0 }
    }
}

/// Per-stage derived timing quantities.
struct StageTiming {
    fwd_compute: f64,
    bwd_compute: f64,
    fwd_comm: f64,
    bwd_comm: f64,
    boundary_in_s: f64, // transfer time from previous stage
}

fn stage_timings(
    cluster: &Cluster,
    cm: &CostModel,
    strat: &ParallelStrategy,
    p: usize,
    opts: SimOptions,
) -> Vec<StageTiming> {
    let pipe = &strat.pipelines[p];
    let tokens_mb = pipe.microbatch_size as u64 * strat.seq_len;
    let mut out = Vec::with_capacity(pipe.stages.len());
    for (si, s) in pipe.stages.iter().enumerate() {
        // slowest member bounds the TP group
        let dev = s
            .ranks
            .iter()
            .map(|&r| cluster.device(r).kind)
            .min_by(|a, b| a.bf16_tflops.partial_cmp(&b.bf16_tflops).unwrap())
            .unwrap();
        let fwd_compute = cm.fwd_s(&dev, s.num_layers(), tokens_mb, strat.seq_len, s.tp());
        let bwd_compute = cm.bwd_s(&dev, s.num_layers(), tokens_mb, strat.seq_len, s.tp());
        let tp_comm = if s.tp() > 1 {
            s.num_layers() as f64
                * cluster.collective_s(&s.ranks, cm.tp_sync_bytes(tokens_mb), true)
        } else {
            0.0
        };
        let boundary_in_s = if si == 0 {
            0.0
        } else {
            let prev = &pipe.stages[si - 1];
            opts.boundary_factor
                * cluster.transfer_s(
                    *prev.ranks.last().unwrap(),
                    *s.ranks.first().unwrap(),
                    cm.pp_boundary_bytes(tokens_mb),
                )
        };
        out.push(StageTiming {
            fwd_compute,
            bwd_compute,
            fwd_comm: tp_comm,
            bwd_comm: tp_comm,
            boundary_in_s,
        });
    }
    out
}

/// Simulate one pipeline's makespan; fills per-rank busy accounting.
fn simulate_pipeline(
    strat: &ParallelStrategy,
    timings: &[StageTiming],
    p: usize,
    busy: &mut BTreeMap<Rank, (f64, f64)>, // rank -> (compute_s, comm_s)
) -> Result<f64> {
    let pipe = &strat.pipelines[p];
    let num_stages = pipe.stages.len();
    let m = pipe.num_microbatches as usize;
    let queues: Vec<Vec<Task>> = (0..num_stages)
        .map(|s| stage_schedule(strat.schedule, num_stages, s, m))
        .collect();
    let mut q_head = vec![0usize; num_stages];
    let mut clock = vec![0f64; num_stages];
    let mut fwd_done = vec![vec![f64::NAN; num_stages]; m];
    let mut bwd_done = vec![vec![f64::NAN; num_stages]; m];

    let total: usize = queues.iter().map(|q| q.len()).sum();
    let mut executed = 0usize;
    loop {
        let mut progressed = false;
        for s in 0..num_stages {
            while q_head[s] < queues[s].len() {
                let task = queues[s][q_head[s]];
                let ready = match task.kind {
                    TaskKind::Fwd => {
                        if s == 0 {
                            Some(0.0)
                        } else {
                            let d = fwd_done[task.microbatch][s - 1];
                            if d.is_nan() {
                                None
                            } else {
                                Some(d + timings[s].boundary_in_s)
                            }
                        }
                    }
                    TaskKind::Bwd => {
                        if s == num_stages - 1 {
                            let f = fwd_done[task.microbatch][s];
                            if f.is_nan() {
                                None
                            } else {
                                Some(f)
                            }
                        } else {
                            let d = bwd_done[task.microbatch][s + 1];
                            if d.is_nan() {
                                None
                            } else {
                                Some(d + timings[s + 1].boundary_in_s)
                            }
                        }
                    }
                };
                let Some(ready) = ready else { break };
                let (compute, comm) = match task.kind {
                    TaskKind::Fwd => (timings[s].fwd_compute, timings[s].fwd_comm),
                    TaskKind::Bwd => (timings[s].bwd_compute, timings[s].bwd_comm),
                };
                let start = clock[s].max(ready);
                let finish = start + compute + comm;
                clock[s] = finish;
                match task.kind {
                    TaskKind::Fwd => fwd_done[task.microbatch][s] = finish,
                    TaskKind::Bwd => bwd_done[task.microbatch][s] = finish,
                }
                for &r in &pipe.stages[s].ranks {
                    let e = busy.entry(r).or_insert((0.0, 0.0));
                    e.0 += compute;
                    e.1 += comm;
                }
                q_head[s] += 1;
                executed += 1;
                progressed = true;
            }
        }
        if executed == total {
            break;
        }
        if !progressed {
            return Err(Error::Strategy(format!(
                "pipeline {p}: schedule deadlock at {executed}/{total} tasks"
            )));
        }
    }
    Ok(clock.iter().copied().fold(0.0, f64::max))
}

/// Gradient synchronization time: for every layer held by >1 pipeline, an
/// all-reduce (ring model) among one TP-shard-matched group per layer.
/// Hetero TP degrees across pipelines correspond to the §4.2 SplitAR path;
/// the ring volume model is identical at equal total bytes.
fn grad_sync(
    cluster: &Cluster,
    cm: &CostModel,
    strat: &ParallelStrategy,
    comm: &mut BTreeMap<Rank, f64>,
) -> f64 {
    let layers = strat
        .pipelines
        .iter()
        .flat_map(|p| p.stages.iter().map(|s| s.layers.1))
        .max()
        .unwrap_or(0);
    for l in 0..layers {
        let holders = strat.holders_of_layer(l);
        if holders.len() <= 1 {
            continue;
        }
        // Bytes each rank must reduce for this layer: its own shard.
        for s in &holders {
            let bytes =
                (cm.model.params_per_layer() as f64 / s.tp() as f64 * cm.params.elem_bytes) as u64;
            // ring across the DP group: one representative per holder stage
            let group: Vec<Rank> = holders.iter().map(|h| h.ranks[0]).collect();
            let t = cluster.collective_s(&group, bytes, true);
            for &r in &s.ranks {
                *comm.entry(r).or_insert(0.0) += t;
            }
        }
    }
    comm.values().copied().fold(0.0, f64::max)
}

/// Rank strategies by simulated step time: the indices of `strats` sorted
/// ascending (fastest first). The engine cross-validation harness
/// (`rust/tests/engine_integration.rs`) asserts the measured makespan
/// ordering of the *lowered* strategies agrees with this ranking.
pub fn rank_by_step_time(
    cluster: &Cluster,
    cm: &CostModel,
    strats: &[&ParallelStrategy],
) -> Result<Vec<usize>> {
    let mut times = Vec::with_capacity(strats.len());
    for (i, &s) in strats.iter().enumerate() {
        times.push((simulate_step(cluster, cm, s)?.step_s, i));
    }
    times.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
    Ok(times.into_iter().map(|(_, i)| i).collect())
}

/// Simulate one training step of `strat` on `cluster` (default options).
pub fn simulate_step(
    cluster: &Cluster,
    cm: &CostModel,
    strat: &ParallelStrategy,
) -> Result<StepReport> {
    simulate_step_opts(cluster, cm, strat, SimOptions::default())
}

/// Simulate one training step with explicit [`SimOptions`].
pub fn simulate_step_opts(
    cluster: &Cluster,
    cm: &CostModel,
    strat: &ParallelStrategy,
    opts: SimOptions,
) -> Result<StepReport> {
    let layers = strat
        .pipelines
        .iter()
        .flat_map(|p| p.stages.iter().map(|s| s.layers.1))
        .max()
        .unwrap_or(0);
    strat.validate(layers)?;

    // activation checkpointing: backward recomputes the forward
    let mut cm_eff = *cm;
    if strat.ac {
        cm_eff.params.ac_recompute = 2.0;
    }
    let cm = &cm_eff;

    let mut busy: BTreeMap<Rank, (f64, f64)> = BTreeMap::new();
    let mut pipeline_s = Vec::with_capacity(strat.pipelines.len());
    for p in 0..strat.pipelines.len() {
        let timings = stage_timings(cluster, cm, strat, p, opts);
        pipeline_s.push(simulate_pipeline(strat, &timings, p, &mut busy)?);
    }
    let compute_span = pipeline_s.iter().copied().fold(0.0, f64::max);

    let mut grad_comm: BTreeMap<Rank, f64> = BTreeMap::new();
    let grad_sync_s = grad_sync(cluster, cm, strat, &mut grad_comm);
    let step_s = compute_span + grad_sync_s;

    let mut per_rank = BTreeMap::new();
    for &r in &strat.ranks() {
        let (c, m) = busy.get(&r).copied().unwrap_or((0.0, 0.0));
        let g = grad_comm.get(&r).copied().unwrap_or(0.0);
        let comm_s = m + g;
        per_rank.insert(
            r,
            RankBreakdown {
                compute_s: c,
                comm_s,
                bubble_s: (step_s - c - comm_s).max(0.0),
            },
        );
    }
    Ok(StepReport { step_s, pipeline_s, grad_sync_s, per_rank })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::costmodel::ModelCfg;
    use crate::spec::schedule::ScheduleKind;
    use crate::strategy::{tables, uniform};

    fn cm32() -> CostModel {
        CostModel::new(ModelCfg::llama_32b())
    }

    #[test]
    fn uniform_tp4pp4_simulates() {
        let cluster = Cluster::h20(16);
        let ranks: Vec<Rank> = (0..16).collect();
        let s = uniform("tp4pp4", &ranks, 1, 4, 4, 60, 64, 1, 4096, ScheduleKind::OneFOneB, true, false)
            .unwrap();
        let rep = simulate_step(&cluster, &cm32(), &s).unwrap();
        assert!(rep.step_s > 0.0);
        assert_eq!(rep.per_rank.len(), 16);
        // conservation: compute+comm+bubble == step for every rank
        for (_, b) in &rep.per_rank {
            assert!((b.total_s() - rep.step_s).abs() < 1e-9);
        }
    }

    #[test]
    fn one_f_one_b_beats_gpipe_bubble() {
        let cluster = Cluster::h20(16);
        let ranks: Vec<Rank> = (0..16).collect();
        let mk = |k| {
            uniform("x", &ranks, 1, 4, 4, 60, 64, 1, 4096, k, true, false).unwrap()
        };
        let t_1f1b = simulate_step(&cluster, &cm32(), &mk(ScheduleKind::OneFOneB)).unwrap().step_s;
        let t_gpipe = simulate_step(&cluster, &cm32(), &mk(ScheduleKind::GPipe)).unwrap().step_s;
        // both schedules have the same total work and near-identical
        // makespan (1F1B's win is activation memory, not speed)
        assert!(t_1f1b <= t_gpipe * 1.01, "1F1B {t_1f1b} vs GPipe {t_gpipe}");
    }

    #[test]
    fn more_microbatches_amortize_bubble() {
        let cluster = Cluster::h20(16);
        let ranks: Vec<Rank> = (0..16).collect();
        let few = uniform("few", &ranks, 1, 4, 4, 60, 4, 1, 4096, ScheduleKind::OneFOneB, true, false).unwrap();
        let many = uniform("many", &ranks, 1, 4, 4, 60, 64, 1, 4096, ScheduleKind::OneFOneB, true, false).unwrap();
        let t_few = simulate_step(&cluster, &cm32(), &few).unwrap();
        let t_many = simulate_step(&cluster, &cm32(), &many).unwrap();
        // per-sample time is better with more microbatches
        assert!(t_many.step_s / 64.0 < t_few.step_s / 4.0);
    }

    #[test]
    fn hetero_strategy_beats_uniform_on_hetero_cluster() {
        // The headline claim (Fig 13): on 16 H800 + 16 H20, Hetu's
        // heterogeneous layout beats the best uniform Megatron layout.
        let cluster = Cluster::h800_16_h20_16();
        let cm = cm32();
        let hetu = tables::hetu_32b_16h800_16h20();
        let t_hetu = simulate_step(&cluster, &cm, &hetu).unwrap().step_s;
        // Megatron optimum from Table 4: DP2 TP4 PP4, bs2
        let ranks: Vec<Rank> = (0..32).collect();
        let mega = uniform("megatron", &ranks, 2, 4, 4, 60, 64, 2, 4096, ScheduleKind::OneFOneB, true, false)
            .unwrap();
        let t_mega = simulate_step(&cluster, &cm, &mega).unwrap().step_s;
        assert!(
            t_hetu < t_mega,
            "hetu {t_hetu:.2}s should beat uniform megatron {t_mega:.2}s on hetero cluster"
        );
    }

    #[test]
    fn h800_heavy_stages_are_balanced() {
        // In the hetero strategy, H800 stages hold ~3x layers; per-stage
        // forward times should be within 2x of each other (balance).
        let cluster = Cluster::h800_16_h20_16();
        let cm = cm32();
        let s = tables::hetu_32b_16h800_16h20();
        let timings = super::stage_timings(&cluster, &cm, &s, 0, SimOptions::default());
        let fwd: Vec<f64> = timings.iter().map(|t| t.fwd_compute).collect();
        let max = fwd.iter().copied().fold(0.0, f64::max);
        let min = fwd.iter().copied().fold(f64::INFINITY, f64::min);
        assert!(max / min < 2.5, "stage fwd times {fwd:?}");
    }

    #[test]
    fn grad_sync_zero_for_single_pipeline() {
        let cluster = Cluster::h800_16_h20_16();
        let s = tables::hetu_70b_16h800_16h20();
        let cm = CostModel::new(ModelCfg::llama_70b());
        let rep = simulate_step(&cluster, &cm, &s).unwrap();
        assert_eq!(rep.grad_sync_s, 0.0);
    }

    #[test]
    fn c2_step_close_to_c1() {
        // Fig 14: losing 1 of 32 GPUs should degrade throughput by far less
        // than the 25% a whole-node discard costs.
        let cluster = Cluster::h20(32);
        let cm = cm32();
        let t1 = simulate_step(&cluster, &cm, &tables::hetu_c1_32h20()).unwrap().step_s;
        let t2 = simulate_step(&cluster, &cm, &tables::hetu_c2_31h20()).unwrap().step_s;
        let t3 = simulate_step(&cluster, &cm, &tables::hetu_c3_24h20()).unwrap().step_s;
        assert!(t2 > t1, "C2 slower than C1");
        assert!(t2 < t3, "C2 (31 GPUs) must beat C3 (24 GPUs): t2={t2:.2} t3={t3:.2}");
    }
}
