//! The per-rank span recorder.
//!
//! One [`Span`] is recorded per `(task, participating rank)` pair: the
//! event-driven executor records its replayed clock, the threaded executor
//! records real wall timestamps from the step's shared epoch, and the
//! compiled replayer records against span identities frozen into the tape
//! at compile time ([`SpanKind`] lives in
//! [`CompiledProgram::spans`](crate::engine::compile::CompiledProgram)).
//!
//! The recorder is engineered for the compiled hot loop's zero-alloc
//! contract (guarded by `tests/compiled_alloc.rs`):
//!
//! - **tracing off**: [`SpanRecorder::record`] is a single branch, no
//!   writes;
//! - **tracing on, warm step**: the buffer was sized by the first
//!   [`SpanRecorder::begin_step`] and is only rewound afterwards — entries
//!   land in preallocated slots, never growing the ring;
//! - **overflow** (more spans than the step-start capacity estimate, which
//!   executors compute exactly, so only reachable through a stale
//!   estimate): old entries are overwritten ring-style rather than
//!   reallocating — a truncated trace over a stalled step.

use crate::engine::SpecTaskKind;

/// The span taxonomy: [`SpecTaskKind`] with the coordinates stripped, so
/// an entry is `Copy` and one byte. Coordinates are recovered from the
/// owning plan via [`Span::task`] when exporting.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum SpanKind {
    /// Stage input hand-off + TP broadcast (stage 0: embed).
    FwdIn,
    /// One forward layer's GEMMs.
    FwdGemm,
    /// Forward TP partial-sum all-reduce.
    FwdTpSync,
    /// Backward stage input hand-off (last stage: fused head).
    BwdIn,
    /// One backward layer's GEMMs + grad accumulation.
    BwdGemm,
    /// One forward layer lowered to a fused workspace kernel
    /// ([`FusedKind::FwdBlock`](crate::engine::compile::FusedKind)).
    FwdGemmFused,
    /// One backward layer lowered to a fused workspace kernel.
    BwdGemmFused,
    /// Backward TP dx all-reduce.
    BwdTpSync,
    /// Stage-0 embedding-gradient epilogue.
    EmbedBwd,
    /// Token-weighted DP gradient reduction.
    GradReduce,
    /// Optimizer application.
    OptimStep,
    /// ZeRO-1 updated-slice exchange.
    ZeroExchange,
}

impl SpanKind {
    /// The span identity of a specialized task.
    pub fn of_task(kind: &SpecTaskKind) -> SpanKind {
        match kind {
            SpecTaskKind::FwdIn { .. } => SpanKind::FwdIn,
            SpecTaskKind::FwdGemm { .. } => SpanKind::FwdGemm,
            SpecTaskKind::FwdTpSync { .. } => SpanKind::FwdTpSync,
            SpecTaskKind::BwdIn { .. } => SpanKind::BwdIn,
            SpecTaskKind::BwdGemm { .. } => SpanKind::BwdGemm,
            SpecTaskKind::BwdTpSync { .. } => SpanKind::BwdTpSync,
            SpecTaskKind::EmbedBwd { .. } => SpanKind::EmbedBwd,
            SpecTaskKind::GradReduce => SpanKind::GradReduce,
            SpecTaskKind::OptimStep => SpanKind::OptimStep,
            SpecTaskKind::ZeroExchange => SpanKind::ZeroExchange,
        }
    }

    /// Kind name (the Chrome-trace event-name prefix).
    pub fn name(self) -> &'static str {
        match self {
            SpanKind::FwdIn => "FwdIn",
            SpanKind::FwdGemm => "FwdGemm",
            SpanKind::FwdTpSync => "FwdTpSync",
            SpanKind::BwdIn => "BwdIn",
            SpanKind::BwdGemm => "BwdGemm",
            SpanKind::FwdGemmFused => "FwdGemmFused",
            SpanKind::BwdGemmFused => "BwdGemmFused",
            SpanKind::BwdTpSync => "BwdTpSync",
            SpanKind::EmbedBwd => "EmbedBwd",
            SpanKind::GradReduce => "GradReduce",
            SpanKind::OptimStep => "OptimStep",
            SpanKind::ZeroExchange => "ZeroExchange",
        }
    }

    /// GEMM-class work (the breakdown's "compute" bucket).
    pub fn is_compute(self) -> bool {
        matches!(
            self,
            SpanKind::FwdGemm
                | SpanKind::BwdGemm
                | SpanKind::FwdGemmFused
                | SpanKind::BwdGemmFused
                | SpanKind::EmbedBwd
        )
    }

    /// Optimizer-class work (optimizer apply + ZeRO-1 exchange).
    pub fn is_optim(self) -> bool {
        matches!(self, SpanKind::OptimStep | SpanKind::ZeroExchange)
    }

    /// Communication-class work — mirrors [`SpecTaskKind::is_comm`]
    /// except that the optimizer kinds are split into their own bucket
    /// (§7's breakdown separates them).
    pub fn is_comm(self) -> bool {
        !self.is_compute() && !self.is_optim()
    }

    /// Chrome-trace category string.
    pub fn category(self) -> &'static str {
        if self.is_compute() {
            "compute"
        } else if self.is_optim() {
            "optim"
        } else {
            "comm"
        }
    }
}

/// One recorded execution interval on one rank's timeline. Fixed-size and
/// `Copy` so ring writes are plain stores.
#[derive(Clone, Copy, Debug)]
pub struct Span {
    /// Task index into the owning `SpecializedPlan::tasks` (== the
    /// `CompiledProgram::ops` index on the compiled path).
    pub task: u32,
    /// What ran.
    pub kind: SpanKind,
    /// Mesh rank whose timeline carries the interval.
    pub rank: u32,
    /// Start, seconds from the step epoch (wall under
    /// `ExecMode::{Threaded,CompiledThreaded}`, replayed clock otherwise).
    pub t0_s: f64,
    /// End, same epoch.
    pub t1_s: f64,
}

impl Span {
    /// Interval length in seconds.
    pub fn dur_s(&self) -> f64 {
        (self.t1_s - self.t0_s).max(0.0)
    }
}

/// Preallocated per-step span ring. The engine owns one across steps; the
/// buffer is sized on the first traced step per plan shape and only
/// rewound on later steps.
#[derive(Debug, Default)]
pub struct SpanRecorder {
    active: bool,
    cap: usize,
    start: usize,
    buf: Vec<Span>,
}

impl SpanRecorder {
    /// Arm (or disarm) the recorder for one step. `capacity` is the exact
    /// span count the executor will emit — Σ over tasks of
    /// `task.ranks.len()` (frozen as `CompiledProgram::trace_slots` on
    /// the compiled path). Allocates only when the capacity grows — the
    /// warm traced step performs no heap allocation here.
    pub fn begin_step(&mut self, capacity: usize, on: bool) {
        self.active = on;
        self.start = 0;
        self.buf.clear();
        if on {
            self.cap = capacity.max(1);
            let have = self.buf.capacity();
            if have < self.cap {
                self.buf.reserve_exact(self.cap - have);
            }
        }
    }

    /// True when the current/last step recorded spans.
    pub fn is_active(&self) -> bool {
        self.active
    }

    /// Record one interval. A branch-only no-op when tracing is off; a
    /// plain store into the preallocated ring when on.
    #[inline]
    pub fn record(&mut self, task: u32, kind: SpanKind, rank: u32, t0_s: f64, t1_s: f64) {
        if !self.active {
            return;
        }
        self.record_span(Span { task, kind, rank, t0_s, t1_s });
    }

    /// Record a prebuilt span (the threaded executor's merge path).
    #[inline]
    pub fn record_span(&mut self, span: Span) {
        if !self.active {
            return;
        }
        if self.buf.len() < self.cap {
            self.buf.push(span);
        } else {
            // ring overwrite — never grows, trace truncates oldest-first
            self.buf[self.start] = span;
            self.start = (self.start + 1) % self.cap;
        }
    }

    /// Spans recorded this step.
    pub fn len(&self) -> usize {
        self.buf.len()
    }

    /// No spans recorded.
    pub fn is_empty(&self) -> bool {
        self.buf.is_empty()
    }

    /// The step's spans in record order. Unwraps the ring in place when
    /// it overflowed (no allocation).
    pub fn contiguous(&mut self) -> &[Span] {
        if self.start != 0 {
            self.buf.rotate_left(self.start);
            self.start = 0;
        }
        &self.buf
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sp(task: u32) -> Span {
        Span { task, kind: SpanKind::FwdGemm, rank: 0, t0_s: 0.0, t1_s: 1.0 }
    }

    #[test]
    fn off_recorder_records_nothing() {
        let mut r = SpanRecorder::default();
        r.begin_step(8, false);
        r.record(0, SpanKind::FwdIn, 0, 0.0, 1.0);
        assert!(r.is_empty());
        assert!(!r.is_active());
    }

    #[test]
    fn ring_overwrites_oldest_and_unwraps_in_order() {
        let mut r = SpanRecorder::default();
        r.begin_step(3, true);
        for t in 0..5 {
            r.record_span(sp(t));
        }
        // capacity 3, wrote 0..5 -> survivors 2,3,4 in record order
        let tasks: Vec<u32> = r.contiguous().iter().map(|s| s.task).collect();
        assert_eq!(tasks, vec![2, 3, 4]);
        assert_eq!(r.len(), 3);
    }

    #[test]
    fn warm_begin_step_reuses_the_buffer() {
        let mut r = SpanRecorder::default();
        r.begin_step(16, true);
        for t in 0..16 {
            r.record_span(sp(t));
        }
        r.begin_step(16, true);
        assert!(r.is_empty(), "begin_step rewinds the ring");
        for t in 0..16 {
            r.record_span(sp(t));
        }
        assert_eq!(r.len(), 16);
        assert_eq!(r.contiguous()[0].task, 0);
    }

    #[test]
    fn span_kind_buckets_partition() {
        for k in [
            SpanKind::FwdIn,
            SpanKind::FwdGemm,
            SpanKind::FwdTpSync,
            SpanKind::BwdIn,
            SpanKind::BwdGemm,
            SpanKind::FwdGemmFused,
            SpanKind::BwdGemmFused,
            SpanKind::BwdTpSync,
            SpanKind::EmbedBwd,
            SpanKind::GradReduce,
            SpanKind::OptimStep,
            SpanKind::ZeroExchange,
        ] {
            let buckets =
                [k.is_compute(), k.is_comm(), k.is_optim()].iter().filter(|&&b| b).count();
            assert_eq!(buckets, 1, "{k:?} must land in exactly one bucket");
        }
    }
}
