//! Measured step breakdowns: fold a step's spans into per-rank and
//! per-step compute / comm / optimizer / bubble / switch-delivery
//! seconds (§7's attribution, measured instead of modeled).
//!
//! Per rank, the busy time is the sum of its span durations (spans on one
//! rank's track never overlap — the event-driven clock propagation and
//! the threaded executor's sequential per-thread timeline both guarantee
//! it) and the bubble is the non-busy remainder of the makespan. The
//! step-level breakdown is the mean over ranks, so by construction
//! `compute + comm + optim + bubble ≈ makespan` — the cross-check
//! `tests/trace_breakdown.rs` asserts within 5%.

use std::collections::BTreeMap;

use super::trace::Span;

/// One step's measured attribution (attached to
/// [`StepStats`](crate::engine::StepStats) when tracing is on).
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct StepBreakdown {
    /// Mean per-rank GEMM-class seconds.
    pub compute_s: f64,
    /// Mean per-rank communication seconds (hand-offs, TP syncs, grad
    /// reduce) — time a rank spends *in* comm tasks, i.e. exposed comm.
    pub comm_s: f64,
    /// Mean per-rank optimizer seconds (apply + ZeRO-1 exchange).
    pub optim_s: f64,
    /// Mean per-rank idle remainder of the makespan (pipeline bubbles,
    /// dependency waits).
    pub bubble_s: f64,
    /// Exposed switch-delivery seconds riding this step's wire lanes
    /// (from the §6.2 measured interleave; not folded from spans).
    pub switch_s: f64,
    /// Span-reconstructed critical path: the latest span end on the step
    /// epoch. Cross-checked against `StepStats::makespan_s`.
    pub critical_path_s: f64,
}

impl StepBreakdown {
    /// `compute + comm + optim + bubble` — must match the makespan within
    /// tolerance (the acceptance cross-check).
    pub fn components_sum_s(&self) -> f64 {
        self.compute_s + self.comm_s + self.optim_s + self.bubble_s
    }
}

/// One rank's measured attribution.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct RankBreakdown {
    /// Mesh rank.
    pub rank: u32,
    /// GEMM-class seconds.
    pub compute_s: f64,
    /// Communication seconds.
    pub comm_s: f64,
    /// Optimizer seconds.
    pub optim_s: f64,
    /// Total span-covered seconds.
    pub busy_s: f64,
    /// `max(0, makespan − busy)`.
    pub bubble_s: f64,
}

/// Per-rank attribution, ascending by rank.
pub fn per_rank(spans: &[Span], makespan_s: f64) -> Vec<RankBreakdown> {
    let mut by: BTreeMap<u32, RankBreakdown> = BTreeMap::new();
    for s in spans {
        let e = by
            .entry(s.rank)
            .or_insert_with(|| RankBreakdown { rank: s.rank, ..Default::default() });
        let d = s.dur_s();
        if s.kind.is_compute() {
            e.compute_s += d;
        } else if s.kind.is_optim() {
            e.optim_s += d;
        } else {
            e.comm_s += d;
        }
        e.busy_s += d;
    }
    by.into_values()
        .map(|mut e| {
            e.bubble_s = (makespan_s - e.busy_s).max(0.0);
            e
        })
        .collect()
}

/// Fold one step's spans into the step-level breakdown. `switch_s` is
/// the step's measured exposed switch delivery
/// ([`StepStats::exposed_switch_s`](crate::engine::StepStats)), carried
/// through for reporting — it is *not* added to the makespan components.
pub fn fold_spans(spans: &[Span], makespan_s: f64, switch_s: f64) -> StepBreakdown {
    let ranks = per_rank(spans, makespan_s);
    let critical_path_s = spans.iter().map(|s| s.t1_s).fold(0.0f64, f64::max);
    if ranks.is_empty() {
        return StepBreakdown { switch_s, critical_path_s, ..Default::default() };
    }
    let n = ranks.len() as f64;
    let mut b = StepBreakdown { switch_s, critical_path_s, ..Default::default() };
    for r in &ranks {
        b.compute_s += r.compute_s;
        b.comm_s += r.comm_s;
        b.optim_s += r.optim_s;
        b.bubble_s += r.bubble_s;
    }
    b.compute_s /= n;
    b.comm_s /= n;
    b.optim_s /= n;
    b.bubble_s /= n;
    b
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::trace::SpanKind;

    fn sp(kind: SpanKind, rank: u32, t0: f64, t1: f64) -> Span {
        Span { task: 0, kind, rank, t0_s: t0, t1_s: t1 }
    }

    #[test]
    fn fold_two_ranks_components_sum_to_makespan() {
        // rank 0: 2s compute + 1s comm, rank 1: 1s compute + 1s optim
        let spans = vec![
            sp(SpanKind::FwdGemm, 0, 0.0, 2.0),
            sp(SpanKind::GradReduce, 0, 2.0, 3.0),
            sp(SpanKind::BwdGemm, 1, 0.0, 1.0),
            sp(SpanKind::OptimStep, 1, 1.0, 2.0),
        ];
        let b = fold_spans(&spans, 3.0, 0.25);
        assert!((b.compute_s - 1.5).abs() < 1e-12);
        assert!((b.comm_s - 0.5).abs() < 1e-12);
        assert!((b.optim_s - 0.5).abs() < 1e-12);
        assert!((b.bubble_s - 0.5).abs() < 1e-12); // rank1 idles 1s of 3s
        assert!((b.components_sum_s() - 3.0).abs() < 1e-12);
        assert!((b.critical_path_s - 3.0).abs() < 1e-12);
        assert!((b.switch_s - 0.25).abs() < 1e-12);
    }

    #[test]
    fn empty_spans_fold_to_zeroes() {
        let b = fold_spans(&[], 1.0, 0.0);
        assert_eq!(b.components_sum_s(), 0.0);
        assert_eq!(b.critical_path_s, 0.0);
    }

    #[test]
    fn per_rank_is_sorted_and_bubble_clamped() {
        let spans =
            vec![sp(SpanKind::FwdGemm, 5, 0.0, 4.0), sp(SpanKind::FwdGemm, 2, 0.0, 1.0)];
        let ranks = per_rank(&spans, 2.0);
        assert_eq!(ranks.iter().map(|r| r.rank).collect::<Vec<_>>(), vec![2, 5]);
        assert_eq!(ranks[1].bubble_s, 0.0, "busy beyond makespan clamps to zero bubble");
    }
}
