//! §10 Observability: per-rank execution tracing and everything built on it.
//!
//! The substrate from PRs 5–7 gives every unit of work an identity — a
//! [`SpecTask`](crate::engine::SpecTask) index, the mesh ranks that carry
//! it, and explicit dependency edges — so a timeline is one recorder away.
//! This module is that recorder plus its consumers:
//!
//! - [`trace`]: the low-overhead [`SpanRecorder`](trace::SpanRecorder) all
//!   three executors emit into — a preallocated ring of fixed-size
//!   [`Span`](trace::Span) entries, zero heap allocation on the warm step
//!   when tracing is on and zero writes when off.
//! - [`chrome`]: Chrome trace-event JSON export (one track per rank, flow
//!   arrows on the p2p hand-off edges) for `chrome://tracing` / Perfetto.
//! - [`breakdown`]: folds a step's spans into measured per-rank and
//!   per-step compute / comm / optimizer / bubble / switch-delivery
//!   seconds, cross-checked against `StepStats::makespan_s`.
//! - [`calibrate`]: fits a measured `(s/flop, s/byte)` profile from a
//!   traced step and feeds it back into the Hetu-B dispatcher's scoring
//!   in place of the analytic constants.
//!
//! DESIGN.md §10 documents the span schema, ring sizing, the Chrome-trace
//! mapping (pid=step, tid=rank), and the calibration loop.

pub mod breakdown;
pub mod calibrate;
pub mod chrome;
pub mod trace;

pub use breakdown::{fold_spans, per_rank, RankBreakdown, StepBreakdown};
pub use calibrate::CalibratedProfile;
pub use chrome::chrome_trace;
pub use trace::{Span, SpanKind, SpanRecorder};
