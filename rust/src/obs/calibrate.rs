//! Span-calibrated dispatch profiles.
//!
//! The Hetu-B dispatcher scores candidate strategies with the *analytic*
//! cost model (packed-window FLOPs over device count). HAP's lesson
//! (PAPERS.md) is that heterogeneous strategy decisions are only as good
//! as the measured profile behind them — so this module fits a
//! two-coefficient linear profile `(seconds/flop, seconds/byte)` from one
//! traced engine step's measured [`StepBreakdown`] and lets the
//! dispatcher score `flops·s_per_flop + bytes·s_per_byte` per device
//! instead of raw FLOPs. The byte term is what changes rankings: a
//! TP-heavy candidate that looks fine on FLOPs pays its measured sync
//! cost under the calibrated profile.
//!
//! The comm-volume model ([`strategy_comm_bytes`]) uses the *same*
//! packed-window convention as `Dispatcher::batch_flops`, so fit and
//! scoring stay consistent by construction.

use crate::costmodel::CostModel;
use crate::data::pack_sequences;
use crate::engine::EngineStrategy;

/// A measured linear step-time profile, fitted from one traced step.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct CalibratedProfile {
    /// Measured seconds per (per-device) compute FLOP.
    pub s_per_flop: f64,
    /// Measured seconds per (per-device) communicated byte.
    pub s_per_byte: f64,
}

impl CalibratedProfile {
    /// Fit from one step's measured per-device compute/comm seconds and
    /// the analytic per-device FLOP/byte volumes that step executed.
    /// `None` when the sample is degenerate (no compute measured) — the
    /// caller keeps the analytic model. A step with no measured comm
    /// fits `s_per_byte = 0`, which degrades to pure-FLOPs scoring.
    pub fn fit(compute_s: f64, comm_s: f64, flops: f64, bytes: f64) -> Option<CalibratedProfile> {
        if compute_s <= 0.0 || flops <= 0.0 || !compute_s.is_finite() || !flops.is_finite() {
            return None;
        }
        let s_per_byte = if bytes > 0.0 { (comm_s / bytes).max(0.0) } else { 0.0 };
        Some(CalibratedProfile { s_per_flop: compute_s / flops, s_per_byte })
    }

    /// Predicted step seconds for a candidate executing `flops` total
    /// compute and `bytes` total comm volume across `ndev` devices.
    pub fn step_s(&self, flops: f64, bytes: f64, ndev: f64) -> f64 {
        (flops * self.s_per_flop + bytes * self.s_per_byte) / ndev.max(1.0)
    }
}

/// Analytic communication volume (bytes) a strategy moves for one batch
/// packed at context `ctx` — the dispatcher-side mirror of the engine's
/// comm tasks, per the cost model's payload formulas:
///
/// - per packed window: activation + gradient hand-offs across every
///   pipeline boundary (`2·(stages−1)·pp_boundary_bytes`), and when the
///   strategy runs TP, forward+backward partial-sum syncs per layer
///   (`2·layers·tp_sync_bytes`);
/// - per step: the DP gradient reduction (`grad_bytes` per extra
///   pipeline replica).
///
/// Windows follow the same [`pack_sequences`] convention as
/// `Dispatcher::batch_flops`, so calibrated scores compare FLOPs and
/// bytes of the *same* packing.
pub fn strategy_comm_bytes(
    cm: &CostModel,
    strategy: &EngineStrategy,
    ctx: u64,
    seq_lens: &[u64],
) -> f64 {
    let stages = strategy.pipelines.iter().map(|p| p.stages.len()).max().unwrap_or(1);
    let tp_max = strategy
        .pipelines
        .iter()
        .flat_map(|p| p.stages.iter())
        .map(|s| s.devices.len())
        .max()
        .unwrap_or(1);
    let layers: u32 = strategy
        .pipelines
        .first()
        .map(|p| p.stages.iter().map(|s| s.layers.1 - s.layers.0).sum())
        .unwrap_or(0);
    let mut bytes = 0.0f64;
    for w in pack_sequences(seq_lens, ctx) {
        let used: u64 = w.iter().sum();
        bytes += 2.0 * (stages.saturating_sub(1)) as f64 * cm.pp_boundary_bytes(used) as f64;
        if tp_max > 1 {
            bytes += 2.0 * layers as f64 * cm.tp_sync_bytes(used) as f64;
        }
    }
    let replicas = strategy.pipelines.len();
    if replicas > 1 {
        bytes += (replicas - 1) as f64 * cm.grad_bytes(layers, tp_max as u32) as f64;
    }
    bytes
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::costmodel::ModelCfg;
    use crate::runtime::native;

    #[test]
    fn fit_roundtrips_the_sample() {
        let p = CalibratedProfile::fit(2.0, 1.0, 1e12, 1e9).unwrap();
        assert!((p.s_per_flop - 2e-12).abs() < 1e-24);
        assert!((p.s_per_byte - 1e-9).abs() < 1e-18);
        // the fitted profile reproduces the sample's total on one device
        assert!((p.step_s(1e12, 1e9, 1.0) - 3.0).abs() < 1e-9);
    }

    #[test]
    fn degenerate_samples_refuse_to_fit() {
        assert!(CalibratedProfile::fit(0.0, 1.0, 1e12, 1e9).is_none());
        assert!(CalibratedProfile::fit(1.0, 1.0, 0.0, 1e9).is_none());
        let p = CalibratedProfile::fit(1.0, 0.5, 1e12, 0.0).unwrap();
        assert_eq!(p.s_per_byte, 0.0, "no measured bytes -> pure-FLOPs profile");
    }

    #[test]
    fn comm_bytes_orders_tp_above_dp() {
        let tiny = native::tiny_config();
        let cm = CostModel::new(ModelCfg::llama_32b());
        let dp2 = EngineStrategy::uniform("dp2", 2, 1, 1, tiny.layers, 1);
        let tp2 = EngineStrategy::uniform("tp2", 1, 2, 1, tiny.layers, 2);
        let lens = vec![2048u64; 8];
        let b_dp = strategy_comm_bytes(&cm, &dp2, 4096, &lens);
        let b_tp = strategy_comm_bytes(&cm, &tp2, 32768, &lens);
        assert!(b_tp > b_dp, "per-layer TP syncs must dominate one DP grad reduce");
    }
}
