//! Chrome trace-event JSON export.
//!
//! Maps one traced step onto the `chrome://tracing` / Perfetto JSON
//! object format: **pid = step**, **tid = mesh rank** (one track per
//! rank), every span a complete `"X"` event (µs timestamps), and a flow
//! arrow (`"s"`/`"f"` pair) along every p2p activation/gradient hand-off
//! edge of the plan — the visual counterpart of
//! [`SpecializedPlan::handoff_edges`].
//!
//! Hand-rolled JSON like `metrics/benchjson.rs` — no serde in the tree.

use std::collections::BTreeMap;
use std::collections::BTreeSet;
use std::fmt::Write as _;

use super::trace::Span;
use crate::engine::{SpecTaskKind, SpecializedPlan};
use crate::Result;

/// Human label for a task: kind plus its `(pipe, stage, mb[, layer])`
/// coordinates, e.g. `FwdGemm p0.s1.mb2.L3`.
fn task_label(kind: &SpecTaskKind) -> String {
    match *kind {
        SpecTaskKind::FwdIn { pipe, stage, mb } => format!("FwdIn p{pipe}.s{stage}.mb{mb}"),
        SpecTaskKind::FwdGemm { pipe, stage, mb, layer } => {
            format!("FwdGemm p{pipe}.s{stage}.mb{mb}.L{layer}")
        }
        SpecTaskKind::FwdTpSync { pipe, stage, mb, layer } => {
            format!("FwdTpSync p{pipe}.s{stage}.mb{mb}.L{layer}")
        }
        SpecTaskKind::BwdIn { pipe, stage, mb } => format!("BwdIn p{pipe}.s{stage}.mb{mb}"),
        SpecTaskKind::BwdGemm { pipe, stage, mb, layer } => {
            format!("BwdGemm p{pipe}.s{stage}.mb{mb}.L{layer}")
        }
        SpecTaskKind::BwdTpSync { pipe, stage, mb, layer } => {
            format!("BwdTpSync p{pipe}.s{stage}.mb{mb}.L{layer}")
        }
        SpecTaskKind::EmbedBwd { pipe, mb } => format!("EmbedBwd p{pipe}.mb{mb}"),
        SpecTaskKind::GradReduce => "GradReduce".to_string(),
        SpecTaskKind::OptimStep => "OptimStep".to_string(),
        SpecTaskKind::ZeroExchange => "ZeroExchange".to_string(),
    }
}

/// Render one traced step as a Chrome trace-event JSON document.
///
/// `spans` is the recorder's contiguous view for the step, `plan` the
/// specialized plan the spans index into (for labels and hand-off
/// edges), `step` the engine step counter the spans came from (becomes
/// the pid so multi-step captures concatenate cleanly). Hand-off edges
/// whose endpoints were truncated out of an overflowed ring are skipped,
/// not errors.
pub fn chrome_trace(spans: &[Span], plan: &SpecializedPlan, step: u64) -> Result<String> {
    let mut ev: Vec<String> = vec![];
    ev.push(format!(
        "{{\"name\": \"process_name\", \"ph\": \"M\", \"pid\": {step}, \
         \"args\": {{\"name\": \"step {step}\"}}}}"
    ));
    let ranks: BTreeSet<u32> = spans.iter().map(|s| s.rank).collect();
    for r in &ranks {
        ev.push(format!(
            "{{\"name\": \"thread_name\", \"ph\": \"M\", \"pid\": {step}, \"tid\": {r}, \
             \"args\": {{\"name\": \"rank {r}\"}}}}"
        ));
    }

    // complete events, one per span; (task, rank) -> span for the flows
    let mut at: BTreeMap<(u32, u32), &Span> = BTreeMap::new();
    for s in spans {
        at.insert((s.task, s.rank), s);
        let kind = plan
            .tasks
            .get(s.task as usize)
            .map(|t| task_label(&t.kind))
            .unwrap_or_else(|| s.kind.name().to_string());
        ev.push(format!(
            "{{\"name\": \"{kind}\", \"cat\": \"{}\", \"ph\": \"X\", \"ts\": {:.3}, \
             \"dur\": {:.3}, \"pid\": {step}, \"tid\": {}}}",
            s.kind.category(),
            s.t0_s * 1e6,
            s.dur_s() * 1e6,
            s.rank
        ));
    }

    // flow arrows along the p2p hand-off edges: start at the producer
    // tail's end on the sender's track, finish at the consuming boundary
    // task on the receiver's track (bp:"e" binds to the enclosing slice)
    for (id, e) in plan.handoff_edges()?.iter().enumerate() {
        let sender = e.producers[0] as u32;
        let (Some(prod), Some(cons)) = (
            at.get(&(e.producer_tail as u32, sender)),
            at.get(&(e.task as u32, e.consumer_root as u32)),
        ) else {
            continue;
        };
        // On the wall-clock executors the producer span closes after all
        // its post-actions, so its end can trail the consumer slice; the
        // start stays inside the producer span (the send is causally
        // between prod.t0 and cons.t1) and the finish inside the consumer
        // slice, never before the start.
        let s_ts = (prod.t1_s * 1e6).min(cons.t1_s * 1e6);
        let f_ts = (cons.t0_s * 1e6).max(s_ts).min(cons.t1_s * 1e6);
        ev.push(format!(
            "{{\"name\": \"handoff\", \"cat\": \"handoff\", \"ph\": \"s\", \"id\": {id}, \
             \"ts\": {s_ts:.3}, \"pid\": {step}, \"tid\": {sender}}}"
        ));
        ev.push(format!(
            "{{\"name\": \"handoff\", \"cat\": \"handoff\", \"ph\": \"f\", \"bp\": \"e\", \
             \"id\": {id}, \"ts\": {f_ts:.3}, \"pid\": {step}, \"tid\": {}}}",
            e.consumer_root
        ));
    }

    let mut out = String::new();
    out.push_str("{\"displayTimeUnit\": \"ms\",\n \"traceEvents\": [\n  ");
    let _ = write!(out, "{}", ev.join(",\n  "));
    out.push_str("\n]}\n");
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::{specialize, EngineStrategy, ShardLayout};
    use crate::obs::trace::SpanKind;
    use crate::runtime::native;

    #[test]
    fn export_is_balanced_and_tracks_ranks() {
        let tiny = native::tiny_config();
        let strat = EngineStrategy::uniform("pp2", 1, 1, 2, tiny.layers, 2);
        let layout = ShardLayout::build(&tiny, &strat).unwrap();
        let plan = specialize(&strat, &layout, false).unwrap();
        // synthesize a minimal consistent trace: every task on every rank
        let mut spans = vec![];
        let mut t = 0.0f64;
        for (ti, task) in plan.tasks.iter().enumerate() {
            for &r in &task.ranks {
                spans.push(Span {
                    task: ti as u32,
                    kind: SpanKind::of_task(&task.kind),
                    rank: r as u32,
                    t0_s: t,
                    t1_s: t + 1e-4,
                });
            }
            t += 1e-4;
        }
        let json = chrome_trace(&spans, &plan, 3).unwrap();
        let open = json.matches('{').count();
        let close = json.matches('}').count();
        assert_eq!(open, close, "balanced braces");
        assert!(json.contains("\"pid\": 3"));
        assert!(json.contains("\"name\": \"rank 0\""));
        assert!(json.contains("\"name\": \"rank 1\""));
        assert!(json.contains("\"ph\": \"s\""), "pp2 must produce hand-off flow arrows");
        assert!(json.contains("\"ph\": \"f\""));
    }
}
