//! Real in-memory collectives over simulated devices.
//!
//! The engine executes the distributed program on a [`Mesh`] of simulated
//! devices — each with its own tensor store — and the communication
//! operators here perform *actual data movement* between those stores, so
//! distributed numerics (TP partial sums, PP boundary transfers, DP
//! gradient synchronization, BSR weight repartitioning) are exact and
//! checked against single-device oracles. Wire volume is accounted per
//! transfer for reporting.
//!
//! The PJRT client is `Rc`-based (not `Send`), so devices are interpreted
//! deterministically on one thread; the *coordination structure* (which
//! device computes which shard, which groups reduce) is identical to the
//! multi-process deployment (DESIGN.md §2).

use std::collections::HashMap;

use crate::hspmd::slices::{Interval, Region};
use crate::runtime::HostTensor;
use crate::{Error, Result};

/// Row-major strides of a shape (last dim stride = 1).
fn strides(shape: &[usize]) -> Vec<usize> {
    let mut st = vec![1usize; shape.len()];
    for d in (0..shape.len().saturating_sub(1)).rev() {
        st[d] = st[d + 1] * shape[d + 1];
    }
    st
}

fn check_region(t: &HostTensor, r: &Region) -> Result<()> {
    if r.is_empty() {
        return Err(Error::Engine("empty region".into()));
    }
    if r.len() != t.shape.len() {
        return Err(Error::Engine(format!(
            "region rank {} vs tensor rank {}",
            r.len(),
            t.shape.len()
        )));
    }
    for (d, iv) in r.iter().enumerate() {
        if iv.is_empty() || iv.hi as usize > t.shape[d] {
            return Err(Error::Engine(format!(
                "region {:?} out of bounds for dim {d} of {:?}",
                iv, t.shape
            )));
        }
    }
    Ok(())
}

/// Extract an axis-aligned sub-box of a tensor (region in the tensor's own
/// local coordinates). Works for any rank; the engine uses rank 1 and 2.
pub fn extract_region(t: &HostTensor, r: &Region) -> Result<HostTensor> {
    check_region(t, r)?;
    let src = t.as_f32()?;
    let st = strides(&t.shape);
    let out_shape: Vec<usize> = r.iter().map(|iv| iv.len() as usize).collect();
    let out_len: usize = out_shape.iter().product();
    let last = r.len() - 1;
    let run_len = r[last].len() as usize;
    let runs = out_len / run_len.max(1);
    let mut out = Vec::with_capacity(out_len);
    for run in 0..runs {
        let mut rem = run;
        let mut off = r[last].lo as usize; // stride of last dim is 1
        for d in (0..last).rev() {
            let ext = r[d].len() as usize;
            let c = rem % ext;
            rem /= ext;
            off += (r[d].lo as usize + c) * st[d];
        }
        out.extend_from_slice(&src[off..off + run_len]);
    }
    HostTensor::f32(out_shape, out)
}

/// Write a sub-box back into a tensor (inverse of [`extract_region`]).
pub fn write_region(t: &mut HostTensor, r: &Region, piece: &HostTensor) -> Result<()> {
    check_region(t, r)?;
    let expect: Vec<usize> = r.iter().map(|iv| iv.len() as usize).collect();
    if piece.shape != expect {
        return Err(Error::Engine(format!(
            "write_region: piece shape {:?} vs region extents {:?}",
            piece.shape, expect
        )));
    }
    let st = strides(&t.shape);
    let last = r.len() - 1;
    let run_len = r[last].len() as usize;
    let runs: usize = expect.iter().product::<usize>() / run_len.max(1);
    let src = piece.as_f32()?;
    let dst = t.as_f32_mut()?;
    for run in 0..runs {
        let mut rem = run;
        let mut off = r[last].lo as usize;
        for d in (0..last).rev() {
            let ext = r[d].len() as usize;
            let c = rem % ext;
            rem /= ext;
            off += (r[d].lo as usize + c) * st[d];
        }
        dst[off..off + run_len].copy_from_slice(&src[run * run_len..(run + 1) * run_len]);
    }
    Ok(())
}

/// Shift a global-coordinate region into the local coordinates of a holder
/// whose own (global) region is `base`.
pub fn localize(slice: &Region, base: &Region) -> Region {
    slice
        .iter()
        .zip(base.iter())
        .map(|(s, b)| Interval { lo: s.lo - b.lo, hi: s.hi - b.lo })
        .collect()
}

/// One simulated device's tensor store.
#[derive(Default, Debug)]
pub struct DeviceMem {
    tensors: HashMap<String, HostTensor>,
}

impl DeviceMem {
    /// Insert/replace a tensor.
    pub fn put(&mut self, key: &str, t: HostTensor) {
        self.tensors.insert(key.to_string(), t);
    }

    /// Borrow a tensor.
    pub fn get(&self, key: &str) -> Result<&HostTensor> {
        self.tensors
            .get(key)
            .ok_or_else(|| Error::Engine(format!("device missing tensor `{key}`")))
    }

    /// Mutable borrow.
    pub fn get_mut(&mut self, key: &str) -> Result<&mut HostTensor> {
        self.tensors
            .get_mut(key)
            .ok_or_else(|| Error::Engine(format!("device missing tensor `{key}`")))
    }

    /// Remove a tensor.
    pub fn take(&mut self, key: &str) -> Result<HostTensor> {
        self.tensors
            .remove(key)
            .ok_or_else(|| Error::Engine(format!("device missing tensor `{key}`")))
    }

    /// Presence test.
    pub fn has(&self, key: &str) -> bool {
        self.tensors.contains_key(key)
    }

    /// Keys (sorted, for deterministic iteration).
    pub fn keys(&self) -> Vec<String> {
        let mut v: Vec<String> = self.tensors.keys().cloned().collect();
        v.sort();
        v
    }
}

/// A mesh of simulated devices.
#[derive(Default, Debug)]
pub struct Mesh {
    /// Device stores, indexed by simulated device id.
    pub devices: Vec<DeviceMem>,
    /// Total elements moved device-to-device (accounting).
    pub wire_elems: u64,
    /// Number of communication operations issued.
    pub ops: u64,
}

impl Mesh {
    /// A mesh of `n` devices.
    pub fn new(n: usize) -> Mesh {
        Mesh { devices: (0..n).map(|_| DeviceMem::default()).collect(), wire_elems: 0, ops: 0 }
    }

    /// Device count.
    pub fn len(&self) -> usize {
        self.devices.len()
    }

    /// True when empty.
    pub fn is_empty(&self) -> bool {
        self.devices.is_empty()
    }

    /// Deliver a freshly-built tensor from `from` to `to` under `key`
    /// (slice transfers during resharding switches): accounts wire volume
    /// and stores at the destination.
    pub fn push(&mut self, from: usize, to: usize, key: &str, t: HostTensor) {
        if from != to {
            self.wire_elems += t.len() as u64;
            self.ops += 1;
        }
        self.devices[to].put(key, t);
    }

    /// Point-to-point copy of `key` from one device to another (stores
    /// under the same key).
    pub fn send(&mut self, from: usize, to: usize, key: &str) -> Result<()> {
        if from == to {
            return Ok(());
        }
        let t = self.devices[from].get(key)?.clone();
        self.wire_elems += t.len() as u64;
        self.ops += 1;
        self.devices[to].put(key, t);
        Ok(())
    }

    /// AllReduce(sum) of `key` across `group`: afterwards every member
    /// holds the elementwise sum.
    pub fn all_reduce(&mut self, group: &[usize], key: &str) -> Result<()> {
        if group.len() <= 1 {
            return Ok(());
        }
        let mut acc = self.devices[group[0]].get(key)?.clone();
        for &d in &group[1..] {
            let t = self.devices[d].get(key)?.clone();
            acc.add_assign(&t)?;
            self.wire_elems += t.len() as u64;
        }
        for &d in group {
            self.wire_elems += acc.len() as u64;
            self.devices[d].put(key, acc.clone());
        }
        self.ops += 1;
        Ok(())
    }

    /// Broadcast `key` from `root` to the rest of `group`.
    pub fn broadcast(&mut self, root: usize, group: &[usize], key: &str) -> Result<()> {
        let t = self.devices[root].get(key)?.clone();
        for &d in group {
            if d != root {
                self.wire_elems += t.len() as u64;
                self.devices[d].put(key, t.clone());
            }
        }
        self.ops += 1;
        Ok(())
    }

    /// AllGather along dim 0: each member holds a `[k, ...]` shard under
    /// `key`; afterwards every member holds the concatenation (group
    /// order) under `out_key`.
    pub fn all_gather0(&mut self, group: &[usize], key: &str, out_key: &str) -> Result<()> {
        let first = self.devices[group[0]].get(key)?.clone();
        let mut shape = first.shape.clone();
        let row = shape[0];
        let mut data: Vec<f32> = Vec::with_capacity(first.len() * group.len());
        for &d in group {
            let t = self.devices[d].get(key)?;
            if t.shape != first.shape {
                return Err(Error::Engine("all_gather0: ragged shards".into()));
            }
            data.extend_from_slice(t.as_f32()?);
            self.wire_elems += (t.len() * (group.len() - 1)) as u64;
        }
        shape[0] = row * group.len();
        let full = HostTensor::f32(shape, data)?;
        for &d in group {
            self.devices[d].put(out_key, full.clone());
        }
        self.ops += 1;
        Ok(())
    }

    /// AllReduce(sum) of a *sub-region* of `key` across holders whose local
    /// coordinates for the shared slice differ (hetero-TP gradient sync):
    /// each `(device, local region)` pair contributes its sub-box; after
    /// the call every holder's sub-box contains the elementwise sum.
    /// Accounting mirrors [`Mesh::all_reduce`] (gather `(n-1)·elems`,
    /// scatter `n·elems`, one op).
    pub fn all_reduce_region(&mut self, parts: &[(usize, Region)], key: &str) -> Result<()> {
        if parts.len() <= 1 {
            return Ok(());
        }
        let (d0, r0) = &parts[0];
        let mut acc = extract_region(self.devices[*d0].get(key)?, r0)?;
        for (d, r) in &parts[1..] {
            let piece = extract_region(self.devices[*d].get(key)?, r)?;
            acc.add_assign(&piece)?;
            self.wire_elems += piece.len() as u64;
        }
        for (d, r) in parts {
            self.wire_elems += acc.len() as u64;
            write_region(self.devices[*d].get_mut(key)?, r, &acc)?;
        }
        self.ops += 1;
        Ok(())
    }

    /// ReduceScatter along dim 0: every member holds a full tensor under
    /// `key`; afterwards member `i` holds the `i`-th dim-0 slice of the
    /// elementwise sum under `out_key`.
    pub fn reduce_scatter0(&mut self, group: &[usize], key: &str, out_key: &str) -> Result<()> {
        let n = group.len();
        let mut acc = self.devices[group[0]].get(key)?.clone();
        for &d in &group[1..] {
            let t = self.devices[d].get(key)?.clone();
            acc.add_assign(&t)?;
            self.wire_elems += t.len() as u64;
        }
        let rows = acc.shape[0];
        if rows % n != 0 {
            return Err(Error::Engine(format!("reduce_scatter0: {rows} rows over {n} devices")));
        }
        let chunk_rows = rows / n;
        let row_elems: usize = acc.shape[1..].iter().product::<usize>().max(1);
        let data = acc.as_f32()?;
        for (i, &d) in group.iter().enumerate() {
            let lo = i * chunk_rows * row_elems;
            let hi = (i + 1) * chunk_rows * row_elems;
            let mut shape = acc.shape.clone();
            shape[0] = chunk_rows;
            let t = HostTensor::f32(shape, data[lo..hi].to_vec())?;
            self.devices[d].put(out_key, t);
        }
        self.ops += 1;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn t(v: Vec<f32>) -> HostTensor {
        let n = v.len();
        HostTensor::f32(vec![n], v).unwrap()
    }

    #[test]
    fn all_reduce_sums_everywhere() {
        let mut m = Mesh::new(3);
        for d in 0..3 {
            m.devices[d].put("x", t(vec![d as f32 + 1.0, 1.0]));
        }
        m.all_reduce(&[0, 1, 2], "x").unwrap();
        for d in 0..3 {
            assert_eq!(m.devices[d].get("x").unwrap().as_f32().unwrap(), &[6.0, 3.0]);
        }
        assert!(m.wire_elems > 0);
    }

    #[test]
    fn send_moves_and_accounts() {
        let mut m = Mesh::new(2);
        m.devices[0].put("a", t(vec![5.0; 8]));
        m.send(0, 1, "a").unwrap();
        assert_eq!(m.devices[1].get("a").unwrap().as_f32().unwrap(), &[5.0; 8]);
        assert_eq!(m.wire_elems, 8);
    }

    #[test]
    fn broadcast_replicates() {
        let mut m = Mesh::new(3);
        m.devices[1].put("w", t(vec![2.0; 4]));
        m.broadcast(1, &[0, 1, 2], "w").unwrap();
        for d in [0, 2] {
            assert_eq!(m.devices[d].get("w").unwrap().as_f32().unwrap(), &[2.0; 4]);
        }
    }

    #[test]
    fn all_gather0_concatenates_in_group_order() {
        let mut m = Mesh::new(2);
        m.devices[0].put("s", HostTensor::f32(vec![1, 2], vec![1.0, 2.0]).unwrap());
        m.devices[1].put("s", HostTensor::f32(vec![1, 2], vec![3.0, 4.0]).unwrap());
        m.all_gather0(&[0, 1], "s", "full").unwrap();
        let f = m.devices[0].get("full").unwrap();
        assert_eq!(f.shape, vec![2, 2]);
        assert_eq!(f.as_f32().unwrap(), &[1.0, 2.0, 3.0, 4.0]);
    }

    #[test]
    fn reduce_scatter0_partitions_the_sum() {
        let mut m = Mesh::new(2);
        m.devices[0].put("g", HostTensor::f32(vec![4], vec![1.0, 2.0, 3.0, 4.0]).unwrap());
        m.devices[1].put("g", HostTensor::f32(vec![4], vec![10.0, 20.0, 30.0, 40.0]).unwrap());
        m.reduce_scatter0(&[0, 1], "g", "gs").unwrap();
        assert_eq!(m.devices[0].get("gs").unwrap().as_f32().unwrap(), &[11.0, 22.0]);
        assert_eq!(m.devices[1].get("gs").unwrap().as_f32().unwrap(), &[33.0, 44.0]);
    }

    fn iv(lo: u64, hi: u64) -> Interval {
        Interval { lo, hi }
    }

    #[test]
    fn extract_and_write_region_roundtrip() {
        let t = HostTensor::f32(vec![4, 6], (0..24).map(|x| x as f32).collect()).unwrap();
        let r = vec![iv(1, 3), iv(2, 5)];
        let sub = extract_region(&t, &r).unwrap();
        assert_eq!(sub.shape, vec![2, 3]);
        assert_eq!(sub.as_f32().unwrap(), &[8.0, 9.0, 10.0, 14.0, 15.0, 16.0]);
        let mut dst = HostTensor::zeros(vec![4, 6]);
        write_region(&mut dst, &r, &sub).unwrap();
        assert_eq!(extract_region(&dst, &r).unwrap(), sub);
        // untouched corner stays zero
        assert_eq!(dst.as_f32().unwrap()[0], 0.0);
    }

    #[test]
    fn extract_region_rejects_out_of_bounds() {
        let t = HostTensor::zeros(vec![2, 2]);
        assert!(extract_region(&t, &vec![iv(0, 3), iv(0, 2)]).is_err());
        assert!(extract_region(&t, &vec![iv(0, 2)]).is_err());
    }

    #[test]
    fn localize_shifts_to_holder_coords() {
        let slice = vec![iv(4, 6), iv(0, 3)];
        let base = vec![iv(4, 8), iv(0, 3)];
        assert_eq!(localize(&slice, &base), vec![iv(0, 2), iv(0, 3)]);
    }

    #[test]
    fn all_reduce_region_sums_shared_slices() {
        // device 0 holds rows [0,4) of an 8-row tensor; device 1 holds all 8.
        // The shared slice is rows [0,4): after the reduce both views agree.
        let mut m = Mesh::new(2);
        m.devices[0].put("g", HostTensor::f32(vec![4, 2], vec![1.0; 8]).unwrap());
        m.devices[1].put("g", HostTensor::f32(vec![8, 2], vec![2.0; 16]).unwrap());
        let parts = vec![(0usize, vec![iv(0, 4), iv(0, 2)]), (1usize, vec![iv(0, 4), iv(0, 2)])];
        m.all_reduce_region(&parts, "g").unwrap();
        assert_eq!(m.devices[0].get("g").unwrap().as_f32().unwrap(), &[3.0; 8]);
        let d1 = m.devices[1].get("g").unwrap().as_f32().unwrap();
        assert_eq!(&d1[..8], &[3.0; 8]);
        assert_eq!(&d1[8..], &[2.0; 8]);
        assert!(m.wire_elems > 0 && m.ops == 1);
    }

    #[test]
    fn rs_then_ag_equals_ar() {
        let mut m = Mesh::new(2);
        for d in 0..2 {
            m.devices[d].put("g", HostTensor::f32(vec![4], vec![d as f32 + 1.0; 4]).unwrap());
        }
        m.reduce_scatter0(&[0, 1], "g", "gs").unwrap();
        m.all_gather0(&[0, 1], "gs", "gf").unwrap();
        assert_eq!(m.devices[0].get("gf").unwrap().as_f32().unwrap(), &[3.0; 4]);
        assert_eq!(m.devices[1].get("gf").unwrap().as_f32().unwrap(), &[3.0; 4]);
    }
}
