//! Real in-memory collectives over simulated devices.
//!
//! The engine executes the distributed program on a [`Mesh`] of simulated
//! devices — each with its own tensor store — and the communication
//! operators here perform *actual data movement* between those stores, so
//! distributed numerics (TP partial sums, PP boundary transfers, DP
//! gradient synchronization, BSR weight repartitioning) are exact and
//! checked against single-device oracles. Wire volume is accounted per
//! transfer for reporting.
//!
//! The PJRT client is `Rc`-based (not `Send`), so devices are interpreted
//! deterministically on one thread; the *coordination structure* (which
//! device computes which shard, which groups reduce) is identical to the
//! multi-process deployment (DESIGN.md §2).

use std::collections::HashMap;

use crate::runtime::HostTensor;
use crate::{Error, Result};

/// One simulated device's tensor store.
#[derive(Default, Debug)]
pub struct DeviceMem {
    tensors: HashMap<String, HostTensor>,
}

impl DeviceMem {
    /// Insert/replace a tensor.
    pub fn put(&mut self, key: &str, t: HostTensor) {
        self.tensors.insert(key.to_string(), t);
    }

    /// Borrow a tensor.
    pub fn get(&self, key: &str) -> Result<&HostTensor> {
        self.tensors
            .get(key)
            .ok_or_else(|| Error::Engine(format!("device missing tensor `{key}`")))
    }

    /// Mutable borrow.
    pub fn get_mut(&mut self, key: &str) -> Result<&mut HostTensor> {
        self.tensors
            .get_mut(key)
            .ok_or_else(|| Error::Engine(format!("device missing tensor `{key}`")))
    }

    /// Remove a tensor.
    pub fn take(&mut self, key: &str) -> Result<HostTensor> {
        self.tensors
            .remove(key)
            .ok_or_else(|| Error::Engine(format!("device missing tensor `{key}`")))
    }

    /// Presence test.
    pub fn has(&self, key: &str) -> bool {
        self.tensors.contains_key(key)
    }

    /// Keys (sorted, for deterministic iteration).
    pub fn keys(&self) -> Vec<String> {
        let mut v: Vec<String> = self.tensors.keys().cloned().collect();
        v.sort();
        v
    }
}

/// A mesh of simulated devices.
#[derive(Default, Debug)]
pub struct Mesh {
    /// Device stores, indexed by simulated device id.
    pub devices: Vec<DeviceMem>,
    /// Total elements moved device-to-device (accounting).
    pub wire_elems: u64,
    /// Number of communication operations issued.
    pub ops: u64,
}

impl Mesh {
    /// A mesh of `n` devices.
    pub fn new(n: usize) -> Mesh {
        Mesh { devices: (0..n).map(|_| DeviceMem::default()).collect(), wire_elems: 0, ops: 0 }
    }

    /// Device count.
    pub fn len(&self) -> usize {
        self.devices.len()
    }

    /// True when empty.
    pub fn is_empty(&self) -> bool {
        self.devices.is_empty()
    }

    /// Deliver a freshly-built tensor from `from` to `to` under `key`
    /// (slice transfers during resharding switches): accounts wire volume
    /// and stores at the destination.
    pub fn push(&mut self, from: usize, to: usize, key: &str, t: HostTensor) {
        if from != to {
            self.wire_elems += t.len() as u64;
            self.ops += 1;
        }
        self.devices[to].put(key, t);
    }

    /// Point-to-point copy of `key` from one device to another (stores
    /// under the same key).
    pub fn send(&mut self, from: usize, to: usize, key: &str) -> Result<()> {
        if from == to {
            return Ok(());
        }
        let t = self.devices[from].get(key)?.clone();
        self.wire_elems += t.len() as u64;
        self.ops += 1;
        self.devices[to].put(key, t);
        Ok(())
    }

    /// AllReduce(sum) of `key` across `group`: afterwards every member
    /// holds the elementwise sum.
    pub fn all_reduce(&mut self, group: &[usize], key: &str) -> Result<()> {
        if group.len() <= 1 {
            return Ok(());
        }
        let mut acc = self.devices[group[0]].get(key)?.clone();
        for &d in &group[1..] {
            let t = self.devices[d].get(key)?.clone();
            acc.add_assign(&t)?;
            self.wire_elems += t.len() as u64;
        }
        for &d in group {
            self.wire_elems += acc.len() as u64;
            self.devices[d].put(key, acc.clone());
        }
        self.ops += 1;
        Ok(())
    }

    /// Broadcast `key` from `root` to the rest of `group`.
    pub fn broadcast(&mut self, root: usize, group: &[usize], key: &str) -> Result<()> {
        let t = self.devices[root].get(key)?.clone();
        for &d in group {
            if d != root {
                self.wire_elems += t.len() as u64;
                self.devices[d].put(key, t.clone());
            }
        }
        self.ops += 1;
        Ok(())
    }

    /// AllGather along dim 0: each member holds a `[k, ...]` shard under
    /// `key`; afterwards every member holds the concatenation (group
    /// order) under `out_key`.
    pub fn all_gather0(&mut self, group: &[usize], key: &str, out_key: &str) -> Result<()> {
        let first = self.devices[group[0]].get(key)?.clone();
        let mut shape = first.shape.clone();
        let row = shape[0];
        let mut data: Vec<f32> = Vec::with_capacity(first.len() * group.len());
        for &d in group {
            let t = self.devices[d].get(key)?;
            if t.shape != first.shape {
                return Err(Error::Engine("all_gather0: ragged shards".into()));
            }
            data.extend_from_slice(t.as_f32()?);
            self.wire_elems += (t.len() * (group.len() - 1)) as u64;
        }
        shape[0] = row * group.len();
        let full = HostTensor::f32(shape, data)?;
        for &d in group {
            self.devices[d].put(out_key, full.clone());
        }
        self.ops += 1;
        Ok(())
    }

    /// ReduceScatter along dim 0: every member holds a full tensor under
    /// `key`; afterwards member `i` holds the `i`-th dim-0 slice of the
    /// elementwise sum under `out_key`.
    pub fn reduce_scatter0(&mut self, group: &[usize], key: &str, out_key: &str) -> Result<()> {
        let n = group.len();
        let mut acc = self.devices[group[0]].get(key)?.clone();
        for &d in &group[1..] {
            let t = self.devices[d].get(key)?.clone();
            acc.add_assign(&t)?;
            self.wire_elems += t.len() as u64;
        }
        let rows = acc.shape[0];
        if rows % n != 0 {
            return Err(Error::Engine(format!("reduce_scatter0: {rows} rows over {n} devices")));
        }
        let chunk_rows = rows / n;
        let row_elems: usize = acc.shape[1..].iter().product::<usize>().max(1);
        let data = acc.as_f32()?;
        for (i, &d) in group.iter().enumerate() {
            let lo = i * chunk_rows * row_elems;
            let hi = (i + 1) * chunk_rows * row_elems;
            let mut shape = acc.shape.clone();
            shape[0] = chunk_rows;
            let t = HostTensor::f32(shape, data[lo..hi].to_vec())?;
            self.devices[d].put(out_key, t);
        }
        self.ops += 1;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn t(v: Vec<f32>) -> HostTensor {
        let n = v.len();
        HostTensor::f32(vec![n], v).unwrap()
    }

    #[test]
    fn all_reduce_sums_everywhere() {
        let mut m = Mesh::new(3);
        for d in 0..3 {
            m.devices[d].put("x", t(vec![d as f32 + 1.0, 1.0]));
        }
        m.all_reduce(&[0, 1, 2], "x").unwrap();
        for d in 0..3 {
            assert_eq!(m.devices[d].get("x").unwrap().as_f32().unwrap(), &[6.0, 3.0]);
        }
        assert!(m.wire_elems > 0);
    }

    #[test]
    fn send_moves_and_accounts() {
        let mut m = Mesh::new(2);
        m.devices[0].put("a", t(vec![5.0; 8]));
        m.send(0, 1, "a").unwrap();
        assert_eq!(m.devices[1].get("a").unwrap().as_f32().unwrap(), &[5.0; 8]);
        assert_eq!(m.wire_elems, 8);
    }

    #[test]
    fn broadcast_replicates() {
        let mut m = Mesh::new(3);
        m.devices[1].put("w", t(vec![2.0; 4]));
        m.broadcast(1, &[0, 1, 2], "w").unwrap();
        for d in [0, 2] {
            assert_eq!(m.devices[d].get("w").unwrap().as_f32().unwrap(), &[2.0; 4]);
        }
    }

    #[test]
    fn all_gather0_concatenates_in_group_order() {
        let mut m = Mesh::new(2);
        m.devices[0].put("s", HostTensor::f32(vec![1, 2], vec![1.0, 2.0]).unwrap());
        m.devices[1].put("s", HostTensor::f32(vec![1, 2], vec![3.0, 4.0]).unwrap());
        m.all_gather0(&[0, 1], "s", "full").unwrap();
        let f = m.devices[0].get("full").unwrap();
        assert_eq!(f.shape, vec![2, 2]);
        assert_eq!(f.as_f32().unwrap(), &[1.0, 2.0, 3.0, 4.0]);
    }

    #[test]
    fn reduce_scatter0_partitions_the_sum() {
        let mut m = Mesh::new(2);
        m.devices[0].put("g", HostTensor::f32(vec![4], vec![1.0, 2.0, 3.0, 4.0]).unwrap());
        m.devices[1].put("g", HostTensor::f32(vec![4], vec![10.0, 20.0, 30.0, 40.0]).unwrap());
        m.reduce_scatter0(&[0, 1], "g", "gs").unwrap();
        assert_eq!(m.devices[0].get("gs").unwrap().as_f32().unwrap(), &[11.0, 22.0]);
        assert_eq!(m.devices[1].get("gs").unwrap().as_f32().unwrap(), &[33.0, 44.0]);
    }

    #[test]
    fn rs_then_ag_equals_ar() {
        let mut m = Mesh::new(2);
        for d in 0..2 {
            m.devices[d].put("g", HostTensor::f32(vec![4], vec![d as f32 + 1.0; 4]).unwrap());
        }
        m.reduce_scatter0(&[0, 1], "g", "gs").unwrap();
        m.all_gather0(&[0, 1], "gs", "gf").unwrap();
        assert_eq!(m.devices[0].get("gf").unwrap().as_f32().unwrap(), &[3.0; 4]);
        assert_eq!(m.devices[1].get("gf").unwrap().as_f32().unwrap(), &[3.0; 4]);
    }
}
