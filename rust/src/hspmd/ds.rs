//! Distributed States (DS) — bottom-tier SPMD sharding description (§3.1).

use crate::{Error, Result};

/// Logical distributed dimension for **Duplicate** semantics.
pub const DUPLICATE: i32 = -1;
/// Logical distributed dimension for **Partial** semantics.
pub const PARTIAL: i32 = -2;

/// The three SPMD sharding semantics of a logical distributed dimension.
#[derive(Clone, Copy, PartialEq, Eq, Debug, Hash)]
pub enum Semantic {
    /// Tensor is uniformly split along physical dimension `dim`.
    Split { dim: u32 },
    /// Tensor is fully replicated.
    Duplicate,
    /// Tensor values are partial sums (must be reduced to materialize).
    Partial,
}

impl Semantic {
    /// Map a logical dimension key (`-2`, `-1`, `>= 0`) to its semantic.
    pub fn of(key: i32) -> Semantic {
        match key {
            PARTIAL => Semantic::Partial,
            DUPLICATE => Semantic::Duplicate,
            d if d >= 0 => Semantic::Split { dim: d as u32 },
            other => panic!("invalid logical dim {other}"),
        }
    }
}

/// Distributed States: an ordered dictionary `logical dim -> #shards`.
///
/// `entries` is kept sorted by key (`-2` first, then `-1`, then physical
/// dims ascending) as the canonical form; `order` is the *device order* —
/// the sequence of logical dims used to decompose a device's position in its
/// [`super::DeviceGroup`](crate::hspmd::DeviceGroup) into per-dim shard
/// coordinates (row-major: first entry of `order` varies slowest).
///
/// Invariants (checked by [`DistStates::new`]):
/// * all shard counts are ≥ 2 (count-1 entries are omitted — they carry no
///   information);
/// * `order` contains exactly the keys of `entries`, each once;
/// * the product of shard counts equals the number of devices the DS is
///   meant to cover ([`DistStates::num_devices`]).
#[derive(Clone, PartialEq, Eq, Debug, Hash)]
pub struct DistStates {
    entries: Vec<(i32, u32)>,
    order: Vec<i32>,
}

impl DistStates {
    /// Build a DS from `(logical dim, #shards)` pairs plus a device order.
    pub fn new(entries: &[(i32, u32)], order: &[i32]) -> Result<Self> {
        let mut es: Vec<(i32, u32)> = entries
            .iter()
            .copied()
            .filter(|&(_, n)| n != 1)
            .collect();
        es.sort_by_key(|&(d, _)| d);
        for w in es.windows(2) {
            if w[0].0 == w[1].0 {
                return Err(Error::InvalidAnnotation(format!(
                    "duplicate logical dim {} in DS",
                    w[0].0
                )));
            }
        }
        for &(d, n) in &es {
            if d < PARTIAL {
                return Err(Error::InvalidAnnotation(format!("logical dim {d} < -2")));
            }
            if n == 0 {
                return Err(Error::InvalidAnnotation(format!("dim {d} has 0 shards")));
            }
        }
        let ord: Vec<i32> = order.iter().copied().filter(|d| es.iter().any(|&(k, _)| k == *d)).collect();
        let mut sorted_ord = ord.clone();
        sorted_ord.sort_unstable();
        let keys: Vec<i32> = es.iter().map(|&(d, _)| d).collect();
        if sorted_ord != keys {
            return Err(Error::InvalidAnnotation(format!(
                "order {ord:?} must be a permutation of DS keys {keys:?}"
            )));
        }
        Ok(DistStates { entries: es, order: ord })
    }

    /// DS with default order (sorted keys: Partial, Duplicate, dims asc).
    pub fn with_default_order(entries: &[(i32, u32)]) -> Result<Self> {
        let keys: Vec<i32> = {
            let mut ks: Vec<i32> = entries.iter().filter(|&&(_, n)| n != 1).map(|&(d, _)| d).collect();
            ks.sort_unstable();
            ks
        };
        Self::new(entries, &keys)
    }

    /// A DS over a single device (no sharding at all).
    pub fn trivial() -> Self {
        DistStates { entries: vec![], order: vec![] }
    }

    /// Pure data/tensor split along one physical dim.
    pub fn split(dim: u32, shards: u32) -> Self {
        if shards <= 1 {
            return Self::trivial();
        }
        DistStates { entries: vec![(dim as i32, shards)], order: vec![dim as i32] }
    }

    /// Fully replicated over `n` devices.
    pub fn duplicate(n: u32) -> Self {
        if n <= 1 {
            return Self::trivial();
        }
        DistStates { entries: vec![(DUPLICATE, n)], order: vec![DUPLICATE] }
    }

    /// Partial-sum over `n` devices.
    pub fn partial(n: u32) -> Self {
        if n <= 1 {
            return Self::trivial();
        }
        DistStates { entries: vec![(PARTIAL, n)], order: vec![PARTIAL] }
    }

    /// Canonical `(dim, shards)` view, sorted by dim.
    pub fn entries(&self) -> &[(i32, u32)] {
        &self.entries
    }

    /// Device order (sequence of logical dims, slowest-varying first).
    pub fn order(&self) -> &[i32] {
        &self.order
    }

    /// Shard count along a logical dim (1 if not present).
    pub fn shards(&self, dim: i32) -> u32 {
        self.entries
            .iter()
            .find(|&&(d, _)| d == dim)
            .map(|&(_, n)| n)
            .unwrap_or(1)
    }

    /// Number of devices this DS covers (product of shard counts).
    pub fn num_devices(&self) -> u32 {
        self.entries.iter().map(|&(_, n)| n).product()
    }

    /// True if any values are partial sums.
    pub fn has_partial(&self) -> bool {
        self.shards(PARTIAL) > 1
    }

    /// True if the tensor is replicated on ≥ 2 devices.
    pub fn has_duplicate(&self) -> bool {
        self.shards(DUPLICATE) > 1
    }

    /// Physical split dims (ascending) with their shard counts.
    pub fn splits(&self) -> Vec<(u32, u32)> {
        self.entries
            .iter()
            .filter(|&&(d, _)| d >= 0)
            .map(|&(d, n)| (d as u32, n))
            .collect()
    }

    /// Decompose a device position (index into the DG, `0..num_devices`)
    /// into per-logical-dim shard coordinates, following `order` row-major.
    pub fn coords_of(&self, pos: usize) -> Vec<(i32, u32)> {
        debug_assert!(pos < self.num_devices() as usize);
        let mut coords = vec![0u32; self.order.len()];
        let mut rem = pos as u64;
        // strides: last dim in order varies fastest
        for i in (0..self.order.len()).rev() {
            let n = self.shards(self.order[i]) as u64;
            coords[i] = (rem % n) as u32;
            rem /= n;
        }
        self.order.iter().copied().zip(coords).collect()
    }

    /// Inverse of [`coords_of`](Self::coords_of): coords (aligned with
    /// `order`) back to a device position.
    pub fn pos_of(&self, coords: &[(i32, u32)]) -> usize {
        let mut pos: u64 = 0;
        for &d in &self.order {
            let n = self.shards(d) as u64;
            let c = coords
                .iter()
                .find(|&&(dim, _)| dim == d)
                .map(|&(_, c)| c as u64)
                .unwrap_or(0);
            pos = pos * n + c;
        }
        pos as usize
    }

    /// Positions grouped along one logical dim: the devices in each returned
    /// group differ only in their coordinate on `dim`. This is the group
    /// structure of collectives (AR over `PARTIAL`, AG/RS over a split dim).
    pub fn groups_along(&self, dim: i32) -> Vec<Vec<usize>> {
        let n = self.num_devices() as usize;
        let k = self.shards(dim) as usize;
        if k <= 1 {
            return (0..n).map(|p| vec![p]).collect();
        }
        let mut map: std::collections::BTreeMap<Vec<(i32, u32)>, Vec<(u32, usize)>> =
            std::collections::BTreeMap::new();
        for pos in 0..n {
            let coords = self.coords_of(pos);
            let key: Vec<(i32, u32)> = coords.iter().copied().filter(|&(d, _)| d != dim).collect();
            let on_dim = coords.iter().find(|&&(d, _)| d == dim).map(|&(_, c)| c).unwrap_or(0);
            map.entry(key).or_default().push((on_dim, pos));
        }
        map.into_values()
            .map(|mut v| {
                v.sort_unstable();
                v.into_iter().map(|(_, p)| p).collect()
            })
            .collect()
    }

    /// Compute the local (per-shard) shape given the tensor's global shape.
    /// Non-divisible extents round like `len * (i+1)/n - len * i/n` (the
    /// shard of coordinate `i`); this returns the shape of shard coord 0.
    pub fn local_shape(&self, global: &[u64]) -> Vec<u64> {
        let mut shape = global.to_vec();
        for (dim, n) in self.splits() {
            let d = dim as usize;
            assert!(d < shape.len(), "split dim {d} out of rank {}", shape.len());
            shape[d] = shape[d] / n as u64 + u64::from(shape[d] % n as u64 != 0);
        }
        shape
    }

    /// Replace logical dim `from` with `to`, keeping the shard count and the
    /// position in `order`. Used by the resolver to model AR/RS/AG effects
    /// (e.g. `PARTIAL -> dim d` is the reduce-scatter post-state).
    pub fn relabel(&self, from: i32, to: i32) -> Result<DistStates> {
        if self.shards(from) == 1 {
            return Err(Error::InvalidAnnotation(format!("dim {from} not present")));
        }
        if to != DUPLICATE && self.shards(to) > 1 {
            return Err(Error::InvalidAnnotation(format!("dim {to} already present")));
        }
        let mut entries = self.entries.clone();
        let mut order = self.order.clone();
        for e in entries.iter_mut() {
            if e.0 == from {
                e.0 = to;
            }
        }
        for o in order.iter_mut() {
            if *o == from {
                *o = to;
            }
        }
        // merge if `to` now appears twice (e.g. relabel onto DUPLICATE which existed)
        let mut merged: Vec<(i32, u32)> = vec![];
        for (d, n) in entries {
            if let Some(e) = merged.iter_mut().find(|e| e.0 == d) {
                e.1 *= n;
            } else {
                merged.push((d, n));
            }
        }
        // `order` may now contain `to` twice; keep both occurrences only if
        // merged kept distinct entries (it didn't), so dedupe while keeping
        // the first occurrence.
        if merged.len() != order.len() {
            let mut seen = std::collections::BTreeSet::new();
            order.retain(|d| seen.insert(*d));
        }
        merged.sort_by_key(|&(d, _)| d);
        // re-validate order vs keys
        DistStates::new(&merged, &order)
    }

    /// Human-readable form, e.g. `{-1:2, 0:4 | order=[-1,0]}`.
    pub fn describe(&self) -> String {
        let body: Vec<String> = self.entries.iter().map(|(d, n)| format!("{d}:{n}")).collect();
        format!("{{{} | order={:?}}}", body.join(", "), self.order)
    }
}

/// The single-entry difference between two DS with identical shard counts —
/// the pattern that triggers bottom-tier collectives (Fig 5).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct DsTransition {
    /// Logical dim in the source.
    pub from: i32,
    /// Logical dim in the destination.
    pub to: i32,
    /// Shard count (same on both sides).
    pub shards: u32,
}

/// If `src` and `dst` differ by exactly one logical-dim relabel with equal
/// shard counts (and identical `order` positions), return that transition.
pub fn single_transition(src: &DistStates, dst: &DistStates) -> Option<DsTransition> {
    if src.num_devices() != dst.num_devices() {
        return None;
    }
    let se = src.entries();
    let de = dst.entries();
    if se.len() != de.len() {
        return None;
    }
    // Match multiset of shard counts; find the single key change.
    let mut diff_from: Vec<(i32, u32)> = vec![];
    let mut diff_to: Vec<(i32, u32)> = vec![];
    for &e in se {
        if !de.contains(&e) {
            diff_from.push(e);
        }
    }
    for &e in de {
        if !se.contains(&e) {
            diff_to.push(e);
        }
    }
    if diff_from.len() != 1 || diff_to.len() != 1 {
        return None;
    }
    let (f, nf) = diff_from[0];
    let (t, nt) = diff_to[0];
    if nf != nt {
        return None;
    }
    // order must be consistent: src.order with f->t equals dst.order
    let mapped: Vec<i32> = src
        .order()
        .iter()
        .map(|&d| if d == f { t } else { d })
        .collect();
    if mapped != dst.order() {
        return None;
    }
    Some(DsTransition { from: f, to: t, shards: nf })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn construction_and_accessors() {
        let ds = DistStates::new(&[(0, 2), (DUPLICATE, 4)], &[-1, 0]).unwrap();
        assert_eq!(ds.num_devices(), 8);
        assert_eq!(ds.shards(0), 2);
        assert_eq!(ds.shards(DUPLICATE), 4);
        assert_eq!(ds.shards(1), 1);
        assert!(ds.has_duplicate());
        assert!(!ds.has_partial());
        assert_eq!(ds.splits(), vec![(0, 2)]);
    }

    #[test]
    fn rejects_bad_order() {
        // missing a sharded dim in the order
        assert!(DistStates::new(&[(0, 2), (1, 2)], &[0]).is_err());
        // dims with shard count 1 are dropped from entries AND order
        let ds = DistStates::new(&[(0, 2), (1, 1)], &[0, 1]).unwrap();
        assert_eq!(ds.order(), &[0]);
    }

    #[test]
    fn rejects_duplicate_dim() {
        assert!(DistStates::new(&[(0, 2), (0, 3)], &[0]).is_err());
    }

    #[test]
    fn count1_entries_dropped() {
        let ds = DistStates::new(&[(0, 1), (1, 2)], &[1]).unwrap();
        assert_eq!(ds.entries(), &[(1, 2)]);
    }

    #[test]
    fn coords_roundtrip() {
        let ds = DistStates::new(&[(DUPLICATE, 2), (0, 2), (1, 3)], &[0, -1, 1]).unwrap();
        for pos in 0..ds.num_devices() as usize {
            let coords = ds.coords_of(pos);
            assert_eq!(ds.pos_of(&coords), pos);
        }
    }

    #[test]
    fn coords_row_major_over_order() {
        // order = [0, -1]: dim0 varies slowest.
        let ds = DistStates::new(&[(0, 2), (DUPLICATE, 2)], &[0, -1]).unwrap();
        assert_eq!(ds.coords_of(0), vec![(0, 0), (-1, 0)]);
        assert_eq!(ds.coords_of(1), vec![(0, 0), (-1, 1)]);
        assert_eq!(ds.coords_of(2), vec![(0, 1), (-1, 0)]);
        assert_eq!(ds.coords_of(3), vec![(0, 1), (-1, 1)]);
    }

    #[test]
    fn groups_along_partial() {
        // TP-style: partial over 2, split dim0 over 2, order=[−2,0]
        let ds = DistStates::new(&[(PARTIAL, 2), (0, 2)], &[-2, 0]).unwrap();
        let groups = ds.groups_along(PARTIAL);
        assert_eq!(groups.len(), 2);
        // each group holds one device per partial coord
        for g in &groups {
            assert_eq!(g.len(), 2);
        }
        // positions: order [-2,0] → pos = p*2 + s
        assert!(groups.contains(&vec![0, 2]));
        assert!(groups.contains(&vec![1, 3]));
    }

    #[test]
    fn local_shape_divides() {
        let ds = DistStates::new(&[(0, 4), (1, 2)], &[0, 1]).unwrap();
        assert_eq!(ds.local_shape(&[8, 6, 5]), vec![2, 3, 5]);
        // non-divisible rounds up (shard 0 size)
        assert_eq!(ds.local_shape(&[9, 6, 5]), vec![3, 3, 5]);
    }

    #[test]
    fn relabel_partial_to_split() {
        let ds = DistStates::new(&[(PARTIAL, 4)], &[-2]).unwrap();
        let rs = ds.relabel(PARTIAL, 0).unwrap();
        assert_eq!(rs.entries(), &[(0, 4)]);
        assert_eq!(rs.order(), &[0]);
    }

    #[test]
    fn relabel_split_to_dup_merges() {
        let ds = DistStates::new(&[(DUPLICATE, 2), (0, 2)], &[-1, 0]).unwrap();
        let ag = ds.relabel(0, DUPLICATE).unwrap();
        assert_eq!(ag.entries(), &[(DUPLICATE, 4)]);
    }

    #[test]
    fn single_transition_detects_ar() {
        let src = DistStates::new(&[(PARTIAL, 4), (0, 2)], &[-2, 0]).unwrap();
        let dst = DistStates::new(&[(DUPLICATE, 4), (0, 2)], &[-1, 0]).unwrap();
        let t = single_transition(&src, &dst).unwrap();
        assert_eq!(t, DsTransition { from: PARTIAL, to: DUPLICATE, shards: 4 });
    }

    #[test]
    fn single_transition_rejects_reorder() {
        let src = DistStates::new(&[(PARTIAL, 2), (0, 2)], &[-2, 0]).unwrap();
        let dst = DistStates::new(&[(DUPLICATE, 2), (0, 2)], &[0, -1]).unwrap();
        assert!(single_transition(&src, &dst).is_none());
    }

    #[test]
    fn single_transition_rejects_multi_change() {
        let src = DistStates::new(&[(PARTIAL, 2), (0, 2)], &[-2, 0]).unwrap();
        let dst = DistStates::new(&[(DUPLICATE, 2), (1, 2)], &[-1, 1]).unwrap();
        assert!(single_transition(&src, &dst).is_none());
    }
}
