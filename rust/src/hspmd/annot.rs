//! Full HSPMD tensor annotations (§3.2): DG Union + DS Union + HDim/HSize.

use super::dg::{DeviceGroup, Rank};
use super::ds::{DistStates, DUPLICATE, PARTIAL};
use super::slices::Interval;
use crate::{Error, Result};

/// One *sharding subgroup*: a device group with its bottom-tier sharding.
#[derive(Clone, PartialEq, Eq, Debug, Hash)]
pub struct Subgroup {
    /// Devices of this subgroup (bottom-tier DG).
    pub dg: DeviceGroup,
    /// Bottom-tier sharding within the subgroup.
    pub ds: DistStates,
}

impl Subgroup {
    /// Construct and validate `|dg| == ds.num_devices()`.
    pub fn new(dg: DeviceGroup, ds: DistStates) -> Result<Self> {
        if dg.len() != ds.num_devices() as usize {
            return Err(Error::InvalidAnnotation(format!(
                "subgroup: |DG|={} but DS covers {} devices ({})",
                dg.len(),
                ds.num_devices(),
                ds.describe()
            )));
        }
        Ok(Subgroup { dg, ds })
    }
}

/// A full HSPMD annotation: the list of sharding subgroups (`DG Union` +
/// `DS Union`, top-tier index = position in the list), the heterogeneous
/// dimension `HDim`, and optional non-uniform split weights along `HDim`
/// (§5.5 allows the actual proportions to be bound at runtime; `hsplit`
/// carries the currently-bound weights, `None` = uniform).
///
/// `HSize` is implicit: `groups.len()`.
#[derive(Clone, PartialEq, Eq, Debug, Hash)]
pub struct Annotation {
    /// Sharding subgroups, in top-tier order (subgroup `g` owns the `g`-th
    /// interval along `hdim` when `hdim >= 0`).
    pub groups: Vec<Subgroup>,
    /// Top-tier semantic: `-2` partial, `-1` replicate, `>= 0` split along
    /// that tensor dimension.
    pub hdim: i32,
    /// Optional per-subgroup weights for non-uniform `hdim` splits.
    pub hsplit: Option<Vec<u64>>,
}

impl Annotation {
    /// Construct and validate: non-empty, mutually-exclusive subgroups,
    /// weight vector length, legal `hdim`.
    pub fn new(groups: Vec<Subgroup>, hdim: i32) -> Result<Self> {
        Self::with_weights(groups, hdim, None)
    }

    /// [`Annotation::new`] with explicit non-uniform `hdim` weights.
    pub fn with_weights(
        groups: Vec<Subgroup>,
        hdim: i32,
        hsplit: Option<Vec<u64>>,
    ) -> Result<Self> {
        if groups.is_empty() {
            return Err(Error::InvalidAnnotation("annotation with 0 subgroups".into()));
        }
        if hdim < PARTIAL {
            return Err(Error::InvalidAnnotation(format!("hdim {hdim} < -2")));
        }
        for i in 0..groups.len() {
            for j in (i + 1)..groups.len() {
                if !groups[i].dg.disjoint_with(&groups[j].dg) {
                    return Err(Error::InvalidAnnotation(format!(
                        "subgroups {i} and {j} share devices"
                    )));
                }
            }
        }
        if let Some(w) = &hsplit {
            if w.len() != groups.len() {
                return Err(Error::InvalidAnnotation(format!(
                    "hsplit has {} weights for {} subgroups",
                    w.len(),
                    groups.len()
                )));
            }
            if hdim < 0 {
                return Err(Error::InvalidAnnotation(
                    "hsplit weights are only meaningful when hdim >= 0".into(),
                ));
            }
            if w.iter().any(|&x| x == 0) {
                return Err(Error::InvalidAnnotation("zero hsplit weight".into()));
            }
        }
        Ok(Annotation { groups, hdim, hsplit })
    }

    /// Classic (non-hierarchical) SPMD annotation: one subgroup, `hdim=-1`.
    pub fn spmd(dg: DeviceGroup, ds: DistStates) -> Result<Self> {
        Self::new(vec![Subgroup::new(dg, ds)?], DUPLICATE)
    }

    /// Number of sharding subgroups (`HSize`, §3.2).
    pub fn hsize(&self) -> usize {
        self.groups.len()
    }

    /// All devices across the union, in union order.
    pub fn all_ranks(&self) -> Vec<Rank> {
        self.groups.iter().flat_map(|g| g.dg.ranks().iter().copied()).collect()
    }

    /// Total device count.
    pub fn num_devices(&self) -> usize {
        self.groups.iter().map(|g| g.dg.len()).sum()
    }

    /// Subgroup index and in-group position of `rank`, if it participates.
    pub fn locate(&self, rank: Rank) -> Option<(usize, usize)> {
        for (g, sub) in self.groups.iter().enumerate() {
            if let Some(p) = sub.dg.position(rank) {
                return Some((g, p));
            }
        }
        None
    }

    /// True if any values (bottom-tier or top-tier) are partial sums.
    pub fn has_partial(&self) -> bool {
        self.hdim == PARTIAL || self.groups.iter().any(|g| g.ds.has_partial())
    }

    /// Same `DG Union` (paper §4.2: "every DG in the union is equivalent"),
    /// compared as *sets* per position.
    pub fn same_dg_union(&self, other: &Annotation) -> bool {
        self.hsize() == other.hsize()
            && self
                .groups
                .iter()
                .zip(other.groups.iter())
                .all(|(a, b)| a.dg.same_set(&b.dg))
    }

    /// Identical `DG Union` including device order (stronger than
    /// [`same_dg_union`](Self::same_dg_union); identity/no-comm requires it).
    pub fn identical_dg_union(&self, other: &Annotation) -> bool {
        self.hsize() == other.hsize()
            && self
                .groups
                .iter()
                .zip(other.groups.iter())
                .all(|(a, b)| a.dg == b.dg)
    }

    /// Same `DS Union` (elementwise DS equality).
    pub fn same_ds_union(&self, other: &Annotation) -> bool {
        self.hsize() == other.hsize()
            && self
                .groups
                .iter()
                .zip(other.groups.iter())
                .all(|(a, b)| a.ds == b.ds)
    }

    /// Top-tier interval of subgroup `g` along `hdim` for a tensor of
    /// extent `len` on that dim. Uniform unless `hsplit` weights are bound.
    /// For `hdim < 0` this is the full `[0, len)` range for every subgroup.
    pub fn top_interval(&self, g: usize, len: u64) -> Interval {
        if self.hdim < 0 {
            return Interval { lo: 0, hi: len };
        }
        let h = self.hsize() as u64;
        match &self.hsplit {
            None => Interval {
                lo: len * g as u64 / h,
                hi: len * (g as u64 + 1) / h,
            },
            Some(w) => {
                let total: u64 = w.iter().sum();
                let before: u64 = w[..g].iter().sum();
                Interval {
                    lo: len * before / total,
                    hi: len * (before + w[g]) / total,
                }
            }
        }
    }

    /// Fig 10 — semantic-preserving `HSize` refinement: split every subgroup
    /// into `k` subgroups along logical dim `split_ld` of its DS, producing
    /// an annotation with `HSize * k` subgroups.
    ///
    /// Validity (checked): every subgroup's DS must shard `split_ld` with a
    /// count divisible by `k`, and the refinement must be expressible with a
    /// single top-tier `HDim`:
    /// * `split_ld == -1` requires `hdim == -1` (replica groups split into
    ///   replica subgroups) — `hdim` stays `-1`;
    /// * `split_ld == -2` requires `hdim ∈ {-1, -2}` with `hsize == 1` when
    ///   `hdim == -1` — result `hdim = -2`;
    /// * `split_ld == d >= 0` requires `hdim == d`, or `hsize == 1` and
    ///   `hdim == -1` — result `hdim = d`.
    pub fn refine(&self, split_ld: i32, k: u32) -> Result<Annotation> {
        if k == 0 {
            return Err(Error::InvalidAnnotation("refine by k=0".into()));
        }
        if k == 1 {
            return Ok(self.clone());
        }
        let new_hdim = match split_ld {
            DUPLICATE => {
                if self.hdim != DUPLICATE {
                    return Err(Error::InvalidAnnotation(format!(
                        "refine along DUP requires hdim=-1, have {}",
                        self.hdim
                    )));
                }
                DUPLICATE
            }
            PARTIAL => {
                if !(self.hdim == PARTIAL || (self.hdim == DUPLICATE && self.hsize() == 1)) {
                    return Err(Error::InvalidAnnotation(format!(
                        "refine along PARTIAL requires hdim=-2 (or hsize=1), have {}",
                        self.hdim
                    )));
                }
                PARTIAL
            }
            d => {
                if !(self.hdim == d || (self.hdim == DUPLICATE && self.hsize() == 1)) {
                    return Err(Error::InvalidAnnotation(format!(
                        "refine along dim {d} requires hdim={d} (or hsize=1), have {}",
                        self.hdim
                    )));
                }
                d
            }
        };
        if self.hsplit.is_some() {
            return Err(Error::InvalidAnnotation(
                "refine with bound non-uniform weights is not supported".into(),
            ));
        }
        let mut groups = Vec::with_capacity(self.groups.len() * k as usize);
        for sub in &self.groups {
            let s = sub.ds.shards(split_ld);
            if s % k != 0 {
                return Err(Error::InvalidAnnotation(format!(
                    "subgroup DS shards {s} on dim {split_ld} not divisible by {k}"
                )));
            }
            let per = s / k; // remaining shards on split_ld inside each new subgroup
            // Partition device positions by coord(split_ld) / per.
            let mut buckets: Vec<Vec<Rank>> = vec![vec![]; k as usize];
            for (pos, &rank) in sub.dg.ranks().iter().enumerate() {
                let coord = sub
                    .ds
                    .coords_of(pos)
                    .iter()
                    .find(|&&(d, _)| d == split_ld)
                    .map(|&(_, c)| c)
                    .unwrap_or(0);
                buckets[(coord / per) as usize].push(rank);
            }
            // New DS: split_ld count reduced to `per`.
            let entries: Vec<(i32, u32)> = sub
                .ds
                .entries()
                .iter()
                .map(|&(d, n)| if d == split_ld { (d, per) } else { (d, n) })
                .collect();
            let order: Vec<i32> = sub
                .ds
                .order()
                .iter()
                .copied()
                .filter(|&d| d != split_ld || per > 1)
                .collect();
            let ds = DistStates::new(&entries, &order)?;
            for b in buckets {
                groups.push(Subgroup::new(DeviceGroup::new(b)?, ds.clone())?);
            }
        }
        Annotation::new(groups, new_hdim)
    }

    /// Short human-readable description.
    pub fn describe(&self) -> String {
        let subs: Vec<String> = self
            .groups
            .iter()
            .map(|g| format!("{}×{}", g.dg, g.ds.describe()))
            .collect();
        format!("hdim={} hsize={} [{}]", self.hdim, self.hsize(), subs.join("; "))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hspmd::slices::regions;

    fn ann(groups: Vec<(Vec<Rank>, DistStates)>, hdim: i32) -> Annotation {
        Annotation::new(
            groups
                .into_iter()
                .map(|(r, ds)| Subgroup::new(DeviceGroup::new(r).unwrap(), ds).unwrap())
                .collect(),
            hdim,
        )
        .unwrap()
    }

    #[test]
    fn rejects_overlapping_subgroups() {
        let g1 = Subgroup::new(DeviceGroup::new(vec![0, 1]).unwrap(), DistStates::split(0, 2)).unwrap();
        let g2 = Subgroup::new(DeviceGroup::new(vec![1, 2]).unwrap(), DistStates::split(0, 2)).unwrap();
        assert!(Annotation::new(vec![g1, g2], 0).is_err());
    }

    #[test]
    fn rejects_dg_ds_size_mismatch() {
        assert!(Subgroup::new(DeviceGroup::new(vec![0, 1, 2]).unwrap(), DistStates::split(0, 2)).is_err());
    }

    #[test]
    fn top_interval_uniform_and_weighted() {
        let a = ann(
            vec![
                (vec![0], DistStates::trivial()),
                (vec![1], DistStates::trivial()),
            ],
            0,
        );
        assert_eq!(a.top_interval(0, 10), Interval { lo: 0, hi: 5 });
        assert_eq!(a.top_interval(1, 10), Interval { lo: 5, hi: 10 });

        let w = Annotation::with_weights(a.groups.clone(), 0, Some(vec![3, 1])).unwrap();
        assert_eq!(w.top_interval(0, 8), Interval { lo: 0, hi: 6 });
        assert_eq!(w.top_interval(1, 8), Interval { lo: 6, hi: 8 });
    }

    #[test]
    fn locate_finds_rank() {
        let a = ann(
            vec![
                (vec![4, 5], DistStates::split(0, 2)),
                (vec![9], DistStates::trivial()),
            ],
            DUPLICATE,
        );
        assert_eq!(a.locate(5), Some((0, 1)));
        assert_eq!(a.locate(9), Some((1, 0)));
        assert_eq!(a.locate(0), None);
    }

    #[test]
    fn refine_hsize1_along_physical_dim_preserves_regions() {
        // DG [0,1,2,3], DS {0:2, -1:2} order [0,-1]: dim0 split outer.
        let ds = DistStates::new(&[(0, 2), (DUPLICATE, 2)], &[0, -1]).unwrap();
        let a = Annotation::spmd(DeviceGroup::range(0, 4), ds).unwrap();
        let r = a.refine(0, 2).unwrap();
        assert_eq!(r.hsize(), 2);
        assert_eq!(r.hdim, 0);
        // devices [0,1] take first half of dim0, [2,3] second half
        assert_eq!(r.groups[0].dg.ranks(), &[0, 1]);
        assert_eq!(r.groups[1].dg.ranks(), &[2, 3]);
        // geometry must be preserved exactly
        let shape = vec![8u64, 6u64];
        let before = regions(&a, &shape).unwrap();
        let after = regions(&r, &shape).unwrap();
        for (x, y) in before.iter().zip(after.iter()) {
            assert_eq!(x.rank, y.rank);
            assert_eq!(x.region, y.region, "rank {}", x.rank);
            assert_eq!(x.partial, y.partial);
        }
    }

    #[test]
    fn refine_along_dup_keeps_replication() {
        let ds = DistStates::new(&[(DUPLICATE, 2), (0, 2)], &[-1, 0]).unwrap();
        let a = Annotation::spmd(DeviceGroup::range(0, 4), ds).unwrap();
        let r = a.refine(DUPLICATE, 2).unwrap();
        assert_eq!(r.hsize(), 2);
        assert_eq!(r.hdim, DUPLICATE);
        assert_eq!(r.groups[0].dg.ranks(), &[0, 1]);
        assert_eq!(r.groups[1].dg.ranks(), &[2, 3]);
        assert_eq!(r.groups[0].ds.entries(), &[(0, 2)]);
    }

    #[test]
    fn refine_strided_inner_dim() {
        // order [-1, 0]: dup outer, dim0 inner. Refining along dim0 yields
        // strided subgroups {0,2} and {1,3}.
        let ds = DistStates::new(&[(DUPLICATE, 2), (0, 2)], &[-1, 0]).unwrap();
        let a = Annotation::spmd(DeviceGroup::range(0, 4), ds).unwrap();
        let r = a.refine(0, 2).unwrap();
        assert_eq!(r.groups[0].dg.ranks(), &[0, 2]);
        assert_eq!(r.groups[1].dg.ranks(), &[1, 3]);
    }

    #[test]
    fn refine_rejects_indivisible() {
        let a = Annotation::spmd(DeviceGroup::range(0, 3), DistStates::split(0, 3)).unwrap();
        assert!(a.refine(0, 2).is_err());
    }

    #[test]
    fn refine_rejects_mismatched_hdim() {
        let a = ann(
            vec![
                (vec![0, 1], DistStates::split(0, 2)),
                (vec![2, 3], DistStates::split(0, 2)),
            ],
            1, // top-tier split on dim 1
        );
        // splitting along dim 0 would need hdim 0
        assert!(a.refine(0, 2).is_err());
    }
}
