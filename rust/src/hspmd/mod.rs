//! §3 — HSPMD sharding annotations.
//!
//! The fundamental data model of the paper: every tensor in the computation
//! graph carries an [`Annotation`] describing *where* it lives and *how* it
//! is sharded.
//!
//! * Bottom tier (classic SPMD, §3.1): a [`DeviceGroup`] (ordered device
//!   list) plus [`DistStates`] — an ordered map from a *logical distributed
//!   dimension* to a shard count, with the three sharding semantics
//!   **Split** (`d ≥ 0`), **Duplicate** (`d = -1`) and **Partial**
//!   (`d = -2`).
//! * Top tier (§3.2): a [`DgUnion`]/[`DsUnion`] of *sharding subgroups*,
//!   related along a single heterogeneous dimension [`HDim`] with
//!   [`HSize`] = number of subgroups. `HDim ≥ 0` splits that tensor
//!   dimension across subgroups (optionally non-uniformly, §5.5),
//!   `HDim = -1` replicates across subgroups, and `HDim = -2` marks a
//!   partial-sum relation across subgroups (appears in deduction, Fig 11).
//!
//! [`slices`] turns annotations into concrete per-device *regions* of a
//! tensor, the geometry on which the §4 communication resolver and the BSR
//! planner operate.

pub mod annot;
pub mod dg;
pub mod ds;
pub mod slices;

pub use annot::{Annotation, Subgroup};
pub use dg::DeviceGroup;
pub use ds::{DistStates, Semantic, DUPLICATE, PARTIAL};
pub use slices::{DeviceRegion, Interval, Region, SliceGrid};

/// Heterogeneous dimension marker type (`-2` partial, `-1` replicate,
/// `>= 0` split along that tensor dimension).
pub type HDim = i32;
