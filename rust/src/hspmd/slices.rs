//! Slice geometry: annotations → per-device tensor regions → atomic slices.
//!
//! The §4 communication resolver and the §4.3 BSR planner both reason about
//! *which bytes of the global tensor live on which device*. This module
//! provides that geometry:
//!
//! * [`regions`] — expand an [`Annotation`] over a concrete global shape
//!   into one axis-aligned [`Region`] (box) per device, with partial-sum
//!   marking;
//! * [`SliceGrid`] — the *finest-grained slices* of a set of region lists
//!   (Figs 6–8): the grid induced by every cut point of every region, such
//!   that each atomic slice is either fully inside or fully outside any
//!   device's region.

use super::annot::Annotation;
use super::dg::Rank;
use crate::Result;

/// Half-open 1-D interval `[lo, hi)` in element units.
#[derive(Clone, Copy, PartialEq, Eq, Debug, Hash, PartialOrd, Ord)]
pub struct Interval {
    /// Inclusive lower bound.
    pub lo: u64,
    /// Exclusive upper bound.
    pub hi: u64,
}

impl Interval {
    /// Interval length.
    pub fn len(&self) -> u64 {
        self.hi.saturating_sub(self.lo)
    }

    /// True if the interval is empty.
    pub fn is_empty(&self) -> bool {
        self.hi <= self.lo
    }

    /// True if `self` fully contains `other`.
    pub fn contains(&self, other: &Interval) -> bool {
        self.lo <= other.lo && other.hi <= self.hi
    }

    /// Intersection (possibly empty).
    pub fn intersect(&self, other: &Interval) -> Interval {
        Interval { lo: self.lo.max(other.lo), hi: self.hi.min(other.hi) }
    }
}

/// An axis-aligned box: one [`Interval`] per tensor dimension.
pub type Region = Vec<Interval>;

/// Number of elements in a region.
pub fn region_elems(r: &Region) -> u64 {
    r.iter().map(|i| i.len()).product()
}

/// The portion of a tensor owned by one device, as derived from an
/// annotation.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct DeviceRegion {
    /// Global device rank.
    pub rank: Rank,
    /// Owned box of the global tensor.
    pub region: Region,
    /// True if this device's values are partial sums (bottom-tier `Partial`
    /// or top-tier `HDim = -2`) — such regions cannot feed a BSR plan.
    pub partial: bool,
    /// Subgroup index within the annotation's union.
    pub subgroup: usize,
}

/// Expand an annotation over a concrete global `shape` into per-device
/// regions (one entry per device, union order).
pub fn regions(annot: &Annotation, shape: &[u64]) -> Result<Vec<DeviceRegion>> {
    let mut out = Vec::with_capacity(annot.num_devices());
    let top_partial = annot.hdim == super::ds::PARTIAL;
    for (g, sub) in annot.groups.iter().enumerate() {
        // Top-tier box for this subgroup.
        let mut top_box: Region = shape.iter().map(|&n| Interval { lo: 0, hi: n }).collect();
        if annot.hdim >= 0 {
            let d = annot.hdim as usize;
            if d >= shape.len() {
                return Err(crate::Error::InvalidAnnotation(format!(
                    "hdim {d} out of rank {}",
                    shape.len()
                )));
            }
            top_box[d] = annot.top_interval(g, shape[d]);
        }
        let bottom_partial = sub.ds.has_partial();
        for (pos, &rank) in sub.dg.ranks().iter().enumerate() {
            let coords = sub.ds.coords_of(pos);
            let mut region = top_box.clone();
            for &(ld, coord) in &coords {
                if ld >= 0 {
                    let d = ld as usize;
                    if d >= shape.len() {
                        return Err(crate::Error::InvalidAnnotation(format!(
                            "split dim {d} out of rank {}",
                            shape.len()
                        )));
                    }
                    let n = sub.ds.shards(ld) as u64;
                    let base = top_box[d];
                    let len = base.len();
                    region[d] = Interval {
                        lo: base.lo + len * coord as u64 / n,
                        hi: base.lo + len * (coord as u64 + 1) / n,
                    };
                }
            }
            out.push(DeviceRegion {
                rank,
                region,
                partial: bottom_partial || top_partial,
                subgroup: g,
            });
        }
    }
    Ok(out)
}

/// The finest-grained slice grid induced by a set of device-region lists.
#[derive(Clone, Debug)]
pub struct SliceGrid {
    /// Cut points per dimension (sorted, deduplicated, includes 0 and len).
    pub cuts: Vec<Vec<u64>>,
}

impl SliceGrid {
    /// Build the grid from the union of all region boundaries.
    pub fn build(shape: &[u64], region_lists: &[&[DeviceRegion]]) -> SliceGrid {
        let mut cuts: Vec<Vec<u64>> = shape.iter().map(|&n| vec![0, n]).collect();
        for list in region_lists {
            for dr in *list {
                for (d, iv) in dr.region.iter().enumerate() {
                    cuts[d].push(iv.lo);
                    cuts[d].push(iv.hi);
                }
            }
        }
        for c in cuts.iter_mut() {
            c.sort_unstable();
            c.dedup();
        }
        SliceGrid { cuts }
    }

    /// Number of atomic slices.
    pub fn num_slices(&self) -> usize {
        self.cuts.iter().map(|c| c.len().saturating_sub(1)).product()
    }

    /// Enumerate atomic slices as regions, row-major over dims.
    pub fn slices(&self) -> Vec<Region> {
        let dims: Vec<usize> = self.cuts.iter().map(|c| c.len() - 1).collect();
        let total: usize = dims.iter().product();
        let mut out = Vec::with_capacity(total);
        for idx in 0..total {
            let mut rem = idx;
            let mut region = Vec::with_capacity(dims.len());
            for d in (0..dims.len()).rev() {
                let i = rem % dims[d];
                rem /= dims[d];
                region.push(Interval { lo: self.cuts[d][i], hi: self.cuts[d][i + 1] });
            }
            region.reverse();
            // skip zero-size slices (from degenerate cuts)
            if region.iter().all(|iv| !iv.is_empty()) {
                out.push(region);
            }
        }
        out
    }

    /// Devices of `list` whose region fully contains `slice`.
    pub fn holders<'a>(slice: &Region, list: &'a [DeviceRegion]) -> Vec<&'a DeviceRegion> {
        list.iter()
            .filter(|dr| dr.region.iter().zip(slice.iter()).all(|(a, b)| a.contains(b)))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hspmd::{DeviceGroup, DistStates, Subgroup};
    use crate::hspmd::ds::DUPLICATE;

    fn simple_annot() -> Annotation {
        // Fig 2-right X-style: two subgroups along dim 0.
        let g0 = Subgroup::new(DeviceGroup::new(vec![0, 3]).unwrap(), DistStates::split(1, 2)).unwrap();
        let g1 = Subgroup::new(DeviceGroup::new(vec![2, 4]).unwrap(), DistStates::split(0, 2)).unwrap();
        Annotation::new(vec![g0, g1], 0).unwrap()
    }

    #[test]
    fn regions_two_tier() {
        let a = simple_annot();
        let shape = vec![8, 6];
        let rs = regions(&a, &shape).unwrap();
        assert_eq!(rs.len(), 4);
        // subgroup 0 owns rows [0,4): device 0 cols [0,3), device 3 cols [3,6)
        assert_eq!(rs[0].rank, 0);
        assert_eq!(rs[0].region, vec![Interval { lo: 0, hi: 4 }, Interval { lo: 0, hi: 3 }]);
        assert_eq!(rs[1].rank, 3);
        assert_eq!(rs[1].region, vec![Interval { lo: 0, hi: 4 }, Interval { lo: 3, hi: 6 }]);
        // subgroup 1 owns rows [4,8): device 2 rows [4,6), device 4 rows [6,8)
        assert_eq!(rs[2].rank, 2);
        assert_eq!(rs[2].region, vec![Interval { lo: 4, hi: 6 }, Interval { lo: 0, hi: 6 }]);
        assert_eq!(rs[3].rank, 4);
        assert_eq!(rs[3].region, vec![Interval { lo: 6, hi: 8 }, Interval { lo: 0, hi: 6 }]);
    }

    #[test]
    fn regions_cover_tensor_exactly_when_no_dup() {
        let a = simple_annot();
        let shape = vec![8, 6];
        let rs = regions(&a, &shape).unwrap();
        let total: u64 = rs.iter().map(|r| region_elems(&r.region)).sum();
        assert_eq!(total, 48);
    }

    #[test]
    fn duplicate_devices_share_region() {
        let ds = DistStates::duplicate(3);
        let a = Annotation::spmd(DeviceGroup::range(0, 3), ds).unwrap();
        let rs = regions(&a, &[4, 4]).unwrap();
        assert!(rs.iter().all(|r| r.region == rs[0].region));
    }

    #[test]
    fn partial_marks_regions() {
        let a = Annotation::spmd(DeviceGroup::range(0, 2), DistStates::partial(2)).unwrap();
        let rs = regions(&a, &[4]).unwrap();
        assert!(rs.iter().all(|r| r.partial));
    }

    #[test]
    fn grid_atomic_slices() {
        let a = simple_annot();
        let shape = vec![8, 6];
        let rs = regions(&a, &shape).unwrap();
        let grid = SliceGrid::build(&shape, &[&rs]);
        // cuts: dim0 {0,4,6,8}, dim1 {0,3,6}
        assert_eq!(grid.cuts[0], vec![0, 4, 6, 8]);
        assert_eq!(grid.cuts[1], vec![0, 3, 6]);
        let slices = grid.slices();
        assert_eq!(slices.len(), 6);
        // every slice has exactly one holder here (no duplication)
        for s in &slices {
            assert_eq!(SliceGrid::holders(s, &rs).len(), 1, "slice {s:?}");
        }
    }

    #[test]
    fn holders_respect_containment() {
        let shape = vec![4u64];
        let a = Annotation::spmd(DeviceGroup::range(0, 2), DistStates::split(0, 2)).unwrap();
        let rs = regions(&a, &shape).unwrap();
        let grid = SliceGrid::build(&shape, &[&rs]);
        let slices = grid.slices();
        assert_eq!(slices.len(), 2);
        assert_eq!(SliceGrid::holders(&slices[0], &rs)[0].rank, 0);
        assert_eq!(SliceGrid::holders(&slices[1], &rs)[0].rank, 1);
    }

    #[test]
    fn non_divisible_extents_partition() {
        // 3-way split of extent 7 → 2/2/3 via floor boundaries, still a partition.
        let a = Annotation::spmd(DeviceGroup::range(0, 3), DistStates::split(0, 3)).unwrap();
        let rs = regions(&a, &[7]).unwrap();
        let total: u64 = rs.iter().map(|r| region_elems(&r.region)).sum();
        assert_eq!(total, 7);
        for w in rs.windows(2) {
            assert_eq!(w[0].region[0].hi, w[1].region[0].lo);
        }
    }

    #[test]
    fn weighted_hsplit_regions() {
        let g0 = Subgroup::new(DeviceGroup::new(vec![0]).unwrap(), DistStates::trivial()).unwrap();
        let g1 = Subgroup::new(DeviceGroup::new(vec![1]).unwrap(), DistStates::trivial()).unwrap();
        let a = Annotation::with_weights(vec![g0, g1], 0, Some(vec![3, 1])).unwrap();
        let rs = regions(&a, &[8]).unwrap();
        assert_eq!(rs[0].region[0], Interval { lo: 0, hi: 6 });
        assert_eq!(rs[1].region[0], Interval { lo: 6, hi: 8 });
    }

    #[test]
    fn hierarchical_dup_inside_split_subgroup() {
        // subgroup with DS {-1:2, 0:2}: 4 devices, rows split 2-way, dup 2-way
        let ds = DistStates::new(&[(DUPLICATE, 2), (0, 2)], &[-1, 0]).unwrap();
        let sub = Subgroup::new(DeviceGroup::range(0, 4), ds).unwrap();
        let a = Annotation::new(vec![sub], DUPLICATE).unwrap();
        let rs = regions(&a, &[4]).unwrap();
        // order [-1,0]: pos = dup*2 + split → devices 0,2 share row-half 0? no:
        // pos0=(dup0,s0) pos1=(dup0,s1) pos2=(dup1,s0) pos3=(dup1,s1)
        assert_eq!(rs[0].region, rs[2].region);
        assert_eq!(rs[1].region, rs[3].region);
        assert_ne!(rs[0].region, rs[1].region);
    }
}
