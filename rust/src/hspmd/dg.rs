//! Device Groups (DG) — ordered device lists (§3.1).

use crate::{Error, Result};

/// Global device rank (index into the cluster's device table).
pub type Rank = u32;

/// An ordered list of device ranks holding a tensor. Order matters: the
/// position of a device inside the group determines which shard it owns
/// (via [`DistStates::coords_of`](crate::hspmd::DistStates::coords_of)).
#[derive(Clone, PartialEq, Eq, Debug, Hash)]
pub struct DeviceGroup {
    ranks: Vec<Rank>,
}

impl DeviceGroup {
    /// Build from an explicit rank list. Ranks must be distinct.
    pub fn new(ranks: Vec<Rank>) -> Result<Self> {
        let mut seen = std::collections::BTreeSet::new();
        for &r in &ranks {
            if !seen.insert(r) {
                return Err(Error::InvalidAnnotation(format!(
                    "device group contains rank {r} twice"
                )));
            }
        }
        Ok(DeviceGroup { ranks })
    }

    /// Contiguous rank range `[lo, hi)` — the common case in the paper's
    /// appendix tables ("R16-19" etc., inclusive notation there).
    pub fn range(lo: Rank, hi: Rank) -> Self {
        DeviceGroup { ranks: (lo..hi).collect() }
    }

    /// Number of devices.
    pub fn len(&self) -> usize {
        self.ranks.len()
    }

    /// True when empty (an empty DG is only legal transiently).
    pub fn is_empty(&self) -> bool {
        self.ranks.is_empty()
    }

    /// Ordered ranks.
    pub fn ranks(&self) -> &[Rank] {
        &self.ranks
    }

    /// Position of `rank` inside the group, if present.
    pub fn position(&self, rank: Rank) -> Option<usize> {
        self.ranks.iter().position(|&r| r == rank)
    }

    /// Membership test.
    pub fn contains(&self, rank: Rank) -> bool {
        self.position(rank).is_some()
    }

    /// Set-disjointness (sharding subgroups must be mutually exclusive, §3.2).
    pub fn disjoint_with(&self, other: &DeviceGroup) -> bool {
        self.ranks.iter().all(|r| !other.contains(*r))
    }

    /// Same device *set* (order-insensitive comparison, used by the §4
    /// resolver: "if every DG in the union is equivalent").
    pub fn same_set(&self, other: &DeviceGroup) -> bool {
        if self.len() != other.len() {
            return false;
        }
        let mut a = self.ranks.clone();
        let mut b = other.ranks.clone();
        a.sort_unstable();
        b.sort_unstable();
        a == b
    }
}

impl std::fmt::Display for DeviceGroup {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "DG{:?}", self.ranks)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn range_and_position() {
        let dg = DeviceGroup::range(4, 8);
        assert_eq!(dg.len(), 4);
        assert_eq!(dg.position(6), Some(2));
        assert_eq!(dg.position(9), None);
    }

    #[test]
    fn rejects_duplicates() {
        assert!(DeviceGroup::new(vec![1, 2, 1]).is_err());
    }

    #[test]
    fn disjoint_and_same_set() {
        let a = DeviceGroup::new(vec![0, 1]).unwrap();
        let b = DeviceGroup::new(vec![2, 3]).unwrap();
        let c = DeviceGroup::new(vec![1, 0]).unwrap();
        assert!(a.disjoint_with(&b));
        assert!(!a.disjoint_with(&c));
        assert!(a.same_set(&c));
        assert!(!a.same_set(&b));
    }
}
