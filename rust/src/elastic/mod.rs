//! §7.2 — Elastic training: availability traces and reconfiguration.
//!
//! A trace is a sequence of device-availability events (GPU failure, node
//! failure). After each event the controller re-selects a strategy for the
//! surviving devices and pays a system-specific reconfiguration cost:
//!
//! * **Hetu** — restart-free: graph specialization (§5, measured) + fused-
//!   BSR graph switching (§6, planned volume / bottleneck link);
//! * **DeepSpeed / Megatron** — checkpoint-and-restart;
//! * **Oobleck** — template re-instantiation + naïve weight broadcast.

use crate::baselines::{deepspeed, megatron, oobleck};
use crate::cluster::Cluster;
use crate::comm::BsrOptions;
use crate::costmodel::CostModel;
use crate::hspmd::dg::Rank;
use crate::sim::simulate_step;
use crate::strategy::ParallelStrategy;
use crate::switch::plan_strategy_switch_avoiding;
use crate::Result;

/// One availability event.
#[derive(Clone, Debug)]
pub enum Event {
    /// Single GPU failure.
    FailGpu(Rank),
    /// Whole-node failure (8 GPUs).
    FailNode(u32),
    /// Repaired GPUs rejoin.
    Restore(Vec<Rank>),
}

/// The systems compared in Fig 14.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum System {
    /// Hetu with heterogeneous strategies + graph switching.
    Hetu,
    /// DeepSpeed (ZeRO-3, checkpoint restart).
    DeepSpeed,
    /// Megatron (ZeRO-1, checkpoint restart).
    Megatron,
    /// Oobleck (pipeline templates, broadcast transition).
    Oobleck,
}

/// Per-configuration outcome.
#[derive(Clone, Debug)]
pub struct ConfigReport {
    /// Configuration label (C1…C7).
    pub name: String,
    /// Alive GPU count.
    pub gpus: usize,
    /// Steady-state per-step seconds under this configuration.
    pub step_s: f64,
    /// Reconfiguration seconds paid to *enter* this configuration
    /// (0 for the initial one).
    pub reconfig_s: f64,
}

/// Checkpoint filesystem bandwidth for restart-based baselines (GB/s).
pub const CKPT_FS_GBPS: f64 = 5.0;
/// Process restart + framework re-initialization seconds.
pub const RESTART_INIT_S: f64 = 60.0;
/// Measured specialization budget for Hetu reconfiguration (the paper's
/// Fig 18-right: operator instantiation dominates, ≤ 10 s including NCCL
/// group creation; we charge this constant on top of the measured planning
/// time since the simulator has no real NCCL groups to build).
pub const HETU_GROUP_INIT_S: f64 = 8.0;

/// An elastic scenario: labelled configurations with the Hetu strategy per
/// configuration and the events between them.
pub struct Scenario {
    /// Configuration labels, in order.
    pub names: Vec<&'static str>,
    /// Hetu strategies per configuration (Tables 7/8).
    pub hetu: Vec<ParallelStrategy>,
    /// Events applied between consecutive configurations.
    pub events: Vec<Event>,
    /// Initial cluster.
    pub cluster: Cluster,
}

/// The homogeneous trace of Fig 14 (top): C1 → (GPU fail) → C2 → (node
/// fail) → C3 on 32 H20s.
pub fn homogeneous_trace() -> Scenario {
    use crate::strategy::tables::*;
    Scenario {
        names: vec!["C1", "C2", "C3"],
        hetu: vec![hetu_c1_32h20(), hetu_c2_31h20(), hetu_c3_24h20()],
        events: vec![Event::FailGpu(31), Event::FailNode(3)],
        cluster: Cluster::h20(32),
    }
}

/// The heterogeneous trace of Fig 14 (bottom): C4 → (node fail) → C5 →
/// (GPU fail) → C6 → (node fail) → C7 on 16 H800 + 32 H20.
pub fn heterogeneous_trace() -> Scenario {
    use crate::strategy::tables::*;
    Scenario {
        names: vec!["C4", "C5", "C6", "C7"],
        hetu: vec![hetu_c4(), hetu_c5(), hetu_c6(), hetu_c7()],
        // C4→C5: lose the last H20 node (ranks 40-47);
        // C5→C6: lose H800 rank 15; C6→C7: lose the H800 node 1 (8-15).
        events: vec![Event::FailNode(5), Event::FailGpu(15), Event::FailNode(1)],
        cluster: Cluster::h800_16_h20_32(),
    }
}

/// Engine-level failover (§7.2 at real numerics): execute the fused-BSR
/// transition with the dead devices excluded as weight sources (the engine
/// itself rejects survivor strategies that still schedule a dead device).
/// The paper-scale analogue is [`plan_strategy_switch_avoiding`]; this one
/// actually moves the surviving shards on the engine's mesh. Always plans
/// fresh; for a pool-managed engine prefer [`pool_failover`], which reuses
/// the cached transition when the failed rank held no needed shard.
pub fn engine_failover(
    engine: &mut crate::engine::Engine,
    survivor: crate::engine::EngineStrategy,
    dead: &[usize],
) -> Result<crate::engine::EngineSwitchReport> {
    engine.switch_to_avoiding(survivor, dead)
}

/// Pool-aware failover (§7.2 over cached pool transitions): drop the dead
/// ranks' timelines (the engine re-specializes the survivors on its next
/// step — DESIGN.md §7) and re-plan the pooled transition only when its
/// cached `SwitchPlan` actually reads from a dead rank; when the failed
/// rank holds no needed shard the cached plan executes untouched, an
/// allocation-free cache hit. See
/// [`StrategyPool::switch_engine_avoiding`](crate::temporal::StrategyPool).
pub fn pool_failover(
    pool: &mut crate::temporal::StrategyPool,
    engine: &mut crate::engine::Engine,
    to: usize,
    dead: &[usize],
) -> Result<crate::engine::EngineSwitchReport> {
    pool.switch_engine_avoiding(engine, to, dead)
}

/// Outcome of an elastic re-synthesis (see [`resynthesize`]).
#[derive(Debug)]
pub struct ResynthReport {
    /// Pool index of the newly added replacement entry.
    pub entry: usize,
    /// Name of the synthesized paper-scale strategy that was lowered.
    pub strategy_name: String,
    /// Its simulated step seconds on the surviving cluster.
    pub sim_step_s: f64,
    /// The executed engine transition onto the replacement.
    pub switch: crate::engine::EngineSwitchReport,
}

/// Full elastic re-synthesis (§7.2 closed loop): after a failure shrinks
/// `cluster`, search a *fresh* strategy for the survivors with
/// [`crate::strategy::synth::synthesize`], lower the best lowerable
/// candidate onto the engine's surviving mesh devices
/// ([`crate::strategy::lower_onto`]), pool it, and execute the fused-BSR
/// transition onto it with the dead devices excluded as weight sources.
///
/// This differs from [`pool_failover`] in that the replacement is not
/// assumed to already be in the pool — it is synthesized for exactly the
/// post-failure device set. `cluster` must already reflect the failure
/// (dead ranks marked), and `dead` names the engine mesh devices (not
/// paper-scale ranks) that went down.
#[allow(clippy::too_many_arguments)]
pub fn resynthesize(
    pool: &mut crate::temporal::StrategyPool,
    engine: &mut crate::engine::Engine,
    cluster: &Cluster,
    cm: &CostModel,
    dead: &[usize],
    global_batch: u64,
    seq_len: u64,
    lopts: &crate::strategy::LowerOptions,
) -> Result<ResynthReport> {
    let opts = crate::strategy::SynthOptions::new(global_batch, seq_len);
    let rep = crate::strategy::synthesize(cluster, cm, &opts)?;
    if rep.ranked.is_empty() {
        return Err(crate::Error::Strategy(
            "resynthesize: no feasible strategy for the surviving cluster".into(),
        ));
    }
    let survivors: Vec<usize> =
        (0..engine.mesh.devices.len()).filter(|d| !dead.contains(d)).collect();
    // keep the current entry's bucket context for the replacement — the
    // dispatcher's eligibility rule should not change under failover
    let ctx = pool
        .index_of(&engine.strategy)
        .map(|i| pool.entry(i).ctx)
        .unwrap_or(seq_len);
    let mut last_err: Option<crate::Error> = None;
    for (cand, sim_step_s) in &rep.ranked {
        // not every synthesized shape lowers to the tiny engine (stage
        // counts can exceed the engine's layer count); fall down the
        // ranking until one does
        let lowered = match crate::strategy::lower_onto(cand, pool.cfg(), lopts, &survivors) {
            Ok(e) => e,
            Err(e) => {
                last_err = Some(e);
                continue;
            }
        };
        let entry = pool.add_entry(lowered, ctx)?;
        let switch = pool.switch_engine_avoiding(engine, entry, dead)?;
        return Ok(ResynthReport {
            entry,
            strategy_name: cand.name.clone(),
            sim_step_s: *sim_step_s,
            switch,
        });
    }
    Err(last_err.unwrap_or_else(|| {
        crate::Error::Strategy("resynthesize: no ranked strategy lowers onto the engine".into())
    }))
}

fn apply(cluster: &mut Cluster, e: &Event) {
    match e {
        Event::FailGpu(r) => cluster.fail_gpu(*r),
        Event::FailNode(n) => cluster.fail_node(*n),
        Event::Restore(rs) => {
            for &r in rs {
                cluster.restore_gpu(r);
            }
        }
    }
}

/// Run a scenario for one system; returns one [`ConfigReport`] per
/// configuration.
pub fn run_scenario(
    scenario: &Scenario,
    cm: &CostModel,
    system: System,
    global_batch: u64,
    seq_len: u64,
) -> Result<Vec<ConfigReport>> {
    let mut cluster = scenario.cluster.clone();
    let mut reports = vec![];
    for (i, name) in scenario.names.iter().enumerate() {
        let mut reconfig_s = 0.0;
        if i > 0 {
            apply(&mut cluster, &scenario.events[i - 1]);
        }
        let step_s = match system {
            System::Hetu => {
                let strat = &scenario.hetu[i];
                if i > 0 {
                    let t0 = std::time::Instant::now();
                    let alive = cluster.alive_ranks();
                    let dead: Vec<crate::hspmd::dg::Rank> = scenario.hetu[i - 1]
                        .ranks()
                        .into_iter()
                        .filter(|r| !alive.contains(r))
                        .collect();
                    let rep = plan_strategy_switch_avoiding(
                        &scenario.hetu[i - 1],
                        strat,
                        cm,
                        &cluster,
                        BsrOptions::default(),
                        true,
                        &dead,
                    )?;
                    let planning_s = t0.elapsed().as_secs_f64();
                    reconfig_s = planning_s + rep.est_seconds + HETU_GROUP_INIT_S;
                }
                simulate_step(&cluster, cm, strat)?.step_s
            }
            System::DeepSpeed => {
                if i > 0 {
                    reconfig_s = deepspeed::restart_overhead_s(cm, CKPT_FS_GBPS, RESTART_INIT_S);
                }
                let cfg = deepspeed::table6(name)
                    .ok_or_else(|| crate::Error::Strategy(format!("no DS config for {name}")))?;
                deepspeed::step_time(&cluster, cm, cfg, global_batch, seq_len)
            }
            System::Megatron => {
                if i > 0 {
                    reconfig_s = deepspeed::restart_overhead_s(cm, CKPT_FS_GBPS, RESTART_INIT_S);
                }
                let cfg = megatron::table6(name)
                    .ok_or_else(|| crate::Error::Strategy(format!("no Mg config for {name}")))?;
                megatron::step_time(&cluster, cm, cfg, global_batch, seq_len)?
            }
            System::Oobleck => {
                if i > 0 {
                    reconfig_s = oobleck::transition_overhead_s(&cluster, cm, 10.0);
                }
                oobleck::step_time(&cluster, cm, global_batch, seq_len)?
            }
        };
        reports.push(ConfigReport {
            name: name.to_string(),
            gpus: cluster.alive_ranks().len(),
            step_s,
            reconfig_s,
        });
    }
    Ok(reports)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::costmodel::ModelCfg;

    fn cm() -> CostModel {
        CostModel::new(ModelCfg::llama_32b())
    }

    #[test]
    fn homogeneous_trace_gpu_counts() {
        let sc = homogeneous_trace();
        let reps = run_scenario(&sc, &cm(), System::Hetu, 64, 4096).unwrap();
        assert_eq!(reps.len(), 3);
        assert_eq!(reps[0].gpus, 32);
        assert_eq!(reps[1].gpus, 31);
        assert_eq!(reps[2].gpus, 24);
        assert_eq!(reps[0].reconfig_s, 0.0);
        assert!(reps[1].reconfig_s > 0.0);
    }

    #[test]
    fn hetu_reconfig_cheaper_than_restart() {
        let sc = homogeneous_trace();
        let hetu = run_scenario(&sc, &cm(), System::Hetu, 64, 4096).unwrap();
        let mega = run_scenario(&sc, &cm(), System::Megatron, 64, 4096).unwrap();
        assert!(
            hetu[1].reconfig_s < mega[1].reconfig_s,
            "hetu switch {} vs restart {}",
            hetu[1].reconfig_s,
            mega[1].reconfig_s
        );
    }

    #[test]
    fn hetu_c2_beats_uniform_baselines() {
        // The Fig 14 headline: on 31 GPUs Hetu uses all of them while
        // DS/Megatron discard the partial node.
        let sc = homogeneous_trace();
        let c = cm();
        let hetu = run_scenario(&sc, &c, System::Hetu, 64, 4096).unwrap();
        let mega = run_scenario(&sc, &c, System::Megatron, 64, 4096).unwrap();
        let ds = run_scenario(&sc, &c, System::DeepSpeed, 64, 4096).unwrap();
        assert!(hetu[1].step_s < mega[1].step_s, "hetu {} vs megatron {}", hetu[1].step_s, mega[1].step_s);
        assert!(hetu[1].step_s < ds[1].step_s, "hetu {} vs deepspeed {}", hetu[1].step_s, ds[1].step_s);
    }

    #[test]
    fn oobleck_trails_hetu_everywhere() {
        let sc = homogeneous_trace();
        let c = cm();
        let hetu = run_scenario(&sc, &c, System::Hetu, 64, 4096).unwrap();
        let oob = run_scenario(&sc, &c, System::Oobleck, 64, 4096).unwrap();
        for (h, o) in hetu.iter().zip(oob.iter()) {
            assert!(h.step_s <= o.step_s * 1.05, "{}: hetu {} oobleck {}", h.name, h.step_s, o.step_s);
        }
    }

    #[test]
    fn heterogeneous_trace_runs_all_systems() {
        let sc = heterogeneous_trace();
        let c = cm();
        for sys in [System::Hetu, System::DeepSpeed, System::Megatron, System::Oobleck] {
            let reps = run_scenario(&sc, &c, sys, 64, 4096).unwrap();
            assert_eq!(reps.len(), 4, "{sys:?}");
            assert!(reps.iter().all(|r| r.step_s > 0.0));
        }
    }
}
