//! Mixed-length data substrate (§7.3).
//!
//! The paper trains on CommonCrawl and GitHub with a 200K-token global batch
//! per step; sequence lengths vary wildly (97% of sequences are under 8K in
//! the 32K-context CommonCrawl workload, Fig 16). We cannot ship those
//! corpora, so this module provides *synthetic length samplers* fitted to
//! the reported statistics (log-normal body with a heavy tail), plus the
//! batch-construction policies of each system:
//!
//! * [`pack_sequences`] — DeepSpeed/Megatron-style packing into fixed
//!   context windows (truncating overlong sequences);
//! * [`bucketize`] — HotSPa/Hetu-A length-interval buckets;
//! * [`dispatch_hetu_b`] — Hetu-B's cost-model dispatch of sequences onto
//!   heterogeneous pipelines (long-sequence vs short-sequence pipelines).

use crate::testutil::Rng;

/// A dataset flavour with a fitted length distribution.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum Corpus {
    /// Web text: log-normal, median ≈ 600 tokens, σ ≈ 1.3 →
    /// P(len < 8K) ≈ 0.97 (matches Fig 16's "97% under 8K").
    CommonCrawl,
    /// Code: heavier tail (long files), median ≈ 900, σ ≈ 1.55.
    GitHub,
    /// The GitHub-corpus stress case (ROADMAP item 5): the same log-normal
    /// body as [`Corpus::GitHub`], but 5% of draws come from a Pareto tail
    /// (`α = 1.1`, scale 8K) — generated monorepo files and vendored blobs
    /// that pin whole steps at the context limit. This is the distribution
    /// the Hetu-B hysteresis default is stress-tested against (the
    /// `temporal_cadence` heavy-tail row).
    GitHubHeavyTail,
}

impl Corpus {
    /// Sample one sequence length in tokens, clipped to `[16, max_len]`.
    pub fn sample_len(&self, rng: &mut Rng, max_len: u64) -> u64 {
        let (mu, sigma) = match self {
            Corpus::CommonCrawl => (6.4, 1.3),
            Corpus::GitHub | Corpus::GitHubHeavyTail => (6.8, 1.55),
        };
        if *self == Corpus::GitHubHeavyTail && rng.chance(0.05) {
            // Pareto(α, scale): scale / U^(1/α). α just above 1 keeps the
            // mean finite but lets the tail reach any context limit.
            let u = rng.f64().max(1e-12);
            let len = (8192.0 / u.powf(1.0 / 1.1)) as u64;
            return len.clamp(16, max_len);
        }
        let len = rng.lognormal(mu, sigma) as u64;
        len.clamp(16, max_len)
    }
}

/// One training step's worth of sequences.
#[derive(Clone, Debug)]
pub struct StepBatch {
    /// Sequence lengths in tokens.
    pub seq_lens: Vec<u64>,
    /// Sum of lengths.
    pub total_tokens: u64,
}

impl StepBatch {
    /// Longest sequence in the batch (drives Hetu-B strategy selection).
    pub fn max_len(&self) -> u64 {
        self.seq_lens.iter().copied().max().unwrap_or(0)
    }
}

/// Sample sequences until the token budget (paper: 200K tokens/step) is
/// reached. The final sequence is clamped to whatever budget remains, so
/// `total_tokens == token_budget` exactly (the last sequence may be
/// shorter than the 16-token sampling floor, but never zero: the loop
/// only runs while at least one token of budget remains).
pub fn sample_step(rng: &mut Rng, corpus: Corpus, token_budget: u64, max_len: u64) -> StepBatch {
    let mut seq_lens = vec![];
    let mut total = 0u64;
    while total < token_budget {
        let l = corpus.sample_len(rng, max_len).min(token_budget - total);
        seq_lens.push(l);
        total += l;
    }
    StepBatch { seq_lens, total_tokens: total }
}

/// Greedy first-fit packing into `ctx`-token windows (the DeepSpeed /
/// Megatron baseline). Returns the actual window *contents* — per-window
/// sequence-length lists in first-fit order (`.len()` is the old bin
/// count); overlong sequences are truncated to `ctx` (the paper's
/// baseline setting), so every window's fill is ≤ `ctx`.
pub fn pack_sequences(seq_lens: &[u64], ctx: u64) -> Vec<Vec<u64>> {
    let mut caps: Vec<u64> = vec![]; // remaining capacity per window
    let mut windows: Vec<Vec<u64>> = vec![];
    for &l in seq_lens {
        let l = l.min(ctx);
        match caps.iter().position(|&cap| cap >= l) {
            Some(i) => {
                caps[i] -= l;
                windows[i].push(l);
            }
            None => {
                caps.push(ctx - l);
                windows.push(vec![l]);
            }
        }
    }
    windows
}

/// Length-interval bucketing (HotSPa / Hetu-A). `bounds` are the interval
/// upper edges, ascending (e.g. `[4K, 16K, 32K]`); returns per-bucket
/// sequence lists. A sequence above the top bound is truncated to it (the
/// baseline truncation rule, as in [`pack_sequences`]), so every bucket
/// honors its upper edge.
pub fn bucketize(seq_lens: &[u64], bounds: &[u64]) -> Vec<Vec<u64>> {
    let mut out: Vec<Vec<u64>> = vec![vec![]; bounds.len()];
    if bounds.is_empty() {
        return out;
    }
    for &l in seq_lens {
        match bounds.iter().position(|&hi| l <= hi) {
            Some(b) => out[b].push(l),
            None => out[bounds.len() - 1].push(*bounds.last().unwrap()),
        }
    }
    out
}

/// A pipeline's dispatch capacity description for Hetu-B.
#[derive(Clone, Copy, Debug)]
pub struct PipeClass {
    /// Maximum sequence length this pipeline can process (memory bound).
    pub max_seq: u64,
    /// Relative throughput in tokens/s (cost-model derived).
    pub tokens_per_s: f64,
}

/// Cost of one sequence on one pipeline class: attention makes long
/// sequences superlinearly costly, so weight by `l·(1 + l/8192)` as a
/// simple quadratic surrogate, divided by throughput.
fn seq_cost(l: u64, c: &PipeClass) -> f64 {
    l as f64 * (1.0 + l as f64 / 8192.0) / c.tokens_per_s
}

/// Hetu-B dispatch: assign each sequence to the pipeline minimizing the
/// resulting makespan (longest-processing-time greedy on the cost model),
/// respecting per-pipeline `max_seq`. Returns per-pipeline token loads in
/// the order of `classes`.
pub fn dispatch_hetu_b(seq_lens: &[u64], classes: &[PipeClass]) -> Vec<Vec<u64>> {
    let mut sorted: Vec<u64> = seq_lens.to_vec();
    sorted.sort_unstable_by(|a, b| b.cmp(a)); // longest first
    let mut loads = vec![0f64; classes.len()];
    let mut assign: Vec<Vec<u64>> = vec![vec![]; classes.len()];
    for l in sorted {
        // eligible pipelines
        let mut best: Option<(usize, f64)> = None;
        for (i, c) in classes.iter().enumerate() {
            if l > c.max_seq {
                continue;
            }
            let t = loads[i] + seq_cost(l, c);
            if best.map(|(_, bt)| t < bt).unwrap_or(true) {
                best = Some((i, t));
            }
        }
        // a sequence longer than every pipeline's max goes to the
        // largest-context pipeline (first on ties), *truncated* to its
        // context (the baseline rule) — the truncated length is both
        // charged and assigned, so the max_seq contract holds and later
        // LPT placement and token weighting see the processed tokens
        let (i, l, t) = match best {
            Some((i, t)) => (i, l, t),
            None => {
                let i = classes
                    .iter()
                    .enumerate()
                    .max_by(|(ia, a), (ib, b)| a.max_seq.cmp(&b.max_seq).then(ib.cmp(ia)))
                    .map(|(i, _)| i)
                    .unwrap_or(0);
                let trunc = l.min(classes[i].max_seq);
                (i, trunc, loads[i] + seq_cost(trunc, &classes[i]))
            }
        };
        loads[i] = t;
        assign[i].push(l);
    }
    assign
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testutil::check;

    #[test]
    fn commoncrawl_matches_97pct_under_8k() {
        let mut rng = Rng::new(42);
        let n = 20_000;
        let mut under = 0;
        for _ in 0..n {
            if Corpus::CommonCrawl.sample_len(&mut rng, 32768) < 8192 {
                under += 1;
            }
        }
        let frac = under as f64 / n as f64;
        assert!((0.95..0.99).contains(&frac), "P(len<8K) = {frac}");
    }

    #[test]
    fn github_has_heavier_tail() {
        let mut rng = Rng::new(7);
        let n = 20_000;
        let longs = |c: Corpus, rng: &mut Rng| {
            (0..n).filter(|_| c.sample_len(rng, 32768) > 8192).count()
        };
        let cc = longs(Corpus::CommonCrawl, &mut rng);
        let gh = longs(Corpus::GitHub, &mut rng);
        assert!(gh > cc, "github {gh} vs commoncrawl {cc} long sequences");
    }

    #[test]
    fn heavy_tail_dominates_github_beyond_8k() {
        // the Pareto mixture must (a) leave the body statistics close to
        // plain GitHub and (b) add ~5% of mass past 8K (every Pareto draw
        // starts at the 8K scale), roughly doubling the context-pinned
        // draws plain GitHub's log-normal produces
        let n = 20_000;
        let max = 32_768u64;
        let stats = |c: Corpus, seed: u64| {
            let mut rng = Rng::new(seed);
            let mut over_8k = 0usize;
            let mut at_max = 0usize;
            let mut under_2k = 0usize;
            for _ in 0..n {
                let l = c.sample_len(&mut rng, max);
                if l > 8192 {
                    over_8k += 1;
                }
                if l == max {
                    at_max += 1;
                }
                if l < 2048 {
                    under_2k += 1;
                }
            }
            (over_8k, at_max, under_2k)
        };
        let (gh_8k, gh_max, gh_body) = stats(Corpus::GitHub, 11);
        let (ht_8k, ht_max, ht_body) = stats(Corpus::GitHubHeavyTail, 11);
        // expected shift ≈ 0.046·n ≈ 920 draws; assert half of it to
        // leave room for sampling noise
        assert!(ht_8k > gh_8k + n / 50, "tail mass: heavy {ht_8k} vs github {gh_8k}");
        assert!(ht_max > gh_max + n / 200, "context-pinned draws: {ht_max} vs {gh_max}");
        // the body is still GitHub's log-normal: short-sequence mass moves
        // by at most the 5% mixture weight (plus sampling noise)
        let drift = (gh_body as f64 - ht_body as f64).abs() / n as f64;
        assert!(drift < 0.08, "body drifted by {drift}");
    }

    #[test]
    fn step_batch_hits_token_budget() {
        check("step batch budget", 50, |rng| {
            let b = sample_step(rng, Corpus::CommonCrawl, 200_000, 32768);
            // the budget invariant is exact: the final sequence is clamped
            // to the remaining budget, never padded back up
            if b.total_tokens != 200_000 {
                return Err(format!("budget missed: {}", b.total_tokens));
            }
            if b.seq_lens.iter().sum::<u64>() != b.total_tokens {
                return Err("total_tokens out of sync with seq_lens".into());
            }
            if b.seq_lens.iter().any(|&l| l == 0 || l > 32768) {
                return Err("sequence outside (0, max_len]".into());
            }
            Ok(())
        });
    }

    #[test]
    fn packing_is_tight_enough() {
        // packing n sequences of ctx/2 + eps each → about n bins of 2... use
        // exact: lengths ctx/2 pack two per bin.
        let lens = vec![16384u64; 10];
        let windows = pack_sequences(&lens, 32768);
        assert_eq!(windows.len(), 5);
        assert!(windows.iter().all(|w| w == &vec![16384u64, 16384]));
        // one overlong sequence truncates into one bin
        assert_eq!(pack_sequences(&[100_000], 32768), vec![vec![32768u64]]);
    }

    #[test]
    fn packing_lower_bound() {
        check("packing >= ceil(total/ctx)", 100, |rng| {
            let b = sample_step(rng, Corpus::GitHub, 100_000, 16384);
            let bins = pack_sequences(&b.seq_lens, 16384).len() as u64;
            let lb = b.seq_lens.iter().map(|&l| l.min(16384)).sum::<u64>().div_ceil(16384);
            if bins < lb {
                return Err(format!("bins {bins} < lower bound {lb}"));
            }
            Ok(())
        });
    }

    #[test]
    fn buckets_partition_sequences() {
        check("bucketize partition", 50, |rng| {
            let bounds = [4096u64, 16384, 32768];
            let b = sample_step(rng, Corpus::CommonCrawl, 100_000, 32768);
            let buckets = bucketize(&b.seq_lens, &bounds);
            let n: usize = buckets.iter().map(|v| v.len()).sum();
            if n != b.seq_lens.len() {
                return Err("lost sequences".into());
            }
            // the bucket invariant: every bucket honors its upper edge
            for (i, bucket) in buckets.iter().enumerate() {
                if let Some(&l) = bucket.iter().find(|&&l| l > bounds[i]) {
                    return Err(format!("bucket {i}: len {l} above bound {}", bounds[i]));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn bucketize_truncates_over_bound_sequences_to_top_edge() {
        // the old code dropped a 40K sequence into the top bucket at its
        // full length, violating that bucket's 32K upper edge; the
        // baseline rule truncates it instead
        let bounds = [4096u64, 16384, 32768];
        let buckets = bucketize(&[2000, 40_000, 33_000, 32_768], &bounds);
        assert_eq!(buckets[0], vec![2000]);
        assert!(buckets[1].is_empty());
        assert_eq!(buckets[2], vec![32_768, 32_768, 32_768]);
    }

    #[test]
    fn dispatch_respects_max_seq() {
        let classes = [
            PipeClass { max_seq: 32768, tokens_per_s: 1.0 },
            PipeClass { max_seq: 8192, tokens_per_s: 4.0 },
        ];
        let lens = vec![30000, 500, 900, 20000, 100, 8000];
        let assign = dispatch_hetu_b(&lens, &classes);
        assert!(assign[1].iter().all(|&l| l <= 8192));
        assert!(assign[0].contains(&30000) && assign[0].contains(&20000));
    }

    #[test]
    fn dispatch_overflow_falls_back_to_widest_truncated_with_cost() {
        // no pipeline can host 50K: it truncates onto the widest (index
        // 1, the first 16K entry on ties) — the assignment records the
        // truncated (processed) length, honoring the max_seq contract —
        // and its cost is charged, so the 8K sequences avoid it.
        let classes = [
            PipeClass { max_seq: 8192, tokens_per_s: 1.0 },
            PipeClass { max_seq: 16384, tokens_per_s: 1.0 },
            PipeClass { max_seq: 16384, tokens_per_s: 1.0 },
        ];
        let lens = vec![50_000, 8000, 8000];
        let assign = dispatch_hetu_b(&lens, &classes);
        assert_eq!(assign[1], vec![16_384]);
        for (seqs, c) in assign.iter().zip(classes.iter()) {
            assert!(seqs.iter().all(|&l| l <= c.max_seq));
        }
        assert!(assign[1].len() == 1 && assign[0].len() + assign[2].len() == 2);
    }

    #[test]
    fn dispatch_balances_load() {
        // two identical pipelines: loads should split roughly evenly
        let classes = [
            PipeClass { max_seq: 32768, tokens_per_s: 1.0 },
            PipeClass { max_seq: 32768, tokens_per_s: 1.0 },
        ];
        let mut rng = Rng::new(3);
        let b = sample_step(&mut rng, Corpus::CommonCrawl, 200_000, 32768);
        let assign = dispatch_hetu_b(&b.seq_lens, &classes);
        let t0: u64 = assign[0].iter().sum();
        let t1: u64 = assign[1].iter().sum();
        let ratio = t0.max(t1) as f64 / t0.min(t1).max(1) as f64;
        assert!(ratio < 1.5, "unbalanced dispatch: {t0} vs {t1}");
    }
}
