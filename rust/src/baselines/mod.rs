//! Baseline systems (§7 comparisons), reimplemented as strategy generators
//! + behavioural restrictions on the shared simulator.
//!
//! The paper's comparisons hinge on each baseline's *expressiveness
//! restrictions*, which we encode directly:
//!
//! * [`deepspeed`] — uniform DP×Ulysses-SP with ZeRO-3 (all-gather weights
//!   every layer); no heterogeneous sharding; checkpoint-restart on
//!   failures.
//! * [`megatron`] — uniform DP×TP×PP(×CP) with ZeRO-1; no heterogeneous
//!   sharding; checkpoint-restart on failures.
//! * [`hexiscale`] — heterogeneous pipelines, but GPipe-only scheduling,
//!   coarse-grained broadcast between stages, no ZeRO (§7.1-II).
//! * [`oobleck`] — elastic training via pre-defined pipeline templates;
//!   transitions by naïve model broadcasting (§7.2-II).
//! * [`hotspa`] — mixed-length training by switching between homogeneous
//!   strategies *within* a step, with gradient accumulation (§7.3).

pub mod deepspeed;
pub mod hexiscale;
pub mod hotspa;
pub mod megatron;
pub mod oobleck;
