//! DeepSpeed baseline: uniform DP × Ulysses-SP with ZeRO-3.
//!
//! Modeled analytically: every device processes `GBS/dp` samples (sequence
//! sharded `sp` ways inside each replica, all-to-all for attention), and
//! ZeRO-3 all-gathers the full parameters in both passes plus reduce-
//! scatters gradients. The slowest device bounds the step — on
//! heterogeneous clusters the H20s throttle everything, which is exactly
//! the paper's observation (§7.1-I).

use crate::cluster::Cluster;
use crate::costmodel::CostModel;

/// A DeepSpeed configuration row (Tables 4/6/9): `DP{dp}SP{sp}AC, bs{bs}`.
#[derive(Clone, Copy, Debug)]
pub struct DsConfig {
    /// Data-parallel degree (number of SP groups).
    pub dp: u32,
    /// Ulysses sequence-parallel degree within each replica.
    pub sp: u32,
    /// Micro-batch size.
    pub bs: u32,
    /// Activation checkpointing (all table rows use AC).
    pub ac: bool,
}

/// Per-step time of a DeepSpeed run over the first `dp*sp` alive ranks.
pub fn step_time(
    cluster: &Cluster,
    cm: &CostModel,
    cfg: DsConfig,
    global_batch: u64,
    seq_len: u64,
) -> f64 {
    let n = (cfg.dp * cfg.sp) as usize;
    let ranks = cluster.alive_ranks();
    let ranks = &ranks[..n.min(ranks.len())];
    // slowest participating device
    let dev = ranks
        .iter()
        .map(|&r| cluster.device(r).kind)
        .min_by(|a, b| a.bf16_tflops.partial_cmp(&b.bf16_tflops).unwrap())
        .expect("no devices");

    // compute: each replica handles GBS/dp samples; each member computes a
    // 1/sp sequence shard. AC triples the backward.
    let samples_per_replica = (global_batch as f64 / cfg.dp as f64).max(1.0);
    let tokens_per_dev = samples_per_replica * seq_len as f64 / cfg.sp as f64;
    let mut cm_ac = *cm;
    if cfg.ac {
        cm_ac.params.ac_recompute = 2.0;
    }
    let layers = cm.model.layers;
    let fwd = cm_ac.fwd_s(&dev, layers, tokens_per_dev as u64, seq_len, 1);
    let bwd = cm_ac.bwd_s(&dev, layers, tokens_per_dev as u64, seq_len, 1);

    // ZeRO-3 traffic: AG(params) on fwd + AG(params) on bwd + RS(grads),
    // each ~P·elem_bytes over the (slowest-link) group of all n devices.
    let p_bytes = (cm.model.params() as f64 * cm.params.elem_bytes) as u64;
    let zero3 = cluster.collective_s(ranks, p_bytes, false) * 3.0;

    // Ulysses all-to-all per layer (2 a2a fwd, 2 bwd) within each SP group:
    // payload tokens·h/sp per member.
    let sp_comm = if cfg.sp > 1 {
        let sp_group: Vec<u32> = ranks[..cfg.sp as usize].to_vec();
        let bytes = (tokens_per_dev * cm.model.hidden as f64 * cm.params.elem_bytes) as u64;
        4.0 * layers as f64 * cluster.collective_s(&sp_group, bytes, false)
    } else {
        0.0
    };

    fwd + bwd + zero3 + sp_comm
}

/// Table 4 rows — optimal DeepSpeed configs for the heterogeneous-cluster
/// experiments, keyed by (model, cluster).
pub fn table4(model: &str, h800: u32, h20: u32) -> Option<DsConfig> {
    let c = |dp, sp, bs| Some(DsConfig { dp, sp, bs, ac: true });
    match (model, h800, h20) {
        ("llama-32b", 16, 0) | ("llama-32b", 0, 16) => c(8, 2, 2),
        ("llama-32b", 16, 16) => c(16, 2, 2),
        ("llama-32b", 16, 24) => c(20, 2, 4),
        ("llama-32b", 16, 32) => c(24, 2, 1),
        ("llama-70b", 16, 16) => c(16, 2, 1),
        ("llama-70b", 16, 24) => c(20, 2, 2),
        ("llama-70b", 16, 32) => c(24, 2, 1),
        _ => None,
    }
}

/// Table 6 rows — elastic-training configs per cluster state C1–C7.
pub fn table6(config: &str) -> Option<DsConfig> {
    let c = |dp, sp, bs| Some(DsConfig { dp, sp, bs, ac: true });
    match config {
        "C1" => c(16, 2, 2),
        "C2" | "C3" => c(12, 2, 2),
        "C4" => c(24, 2, 1),
        "C5" => c(20, 2, 2),
        "C6" | "C7" => c(16, 2, 2),
        _ => None,
    }
}

/// Table 9 rows — mixed-length configs per context length (32 H20 GPUs).
pub fn table9(ctx: u64) -> Option<DsConfig> {
    match ctx {
        32768 => Some(DsConfig { dp: 4, sp: 8, bs: 1, ac: true }),
        16384 => Some(DsConfig { dp: 8, sp: 4, bs: 1, ac: true }),
        _ => None,
    }
}

/// Checkpoint-and-restart overhead on a reconfiguration (§7.2-I): write +
/// read the sharded checkpoint (params + optimizer states = 16 bytes/param
/// over a parallel filesystem) plus process restart and re-initialization.
pub fn restart_overhead_s(cm: &CostModel, fs_gbps: f64, init_s: f64) -> f64 {
    let ckpt_bytes = cm.model.params() as f64 * 16.0;
    2.0 * ckpt_bytes / (fs_gbps * 1e9) + init_s
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::costmodel::ModelCfg;

    #[test]
    fn hetero_cluster_is_throttled_by_h20() {
        let cm = CostModel::new(ModelCfg::llama_32b());
        let hetero = Cluster::h800_16_h20_16();
        let homo800 = Cluster::h800(16);
        let cfg = table4("llama-32b", 16, 16).unwrap();
        let cfg16 = table4("llama-32b", 16, 0).unwrap();
        let t_hetero = step_time(&hetero, &cm, cfg, 64, 4096);
        let t_homo = step_time(&homo800, &cm, cfg16, 64, 4096);
        // 32 mixed GPUs barely beat (or lose to) 16 pure H800s: the H20
        // compute floor dominates.
        assert!(t_hetero > t_homo * 0.5, "hetero {t_hetero} vs homo {t_homo}");
    }

    #[test]
    fn restart_overhead_is_large() {
        let cm = CostModel::new(ModelCfg::llama_32b());
        let t = restart_overhead_s(&cm, 5.0, 60.0);
        assert!(t > 100.0, "32B checkpoint restart should cost minutes: {t}");
    }

    #[test]
    fn table_rows_exist() {
        assert!(table4("llama-32b", 16, 32).is_some());
        assert!(table4("llama-70b", 16, 24).is_some());
        assert!(table6("C2").is_some());
        assert!(table9(32768).is_some());
        assert!(table9(1024).is_none());
    }

    #[test]
    fn sp_reduces_per_device_compute_not_total() {
        let cm = CostModel::new(ModelCfg::llama_32b());
        let c = Cluster::h20(32);
        let t_sp2 = step_time(&c, &cm, DsConfig { dp: 16, sp: 2, bs: 1, ac: true }, 64, 4096);
        let t_sp4 = step_time(&c, &cm, DsConfig { dp: 8, sp: 4, bs: 1, ac: true }, 64, 4096);
        // same device count; sp4 halves per-device tokens vs sp2 but adds
        // a2a — both within 2x of each other
        let ratio = t_sp2.max(t_sp4) / t_sp2.min(t_sp4);
        assert!(ratio < 2.0, "sp2 {t_sp2} vs sp4 {t_sp4}");
    }
}
