//! HotSPa baseline (§7.3) — and Hetu-A, which expresses the same plan.
//!
//! HotSPa pre-defines an optimal *homogeneous* strategy per sequence-length
//! interval (Table 10); within one training step it partitions the batch by
//! length, runs each bucket under its strategy sequentially (accumulating
//! gradients), and hot-switches weights between buckets. Hetu-A reproduces
//! exactly this plan through HSPMD annotations (the paper reports matching
//! performance), so both systems share this implementation; they differ
//! only in the switch planner handed to [`step_time`].

use crate::cluster::Cluster;
use crate::costmodel::CostModel;
use crate::data::{bucketize, StepBatch};
use crate::sim::simulate_step;
use crate::spec::schedule::ScheduleKind;
use crate::strategy::{uniform, ParallelStrategy};
use crate::Result;

/// One Table 10 row: a length interval and its uniform strategy.
#[derive(Clone, Copy, Debug)]
pub struct BucketCfg {
    /// Upper edge of the length interval (tokens).
    pub upper: u64,
    /// Data/tensor/pipeline degrees.
    pub dp: u32,
    /// Tensor parallel degree.
    pub tp: u32,
    /// Pipeline parallel degree.
    pub pp: u32,
}

/// Table 10 — interval strategies for a given context length (32 H20).
pub fn table10(ctx: u64) -> Vec<BucketCfg> {
    match ctx {
        32768 => vec![
            BucketCfg { upper: 4096, dp: 4, tp: 4, pp: 2 },
            BucketCfg { upper: 16384, dp: 2, tp: 8, pp: 2 },
            BucketCfg { upper: 32768, dp: 2, tp: 16, pp: 1 },
        ],
        16384 => vec![
            BucketCfg { upper: 4096, dp: 4, tp: 4, pp: 2 },
            BucketCfg { upper: 16384, dp: 2, tp: 8, pp: 2 },
        ],
        _ => panic!("no Table 10 row for ctx {ctx}"),
    }
}

/// The uniform strategy for one bucket, sized for `samples` packed
/// sequences of up to `upper` tokens.
pub fn bucket_strategy(
    cluster: &Cluster,
    cfg: BucketCfg,
    layers: u32,
    samples: u64,
) -> Result<ParallelStrategy> {
    let ranks = cluster.alive_ranks();
    uniform(
        &format!("hotspa-{}k", cfg.upper / 1024),
        &ranks,
        cfg.dp,
        cfg.tp,
        cfg.pp,
        layers,
        samples.max(cfg.dp as u64),
        1,
        cfg.upper,
        ScheduleKind::OneFOneB,
        true,
        false, // Table 10: ZeRO-1, no activation checkpointing
    )
}

/// Per-step time: sequential bucket execution + inter-bucket switches.
///
/// `switch_cost` gives the transition seconds between two bucket indices
/// (caller computes it once per pair via
/// [`crate::switch::plan_strategy_switch`] — fused for Hetu-A, unfused for
/// vanilla HotSPa).
pub fn step_time(
    cluster: &Cluster,
    cm: &CostModel,
    batch: &StepBatch,
    ctx: u64,
    switch_cost: &dyn Fn(usize, usize) -> f64,
) -> Result<f64> {
    let cfgs = table10(ctx);
    let bounds: Vec<u64> = cfgs.iter().map(|c| c.upper).collect();
    let buckets = bucketize(&batch.seq_lens, &bounds);
    let mut total = 0.0;
    let mut prev: Option<usize> = None;
    for (i, (cfg, seqs)) in cfgs.iter().zip(buckets.iter()).enumerate() {
        if seqs.is_empty() {
            continue;
        }
        // pack bucket sequences into upper-length windows
        let samples = crate::data::pack_sequences(seqs, cfg.upper).len() as u64;
        let s = bucket_strategy(cluster, *cfg, cm.model.layers, samples)?;
        total += simulate_step(cluster, cm, &s)?.step_s;
        if let Some(p) = prev {
            total += switch_cost(p, i);
        }
        prev = Some(i);
    }
    // switch back to the first bucket's strategy for the next step
    if let Some(p) = prev {
        if p != 0 {
            total += switch_cost(p, 0);
        }
    }
    Ok(total)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::costmodel::ModelCfg;
    use crate::data::{sample_step, Corpus};
    use crate::testutil::Rng;

    #[test]
    fn table10_shapes() {
        assert_eq!(table10(32768).len(), 3);
        assert_eq!(table10(16384).len(), 2);
        for c in table10(32768) {
            assert_eq!(c.dp * c.tp * c.pp, 32);
        }
    }

    #[test]
    fn bucketed_step_beats_packed_long_strategy() {
        // The §7.3 headline: with 97% of sequences short, dedicated short
        // strategies beat one long-sequence strategy even with switching.
        let cluster = Cluster::h20(32);
        let cm = CostModel::new(ModelCfg::llama_32b());
        let mut rng = Rng::new(11);
        let batch = sample_step(&mut rng, Corpus::CommonCrawl, 200_000, 32768);

        let t_hotspa = step_time(&cluster, &cm, &batch, 32768, &|_, _| 2.0).unwrap();

        // Megatron packed baseline: everything packed to 32K and run under
        // the long-sequence uniform strategy.
        let packed = crate::data::pack_sequences(&batch.seq_lens, 32768).len() as u64;
        let cfg = crate::baselines::megatron::table9(32768).unwrap();
        let s = crate::baselines::megatron::strategy(&cluster, cfg, 60, packed, 32768).unwrap();
        let t_packed = simulate_step(&cluster, &cm, &s).unwrap().step_s;
        assert!(
            t_hotspa < t_packed,
            "hotspa {t_hotspa:.2}s should beat packed megatron {t_packed:.2}s"
        );
    }

    #[test]
    fn empty_buckets_skip_switches() {
        let cluster = Cluster::h20(32);
        let cm = CostModel::new(ModelCfg::llama_32b());
        // all-short batch → only bucket 0 runs, zero switches
        let batch = StepBatch { seq_lens: vec![1000; 50], total_tokens: 50_000 };
        let calls = std::cell::Cell::new(0);
        let t = step_time(&cluster, &cm, &batch, 32768, &|_, _| {
            calls.set(calls.get() + 1);
            1.0
        })
        .unwrap();
        assert_eq!(calls.get(), 0);
        assert!(t > 0.0);
    }
}
