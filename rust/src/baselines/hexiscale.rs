//! HexiScale baseline (§7.1-II).
//!
//! HexiScale supports heterogeneous tensor-parallel grouping and
//! non-uniform pipeline layering, but: (1) only GPipe scheduling (its tight
//! coupling of expression and execution blocks 1F1B under non-uniform
//! partitioning), (2) coarse-grained broadcast for inter-stage activation
//! transfer, and (3) no ZeRO-series sharding. We model it as the Hetu
//! heterogeneous layout with those three handicaps applied.

use crate::cluster::Cluster;
use crate::costmodel::CostModel;
use crate::sim::{simulate_step_opts, SimOptions};
use crate::spec::schedule::ScheduleKind;
use crate::strategy::ParallelStrategy;
use crate::Result;

/// Transform a Hetu heterogeneous strategy into its HexiScale-expressible
/// counterpart: GPipe schedule, ZeRO off. Without ZeRO-1 the fp32 optimizer
/// states stay unsharded, so the memory budget forces activation
/// checkpointing (backward recompute) — the performance channel through
/// which the paper's "cannot support ZeRO-series partitioning" materializes.
pub fn restrict(hetu: &ParallelStrategy) -> ParallelStrategy {
    let mut s = hetu.clone();
    s.name = format!("hexiscale({})", hetu.name);
    s.schedule = ScheduleKind::GPipe;
    s.zero1 = false;
    s.ac = true;
    s
}

/// HexiScale's simulator options: activation transfer between stages is a
/// broadcast to every member of the destination TP group rather than a
/// sharded send (§7.1-II "coarse-grained broadcast"). With TP degree 4 this
/// is a 4× boundary penalty.
pub fn sim_options(typical_tp: u32) -> SimOptions {
    SimOptions { boundary_factor: typical_tp as f64 }
}

/// Per-step time of the restricted strategy.
pub fn step_time(cluster: &Cluster, cm: &CostModel, hetu: &ParallelStrategy) -> Result<f64> {
    let s = restrict(hetu);
    let tp = s.pipelines[0].stages[0].tp();
    Ok(simulate_step_opts(cluster, cm, &s, sim_options(tp))?.step_s)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::costmodel::ModelCfg;
    use crate::sim::simulate_step;
    use crate::strategy::tables;

    #[test]
    fn hexiscale_is_slower_than_hetu_same_layout() {
        let cluster = Cluster::h800_16_h20_16();
        let cm = CostModel::new(ModelCfg::llama_32b());
        let hetu = tables::hetu_32b_16h800_16h20();
        let t_hetu = simulate_step(&cluster, &cm, &hetu).unwrap().step_s;
        let t_hexi = step_time(&cluster, &cm, &hetu).unwrap();
        assert!(t_hexi > t_hetu, "hexiscale {t_hexi} must trail hetu {t_hetu}");
    }

    #[test]
    fn restriction_flips_schedule_and_zero() {
        let hetu = tables::hetu_32b_16h800_16h20();
        let r = restrict(&hetu);
        assert_eq!(r.schedule, ScheduleKind::GPipe);
        assert!(!r.zero1);
        assert_eq!(r.pipelines.len(), hetu.pipelines.len());
    }
}
