//! Oobleck baseline (§7.2-II).
//!
//! Oobleck provides restart-free elasticity through *pre-defined pipeline
//! templates*: the live GPU set must be covered by template instances, and
//! transitions re-instantiate templates with naïve model broadcasting.
//! Both restrictions cost performance: template granularity wastes GPUs
//! that don't fit a template, and the strategy space excludes asymmetric
//! stages (no C2-style 2-GPU/1-GPU tail).

use crate::cluster::Cluster;
use crate::costmodel::CostModel;
use crate::sim::simulate_step;
use crate::spec::schedule::ScheduleKind;
use crate::strategy::{ParallelStrategy, PipelineSpec, StageSpec};
use crate::{Error, Result};

/// A pipeline template: `gpus = tp × stages` per instance.
#[derive(Clone, Copy, Debug)]
pub struct Template {
    /// TP degree per stage.
    pub tp: u32,
    /// Stage count.
    pub stages: u32,
}

/// Oobleck's template set for the 32B model: 4-stage and 3-stage TP4
/// pipelines (16 / 12 GPUs per instance).
pub fn default_templates() -> Vec<Template> {
    vec![Template { tp: 4, stages: 4 }, Template { tp: 4, stages: 3 }]
}

/// Cover the alive GPUs with template instances (largest first), splitting
/// layers evenly per stage. GPUs that fit no template are *wasted* — the
/// core restriction the paper exploits.
pub fn strategy(
    cluster: &Cluster,
    templates: &[Template],
    layers: u32,
    global_batch: u64,
    seq_len: u64,
) -> Result<ParallelStrategy> {
    let alive = cluster.alive_ranks();
    let mut remaining: &[u32] = &alive;
    let mut pipelines: Vec<PipelineSpec> = vec![];
    let mut sorted: Vec<Template> = templates.to_vec();
    sorted.sort_by_key(|t| std::cmp::Reverse(t.tp * t.stages));
    while !remaining.is_empty() {
        let Some(t) = sorted.iter().find(|t| (t.tp * t.stages) as usize <= remaining.len()) else {
            break; // leftover GPUs wasted
        };
        let need = (t.tp * t.stages) as usize;
        let ranks = &remaining[..need];
        let mut stages = vec![];
        let mut l = 0u32;
        for s in 0..t.stages {
            let hi = layers * (s + 1) / t.stages;
            stages.push(StageSpec {
                ranks: ranks[(s * t.tp) as usize..((s + 1) * t.tp) as usize].to_vec(),
                layers: (l, hi),
            });
            l = hi;
        }
        pipelines.push(PipelineSpec { stages, num_microbatches: 1, microbatch_size: 1 });
        remaining = &remaining[need..];
    }
    if pipelines.is_empty() {
        return Err(Error::Strategy("no template fits the alive GPU set".into()));
    }
    // distribute the global batch over pipelines proportionally to GPU count
    let total_gpus: u64 = pipelines.iter().map(|p| p.ranks().len() as u64).sum();
    let mut assigned = 0u64;
    let np = pipelines.len();
    for (i, p) in pipelines.iter_mut().enumerate() {
        let share = if i + 1 == np {
            global_batch - assigned
        } else {
            (global_batch * p.ranks().len() as u64 / total_gpus).max(1)
        };
        assigned += share;
        p.num_microbatches = share.max(1) as u32;
        p.microbatch_size = 1;
    }
    Ok(ParallelStrategy {
        name: "oobleck".into(),
        pipelines,
        zero1: false, // fault tolerance requires unsharded optimizer states
        schedule: ScheduleKind::OneFOneB,
        seq_len,
        ac: false,
    })
}

/// Per-step time of the template strategy.
pub fn step_time(
    cluster: &Cluster,
    cm: &CostModel,
    global_batch: u64,
    seq_len: u64,
) -> Result<f64> {
    let s = strategy(cluster, &default_templates(), cm.model.layers, global_batch, seq_len)?;
    Ok(simulate_step(cluster, cm, &s)?.step_s)
}

/// Transition overhead: naïve broadcast of the full (bf16) model weights
/// from one surviving replica to all others, over the slowest link, plus
/// template re-instantiation.
pub fn transition_overhead_s(cluster: &Cluster, cm: &CostModel, instantiate_s: f64) -> f64 {
    let bytes = cm.model.params() as f64 * cm.params.elem_bytes;
    let alive = cluster.alive_ranks();
    let min_gbps = alive
        .iter()
        .flat_map(|&a| alive.iter().map(move |&b| (a, b)))
        .filter(|(a, b)| a != b)
        .map(|(a, b)| cluster.link_gbps(a, b))
        .fold(f64::INFINITY, f64::min);
    bytes / (min_gbps * 1e9) + instantiate_s
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::costmodel::ModelCfg;

    #[test]
    fn templates_waste_leftover_gpus() {
        let mut cluster = Cluster::h20(32);
        cluster.fail_gpu(31); // 31 left
        let s = strategy(&cluster, &default_templates(), 60, 64, 4096).unwrap();
        let used: usize = s.pipelines.iter().map(|p| p.ranks().len()).sum();
        assert!(used < 31, "templates (16/12 GPUs) cannot cover 31: used {used}");
        assert_eq!(used, 28); // 16 + 12
    }

    #[test]
    fn oobleck_slower_than_hetu_on_c2() {
        let mut cluster = Cluster::h20(32);
        cluster.fail_gpu(31);
        let cm = CostModel::new(ModelCfg::llama_32b());
        let t_oob = step_time(&cluster, &cm, 64, 4096).unwrap();
        let hetu = crate::strategy::tables::hetu_c2_31h20();
        let t_hetu = crate::sim::simulate_step(&cluster, &cm, &hetu).unwrap().step_s;
        assert!(t_oob > t_hetu, "oobleck {t_oob} vs hetu {t_hetu}");
    }

    #[test]
    fn broadcast_transition_is_expensive() {
        let cluster = Cluster::h20(32);
        let cm = CostModel::new(ModelCfg::llama_32b());
        let t = transition_overhead_s(&cluster, &cm, 10.0);
        assert!(t > 10.0);
    }

    #[test]
    fn batch_is_fully_distributed() {
        let cluster = Cluster::h20(32);
        let s = strategy(&cluster, &default_templates(), 60, 64, 4096).unwrap();
        assert_eq!(s.global_batch(), 64);
    }
}
