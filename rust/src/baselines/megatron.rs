//! Megatron baseline: uniform DP × TP × PP (× CP) with ZeRO-1.
//!
//! Strategies come straight from Tables 4/6/9; they run on the shared
//! simulator as [`crate::strategy::uniform`] layouts with contiguous rank
//! assignment — which is exactly why the H20 pipeline throttles the H800
//! one on heterogeneous clusters (uniform partitioning, §7.1-I).

use crate::cluster::Cluster;
use crate::costmodel::CostModel;
use crate::sim::simulate_step;
use crate::spec::schedule::ScheduleKind;
use crate::strategy::{uniform, ParallelStrategy};
use crate::Result;

/// A Megatron configuration row: `DP{dp}TP{tp}PP{pp}(CP{cp}), bs{bs}`.
#[derive(Clone, Copy, Debug)]
pub struct MgConfig {
    /// Data parallel degree.
    pub dp: u32,
    /// Tensor parallel degree.
    pub tp: u32,
    /// Pipeline parallel degree.
    pub pp: u32,
    /// Context parallel degree (sequence sharded; modeled as a TP-like
    /// multiplier on the group size with ring-attention comm).
    pub cp: u32,
    /// Micro-batch size.
    pub bs: u32,
}

/// Table 4 rows (heterogeneous clusters).
pub fn table4(model: &str, h800: u32, h20: u32) -> Option<MgConfig> {
    let c = |dp, tp, pp, bs| Some(MgConfig { dp, tp, pp, cp: 1, bs });
    match (model, h800, h20) {
        ("llama-32b", 16, 0) | ("llama-32b", 0, 16) => c(1, 4, 4, 1),
        ("llama-32b", 16, 16) => c(2, 4, 4, 2),
        ("llama-32b", 16, 24) => c(2, 4, 5, 2),
        ("llama-32b", 16, 32) => c(4, 4, 3, 2),
        ("llama-70b", 16, 16) => c(1, 8, 4, 1),
        ("llama-70b", 16, 24) => c(1, 8, 5, 1),
        ("llama-70b", 16, 32) => c(1, 8, 6, 1),
        _ => None,
    }
}

/// Table 6 rows (elastic training).
pub fn table6(config: &str) -> Option<MgConfig> {
    let c = |dp, tp, pp, bs| Some(MgConfig { dp, tp, pp, cp: 1, bs });
    match config {
        "C1" => c(2, 4, 4, 2),
        "C2" | "C3" => c(1, 4, 6, 1),
        "C4" => c(4, 4, 3, 2),
        "C5" => c(1, 8, 5, 1),
        "C6" | "C7" => c(2, 4, 4, 2),
        _ => None,
    }
}

/// Table 9 rows (mixed-length, 32 H20).
pub fn table9(ctx: u64) -> Option<MgConfig> {
    match ctx {
        32768 => Some(MgConfig { dp: 2, tp: 8, pp: 1, cp: 2, bs: 1 }),
        16384 => Some(MgConfig { dp: 1, tp: 8, pp: 4, cp: 1, bs: 1 }),
        _ => None,
    }
}

/// Build the uniform strategy over the first `dp·tp·pp·cp` alive ranks.
/// CP is folded into the TP group size for simulation (both shard the
/// per-layer work across the group with per-layer collectives).
pub fn strategy(
    cluster: &Cluster,
    cfg: MgConfig,
    layers: u32,
    global_batch: u64,
    seq_len: u64,
) -> Result<ParallelStrategy> {
    let ranks = cluster.alive_ranks();
    uniform(
        &format!("megatron-dp{}tp{}pp{}cp{}", cfg.dp, cfg.tp, cfg.pp, cfg.cp),
        &ranks,
        cfg.dp,
        cfg.tp * cfg.cp,
        cfg.pp,
        layers,
        global_batch,
        cfg.bs,
        seq_len,
        ScheduleKind::OneFOneB,
        true,
        false,
    )
}

/// Per-step time on the shared simulator.
pub fn step_time(
    cluster: &Cluster,
    cm: &CostModel,
    cfg: MgConfig,
    global_batch: u64,
    seq_len: u64,
) -> Result<f64> {
    let s = strategy(cluster, cfg, cm.model.layers, global_batch, seq_len)?;
    Ok(simulate_step(cluster, cm, &s)?.step_s)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::costmodel::ModelCfg;

    #[test]
    fn uniform_on_hetero_is_slower_than_homo_per_gpu() {
        let cm = CostModel::new(ModelCfg::llama_32b());
        // 16 H800 homo
        let homo = Cluster::h800(16);
        let t_homo = step_time(&homo, &cm, table4("llama-32b", 16, 0).unwrap(), 64, 4096).unwrap();
        // 32 mixed: uniform partitioning wastes the H800s
        let hetero = Cluster::h800_16_h20_16();
        let t_hetero =
            step_time(&hetero, &cm, table4("llama-32b", 16, 16).unwrap(), 64, 4096).unwrap();
        // doubling GPU count with uniform sharding gives much less than 2x
        assert!(
            t_hetero > t_homo * 0.6,
            "uniform megatron barely gains from slow extra GPUs: {t_homo} -> {t_hetero}"
        );
    }

    #[test]
    fn strategies_validate() {
        let c = Cluster::h800_16_h20_32();
        for (m, h8, h2) in [("llama-32b", 16u32, 16u32), ("llama-32b", 16, 32), ("llama-70b", 16, 32)] {
            let cfg = table4(m, h8, h2).unwrap();
            let layers = if m == "llama-32b" { 60 } else { 80 };
            let s = strategy(&c, cfg, layers, 64, 4096).unwrap();
            s.validate(layers).unwrap();
        }
    }

    #[test]
    fn elastic_c2_discards_partial_node() {
        // C2 (31 GPUs): Megatron can only use 24 (TP4PP6×DP1) — the
        // paper's uniform-partitioning penalty.
        let cfg = table6("C2").unwrap();
        assert_eq!(cfg.dp * cfg.tp * cfg.pp, 24);
    }
}
