//! §6 — Dynamic graph switching.
//!
//! Temporal heterogeneity (failures, shifting sequence-length mixes)
//! requires *changing* the parallel strategy at runtime. With §6.1 multiple
//! annotations, each strategy is an annotated view of the same user graph;
//! switching from strategy `a` to strategy `b` re-partitions every
//! parameter from its `a`-annotation to its `b`-annotation — planned here
//! as one §6.2 **fused BSR** over all weights.

use crate::comm::{plan_transition, Bandwidth, BsrOptions, FusedBsrPlan, TensorMove};
use crate::graph::{Binding, Graph, OpKind};
use crate::Result;

/// Per-message launch overhead used for transition-time estimates
/// (kernel-launch + handshake; NCCL-style p2p setup is ~10s of µs, we use a
/// conservative value that also covers message framing).
pub const LAUNCH_OVERHEAD_S: f64 = 50e-6;

/// Summary of one strategy transition (Fig 18-right, Table 2).
#[derive(Clone, Debug)]
pub struct SwitchReport {
    /// The fused (or per-tensor) BSR plan.
    pub plan: FusedBsrPlan,
    /// Total bytes on the wire.
    pub wire_bytes: u64,
    /// Number of send-receive launches.
    pub num_messages: usize,
    /// Estimated transition time (bottleneck sender, serialized links).
    pub est_seconds: f64,
}

/// Plan the weight re-partitioning for a strategy switch `from → to`.
///
/// * `fuse = true`, `opts.heuristics = true` — the paper's optimized planner;
/// * `fuse = false` — per-tensor planning (no cross-tensor balancing, one
///   message per slice);
/// * `opts.heuristics = false` — minimal-rank sender baseline.
pub fn plan_switch(
    g: &Graph,
    from: usize,
    to: usize,
    binding: &Binding,
    bw: &dyn Bandwidth,
    opts: BsrOptions,
    fuse: bool,
) -> Result<SwitchReport> {
    let moves = parameter_moves(g, from, to, binding)?;
    let plan = plan_transition(&moves, bw, opts, fuse)?;
    let wire_bytes = plan.wire_bytes();
    let num_messages = plan.num_messages();
    let est_seconds = plan.bottleneck_seconds(bw, LAUNCH_OVERHEAD_S);
    Ok(SwitchReport { plan, wire_bytes, num_messages, est_seconds })
}

/// Plan a switch between two [`crate::strategy::ParallelStrategy`]s
/// directly: every layer's weight bundle moves from its `from`-annotation
/// to its `to`-annotation (1-D geometry of `params_per_layer` elements,
/// TP-split along dim 0 — the layout the engine actually uses).
pub fn plan_strategy_switch(
    from: &crate::strategy::ParallelStrategy,
    to: &crate::strategy::ParallelStrategy,
    cm: &crate::costmodel::CostModel,
    bw: &dyn Bandwidth,
    opts: BsrOptions,
    fuse: bool,
) -> Result<SwitchReport> {
    plan_strategy_switch_avoiding(from, to, cm, bw, opts, fuse, &[])
}

/// [`plan_strategy_switch`] with failed devices excluded as *sources*:
/// a dead rank cannot send, so every source subgroup containing one is
/// dropped — its surviving DP replica(s) supply the weights. This is the
/// fault-tolerance contract of §7.2 (ZeRO-1 disabled so each weight shard
/// has at least one full replica outside any single failure domain);
/// errors if a weight has no surviving replica.
pub fn plan_strategy_switch_avoiding(
    from: &crate::strategy::ParallelStrategy,
    to: &crate::strategy::ParallelStrategy,
    cm: &crate::costmodel::CostModel,
    bw: &dyn Bandwidth,
    opts: BsrOptions,
    fuse: bool,
    dead: &[crate::hspmd::dg::Rank],
) -> Result<SwitchReport> {
    let layers = cm.model.layers;
    let mut moves = vec![];
    for l in 0..layers {
        let src = from.weight_annotation(l, 0)?;
        let dst = to.weight_annotation(l, 0)?;
        if src == dst && dead.is_empty() {
            continue;
        }
        moves.push(TensorMove {
            name: format!("layer{l}.weights"),
            src,
            dst,
            shape: vec![cm.model.params_per_layer()],
            elem_bytes: cm.params.elem_bytes as u64,
        });
    }
    let plan = crate::comm::fused::plan_transition_avoiding(&moves, bw, opts, fuse, dead)?;
    let wire_bytes = plan.wire_bytes();
    let num_messages = plan.num_messages();
    let est_seconds = plan.bottleneck_seconds(bw, LAUNCH_OVERHEAD_S);
    Ok(SwitchReport { plan, wire_bytes, num_messages, est_seconds })
}

/// Collect the [`TensorMove`]s of all parameters whose annotation changes
/// between the two strategies.
pub fn parameter_moves(
    g: &Graph,
    from: usize,
    to: usize,
    binding: &Binding,
) -> Result<Vec<TensorMove>> {
    let mut moves = vec![];
    for op in &g.ops {
        if !matches!(op.kind, OpKind::Parameter) {
            continue;
        }
        let t = &g.tensors[op.outputs[0]];
        let src = t.annotation(from).ok_or_else(|| {
            crate::Error::Graph(format!("parameter `{}` lacks strategy-{from} annotation", t.name))
        })?;
        let dst = t.annotation(to).ok_or_else(|| {
            crate::Error::Graph(format!("parameter `{}` lacks strategy-{to} annotation", t.name))
        })?;
        if src == dst {
            continue;
        }
        moves.push(TensorMove {
            name: t.name.clone(),
            src: src.clone(),
            dst: dst.clone(),
            shape: binding.shape(&t.shape)?,
            elem_bytes: t.dtype.bytes(),
        });
    }
    Ok(moves)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::UniformBandwidth;
    use crate::graph::{lits, DType};
    use crate::hspmd::{Annotation, DeviceGroup, DistStates};

    /// Two strategies over 4 devices: TP4 (all params split 4-way on dim 0)
    /// vs TP2×DP2 (split 2-way, duplicated on the other pair).
    fn two_strategy_graph(n_params: usize) -> Graph {
        let mut g = Graph::new(2);
        let tp4 = Annotation::spmd(DeviceGroup::range(0, 4), DistStates::split(0, 4)).unwrap();
        let tp2 = Annotation::spmd(
            DeviceGroup::range(0, 4),
            DistStates::new(&[(crate::hspmd::ds::DUPLICATE, 2), (0, 2)], &[-1, 0]).unwrap(),
        )
        .unwrap();
        for i in 0..n_params {
            g.parameter(&format!("w{i}"), lits(&[16, 8]), DType::F32, vec![tp4.clone(), tp2.clone()])
                .unwrap();
        }
        g
    }

    #[test]
    fn switch_plans_all_changed_params() {
        let g = two_strategy_graph(4);
        let rep = plan_switch(
            &g,
            0,
            1,
            &Binding::new(),
            &UniformBandwidth,
            BsrOptions::default(),
            true,
        )
        .unwrap();
        assert!(rep.wire_bytes > 0);
        assert!(rep.num_messages > 0);
        assert!(rep.est_seconds > 0.0);
    }

    #[test]
    fn unchanged_params_skip_movement() {
        let mut g = Graph::new(2);
        let a = Annotation::spmd(DeviceGroup::range(0, 2), DistStates::split(0, 2)).unwrap();
        g.parameter("w", lits(&[8]), DType::F32, vec![a.clone(), a]).unwrap();
        let moves = parameter_moves(&g, 0, 1, &Binding::new()).unwrap();
        assert!(moves.is_empty());
    }

    #[test]
    fn fused_beats_unfused_messages() {
        let g = two_strategy_graph(8);
        let fused = plan_switch(&g, 0, 1, &Binding::new(), &UniformBandwidth, BsrOptions::default(), true).unwrap();
        let unfused = plan_switch(&g, 0, 1, &Binding::new(), &UniformBandwidth, BsrOptions::default(), false).unwrap();
        assert_eq!(fused.wire_bytes, unfused.wire_bytes, "volume invariant");
        assert!(fused.num_messages <= unfused.num_messages);
        assert!(fused.est_seconds <= unfused.est_seconds);
    }

    #[test]
    fn reverse_switch_also_plans() {
        let g = two_strategy_graph(2);
        let fwd = plan_switch(&g, 0, 1, &Binding::new(), &UniformBandwidth, BsrOptions::default(), true).unwrap();
        let rev = plan_switch(&g, 1, 0, &Binding::new(), &UniformBandwidth, BsrOptions::default(), true).unwrap();
        // TP4→TP2×DP2 replicates (more bytes); reverse narrows (fewer)
        assert!(fwd.wire_bytes > 0 && rev.wire_bytes > 0);
    }
}
