//! Crate-wide error type.
//!
//! Hand-rolled `Display`/`Error` impls (the build image cannot fetch
//! `thiserror`; the derive would be the only use of proc macros in the
//! whole tree).

use std::fmt;

/// Unified error for all Hetu subsystems.
#[derive(Debug)]
pub enum Error {
    /// Invalid HSPMD annotation (ill-formed DS/DG/union).
    InvalidAnnotation(String),

    /// Communication resolution cannot handle the requested transformation
    /// (e.g. BSR over `Partial` tensors — unsupported by design, §4.3).
    UnsupportedComm(String),

    /// Annotation deduction failure (§5.2) — the user must insert a CommOp.
    Deduction(String),

    /// Symbolic-shape binding/verification failure (§5.5).
    SymbolicShape(String),

    /// Graph construction / topology errors.
    Graph(String),

    /// Strategy specification errors (rank/layer coverage, memory fit).
    Strategy(String),

    /// Runtime (PJRT / artifact) errors.
    Runtime(String),

    /// Engine execution errors (worker panic, channel closure, shape
    /// mismatch between artifacts and plan).
    Engine(String),

    /// Configuration / CLI errors.
    Config(String),

    /// I/O errors (artifact files, traces, reports).
    Io(std::io::Error),
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Error::InvalidAnnotation(m) => write!(f, "invalid annotation: {m}"),
            Error::UnsupportedComm(m) => write!(f, "unsupported communication: {m}"),
            Error::Deduction(m) => write!(f, "deduction error: {m}"),
            Error::SymbolicShape(m) => write!(f, "symbolic shape error: {m}"),
            Error::Graph(m) => write!(f, "graph error: {m}"),
            Error::Strategy(m) => write!(f, "strategy error: {m}"),
            Error::Runtime(m) => write!(f, "runtime error: {m}"),
            Error::Engine(m) => write!(f, "engine error: {m}"),
            Error::Config(m) => write!(f, "config error: {m}"),
            Error::Io(e) => write!(f, "io error: {e}"),
        }
    }
}

impl std::error::Error for Error {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            Error::Io(e) => Some(e),
            _ => None,
        }
    }
}

impl From<std::io::Error> for Error {
    fn from(e: std::io::Error) -> Self {
        Error::Io(e)
    }
}

impl Error {
    /// Shorthand constructor used throughout deduction code.
    pub fn ded(msg: impl Into<String>) -> Self {
        Error::Deduction(msg.into())
    }
}

/// Crate-wide result alias.
pub type Result<T> = std::result::Result<T, Error>;
