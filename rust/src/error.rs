//! Crate-wide error type.

use thiserror::Error;

/// Unified error for all Hetu subsystems.
#[derive(Error, Debug)]
pub enum Error {
    /// Invalid HSPMD annotation (ill-formed DS/DG/union).
    #[error("invalid annotation: {0}")]
    InvalidAnnotation(String),

    /// Communication resolution cannot handle the requested transformation
    /// (e.g. BSR over `Partial` tensors — unsupported by design, §4.3).
    #[error("unsupported communication: {0}")]
    UnsupportedComm(String),

    /// Annotation deduction failure (§5.2) — the user must insert a CommOp.
    #[error("deduction error: {0}")]
    Deduction(String),

    /// Symbolic-shape binding/verification failure (§5.5).
    #[error("symbolic shape error: {0}")]
    SymbolicShape(String),

    /// Graph construction / topology errors.
    #[error("graph error: {0}")]
    Graph(String),

    /// Strategy specification errors (rank/layer coverage, memory fit).
    #[error("strategy error: {0}")]
    Strategy(String),

    /// Runtime (PJRT / artifact) errors.
    #[error("runtime error: {0}")]
    Runtime(String),

    /// Engine execution errors (worker panic, channel closure, shape
    /// mismatch between artifacts and plan).
    #[error("engine error: {0}")]
    Engine(String),

    /// Configuration / CLI errors.
    #[error("config error: {0}")]
    Config(String),

    /// I/O errors (artifact files, traces, reports).
    #[error("io error: {0}")]
    Io(#[from] std::io::Error),
}

impl Error {
    /// Shorthand constructor used throughout deduction code.
    pub fn ded(msg: impl Into<String>) -> Self {
        Error::Deduction(msg.into())
    }
}

/// Crate-wide result alias.
pub type Result<T> = std::result::Result<T, Error>;
