//! The strategy pool: N instantiated strategies + the pairwise
//! switch-plan cache.
//!
//! HotSPa re-plans (and re-builds process groups for) every transition;
//! GSPMD's define-once/instantiate-many model points the other way — the
//! pool instantiates every strategy *once* ([`ShardLayout`]s precomputed
//! at construction) and caches the fused-BSR [`SwitchPlan`] per ordered
//! `(from, to, moments?)` triple, so steady-state A↔B oscillation (the
//! common Fig 16 cadence) never re-plans. Failover switches (`dead` set)
//! bypass the cache and re-plan fresh.
//!
//! Both caches are keyed by **entry index + scalar inputs** (tuples of
//! `usize`/enum/flag), never by tensor-key strings: the string↔id mapping
//! lives inside each pooled artifact's own
//! [`KeyInterner`](crate::engine::KeyInterner) (the `ShardLayout` and the
//! `CompiledProgram` each carry one), so pooling, sharing, and eviction
//! never touch per-key string state. Entries may be appended at runtime
//! ([`StrategyPool::add_entry`] — elastic re-synthesis proposes fresh
//! strategies for a degraded cluster); appending never invalidates the
//! index-keyed caches.

use std::collections::HashMap;
use std::sync::Arc;

use crate::comm::{Bandwidth, UniformBandwidth};
use crate::engine::{
    plan_switch, CompiledProgram, Engine, EngineStrategy, EngineSwitchReport, ShapeClass,
    ShardLayout, SwitchPlan,
};
use crate::runtime::ManifestConfig;
use crate::spec::schedule::ScheduleKind;
use crate::{Error, Result};

/// One pooled strategy: the lowered graph, its precomputed layout, and the
/// length bucket it serves.
#[derive(Clone, Debug)]
pub struct PoolEntry {
    /// The runnable strategy.
    pub strategy: EngineStrategy,
    /// Precomputed ownership/sync/update plans, shared (`Arc`) with every
    /// engine switched onto this entry — a hot switch hands the layout
    /// over by refcount, never by deep clone.
    pub layout: Arc<ShardLayout>,
    /// Bucket context: the longest sequence this strategy can host
    /// (memory-bound at paper scale; the dispatcher's eligibility rule).
    pub ctx: u64,
}

/// `(from, to, with_moments, topology_aware)` — the plan-cache key. The
/// last flag records whether the plan was built against a real topology
/// (bandwidth heuristic 2) or the uniform stand-in, so attaching a
/// topology after a plan was cached re-plans instead of silently
/// replaying uniform-bandwidth sender selection.
type PlanKey = (usize, usize, bool, bool);

/// `(entry, schedule, zero1, kernel-fusion, micro-batch shape class)` —
/// the compiled-artifact cache key (DESIGN.md §9). The entry index stands
/// in for `(strategy, layout)` (the pool instantiates each exactly once);
/// the rest are the inputs the compile pass freezes — kernel fusion
/// included, since a fused tape carries `FusedCall`s and workspace
/// reservations an unfused engine must not replay (DESIGN.md §12).
/// Anything else — notably an elastic `dead` set — is *not* an input: a
/// compiled tape names only the strategy's own ranks, so failover
/// recompiles can share cache entries with healthy engines without
/// pollution.
type ArtifactKey = (usize, ScheduleKind, bool, bool, ShapeClass);

/// A pool of instantiated strategies with a pairwise switch-plan cache.
/// Cached plans are `Arc`-shared: a cache hit hands the pooled allocation
/// out by refcount — no `SwitchPlan`/`FusedBsrPlan`/layout clones on the
/// steady-state switch path (the ROADMAP hot-switch constant factors).
pub struct StrategyPool {
    cfg: ManifestConfig,
    entries: Vec<PoolEntry>,
    plans: HashMap<PlanKey, Arc<SwitchPlan>>,
    hits: u64,
    misses: u64,
    /// Compiled MPMD step programs, cached alongside the switch plans so
    /// an A↔B oscillation re-dispatches frozen tapes instead of
    /// recompiling (the engine-local cache dies on every switch; this one
    /// survives, keyed per entry).
    artifacts: HashMap<ArtifactKey, Arc<CompiledProgram>>,
    artifact_hits: u64,
    artifact_misses: u64,
}

/// Same parallel topology (pipelines, stages, schedule) up to micro-batch
/// counts — the dispatcher retunes `num_microbatches` per step, so pool
/// membership must ignore it.
fn same_topology(a: &EngineStrategy, b: &EngineStrategy) -> bool {
    a.schedule == b.schedule
        && a.pipelines.len() == b.pipelines.len()
        && a.pipelines
            .iter()
            .zip(b.pipelines.iter())
            .all(|(pa, pb)| pa.stages == pb.stages)
}

impl StrategyPool {
    /// Build a pool: one [`ShardLayout`] per strategy, computed once.
    /// `entries` pairs each strategy with its bucket context.
    pub fn new(cfg: ManifestConfig, entries: Vec<(EngineStrategy, u64)>) -> Result<StrategyPool> {
        if entries.is_empty() {
            return Err(Error::Engine("StrategyPool: no strategies".into()));
        }
        let mut out = Vec::with_capacity(entries.len());
        for (strategy, ctx) in entries {
            let layout = Arc::new(ShardLayout::build(&cfg, &strategy)?);
            out.push(PoolEntry { strategy, layout, ctx });
        }
        Ok(StrategyPool {
            cfg,
            entries: out,
            plans: HashMap::new(),
            hits: 0,
            misses: 0,
            artifacts: HashMap::new(),
            artifact_hits: 0,
            artifact_misses: 0,
        })
    }

    /// Append a freshly synthesized strategy to the pool at runtime,
    /// instantiating its [`ShardLayout`] once like construction does.
    /// Returns the new entry's index. Existing plan/artifact cache
    /// entries stay valid — both caches key on entry indices, and
    /// appending never renumbers them. This is the elastic re-synthesis
    /// entry point: after a failover shrinks the usable cluster,
    /// [`crate::elastic::resynthesize`] searches a replacement strategy
    /// for the survivors and pools it here before switching onto it.
    pub fn add_entry(&mut self, strategy: EngineStrategy, ctx: u64) -> Result<usize> {
        let layout = Arc::new(ShardLayout::build(&self.cfg, &strategy)?);
        self.entries.push(PoolEntry { strategy, layout, ctx });
        Ok(self.entries.len() - 1)
    }

    /// Number of pooled strategies.
    pub fn len(&self) -> usize {
        self.entries.len()
    }

    /// True when the pool is empty (never: construction rejects it).
    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// A pooled entry.
    pub fn entry(&self, i: usize) -> &PoolEntry {
        &self.entries[i]
    }

    /// All entries.
    pub fn entries(&self) -> &[PoolEntry] {
        &self.entries
    }

    /// The model configuration every pooled strategy is lowered against
    /// (elastic re-synthesis lowers replacement strategies onto it).
    pub fn cfg(&self) -> &ManifestConfig {
        &self.cfg
    }

    /// Pool index whose topology matches `strategy`, if any.
    pub fn index_of(&self, strategy: &EngineStrategy) -> Option<usize> {
        self.entries.iter().position(|e| same_topology(&e.strategy, strategy))
    }

    /// Plan-cache hits so far (repeated transitions that skipped BSR
    /// planning).
    pub fn hits(&self) -> u64 {
        self.hits
    }

    /// Plan-cache misses so far (first-time transitions).
    pub fn misses(&self) -> u64 {
        self.misses
    }

    /// Drop every cached plan (counters keep running). The cache key
    /// records *whether* a plan was topology-aware, not which topology —
    /// callers that swap one attached `Cluster` for a different one
    /// mid-run must invalidate, or cached sender selection keeps
    /// optimizing for the old link bandwidths.
    pub fn clear_plans(&mut self) {
        self.plans.clear();
    }

    /// Artifact-cache hits so far (steps/switches that re-dispatched a
    /// pooled compiled program instead of recompiling).
    pub fn artifact_hits(&self) -> u64 {
        self.artifact_hits
    }

    /// Artifact-cache misses so far (first compile per key).
    pub fn artifact_misses(&self) -> u64 {
        self.artifact_misses
    }

    /// Drop every cached compiled program (counters keep running).
    pub fn clear_artifacts(&mut self) {
        self.artifacts.clear();
    }

    /// The pooled compiled MPMD program for `engine`'s current strategy,
    /// compiling on first use and installing it as the engine's cached
    /// artifact. Keyed by `(entry, schedule, zero1, kernel fusion, shape
    /// class)` — the exact inputs the compile pass freezes — so a hit is
    /// a refcount
    /// bump shared with every engine on the same key, and a hot switch
    /// back onto a previously-compiled entry skips the compile entirely
    /// even though the switch cleared the engine-local cache.
    ///
    /// Elastic recompiles cannot pollute this cache: a `dead` set is not
    /// a compile input (tapes name only the strategy's own ranks), so
    /// the program a failed-over engine compiles is byte-identical to a
    /// healthy engine's.
    pub fn compiled_for(&mut self, engine: &mut Engine) -> Result<Arc<CompiledProgram>> {
        let entry = self.index_of(&engine.strategy).ok_or_else(|| {
            Error::Engine(format!(
                "compiled_for: engine strategy `{}` is not in the pool",
                engine.strategy.name
            ))
        })?;
        let key = (
            entry,
            engine.strategy.schedule,
            engine.zero1,
            engine.fusion_active(),
            ShapeClass::of_engine(engine),
        );
        if let Some(p) = self.artifacts.get(&key) {
            let p = Arc::clone(p);
            // install re-validates schedule/zero1/counts/shape at the
            // boundary — the key logic and the program must agree
            engine.install_compiled(Arc::clone(&p))?;
            self.artifact_hits += 1;
            return Ok(p);
        }
        let p = engine.compiled_program_cached()?;
        self.artifacts.insert(key, Arc::clone(&p));
        self.artifact_misses += 1;
        Ok(p)
    }

    /// The cached plan for `from → to`, planning it on first use.
    /// `with_moments` selects whether `m.*`/`v.*` companions ride along;
    /// `topology_aware` must say whether `bw` is a real topology (both
    /// are part of the cache key — a pre-step-1 switch moves no moments,
    /// and a uniform-bandwidth plan must not be replayed once a topology
    /// is attached). Returns the pooled `Arc`: a hit is a refcount bump,
    /// not a plan clone.
    pub fn plan_for(
        &mut self,
        from: usize,
        to: usize,
        with_moments: bool,
        topology_aware: bool,
        bw: &dyn Bandwidth,
    ) -> Result<Arc<SwitchPlan>> {
        if from >= self.entries.len() || to >= self.entries.len() {
            return Err(Error::Engine(format!(
                "plan_for: {from}->{to} out of pool (len {})",
                self.entries.len()
            )));
        }
        if from == to {
            return Err(Error::Engine("plan_for: from == to".into()));
        }
        let key = (from, to, with_moments, topology_aware);
        if let Some(sp) = self.plans.get(&key) {
            self.hits += 1;
            return Ok(Arc::clone(sp));
        }
        let sp = Arc::new(plan_switch(
            &self.cfg,
            &self.entries[from].layout,
            &self.entries[to].layout,
            with_moments,
            bw,
            &[],
        )?);
        self.plans.insert(key, Arc::clone(&sp));
        self.misses += 1;
        Ok(sp)
    }

    /// Hot-switch a pool-managed engine to entry `to`, reusing the cached
    /// plan when this transition has run before. The engine's current
    /// strategy must match a pool entry (micro-batch counts ignored);
    /// sender selection uses the engine's attached topology, if any. On a
    /// cache hit nothing is deep-cloned: the plan and the target layout
    /// are both handed over by `Arc`.
    pub fn switch_engine(&mut self, engine: &mut Engine, to: usize) -> Result<EngineSwitchReport> {
        self.switch_engine_avoiding(engine, to, &[])
    }

    /// Pool-aware elastic failover (§7.2 over cached transitions): switch
    /// a pool-managed engine to entry `to` with `dead` ranks excluded as
    /// weight sources. Two paths:
    ///
    /// * **cache reuse** — when the cached `from → to` plan never reads
    ///   from a dead rank (the failed rank held no *needed* shard — every
    ///   moved slice sources elsewhere), the pooled plan executes
    ///   untouched: a normal cache hit, allocation-free;
    /// * **re-plan** — when the cached plan references a dead sender, a
    ///   fresh fused-BSR plan is built with the dead ranks excluded
    ///   (surviving replicas cover their slices or planning errors out)
    ///   and executed *without* touching the cache, so the pooled
    ///   full-membership plan survives for post-repair switches.
    ///
    /// With an empty `dead` this is exactly [`StrategyPool::switch_engine`].
    pub fn switch_engine_avoiding(
        &mut self,
        engine: &mut Engine,
        to: usize,
        dead: &[usize],
    ) -> Result<EngineSwitchReport> {
        let from = self.index_of(&engine.strategy).ok_or_else(|| {
            Error::Engine(format!(
                "switch_engine: engine strategy `{}` is not in the pool",
                engine.strategy.name
            ))
        })?;
        if from == to {
            return Err(Error::Engine(format!("switch_engine: already on entry {to}")));
        }
        // the same coverage guard switch_to_avoiding runs: a topology
        // that cannot host the target entry must be a typed error, not
        // an index panic inside the bandwidth callbacks
        engine.require_topology_coverage(
            self.entries[to].strategy.max_device_bound().max(engine.mesh.devices.len()),
        )?;
        let with_moments = engine.has_moments();
        let topology_aware = engine.topology.is_some();
        let bw: &dyn Bandwidth = match &engine.topology {
            Some(c) => c,
            None => &UniformBandwidth,
        };
        let sp = self.plan_for(from, to, with_moments, topology_aware, bw)?;
        let needs_replan = !dead.is_empty()
            && sp.plan.messages.iter().any(|m| dead.contains(&(m.from as usize)));
        let entry = &self.entries[to];
        if needs_replan {
            // the failed rank holds a needed shard: re-plan this one
            // transition with dead senders excluded, cache untouched
            let fresh = plan_switch(
                &self.cfg,
                &engine.layout,
                &entry.layout,
                with_moments,
                bw,
                dead,
            )?;
            return engine.switch_to_planned_avoiding(
                entry.strategy.clone(),
                Arc::clone(&entry.layout),
                &fresh,
                dead,
            );
        }
        engine.switch_to_planned_avoiding(
            entry.strategy.clone(),
            Arc::clone(&entry.layout),
            &sp,
            dead,
        )
    }

    /// Spawn an engine on entry `i` (convenience for tests/benches).
    pub fn spawn_engine(
        &self,
        runtime: crate::runtime::Runtime,
        i: usize,
        seed: u64,
        lr: f32,
    ) -> Result<Engine> {
        Engine::with_runtime(runtime, self.entries[i].strategy.clone(), seed, lr)
    }

    /// Spawn an engine on entry `i` running the concurrent OS-thread
    /// executor ([`crate::engine::ExecMode::Threaded`]). Hot switches and
    /// cached plans work unchanged — the executor choice only affects how
    /// a step's `RankPlan`s are driven, never what they compute (losses
    /// stay bit-identical, see [`crate::engine::thread`]).
    pub fn spawn_engine_threaded(
        &self,
        runtime: crate::runtime::Runtime,
        i: usize,
        seed: u64,
        lr: f32,
    ) -> Result<Engine> {
        let mut eng = self.spawn_engine(runtime, i, seed, lr)?;
        eng.set_exec_mode(crate::engine::ExecMode::Threaded);
        Ok(eng)
    }

    /// Spawn an engine on entry `i` replaying compiled tapes
    /// ([`crate::engine::ExecMode::Compiled`]); pair with
    /// [`StrategyPool::compiled_for`] after each switch to dispatch
    /// pooled artifacts instead of recompiling.
    pub fn spawn_engine_compiled(
        &self,
        runtime: crate::runtime::Runtime,
        i: usize,
        seed: u64,
        lr: f32,
    ) -> Result<Engine> {
        let mut eng = self.spawn_engine(runtime, i, seed, lr)?;
        eng.set_exec_mode(crate::engine::ExecMode::Compiled);
        Ok(eng)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::native;

    fn tiny_pool() -> StrategyPool {
        let cfg = native::tiny_config();
        StrategyPool::new(
            cfg,
            vec![
                (EngineStrategy::uniform("dp2", 2, 1, 1, 8, 1), 4096),
                (EngineStrategy::uniform("tp2", 1, 2, 1, 8, 2), 32768),
            ],
        )
        .unwrap()
    }

    #[test]
    fn plan_cache_hits_on_repeated_transitions() {
        let mut pool = tiny_pool();
        let m1 =
            pool.plan_for(0, 1, false, false, &UniformBandwidth).unwrap().plan.num_messages();
        assert_eq!((pool.hits(), pool.misses()), (0, 1));
        let m2 =
            pool.plan_for(0, 1, false, false, &UniformBandwidth).unwrap().plan.num_messages();
        assert_eq!((pool.hits(), pool.misses()), (1, 1));
        assert_eq!(m1, m2);
        // reverse direction, the with-moments variant, and the
        // topology-aware variant are all distinct cache entries
        pool.plan_for(1, 0, false, false, &UniformBandwidth).unwrap();
        pool.plan_for(0, 1, true, false, &UniformBandwidth).unwrap();
        pool.plan_for(0, 1, false, true, &UniformBandwidth).unwrap();
        assert_eq!((pool.hits(), pool.misses()), (1, 4));
    }

    #[test]
    fn clear_plans_forces_replanning() {
        let mut pool = tiny_pool();
        pool.plan_for(0, 1, false, false, &UniformBandwidth).unwrap();
        pool.clear_plans();
        pool.plan_for(0, 1, false, false, &UniformBandwidth).unwrap();
        assert_eq!((pool.hits(), pool.misses()), (0, 2));
    }

    #[test]
    fn plan_for_rejects_degenerate_keys() {
        let mut pool = tiny_pool();
        assert!(pool.plan_for(0, 0, false, false, &UniformBandwidth).is_err());
        assert!(pool.plan_for(0, 7, false, false, &UniformBandwidth).is_err());
    }

    #[test]
    fn cache_hits_share_the_pooled_plan_allocation() {
        // both the plan and the executing reports must point at the SAME
        // FusedBsrPlan allocation — the cache hit is a refcount bump
        let mut pool = tiny_pool();
        let p1 = pool.plan_for(0, 1, false, false, &UniformBandwidth).unwrap();
        let p2 = pool.plan_for(0, 1, false, false, &UniformBandwidth).unwrap();
        assert!(Arc::ptr_eq(&p1, &p2), "cache hit must hand out the pooled Arc");

        let mut eng = pool
            .spawn_engine(crate::runtime::Runtime::native(native::tiny_config()), 0, 42, 1e-3)
            .unwrap();
        let r1 = pool.switch_engine(&mut eng, 1).unwrap();
        let r2 = pool.switch_engine(&mut eng, 0).unwrap();
        let r3 = pool.switch_engine(&mut eng, 1).unwrap();
        assert!(Arc::ptr_eq(&r1.plan, &r3.plan), "repeated A→B reports share one plan");
        assert!(!Arc::ptr_eq(&r1.plan, &r2.plan), "opposite directions are distinct plans");
        assert_eq!(r1.plan_messages, r1.plan.num_messages() as u64);
        assert_eq!(r1.plan_wire_bytes, r1.plan.wire_bytes());
        // the engine's layout is the pooled entry's layout, not a clone
        assert!(Arc::ptr_eq(&eng.layout, &pool.entry(1).layout));
    }

    #[test]
    fn pool_failover_reuses_cache_when_dead_holds_no_needed_shard() {
        // dp3 → dp2: every destination shard is locally owned (heuristic
        // 1), so rank 2 is never a needed sender — the cached plan must
        // execute untouched under `dead = [2]`, as a plain cache hit.
        let cfg = native::tiny_config();
        let mut pool = StrategyPool::new(
            cfg,
            vec![
                (EngineStrategy::uniform("dp3", 3, 1, 1, 8, 1), 4096),
                (EngineStrategy::uniform("dp2", 2, 1, 1, 8, 1), 8192),
            ],
        )
        .unwrap();
        let mut eng = pool
            .spawn_engine(crate::runtime::Runtime::native(cfg), 0, 42, 1e-3)
            .unwrap();
        let mut corpus = crate::coordinator::SyntheticCorpus::new(3, cfg.vocab);
        let (b, s) = (cfg.batch, cfg.seq);
        eng.train_step(&mut |_p, _m| corpus.microbatch(b, s)).unwrap(); // moments exist
        let healthy = pool.plan_for(0, 1, true, false, &UniformBandwidth).unwrap();
        assert!(
            healthy.plan.messages.iter().all(|m| m.from != 2),
            "dp3→dp2 sources everything locally; rank 2 holds no needed shard"
        );
        let (h0, m0) = (pool.hits(), pool.misses());
        let rep = crate::elastic::pool_failover(&mut pool, &mut eng, 1, &[2]).unwrap();
        assert!(Arc::ptr_eq(&rep.plan, &healthy.plan), "cache reused by refcount");
        assert_eq!((pool.hits(), pool.misses()), (h0 + 1, m0), "reuse is a plain hit");
        assert!(
            eng.mesh.devices[2].keys().is_empty(),
            "dead rank evicted: {:?}",
            eng.mesh.devices[2].keys()
        );
        // survivors re-specialize and keep training
        let stats = eng.train_step(&mut |_p, _m| corpus.microbatch(b, s)).unwrap();
        assert!(stats.loss.is_finite());
    }

    #[test]
    fn pool_failover_replans_when_cached_plan_reads_dead_sender() {
        use crate::engine::{EnginePipeline, EngineStage};
        use crate::spec::schedule::ScheduleKind;
        // dp2 {0,1} → tp2 {2,3}: the destinations own nothing, so load
        // balancing makes both survivors senders of the healthy plan;
        // killing rank 1 forces a fresh dead-excluding plan while the
        // cache keeps the full-membership one.
        let cfg = native::tiny_config();
        let far = EngineStrategy {
            name: "tp2-far".into(),
            pipelines: vec![EnginePipeline {
                stages: vec![EngineStage { devices: vec![2, 3], layers: (0, 8) }],
                num_microbatches: 2,
            }],
            schedule: ScheduleKind::GPipe,
        };
        let mut pool = StrategyPool::new(
            cfg,
            vec![
                (EngineStrategy::uniform("dp2", 2, 1, 1, 8, 1), 4096),
                (far, 32768),
            ],
        )
        .unwrap();
        let mut eng = pool
            .spawn_engine(crate::runtime::Runtime::native(cfg), 0, 42, 1e-3)
            .unwrap();
        let healthy = pool.plan_for(0, 1, false, false, &UniformBandwidth).unwrap();
        assert!(
            healthy.plan.messages.iter().any(|m| m.from == 1),
            "load balancing makes rank 1 a needed sender of the healthy plan"
        );
        // executing the dead-referencing plan directly is a typed error
        let mut eng2 = pool
            .spawn_engine(crate::runtime::Runtime::native(cfg), 0, 43, 1e-3)
            .unwrap();
        assert!(eng2
            .switch_to_planned_avoiding(
                pool.entry(1).strategy.clone(),
                Arc::clone(&pool.entry(1).layout),
                &healthy,
                &[1],
            )
            .is_err());

        let (h0, m0) = (pool.hits(), pool.misses());
        let rep = crate::elastic::pool_failover(&mut pool, &mut eng, 1, &[1]).unwrap();
        assert!(
            !Arc::ptr_eq(&rep.plan, &healthy.plan),
            "failover must not execute the dead-referencing plan"
        );
        assert!(
            rep.plan.messages.iter().all(|m| m.from == 0),
            "every slice re-sourced from the survivor"
        );
        assert!(rep.wire_elems > 0);
        // the fresh plan did not pollute the cache: the lookup was a hit
        // and the pooled Arc is still the healthy full-membership plan
        assert_eq!((pool.hits(), pool.misses()), (h0 + 1, m0));
        let again = pool.plan_for(0, 1, false, false, &UniformBandwidth).unwrap();
        assert!(Arc::ptr_eq(&again, &healthy), "cache untouched for post-repair switches");
        assert!(eng.mesh.devices[1].keys().is_empty(), "dead rank evicted");
    }

    #[test]
    fn threaded_engine_survives_hot_switch_cycle_bit_identically() {
        // the executor choice is orthogonal to the pool: a threaded
        // engine hot-switches through cached plans and lands on the same
        // losses, wire counters, and token counts as its event-driven twin
        let cfg = native::tiny_config();
        let rt = crate::runtime::Runtime::native;
        let mut pool = tiny_pool();
        let mut ev = pool.spawn_engine(rt(cfg), 0, 42, 1e-3).unwrap();
        let mut th = pool.spawn_engine_threaded(rt(cfg), 0, 42, 1e-3).unwrap();
        let (b, s) = (cfg.batch, cfg.seq);
        let mut step = |eng: &mut Engine, seed: u64| {
            let mut corpus = crate::coordinator::SyntheticCorpus::new(seed, cfg.vocab);
            eng.train_step(&mut |_p, _m| corpus.microbatch(b, s)).unwrap()
        };
        for (salt, entry) in [(3u64, 1usize), (4, 0), (5, 1)] {
            let a = step(&mut ev, salt);
            let bst = step(&mut th, salt);
            assert_eq!(a.loss.to_bits(), bst.loss.to_bits(), "salt {salt}");
            assert_eq!(a.tokens, bst.tokens);
            pool.switch_engine(&mut ev, entry).unwrap();
            pool.switch_engine(&mut th, entry).unwrap();
        }
    }

    #[test]
    fn artifact_cache_hits_share_the_pooled_program() {
        // repeated lookups on one key hand out the SAME CompiledProgram
        // allocation — the hit is a refcount bump, not a recompile
        let cfg = native::tiny_config();
        let mut pool = tiny_pool();
        let mut eng = pool.spawn_engine(crate::runtime::Runtime::native(cfg), 0, 42, 1e-3).unwrap();
        let p1 = pool.compiled_for(&mut eng).unwrap();
        assert_eq!((pool.artifact_hits(), pool.artifact_misses()), (0, 1));
        let p2 = pool.compiled_for(&mut eng).unwrap();
        assert!(Arc::ptr_eq(&p1, &p2), "artifact hit must hand out the pooled Arc");
        assert_eq!((pool.artifact_hits(), pool.artifact_misses()), (1, 1));
        // the engine's cached artifact IS the pooled one
        assert!(Arc::ptr_eq(eng.compiled_cached().unwrap(), &p1));
        // a second engine on the same entry shares it too
        let mut eng2 =
            pool.spawn_engine(crate::runtime::Runtime::native(cfg), 0, 43, 1e-3).unwrap();
        let p3 = pool.compiled_for(&mut eng2).unwrap();
        assert!(Arc::ptr_eq(&p1, &p3), "same key across engines shares one program");
        assert_eq!((pool.artifact_hits(), pool.artifact_misses()), (2, 1));
        // clear forces a recompile
        pool.clear_artifacts();
        let p4 = pool.compiled_for(&mut eng).unwrap();
        assert!(!Arc::ptr_eq(&p1, &p4));
        assert_eq!((pool.artifact_hits(), pool.artifact_misses()), (2, 2));
    }

    #[test]
    fn artifacts_survive_switches_and_key_on_zero1() {
        // a switch invalidates the ENGINE-local artifact (the tape froze
        // that strategy's keys/endpoints) but the POOLED one survives for
        // the switch back; a ZeRO-1 toggle lands on a distinct key.
        let cfg = native::tiny_config();
        let mut pool = tiny_pool();
        let mut eng = pool.spawn_engine(crate::runtime::Runtime::native(cfg), 0, 42, 1e-3).unwrap();
        let p_a = pool.compiled_for(&mut eng).unwrap();

        pool.switch_engine(&mut eng, 1).unwrap();
        assert!(eng.compiled_cached().is_none(), "switch clears the engine-local artifact");
        let p_b = pool.compiled_for(&mut eng).unwrap();
        assert!(!Arc::ptr_eq(&p_a, &p_b));

        pool.switch_engine(&mut eng, 0).unwrap();
        assert!(eng.compiled_cached().is_none());
        let (h0, m0) = (pool.artifact_hits(), pool.artifact_misses());
        let p_a2 = pool.compiled_for(&mut eng).unwrap();
        assert!(Arc::ptr_eq(&p_a, &p_a2), "switch back re-dispatches the pooled tape");
        assert_eq!((pool.artifact_hits(), pool.artifact_misses()), (h0 + 1, m0));

        // ZeRO-1 on: engine cache cleared, pooled lookup is a distinct key
        eng.set_zero1(true).unwrap();
        assert!(eng.compiled_cached().is_none(), "zero1 toggle clears the artifact");
        let p_z = pool.compiled_for(&mut eng).unwrap();
        assert!(!Arc::ptr_eq(&p_a, &p_z), "zero1 is part of the artifact key");
        assert!(p_z.zero1 && !p_a.zero1);
    }

    #[test]
    fn failover_recompiles_do_not_pollute_artifact_cache() {
        // a failed-over engine's compiled program is keyed (and built)
        // without any notion of the dead set — tapes name only the
        // strategy's own ranks — so a healthy engine landing on the same
        // entry shares the exact same pooled program and still trains
        // bit-identically to the reference interpreter.
        let cfg = native::tiny_config();
        let mut pool = StrategyPool::new(
            cfg,
            vec![
                (EngineStrategy::uniform("dp3", 3, 1, 1, 8, 1), 4096),
                (EngineStrategy::uniform("dp2", 2, 1, 1, 8, 1), 8192),
            ],
        )
        .unwrap();
        let mut eng = pool.spawn_engine(crate::runtime::Runtime::native(cfg), 0, 42, 1e-3).unwrap();
        let mut corpus = crate::coordinator::SyntheticCorpus::new(3, cfg.vocab);
        let (b, s) = (cfg.batch, cfg.seq);
        eng.train_step(&mut |_p, _m| corpus.microbatch(b, s)).unwrap();
        crate::elastic::pool_failover(&mut pool, &mut eng, 1, &[2]).unwrap();
        let p_failover = pool.compiled_for(&mut eng).unwrap();
        assert_eq!((pool.artifact_hits(), pool.artifact_misses()), (0, 1));

        // a fresh healthy engine on the same entry: plain hit, same Arc
        let mut healthy =
            pool.spawn_engine_compiled(crate::runtime::Runtime::native(cfg), 1, 7, 1e-3).unwrap();
        let p_healthy = pool.compiled_for(&mut healthy).unwrap();
        assert!(
            Arc::ptr_eq(&p_failover, &p_healthy),
            "failover recompile and healthy compile share one pooled program"
        );
        assert_eq!((pool.artifact_hits(), pool.artifact_misses()), (1, 1));

        // and the shared tape trains the healthy engine bit-identically
        let mut refr = pool.spawn_engine(crate::runtime::Runtime::native(cfg), 1, 7, 1e-3).unwrap();
        let mut c1 = crate::coordinator::SyntheticCorpus::new(11, cfg.vocab);
        let mut c2 = crate::coordinator::SyntheticCorpus::new(11, cfg.vocab);
        let a = healthy.train_step(&mut |_p, _m| c1.microbatch(b, s)).unwrap();
        let r = refr.train_step_reference(&mut |_p, _m| c2.microbatch(b, s)).unwrap();
        assert_eq!(a.loss.to_bits(), r.loss.to_bits(), "compiled loss bits diverge");
        assert_eq!(a.wire_elems, r.wire_elems);
    }

    #[test]
    fn pooled_artifacts_carry_the_kernel_level_plan() {
        // the pooled program is the FULL compiled artifact — the fused
        // call table and per-rank workspace reservations ride along, so
        // a cache hit re-dispatches zero-alloc fused replay with no
        // kernel-level rework; a fusion-off engine lands on a distinct
        // key (its tape must carry no FusedCalls to replay)
        let cfg = native::tiny_config();
        let mut pool = tiny_pool();
        let mut eng = pool.spawn_engine(crate::runtime::Runtime::native(cfg), 0, 42, 1e-3).unwrap();
        let p = pool.compiled_for(&mut eng).unwrap();
        assert!(p.fused_kernels, "native engines fuse by default");
        assert_eq!(p.fused.len(), p.ops.len());
        assert!(
            p.fused.iter().any(|f| f.is_some()),
            "dp2 block GEMMs must lower to fused calls"
        );
        assert!(
            (0..2).all(|d| p.ws_plan.floats_for(d) > 0),
            "both dp ranks run blocks and need workspace"
        );

        // fusion off: engine-local cache cleared, pooled lookup is a miss
        // on its own key, and the unfused tape is genuinely unfused
        eng.set_kernel_fusion(false);
        assert!(eng.compiled_cached().is_none(), "fusion toggle clears the artifact");
        let (h0, m0) = (pool.artifact_hits(), pool.artifact_misses());
        let p_off = pool.compiled_for(&mut eng).unwrap();
        assert_eq!((pool.artifact_hits(), pool.artifact_misses()), (h0, m0 + 1));
        assert!(!Arc::ptr_eq(&p, &p_off), "fusion is part of the artifact key");
        assert!(!p_off.fused_kernels);
        assert!(p_off.fused.iter().all(|f| f.is_none()));
        assert!(p_off.ws_plan.per_device_floats.iter().all(|&f| f == 0));

        // toggling back re-dispatches the pooled fused tape as a hit
        eng.set_kernel_fusion(true);
        let p2 = pool.compiled_for(&mut eng).unwrap();
        assert!(Arc::ptr_eq(&p, &p2), "fused key hit hands back the pooled tape");
        assert_eq!((pool.artifact_hits(), pool.artifact_misses()), (h0 + 1, m0 + 1));
    }

    #[test]
    fn index_matching_ignores_microbatch_counts() {
        let pool = tiny_pool();
        let mut probe = pool.entry(0).strategy.clone();
        probe.pipelines[0].num_microbatches = 17;
        assert_eq!(pool.index_of(&probe), Some(0));
        let other = EngineStrategy::uniform("pp2", 1, 1, 2, 8, 1);
        assert_eq!(pool.index_of(&other), None);
    }
}
