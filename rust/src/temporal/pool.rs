//! The strategy pool: N instantiated strategies + the pairwise
//! switch-plan cache.
//!
//! HotSPa re-plans (and re-builds process groups for) every transition;
//! GSPMD's define-once/instantiate-many model points the other way — the
//! pool instantiates every strategy *once* ([`ShardLayout`]s precomputed
//! at construction) and caches the fused-BSR [`SwitchPlan`] per ordered
//! `(from, to, moments?)` triple, so steady-state A↔B oscillation (the
//! common Fig 16 cadence) never re-plans. Failover switches (`dead` set)
//! bypass the cache and re-plan fresh.

use std::collections::HashMap;

use crate::comm::{Bandwidth, UniformBandwidth};
use crate::engine::{
    plan_switch, Engine, EngineStrategy, EngineSwitchReport, ShardLayout, SwitchPlan,
};
use crate::runtime::ManifestConfig;
use crate::{Error, Result};

/// One pooled strategy: the lowered graph, its precomputed layout, and the
/// length bucket it serves.
#[derive(Clone, Debug)]
pub struct PoolEntry {
    /// The runnable strategy.
    pub strategy: EngineStrategy,
    /// Precomputed ownership/sync/update plans.
    pub layout: ShardLayout,
    /// Bucket context: the longest sequence this strategy can host
    /// (memory-bound at paper scale; the dispatcher's eligibility rule).
    pub ctx: u64,
}

/// `(from, to, with_moments, topology_aware)` — the plan-cache key. The
/// last flag records whether the plan was built against a real topology
/// (bandwidth heuristic 2) or the uniform stand-in, so attaching a
/// topology after a plan was cached re-plans instead of silently
/// replaying uniform-bandwidth sender selection.
type PlanKey = (usize, usize, bool, bool);

/// A pool of instantiated strategies with a pairwise switch-plan cache.
pub struct StrategyPool {
    cfg: ManifestConfig,
    entries: Vec<PoolEntry>,
    plans: HashMap<PlanKey, SwitchPlan>,
    hits: u64,
    misses: u64,
}

/// Same parallel topology (pipelines, stages, schedule) up to micro-batch
/// counts — the dispatcher retunes `num_microbatches` per step, so pool
/// membership must ignore it.
fn same_topology(a: &EngineStrategy, b: &EngineStrategy) -> bool {
    a.schedule == b.schedule
        && a.pipelines.len() == b.pipelines.len()
        && a.pipelines
            .iter()
            .zip(b.pipelines.iter())
            .all(|(pa, pb)| pa.stages == pb.stages)
}

impl StrategyPool {
    /// Build a pool: one [`ShardLayout`] per strategy, computed once.
    /// `entries` pairs each strategy with its bucket context.
    pub fn new(cfg: ManifestConfig, entries: Vec<(EngineStrategy, u64)>) -> Result<StrategyPool> {
        if entries.is_empty() {
            return Err(Error::Engine("StrategyPool: no strategies".into()));
        }
        let mut out = Vec::with_capacity(entries.len());
        for (strategy, ctx) in entries {
            let layout = ShardLayout::build(&cfg, &strategy)?;
            out.push(PoolEntry { strategy, layout, ctx });
        }
        Ok(StrategyPool { cfg, entries: out, plans: HashMap::new(), hits: 0, misses: 0 })
    }

    /// Number of pooled strategies.
    pub fn len(&self) -> usize {
        self.entries.len()
    }

    /// True when the pool is empty (never: construction rejects it).
    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// A pooled entry.
    pub fn entry(&self, i: usize) -> &PoolEntry {
        &self.entries[i]
    }

    /// All entries.
    pub fn entries(&self) -> &[PoolEntry] {
        &self.entries
    }

    /// Pool index whose topology matches `strategy`, if any.
    pub fn index_of(&self, strategy: &EngineStrategy) -> Option<usize> {
        self.entries.iter().position(|e| same_topology(&e.strategy, strategy))
    }

    /// Plan-cache hits so far (repeated transitions that skipped BSR
    /// planning).
    pub fn hits(&self) -> u64 {
        self.hits
    }

    /// Plan-cache misses so far (first-time transitions).
    pub fn misses(&self) -> u64 {
        self.misses
    }

    /// Drop every cached plan (counters keep running). The cache key
    /// records *whether* a plan was topology-aware, not which topology —
    /// callers that swap one attached `Cluster` for a different one
    /// mid-run must invalidate, or cached sender selection keeps
    /// optimizing for the old link bandwidths.
    pub fn clear_plans(&mut self) {
        self.plans.clear();
    }

    /// The cached plan for `from → to`, planning it on first use.
    /// `with_moments` selects whether `m.*`/`v.*` companions ride along;
    /// `topology_aware` must say whether `bw` is a real topology (both
    /// are part of the cache key — a pre-step-1 switch moves no moments,
    /// and a uniform-bandwidth plan must not be replayed once a topology
    /// is attached).
    pub fn plan_for(
        &mut self,
        from: usize,
        to: usize,
        with_moments: bool,
        topology_aware: bool,
        bw: &dyn Bandwidth,
    ) -> Result<&SwitchPlan> {
        if from >= self.entries.len() || to >= self.entries.len() {
            return Err(Error::Engine(format!(
                "plan_for: {from}->{to} out of pool (len {})",
                self.entries.len()
            )));
        }
        if from == to {
            return Err(Error::Engine("plan_for: from == to".into()));
        }
        let key = (from, to, with_moments, topology_aware);
        match self.plans.entry(key) {
            std::collections::hash_map::Entry::Occupied(_) => self.hits += 1,
            std::collections::hash_map::Entry::Vacant(v) => {
                v.insert(plan_switch(
                    &self.cfg,
                    &self.entries[from].layout,
                    &self.entries[to].layout,
                    with_moments,
                    bw,
                    &[],
                )?);
                self.misses += 1;
            }
        }
        Ok(&self.plans[&key])
    }

    /// Hot-switch a pool-managed engine to entry `to`, reusing the cached
    /// plan when this transition has run before. The engine's current
    /// strategy must match a pool entry (micro-batch counts ignored);
    /// sender selection uses the engine's attached topology, if any.
    pub fn switch_engine(&mut self, engine: &mut Engine, to: usize) -> Result<EngineSwitchReport> {
        let from = self.index_of(&engine.strategy).ok_or_else(|| {
            Error::Engine(format!(
                "switch_engine: engine strategy `{}` is not in the pool",
                engine.strategy.name
            ))
        })?;
        if from == to {
            return Err(Error::Engine(format!("switch_engine: already on entry {to}")));
        }
        // the same coverage guard switch_to_avoiding runs: a topology
        // that cannot host the target entry must be a typed error, not
        // an index panic inside the bandwidth callbacks
        engine.require_topology_coverage(
            self.entries[to].strategy.max_device_bound().max(engine.mesh.devices.len()),
        )?;
        let with_moments = engine.has_moments();
        let topology_aware = engine.topology.is_some();
        {
            let bw: &dyn Bandwidth = match &engine.topology {
                Some(c) => c,
                None => &UniformBandwidth,
            };
            self.plan_for(from, to, with_moments, topology_aware, bw)?;
        }
        let sp = &self.plans[&(from, to, with_moments, topology_aware)];
        let entry = &self.entries[to];
        engine.switch_to_planned(entry.strategy.clone(), entry.layout.clone(), sp)
    }

    /// Spawn an engine on entry `i` (convenience for tests/benches).
    pub fn spawn_engine(
        &self,
        runtime: crate::runtime::Runtime,
        i: usize,
        seed: u64,
        lr: f32,
    ) -> Result<Engine> {
        Engine::with_runtime(runtime, self.entries[i].strategy.clone(), seed, lr)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::native;

    fn tiny_pool() -> StrategyPool {
        let cfg = native::tiny_config();
        StrategyPool::new(
            cfg,
            vec![
                (EngineStrategy::uniform("dp2", 2, 1, 1, 8, 1), 4096),
                (EngineStrategy::uniform("tp2", 1, 2, 1, 8, 2), 32768),
            ],
        )
        .unwrap()
    }

    #[test]
    fn plan_cache_hits_on_repeated_transitions() {
        let mut pool = tiny_pool();
        let m1 =
            pool.plan_for(0, 1, false, false, &UniformBandwidth).unwrap().plan.num_messages();
        assert_eq!((pool.hits(), pool.misses()), (0, 1));
        let m2 =
            pool.plan_for(0, 1, false, false, &UniformBandwidth).unwrap().plan.num_messages();
        assert_eq!((pool.hits(), pool.misses()), (1, 1));
        assert_eq!(m1, m2);
        // reverse direction, the with-moments variant, and the
        // topology-aware variant are all distinct cache entries
        pool.plan_for(1, 0, false, false, &UniformBandwidth).unwrap();
        pool.plan_for(0, 1, true, false, &UniformBandwidth).unwrap();
        pool.plan_for(0, 1, false, true, &UniformBandwidth).unwrap();
        assert_eq!((pool.hits(), pool.misses()), (1, 4));
    }

    #[test]
    fn clear_plans_forces_replanning() {
        let mut pool = tiny_pool();
        pool.plan_for(0, 1, false, false, &UniformBandwidth).unwrap();
        pool.clear_plans();
        pool.plan_for(0, 1, false, false, &UniformBandwidth).unwrap();
        assert_eq!((pool.hits(), pool.misses()), (0, 2));
    }

    #[test]
    fn plan_for_rejects_degenerate_keys() {
        let mut pool = tiny_pool();
        assert!(pool.plan_for(0, 0, false, false, &UniformBandwidth).is_err());
        assert!(pool.plan_for(0, 7, false, false, &UniformBandwidth).is_err());
    }

    #[test]
    fn index_matching_ignores_microbatch_counts() {
        let pool = tiny_pool();
        let mut probe = pool.entry(0).strategy.clone();
        probe.pipelines[0].num_microbatches = 17;
        assert_eq!(pool.index_of(&probe), Some(0));
        let other = EngineStrategy::uniform("pp2", 1, 1, 2, 8, 1);
        assert_eq!(pool.index_of(&other), None);
    }
}
