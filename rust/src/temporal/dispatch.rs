//! Length-aware dispatch: StepBatch streams → strategy choice → real
//! packed windows → ragged engine steps.
//!
//! Two policies, mirroring the paper's §6 evaluation:
//!
//! * **Hetu-A** — bucketize: the batch's max sequence length selects the
//!   smallest bucket (pool entry `ctx`) that can host it;
//! * **Hetu-B** — cost-model dispatch: among eligible entries, minimize
//!   the paper-scale [`CostModel`] cost of processing the batch at that
//!   entry's context. Sequences pack first-fit into `ctx`-token windows
//!   and every window pays its *actual* fill — linear dense FLOPs plus
//!   the quadratic causal-attention term over the packed window length
//!   (cross-sequence attention, the packing baseline's semantics). A
//!   near-full long-context window is therefore quadratically more
//!   expensive than the same tokens split across short windows — which is
//!   exactly why running short data on a long-context strategy loses —
//!   while an underfilled window no longer pays padded context. Scores
//!   normalize by the entry's device parallelism, with hysteresis so the
//!   engine only leaves the incumbent when the win is clear.
//!
//! The chosen batch then becomes *real variable-shape micro-batches*
//! (§5.5 symbolic shapes at engine numerics — the context-window quota
//! stand-in is gone): [`dispatch_hetu_b`] splits the sequences over the
//! strategy's pipelines, each pipeline's share packs into `ctx`-token
//! windows, every window scales to `ceil(fill / cell_tokens)` engine
//! tokens, and equal-length windows group as rows of one ragged
//! [`WindowShape`] micro-batch handed to the engine via
//! [`Engine::set_microbatches`]. The engine's token-weighted gradient
//! sync keeps the uneven shapes and counts exact data parallelism, so
//! losses stay on one trajectory across switches.

use std::collections::BTreeSet;

use crate::coordinator::SyntheticCorpus;
use crate::costmodel::CostModel;
use crate::data::{dispatch_hetu_b, pack_sequences, PipeClass, StepBatch};
use crate::engine::{Engine, WindowShape};
use crate::obs::breakdown::StepBreakdown;
use crate::obs::calibrate::{strategy_comm_bytes, CalibratedProfile};
use crate::Result;

use super::overlap::SwitchOverlap;
use super::pool::{PoolEntry, StrategyPool};

/// Which §6 dispatch policy drives strategy selection.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DispatchPolicy {
    /// Length-interval bucketing (HotSPa-style, fused switches).
    HetuA,
    /// Cost-model dispatch with hysteresis.
    HetuB,
}

/// The length-aware dispatcher.
#[derive(Clone, Debug)]
pub struct Dispatcher {
    /// Selection policy.
    pub policy: DispatchPolicy,
    /// Paper-scale cost model driving Hetu-B selection.
    pub cm: CostModel,
    /// Paper-scale tokens one engine token cell stands for when scaling
    /// packed windows onto the tiny engine (default 2048: a full 32K
    /// window maps to the native tiny-48 compiled seq of 16 cells, so a
    /// window's engine cost tracks its true length).
    pub cell_tokens: u64,
    /// Maximum equal-length windows grouped as rows of one ragged engine
    /// micro-batch (default 2, the tiny compiled batch rows). Only
    /// equal-length windows share a micro-batch, so dispatcher-built
    /// steps execute zero padded positions.
    pub rows_per_mb: usize,
    /// Hetu-B hysteresis: switch only when the winner undercuts the
    /// incumbent by this fraction.
    pub hysteresis: f64,
    /// Span-calibrated step-time profile (DESIGN.md §10). `None` (the
    /// default) scores Hetu-B candidates on analytic FLOPs alone; `Some`
    /// scores `flops·s_per_flop + bytes·s_per_byte` per device, with both
    /// coefficients *measured* from a traced engine step
    /// ([`Dispatcher::calibrate_from_step`]) — the HAP-style measured
    /// profile in place of analytic constants.
    pub calibration: Option<CalibratedProfile>,
}

impl Dispatcher {
    /// Dispatcher with default scaling/hysteresis settings.
    pub fn new(cm: CostModel, policy: DispatchPolicy) -> Dispatcher {
        Dispatcher {
            policy,
            cm,
            cell_tokens: 2048,
            rows_per_mb: 2,
            hysteresis: 0.05,
            calibration: None,
        }
    }

    /// Install (or clear) a span-calibrated profile for Hetu-B scoring.
    pub fn set_calibration(&mut self, profile: Option<CalibratedProfile>) {
        self.calibration = profile;
    }

    /// Fit a [`CalibratedProfile`] by tracing one engine step on the
    /// engine's *current* pool entry and install it for subsequent
    /// [`Dispatcher::choose`] calls. The measured per-rank compute/comm
    /// seconds (summed over ranks) regress against the entry's analytic
    /// FLOP and byte volumes for the same batch, so the profile carries
    /// real executor timings into Hetu-B scoring. The engine's tracing
    /// flag is restored afterwards.
    pub fn calibrate_from_step(
        &mut self,
        engine: &mut Engine,
        pool: &StrategyPool,
        batch: &StepBatch,
        corpus: &mut SyntheticCorpus,
    ) -> Result<CalibratedProfile> {
        let entry = pool.index_of(&engine.strategy).ok_or_else(|| {
            crate::Error::Engine(format!(
                "calibrate_from_step: engine strategy `{}` is not in the pool",
                engine.strategy.name
            ))
        })?;
        let e = pool.entry(entry);
        let windows = self.microbatch_windows(e, batch)?;
        engine.set_microbatches(&windows)?;
        let was_tracing = engine.tracing();
        engine.set_tracing(true);
        let stats = engine.train_step(&mut |p, m| corpus.window_for(&windows[p][m]));
        engine.set_tracing(was_tracing);
        let stats = stats?;
        let b = stats.breakdown.ok_or_else(|| {
            crate::Error::Engine("calibrate_from_step: traced step carried no breakdown".into())
        })?;
        let ndev = e.strategy.num_devices().max(1) as f64;
        let flops = self.batch_flops(batch, e.ctx);
        let bytes = strategy_comm_bytes(&self.cm, &e.strategy, e.ctx, &batch.seq_lens);
        let profile = CalibratedProfile::fit(b.compute_s * ndev, b.comm_s * ndev, flops, bytes)
            .ok_or_else(|| {
                crate::Error::Engine(
                    "calibrate_from_step: degenerate sample (no measured compute)".into(),
                )
            })?;
        self.calibration = Some(profile);
        Ok(profile)
    }

    /// Derive the engine-cell scaling from the pool instead of the
    /// hard-coded 32K default (ROADMAP ragged follow-on): one compiled
    /// engine sequence (`engine_seq` cells — `ManifestConfig::seq`)
    /// stands for the pool's *widest* context window, so a full
    /// widest-ctx window maps to exactly the compiled engine length
    /// whatever the pool's bucket set is. (With the default tiny-48
    /// `seq = 16` and a 32K-widest pool this reproduces the historical
    /// 2048 tokens/cell.)
    pub fn scale_cells_to_pool(&mut self, pool: &StrategyPool, engine_seq: usize) {
        self.scale_cells(pool.entries().iter().map(|e| e.ctx).max().unwrap_or(0), engine_seq);
    }

    /// [`Dispatcher::scale_cells_to_pool`] from a bare widest-context
    /// value — for callers that hold the `(strategy, ctx)` entry list
    /// before instantiating any pool.
    pub fn scale_cells(&mut self, widest_ctx: u64, engine_seq: usize) {
        self.cell_tokens = widest_ctx.max(1).div_ceil(engine_seq.max(1) as u64).max(1);
    }

    /// Cost-model FLOPs to process `batch` at bucket context `ctx`: every
    /// packed window pays its *actual* fill (ragged — no padded-context
    /// charge), with the quadratic attention term spanning the packed
    /// window (cross-sequence attention, the packing baseline rule).
    pub fn batch_flops(&self, batch: &StepBatch, ctx: u64) -> f64 {
        pack_sequences(&batch.seq_lens, ctx)
            .iter()
            .map(|w| {
                let used: u64 = w.iter().sum();
                self.cm.model.fwd_flops(self.cm.model.layers, used, used)
            })
            .sum()
    }

    /// Select the pool entry for `batch`, given the engine currently runs
    /// `current`. Entries whose `ctx` cannot host the batch's longest
    /// sequence are ineligible; if none can, the widest-context entry
    /// truncates.
    pub fn choose(&self, pool: &StrategyPool, batch: &StepBatch, current: usize) -> usize {
        let max_len = batch.max_len();
        let eligible: Vec<usize> = pool
            .entries()
            .iter()
            .enumerate()
            .filter(|(_, e)| e.ctx >= max_len)
            .map(|(i, _)| i)
            .collect();
        if eligible.is_empty() {
            return pool
                .entries()
                .iter()
                .enumerate()
                .max_by_key(|(_, e)| e.ctx)
                .map(|(i, _)| i)
                .unwrap();
        }
        match self.policy {
            DispatchPolicy::HetuA => {
                eligible.into_iter().min_by_key(|&i| pool.entry(i).ctx).unwrap()
            }
            DispatchPolicy::HetuB => {
                // score each eligible entry once: batch_flops packs the
                // whole batch, so re-evaluating it per comparison would
                // repeat that work inside min_by
                let scores: Vec<(usize, f64)> = eligible
                    .iter()
                    .map(|&i| {
                        let e = pool.entry(i);
                        let ndev = e.strategy.num_devices().max(1) as f64;
                        let flops = self.batch_flops(batch, e.ctx);
                        let s = match &self.calibration {
                            // measured profile: the byte term is what can
                            // reorder candidates vs pure-FLOPs scoring
                            Some(p) => p.step_s(
                                flops,
                                strategy_comm_bytes(&self.cm, &e.strategy, e.ctx, &batch.seq_lens),
                                ndev,
                            ),
                            None => flops / ndev,
                        };
                        (i, s)
                    })
                    .collect();
                let &(best, best_s) = scores
                    .iter()
                    .min_by(|a, b| a.1.partial_cmp(&b.1).unwrap())
                    .unwrap();
                match scores.iter().find(|(i, _)| *i == current) {
                    // the win does not clear the switch cost
                    Some(&(_, cur_s)) if best_s > cur_s * (1.0 - self.hysteresis) => current,
                    _ => best,
                }
            }
        }
    }

    /// The real packed windows for running `batch` on `entry`, scaled to
    /// ragged engine shapes: sequences dispatch over the entry's pipelines
    /// by [`dispatch_hetu_b`] token loads, each pipeline's share packs
    /// first-fit into `ctx`-token windows, and every window becomes one
    /// engine row of `ceil(fill / cell_tokens)` cells. Equal-length
    /// windows (sorted longest-first) group up to `rows_per_mb` rows per
    /// micro-batch, so no dispatcher-built step executes a padded
    /// position. A pipeline left without sequences still runs one minimal
    /// window: every pipeline must contribute to the token-weighted
    /// gradient sync.
    pub fn microbatch_windows(
        &self,
        entry: &PoolEntry,
        batch: &StepBatch,
    ) -> Result<Vec<Vec<WindowShape>>> {
        let npipes = entry.strategy.pipelines.len();
        let cell = self.cell_tokens.max(1);
        let assign: Vec<Vec<u64>> = if npipes == 1 {
            vec![batch.seq_lens.clone()]
        } else {
            let classes: Vec<PipeClass> = entry
                .strategy
                .pipelines
                .iter()
                .map(|p| PipeClass {
                    max_seq: entry.ctx,
                    tokens_per_s: p.stages.iter().map(|s| s.devices.len()).sum::<usize>() as f64,
                })
                .collect();
            dispatch_hetu_b(&batch.seq_lens, &classes)
        };
        let mut out = Vec::with_capacity(npipes);
        for seqs in &assign {
            let mut cells: Vec<usize> = pack_sequences(seqs, entry.ctx)
                .iter()
                .map(|w| {
                    let used: u64 = w.iter().sum();
                    used.div_ceil(cell).max(1) as usize
                })
                .collect();
            if cells.is_empty() {
                cells.push(1); // starved pipeline: one minimal window
            }
            cells.sort_unstable_by(|a, b| b.cmp(a));
            let rows_cap = self.rows_per_mb.max(1);
            let mut mbs: Vec<WindowShape> = vec![];
            let mut i = 0;
            while i < cells.len() {
                let mut j = i + 1;
                while j < cells.len() && cells[j] == cells[i] && j - i < rows_cap {
                    j += 1;
                }
                mbs.push(WindowShape { rows: cells[i..j].to_vec(), seq_len: cells[i] });
                i = j;
            }
            out.push(mbs);
        }
        Ok(out)
    }

    /// Drive a pool-managed engine over a batch stream: choose a strategy
    /// per batch, hot-switch (cached plans) only on bucket change, hand
    /// the engine the batch's real packed-window shapes, and run the
    /// ragged step. Switch deliveries are **measured interleaved** by the
    /// event-driven executor — each switch's per-sender batches ride wire
    /// lanes inside the first post-switch step's timelines
    /// ([`crate::engine::StepStats::exposed_switch_s`]) — and checked
    /// against the old accounted `max(0, Σ delivery − makespan)` scalar
    /// bound, reported per step as
    /// [`StepOutcome::exposed_bound_s`].
    pub fn run_stream(
        &self,
        engine: &mut Engine,
        pool: &mut StrategyPool,
        stream: &[StepBatch],
        corpus: &mut SyntheticCorpus,
    ) -> Result<StreamReport> {
        let mut current = pool.index_of(&engine.strategy).ok_or_else(|| {
            crate::Error::Engine(format!(
                "run_stream: engine strategy `{}` is not in the pool",
                engine.strategy.name
            ))
        })?;
        let mut overlap = SwitchOverlap::new();
        // deliveries from switches executed before the stream started
        // still interleave with the first step; seed the scalar bound so
        // it stays an upper bound on the measured exposure
        overlap.on_switch(engine.pending_deliveries.iter().map(|d| d.1).sum());
        let hits0 = pool.hits();
        let mut steps = Vec::with_capacity(stream.len());
        let mut switches = 0u64;
        for (i, batch) in stream.iter().enumerate() {
            let chosen = self.choose(pool, batch, current);
            let (mut switched, mut cache_hit, mut delivery_s) = (false, false, 0.0);
            if chosen != current {
                let h0 = pool.hits();
                let rep = pool.switch_engine(engine, chosen)?;
                switched = true;
                cache_hit = pool.hits() > h0;
                delivery_s = rep.delivery_s;
                overlap.on_switch(rep.delivery_s);
                switches += 1;
                current = chosen;
            }
            let windows = self.microbatch_windows(pool.entry(chosen), batch)?;
            engine.set_microbatches(&windows)?;
            let stats = engine.train_step(&mut |p, m| corpus.window_for(&windows[p][m]))?;
            // the executor measured the interleaved exposure; the scalar
            // accountant yields the old per-switch-serialized bound the
            // measurement can never exceed (per-sender lanes ≤ summed
            // switch deliveries)
            let exposed_bound_s = overlap.on_step(stats.makespan_s);
            let exposed_s = stats.exposed_switch_s;
            debug_assert!(
                exposed_s <= exposed_bound_s + 1e-9,
                "measured interleaved exposure {exposed_s} exceeds the accounted bound \
                 {exposed_bound_s}"
            );
            steps.push(StepOutcome {
                step: i,
                entry: chosen,
                switched,
                cache_hit,
                delivery_s,
                exposed_s,
                exposed_bound_s,
                loss: stats.loss,
                makespan_s: stats.makespan_s,
                microbatches: windows.iter().map(|w| w.len()).sum(),
                windows: windows.iter().flat_map(|w| w.iter().map(|s| s.rows.len())).sum(),
                tokens: stats.tokens,
                padded: stats.padded,
                breakdown: stats.breakdown,
            });
        }
        Ok(StreamReport { steps, switches, cache_hits: pool.hits() - hits0 })
    }
}

/// One dispatched step's outcome.
#[derive(Clone, Debug)]
pub struct StepOutcome {
    /// Stream position.
    pub step: usize,
    /// Pool entry the step ran on.
    pub entry: usize,
    /// Whether a hot switch preceded the step.
    pub switched: bool,
    /// Whether that switch reused a cached plan.
    pub cache_hit: bool,
    /// The switch's measured delivery time (slowest sender's batch).
    pub delivery_s: f64,
    /// Switch seconds this step's compute could not hide — **measured**
    /// by the event-driven executor, which interleaves the pending
    /// per-sender delivery batches with the step's timelines (§6.2,
    /// DESIGN.md §7.3).
    pub exposed_s: f64,
    /// The old accounted scalar bound `max(0, Σ delivery − makespan)`
    /// for the same step; `exposed_s ≤ exposed_bound_s` always.
    pub exposed_bound_s: f64,
    /// Step loss.
    pub loss: f32,
    /// Measured step makespan.
    pub makespan_s: f64,
    /// Engine micro-batches this step ran (all pipelines).
    pub microbatches: usize,
    /// Packed data windows this step executed (micro-batch rows).
    pub windows: usize,
    /// Real engine tokens this step processed (measured, unmasked).
    pub tokens: u64,
    /// Padded (masked) positions this step executed — 0 for
    /// dispatcher-built windows, which always run at true ragged length.
    pub padded: u64,
    /// Measured span breakdown (`Some` only when the engine traced the
    /// step): per-rank-mean compute/comm/optim/bubble seconds on the same
    /// epoch as `makespan_s`.
    pub breakdown: Option<StepBreakdown>,
}

/// A dispatched stream's outcomes.
#[derive(Clone, Debug)]
pub struct StreamReport {
    /// Per-step outcomes in stream order.
    pub steps: Vec<StepOutcome>,
    /// Hot switches performed.
    pub switches: u64,
    /// Switches that reused a cached plan.
    pub cache_hits: u64,
}

impl StreamReport {
    /// Total time: step makespans plus exposed (non-overlapped) switch
    /// seconds.
    pub fn total_s(&self) -> f64 {
        self.steps.iter().map(|s| s.makespan_s + s.exposed_s).sum()
    }

    /// Amortized per-step time — the Fig 15 quantity.
    pub fn amortized_step_s(&self) -> f64 {
        self.total_s() / self.steps.len().max(1) as f64
    }

    /// Engine micro-batches run across the stream.
    pub fn total_microbatches(&self) -> usize {
        self.steps.iter().map(|s| s.microbatches).sum()
    }

    /// Packed data windows executed across the stream.
    pub fn total_windows(&self) -> usize {
        self.steps.iter().map(|s| s.windows).sum()
    }

    /// Real engine tokens processed across the stream.
    pub fn total_tokens(&self) -> u64 {
        self.steps.iter().map(|s| s.tokens).sum()
    }

    /// Padded positions executed across the stream (0 ⇔ every step ran at
    /// true ragged lengths — no padded-context fallback).
    pub fn total_padded(&self) -> u64 {
        self.steps.iter().map(|s| s.padded).sum()
    }

    /// Distinct pool entries the stream executed on.
    pub fn entries_used(&self) -> BTreeSet<usize> {
        self.steps.iter().map(|s| s.entry).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::costmodel::ModelCfg;
    use crate::runtime::native;
    use crate::temporal::default_pool_entries;

    fn batch(lens: Vec<u64>) -> StepBatch {
        let total_tokens = lens.iter().sum();
        StepBatch { seq_lens: lens, total_tokens }
    }

    fn pool() -> StrategyPool {
        let cfg = native::tiny_config();
        StrategyPool::new(cfg, default_pool_entries(&cfg).unwrap()).unwrap()
    }

    #[test]
    fn hetu_a_bucketizes_by_max_length() {
        let pool = pool();
        let d = Dispatcher::new(CostModel::new(ModelCfg::llama_32b()), DispatchPolicy::HetuA);
        assert_eq!(d.choose(&pool, &batch(vec![2048; 10]), 0), 0);
        assert_eq!(d.choose(&pool, &batch(vec![2048, 10_000]), 0), 1);
        assert_eq!(d.choose(&pool, &batch(vec![2048, 20_000]), 0), 2);
        // overlong tail truncates on the widest entry
        assert_eq!(d.choose(&pool, &batch(vec![40_000]), 0), 2);
    }

    #[test]
    fn hetu_b_prefers_cheap_short_context_and_honors_hysteresis() {
        let pool = pool();
        let d = Dispatcher::new(CostModel::new(ModelCfg::llama_32b()), DispatchPolicy::HetuB);
        // short data packed to a long context pays quadratic cross-window
        // attention over near-full 32K windows → leaves the incumbent
        assert_eq!(d.choose(&pool, &batch(vec![2048; 48]), 2), 0);
        // a long sequence forces the wide strategy
        let mut long = vec![2048u64; 38];
        long.push(20_000);
        assert_eq!(d.choose(&pool, &batch(long), 0), 2);
        // near-tie keeps the incumbent (hysteresis): two entries with the
        // same ctx and device count score identically
        let cfg = native::tiny_config();
        let twin = StrategyPool::new(
            cfg,
            vec![
                (crate::engine::EngineStrategy::uniform("a", 1, 2, 1, 8, 2), 4096),
                (crate::engine::EngineStrategy::uniform("b", 1, 1, 2, 8, 2), 4096),
            ],
        )
        .unwrap();
        assert_eq!(d.choose(&twin, &batch(vec![2048; 8]), 1), 1);
    }

    #[test]
    fn cell_scaling_follows_the_pools_widest_context() {
        let cfg = native::tiny_config();
        // a pool whose widest context is 16K, not the 32K default
        let pool16 = StrategyPool::new(
            cfg,
            vec![
                (crate::engine::EngineStrategy::uniform("dp2", 2, 1, 1, 8, 1), 4096),
                (crate::engine::EngineStrategy::uniform("pp2", 1, 1, 2, 8, 2), 16384),
            ],
        )
        .unwrap();
        let mut d = Dispatcher::new(CostModel::new(ModelCfg::llama_32b()), DispatchPolicy::HetuB);
        assert_eq!(d.cell_tokens, 2048, "default keeps the 32K-derived scale");
        d.scale_cells_to_pool(&pool16, cfg.seq);
        assert_eq!(d.cell_tokens, 1024, "16K widest ctx over the 16 compiled cells");
        // a full widest-context window now fills the whole compiled
        // engine length instead of half of it
        let full = batch(vec![16384]);
        let w = d.microbatch_windows(pool16.entry(1), &full).unwrap();
        let rows: Vec<usize> = w
            .iter()
            .flat_map(|p| p.iter().flat_map(|m| m.rows.iter().copied()))
            .collect();
        assert_eq!(rows, vec![16]);
        // and a 4K fill scales proportionally (4 cells, not 2)
        let short = batch(vec![4096]);
        let ws = d.microbatch_windows(pool16.entry(1), &short).unwrap();
        let cells: usize = ws.iter().flat_map(|p| p.iter().map(|m| m.real_cells())).sum();
        assert_eq!(cells, 4);
        // the default pool round-trips to the historical constant
        d.scale_cells_to_pool(&pool(), cfg.seq);
        assert_eq!(d.cell_tokens, 2048);
    }

    #[test]
    fn microbatch_windows_carry_real_packed_shapes() {
        let pool = pool();
        let d = Dispatcher::new(CostModel::new(ModelCfg::llama_32b()), DispatchPolicy::HetuB);
        // 48 × 2K sequences. Entry 0 (ctx 4K, DP2): 24 sequences per
        // pipeline pack 2-per-window into 12 full 4K windows of 2 engine
        // cells each, grouped 2 rows/mb → 6 ragged [2, 2] micro-batches
        // per pipeline.
        let short = batch(vec![2048; 48]);
        let w0 = d.microbatch_windows(pool.entry(0), &short).unwrap();
        assert_eq!(w0.len(), 2);
        for pipe in &w0 {
            assert_eq!(pipe.len(), 6);
            for mb in pipe {
                assert_eq!(mb.rows, vec![2, 2]);
                assert_eq!(mb.seq_len, 2);
            }
        }
        // Entry 2 (ctx 32K, TP2, one pipeline): the same tokens pack into
        // 3 full 32K windows of 16 cells — real window lengths, so the
        // quadratic attention cost difference is *executed*, not assumed.
        let w2 = d.microbatch_windows(pool.entry(2), &short).unwrap();
        assert_eq!(w2.len(), 1);
        let rows: Vec<usize> =
            w2[0].iter().flat_map(|m| m.rows.iter().copied()).collect();
        assert_eq!(rows, vec![16, 16, 16]);
        // token cells conserve across entries: ragged execution never
        // pads a window up to its context
        let cells = |w: &Vec<Vec<WindowShape>>| -> usize {
            w.iter().flat_map(|p| p.iter().map(|m| m.real_cells())).sum()
        };
        assert_eq!(cells(&w0), cells(&w2));
        // a starved pipeline still gets one minimal window so it joins
        // the token-weighted gradient sync
        let tiny_b = batch(vec![64]);
        let c = d.microbatch_windows(pool.entry(0), &tiny_b).unwrap();
        assert_eq!(c.len(), 2);
        assert!(c.iter().all(|pipe| !pipe.is_empty()));
        for pipe in &c {
            for mb in pipe {
                mb.validate().unwrap();
            }
        }
    }
}
