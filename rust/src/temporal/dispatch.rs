//! Length-aware dispatch: StepBatch streams → strategy choice →
//! token-weighted micro-batching → engine steps.
//!
//! Two policies, mirroring the paper's §6 evaluation:
//!
//! * **Hetu-A** — bucketize: the batch's max sequence length selects the
//!   smallest bucket (pool entry `ctx`) that can host it;
//! * **Hetu-B** — cost-model dispatch: among eligible entries, minimize
//!   the paper-scale [`CostModel`] cost of processing the batch at that
//!   entry's context (packed windows each pay their full — possibly
//!   padded — context, including the quadratic attention term, which is
//!   exactly why running short data on a long-context strategy loses),
//!   normalized by the entry's device parallelism, with hysteresis so the
//!   engine only leaves the incumbent when the win is clear.
//!
//! The chosen batch is then threaded through the engine's token-weighted
//! uneven micro-batching: the same cost model converts the batch into an
//! engine micro-batch quota (`flops_per_mb` cost units each — the tiny
//! fixed-shape engine micro-batch stands in for one context window of
//! work), [`dispatch_hetu_b`] splits the sequences over the strategy's
//! pipelines, and the quota is apportioned largest-remainder over the
//! per-pipeline token loads (`strategy::lower`'s rule, floor one). The
//! engine's token-weighted gradient sync keeps the uneven counts exact
//! data parallelism, so losses stay on one trajectory across switches.

use std::collections::BTreeSet;

use crate::coordinator::SyntheticCorpus;
use crate::costmodel::CostModel;
use crate::data::{dispatch_hetu_b, pack_sequences, PipeClass, StepBatch};
use crate::engine::Engine;
use crate::{Error, Result};

use super::overlap::SwitchOverlap;
use super::pool::{PoolEntry, StrategyPool};

/// Which §6 dispatch policy drives strategy selection.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DispatchPolicy {
    /// Length-interval bucketing (HotSPa-style, fused switches).
    HetuA,
    /// Cost-model dispatch with hysteresis.
    HetuB,
}

/// The length-aware dispatcher.
#[derive(Clone, Debug)]
pub struct Dispatcher {
    /// Selection policy.
    pub policy: DispatchPolicy,
    /// Paper-scale cost model driving Hetu-B selection and the
    /// micro-batch quota.
    pub cm: CostModel,
    /// Cost-model FLOPs one engine micro-batch stands for (default: 25K
    /// tokens at 4K context through the full model).
    pub flops_per_mb: f64,
    /// Hetu-B hysteresis: switch only when the winner undercuts the
    /// incumbent by this fraction.
    pub hysteresis: f64,
    /// Upper clamp on engine micro-batches per step.
    pub max_microbatches: usize,
}

impl Dispatcher {
    /// Dispatcher with default quota/hysteresis settings.
    pub fn new(cm: CostModel, policy: DispatchPolicy) -> Dispatcher {
        let flops_per_mb = cm.model.fwd_flops(cm.model.layers, 25_000, 4096);
        Dispatcher { policy, cm, flops_per_mb, hysteresis: 0.05, max_microbatches: 32 }
    }

    /// Cost-model FLOPs to process `batch` at bucket context `ctx`:
    /// sequences pack first-fit into `ctx`-token windows (overlong ones
    /// truncate — the baseline rule) and every window pays its full
    /// padded context, quadratic attention included.
    pub fn batch_flops(&self, batch: &StepBatch, ctx: u64) -> f64 {
        let windows = pack_sequences(&batch.seq_lens, ctx);
        windows as f64 * self.cm.model.fwd_flops(self.cm.model.layers, ctx, ctx)
    }

    /// Select the pool entry for `batch`, given the engine currently runs
    /// `current`. Entries whose `ctx` cannot host the batch's longest
    /// sequence are ineligible; if none can, the widest-context entry
    /// truncates.
    pub fn choose(&self, pool: &StrategyPool, batch: &StepBatch, current: usize) -> usize {
        let max_len = batch.max_len();
        let eligible: Vec<usize> = pool
            .entries()
            .iter()
            .enumerate()
            .filter(|(_, e)| e.ctx >= max_len)
            .map(|(i, _)| i)
            .collect();
        if eligible.is_empty() {
            return pool
                .entries()
                .iter()
                .enumerate()
                .max_by_key(|(_, e)| e.ctx)
                .map(|(i, _)| i)
                .unwrap();
        }
        match self.policy {
            DispatchPolicy::HetuA => {
                eligible.into_iter().min_by_key(|&i| pool.entry(i).ctx).unwrap()
            }
            DispatchPolicy::HetuB => {
                let score = |i: usize| {
                    self.batch_flops(batch, pool.entry(i).ctx)
                        / pool.entry(i).strategy.num_devices().max(1) as f64
                };
                let best = eligible
                    .iter()
                    .copied()
                    .min_by(|&a, &b| score(a).partial_cmp(&score(b)).unwrap())
                    .unwrap();
                if eligible.contains(&current)
                    && score(best) > score(current) * (1.0 - self.hysteresis)
                {
                    current // the win does not clear the switch cost
                } else {
                    best
                }
            }
        }
    }

    /// Token-weighted per-pipeline micro-batch counts for running `batch`
    /// on `entry`: the cost-model quota, split over pipelines by their
    /// [`dispatch_hetu_b`] token loads (largest remainder, floor one).
    pub fn microbatch_counts(&self, entry: &PoolEntry, batch: &StepBatch) -> Result<Vec<usize>> {
        let npipes = entry.strategy.pipelines.len();
        let quota = (self.batch_flops(batch, entry.ctx) / self.flops_per_mb).ceil() as usize;
        let total = quota.clamp(npipes, self.max_microbatches.max(npipes));
        if npipes == 1 {
            return Ok(vec![total]);
        }
        let classes: Vec<PipeClass> = entry
            .strategy
            .pipelines
            .iter()
            .map(|p| PipeClass {
                max_seq: entry.ctx,
                tokens_per_s: p.stages.iter().map(|s| s.devices.len()).sum::<usize>() as f64,
            })
            .collect();
        let assign = dispatch_hetu_b(&batch.seq_lens, &classes);
        let mut weights: Vec<u64> = assign.iter().map(|v| v.iter().sum()).collect();
        if weights.iter().all(|&w| w == 0) {
            weights = vec![1; npipes];
        }
        crate::strategy::lower::apportion(&weights, total)
            .map_err(|e| Error::Engine(format!("microbatch apportioning: {e}")))
    }

    /// Drive a pool-managed engine over a batch stream: choose a strategy
    /// per batch, hot-switch (cached plans) only on bucket change, retune
    /// micro-batch counts, run the step, and account switch deliveries
    /// through the §6.2 overlap model.
    pub fn run_stream(
        &self,
        engine: &mut Engine,
        pool: &mut StrategyPool,
        stream: &[StepBatch],
        corpus: &mut SyntheticCorpus,
    ) -> Result<StreamReport> {
        let mut current = pool.index_of(&engine.strategy).ok_or_else(|| {
            Error::Engine(format!(
                "run_stream: engine strategy `{}` is not in the pool",
                engine.strategy.name
            ))
        })?;
        let (b, s) = (engine.runtime.config.batch, engine.runtime.config.seq);
        let mut overlap = SwitchOverlap::new();
        let hits0 = pool.hits();
        let mut steps = Vec::with_capacity(stream.len());
        let mut switches = 0u64;
        for (i, batch) in stream.iter().enumerate() {
            let chosen = self.choose(pool, batch, current);
            let (mut switched, mut cache_hit, mut delivery_s) = (false, false, 0.0);
            if chosen != current {
                let h0 = pool.hits();
                let rep = pool.switch_engine(engine, chosen)?;
                switched = true;
                cache_hit = pool.hits() > h0;
                delivery_s = rep.delivery_s;
                overlap.on_switch(rep.delivery_s);
                switches += 1;
                current = chosen;
            }
            let counts = self.microbatch_counts(pool.entry(chosen), batch)?;
            engine.set_microbatches(&counts)?;
            let stats = engine.train_step(&mut |_p, _m| corpus.microbatch(b, s))?;
            let exposed_s = overlap.on_step(stats.makespan_s);
            steps.push(StepOutcome {
                step: i,
                entry: chosen,
                switched,
                cache_hit,
                delivery_s,
                exposed_s,
                loss: stats.loss,
                makespan_s: stats.makespan_s,
                microbatches: counts.iter().sum(),
            });
        }
        Ok(StreamReport { steps, switches, cache_hits: pool.hits() - hits0 })
    }
}

/// One dispatched step's outcome.
#[derive(Clone, Debug)]
pub struct StepOutcome {
    /// Stream position.
    pub step: usize,
    /// Pool entry the step ran on.
    pub entry: usize,
    /// Whether a hot switch preceded the step.
    pub switched: bool,
    /// Whether that switch reused a cached plan.
    pub cache_hit: bool,
    /// The switch's measured delivery time (slowest sender's batch).
    pub delivery_s: f64,
    /// Switch seconds this step's compute could not hide (§6.2 overlap).
    pub exposed_s: f64,
    /// Step loss.
    pub loss: f32,
    /// Measured step makespan.
    pub makespan_s: f64,
    /// Engine micro-batches this step ran (all pipelines).
    pub microbatches: usize,
}

/// A dispatched stream's outcomes.
#[derive(Clone, Debug)]
pub struct StreamReport {
    /// Per-step outcomes in stream order.
    pub steps: Vec<StepOutcome>,
    /// Hot switches performed.
    pub switches: u64,
    /// Switches that reused a cached plan.
    pub cache_hits: u64,
}

impl StreamReport {
    /// Total time: step makespans plus exposed (non-overlapped) switch
    /// seconds.
    pub fn total_s(&self) -> f64 {
        self.steps.iter().map(|s| s.makespan_s + s.exposed_s).sum()
    }

    /// Amortized per-step time — the Fig 15 quantity.
    pub fn amortized_step_s(&self) -> f64 {
        self.total_s() / self.steps.len().max(1) as f64
    }

    /// Engine micro-batches run across the stream.
    pub fn total_microbatches(&self) -> usize {
        self.steps.iter().map(|s| s.microbatches).sum()
    }

    /// Distinct pool entries the stream executed on.
    pub fn entries_used(&self) -> BTreeSet<usize> {
        self.steps.iter().map(|s| s.entry).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::costmodel::ModelCfg;
    use crate::runtime::native;
    use crate::temporal::default_pool_entries;

    fn batch(lens: Vec<u64>) -> StepBatch {
        let total_tokens = lens.iter().sum();
        StepBatch { seq_lens: lens, total_tokens }
    }

    fn pool() -> StrategyPool {
        let cfg = native::tiny_config();
        StrategyPool::new(cfg, default_pool_entries(&cfg).unwrap()).unwrap()
    }

    #[test]
    fn hetu_a_bucketizes_by_max_length() {
        let pool = pool();
        let d = Dispatcher::new(CostModel::new(ModelCfg::llama_32b()), DispatchPolicy::HetuA);
        assert_eq!(d.choose(&pool, &batch(vec![2048; 10]), 0), 0);
        assert_eq!(d.choose(&pool, &batch(vec![2048, 10_000]), 0), 1);
        assert_eq!(d.choose(&pool, &batch(vec![2048, 20_000]), 0), 2);
        // overlong tail truncates on the widest entry
        assert_eq!(d.choose(&pool, &batch(vec![40_000]), 0), 2);
    }

    #[test]
    fn hetu_b_prefers_cheap_short_context_and_honors_hysteresis() {
        let pool = pool();
        let d = Dispatcher::new(CostModel::new(ModelCfg::llama_32b()), DispatchPolicy::HetuB);
        // short data on a long-context strategy wastes quadratic attention
        // → leaves the incumbent
        assert_eq!(d.choose(&pool, &batch(vec![2048; 48]), 2), 0);
        // a long sequence forces the wide strategy
        let mut long = vec![2048u64; 38];
        long.push(20_000);
        assert_eq!(d.choose(&pool, &batch(long), 0), 2);
        // near-tie keeps the incumbent (hysteresis): two entries with the
        // same ctx and device count score identically
        let cfg = native::tiny_config();
        let twin = StrategyPool::new(
            cfg,
            vec![
                (crate::engine::EngineStrategy::uniform("a", 1, 2, 1, 8, 2), 4096),
                (crate::engine::EngineStrategy::uniform("b", 1, 1, 2, 8, 2), 4096),
            ],
        )
        .unwrap();
        assert_eq!(d.choose(&twin, &batch(vec![2048; 8]), 1), 1);
    }

    #[test]
    fn microbatch_quota_scales_with_context_waste() {
        let pool = pool();
        let d = Dispatcher::new(CostModel::new(ModelCfg::llama_32b()), DispatchPolicy::HetuB);
        // ~98K tokens of 2K sequences at 4K context: 24 windows ≈ 4 quota
        // units, split 2:2 over the DP pipelines
        let short = batch(vec![2048; 48]);
        let c0 = d.microbatch_counts(pool.entry(0), &short).unwrap();
        assert_eq!(c0.iter().sum::<usize>(), 4);
        assert_eq!(c0, vec![2, 2]);
        // the same tokens at 32K context pay padding + quadratic attention
        let c2 = d.microbatch_counts(pool.entry(2), &short).unwrap();
        assert_eq!(c2.len(), 1);
        assert!(
            c2[0] > c0.iter().sum::<usize>(),
            "long-context waste must exceed the short-context quota: {c2:?} vs {c0:?}"
        );
        // floors: every pipeline gets at least one micro-batch
        let tiny_b = batch(vec![64]);
        let c = d.microbatch_counts(pool.entry(0), &tiny_b).unwrap();
        assert_eq!(c, vec![1, 1]);
    }
}
