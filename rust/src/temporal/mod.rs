//! The temporal-heterogeneity runtime (§6, Figs 15/16).
//!
//! The paper's answer to *temporal* heterogeneity — sequence-length mix
//! shifting batch to batch — is to define the program once, instantiate
//! several parallel strategies, and **hot-switch** between their graphs as
//! the mix shifts. This module is that runtime at engine scale, executing
//! real numerics rather than the simulator:
//!
//! * [`pool::StrategyPool`] owns N lowered [`EngineStrategy`] graphs with
//!   their [`ShardLayout`](crate::engine::ShardLayout)s precomputed and a
//!   **pairwise switch-plan cache**: a repeated A↔B transition reuses the
//!   fused-BSR [`SwitchPlan`](crate::engine::SwitchPlan) instead of
//!   re-planning (hits/misses are counted and asserted in tests);
//! * [`dispatch::Dispatcher`] consumes [`data::StepBatch`] streams and
//!   implements the paper's two dispatch policies — **Hetu-A** (bucketize
//!   by max length, run the bucket's strategy) and **Hetu-B** (cost-model
//!   dispatch via [`costmodel`](crate::costmodel), with hysteresis so the
//!   engine only switches when the win clears the transition cost) —
//!   triggering `Engine::switch_to_planned` only on bucket change and
//!   handing the engine each batch's *real packed-window shapes*
//!   ([`Engine::set_microbatches`](crate::engine::Engine) window
//!   contract): ragged `[n_seqs, seq_len]` micro-batches executed at
//!   true window lengths, with the token-weighted gradient sync keeping
//!   the uneven shapes exact data parallelism;
//! * the §6.2 switch/compute overlap (Fig 18-right) is **measured, not
//!   accounted** (DESIGN.md §7.3): fused switch messages execute
//!   **batched per sender** (`engine/switch.rs`), the engine queues the
//!   per-sender batches, and the first post-switch step's event-driven
//!   executor interleaves them on wire lanes concurrent with its
//!   specialized per-rank timelines — only the measured overhang is
//!   exposed in the amortized per-step time.
//!   [`overlap::SwitchOverlap`] survives as the accounted scalar upper
//!   bound the measurement is checked against.
//!
//! `figures::fig15_engine` drives this runtime over synthetic
//! CommonCrawl/GitHub streams to produce the *measured* engine column of
//! the Fig 15 comparison: amortized per-step time of the switching engine
//! vs. each single static strategy on the same stream.

pub mod dispatch;
pub mod overlap;
pub mod pool;

pub use dispatch::{DispatchPolicy, Dispatcher, StepOutcome, StreamReport};
pub use overlap::SwitchOverlap;
pub use pool::{PoolEntry, StrategyPool};

use crate::data::{sample_step, Corpus, StepBatch};
use crate::engine::EngineStrategy;
use crate::runtime::ManifestConfig;
use crate::spec::schedule::ScheduleKind;
use crate::testutil::Rng;
use crate::Result;

/// The default temporal pool: three strategies lowered from paper-scale
/// encodings onto `cfg`, one per length bucket — a DP-wide short-sequence
/// strategy, a pipelined mid-bucket strategy, and a TP-wide long-sequence
/// variant. All use the same two devices, so hot switches move real
/// parameter and optimizer state.
pub fn default_pool_entries(cfg: &ManifestConfig) -> Result<Vec<(EngineStrategy, u64)>> {
    let mk = |name: &str, dp: u32, tp: u32, pp: u32, seq: u64| -> Result<EngineStrategy> {
        let n = dp * tp * pp;
        let ranks: Vec<u32> = (0..n).collect();
        let spec = crate::strategy::uniform(
            name,
            &ranks,
            dp,
            tp,
            pp,
            60,
            (dp as u64) * 4,
            1,
            seq,
            ScheduleKind::GPipe,
            false,
            false,
        )?;
        let lopts = crate::strategy::LowerOptions {
            total_microbatches: (dp as usize) * 2,
            tp_degrees: crate::runtime::native::TP_DEGREES.to_vec(),
        };
        crate::strategy::lower(&spec, cfg, &lopts)
    };
    Ok(vec![
        (mk("hetu-short-dp2", 2, 1, 1, 4096)?, 4096),
        (mk("hetu-mid-pp2", 1, 1, 2, 16384)?, 16384),
        (mk("hetu-long-tp2", 1, 2, 1, 32768)?, 32768),
    ])
}

/// Sample a synthetic mixed-length stream: `steps` × [`sample_step`].
pub fn sample_stream(
    rng: &mut Rng,
    corpus: Corpus,
    steps: usize,
    token_budget: u64,
    max_len: u64,
) -> Vec<StepBatch> {
    (0..steps).map(|_| sample_step(rng, corpus, token_budget, max_len)).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::native;

    #[test]
    fn default_pool_lowers_three_two_device_strategies() {
        let cfg = native::tiny_config();
        let entries = default_pool_entries(&cfg).unwrap();
        assert_eq!(entries.len(), 3);
        let ctxs: Vec<u64> = entries.iter().map(|(_, c)| *c).collect();
        assert_eq!(ctxs, vec![4096, 16384, 32768]);
        for (s, _) in &entries {
            s.validate(&cfg, &[1, 2, 4]).unwrap();
            assert_eq!(s.num_devices(), 2, "{}", s.name);
        }
        // short = 2 pipelines (DP), long = 1 pipeline at TP2
        assert_eq!(entries[0].0.pipelines.len(), 2);
        assert_eq!(entries[2].0.pipelines[0].stages[0].devices, vec![0, 1]);
    }

    #[test]
    fn sample_stream_is_deterministic() {
        let mut a = Rng::new(9);
        let mut b = Rng::new(9);
        let sa = sample_stream(&mut a, Corpus::CommonCrawl, 5, 50_000, 32768);
        let sb = sample_stream(&mut b, Corpus::CommonCrawl, 5, 50_000, 32768);
        assert_eq!(sa.len(), 5);
        for (x, y) in sa.iter().zip(sb.iter()) {
            assert_eq!(x.seq_lens, y.seq_lens);
        }
    }
}
