//! §6.2 switch/compute overlap: the accounted **upper bound** on exposure
//! (Fig 18-right).
//!
//! The engine executes a transition's fused messages batched per sender
//! (`engine/switch.rs`), and senders run concurrently in a deployment, so
//! a switch's *delivery time* is the slowest sender's batch
//! ([`EngineSwitchReport::delivery_s`](crate::engine::EngineSwitchReport)).
//! The paper then overlaps that delivery with the first post-switch step:
//! early pipeline stages start computing while later layers' shards are
//! still in flight.
//!
//! Since the specialize→execute refactor (DESIGN.md §7), the overlap is
//! **measured, not accounted**: the switch hands its per-sender delivery
//! batches to the engine, the event-driven executor injects them onto
//! per-sender wire lanes inside the first post-switch step's timelines,
//! and the step reports the interleaved exposure it actually measured
//! ([`StepStats::exposed_switch_s`](crate::engine::StepStats)). This
//! module remains as the *scalar bound* that measurement is checked
//! against — per-switch serialization over the step's global makespan:
//!
//! ```text
//! exposed_bound = max(0, Σ pending deliveries − step_makespan)
//! ```
//!
//! Because the executor serializes back-to-back deliveries per *sender*
//! (lanes) rather than per switch, the measured exposure is ≤ this bound
//! on every step (equality for a single pending switch) — asserted by
//! [`Dispatcher::run_stream`](super::Dispatcher) in debug builds and by
//! the `temporal_cadence` CI smoke. The dispatcher folds
//! `makespan + measured exposure` into the amortized per-step time, so a
//! switch's cost is amortized over the following bucket run-length
//! exactly as Fig 15's Hetu-A/B cells assume.
//!
//! The concurrent OS-thread executor ([`crate::engine::thread`]) reports
//! the same quantity against its *wall-clock* makespan: delivery lanes
//! are folded per sender and the exposed remainder is
//! `max(0, slowest_lane − wall_makespan)`, which respects this scalar
//! bound too (checked by a `debug_assert!` on its return path).

/// Running overlap state across a step stream.
#[derive(Clone, Copy, Debug, Default)]
pub struct SwitchOverlap {
    pending_delivery_s: f64,
}

impl SwitchOverlap {
    /// Fresh accountant with nothing in flight.
    pub fn new() -> SwitchOverlap {
        SwitchOverlap::default()
    }

    /// A switch completed; its delivery overlaps the next step. Multiple
    /// switches before a step serialize (their deliveries sum).
    pub fn on_switch(&mut self, delivery_s: f64) {
        self.pending_delivery_s += delivery_s.max(0.0);
    }

    /// A step of `makespan_s` ran; returns the switch seconds this step
    /// could *not* hide (its exposed overhead). Afterwards nothing is
    /// pending — a delivery longer than one step surfaces entirely on
    /// that step.
    pub fn on_step(&mut self, makespan_s: f64) -> f64 {
        let exposed = (self.pending_delivery_s - makespan_s.max(0.0)).max(0.0);
        self.pending_delivery_s = 0.0;
        exposed
    }

    /// Delivery seconds currently awaiting overlap.
    pub fn pending_s(&self) -> f64 {
        self.pending_delivery_s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn short_delivery_hides_entirely() {
        let mut o = SwitchOverlap::new();
        o.on_switch(0.010);
        assert!((o.pending_s() - 0.010).abs() < 1e-12);
        assert_eq!(o.on_step(0.050), 0.0);
        assert_eq!(o.pending_s(), 0.0);
        // nothing pending → nothing exposed
        assert_eq!(o.on_step(0.050), 0.0);
    }

    #[test]
    fn long_delivery_exposes_the_remainder_once() {
        let mut o = SwitchOverlap::new();
        o.on_switch(0.080);
        let e = o.on_step(0.050);
        assert!((e - 0.030).abs() < 1e-12);
        assert_eq!(o.on_step(0.050), 0.0);
    }

    #[test]
    fn back_to_back_switches_serialize() {
        let mut o = SwitchOverlap::new();
        o.on_switch(0.030);
        o.on_switch(0.040);
        let e = o.on_step(0.050);
        assert!((e - 0.020).abs() < 1e-12);
    }
}
