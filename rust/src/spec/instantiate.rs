//! §5.3 — Operator instantiation: annotated graph → per-device executable
//! graphs.

use std::collections::HashMap;
use std::time::Instant;

use crate::comm::{resolve, Bandwidth, BsrOptions, Resolution};
use crate::graph::{Binding, Graph, OpId, OpKind};
use crate::hspmd::dg::Rank;
use crate::{Error, Result};

/// What a device does for one graph op.
#[derive(Clone, Debug)]
pub enum Action {
    /// Run the op's local compute on this device's shard.
    Compute,
    /// Execute (this device's part of) a resolved communication plan.
    Comm(Resolution),
}

/// One step of a device's executable graph.
#[derive(Clone, Debug)]
pub struct ExecOp {
    /// Originating graph op.
    pub op: OpId,
    /// Compute or communication.
    pub action: Action,
}

/// A device-specific executable graph (§5.3): the pruned, substituted op
/// sequence for one rank.
#[derive(Clone, Debug)]
pub struct ExecutableGraph {
    /// The device this graph runs on.
    pub rank: Rank,
    /// Ops in topological order.
    pub ops: Vec<ExecOp>,
}

/// Wall-clock breakdown of the specialization phases (Fig 18-right).
#[derive(Clone, Copy, Debug, Default)]
pub struct SpecReport {
    /// Annotation deduction time (s).
    pub deduction_s: f64,
    /// CommOp resolution + operator instantiation time (s).
    pub instantiation_s: f64,
    /// Pipeline construction time (s).
    pub pipeline_s: f64,
}

impl SpecReport {
    /// Total specialization time.
    pub fn total_s(&self) -> f64 {
        self.deduction_s + self.instantiation_s + self.pipeline_s
    }
}

/// Specialization output: per-device graphs + resolved CommOps + timings.
#[derive(Clone, Debug)]
pub struct Specialized {
    /// Executable graph per participating rank.
    pub graphs: HashMap<Rank, ExecutableGraph>,
    /// Resolution of every CommOp (op id → resolution), for the pipeline
    /// constructor and the Fig 17 case study.
    pub comm_resolutions: HashMap<OpId, Resolution>,
    /// Phase timings.
    pub report: SpecReport,
}

/// Specialize the graph under strategy `k` (§5.3).
///
/// Runs annotation deduction if not already done, resolves every CommOp via
/// §4, prunes non-local ops per device, and returns the per-device
/// executable graphs.
pub fn specialize(
    g: &mut Graph,
    k: usize,
    binding: &Binding,
    bw: &dyn Bandwidth,
    opts: BsrOptions,
) -> Result<Specialized> {
    let t0 = Instant::now();
    crate::graph::deduce::deduce(g, k)?;
    let deduction_s = t0.elapsed().as_secs_f64();

    let t1 = Instant::now();
    // Resolve all CommOps.
    let mut comm_resolutions: HashMap<OpId, Resolution> = HashMap::new();
    for op in g.topo().to_vec() {
        if matches!(op.kind, OpKind::Comm) {
            let src = g
                .tensor(op.inputs[0])
                .annotation(k)
                .ok_or_else(|| Error::Graph("comm input not annotated".into()))?
                .clone();
            let dst = g
                .tensor(op.outputs[0])
                .annotation(k)
                .ok_or_else(|| Error::Graph("comm output not annotated".into()))?
                .clone();
            let shape = binding.shape(&g.tensor(op.inputs[0]).shape)?;
            let res = resolve(&src, &dst, &shape, bw, opts)?;
            comm_resolutions.insert(op.id, res);
        }
    }

    // Build per-device graphs: include an op iff one of its tensors places
    // the device in its DG union (non-local operator removal).
    let mut graphs: HashMap<Rank, ExecutableGraph> = HashMap::new();
    for op in g.topo() {
        let mut ranks: Vec<Rank> = vec![];
        for &t in op.inputs.iter().chain(op.outputs.iter()) {
            if let Some(ann) = g.tensor(t).annotation(k) {
                ranks.extend(ann.all_ranks());
            }
        }
        ranks.sort_unstable();
        ranks.dedup();
        let action = match op.kind {
            OpKind::Comm => Action::Comm(comm_resolutions[&op.id].clone()),
            _ => Action::Compute,
        };
        for r in ranks {
            // For compute ops the device must be in the *output* DG (it
            // produces a local shard); comm ops involve both sides.
            let participates = match op.kind {
                OpKind::Comm => true,
                _ => op
                    .outputs
                    .iter()
                    .any(|&t| g.tensor(t).annotation(k).map(|a| a.locate(r).is_some()).unwrap_or(false)),
            };
            if !participates {
                continue;
            }
            graphs
                .entry(r)
                .or_insert_with(|| ExecutableGraph { rank: r, ops: vec![] })
                .ops
                .push(ExecOp { op: op.id, action: action.clone() });
        }
    }
    let instantiation_s = t1.elapsed().as_secs_f64();

    Ok(Specialized {
        graphs,
        comm_resolutions,
        report: SpecReport { deduction_s, instantiation_s, pipeline_s: 0.0 },
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::{ResolvedKind, UniformBandwidth};
    use crate::graph::{lits, DType, UnaryKind};
    use crate::hspmd::ds::DUPLICATE;
    use crate::hspmd::{Annotation, DeviceGroup, DistStates};

    /// The Fig 9 running example, in miniature: Gelu(X) @ Comm(W) → Comm(Y).
    fn fig9_graph() -> (Graph, crate::graph::TensorId, crate::graph::TensorId) {
        let mut g = Graph::new(1);
        // X: split batch over 2 DP groups of 2 TP workers (contraction split)
        let x_ann = Annotation::spmd(
            DeviceGroup::range(0, 4),
            DistStates::new(&[(0, 2), (1, 2)], &[0, 1]).unwrap(),
        )
        .unwrap();
        let x = g.placeholder("X", lits(&[8, 16]), DType::F32, vec![x_ann]).unwrap();
        // W initially replicated on all 4; comm to row-split for TP.
        let w_ann = Annotation::spmd(DeviceGroup::range(0, 4), DistStates::duplicate(4)).unwrap();
        let w = g.parameter("W", lits(&[16, 32]), DType::F32, vec![w_ann]).unwrap();
        let w_tp = Annotation::spmd(
            DeviceGroup::range(0, 4),
            DistStates::new(&[(DUPLICATE, 2), (0, 2)], &[-1, 0]).unwrap(),
        )
        .unwrap();
        let wc = g.comm(w, vec![w_tp]).unwrap();
        let xg = g.unary(UnaryKind::Gelu, x);
        let y = g.dot(xg, wc).unwrap();
        // Y is partial over TP: comm to replicated (AR). The deduced Y uses
        // canonical order [-2, 0]; the AR relabel yields [-1, 0].
        let y_ann = Annotation::spmd(
            DeviceGroup::range(0, 4),
            DistStates::new(&[(0, 2), (DUPLICATE, 2)], &[-1, 0]).unwrap(),
        )
        .unwrap();
        let yc = g.comm(y, vec![y_ann]).unwrap();
        (g, y, yc)
    }

    #[test]
    fn specialization_builds_per_device_graphs() {
        let (mut g, _, _) = fig9_graph();
        let spec =
            specialize(&mut g, 0, &Binding::new(), &UniformBandwidth, BsrOptions::default())
                .unwrap();
        assert_eq!(spec.graphs.len(), 4);
        // every device runs: X placeholder, W param, commW, gelu, dot, commY
        for r in 0..4u32 {
            assert_eq!(spec.graphs[&r].ops.len(), 6, "rank {r}");
        }
    }

    #[test]
    fn commops_are_substituted() {
        let (mut g, y, _) = fig9_graph();
        let spec =
            specialize(&mut g, 0, &Binding::new(), &UniformBandwidth, BsrOptions::default())
                .unwrap();
        // Y's partial-over-TP → dup is an AllReduce; W's dup → split is BSR
        // (a broadcast-like scatter has no single collective here since DS
        // dup4 -> dup2×split2 is a *narrowing*; it resolves via BSR local
        // copies only — zero wire volume).
        let kinds: Vec<ResolvedKind> = spec.comm_resolutions.values().map(|r| r.kind).collect();
        assert!(kinds.contains(&ResolvedKind::AllReduce), "{kinds:?}");
        let y_comm = g.tensors[y].clone();
        let _ = y_comm;
    }

    #[test]
    fn non_local_ops_removed() {
        // Two disjoint islands: op on {0,1} and op on {2,3}; device 3 must
        // not see the first island.
        let mut g = Graph::new(1);
        let a01 = Annotation::spmd(DeviceGroup::range(0, 2), DistStates::split(0, 2)).unwrap();
        let a23 = Annotation::spmd(DeviceGroup::range(2, 4), DistStates::split(0, 2)).unwrap();
        let x = g.placeholder("X", lits(&[4]), DType::F32, vec![a01]).unwrap();
        let y = g.placeholder("Y", lits(&[4]), DType::F32, vec![a23]).unwrap();
        let _gx = g.unary(UnaryKind::Gelu, x);
        let _gy = g.unary(UnaryKind::Gelu, y);
        let spec =
            specialize(&mut g, 0, &Binding::new(), &UniformBandwidth, BsrOptions::default())
                .unwrap();
        assert_eq!(spec.graphs[&0].ops.len(), 2); // X + gelu(X)
        assert_eq!(spec.graphs[&3].ops.len(), 2); // Y + gelu(Y)
        let ops3: Vec<OpId> = spec.graphs[&3].ops.iter().map(|e| e.op).collect();
        assert!(ops3.iter().all(|&o| g.ops[o].inputs.iter().all(|&t| t != x)));
    }

    #[test]
    fn zero_wire_commop_is_local() {
        // dup4 → dup2×split2 narrows each device's shard: pure local copies.
        let (mut g, _, _) = fig9_graph();
        let spec =
            specialize(&mut g, 0, &Binding::new(), &UniformBandwidth, BsrOptions::default())
                .unwrap();
        let w_comm_res = spec
            .comm_resolutions
            .values()
            .find(|r| r.kind == ResolvedKind::Bsr)
            .expect("W comm resolves to BSR");
        assert_eq!(w_comm_res.plan.elems_on_wire(), 0);
    }

    #[test]
    fn symbolic_shapes_bind_at_specialization() {
        let mut g = Graph::new(1);
        let ann = Annotation::spmd(DeviceGroup::range(0, 2), DistStates::split(0, 2)).unwrap();
        let x = g
            .placeholder(
                "X",
                vec![crate::graph::SymDim::sym("B"), crate::graph::SymDim::Lit(4)],
                DType::F32,
                vec![ann.clone()],
            )
            .unwrap();
        let dst = Annotation::spmd(DeviceGroup::range(0, 2), DistStates::split(1, 2)).unwrap();
        let _xc = g.comm(x, vec![dst]).unwrap();
        let mut b = Binding::new();
        b.set("B", 8);
        let spec = specialize(&mut g, 0, &b, &UniformBandwidth, BsrOptions::default()).unwrap();
        assert_eq!(spec.comm_resolutions.len(), 1);
        // unbound symbol must fail verification
        let mut g2 = g.clone();
        assert!(specialize(&mut g2, 0, &Binding::new(), &UniformBandwidth, BsrOptions::default())
            .is_err());
    }
}
