//! Pipeline schedules: GPipe and 1F1B (§5.4).
//!
//! Hetu supports various scheduling schemes and lets independent pipelines
//! process different numbers of micro-batches with varying sizes. The
//! schedule here is the per-stage *task order*; actual timing (bubble
//! structure) emerges in the simulator / engine from the cross-stage
//! dependencies `Fwd(m, s)` ⇐ `Fwd(m, s-1)` and `Bwd(m, s)` ⇐ `Bwd(m, s+1)`.

/// Scheduling scheme. `Hash` because the kind is part of the pool's
/// compiled-artifact cache key (`temporal/pool.rs`).
#[derive(Clone, Copy, PartialEq, Eq, Hash, Debug)]
pub enum ScheduleKind {
    /// All forwards, then all backwards (high activation memory).
    GPipe,
    /// One-forward-one-backward steady state (PipeDream-flush).
    OneFOneB,
}

/// Task kind.
#[derive(Clone, Copy, PartialEq, Eq, Debug, Hash)]
pub enum TaskKind {
    /// Forward pass of one micro-batch through this stage.
    Fwd,
    /// Backward pass of one micro-batch through this stage.
    Bwd,
}

/// One scheduled task of a stage.
#[derive(Clone, Copy, PartialEq, Eq, Debug, Hash)]
pub struct Task {
    /// Fwd or Bwd.
    pub kind: TaskKind,
    /// Micro-batch index.
    pub microbatch: usize,
}

/// A full pipeline schedule: per-stage ordered task lists.
#[derive(Clone, Debug)]
pub struct PipelineSchedule {
    /// `tasks[stage]` = ordered tasks for that stage.
    pub tasks: Vec<Vec<Task>>,
    /// Scheme used.
    pub kind: ScheduleKind,
    /// Number of micro-batches.
    pub num_microbatches: usize,
}

/// Emit the task order for one stage.
pub fn stage_schedule(
    kind: ScheduleKind,
    num_stages: usize,
    stage: usize,
    num_microbatches: usize,
) -> Vec<Task> {
    let m = num_microbatches;
    let mut out = Vec::with_capacity(2 * m);
    match kind {
        ScheduleKind::GPipe => {
            for i in 0..m {
                out.push(Task { kind: TaskKind::Fwd, microbatch: i });
            }
            for i in (0..m).rev() {
                out.push(Task { kind: TaskKind::Bwd, microbatch: i });
            }
        }
        ScheduleKind::OneFOneB => {
            // warmup forwards: deeper stages run fewer
            let warmup = (num_stages - stage).min(m);
            for i in 0..warmup {
                out.push(Task { kind: TaskKind::Fwd, microbatch: i });
            }
            for j in 0..(m - warmup) {
                out.push(Task { kind: TaskKind::Bwd, microbatch: j });
                out.push(Task { kind: TaskKind::Fwd, microbatch: j + warmup });
            }
            for j in (m - warmup)..m {
                out.push(Task { kind: TaskKind::Bwd, microbatch: j });
            }
        }
    }
    out
}

impl PipelineSchedule {
    /// Micro-batch indices in the order `stage` retires its backward
    /// tasks. For the last stage this is the order losses surface — the
    /// accumulation order the per-rank specialization pass records
    /// ([`crate::engine::specialize`]), keeping the event-driven
    /// executor's f64 loss sum bit-identical to the global interpreter's
    /// (GPipe retires LIFO, 1F1B FIFO).
    pub fn bwd_retirement_order(&self, stage: usize) -> Vec<usize> {
        self.tasks[stage]
            .iter()
            .filter(|t| t.kind == TaskKind::Bwd)
            .map(|t| t.microbatch)
            .collect()
    }
}

/// Build the full schedule for a pipeline.
pub fn full_schedule(
    kind: ScheduleKind,
    num_stages: usize,
    num_microbatches: usize,
) -> PipelineSchedule {
    PipelineSchedule {
        tasks: (0..num_stages)
            .map(|s| stage_schedule(kind, num_stages, s, num_microbatches))
            .collect(),
        kind,
        num_microbatches,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn counts(tasks: &[Task], m: usize) {
        let fwd = tasks.iter().filter(|t| t.kind == TaskKind::Fwd).count();
        let bwd = tasks.iter().filter(|t| t.kind == TaskKind::Bwd).count();
        assert_eq!(fwd, m);
        assert_eq!(bwd, m);
        // each microbatch appears exactly once per kind
        for i in 0..m {
            assert_eq!(
                tasks.iter().filter(|t| t.kind == TaskKind::Fwd && t.microbatch == i).count(),
                1
            );
        }
    }

    #[test]
    fn gpipe_order() {
        let t = stage_schedule(ScheduleKind::GPipe, 4, 0, 3);
        counts(&t, 3);
        assert_eq!(t[0], Task { kind: TaskKind::Fwd, microbatch: 0 });
        assert_eq!(t[3], Task { kind: TaskKind::Bwd, microbatch: 2 });
    }

    #[test]
    fn one_f_one_b_last_stage_alternates() {
        // last stage: warmup = 1 → F0 B0 F1 B1 ...
        let t = stage_schedule(ScheduleKind::OneFOneB, 4, 3, 4);
        counts(&t, 4);
        assert_eq!(t[0], Task { kind: TaskKind::Fwd, microbatch: 0 });
        assert_eq!(t[1], Task { kind: TaskKind::Bwd, microbatch: 0 });
        assert_eq!(t[2], Task { kind: TaskKind::Fwd, microbatch: 1 });
    }

    #[test]
    fn one_f_one_b_first_stage_warmup() {
        // first of 4 stages, 8 microbatches: warmup = 4 forwards
        let t = stage_schedule(ScheduleKind::OneFOneB, 4, 0, 8);
        counts(&t, 8);
        for i in 0..4 {
            assert_eq!(t[i].kind, TaskKind::Fwd);
        }
        assert_eq!(t[4].kind, TaskKind::Bwd);
    }

    #[test]
    fn warmup_capped_by_microbatches() {
        // more stages than microbatches: warmup = m, pure GPipe-like
        let t = stage_schedule(ScheduleKind::OneFOneB, 8, 0, 2);
        counts(&t, 2);
        assert_eq!(t[0].kind, TaskKind::Fwd);
        assert_eq!(t[1].kind, TaskKind::Fwd);
        assert_eq!(t[2].kind, TaskKind::Bwd);
    }

    #[test]
    fn full_schedule_shape() {
        let s = full_schedule(ScheduleKind::OneFOneB, 4, 6);
        assert_eq!(s.tasks.len(), 4);
        for st in &s.tasks {
            counts(st, 6);
        }
    }

    #[test]
    fn bwd_retirement_order_per_schedule() {
        let g = full_schedule(ScheduleKind::GPipe, 2, 3);
        assert_eq!(g.bwd_retirement_order(1), vec![2, 1, 0]);
        let f = full_schedule(ScheduleKind::OneFOneB, 2, 3);
        assert_eq!(f.bwd_retirement_order(1), vec![0, 1, 2]);
    }

    #[test]
    fn bwd_fifo_in_1f1b() {
        let t = stage_schedule(ScheduleKind::OneFOneB, 4, 1, 6);
        let bwds: Vec<usize> = t
            .iter()
            .filter(|x| x.kind == TaskKind::Bwd)
            .map(|x| x.microbatch)
            .collect();
        let mut sorted = bwds.clone();
        sorted.sort_unstable();
        assert_eq!(bwds, sorted, "1F1B backwards complete in FIFO order");
    }
}
