//! §5.3–5.4 — Progressive graph specialization.
//!
//! From the annotated graph, Hetu instantiates one *executable graph per
//! device*: ops whose tensors never touch the device are pruned
//! (**non-local operator removal**), and every CommOp is replaced by the
//! communication operators the §4 resolver derives (**CommOp
//! substitution**). Pipelines are then discovered from the scheduled
//! CommOps' communication patterns (collective peers merge into a stage,
//! P2P peers chain into successive stages), and per-stage GPipe/1F1B task
//! schedules are emitted.

pub mod instantiate;
pub mod pipeline;
pub mod schedule;

pub use instantiate::{specialize, Action, ExecOp, ExecutableGraph, SpecReport, Specialized};
pub use pipeline::{build_pipelines, Pipeline, PipelineSet};
pub use schedule::{stage_schedule, PipelineSchedule, ScheduleKind, Task, TaskKind};
