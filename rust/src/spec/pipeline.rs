//! §5.4 — Pipeline construction.
//!
//! A *pipeline* is the minimal device set needed for complete dataflow
//! execution. Construction starts with one pipeline per device and merges
//! step by step from the scheduled CommOps' communication patterns:
//! devices joined by **collective** communication merge into the same
//! stage; **P2P** (send-receive / BSR) chains stages into successor stages.
//! CommOps that execute only once per run (pure parameter-side transforms,
//! e.g. Fig 9's CommOp id=1) are excluded from the analysis.

use std::collections::{BTreeMap, BTreeSet, HashMap};

use crate::comm::{CommPlan, Resolution};
use crate::graph::{Graph, OpId, OpKind};
use crate::hspmd::dg::Rank;
use crate::Result;

/// One pipeline: ordered stages, each a set of devices.
#[derive(Clone, Debug, PartialEq)]
pub struct Pipeline {
    /// Stages in dataflow order; each stage lists its member ranks.
    pub stages: Vec<Vec<Rank>>,
}

impl Pipeline {
    /// All ranks of the pipeline.
    pub fn ranks(&self) -> Vec<Rank> {
        self.stages.iter().flatten().copied().collect()
    }

    /// Stage index of `rank`, if a member.
    pub fn stage_of(&self, rank: Rank) -> Option<usize> {
        self.stages.iter().position(|s| s.contains(&rank))
    }
}

/// All pipelines discovered in a specialized graph.
#[derive(Clone, Debug, Default)]
pub struct PipelineSet {
    /// Independent pipelines (may process different microbatch counts).
    pub pipelines: Vec<Pipeline>,
}

/// Union-find over ranks (stage merging).
struct Uf {
    parent: HashMap<Rank, Rank>,
}

impl Uf {
    fn new() -> Self {
        Uf { parent: HashMap::new() }
    }
    fn find(&mut self, x: Rank) -> Rank {
        let p = *self.parent.get(&x).unwrap_or(&x);
        if p == x {
            x
        } else {
            let root = self.find(p);
            self.parent.insert(x, root);
            root
        }
    }
    fn union(&mut self, a: Rank, b: Rank) {
        let (ra, rb) = (self.find(a), self.find(b));
        if ra != rb {
            self.parent.insert(ra, rb);
        }
    }
}

/// Whether a CommOp participates in per-microbatch scheduling: true iff its
/// input depends (transitively) on a `Placeholder` — parameter-only
/// transforms run once and are excluded (§5.4).
pub fn is_scheduled_comm(g: &Graph, op: OpId) -> bool {
    fn depends_on_placeholder(g: &Graph, t: usize, memo: &mut HashMap<usize, bool>) -> bool {
        if let Some(&v) = memo.get(&t) {
            return v;
        }
        let v = match g.tensors[t].producer {
            None => false,
            Some(p) => match g.ops[p].kind {
                OpKind::Placeholder => true,
                _ => g.ops[p]
                    .inputs
                    .clone()
                    .into_iter()
                    .any(|i| depends_on_placeholder(g, i, memo)),
            },
        };
        memo.insert(t, v);
        v
    }
    let mut memo = HashMap::new();
    g.ops[op].inputs.iter().any(|&t| depends_on_placeholder(g, t, &mut memo))
}

/// Build pipelines from the resolved CommOps (§5.4) under strategy `k`.
///
/// `resolutions` maps CommOp ids → their §4 resolutions; `all_ranks` is the
/// full device set of the strategy (devices that never communicate form
/// single-device pipelines).
pub fn build_pipelines(
    g: &Graph,
    k: usize,
    resolutions: &HashMap<OpId, Resolution>,
    all_ranks: &[Rank],
) -> Result<PipelineSet> {
    let mut uf = Uf::new();
    // edges between stage roots (P2P: predecessor → successor)
    let mut edges: BTreeSet<(Rank, Rank)> = BTreeSet::new();

    let merge_collective = |uf: &mut Uf, plan: &CommPlan| {
        for leaf in plan.leaves() {
            if let CommPlan::Collective { ops, top_tier } = leaf {
                if *top_tier {
                    continue; // cross-subgroup sync does not merge pipelines
                }
                for op in ops {
                    for w in op.group.windows(2) {
                        uf.union(w[0], w[1]);
                    }
                }
            }
        }
    };

    // First pass: merge collective peers into stages. TP/CP groups are
    // joined by their activation-sync collectives (AR/RS/AG); DP replicas
    // never share a bottom-tier collective on the activation path, so they
    // correctly remain in separate pipelines.
    let _ = k;
    for (&op_id, res) in resolutions.iter() {
        if !is_scheduled_comm(g, op_id) {
            continue;
        }
        merge_collective(&mut uf, &res.plan);
    }

    // Second pass: P2P chains become stage successors.
    for (&op_id, res) in resolutions.iter() {
        if !is_scheduled_comm(g, op_id) {
            continue;
        }
        for leaf in res.plan.leaves() {
            let pairs: Vec<(Rank, Rank)> = match leaf {
                CommPlan::SendRecv(ts) => ts.iter().map(|t| (t.from, t.to)).collect(),
                CommPlan::Bsr(p) => p.transfers.iter().map(|t| (t.from, t.to)).collect(),
                _ => vec![],
            };
            for (from, to) in pairs {
                let (rf, rt) = (uf.find(from), uf.find(to));
                if rf != rt {
                    edges.insert((rf, rt));
                }
            }
        }
    }

    // Collect stages: root → members.
    let mut stages: BTreeMap<Rank, Vec<Rank>> = BTreeMap::new();
    for &r in all_ranks {
        stages.entry(uf.find(r)).or_default().push(r);
    }
    for members in stages.values_mut() {
        members.sort_unstable();
    }

    // Re-root edges after all unions.
    let edges: BTreeSet<(Rank, Rank)> = edges
        .into_iter()
        .map(|(a, b)| (uf.find(a), uf.find(b)))
        .filter(|(a, b)| a != b)
        .collect();

    // Weakly-connected components of the stage graph = pipelines; order
    // stages inside each component topologically (Kahn, deterministic).
    let mut comp_uf = Uf::new();
    for &(a, b) in &edges {
        comp_uf.union(a, b);
    }
    let mut components: BTreeMap<Rank, Vec<Rank>> = BTreeMap::new();
    for &root in stages.keys() {
        components.entry(comp_uf.find(root)).or_default().push(root);
    }

    let mut pipelines = vec![];
    for (_, mut roots) in components {
        roots.sort_unstable();
        // topological order by P2P edges
        let mut indeg: BTreeMap<Rank, usize> = roots.iter().map(|&r| (r, 0)).collect();
        for &(a, b) in &edges {
            if indeg.contains_key(&a) && indeg.contains_key(&b) {
                *indeg.get_mut(&b).unwrap() += 1;
                let _ = a;
            }
        }
        let mut ready: Vec<Rank> =
            indeg.iter().filter(|&(_, &d)| d == 0).map(|(&r, _)| r).collect();
        ready.sort_unstable();
        let mut order = vec![];
        while let Some(r) = ready.first().copied() {
            ready.remove(0);
            order.push(r);
            for &(a, b) in &edges {
                if a == r {
                    if let Some(d) = indeg.get_mut(&b) {
                        *d -= 1;
                        if *d == 0 {
                            ready.push(b);
                            ready.sort_unstable();
                        }
                    }
                }
            }
        }
        // cycles (e.g. bidirectional P2P) — fall back to root order
        if order.len() != roots.len() {
            order = roots.clone();
        }
        pipelines.push(Pipeline {
            stages: order.into_iter().map(|r| stages[&r].clone()).collect(),
        });
    }
    Ok(PipelineSet { pipelines })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::{resolve, BsrOptions, UniformBandwidth};
    use crate::graph::{lits, DType};
    use crate::hspmd::ds::{DUPLICATE, PARTIAL};
    use crate::hspmd::{Annotation, DeviceGroup, DistStates};

    /// Build a 2-stage TP2 pipeline graph: stage0 = {0,1} (TP pair),
    /// stage1 = {2,3} (TP pair); activations AR within stage, SR between.
    fn two_stage_graph() -> (Graph, HashMap<OpId, Resolution>, Vec<Rank>) {
        let mut g = Graph::new(1);
        let s0 = |entries: &[(i32, u32)], order: &[i32]| {
            Annotation::spmd(DeviceGroup::range(0, 2), DistStates::new(entries, order).unwrap())
                .unwrap()
        };
        let s1 = |entries: &[(i32, u32)], order: &[i32]| {
            Annotation::spmd(DeviceGroup::range(2, 4), DistStates::new(entries, order).unwrap())
                .unwrap()
        };
        let x = g
            .placeholder("X", lits(&[8, 16]), DType::F32, vec![s0(&[(PARTIAL, 2)], &[-2])])
            .unwrap();
        // stage-0 TP output sync: partial -> dup on {0,1} (AllReduce)
        let x_sync = g.comm(x, vec![s0(&[(DUPLICATE, 2)], &[-1])]).unwrap();
        // stage boundary: scatter the activation to stage 1's TP pair (SR/BSR)
        let x_next = g.comm(x_sync, vec![s1(&[(0, 2)], &[0])]).unwrap();
        // stage-1 TP input gather: split -> dup on {2,3} (AllGather)
        let x_gathered = g.comm(x_next, vec![s1(&[(DUPLICATE, 2)], &[-1])]).unwrap();
        let _ = x_gathered;
        let mut resolutions = HashMap::new();

        crate::graph::deduce::deduce(&mut g, 0).unwrap();
        for op in g.topo().to_vec() {
            if matches!(op.kind, OpKind::Comm) {
                let src = g.tensor(op.inputs[0]).annotation(0).unwrap().clone();
                let dst = g.tensor(op.outputs[0]).annotation(0).unwrap().clone();
                let res =
                    resolve(&src, &dst, &[8, 16], &UniformBandwidth, BsrOptions::default())
                        .unwrap();
                resolutions.insert(op.id, res);
            }
        }
        (g, resolutions, vec![0, 1, 2, 3])
    }

    #[test]
    fn collective_merges_p2p_chains() {
        let (g, res, ranks) = two_stage_graph();
        let ps = build_pipelines(&g, 0, &res, &ranks).unwrap();
        assert_eq!(ps.pipelines.len(), 1, "{ps:?}");
        let p = &ps.pipelines[0];
        assert_eq!(p.stages.len(), 2, "{p:?}");
        assert_eq!(p.stages[0], vec![0, 1]);
        assert_eq!(p.stages[1], vec![2, 3]);
    }

    #[test]
    fn independent_devices_form_own_pipelines() {
        let g = Graph::new(1);
        let res = HashMap::new();
        let ps = build_pipelines(&g, 0, &res, &[0, 1, 2]).unwrap();
        assert_eq!(ps.pipelines.len(), 3);
        assert!(ps.pipelines.iter().all(|p| p.stages.len() == 1));
    }

    #[test]
    fn parameter_only_comm_excluded() {
        // A parameter-side CommOp (no placeholder dependency) must not
        // merge devices.
        let mut g = Graph::new(1);
        let a = Annotation::spmd(DeviceGroup::range(0, 2), DistStates::duplicate(2)).unwrap();
        let w = g.parameter("W", lits(&[4]), DType::F32, vec![a]).unwrap();
        let b = Annotation::spmd(DeviceGroup::range(0, 2), DistStates::split(0, 2)).unwrap();
        let wc = g.comm(w, vec![b]).unwrap();
        let _ = wc;
        crate::graph::deduce::deduce(&mut g, 0).unwrap();
        let comm_id = g
            .topo()
            .iter()
            .find(|o| matches!(o.kind, OpKind::Comm))
            .unwrap()
            .id;
        assert!(!is_scheduled_comm(&g, comm_id));
        let src = g.tensor(g.ops[comm_id].inputs[0]).annotation(0).unwrap().clone();
        let dst = g.tensor(g.ops[comm_id].outputs[0]).annotation(0).unwrap().clone();
        let res = resolve(&src, &dst, &[4], &UniformBandwidth, BsrOptions::default()).unwrap();
        let mut m = HashMap::new();
        m.insert(comm_id, res);
        let ps = build_pipelines(&g, 0, &m, &[0, 1]).unwrap();
        assert_eq!(ps.pipelines.len(), 2);
    }

    #[test]
    fn stage_of_lookup() {
        let p = Pipeline { stages: vec![vec![0, 1], vec![2]] };
        assert_eq!(p.stage_of(1), Some(0));
        assert_eq!(p.stage_of(2), Some(1));
        assert_eq!(p.stage_of(9), None);
    }
}
