//! The top-level trainer: config → strategy → engine → training loop, with
//! dynamic strategy switching (the Hetu-B loop) and loss-curve logging.

use crate::cluster::Cluster;
use crate::config::RunConfig;
use crate::engine::{Engine, EngineStrategy, MicroBatch, StepStats, WindowShape};
use crate::testutil::Rng;
use crate::{Error, Result};

/// One completed step's log line.
#[derive(Clone, Debug)]
pub struct StepLog {
    /// Step index.
    pub step: u64,
    /// Strategy name the step ran under.
    pub strategy: String,
    /// Mean loss.
    pub loss: f32,
    /// Wall-clock seconds.
    pub wall_s: f64,
    /// Estimated parallel step seconds (measured per-task durations
    /// replayed through the schedule — see
    /// [`StepStats::makespan_s`](crate::engine::StepStats)).
    pub makespan_s: f64,
    /// Elements on the (simulated) wire.
    pub wire_elems: u64,
}

/// Synthetic next-token corpus: a fixed bank of token motifs shared across
/// the whole corpus (so transitions are *learnable*), each sequence
/// repeating one motif with light noise. Deterministic per seed.
pub struct SyntheticCorpus {
    rng: Rng,
    vocab: i32,
    motifs: Vec<Vec<i32>>,
}

/// Motif bank size (distinct learnable patterns).
const NUM_MOTIFS: usize = 32;
/// Motif length.
const MOTIF_LEN: usize = 5;

impl SyntheticCorpus {
    /// New corpus over `vocab` tokens.
    pub fn new(seed: u64, vocab: usize) -> SyntheticCorpus {
        let mut rng = Rng::new(seed);
        let motifs = (0..NUM_MOTIFS)
            .map(|_| (0..MOTIF_LEN).map(|_| rng.below(vocab as u64) as i32).collect())
            .collect();
        SyntheticCorpus { rng, vocab: vocab as i32, motifs }
    }

    /// One `[b, s]` micro-batch (tokens + shifted targets, no padding).
    pub fn microbatch(&mut self, b: usize, s: usize) -> MicroBatch {
        self.window(&vec![s; b], s)
    }

    /// One ragged `[rows.len(), seq_len]` micro-batch: row `i` carries
    /// `rows[i]` real tokens of motif stream and is right-padded with
    /// token 0 / target `-1` (the padding mask) up to `seq_len`. With
    /// every row full this is exactly [`SyntheticCorpus::microbatch`] —
    /// same rng draws, same data.
    pub fn window(&mut self, rows: &[usize], seq_len: usize) -> MicroBatch {
        let n = rows.len() * seq_len;
        let mut inp = Vec::with_capacity(n);
        let mut tgt = Vec::with_capacity(n);
        for &rl in rows {
            let rl = rl.min(seq_len);
            let motif = self.rng.pick(&self.motifs).clone();
            let phase = self.rng.range(0, MOTIF_LEN - 1);
            let mut row = Vec::with_capacity(rl + 1);
            for i in 0..rl + 1 {
                if self.rng.chance(0.02) {
                    row.push(self.rng.below(self.vocab as u64) as i32);
                } else {
                    row.push(motif[(i + phase) % MOTIF_LEN]);
                }
            }
            inp.extend_from_slice(&row[..rl]);
            tgt.extend_from_slice(&row[1..rl + 1]);
            inp.extend(std::iter::repeat(0).take(seq_len - rl));
            tgt.extend(std::iter::repeat(-1).take(seq_len - rl));
        }
        MicroBatch { tokens: inp, targets: tgt, n_seqs: rows.len(), seq_len }
    }

    /// The micro-batch for one prescribed [`WindowShape`] slot.
    pub fn window_for(&mut self, shape: &WindowShape) -> MicroBatch {
        self.window(&shape.rows, shape.seq_len)
    }
}

/// The trainer.
pub struct Trainer {
    /// Engine (owns runtime + mesh).
    pub engine: Engine,
    corpus: SyntheticCorpus,
    cfg: RunConfig,
    logs: Vec<StepLog>,
}

impl Trainer {
    /// Build a trainer from a run config and an initial strategy.
    pub fn new(cfg: RunConfig, strategy: EngineStrategy) -> Result<Trainer> {
        let engine = Engine::new(&cfg.artifacts_dir, strategy, cfg.seed, cfg.lr as f32)?;
        let corpus = SyntheticCorpus::new(cfg.seed ^ 0xDA7A, engine.runtime.config.vocab);
        Ok(Trainer { engine, corpus, cfg, logs: vec![] })
    }

    /// Run `steps` training steps; returns the per-step logs.
    pub fn train(&mut self, steps: u64) -> Result<&[StepLog]> {
        let b = self.engine.runtime.config.batch;
        let s = self.engine.runtime.config.seq;
        for _ in 0..steps {
            let t0 = std::time::Instant::now();
            let corpus = &mut self.corpus;
            let stats: StepStats = self
                .engine
                .train_step(&mut |_pipe, _mb| corpus.microbatch(b, s))?;
            let step = self.logs.len() as u64;
            self.logs.push(StepLog {
                step,
                strategy: self.engine.strategy.name.clone(),
                loss: stats.loss,
                wall_s: t0.elapsed().as_secs_f64(),
                makespan_s: stats.makespan_s,
                wire_elems: stats.wire_elems,
            });
        }
        Ok(&self.logs)
    }

    /// Attach the physical topology behind the engine's device ids so
    /// switches use bandwidth-aware sender selection (BSR heuristic 2).
    pub fn set_topology(&mut self, topology: Cluster) {
        self.engine.set_topology(topology);
    }

    /// Switch the running strategy (graph switching §6 at engine level).
    /// Returns `(messages, elems moved)`.
    pub fn switch(&mut self, new: EngineStrategy) -> Result<(u64, u64)> {
        self.engine.switch_to(new)
    }

    /// [`Trainer::switch`] for elastic failover: `dead` devices are
    /// excluded as weight sources when executing the fused-BSR transition
    /// (§7.2 — surviving DP replicas supply their slices).
    pub fn switch_avoiding(&mut self, new: EngineStrategy, dead: &[usize]) -> Result<(u64, u64)> {
        let report = self.engine.switch_to_avoiding(new, dead)?;
        Ok((report.messages, report.wire_elems))
    }

    /// All logs so far.
    pub fn logs(&self) -> &[StepLog] {
        &self.logs
    }

    /// The run config.
    pub fn config(&self) -> &RunConfig {
        &self.cfg
    }

    /// Verify the loss curve decreased (end-to-end sanity used by the
    /// examples and EXPERIMENTS.md).
    pub fn loss_improved(&self) -> Result<(f32, f32)> {
        if self.logs.len() < 2 {
            return Err(Error::Engine("not enough steps to assess loss".into()));
        }
        let k = (self.logs.len() / 4).max(1);
        let head: f32 = self.logs[..k].iter().map(|l| l.loss).sum::<f32>() / k as f32;
        let tail: f32 =
            self.logs[self.logs.len() - k..].iter().map(|l| l.loss).sum::<f32>() / k as f32;
        Ok((head, tail))
    }
}
