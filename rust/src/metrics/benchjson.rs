//! Machine-readable benchmark emission: the perf trajectory.
//!
//! Each bench harness builds a [`BenchReport`], appends one row per
//! measurement (tagged `"wall"` for wall-clock timings or `"modeled"` for
//! replayed simulator/cost-model estimates — the two must never be
//! conflated), and writes `BENCH_<name>.json` next to `Cargo.toml`. Every
//! report carries the git revision and the run configuration so
//! `tools/bench_compare.py` can diff a fresh run against the committed
//! checkpoint under `bench/baseline/` and fail CI on a >20% regression in
//! the guarded rows.
//!
//! The writer is hand-rolled (no serde in the image): the schema is flat
//! enough that escaping strings and formatting finite floats covers it.

use std::fmt::Write as _;

/// One measurement row: a named quantity, how it was obtained, and the
/// mean/best seconds over the bench iterations.
#[derive(Clone, Debug)]
pub struct BenchRow {
    /// Row name — must match the printed bench row so humans and the
    /// compare script read the same trajectory.
    pub name: String,
    /// `"wall"` (measured wall-clock) or `"modeled"` (replayed estimate).
    pub kind: String,
    /// Mean seconds across iterations.
    pub mean_s: f64,
    /// Best (minimum) seconds across iterations.
    pub best_s: f64,
    /// Optional named side-columns (e.g. a step's measured
    /// compute/comm/bubble/switch breakdown). Empty for plain rows;
    /// emitted as a `"cols"` object when present.
    pub cols: Vec<(String, f64)>,
}

/// A bench run's machine-readable output: rows plus provenance tags.
#[derive(Clone, Debug)]
pub struct BenchReport {
    /// Short bench name; the file is written as `BENCH_<bench>.json`.
    pub bench: String,
    /// Git revision the run was built from (`"unknown"` outside a repo).
    pub rev: String,
    /// Smoke runs (`--test`) time a single iteration — the compare
    /// script skips ratio checks on them.
    pub smoke: bool,
    /// Free-form configuration tags (backend, model size, schedules…).
    pub config: Vec<(String, String)>,
    /// Measurement rows in emission order.
    pub rows: Vec<BenchRow>,
}

impl BenchReport {
    /// New report for `bench`, stamping the current git revision.
    pub fn new(bench: &str, smoke: bool) -> BenchReport {
        BenchReport {
            bench: bench.to_string(),
            rev: git_rev(),
            smoke,
            config: vec![],
            rows: vec![],
        }
    }

    /// Attach a configuration tag.
    pub fn tag(&mut self, key: &str, value: &str) -> &mut Self {
        self.config.push((key.to_string(), value.to_string()));
        self
    }

    /// Record a measurement row.
    pub fn row(&mut self, name: &str, kind: &str, mean_s: f64, best_s: f64) -> &mut Self {
        self.rows.push(BenchRow {
            name: name.to_string(),
            kind: kind.to_string(),
            mean_s,
            best_s,
            cols: vec![],
        });
        self
    }

    /// Record a measurement row carrying named side-columns (per-step
    /// breakdown components and the like).
    pub fn row_cols(
        &mut self,
        name: &str,
        kind: &str,
        mean_s: f64,
        best_s: f64,
        cols: &[(&str, f64)],
    ) -> &mut Self {
        self.rows.push(BenchRow {
            name: name.to_string(),
            kind: kind.to_string(),
            mean_s,
            best_s,
            cols: cols.iter().map(|(k, v)| (k.to_string(), *v)).collect(),
        });
        self
    }

    /// Render the report as a JSON document.
    pub fn json(&self) -> String {
        let mut out = String::new();
        out.push_str("{\n");
        let _ = writeln!(out, "  \"bench\": {},", quote(&self.bench));
        let _ = writeln!(out, "  \"rev\": {},", quote(&self.rev));
        let _ = writeln!(out, "  \"smoke\": {},", self.smoke);
        out.push_str("  \"config\": {");
        for (i, (k, v)) in self.config.iter().enumerate() {
            if i > 0 {
                out.push_str(", ");
            }
            let _ = write!(out, "{}: {}", quote(k), quote(v));
        }
        out.push_str("},\n  \"rows\": [\n");
        for (i, r) in self.rows.iter().enumerate() {
            let _ = write!(
                out,
                "    {{\"name\": {}, \"kind\": {}, \"mean_s\": {}, \"best_s\": {}",
                quote(&r.name),
                quote(&r.kind),
                num(r.mean_s),
                num(r.best_s)
            );
            if !r.cols.is_empty() {
                out.push_str(", \"cols\": {");
                for (j, (k, v)) in r.cols.iter().enumerate() {
                    if j > 0 {
                        out.push_str(", ");
                    }
                    let _ = write!(out, "{}: {}", quote(k), num(*v));
                }
                out.push('}');
            }
            out.push('}');
            out.push_str(if i + 1 < self.rows.len() { ",\n" } else { "\n" });
        }
        out.push_str("  ]\n}\n");
        out
    }

    /// Write `BENCH_<bench>.json` next to `Cargo.toml` (falling back to
    /// the working directory) and return the path written.
    pub fn write(&self) -> std::io::Result<std::path::PathBuf> {
        let dir = std::env::var_os("CARGO_MANIFEST_DIR")
            .map(std::path::PathBuf::from)
            .unwrap_or_else(|| std::path::PathBuf::from("."));
        let path = dir.join(format!("BENCH_{}.json", self.bench));
        std::fs::write(&path, self.json())?;
        Ok(path)
    }
}

/// JSON string escape (quotes, backslashes, control chars).
fn quote(s: &str) -> String {
    let mut out = String::with_capacity(s.len() + 2);
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
    out
}

/// Finite JSON number (JSON has no NaN/Inf — those become `null`).
fn num(v: f64) -> String {
    if v.is_finite() {
        format!("{v:e}")
    } else {
        "null".to_string()
    }
}

/// `git rev-parse --short HEAD`, or `"unknown"` when git is unavailable.
fn git_rev() -> String {
    git_rev_in(None)
}

/// [`git_rev`] with an explicit working directory (`None` inherits the
/// process cwd). Every failure mode — git binary missing, `dir` not a
/// repository, non-UTF-8 output, empty output — degrades to `"unknown"`
/// instead of erroring: the bench must still emit its report outside a
/// checkout (e.g. an unpacked source tarball in CI).
fn git_rev_in(dir: Option<&std::path::Path>) -> String {
    let mut cmd = std::process::Command::new("git");
    cmd.args(["rev-parse", "--short", "HEAD"]);
    if let Some(d) = dir {
        cmd.current_dir(d);
    }
    cmd.output()
        .ok()
        .filter(|o| o.status.success())
        .and_then(|o| String::from_utf8(o.stdout).ok())
        .map(|s| s.trim().to_string())
        .filter(|s| !s.is_empty())
        .unwrap_or_else(|| "unknown".to_string())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn report_renders_valid_flat_json() {
        let mut r = BenchReport::new("demo", true);
        r.tag("backend", "native").tag("model", "tiny-48");
        r.row("step \"a\"", "wall", 1.5e-3, 1.25e-3);
        r.row("replay", "modeled", f64::NAN, 2.0);
        let j = r.json();
        assert!(j.contains("\"bench\": \"demo\""));
        assert!(j.contains("\"smoke\": true"));
        assert!(j.contains("\"backend\": \"native\", \"model\": \"tiny-48\""));
        assert!(j.contains("\"name\": \"step \\\"a\\\"\""));
        assert!(j.contains("\"kind\": \"modeled\""));
        assert!(j.contains("\"mean_s\": null"));
        assert!(!j.contains("NaN"));
        // balanced braces/brackets ⇒ parseable by the compare script
        assert_eq!(j.matches('{').count(), j.matches('}').count());
        assert_eq!(j.matches('[').count(), j.matches(']').count());
    }

    #[test]
    fn cols_rows_emit_a_cols_object() {
        let mut r = BenchReport::new("demo", true);
        r.row_cols("step 0", "wall", 1.0, 1.0, &[("compute_s", 0.5), ("comm_s", 0.25)]);
        r.row("plain", "modeled", 1.0, 1.0);
        let j = r.json();
        assert!(j.contains("\"cols\": {\"compute_s\": 5e-1, \"comm_s\": 2.5e-1}"));
        assert_eq!(j.matches("\"cols\"").count(), 1, "plain rows omit the object");
        assert_eq!(j.matches('{').count(), j.matches('}').count());
        assert_eq!(j.matches('[').count(), j.matches(']').count());
    }

    #[test]
    fn rev_is_nonempty() {
        assert!(!git_rev().is_empty());
    }

    #[test]
    fn rev_falls_back_to_unknown_outside_a_repo() {
        // `/` is never a git repository: rev-parse fails (or git itself
        // is absent) and the stamp must degrade to "unknown", never an
        // error or an empty string
        assert_eq!(git_rev_in(Some(std::path::Path::new("/"))), "unknown");
    }
}
