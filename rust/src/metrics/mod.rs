//! Reporting utilities: markdown/CSV tables and wall-clock timers used by
//! the bench harnesses to regenerate the paper's tables and figures.

use std::fmt::Write as _;
use std::time::Instant;

pub mod benchjson;

/// A simple column-aligned table that renders to markdown or CSV.
#[derive(Clone, Debug, Default)]
pub struct Table {
    /// Table title.
    pub title: String,
    /// Column headers.
    pub headers: Vec<String>,
    /// Rows of cells.
    pub rows: Vec<Vec<String>>,
}

impl Table {
    /// New table.
    pub fn new(title: &str, headers: &[&str]) -> Table {
        Table {
            title: title.to_string(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: vec![],
        }
    }

    /// Append a row.
    pub fn row(&mut self, cells: Vec<String>) -> &mut Self {
        assert_eq!(cells.len(), self.headers.len(), "row arity mismatch");
        self.rows.push(cells);
        self
    }

    /// Render as GitHub-flavoured markdown.
    pub fn markdown(&self) -> String {
        let mut out = String::new();
        let _ = writeln!(out, "\n### {}\n", self.title);
        let widths: Vec<usize> = self
            .headers
            .iter()
            .enumerate()
            .map(|(i, h)| {
                self.rows
                    .iter()
                    .map(|r| r[i].len())
                    .chain(std::iter::once(h.len()))
                    .max()
                    .unwrap_or(1)
            })
            .collect();
        let line = |cells: &[String], out: &mut String| {
            let padded: Vec<String> = cells
                .iter()
                .zip(widths.iter())
                .map(|(c, w)| format!("{c:<w$}"))
                .collect();
            let _ = writeln!(out, "| {} |", padded.join(" | "));
        };
        line(&self.headers, &mut out);
        let dashes: Vec<String> = widths.iter().map(|w| "-".repeat(*w)).collect();
        let _ = writeln!(out, "|-{}-|", dashes.join("-|-"));
        for r in &self.rows {
            line(r, &mut out);
        }
        out
    }

    /// Render as CSV.
    pub fn csv(&self) -> String {
        let mut out = String::new();
        let _ = writeln!(out, "{}", self.headers.join(","));
        for r in &self.rows {
            let _ = writeln!(out, "{}", r.join(","));
        }
        out
    }
}

/// Format seconds with adaptive precision.
pub fn fmt_s(s: f64) -> String {
    if s >= 100.0 {
        format!("{s:.0}s")
    } else if s >= 1.0 {
        format!("{s:.2}s")
    } else if s >= 1e-3 {
        format!("{:.2}ms", s * 1e3)
    } else {
        format!("{:.1}us", s * 1e6)
    }
}

/// Format byte counts as MB (the Table 2 unit).
pub fn fmt_mb(bytes: u64) -> String {
    format!("{}", bytes / (1 << 20))
}

/// Simple statistics over a sample (for the Fig 15 box plots).
#[derive(Clone, Copy, Debug)]
pub struct Stats {
    /// Minimum.
    pub min: f64,
    /// 25th percentile.
    pub p25: f64,
    /// Median.
    pub p50: f64,
    /// 75th percentile.
    pub p75: f64,
    /// Maximum.
    pub max: f64,
    /// Mean.
    pub mean: f64,
}

impl Stats {
    /// Compute from samples (panics on empty input).
    pub fn of(samples: &[f64]) -> Stats {
        assert!(!samples.is_empty());
        let mut s = samples.to_vec();
        s.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let q = |p: f64| {
            let idx = (p * (s.len() - 1) as f64).round() as usize;
            s[idx]
        };
        Stats {
            min: s[0],
            p25: q(0.25),
            p50: q(0.50),
            p75: q(0.75),
            max: s[s.len() - 1],
            mean: s.iter().sum::<f64>() / s.len() as f64,
        }
    }
}

/// Wall-clock stopwatch for §Perf measurements.
pub struct Stopwatch {
    start: Instant,
}

impl Stopwatch {
    /// Start timing.
    pub fn start() -> Stopwatch {
        Stopwatch { start: Instant::now() }
    }

    /// Elapsed seconds.
    pub fn s(&self) -> f64 {
        self.start.elapsed().as_secs_f64()
    }
}

/// Time a closure over `iters` iterations, returning (mean_s, best_s).
pub fn bench<F: FnMut()>(iters: u32, mut f: F) -> (f64, f64) {
    let mut best = f64::INFINITY;
    let mut total = 0.0;
    for _ in 0..iters {
        let t = Instant::now();
        f();
        let dt = t.elapsed().as_secs_f64();
        best = best.min(dt);
        total += dt;
    }
    (total / iters as f64, best)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table_renders_markdown_and_csv() {
        let mut t = Table::new("Demo", &["a", "b"]);
        t.row(vec!["1".into(), "2".into()]);
        let md = t.markdown();
        assert!(md.contains("### Demo"));
        assert!(md.contains("| 1 | 2 |"));
        let csv = t.csv();
        assert!(csv.starts_with("a,b\n1,2"));
    }

    #[test]
    #[should_panic]
    fn row_arity_checked() {
        let mut t = Table::new("x", &["a", "b"]);
        t.row(vec!["1".into()]);
    }

    #[test]
    fn stats_quartiles() {
        let s = Stats::of(&[1.0, 2.0, 3.0, 4.0, 5.0]);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.p50, 3.0);
        assert_eq!(s.max, 5.0);
        assert_eq!(s.mean, 3.0);
    }

    #[test]
    fn fmt_helpers() {
        assert_eq!(fmt_s(2.5), "2.50s");
        assert_eq!(fmt_s(0.0025), "2.50ms");
        assert_eq!(fmt_mb(10 << 20), "10");
    }

    #[test]
    fn bench_returns_positive() {
        let (mean, best) = bench(3, || {
            std::hint::black_box((0..1000).sum::<u64>());
        });
        assert!(mean >= best && best >= 0.0);
    }
}
