//! §5.1–5.2 — The user-defined computation graph and its annotation state.
//!
//! A [`Graph`] is a DAG of [`Op`]s over [`Tensor`]s. Leaf ops (placeholders,
//! parameters) and [`OpKind::Comm`] ops carry *explicit* HSPMD annotations —
//! one per parallel strategy (§6.1 multiple annotations); all other tensors'
//! annotations are *deduced* ([`deduce`]). Specialization (§5.3–5.4) then
//! turns the annotated graph into per-device executable graphs.

pub mod deduce;
pub mod symbolic;

pub use symbolic::{lits, Binding, SymDim};

use crate::hspmd::Annotation;
use crate::{Error, Result};

/// Tensor handle.
pub type TensorId = usize;
/// Operator handle.
pub type OpId = usize;

/// Element types we track (compute artifacts are f32 on the CPU path;
/// bf16 is modeled for volume accounting).
#[derive(Clone, Copy, PartialEq, Eq, Debug, Hash)]
pub enum DType {
    /// 32-bit float.
    F32,
    /// bfloat16 (modeled; PJRT CPU artifacts run f32).
    Bf16,
    /// 32-bit int (token ids).
    I32,
}

impl DType {
    /// Bytes per element.
    pub fn bytes(&self) -> u64 {
        match self {
            DType::F32 | DType::I32 => 4,
            DType::Bf16 => 2,
        }
    }
}

/// Unary elementwise operators (annotation-transparent).
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum UnaryKind {
    /// GELU activation (the paper's running example).
    Gelu,
    /// RMSNorm (block-level marker; sharding-transparent on the batch dims).
    RmsNorm,
    /// Softmax over the last dim (transparent unless last dim is sharded).
    Softmax,
}

/// Operator kinds. The set mirrors the paper's discussion: most ops
/// propagate annotations unchanged; `Dot`, `Sum` and `Reshape` have
/// specialized deduction; `Comm` explicitly re-annotates (§5.1).
#[derive(Clone, Debug)]
pub enum OpKind {
    /// Graph input (leaf; explicitly annotated).
    Placeholder,
    /// Trainable parameter (leaf; explicitly annotated).
    Parameter,
    /// Explicit annotation transformation — the CommOp (§5.1).
    Comm,
    /// Elementwise unary op.
    Unary(UnaryKind),
    /// Elementwise binary add (annotations must match).
    Add,
    /// Matrix product `X[..., k] @ W[k, n]` (Fig 11 deduction).
    Dot,
    /// Reduction over one physical dimension.
    Sum {
        /// Reduced dim.
        dim: u32,
    },
    /// Shape change; sharding must be preserved on dim 0 (the only case the
    /// deduction supports — matching Hetu's "specialized deduction logic").
    Reshape,
    /// Engine-level compute backed by an AOT artifact (treated as
    /// annotation-transparent; its sharding contract is set via CommOps).
    ArtifactCall {
        /// Artifact name in the registry.
        artifact: String,
    },
}

/// A tensor: metadata plus per-strategy annotations.
#[derive(Clone, Debug)]
pub struct Tensor {
    /// Stable name.
    pub name: String,
    /// Symbolic shape (§5.5).
    pub shape: Vec<SymDim>,
    /// Element type.
    pub dtype: DType,
    /// Producing op (`None` for leaves until wired).
    pub producer: Option<OpId>,
    /// Per-strategy annotations. `annotations[k]` is `Some` once declared
    /// (leaves/CommOps) or deduced (§5.2).
    pub annotations: Vec<Option<Annotation>>,
}

impl Tensor {
    /// The annotation under strategy `k`, if available.
    pub fn annotation(&self, k: usize) -> Option<&Annotation> {
        self.annotations.get(k).and_then(|a| a.as_ref())
    }
}

/// An operator node.
#[derive(Clone, Debug)]
pub struct Op {
    /// Node id.
    pub id: OpId,
    /// Kind + attributes.
    pub kind: OpKind,
    /// Input tensor ids.
    pub inputs: Vec<TensorId>,
    /// Output tensor ids.
    pub outputs: Vec<TensorId>,
    /// For leaves and CommOps: the explicit per-strategy annotations of the
    /// output (§6.1 multiple annotations).
    pub declared: Vec<Option<Annotation>>,
}

/// The computation graph (ops are stored in topological construction order).
#[derive(Clone, Debug, Default)]
pub struct Graph {
    /// All ops.
    pub ops: Vec<Op>,
    /// All tensors.
    pub tensors: Vec<Tensor>,
    /// Number of strategies annotated so far.
    pub num_strategies: usize,
}

impl Graph {
    /// Empty graph supporting `num_strategies` parallel strategies.
    pub fn new(num_strategies: usize) -> Self {
        Graph { ops: vec![], tensors: vec![], num_strategies }
    }

    fn add_tensor(&mut self, name: &str, shape: Vec<SymDim>, dtype: DType) -> TensorId {
        let id = self.tensors.len();
        self.tensors.push(Tensor {
            name: name.to_string(),
            shape,
            dtype,
            producer: None,
            annotations: vec![None; self.num_strategies],
        });
        id
    }

    fn add_op(&mut self, kind: OpKind, inputs: Vec<TensorId>, out: TensorId) -> OpId {
        let id = self.ops.len();
        self.ops.push(Op { id, kind, inputs, outputs: vec![out], declared: vec![] });
        self.tensors[out].producer = Some(id);
        id
    }

    fn check_strategies(&self, anns: &[Annotation]) -> Result<()> {
        if anns.len() != self.num_strategies {
            return Err(Error::Graph(format!(
                "expected {} per-strategy annotations, got {}",
                self.num_strategies,
                anns.len()
            )));
        }
        Ok(())
    }

    /// Add a placeholder (graph input) with explicit per-strategy
    /// annotations.
    pub fn placeholder(
        &mut self,
        name: &str,
        shape: Vec<SymDim>,
        dtype: DType,
        anns: Vec<Annotation>,
    ) -> Result<TensorId> {
        self.check_strategies(&anns)?;
        let t = self.add_tensor(name, shape, dtype);
        let op = self.add_op(OpKind::Placeholder, vec![], t);
        self.tensors[t].annotations = anns.iter().cloned().map(Some).collect();
        self.ops[op].declared = anns.into_iter().map(Some).collect();
        Ok(t)
    }

    /// Add a parameter with explicit per-strategy annotations.
    pub fn parameter(
        &mut self,
        name: &str,
        shape: Vec<SymDim>,
        dtype: DType,
        anns: Vec<Annotation>,
    ) -> Result<TensorId> {
        self.check_strategies(&anns)?;
        let t = self.add_tensor(name, shape, dtype);
        let op = self.add_op(OpKind::Parameter, vec![], t);
        self.tensors[t].annotations = anns.iter().cloned().map(Some).collect();
        self.ops[op].declared = anns.into_iter().map(Some).collect();
        Ok(t)
    }

    /// Insert a CommOp re-annotating `input` to the per-strategy targets
    /// (§5.1, `hetu.comm(x, new_annotation)`).
    pub fn comm(&mut self, input: TensorId, targets: Vec<Annotation>) -> Result<TensorId> {
        self.check_strategies(&targets)?;
        let (name, shape, dtype) = {
            let t = &self.tensors[input];
            (format!("{}'", t.name), t.shape.clone(), t.dtype)
        };
        let out = self.add_tensor(&name, shape, dtype);
        let op = self.add_op(OpKind::Comm, vec![input], out);
        self.tensors[out].annotations = targets.iter().cloned().map(Some).collect();
        self.ops[op].declared = targets.into_iter().map(Some).collect();
        Ok(out)
    }

    /// Elementwise unary op.
    pub fn unary(&mut self, kind: UnaryKind, input: TensorId) -> TensorId {
        let (name, shape, dtype) = {
            let t = &self.tensors[input];
            (format!("{kind:?}({})", t.name), t.shape.clone(), t.dtype)
        };
        let out = self.add_tensor(&name, shape, dtype);
        self.add_op(OpKind::Unary(kind), vec![input], out);
        out
    }

    /// Elementwise add.
    pub fn add(&mut self, a: TensorId, b: TensorId) -> Result<TensorId> {
        if self.tensors[a].shape != self.tensors[b].shape {
            return Err(Error::Graph(format!(
                "add shape mismatch: {:?} vs {:?}",
                self.tensors[a].shape, self.tensors[b].shape
            )));
        }
        let (name, shape, dtype) = {
            let t = &self.tensors[a];
            (format!("({}+{})", t.name, self.tensors[b].name), t.shape.clone(), t.dtype)
        };
        let out = self.add_tensor(&name, shape, dtype);
        self.add_op(OpKind::Add, vec![a, b], out);
        Ok(out)
    }

    /// Matrix product `X[..., k] @ W[k, n]` (W must be 2-D).
    pub fn dot(&mut self, x: TensorId, w: TensorId) -> Result<TensorId> {
        let xs = self.tensors[x].shape.clone();
        let ws = self.tensors[w].shape.clone();
        if ws.len() != 2 {
            return Err(Error::Graph("dot: W must be 2-D".into()));
        }
        if xs.is_empty() {
            return Err(Error::Graph("dot: X must have rank >= 1".into()));
        }
        if xs[xs.len() - 1] != ws[0] {
            return Err(Error::Graph(format!(
                "dot: contraction mismatch {} vs {}",
                xs[xs.len() - 1],
                ws[0]
            )));
        }
        let mut out_shape = xs[..xs.len() - 1].to_vec();
        out_shape.push(ws[1].clone());
        let name = format!("({}@{})", self.tensors[x].name, self.tensors[w].name);
        let out = self.add_tensor(&name, out_shape, self.tensors[x].dtype);
        self.add_op(OpKind::Dot, vec![x, w], out);
        Ok(out)
    }

    /// Reduce over `dim`.
    pub fn sum(&mut self, input: TensorId, dim: u32) -> Result<TensorId> {
        let shape = self.tensors[input].shape.clone();
        if dim as usize >= shape.len() {
            return Err(Error::Graph(format!("sum dim {dim} out of rank {}", shape.len())));
        }
        let mut out_shape = shape;
        out_shape.remove(dim as usize);
        let name = format!("sum({}, {dim})", self.tensors[input].name);
        let dtype = self.tensors[input].dtype;
        let out = self.add_tensor(&name, out_shape, dtype);
        self.add_op(OpKind::Sum { dim }, vec![input], out);
        Ok(out)
    }

    /// Reshape (sharding restricted to dim 0, see [`OpKind::Reshape`]).
    pub fn reshape(&mut self, input: TensorId, new_shape: Vec<SymDim>) -> TensorId {
        let name = format!("reshape({})", self.tensors[input].name);
        let dtype = self.tensors[input].dtype;
        let out = self.add_tensor(&name, new_shape, dtype);
        self.add_op(OpKind::Reshape, vec![input], out);
        out
    }

    /// Artifact-backed compute (engine path): annotation-transparent on its
    /// first input.
    pub fn artifact_call(
        &mut self,
        artifact: &str,
        inputs: Vec<TensorId>,
        out_name: &str,
        out_shape: Vec<SymDim>,
        dtype: DType,
    ) -> TensorId {
        let out = self.add_tensor(out_name, out_shape, dtype);
        self.add_op(OpKind::ArtifactCall { artifact: artifact.to_string() }, inputs, out);
        out
    }

    /// §6.1 — register an additional strategy (appends one annotation slot
    /// to every tensor; leaves/CommOps must then be given their new
    /// annotation via [`Graph::declare_for_strategy`]).
    pub fn add_strategy(&mut self) -> usize {
        let k = self.num_strategies;
        self.num_strategies += 1;
        for t in &mut self.tensors {
            t.annotations.push(None);
        }
        for op in &mut self.ops {
            if !op.declared.is_empty() {
                op.declared.push(None);
            }
        }
        k
    }

    /// Declare the annotation of a leaf/CommOp output for a (new) strategy.
    pub fn declare_for_strategy(
        &mut self,
        tensor: TensorId,
        strategy: usize,
        ann: Annotation,
    ) -> Result<()> {
        let op_id = self.tensors[tensor]
            .producer
            .ok_or_else(|| Error::Graph("tensor has no producer".into()))?;
        if strategy >= self.num_strategies {
            return Err(Error::Graph(format!("strategy {strategy} out of range")));
        }
        let n = self.num_strategies;
        let op = &mut self.ops[op_id];
        match op.kind {
            OpKind::Placeholder | OpKind::Parameter | OpKind::Comm => {
                if op.declared.len() < n {
                    op.declared.resize(n, None);
                }
                op.declared[strategy] = Some(ann.clone());
                self.tensors[tensor].annotations[strategy] = Some(ann);
                Ok(())
            }
            _ => Err(Error::Graph("only leaves and CommOps carry declared annotations".into())),
        }
    }

    /// All ops in topological order (construction order is topological by
    /// builder invariant; verified in debug builds).
    pub fn topo(&self) -> &[Op] {
        #[cfg(debug_assertions)]
        for op in &self.ops {
            for &i in &op.inputs {
                debug_assert!(
                    self.tensors[i].producer.map(|p| p < op.id).unwrap_or(true),
                    "graph not topologically ordered"
                );
            }
        }
        &self.ops
    }

    /// Tensor accessor.
    pub fn tensor(&self, id: TensorId) -> &Tensor {
        &self.tensors[id]
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hspmd::{DeviceGroup, DistStates};

    fn dp2(name_dim: u32) -> Annotation {
        Annotation::spmd(DeviceGroup::range(0, 2), DistStates::split(name_dim, 2)).unwrap()
    }

    #[test]
    fn builder_wires_producers() {
        let mut g = Graph::new(1);
        let x = g
            .placeholder("X", lits(&[4, 8]), DType::F32, vec![dp2(0)])
            .unwrap();
        let y = g.unary(UnaryKind::Gelu, x);
        assert_eq!(g.tensor(y).producer, Some(1));
        assert_eq!(g.ops[1].inputs, vec![x]);
    }

    #[test]
    fn dot_shape_inference() {
        let mut g = Graph::new(1);
        let x = g
            .placeholder("X", lits(&[2, 4, 8]), DType::F32, vec![dp2(0)])
            .unwrap();
        let w = g
            .parameter("W", lits(&[8, 16]), DType::F32, vec![dp2(1)])
            .unwrap();
        let y = g.dot(x, w).unwrap();
        assert_eq!(g.tensor(y).shape, lits(&[2, 4, 16]));
    }

    #[test]
    fn dot_rejects_contraction_mismatch() {
        let mut g = Graph::new(1);
        let x = g.placeholder("X", lits(&[2, 4]), DType::F32, vec![dp2(0)]).unwrap();
        let w = g.parameter("W", lits(&[8, 16]), DType::F32, vec![dp2(1)]).unwrap();
        assert!(g.dot(x, w).is_err());
    }

    #[test]
    fn strategy_addition_extends_slots() {
        let mut g = Graph::new(1);
        let x = g.placeholder("X", lits(&[4]), DType::F32, vec![dp2(0)]).unwrap();
        let k = g.add_strategy();
        assert_eq!(k, 1);
        assert_eq!(g.tensor(x).annotations.len(), 2);
        g.declare_for_strategy(x, 1, dp2(0)).unwrap();
        assert!(g.ops[0].declared[1].is_some());
    }

    #[test]
    fn sum_drops_dim() {
        let mut g = Graph::new(1);
        let x = g.placeholder("X", lits(&[2, 4, 8]), DType::F32, vec![dp2(0)]).unwrap();
        let s = g.sum(x, 1).unwrap();
        assert_eq!(g.tensor(s).shape, lits(&[2, 8]));
    }
}
