//! §5.5 — Symbolic shapes.
//!
//! Annotations define the *pattern* of sharding; the concrete shapes of the
//! shards are resolved at runtime. Tensor metadata therefore carries
//! [`SymDim`]s — either literal extents or a named symbol with a rational
//! scale (`B`, `B/2`, `3*S/4`, …). Symbols are bound to arithmetic values
//! when concrete inputs arrive; binding *verifies* divisibility so invalid
//! symbol usage is rejected instead of silently mis-sharding (footnote 3).

use std::collections::HashMap;

use crate::{Error, Result};

/// A symbolic dimension: `Lit(n)` or `sym * num / den`.
#[derive(Clone, PartialEq, Eq, Debug, Hash)]
pub enum SymDim {
    /// A concrete extent.
    Lit(u64),
    /// A scaled symbol (`name * num / den`).
    Sym {
        /// Symbol name, e.g. `"B"` (batch) or `"S"` (sequence).
        name: String,
        /// Numerator scale.
        num: u64,
        /// Denominator scale.
        den: u64,
    },
}

impl SymDim {
    /// A fresh unscaled symbol.
    pub fn sym(name: &str) -> SymDim {
        SymDim::Sym { name: name.to_string(), num: 1, den: 1 }
    }

    /// Constraint-preserving division (e.g. splitting the batch dimension
    /// `B` two ways yields `B/2`, §5.5).
    pub fn div(&self, k: u64) -> Result<SymDim> {
        if k == 0 {
            return Err(Error::SymbolicShape("division by zero".into()));
        }
        match self {
            SymDim::Lit(n) => {
                if n % k != 0 {
                    return Err(Error::SymbolicShape(format!("{n} not divisible by {k}")));
                }
                Ok(SymDim::Lit(n / k))
            }
            SymDim::Sym { name, num, den } => Ok(SymDim::Sym {
                name: name.clone(),
                num: *num,
                den: den.checked_mul(k).ok_or_else(|| Error::SymbolicShape("overflow".into()))?,
            }),
        }
    }

    /// Multiplication by a constant.
    pub fn mul(&self, k: u64) -> SymDim {
        match self {
            SymDim::Lit(n) => SymDim::Lit(n * k),
            SymDim::Sym { name, num, den } => {
                SymDim::Sym { name: name.clone(), num: num * k, den: *den }
            }
        }
    }

    /// Bind against a symbol table, verifying integrality.
    pub fn resolve(&self, binding: &Binding) -> Result<u64> {
        match self {
            SymDim::Lit(n) => Ok(*n),
            SymDim::Sym { name, num, den } => {
                let v = binding.get(name).ok_or_else(|| {
                    Error::SymbolicShape(format!("unbound symbol `{name}`"))
                })?;
                let scaled = v.checked_mul(*num).ok_or_else(|| {
                    Error::SymbolicShape(format!("overflow binding `{name}`"))
                })?;
                if scaled % den != 0 {
                    return Err(Error::SymbolicShape(format!(
                        "symbol `{name}`={v} scaled by {num}/{den} is not integral \
                         (invalid symbol usage would cause a shape mismatch)"
                    )));
                }
                Ok(scaled / den)
            }
        }
    }
}

impl std::fmt::Display for SymDim {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            SymDim::Lit(n) => write!(f, "{n}"),
            SymDim::Sym { name, num: 1, den: 1 } => write!(f, "{name}"),
            SymDim::Sym { name, num, den: 1 } => write!(f, "{num}{name}"),
            SymDim::Sym { name, num: 1, den } => write!(f, "{name}/{den}"),
            SymDim::Sym { name, num, den } => write!(f, "{num}{name}/{den}"),
        }
    }
}

/// Symbol table bound at runtime when concrete inputs arrive.
#[derive(Clone, Debug, Default)]
pub struct Binding {
    values: HashMap<String, u64>,
}

impl Binding {
    /// Empty binding.
    pub fn new() -> Self {
        Self::default()
    }

    /// Bind `name = value` (overwrites).
    pub fn set(&mut self, name: &str, value: u64) -> &mut Self {
        self.values.insert(name.to_string(), value);
        self
    }

    /// Look up a symbol.
    pub fn get(&self, name: &str) -> Option<u64> {
        self.values.get(name).copied()
    }

    /// Resolve a whole symbolic shape.
    pub fn shape(&self, dims: &[SymDim]) -> Result<Vec<u64>> {
        dims.iter().map(|d| d.resolve(self)).collect()
    }
}

/// Convenience constructor for literal shapes.
pub fn lits(dims: &[u64]) -> Vec<SymDim> {
    dims.iter().map(|&d| SymDim::Lit(d)).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_resolution() {
        let b = Binding::new();
        assert_eq!(SymDim::Lit(7).resolve(&b).unwrap(), 7);
    }

    #[test]
    fn symbol_binding_and_scaling() {
        let mut b = Binding::new();
        b.set("B", 64);
        let half = SymDim::sym("B").div(2).unwrap();
        assert_eq!(half.resolve(&b).unwrap(), 32);
        assert_eq!(half.mul(4).resolve(&b).unwrap(), 128);
    }

    #[test]
    fn rejects_non_integral() {
        let mut b = Binding::new();
        b.set("B", 10);
        let third = SymDim::sym("B").div(3).unwrap();
        assert!(third.resolve(&b).is_err());
    }

    #[test]
    fn rejects_unbound() {
        let b = Binding::new();
        assert!(SymDim::sym("S").resolve(&b).is_err());
    }

    #[test]
    fn literal_div_checks() {
        assert!(SymDim::Lit(9).div(2).is_err());
        assert_eq!(SymDim::Lit(8).div(2).unwrap(), SymDim::Lit(4));
    }

    #[test]
    fn display_forms() {
        assert_eq!(SymDim::sym("B").to_string(), "B");
        assert_eq!(SymDim::sym("B").div(2).unwrap().to_string(), "B/2");
        assert_eq!(SymDim::sym("B").mul(3).to_string(), "3B");
    }

    #[test]
    fn shape_resolution() {
        let mut b = Binding::new();
        b.set("B", 4).set("S", 128);
        let shape = vec![SymDim::sym("B"), SymDim::sym("S"), SymDim::Lit(512)];
        assert_eq!(b.shape(&shape).unwrap(), vec![4, 128, 512]);
    }
}
